"""§Perf hillclimb runner: lower a cell with config overrides, record the
roofline delta vs the baseline artifact.

    PYTHONPATH=src python -m benchmarks.perf_iter --cell qwen3-0.6b/train_4k \
        --variant mp_attn
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os


VARIANTS = {
    # H: fp32 q/k/v copies + fp32 probabilities dominate attention HBM
    # traffic -> bf16 matmuls with fp32 accumulation halve those bytes.
    "mp_attn": dict(model=dict(mp_attn=True)),
    # H: fp32 params => fp32 grads => fp32 DP all-reduce; bf16 params with
    # fp32 math in the optimizer (cast-up/cast-down) halve grad bytes and
    # param/optimizer memory.
    "bf16_params": dict(arch=dict(param_dtype="bfloat16",
                                  moment_dtype="bfloat16")),
    "mp_attn+bf16": dict(model=dict(mp_attn=True),
                         arch=dict(param_dtype="bfloat16",
                                   moment_dtype="bfloat16")),
    # H: more microbatches shrink MoE dispatch buffers but repeat per-pass
    # collectives; fewer do the reverse.
    "accum2": dict(arch=dict(accum_steps=2)),
    "accum8": dict(arch=dict(accum_steps=8)),
    # H: TP=16 is mis-sized for sub-1B models — the per-layer activation
    # all-reduce over the model axis dwarfs everything.  Pure 256-way DP
    # (params replicated, batch over all axes) removes TP collectives
    # entirely; the only collective left is the grad all-reduce.
    "pure_dp": dict(arch=dict(
        param_rules={"embed": None, "heads": None, "kv_heads": None,
                     "head_dim": None, "ffn": None, "vocab": None,
                     "layers": None},
        lm_batch_axes="ALL")),
    "pure_dp+bf16": dict(arch=dict(
        param_rules={"embed": None, "heads": None, "kv_heads": None,
                     "head_dim": None, "ffn": None, "vocab": None,
                     "layers": None},
        lm_batch_axes="ALL", param_dtype="bfloat16",
        moment_dtype="bfloat16")),
    # H(fm): the dense DP all-reduce of the (41M, 10) table gradient wastes
    # ~94% of its bytes (a 65536-batch touches <6% of rows).  Fully
    # sharding the table over (data, model) removes the replication — grads
    # become owner-local and only the looked-up rows move.
    "full_shard_table": dict(arch=dict(
        param_rules={"table_rows": ("data", "model")})),
    # H: XLA keeps the gradient all-reduce in f32 regardless of param
    # dtype; casting the LOCAL partial grads to bf16 before they cross the
    # sharding boundary halves the reduce bytes.
    "bf16_grads": dict(arch=dict(grad_dtype="bfloat16")),
}


def run_variant(cell: str, variant: str, mesh: str = "single",
                out_dir: str = "artifacts/perf"):
    from repro.configs.base import get_arch
    from repro.launch.dryrun import run_cell
    arch_id, shape = cell.split("/")
    spec = VARIANTS[variant]
    arch = get_arch(arch_id)
    if "model" in spec:
        arch = dataclasses.replace(
            arch, model_cfg=dataclasses.replace(arch.model_cfg,
                                                **spec["model"]))
    if "arch" in spec:
        arch = dataclasses.replace(arch, **spec["arch"])
    rec = run_cell(arch_id, shape, mesh,
                   out_dir=os.path.join(out_dir, variant), arch_obj=arch)
    base_path = f"artifacts/dryrun/{mesh}/{arch_id}__{shape}.json"
    if os.path.exists(base_path) and rec["status"] == "ok":
        base = json.load(open(base_path))
        if base["status"] == "ok":
            print(f"--- {cell} [{variant}] vs baseline ---")
            for k in ("compute_s", "memory_s", "collective_s"):
                b, v = base["roofline"][k], rec["roofline"][k]
                print(f"  {k:14s} {b:.3e} -> {v:.3e} "
                      f"({(1 - v / max(b, 1e-30)) * 100:+.1f}% better)"
                      .replace("+-", "-"))
            mb = base["memory"]["total_bytes_per_device"] / 1e9
            mv = rec["memory"]["total_bytes_per_device"] / 1e9
            print(f"  {'mem/device':14s} {mb:.2f} GB -> {mv:.2f} GB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    run_variant(args.cell, args.variant, args.mesh)


if __name__ == "__main__":
    main()
