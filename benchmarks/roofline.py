"""Roofline report generator: reads artifacts/dryrun/*/*.json and renders
the EXPERIMENTS.md §Roofline table with MODEL_FLOPS ratios.

    PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

import numpy as np


def _lm_active_params(arch) -> float:
    """Active (per-token) non-embedding params for 6·N·D MODEL_FLOPS."""
    import jax
    import jax.numpy as jnp
    from repro.models import transformer as T
    cfg = arch.model_cfg
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    shapes = jax.eval_shape(lambda k: T.init_lm(k, cfg), key)
    total = 0.0
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    mc = cfg.moe
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        n = float(np.prod(leaf.shape))
        if "embed" in name and "blocks" not in name:
            continue  # embeddings excluded from 6ND
        # routed expert stacks are (L, E, d, f): only top_k/E active
        if mc is not None and "ffn" in name and "shared" not in name \
                and leaf.ndim >= 4 and leaf.shape[-3] == mc.n_experts:
            n *= mc.top_k / mc.n_experts
        total += n
    return total


def model_flops(arch, shape) -> float | None:
    if arch.family != "lm":
        return None
    n_active = _lm_active_params(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/slot


def load(dir_: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*", "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def render(recs, n_chips_by_mesh=None) -> str:
    from repro.configs.base import get_arch
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s "
        "| dominant | mem/dev GB | MODEL/HLO flops | source |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP | | | | | {r['reason'][:50]} | |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | | | | | {r.get('error', '')[:60]} | |")
            continue
        t = r["roofline"]
        mem = r["memory"].get("total_bytes_per_device", 0) / 1e9
        ratio = ""
        try:
            arch = get_arch(r["arch"])
            mf = model_flops(arch, arch.shapes[r["shape"]])
            if mf:
                n_chips = int(np.prod(r["mesh_shape"]))
                hlo_total = r["flops_per_device"] * n_chips
                ratio = f"{mf / hlo_total:.2f}"
        except Exception:
            pass
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} "
            f"| {t['collective_s']:.2e} | {r['dominant'].replace('_s', '')} "
            f"| {mem:.2f} | {ratio} | {r.get('cost_source', '')[:14]} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    print(render(recs))


if __name__ == "__main__":
    main()
