"""§4.1 kernel benchmarks: pull-kernel layouts and the TC-call model.

Wall times here run the Pallas bodies in interpret mode (CPU container) —
meaningful only relative to each other.  The ``derived`` column carries the
hardware-independent §4.1 model: calls-per-128-slices for the SotA (BRS)
layout vs BLEST's (16 -> 2 on the paper's m8n8k128; on TPU, 1 VPU
AND+popcount op resolves 4 slice dot-products, and 1 MXU int8 call resolves
128x128 popcount dot-products for multi-source)."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import fmt_row
from repro.kernels import bit_spmm, bvss_pull
from repro.kernels.ref import bit_spmm_ref, bvss_pull_ref


def _med_time(f, *args, reps=5):
    f(*args)  # compile
    ts = []
    for _ in range(reps):
        t0 = time.time()
        np.asarray(f(*args))
        ts.append(time.time() - t0)
    return float(np.median(ts))


def run(verbose: bool = True):
    rng = np.random.default_rng(0)
    rows = []
    B = 4096
    masks = jnp.asarray(rng.integers(0, 2 ** 32, (B, 32),
                                     dtype=np.uint64).astype(np.uint32))
    fb = jnp.asarray(rng.integers(0, 2 ** 32, (B,),
                                  dtype=np.uint64).astype(np.uint32))
    for layout in ("lanes", "rows"):
        sec = _med_time(lambda m, f: bvss_pull(m, f, layout=layout),
                        masks, fb)
        rows.append(fmt_row(
            f"kernel/bvss_pull[{layout}]", sec * 1e6,
            f"slices={B * 128};dots_per_vpu_op=4;"
            f"calls_per_128_slices=2(paper)_vs_16(brs)"))
    sec = _med_time(bvss_pull_ref, masks, fb)
    rows.append(fmt_row("kernel/bvss_pull[jnp-ref]", sec * 1e6, ""))

    R, C, S = 512, 512, 128
    a = rng.integers(0, 2 ** 32, (R, C // 32),
                     dtype=np.uint64).astype(np.uint32)
    x = rng.integers(0, 2, (C, S)).astype(np.int8)
    sec = _med_time(bit_spmm, jnp.asarray(a), jnp.asarray(x))
    rows.append(fmt_row(
        "kernel/bit_spmm[mxu]", sec * 1e6,
        f"dots_per_mma={128 * 128};paper_m8n8k128_dots=64;"
        f"sources={S}"))
    sec = _med_time(bit_spmm_ref, jnp.asarray(a), jnp.asarray(x))
    rows.append(fmt_row("kernel/bit_spmm[jnp-ref]", sec * 1e6, ""))
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
