"""PR-1 fused level-step pipeline benchmark: fused engines vs the seed.

Per graph of the suite, times (median s/BFS over a source set, post-jit):

* ``blest_seed`` / ``blest_lazy_seed`` — the frozen pre-PR implementation
  (sequential per-block while_loop, jnp pull, three separate dense tail
  passes; see ``benchmarks/seed_baseline.py``);
* ``blest_fused`` / ``blest_lazy_fused`` — the live engine: batched
  bucketed pull through Pallas ``bvss_pull`` + fused
  ``finalize_pack_sweep`` (interpret mode on CPU, honest numbers);
* ``blest_fused_jnp`` / ``blest_lazy_fused_jnp`` — the same fused pipeline
  with the pure-jnp pull/finalise fallbacks, isolating the batching win
  from Pallas-interpret overhead.

``--json`` writes the machine-readable perf-trajectory artifact
(``BENCH_pr1.json``): per-engine per-graph seconds, MTEPS, level count,
plus fused-vs-seed speedups and their geomean.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import (bench_envelope, fmt_row, geomean, graph_suite,
                               time_engine)
from benchmarks.seed_baseline import make_seed_blest_bfs
from repro.core import build_bvss, reference_bfs
from repro.core.bfs import INF, BlestProblem, make_blest_bfs


def _engine_builders():
    return {
        "blest_seed": lambda pr: make_seed_blest_bfs(pr, lazy=False),
        "blest_lazy_seed": lambda pr: make_seed_blest_bfs(pr, lazy=True),
        "blest_fused": lambda pr: make_blest_bfs(pr, lazy=False),
        "blest_lazy_fused": lambda pr: make_blest_bfs(pr, lazy=True),
        "blest_fused_jnp": lambda pr: make_blest_bfs(pr, lazy=False,
                                                     use_kernels=False),
        "blest_lazy_fused_jnp": lambda pr: make_blest_bfs(
            pr, lazy=True, use_kernels=False),
    }


def run(scale: int = 9, n_sources: int = 2, json_path: str | None = None,
        verbose: bool = True):
    suite = graph_suite(scale)
    builders = _engine_builders()
    graphs_out = {}
    for gname, g in suite.items():
        rng = np.random.default_rng(0)
        cand = np.flatnonzero(g.out_degree > 0)
        srcs = rng.choice(cand, size=min(n_sources, len(cand)),
                          replace=False)
        b = build_bvss(g)
        problem = BlestProblem.build(b)
        ref_levels = reference_bfs(g, int(srcs[0]))
        n_levels = (int(ref_levels[ref_levels != INF].max())
                    if (ref_levels != INF).any() else 0)
        engines_out = {}
        for ename, build in builders.items():
            fn = build(problem)
            sec = time_engine(fn, srcs)
            mteps = (g.m / sec / 1e6) if sec > 0 else None
            engines_out[ename] = {"sec": sec, "mteps": mteps}
            if verbose:
                mteps_s = f"{mteps:.3f}" if mteps is not None else "inf"
                print(fmt_row(f"bench_fused/{gname}/{ename}", sec * 1e6,
                              f"mteps={mteps_s};levels={n_levels}"))
        speedup = {
            "blest": engines_out["blest_seed"]["sec"]
            / max(engines_out["blest_fused"]["sec"], 1e-12),
            "blest_lazy": engines_out["blest_lazy_seed"]["sec"]
            / max(engines_out["blest_lazy_fused"]["sec"], 1e-12),
            "blest_jnp": engines_out["blest_seed"]["sec"]
            / max(engines_out["blest_fused_jnp"]["sec"], 1e-12),
            "blest_lazy_jnp": engines_out["blest_lazy_seed"]["sec"]
            / max(engines_out["blest_lazy_fused_jnp"]["sec"], 1e-12),
        }
        graphs_out[gname] = {
            "n": int(g.n), "m": int(g.m), "num_vss": int(b.num_vss),
            "levels": n_levels, "engines": engines_out,
            "speedup_fused_vs_seed": speedup,
        }
    summary = {
        f"geomean_speedup_{k}": geomean(
            [go["speedup_fused_vs_seed"][k] for go in graphs_out.values()])
        for k in ("blest", "blest_lazy", "blest_jnp", "blest_lazy_jnp")
    }
    out = {
        **bench_envelope("pr1_fused_level_pipeline", scale),
        "n_sources": int(n_sources),
        "note": ("wall-clock on this host; on CPU the Pallas kernels run in "
                 "interpret mode, so *_fused isolates pipeline fusion + "
                 "batching while *_fused_jnp shows the same pipeline with "
                 "jnp stand-ins (no interpreter overhead)"),
        "graphs": graphs_out,
        "summary": summary,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=False)
        if verbose:
            print(f"# wrote {json_path}")
    if verbose:
        for k, v in summary.items():
            print(f"# {k}={v:.2f}x")
    return out


if __name__ == "__main__":
    run(json_path="BENCH_pr1.json")
