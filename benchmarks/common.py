"""Shared benchmark utilities: the graph suite (CPU-scale stand-ins for the
paper's benchmark families) and timing/work-model helpers.

The paper's speedups are measured on an H200; this container is a single
CPU core, so wall-clock ratios between engines are dominated by interpreter
and dispatch overheads rather than the mechanisms the paper isolates.  Each
benchmark therefore reports BOTH:

* wall time (measured here, honest but CPU-flavoured), and
* the *modeled TC work*: the number of 128-slice pull operations the engine
  issues (frontier-aware queue vs frontier-oblivious sweep), which is the
  hardware-independent quantity behind the paper's Table-2 speedups.
"""
from __future__ import annotations

import math
import time

import numpy as np

from repro.core import BVSS, build_bvss, reference_bfs
from repro.graphs import Graph
from repro.graphs import generators as gen

INF = np.int32(np.iinfo(np.int32).max)


def graph_suite(scale: int = 11) -> dict[str, Graph]:
    """CPU-scale stand-ins for the paper's graph families."""
    side = int((1 << scale) ** 0.5)
    return {
        "kron": gen.rmat(scale, 16, seed=1),            # GAP-kron-like
        "urand": gen.erdos_renyi(1 << scale, 16.0, seed=2),  # GAP-urand-like
        "road": gen.grid2d(side, side, shuffle=True, seed=3),  # GAP-road-like
        "web": gen.clustered((1 << scale) // 64, 64, seed=4),  # crawl-like
        "rgg": gen.rgg2d(1 << scale, seed=5),           # rgg_24-like
        "star": gen.star(1 << scale),                   # vsp_msc-like
    }


def time_engine(fn, sources, *, reps: int = 1) -> float:
    """Median seconds per BFS over the source set (post-compile)."""
    fn(int(sources[0]))  # compile + warm
    times = []
    for s in sources:
        t0 = time.time()
        np.asarray(fn(int(s)))
        times.append(time.time() - t0)
    return float(np.median(times))


def modeled_tc_pulls(g: Graph, b: BVSS, src: int, *,
                     frontier_aware: bool) -> int:
    """Exact number of VSS pull operations a queue-based (frontier-aware)
    or sweep-based (frontier-oblivious) engine performs for this BFS,
    derived from the oracle level sets (no device run needed)."""
    levels = reference_bfs(g, src)
    n_levels = int(levels[levels != INF].max()) if (levels != INF).any() \
        else 0
    if not frontier_aware:
        return b.num_vss * max(n_levels, 1)
    sigma = b.sigma
    vss_per_set = np.diff(b.real_ptrs).astype(np.int64)
    total = 0
    for lvl in range(0, n_levels):
        verts = np.flatnonzero(levels == lvl)
        sets = np.unique(verts // sigma)
        total += int(vss_per_set[sets].sum())
    return total


def median_sec(f, reps: int = 3) -> float:
    """Median seconds per call (post-warm) — the perf suites' timing
    idiom: single-shot wall clocks flip CPU ratios by 2x."""
    ts = []
    for _ in range(reps):
        t0 = time.time()
        f()
        ts.append(time.time() - t0)
    return float(np.median(ts))


def fmt_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def geomean(xs) -> float:
    """Geometric mean of the positive entries (0.0 if none) — the summary
    statistic shared by every BENCH_prN suite."""
    xs = [x for x in xs if x and x > 0]
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 0.0


def bench_envelope(bench: str, scale: int) -> dict:
    """The metadata envelope shared by every BENCH_prN artifact/suite
    (one definition so backend/interpret/scale/timestamp cannot drift
    between the top-level artifact and its nested suites)."""
    import jax

    return {
        "bench": bench,
        "backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() == "cpu",
        "scale": scale,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
