"""PR-8 scale sweep: MTEPS and device-memory trajectory of the fused
BLEST engine over growing RMAT graphs.

One lane, one graph family (RMAT, avg degree 16 — the paper's kron-like
scaling family), scales 2^10 .. 2^14: per scale the full ``prepare``
pipeline runs (ordering + BVSS + policy + fused engine) and the sweep
records

* MTEPS — million traversed edges per second, ``m / median_bfs_sec /
  1e6`` over a fixed source sample (the paper's headline unit, honest
  CPU-flavoured absolute numbers);
* the peak static device footprint, ``BVSS.memory_bytes()`` (Table-4
  breakdown: bvss + dynamic working set + level array) — the quantity
  that must scale with BVSS words, not n²/32 dense bits.

The SMALLEST scale is oracle-verified against ``reference_bfs`` before
any timing is trusted (the larger scales share the same engine build
path, and verifying 2^14 against the NumPy oracle would dominate the
sweep).  ``--quick`` stops at 2^11 — the CI lane; the weekly bench.yml
runs the full depth.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from benchmarks.common import bench_envelope, fmt_row

FULL_SCALES = (10, 11, 12, 13, 14)
QUICK_SCALES = (10, 11)


def run(scales=None, quick: bool = False, n_sources: int = 3,
        json_path: str | None = None, verbose: bool = True) -> dict:
    from repro.core import reference_bfs
    from repro.core.policy import prepare
    from repro.graphs import generators as gen

    if scales is None:
        scales = QUICK_SCALES if quick else FULL_SCALES
    scales = sorted(int(s) for s in scales)

    scales_out = {}
    all_verified = True
    for si, sc in enumerate(scales):
        g = gen.rmat(sc, 16, seed=1)
        prep = prepare(g, w=512)
        rng = np.random.default_rng(0)
        srcs = [int(s) for s in rng.integers(0, g.n, n_sources)]
        verified = True
        if si == 0:  # oracle-verify the smallest scale (shared build path)
            for s in srcs:
                verified &= bool((prep.levels(s) == reference_bfs(g, s)
                                  ).all())
            assert verified, f"scale {sc}: engine diverges from oracle"
        all_verified &= verified
        prep.levels(srcs[0])                      # compile + warm
        ts = []
        import time
        for s in srcs:
            t0 = time.time()
            np.asarray(prep.levels(s))
            ts.append(time.time() - t0)
        t_med = float(np.median(ts))
        mem = prep.bvss.memory_bytes()
        scales_out[str(sc)] = {
            "n": int(g.n), "m": int(g.m),
            "ordering": prep.ordering, "engine": prep.engine_name,
            "n_sources": len(srcs),
            "median_bfs_sec": t_med,
            "mteps": g.m / max(t_med, 1e-12) / 1e6,
            "memory_bytes": mem,
            "peak_memory_bytes": int(mem["total"]),
            "verified": verified,
        }
        if verbose:
            so = scales_out[str(sc)]
            print(fmt_row(f"bench_scale/rmat{sc}", t_med * 1e6,
                          f"mteps={so['mteps']:.2f} "
                          f"mem={so['peak_memory_bytes'] / 1e6:.2f}MB"))

    summary = {
        "scales": scales,
        "max_mteps": max(so["mteps"] for so in scales_out.values()),
        "peak_memory_bytes_largest": scales_out[str(scales[-1])
                                                ]["peak_memory_bytes"],
        "all_verified": all_verified,
    }
    out = {
        **bench_envelope("pr8_scale", scales[-1]),
        "family": "rmat_deg16",
        "note": ("MTEPS = m / median fused-BFS seconds / 1e6 over a fixed "
                 "source sample per scale; peak_memory_bytes is the "
                 "BVSS.memory_bytes() Table-4 total (static BVSS + dynamic "
                 "working set + level array).  Smallest scale is "
                 "oracle-verified; absolute MTEPS are CPU-flavoured "
                 "(interpret-mode kernels), the trajectory across scales "
                 "is the signal"),
        "scales": scales_out,
        "summary": summary,
    }
    if verbose:
        print(f"# max_mteps={summary['max_mteps']:.2f} "
              f"(verified={all_verified})")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=False)
        if verbose:
            print(f"# wrote {json_path}")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help=f"scales {QUICK_SCALES} instead of {FULL_SCALES}")
    ap.add_argument("--scales", type=int, nargs="+", default=None)
    ap.add_argument("--sources", type=int, default=3)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    run(scales=args.scales, quick=args.quick, n_sources=args.sources,
        json_path=args.json)


if __name__ == "__main__":
    main()
