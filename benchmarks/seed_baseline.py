"""FROZEN seed BLEST engine — perf baseline only, not a production path.

This is the pre-PR-1 implementation of ``make_blest_bfs`` kept verbatim so
``benchmarks/bench_fused.py`` can report the fused-pipeline speedup against
the exact code it replaced: a *sequential* per-block ``jax.lax.while_loop``
around a pure-jnp pull, followed by three separate dense passes (inline
finalise, ``_pack_bits``, ``rebuild_queue``).  Do not use it outside
benchmarks; the live engine lives in ``repro.core.bfs``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.bfs import (INF, BlestProblem, PullFn, _frontier_bytes,
                            _pack_bits, pull_vss_jnp)


def make_seed_blest_bfs(problem: BlestProblem, *, lazy: bool,
                        block: int = 256, pull_impl: PullFn | None = None,
                        max_levels: int | None = None) -> Callable:
    """Seed Alg. 2/3 engine: sequential block loop + separate dense passes."""
    p = problem
    dev = p.dev
    sigma = p.sigma
    qcap = p.num_vss + block  # pad so dynamic_slice blocks always fit
    dummy_vss = p.num_vss
    pull = pull_impl or pull_vss_jnp
    n_setbits = p.n_sets * sigma
    n_pad = p.n_fwords * 32
    max_lv = max_levels if max_levels is not None else p.n + 1

    vss_ids_all = jnp.arange(p.num_vss, dtype=jnp.int32)

    def rebuild_queue(new_bits: jnp.ndarray):
        set_active = new_bits[:n_setbits].reshape(p.n_sets, sigma).any(axis=1)
        vss_active = set_active[dev.virtual_to_real[:p.num_vss]]
        pos = jnp.cumsum(vss_active.astype(jnp.int32)) - 1
        idx = jnp.where(vss_active, pos, qcap)  # OOB -> dropped
        Q = jnp.full((qcap,), dummy_vss, dtype=jnp.int32)
        Q = Q.at[idx].set(vss_ids_all, mode="drop")
        return Q, vss_active.sum().astype(jnp.int32)

    def process_blocks(F, Q, count, lvl, levels, marks):
        n_blocks = (count + block - 1) // block

        def body(carry):
            i, levels, marks = carry
            ids = jax.lax.dynamic_slice(Q, (i * block,), (block,))
            fbytes = _frontier_bytes(F, dev.virtual_to_real[ids], sigma)
            hits = pull(dev.masks[ids], fbytes, sigma)      # (B, spw, 32)
            rows = dev.row_ids[ids].reshape(-1)             # (B*spw*32,)
            h = hits.reshape(-1)
            if lazy:
                marks = marks.at[rows].max(h.astype(jnp.uint8))
            else:
                upd = jnp.where(h, lvl, INF).astype(jnp.int32)
                levels = levels.at[rows].min(upd)
            return i + 1, levels, marks

        def cond(carry):
            return carry[0] < n_blocks

        _, levels, marks = jax.lax.while_loop(cond, body, (jnp.int32(0),
                                                           levels, marks))
        return levels, marks

    def bfs(src: jnp.ndarray) -> jnp.ndarray:
        src = jnp.asarray(src, dtype=jnp.int32)
        levels = jnp.full((p.n + 1,), INF, dtype=jnp.int32)
        levels = levels.at[src].set(0)
        F = jnp.zeros((p.n_fwords,), dtype=jnp.uint32)
        F = F.at[src // 32].set(jnp.uint32(1) << (src % 32).astype(jnp.uint32))
        init_bits = jnp.zeros((n_pad,), dtype=bool).at[src].set(True)
        Q, count = rebuild_queue(init_bits)
        marks0 = jnp.zeros((p.n + 1,), dtype=jnp.uint8)

        def cond(state):
            levels, F, Q, count, lvl = state
            return (count > 0) & (lvl < max_lv)

        def body(state):
            levels, F, Q, count, lvl = state
            lvl = lvl + 1
            levels, marks = process_blocks(F, Q, count, lvl, levels, marks0)
            if lazy:
                new = (marks[:p.n] > 0) & (levels[:p.n] == INF)
                levels = levels.at[:p.n].set(
                    jnp.where(new, lvl, levels[:p.n]))
            else:
                new = levels[:p.n] == lvl
            new_pad = jnp.zeros((n_pad,), dtype=bool).at[:p.n].set(new)
            F = _pack_bits(new_pad, p.n_fwords)
            Q, count = rebuild_queue(new_pad)
            return levels, F, Q, count, lvl

        state = (levels, F, Q, count, jnp.int32(0))
        levels, *_ = jax.lax.while_loop(cond, body, state)
        return levels[:p.n]

    return jax.jit(bfs)
