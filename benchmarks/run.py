"""Benchmark orchestrator — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]
                                            [--fused-only]

Prints ``name,us_per_call,derived`` CSV rows.  ``--json`` additionally runs
the perf-trajectory benches — the PR-1 fused-pipeline bench
(``benchmarks/bench_fused.py``), the PR-2 GraphSession serving bench
(``benchmarks/bench_service.py``), the PR-3 mesh-native bench
(``benchmarks/bench_dist.py``, which simulates its device mesh in a
subprocess — since PR 8 with the ``dist2d`` butterfly comm-volume block),
the PR-4/PR-5 analytics bench (``benchmarks/bench_analytics.py``,
with the closeness suite, sharded betweenness in ``dist`` and — since
PR 9 — the weighted ``sssp`` delta-stepping and ``pagerank`` suites),
the PR-7 compiled-dispatch hybrid bench (``benchmarks/bench_hybrid.py``:
direction-optimizing hybrid vs pull-only, pure-XLA lane), the PR-8
RMAT scale sweep (``benchmarks/bench_scale.py``: MTEPS + peak device
footprint over 2^10..2^14, quick mode stops at 2^11) and the PR-10
async-queue bench (``benchmarks/bench_queue.py``: RequestQueue wave
coalescing vs call-at-a-time on a Poisson-arrival stream) — and
writes one machine-readable artifact (default ``BENCH_pr10.json``) with
``fused``, ``service``, ``dist``, ``analytics``, ``hybrid``,
``scale_sweep`` and ``queue`` suites;
``--fused-only`` skips the paper tables so CI can smoke the JSON path
quickly.  CI diffs the artifact's geomean speedups against the checked-in
floors (``benchmarks/perf_gate.py``).  Roofline tables (E7) come from the
dry-run artifacts: run ``python -m repro.launch.dryrun --all`` first, then
``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller graphs (CI-speed)")
    ap.add_argument("--json", nargs="?", const="BENCH_pr10.json",
                    default=None, metavar="PATH",
                    help="run the fused-pipeline + service + dist + "
                         "analytics + hybrid + scale-sweep + queue benches "
                         "and write JSON (default %(const)s)")
    ap.add_argument("--fused-only", action="store_true",
                    help="only the JSON perf benches, skip the paper tables "
                         "(implies --json)")
    args = ap.parse_args(argv)
    scale = 9 if args.quick else 11
    t0 = time.time()
    print("name,us_per_call,derived")

    json_path = args.json
    if args.fused_only and json_path is None:
        json_path = "BENCH_pr10.json"
    if json_path is not None:
        from benchmarks import (bench_analytics, bench_dist, bench_fused,
                                bench_hybrid, bench_queue, bench_scale,
                                bench_service)
        from benchmarks.common import bench_envelope
        suite_scale = min(scale, 9 if args.quick else 10)
        fused = bench_fused.run(scale=suite_scale,
                                n_sources=2 if args.quick else 3,
                                json_path=None)
        service = bench_service.run(scale=suite_scale,
                                    n_queries=6 if args.quick else 8,
                                    json_path=None)
        dist = bench_dist.run(scale=min(scale, 8 if args.quick else 9),
                              devices=2 if args.quick else 4,
                              n_queries=4 if args.quick else 6,
                              json_path=None)
        analytics = bench_analytics.run(scale=suite_scale,
                                        n_queries=6 if args.quick else 8,
                                        n_pivots=3 if args.quick else 4,
                                        json_path=None)
        # the hybrid lane keeps scale 14 even in quick mode: the 2-bucket
        # baseline's small rung only leaves the tuned ladder room when
        # num_vss > 1024, so shrinking the graphs would benchmark nothing
        # (quick mode trims sources/reps instead)
        hybrid = bench_hybrid.run(scale=14,
                                  n_sources=2,
                                  reps=3 if args.quick else 5,
                                  json_path=None)
        scale_sweep = bench_scale.run(quick=args.quick,
                                      n_sources=2 if args.quick else 3,
                                      json_path=None)
        queue = bench_queue.run(scale=suite_scale,
                                n_requests=8 if args.quick else 12,
                                json_path=None)
        out = {
            **bench_envelope("pr10_async_queue_suite", suite_scale),
            "fused": fused,
            "service": service,
            "dist": dist,
            "analytics": analytics,
            "hybrid": hybrid,
            "scale_sweep": scale_sweep,
            "queue": queue,
        }
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=False)
        print(f"# wrote {json_path}")
    if args.fused_only:
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return

    from benchmarks import (fig3_window, kernel_bench, table1a_compression,
                            table1b_divergence, table2_bfs, table4_footprint)
    table2_bfs.run(scale=min(scale, 10), n_sources=3)
    table1a_compression.run(n=1 << min(scale, 11))
    table1b_divergence.run(scale=scale)
    fig3_window.run(scale=min(scale, 10))
    table4_footprint.run(scale=scale)
    kernel_bench.run()

    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
