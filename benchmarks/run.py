"""Benchmark orchestrator — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows.  Roofline tables (E7) come
from the dry-run artifacts: run ``python -m repro.launch.dryrun --all``
first, then ``python -m benchmarks.roofline``.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller graphs (CI-speed)")
    args = ap.parse_args(argv)
    scale = 9 if args.quick else 11
    t0 = time.time()
    print("name,us_per_call,derived")

    from benchmarks import (fig3_window, kernel_bench, table1a_compression,
                            table1b_divergence, table2_bfs, table4_footprint)
    table2_bfs.run(scale=min(scale, 10), n_sources=3)
    table1a_compression.run(n=1 << min(scale, 11))
    table1b_divergence.run(scale=scale)
    fig3_window.run(scale=min(scale, 10))
    table4_footprint.run(scale=scale)
    kernel_bench.run()

    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
