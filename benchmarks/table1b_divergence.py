"""Table 1b: update divergence before/after RCM on non-social graphs."""
from __future__ import annotations

import time

from benchmarks.common import fmt_row
from repro.core import build_bvss
from repro.core.ordering import rcm
from repro.graphs import generators as gen


def run(scale: int = 11, verbose: bool = True):
    side = int((1 << scale) ** 0.5)
    graphs = {
        "road(grid)": gen.grid2d(side, side, shuffle=True, seed=3),
        "rgg": gen.rgg2d(1 << scale, seed=5),
        "path": gen.path(1 << scale),
    }
    rows = []
    for name, g in graphs.items():
        u_before = build_bvss(g).update_divergence()
        t0 = time.time()
        perm = rcm(g)
        dt = time.time() - t0
        u_after = build_bvss(g.permute_fast(perm)).update_divergence()
        row = fmt_row(f"table1b/{name}", dt * 1e6,
                      f"udiv_before={u_before:.0f};udiv_after={u_after:.0f};"
                      f"reduction={u_before / max(u_after, 1e-9):.1f}x")
        rows.append(row)
        if verbose:
            print(row)
    return rows


if __name__ == "__main__":
    run()
