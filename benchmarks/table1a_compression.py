"""Table 1a: compression ratio + ordering time per heuristic on a
vsp_msc-like graph (star + random edges, shuffled labels)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_row
from repro.core import build_bvss
from repro.core.ordering import (jaccard_windows, natural_order, random_order,
                                 rcm, shingle_order)
from repro.graphs import from_edges, src_of_edges
from repro.graphs import generators as gen


def vsp_msc_like(n: int = 4096, seed: int = 0):
    """Random star graph: hub-heavy + uniform noise (paper's Table-1a
    subject is vsp_msc, 'a random star graph')."""
    star = gen.star(n)
    rng = np.random.default_rng(seed)
    m_extra = n * 8
    src = np.concatenate([src_of_edges(star),
                          rng.integers(0, n, m_extra)])
    dst = np.concatenate([star.indices.astype(np.int64),
                          rng.integers(0, n, m_extra)])
    g = from_edges(n, src, dst)
    return g.permute_fast(rng.permutation(n))


def run(n: int = 4096, verbose: bool = True):
    g = vsp_msc_like(n)
    rows = []
    orderings = [
        ("natural", lambda: natural_order(g)),
        ("random", lambda: random_order(g)),
        ("shingle(gorder-lite)", lambda: shingle_order(g)),
        ("rcm", lambda: rcm(g)),
        ("jaccard_windows", lambda: jaccard_windows(
            g, w=512, pre_order=shingle_order(g))),
    ]
    for name, fn in orderings:
        t0 = time.time()
        perm = fn()
        dt = time.time() - t0
        b = build_bvss(g.permute_fast(perm))
        row = fmt_row(f"table1a/{name}", dt * 1e6,
                      f"compression={b.compression_ratio():.3f}")
        rows.append(row)
        if verbose:
            print(row)
    return rows


if __name__ == "__main__":
    run()
