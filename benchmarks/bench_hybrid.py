"""PR-7 compiled-dispatch hybrid benchmark: direction-optimizing hybrid
(autotuned ladder + push, DESIGN §2.8) vs the pull-only 2-bucket engine.

This is the COMPILED-DISPATCH lane: both engines run the pure-jnp kernel
twins (``use_kernels=False``) so the whole level loop is ONE XLA-compiled
computation end to end — no Pallas interpreter anywhere in the timed
region.  Interpret-mode wall clocks (the PR-1..PR-5 lanes) are dominated
by the Python kernel-body interpreter and bury exactly the dispatch- and
width-shaped effects the hybrid targets; this lane is the one whose
ratios track real accelerator-shaped behaviour.

Per graph of the small-frontier-heavy suite (high-diameter families whose
traversals spend most levels far below the full queue width):

* ``pull``   — the pre-PR-7 static engine: ``direction="pull"``, the
  original 2-bucket ladder;
* ``hybrid`` — ``direction="auto"`` with the knobs
  ``core.autotune.tune()`` picked for this backend.

Every graph is also oracle-verified (levels AND a valid parents tree via
``parents_from_levels``) in ALL THREE direction modes before timing —
a speedup over wrong answers is worthless, so verification failures zero
the speedup rather than report it.

``--json`` writes the ``BENCH_pr7`` artifact; CI gates
``hybrid.summary.geomean_hybrid_vs_pull`` against
``benchmarks/perf_floors.json`` (floor 1.15 — the PR-7 acceptance
threshold, stricter than the generic 25%-regression rule).
"""
from __future__ import annotations

import json

import numpy as np

import time

from benchmarks.common import bench_envelope, fmt_row, geomean
from repro.core import build_bvss, reference_bfs
from repro.core.autotune import stats as autotune_stats
from repro.core.autotune import tune
from repro.core.bfs import INF, BlestProblem, make_blest_bfs, queue_widths
from repro.core.policy import parents_from_levels
from repro.graphs import Graph, generators as gen


def hybrid_suite(scale: int = 14) -> dict[str, Graph]:
    """Small-frontier-heavy families: high diameter, trickling frontiers,
    ``num_vss`` large enough that the static 2-bucket ladder's small rung
    (``num_vss / 8``) sits far above the real per-level live counts."""
    side = int((1 << scale) ** 0.5)
    return {
        "road": gen.grid2d(side, side, shuffle=True, seed=3),
        "web": gen.clustered((1 << scale) // 60, 60, p_in=0.4, seed=4),
        "rgg": gen.rgg2d(1 << scale, seed=5),
        # planted-partition graph whose frontier trace makes auto mode
        # genuinely alternate pull and push levels (tests/test_hybrid.py
        # replays the predicate host-side to prove it)
        "flip": gen.clustered(40, 60, p_in=0.4, seed=1),
    }


#: graphs in the suite for oracle VERIFICATION only, excluded from the
#: gated geomean: flip is n=2400 — its ~40ms traversals sit at the
#: dispatch-noise floor, so its ratio is a coin toss that would make the
#: CI floor flake at par; its job (proving a genuine pull/push multi-flip
#: stays oracle-exact in all three modes) doesn't need a stopwatch
TIMING_EXCLUDED = frozenset({"flip"})


def _best_sec(f, reps: int) -> float:
    """Min-of-``reps`` wall time.  The lane gates a RATIO of two timed
    loops, and scheduler/co-tenant noise is one-sided (it only ever adds
    time), so the minimum is the low-variance estimator of the true
    dispatch cost — medians of this workload were observed swinging
    ~30% between idle runs, which would make the CI floor flake."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        f()
        best = min(best, time.perf_counter() - t0)
    return best


def _valid_parents(g: Graph, levels: np.ndarray, src: int) -> bool:
    parents = parents_from_levels(g, levels)
    if parents[src] != -1:
        return False
    reached = np.flatnonzero((levels != INF) & (np.arange(g.n) != src))
    return bool((parents[reached] >= 0).all()
                and (levels[parents[reached]] == levels[reached] - 1).all()
                and (parents[levels == INF] == -1).all())


def _verify(g: Graph, problem, cfg, src: int) -> dict[str, bool]:
    """Oracle parity (levels + parents) in all three direction modes."""
    want = reference_bfs(g, src)
    out = {}
    for direction in ("pull", "push", "auto"):
        fn = make_blest_bfs(problem, lazy=False, use_kernels=False,
                            direction=direction,
                            **(cfg.engine_kwargs()
                               if direction == "auto" else {}))
        lv = np.asarray(fn(src))
        out[direction] = bool(np.array_equal(lv, want)
                              and _valid_parents(g, lv, src))
    return out


def run(scale: int = 14, n_sources: int = 2, reps: int = 5,
        json_path: str | None = None, verbose: bool = True):
    suite = hybrid_suite(scale)
    graphs_out = {}
    for gname, g in suite.items():
        rng = np.random.default_rng(0)
        cand = np.flatnonzero(g.out_degree > 0)
        srcs = [int(s) for s in rng.choice(
            cand, size=min(n_sources, len(cand)), replace=False)]
        b = build_bvss(g)
        problem = BlestProblem.build(b)
        cfg = tune(problem, use_kernels=False)
        verified = _verify(g, problem, cfg, srcs[0])

        pull_fn = make_blest_bfs(problem, lazy=False, use_kernels=False,
                                 buckets=2, direction="pull")
        hybrid_fn = make_blest_bfs(problem, lazy=False, use_kernels=False,
                                   direction="auto", **cfg.engine_kwargs())

        def sweep(fn):
            for s in srcs:
                np.asarray(fn(s))

        sweep(pull_fn)      # compile + warm
        sweep(hybrid_fn)
        pull_sec = _best_sec(lambda: sweep(pull_fn), reps) / len(srcs)
        hybrid_sec = _best_sec(lambda: sweep(hybrid_fn), reps) / len(srcs)
        ref = reference_bfs(g, srcs[0])
        n_levels = (int(ref[ref != INF].max()) if (ref != INF).any() else 0)
        speedup = (pull_sec / max(hybrid_sec, 1e-12)
                   if all(verified.values()) else 0.0)
        graphs_out[gname] = {
            "timed": gname not in TIMING_EXCLUDED,
            "n": int(g.n), "m": int(g.m), "num_vss": int(b.num_vss),
            "max_vss_per_set": int(problem.max_vss_per_set),
            "levels": n_levels,
            "base_widths": queue_widths(b.num_vss, 2),
            "tuned": {"widths": list(cfg.pull_widths),
                      "push_cap": cfg.push_cap, "alpha": cfg.alpha,
                      "source": cfg.source},
            "pull_sec": pull_sec, "hybrid_sec": hybrid_sec,
            "speedup_hybrid_vs_pull": speedup,
            "verified": verified,
        }
        if verbose:
            print(fmt_row(f"bench_hybrid/{gname}/pull", pull_sec * 1e6,
                          f"levels={n_levels}"))
            print(fmt_row(f"bench_hybrid/{gname}/hybrid", hybrid_sec * 1e6,
                          f"speedup={speedup:.2f};verified="
                          f"{all(verified.values())}"))
    summary = {
        "geomean_hybrid_vs_pull": geomean(
            [go["speedup_hybrid_vs_pull"] for go in graphs_out.values()
             if go["timed"]]),
        "all_verified": all(all(go["verified"].values())
                            for go in graphs_out.values()),
        "autotune": dict(autotune_stats),
    }
    out = {
        **bench_envelope("pr7_hybrid_compiled_dispatch", scale),
        "lane": "compiled-dispatch",
        "use_kernels": False,
        "n_sources": int(n_sources),
        "note": ("pure-jnp kernel twins, whole level loop XLA-compiled "
                 "end to end (no Pallas interpreter in the timed region); "
                 "speedups are zeroed unless the hybrid is oracle-exact "
                 "in all three direction modes, parents included; graphs "
                 "with timed=false (the multi-flip demonstration graph) "
                 "are verification-only and excluded from the gated "
                 "geomean — too small to time above dispatch noise"),
        "graphs": graphs_out,
        "summary": summary,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=False)
        if verbose:
            print(f"# wrote {json_path}")
    if verbose:
        print(f"# geomean_hybrid_vs_pull="
              f"{summary['geomean_hybrid_vs_pull']:.2f}x "
              f"all_verified={summary['all_verified']}")
    return out


if __name__ == "__main__":
    run(json_path="BENCH_pr7_hybrid.json")
