"""Figure 3: effect of JaccardWithWindows window size w on compression and
BFS runtime (GAP-web stand-in: clustered community graph)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_row, time_engine
from repro.core import build_bvss, make_engine
from repro.core.ordering import jaccard_windows, shingle_order
from repro.graphs import generators as gen


def run(scale: int = 10, verbose: bool = True):
    g = gen.clustered((1 << scale) // 64, 64, seed=4)
    pre = shingle_order(g)
    rows = []
    srcs = np.random.default_rng(0).integers(0, g.n, 3)
    for logw in range(3, 13):
        w = 1 << logw
        if w > g.n:
            break
        perm = jaccard_windows(g, w=w, pre_order=pre)
        gg = g.permute_fast(perm)
        b = build_bvss(gg)
        fn = make_engine(gg, "blest", bvss=b)
        sec = time_engine(fn, perm[srcs])
        row = fmt_row(f"fig3/w={w}", sec * 1e6,
                      f"compression={b.compression_ratio():.3f}")
        rows.append(row)
        if verbose:
            print(row)
    return rows


if __name__ == "__main__":
    run()
