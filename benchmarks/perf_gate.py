"""CI perf-regression gate: diff a ``BENCH_pr4``-schema artifact against
checked-in geomean-speedup floors and fail loudly on regression.

    python -m benchmarks.perf_gate bench_ci.json
    python -m benchmarks.perf_gate bench_ci.json --prove-gate

The floors (``benchmarks/perf_floors.json``) are dotted paths into the
artifact mapped to minimum acceptable values — derived from the PR-3
reference artifact (``BENCH_pr3.json``) and the PR-4 analytics reference
run at the CI quick settings, scaled by ``1 - max_regression`` (25%).
Every gated metric is a *speedup ratio* (batched wave vs sequential,
fused vs seed, BVSS vs dense), so the gate is insensitive to absolute
runner speed; the 25% headroom absorbs CPU-runner noise on top.

``--prove-gate`` is the self-test CI runs after the real gate: it
re-evaluates the artifact against floors inflated 100× and exits 0 only
if the gate would FAIL — demonstrating the gate actually trips instead
of silently passing everything.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_FLOORS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                              "perf_floors.json")


def resolve(artifact: dict, dotted: str):
    """Walk a dotted path ('service.summary.geomean_wave_speedup')."""
    node = artifact
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def check(artifact: dict, floors: dict[str, float]
          ) -> tuple[list[str], list[str]]:
    """Returns (report lines, violations)."""
    lines, violations = [], []
    for dotted, floor in sorted(floors.items()):
        value = resolve(artifact, dotted)
        if value is None:
            violations.append(f"{dotted}: MISSING from artifact "
                              f"(floor {floor:.3f})")
            continue
        ok = value >= floor
        lines.append(f"{'ok  ' if ok else 'FAIL'} {dotted}: "
                     f"{value:.3f} (floor {floor:.3f})")
        if not ok:
            violations.append(
                f"{dotted}: {value:.3f} < floor {floor:.3f} "
                f"(>{100 * (1 - value / floor):.0f}% under)")
    return lines, violations


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("artifact", help="bench JSON (BENCH_pr4 schema)")
    ap.add_argument("--floors", default=DEFAULT_FLOORS)
    ap.add_argument("--only", default=None, metavar="PREFIX",
                    help="gate only floors whose dotted path starts with "
                         "PREFIX (e.g. 'hybrid.') — for partial artifacts "
                         "like the compiled-smoke job's hybrid-only run; "
                         "an empty selection is an error, not a pass")
    ap.add_argument("--prove-gate", action="store_true",
                    help="self-test: exit 0 only if 100x-inflated floors "
                         "make the gate fail")
    ap.add_argument("--require-covered", action="store_true",
                    help="fail if the artifact contains a suite (top-level "
                         "dict key) with no floor under it — a new bench "
                         "suite must land WITH a floor, never silently "
                         "escape the gate (the weekly full-depth run sets "
                         "this)")
    args = ap.parse_args(argv)

    with open(args.artifact) as f:
        artifact = json.load(f)
    with open(args.floors) as f:
        spec = json.load(f)
    floors = {k: float(v) for k, v in spec["floors"].items()}
    if args.require_covered:
        # coverage is judged against the FULL floors file, before any
        # --only narrowing: a suite is covered if at least one checked-in
        # floor gates a metric inside it
        suites = [k for k, v in artifact.items() if isinstance(v, dict)]
        uncovered = [s for s in suites
                     if not any(path.startswith(s + ".") for path in floors)]
        if uncovered:
            print(f"perf gate FAILED: artifact suite(s) with no floor: "
                  f"{', '.join(sorted(uncovered))} — add a floor to "
                  f"{args.floors} (new suites must not escape the gate)")
            return 1
        print(f"perf gate: all {len(suites)} artifact suites covered by "
              f"floors")
    if args.only is not None:
        floors = {k: v for k, v in floors.items()
                  if k.startswith(args.only)}
        if not floors:
            print(f"perf gate: no floors match --only {args.only!r} — "
                  f"refusing to vacuously pass")
            return 1

    if args.prove_gate:
        inflated = {k: v * 100.0 for k, v in floors.items()}
        _, violations = check(artifact, inflated)
        if violations:
            print(f"perf gate self-test ok: inflated floors trip "
                  f"{len(violations)}/{len(inflated)} checks")
            return 0
        print("perf gate self-test FAILED: inflated floors did not trip "
              "the gate — the gate is not actually comparing anything")
        return 1

    lines, violations = check(artifact, floors)
    print(f"perf gate: {args.artifact} vs {args.floors} "
          f"(max regression {spec.get('max_regression', 0.25):.0%})")
    for line in lines:
        print(f"  {line}")
    if violations:
        print(f"perf gate FAILED: {len(violations)} regression(s)")
        for v in violations:
            print(f"  {v}")
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
