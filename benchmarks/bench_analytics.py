"""Analytics benchmark (PR 4, closeness since PR 5): batched-wave
analytics vs sequential per-source BFS baselines, all oracle-verified.

Per graph of the suite:

* ``components`` — connected components via (a) the batched flood-fill
  with wave-slot re-seeding (``GraphSession.components``) and (b) a
  sequential baseline running one fused single-source BFS per seed over
  the SAME symmetrised problem (identical tiles, no column batching).
  Labels verified against the SciPy oracle.
* ``eccentricity`` — N eccentricity queries via (a) one fixed-cohort
  multi-source wave and (b) N sequential single-source runs.  Verified
  against the SciPy distance oracle.
* ``betweenness`` — sampled-source Brandes through the σ-channel wave
  forward + reverse tile sweep, verified against the NumPy Brandes
  oracle within fp tolerance (the speed story here is the new capability,
  not a ratio — the baseline oracle is host code).
* ``closeness`` — N closeness queries via (a) fixed wave cohorts through
  the session's cached multi-source engine and (b) N sequential fused
  single-source runs with the same reduction.  Verified against the
  SciPy closeness oracle.
* ``sssp`` (PR 9) — N weighted shortest-path queries via batched
  delta-stepping over the min-plus tiles (``GraphSession.sssp_batch``)
  vs the SciPy Dijkstra oracle's own wall time; dyadic edge weights make
  the f32 wave distances bit-comparable to the float64 oracle.
* ``pagerank`` (PR 9) — the fused device power iteration
  (``GraphSession.pagerank``) vs NetworkX's host iteration, verified to
  ≤1e-6 relative error.

``run(..., json_path=...)`` feeds the ``analytics`` suite of the
``BENCH_pr*.json`` artifact via ``benchmarks/run.py --json``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (bench_envelope, fmt_row, geomean,
                               graph_suite, median_sec)
from repro.analytics.closeness import closeness_from_levels
from repro.core import INF
from repro.kernels.ref import (betweenness_ref, closeness_ref,
                               connected_components_ref, eccentricity_ref,
                               normalize_labels)


def _sequential_components(problem, levels_fn, perm) -> np.ndarray:
    """Baseline: per-seed fused single-source BFS flood-fill (same
    symmetrised problem, no wave batching), labels in caller ids."""
    n = problem.n
    vcomp = np.full(n, -1, dtype=np.int64)
    scan = 0
    c = 0
    while True:
        while scan < n and vcomp[scan] >= 0:
            scan += 1
        if scan >= n:
            break
        lv = np.asarray(levels_fn(scan))
        vcomp[lv != INF] = c
        c += 1
    return normalize_labels(vcomp[perm])


def run(scale: int = 9, n_queries: int = 8, n_pivots: int = 4,
        json_path: str | None = None, verbose: bool = True) -> dict:
    import jax.numpy as jnp

    from repro.graphs import generators as gen
    from repro.serve import GraphSession

    suite = graph_suite(scale)
    # the component-rich regime the flood-fill is FOR: disconnected
    # communities (p_out=0), the workload class of components queries
    suite["frag"] = gen.clustered((1 << scale) // 32, 32, p_out=0.0, seed=6)
    graphs_out = {}
    for gname, g in suite.items():
        rng = np.random.default_rng(0)
        # dyadic rationals: f32 path sums are exact, so the wave distances
        # must MATCH the float64 Dijkstra oracle (not just approximate it)
        wts = (rng.integers(1, 128, g.m) / 32.0).astype(np.float32)
        sess = GraphSession(g, max_batch=min(8, n_queries), w=512,
                            weights=wts)
        seq_bfs = sess._sym_sss()   # the baseline IS the phase-0 engine:
                                    # same tiles, no wave batching

        def seq_levels(src_internal: int) -> np.ndarray:
            return seq_bfs(jnp.int32(src_internal))

        # -- components: wave flood-fill vs sequential per-seed BFS --------
        sess.components()                                  # warm wave path
        seq_levels(0)                                      # warm baseline
        labels = sess.components()
        labels_seq = _sequential_components(sess._sym_problem(), seq_levels,
                                            sess.perm)
        t_wave = median_sec(sess.components)
        t_seq = median_sec(lambda: _sequential_components(
            sess._sym_problem(), seq_levels, sess.perm))
        ref = connected_components_ref(g)
        cverified = bool((labels == ref).all() and (labels_seq == ref).all())
        assert cverified, f"{gname}: component labels diverge from scipy"
        comp = {
            "n_components": int(labels.max()) + 1,
            "sequential_sec": t_seq, "wave_sec": t_wave,
            "speedup": t_seq / max(t_wave, 1e-12), "verified": cverified,
        }

        # -- eccentricity: one batched wave vs N sequential runs -----------
        srcs = rng.integers(0, g.n, n_queries)
        internal = sess.perm[srcs]
        sess.eccentricity_batch(srcs)                # warm at the timed width
        eccs = sess.eccentricity_batch(srcs)

        def seq_ecc() -> np.ndarray:
            return np.array([
                int(np.where((lv := np.asarray(seq_levels(int(s)))) != INF,
                             lv, 0).max()) for s in internal])

        eccs_seq = seq_ecc()
        t_wave_e = median_sec(lambda: sess.eccentricity_batch(srcs))
        t_seq_e = median_sec(seq_ecc)
        ref_e = eccentricity_ref(g.symmetrized, srcs)
        everified = bool((eccs == ref_e).all() and (eccs_seq == ref_e).all())
        assert everified, f"{gname}: eccentricity diverges from scipy"
        ecc = {
            "n_queries": int(n_queries),
            "sequential_sec": t_seq_e, "wave_sec": t_wave_e,
            "speedup": t_seq_e / max(t_wave_e, 1e-12), "verified": everified,
        }

        # -- betweenness: σ-channel wave + reverse tile sweep ---------------
        pivots = rng.choice(g.n, size=min(n_pivots, g.n), replace=False)
        sess.betweenness_batch(pivots)               # warm at the timed width
        bc = sess.betweenness_batch(pivots)
        t_bc = median_sec(lambda: sess.betweenness_batch(pivots))
        ref_bc = betweenness_ref(g, pivots)
        scale_ref = max(float(np.abs(ref_bc).max()), 1.0)
        max_rel_err = float(np.abs(bc - ref_bc).max()) / scale_ref
        bverified = bool(max_rel_err < 1e-4)
        assert bverified, f"{gname}: betweenness err {max_rel_err}"
        bet = {
            "n_pivots": int(len(pivots)), "wave_sec": t_bc,
            "max_rel_err": max_rel_err, "verified": bverified,
        }

        # -- closeness: wave cohorts vs N sequential fused runs -------------
        srcs_c = rng.integers(0, g.n, n_queries)
        sess.closeness_batch(srcs_c)                 # warm at the timed width
        cc = sess.closeness_batch(srcs_c)

        def seq_close() -> np.ndarray:
            return np.concatenate([
                closeness_from_levels(
                    np.asarray(sess.levels(int(s)))[:, None])
                for s in srcs_c])

        cc_seq = seq_close()
        t_wave_c = median_sec(lambda: sess.closeness_batch(srcs_c))
        t_seq_c = median_sec(seq_close)
        ref_c = closeness_ref(g, srcs_c)
        closeverified = bool(
            np.allclose(cc, ref_c, rtol=1e-9)
            and np.allclose(cc_seq, ref_c, rtol=1e-9))
        assert closeverified, f"{gname}: closeness diverges from scipy"
        close = {
            "n_queries": int(n_queries),
            "sequential_sec": t_seq_c, "wave_sec": t_wave_c,
            "speedup": t_seq_c / max(t_wave_c, 1e-12),
            "verified": closeverified,
        }

        # -- sssp: batched delta-stepping waves vs the SciPy oracle ---------
        from repro.kernels.ref import pagerank_ref, sssp_ref
        srcs_s = rng.integers(0, g.n, n_queries)
        sess.sssp_batch(srcs_s)                # warm at the timed width
        dist = sess.sssp_batch(srcs_s)
        t_wave_s = median_sec(lambda: sess.sssp_batch(srcs_s))
        t_scipy = median_sec(lambda: sssp_ref(g, srcs_s, wts))
        ref_s = sssp_ref(g, srcs_s, wts)
        sverified = bool(
            np.array_equal(np.isinf(dist), np.isinf(ref_s))
            and np.allclose(np.where(np.isinf(dist), 0.0, dist),
                            np.where(np.isinf(ref_s), 0.0, ref_s),
                            rtol=1e-6))
        assert sverified, f"{gname}: sssp diverges from the Dijkstra oracle"
        sssp = {
            "n_sources": int(n_queries),
            "scipy_sec": t_scipy, "wave_sec": t_wave_s,
            "speedup": t_scipy / max(t_wave_s, 1e-12), "verified": sverified,
        }

        # -- pagerank: fused device iteration vs NetworkX ------------------
        sess.pagerank(tol=1e-10, max_iter=500)             # warm
        pr = sess.pagerank(tol=1e-10, max_iter=500)
        t_pr = median_sec(lambda: sess.pagerank(tol=1e-10, max_iter=500))
        t_nx = median_sec(lambda: pagerank_ref(g))
        ref_pr = pagerank_ref(g)
        pr_rel = float(np.max(np.abs(pr - ref_pr)
                              / np.maximum(np.abs(ref_pr), 1e-30)))
        # 5e-6 here, not the verbs lane's 1e-6: the f32 iterate's error
        # floor grows with n, and the bench runs at suite scale (2^10)
        # where the float64 NetworkX oracle sits ~2e-6 away
        pverified = bool(pr_rel <= 5e-6)
        assert pverified, f"{gname}: pagerank err {pr_rel}"
        pagerank = {
            "networkx_sec": t_nx, "wave_sec": t_pr,
            "speedup": t_nx / max(t_pr, 1e-12),
            "max_rel_err": pr_rel, "verified": pverified,
        }

        graphs_out[gname] = {
            "n": int(g.n), "m": int(g.m), "ordering": sess.ordering,
            "components": comp, "eccentricity": ecc, "betweenness": bet,
            "closeness": close, "sssp": sssp, "pagerank": pagerank,
        }
        if verbose:
            print(fmt_row(f"bench_analytics/{gname}/components",
                          t_wave * 1e6, f"speedup={comp['speedup']:.2f}"))
            print(fmt_row(f"bench_analytics/{gname}/eccentricity",
                          t_wave_e * 1e6, f"speedup={ecc['speedup']:.2f}"))
            print(fmt_row(f"bench_analytics/{gname}/betweenness",
                          t_bc * 1e6, f"err={max_rel_err:.1e}"))
            print(fmt_row(f"bench_analytics/{gname}/closeness",
                          t_wave_c * 1e6, f"speedup={close['speedup']:.2f}"))
            print(fmt_row(f"bench_analytics/{gname}/sssp",
                          t_wave_s * 1e6, f"speedup={sssp['speedup']:.2f}"))
            print(fmt_row(f"bench_analytics/{gname}/pagerank",
                          t_pr * 1e6,
                          f"speedup={pagerank['speedup']:.2f}"))

    summary = {
        "geomean_components_speedup": geomean(
            [go["components"]["speedup"] for go in graphs_out.values()]),
        "geomean_ecc_speedup": geomean(
            [go["eccentricity"]["speedup"] for go in graphs_out.values()]),
        "geomean_closeness_speedup": geomean(
            [go["closeness"]["speedup"] for go in graphs_out.values()]),
        "geomean_sssp_speedup": geomean(
            [go["sssp"]["speedup"] for go in graphs_out.values()]),
        "geomean_pagerank_speedup": geomean(
            [go["pagerank"]["speedup"] for go in graphs_out.values()]),
        "all_verified": all(
            go["components"]["verified"] and go["eccentricity"]["verified"]
            and go["betweenness"]["verified"]
            and go["closeness"]["verified"] and go["sssp"]["verified"]
            and go["pagerank"]["verified"]
            for go in graphs_out.values()),
    }
    out = {
        **bench_envelope("pr9_analytics", scale),
        "note": ("components/eccentricity = batched wave (stacked bit-SpMM "
                 "columns, slot re-seeding) vs sequential fused "
                 "single-source BFS over the same symmetrised BVSS; "
                 "betweenness = Brandes forward σ wave channel + reverse "
                 "sweep over the recorded per-level tile queues, verified "
                 "against the NumPy Brandes oracle; closeness = wave-cohort "
                 "level-channel reduction vs sequential fused runs, "
                 "verified against the SciPy closeness oracle; sssp = "
                 "batched delta-stepping over the min-plus tiles vs the "
                 "SciPy Dijkstra oracle (dyadic weights, exact match); "
                 "pagerank = fused device power iteration vs NetworkX"),
        "graphs": graphs_out,
        "summary": summary,
    }
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=False)
        if verbose:
            print(f"# wrote {json_path}")
    if verbose:
        for k, v in summary.items():
            print(f"# {k}={v if isinstance(v, bool) else f'{v:.2f}x'}")
    return out


if __name__ == "__main__":
    run(json_path="BENCH_analytics.json")
