"""Table 2: BFS engine matrix — BLEST variants (a/ab/ac/full) vs baselines.

Per (graph x engine): wall ms/BFS (CPU) + modeled TC-pull count; speedups
are reported against the BRS (BerryBees-like) frontier-oblivious engine,
matching the paper's "vs [27]" column.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (fmt_row, graph_suite, modeled_tc_pulls,
                               time_engine)
from repro.core import build_bvss, make_engine
from repro.core.ordering import auto_order


def run(scale: int = 10, n_sources: int = 3, verbose: bool = True):
    suite = graph_suite(scale)
    rows = []
    engines = ["csr_push", "csr_pull", "dirop", "brs",
               "blest_a", "blest_ab", "blest_ac", "blest_full"]
    for gname, g in suite.items():
        rng = np.random.default_rng(0)
        # pick sources with nonzero out-degree so BFS does work
        deg = g.out_degree
        cand = np.flatnonzero(deg > 0)
        srcs = rng.choice(cand, size=min(n_sources, len(cand)),
                          replace=False)
        t0 = time.time()
        perm, kind = auto_order(g, w=512)
        order_s = time.time() - t0
        g_ord = g.permute_fast(perm)
        b_nat = build_bvss(g)
        b_ord = build_bvss(g_ord)
        base_pulls = None
        for engine in engines:
            ordered = engine in ("blest_ab", "blest_full")
            gg = g_ord if ordered else g
            bb = b_ord if ordered else b_nat
            core = {"blest_a": "blest", "blest_ab": "blest",
                    "blest_ac": "blest_lazy", "blest_full": "blest_lazy"
                    }.get(engine, engine)
            kwargs = {"bvss": bb} if core in ("brs", "blest", "blest_lazy") \
                else {}
            fn = make_engine(gg, core, **kwargs)
            srcs_m = (perm[srcs] if ordered else srcs)
            sec = time_engine(fn, srcs_m)
            if core in ("brs", "blest", "blest_lazy"):
                pulls = int(np.mean([modeled_tc_pulls(
                    gg, bb, int(s), frontier_aware=core != "brs")
                    for s in srcs_m]))
            else:
                pulls = 0
            if engine == "brs":
                base_pulls = pulls
                base_sec = sec
            derived = ""
            if pulls and base_pulls:
                derived = (f"tc_pulls={pulls};work_speedup_vs_brs="
                           f"{base_pulls / max(pulls, 1):.2f}x")
            elif engine != "brs" and base_pulls is None:
                derived = ""
            row = fmt_row(f"table2/{gname}/{engine}", sec * 1e6, derived)
            rows.append(row)
            if verbose:
                print(row)
        if verbose:
            print(fmt_row(f"table2/{gname}/ordering", order_s * 1e6,
                          f"kind={kind}"))
    return rows


if __name__ == "__main__":
    run()
