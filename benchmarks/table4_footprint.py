"""Table 4: BVSS structural statistics + memory footprint per graph."""
from __future__ import annotations

from benchmarks.common import fmt_row, graph_suite
from repro.core import build_bvss


def run(scale: int = 11, verbose: bool = True):
    rows = []
    for name, g in graph_suite(scale).items():
        b = build_bvss(g)
        mem = b.memory_bytes()
        row = fmt_row(
            f"table4/{name}", 0.0,
            f"n_sets={b.n_sets};num_vss={b.num_vss};"
            f"slices={b.num_slices};padded_slices={b.num_vss * b.tau};"
            f"conn_bits={b.connectivity_bits()};"
            f"udiv={b.update_divergence():.0f};"
            f"compression={b.compression_ratio():.3f};"
            f"mem_mb={mem['total'] / 1e6:.2f}")
        rows.append(row)
        if verbose:
            print(row)
    return rows


if __name__ == "__main__":
    run()
