"""PR-10 async-queue serving benchmark: RequestQueue coalescing vs
call-at-a-time serving on a Poisson-arrival request stream.

Per graph of the suite:

* ``poisson`` — N single-source level requests with exponential
  inter-arrival gaps (mean a fraction of the single-query service time,
  so a backlog builds).  (a) *call-at-a-time*: a single server thread
  sleeps until each arrival, then answers it through the fused
  single-source engine — the pre-queue serving discipline.  (b) *queued*:
  every request is ``submit()``-ed with ``not_before`` at its arrival
  time and one ``drain(wait=True)`` coalesces the backlog into
  ``max_batch``-wide multi-source waves, refilling slots mid-flight.
  Both makespans span first arrival to last completion; throughput is
  N/makespan and the floored ratio is queued/call-at-a-time.
* ``backlog`` — the same requests all available at t=0 (pure wave-batching
  throughput, no arrival idle time), as a secondary diagnostic.

Every queued answer is verified bit-identical to ``reference_bfs`` before
timing is reported.  ``run(..., json_path=...)`` is invoked by
``benchmarks/run.py --json`` and feeds the ``queue`` suite of the bench
artifact; ``perf_floors.json`` floors the Poisson geomean at 1.3x.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_envelope, fmt_row, geomean, graph_suite
from repro import GraphSessionManager, PrepareOptions, RequestQueue
from repro.core import reference_bfs


def _serve_call_at_a_time(sess, queries, arrivals):
    """The pre-queue discipline: one server loop, sleep until each
    request's arrival, answer it alone.  Returns (makespan_s, answers)."""
    t0 = time.monotonic()
    out = []
    for q, a in zip(queries, arrivals):
        while True:
            gap = a - (time.monotonic() - t0)
            if gap <= 0:
                break
            time.sleep(min(gap, 0.0005))
        out.append(sess.levels(q))
    return time.monotonic() - t0, out


def _serve_queued(queue, name, queries, arrivals):
    """Submit every request with ``not_before`` at its arrival time, then
    one draining pass coalesces the backlog into waves."""
    t0 = time.monotonic()
    futs = [queue.submit(name, q, not_before=t0 + a)
            for q, a in zip(queries, arrivals)]
    queue.drain(wait=True)
    makespan = time.monotonic() - t0
    return makespan, [f.result(0) for f in futs]


def run(scale: int = 9, n_requests: int = 12, max_batch: int = 8,
        json_path: str | None = None, verbose: bool = True) -> dict:
    suite = graph_suite(scale)
    graphs_out = {}
    for gname, g in suite.items():
        rng = np.random.default_rng(10)
        mgr = GraphSessionManager()
        sess = mgr.open_session(gname, g, max_batch=max_batch,
                                options=PrepareOptions(w=512))
        queue = RequestQueue(mgr)
        queries = [int(q) for q in rng.integers(0, g.n, n_requests)]
        refs = [reference_bfs(g, q) for q in queries]

        # warm both paths, then estimate the single-query service time so
        # the arrival process is scaled to THIS machine (mean gap = t1/4:
        # arrivals outpace the one-at-a-time server and a backlog builds)
        sess.levels(queries[0])
        sess.levels_batch(queries[: min(2, len(queries))])
        t0 = time.monotonic()
        for q in queries[:3]:
            sess.levels(q)
        t1 = (time.monotonic() - t0) / 3
        gaps = rng.exponential(t1 / 4, n_requests)
        gaps[0] = 0.0
        arrivals = np.cumsum(gaps)

        t_call, seq = _serve_call_at_a_time(sess, queries, arrivals)
        t_queued, ans = _serve_queued(queue, gname, queries, arrivals)
        verified = all((a == r).all() and (s == r).all()
                       for a, s, r in zip(ans, seq, refs))
        assert verified, f"{gname}: queued levels differ from reference_bfs"
        qs = queue.stats()
        poisson = {
            "n_requests": n_requests, "max_batch": max_batch,
            "mean_gap_sec": float(t1 / 4),
            "call_at_a_time_sec": t_call, "queued_sec": t_queued,
            "queued_vs_call_at_a_time": t_call / max(t_queued, 1e-12),
            "waves": qs["waves"], "coalesced": qs["coalesced"],
            "verified": verified,
        }

        # -- backlog: all requests available at t=0 ------------------------
        t_call0, _ = _serve_call_at_a_time(
            sess, queries, np.zeros(n_requests))
        t_q0, ans0 = _serve_queued(queue, gname, queries,
                                   np.zeros(n_requests))
        assert all((a == r).all() for a, r in zip(ans0, refs))
        backlog = {
            "call_at_a_time_sec": t_call0, "queued_sec": t_q0,
            "queued_vs_call_at_a_time": t_call0 / max(t_q0, 1e-12),
        }

        graphs_out[gname] = {
            "n": int(g.n), "m": int(g.m), "ordering": sess.ordering,
            "engine": sess.engine_name,
            "poisson": poisson, "backlog": backlog,
        }
        if verbose:
            print(fmt_row(f"bench_queue/{gname}/poisson", t_queued * 1e6,
                          f"vs_call={poisson['queued_vs_call_at_a_time']:.2f}"
                          f";coalesced={qs['coalesced']}"))
            print(fmt_row(f"bench_queue/{gname}/backlog", t_q0 * 1e6,
                          f"vs_call="
                          f"{backlog['queued_vs_call_at_a_time']:.2f}"))

    summary = {
        "geomean_queued_vs_call_at_a_time": geomean(
            [go["poisson"]["queued_vs_call_at_a_time"]
             for go in graphs_out.values()]),
        "geomean_backlog_queued_vs_call_at_a_time": geomean(
            [go["backlog"]["queued_vs_call_at_a_time"]
             for go in graphs_out.values()]),
        "total_coalesced": int(sum(go["poisson"]["coalesced"]
                                   for go in graphs_out.values())),
        "all_verified": all(go["poisson"]["verified"]
                            for go in graphs_out.values()),
    }
    out = {
        **bench_envelope("pr10_async_queue", scale),
        "note": ("poisson = RequestQueue submits with not_before at each "
                 "exponential arrival, one drain(wait=True) coalescing the "
                 "backlog into max_batch-wide waves with mid-flight slot "
                 "refills; call_at_a_time = the same arrivals answered one "
                 "at a time through the fused single-source engine; both "
                 "makespans span first arrival to last completion"),
        "graphs": graphs_out,
        "summary": summary,
    }
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=False)
        if verbose:
            print(f"# wrote {json_path}")
    if verbose:
        for k, v in summary.items():
            print(f"# {k}={v if isinstance(v, (bool, int)) else f'{v:.2f}x'}")
    return out


if __name__ == "__main__":
    run(json_path="BENCH_queue.json")
