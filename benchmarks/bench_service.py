"""PR-2 GraphSession serving benchmark: batched waves vs sequential BFS,
and BVSS vs dense-adjacency multi-source.

Per graph of the suite:

* ``serve`` — N single-source level queries answered (a) sequentially
  through the fused single-source engine and (b) as one batched
  multi-source wave through :class:`repro.serve.GraphSession` (slot pool,
  lock-step levels, mid-flight refills).  Wave answers are verified
  bit-identical to ``reference_bfs`` per column before timing is reported.
* ``multi_source`` — the fixed-cohort multi-source engine on the BVSS
  bit-SpMM path (`core/multi_source.py`) vs the FROZEN pre-PR dense
  baseline below (``to_dense_bits`` adjacency + ``bit_spmm``), with the
  adjacency footprint of each (the dense bitmap is O(n²/32) words; the
  BVSS scales with slices).
* ``hardened`` — the same wave workload through the multi-tenant
  :class:`repro.serve.GraphSessionManager` front (ingress validation,
  LRU touch, deadline clock hooks armed with a never-firing budget) vs
  the bare session, quantifying the robustness-layer overhead (DESIGN
  §2.7 requires it stay in the noise; the perf gate floors the ratio).

``run(..., json_path=...)`` is invoked by ``benchmarks/run.py --json`` and
feeds the ``service`` suite of ``BENCH_pr2.json``.
"""
from __future__ import annotations

import time
from typing import Callable, NamedTuple

import numpy as np

from benchmarks.common import bench_envelope, fmt_row, geomean, graph_suite
from repro.core import INF, reference_bfs
from repro.serve import GraphSession, GraphSessionManager, TimeoutResult


# ---------------------------------------------------------------------------
# FROZEN baseline: the seed/PR-1 dense-adjacency multi-source implementation
# ---------------------------------------------------------------------------
def make_dense_multi_source_bfs(g, n_sources: int) -> Callable:
    """The pre-PR-2 implementation, kept verbatim as the perf baseline: a
    dense ``to_dense_bits`` pull adjacency resolved through ``bit_spmm``."""
    import jax
    import jax.numpy as jnp

    from repro.core.level_pipeline import (LevelPipeline, compose_step,
                                           run_levels)
    from repro.graphs import to_dense_bits
    from repro.kernels import bit_spmm

    class _MSState(NamedTuple):
        levels: jnp.ndarray
        X: jnp.ndarray

    n = g.n
    adj = jnp.asarray(to_dense_bits(g))      # (n, ceil(n/32)) u32
    S = n_sources

    def gather(s):
        return adj, s.X

    def update(s, pop, lvl):
        new = (pop > 0) & (s.levels == INF)
        return _MSState(levels=jnp.where(new, lvl, s.levels),
                        X=new.astype(jnp.int8))

    pipe = LevelPipeline(step=compose_step(gather, bit_spmm, update),
                         finalize=lambda s, lvl: s,
                         active=lambda s: (s.X != 0).any())

    def bfs(sources):
        sources = jnp.asarray(sources, dtype=jnp.int32)
        levels = jnp.full((n, S), INF, dtype=jnp.int32)
        levels = levels.at[sources, jnp.arange(S)].set(0)
        X = jnp.zeros((n, S), dtype=jnp.int8)
        X = X.at[sources, jnp.arange(S)].set(1)
        state, _ = run_levels(pipe, _MSState(levels, X), max_levels=n + 1)
        return state.levels

    return jax.jit(bfs)


def _median_time(fn, arg, reps: int = 3) -> float:
    """Median seconds per call (post-warm), matching time_engine's idiom."""
    ts = []
    for _ in range(reps):
        t0 = time.time()
        np.asarray(fn(arg))
        ts.append(time.time() - t0)
    return float(np.median(ts))


def run(scale: int = 9, n_queries: int = 8, json_path: str | None = None,
        verbose: bool = True) -> dict:
    import jax.numpy as jnp

    from repro.core.multi_source import make_multi_source_bfs

    suite = graph_suite(scale)
    graphs_out = {}
    for gname, g in suite.items():
        rng = np.random.default_rng(0)
        mgr = GraphSessionManager()
        sess = mgr.open_session(gname, g, max_batch=min(8, n_queries),
                                w=512)
        queries = [int(q) for q in rng.integers(0, g.n, n_queries)]

        # -- serve: batched wave vs N sequential single-source runs --------
        sess.levels(queries[0])                       # warm both paths
        sess.levels_batch(queries[: min(2, len(queries))])
        t0 = time.time()
        seq = [sess.levels(q) for q in queries]
        t_seq = time.time() - t0
        t0 = time.time()
        wave = sess.levels_batch(queries)
        t_wave = time.time() - t0
        verified = all(
            (lv == reference_bfs(g, q)).all() and (lv == lv_s).all()
            for q, lv, lv_s in zip(queries, wave, seq))
        assert verified, f"{gname}: wave levels differ from reference_bfs"
        serve = {
            "n_queries": n_queries, "max_batch": sess.max_batch,
            "sequential_sec": t_seq, "wave_sec": t_wave,
            "speedup": t_seq / max(t_wave, 1e-12), "verified": verified,
        }

        # -- hardened: manager-fronted wave vs the bare session ------------
        # same compiled engine underneath (the manager holds THIS sess),
        # so the delta is pure robustness-layer cost: source validation,
        # LRU touch, and the per-level deadline clock hooks (armed with a
        # budget that never fires)
        def _median(fn, reps: int = 5) -> float:
            ts = []
            for _ in range(reps):
                t0 = time.time()
                fn()
                ts.append(time.time() - t0)
            return float(np.median(ts))

        t_plain = _median(lambda: sess.levels_batch(queries))
        t_hard = _median(lambda: mgr.levels_batch(
            gname, queries, deadline_s=3600.0))
        hard = mgr.levels_batch(gname, queries, deadline_s=3600.0)
        hardened_verified = (
            not any(isinstance(lv, TimeoutResult) for lv in hard)
            and all((lv == lv_s).all() for lv, lv_s in zip(hard, wave)))
        assert hardened_verified, f"{gname}: hardened path diverges"
        hardened = {
            "n_queries": n_queries,
            "plain_sec": t_plain, "hardened_sec": t_hard,
            "plain_vs_hardened": t_plain / max(t_hard, 1e-12),
            "verified": hardened_verified,
        }

        # -- multi-source: BVSS bit-SpMM vs frozen dense baseline ----------
        # the BVSS engine rides the session's prepared (ordered) structure,
        # so bvss_static_bytes below describes exactly the timed engine;
        # sources go in internal ids, levels come back out via the perm
        S = min(8, n_queries)
        srcs_orig = rng.integers(0, g.n, S).astype(np.int32)
        assert sess.prepared.problem is not None
        f_bvss = make_multi_source_bfs(None, S,
                                       problem=sess.prepared.problem)
        f_dense = make_dense_multi_source_bfs(g, S)
        internal = jnp.asarray(sess.perm[srcs_orig].astype(np.int32))
        srcs = jnp.asarray(srcs_orig)
        lv_b = np.asarray(f_bvss(internal))           # warm + verify
        lv_d = np.asarray(f_dense(srcs))
        np.testing.assert_array_equal(lv_b[sess.perm], lv_d)
        t_bvss = _median_time(f_bvss, internal)
        t_dense = _median_time(f_dense, srcs)
        n_words = (g.n + 31) // 32
        ms = {
            "n_sources": S, "bvss_sec": t_bvss, "dense_sec": t_dense,
            "speedup_bvss_vs_dense": t_dense / max(t_bvss, 1e-12),
            "dense_adjacency_bytes": int(g.n * n_words * 4),
            "bvss_static_bytes": int(sess.bvss.memory_bytes()["bvss"]),
        }

        social = sess.ordering == "jaccard_windows"
        graphs_out[gname] = {
            "n": int(g.n), "m": int(g.m),
            "social_like": social, "ordering": sess.ordering,
            "engine": sess.engine_name,
            "serve": serve, "multi_source": ms, "hardened": hardened,
        }
        if verbose:
            print(fmt_row(f"bench_service/{gname}/serve", t_wave * 1e6,
                          f"speedup={serve['speedup']:.2f};social={social}"))
            print(fmt_row(f"bench_service/{gname}/multi_source",
                          t_bvss * 1e6,
                          f"vs_dense={ms['speedup_bvss_vs_dense']:.2f}"))
            print(fmt_row(f"bench_service/{gname}/hardened", t_hard * 1e6,
                          f"plain_vs_hardened="
                          f"{hardened['plain_vs_hardened']:.3f}"))

    social_graphs = [go for go in graphs_out.values() if go["social_like"]]
    summary = {
        "geomean_wave_speedup": geomean(
            [go["serve"]["speedup"] for go in graphs_out.values()]),
        "geomean_wave_speedup_social": geomean(
            [go["serve"]["speedup"] for go in social_graphs]),
        "geomean_bvss_vs_dense": geomean(
            [go["multi_source"]["speedup_bvss_vs_dense"]
             for go in graphs_out.values()]),
        "geomean_hardened_vs_plain": geomean(
            [go["hardened"]["plain_vs_hardened"]
             for go in graphs_out.values()]),
        "all_verified": all(
            go["serve"]["verified"] and go["hardened"]["verified"]
            for go in graphs_out.values()),
    }
    out = {
        **bench_envelope("pr2_graph_session_service", scale),
        "note": ("wave = GraphSession slot-pool serving (one batched BVSS "
                 "bit-SpMM pull per lock-step level, host refills between "
                 "levels); sequential = the same queries one-at-a-time "
                 "through the fused single-source engine; multi_source "
                 "compares the BVSS SpMM engine against the frozen dense "
                 "to_dense_bits baseline"),
        "graphs": graphs_out,
        "summary": summary,
    }
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=False)
        if verbose:
            print(f"# wrote {json_path}")
    if verbose:
        for k, v in summary.items():
            print(f"# {k}={v if isinstance(v, bool) else f'{v:.2f}x'}")
    return out


if __name__ == "__main__":
    run(json_path="BENCH_service.json")
