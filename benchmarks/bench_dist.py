"""PR-3 mesh-native benchmark: the sharded stack vs its single-device twin.

Per graph of the (small) suite:

* ``engine`` — the fused single-source engine prepared single-device vs
  prepared with ``mesh=...`` (same policy decisions, same LevelPipeline,
  the sharded one under ``shard_map``).  Levels of BOTH are verified
  against ``reference_bfs`` before timing is reported.
* ``serve`` — N level queries through the SHARDED GraphSession, (a)
  sequentially via the fused sharded single-source engine and (b) as one
  batched wave over the sharded slot pool (mid-flight refills, lock-step
  levels).  Wave answers verified against the oracle per query.
* ``betweenness`` — sampled-source Brandes through the MESH-NATIVE
  weighted sweeps (forward σ channel + psum-scattered backward, zero
  replicated problems) vs the single-device session, verified against
  both (<= 1e-6 rel err sharded-vs-single, NumPy Brandes oracle).

On this container the "devices" are simulated host-platform CPU devices,
so wall-clock ratios measure dispatch + collective overhead, not ICI
bandwidth — the honest claim is *parity* (verified levels through one
code path), with the sharded/single ratio recorded for trajectory.

``run(...)`` re-invokes itself in a subprocess with
``--xla_force_host_platform_device_count`` when the current process has
too few devices (the flag binds at backend init), so
``benchmarks/run.py --json`` can emit the ``dist`` suite of
``BENCH_pr3.json`` from an ordinary single-device session.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import (bench_envelope, fmt_row, geomean,
                               median_sec)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _dist_suite(scale: int) -> dict:
    """Small suite: one social-like and one high-diameter graph (the two
    regimes the update-scheme policy splits on)."""
    from repro.graphs import generators as gen
    side = int((1 << scale) ** 0.5)
    return {
        "kron": gen.rmat(scale, 16, seed=1),
        "road": gen.grid2d(side, side, shuffle=True, seed=3),
    }


def _median_bfs_time(levels_fn, sources) -> float:
    ts = []
    for s in sources:
        t0 = time.time()
        np.asarray(levels_fn(int(s)))
        ts.append(time.time() - t0)
    return float(np.median(ts))


def _run_inline(scale: int, devices: int, n_queries: int,
                verbose: bool) -> dict:
    from repro.core import reference_bfs
    from repro.core.policy import prepare
    from repro.distributed.bfs_dist import bfs_mesh
    from repro.serve import GraphSession

    mesh = bfs_mesh(devices)
    graphs_out = {}
    for gname, g in _dist_suite(scale).items():
        rng = np.random.default_rng(0)
        srcs = [int(s) for s in rng.integers(0, g.n, 3)]

        # -- engine: sharded vs single-device fused single-source ----------
        prep1 = prepare(g, w=512)
        prepD = prepare(g, w=512, mesh=mesh)
        verified = True
        for s in srcs:
            ref = reference_bfs(g, s)
            verified &= bool((prep1.levels(s) == ref).all())
            verified &= bool((prepD.levels(s) == ref).all())
        assert verified, f"{gname}: sharded engine diverges from oracle"
        t_1 = _median_bfs_time(prep1.levels, srcs)
        t_D = _median_bfs_time(prepD.levels, srcs)
        engine = {
            "n_sources": len(srcs),
            "single_sec": t_1, "sharded_sec": t_D,
            "ratio_sharded_vs_single": t_D / max(t_1, 1e-12),
            "verified": verified,
        }

        # -- serve: sharded wave vs sequential through the sharded engine --
        sess = GraphSession(g, max_batch=min(4, n_queries), w=512, mesh=mesh)
        queries = [int(q) for q in rng.integers(0, g.n, n_queries)]
        sess.levels(queries[0])                        # warm both paths
        sess.levels_batch(queries[: min(2, len(queries))])
        t0 = time.time()
        seq = [sess.levels(q) for q in queries]
        t_seq = time.time() - t0
        t0 = time.time()
        wave = sess.levels_batch(queries)
        t_wave = time.time() - t0
        sverified = all(
            (lv == reference_bfs(g, q)).all() and (lv == lv_s).all()
            for q, lv, lv_s in zip(queries, wave, seq))
        assert sverified, f"{gname}: sharded wave diverges from oracle"
        serve = {
            "n_queries": n_queries, "max_batch": sess.max_batch,
            "sequential_sec": t_seq, "wave_sec": t_wave,
            "speedup": t_seq / max(t_wave, 1e-12), "verified": sverified,
        }

        # -- betweenness: mesh-native weighted sweeps vs single-device -----
        from repro.kernels.ref import betweenness_ref
        sess1 = GraphSession(g, max_batch=min(4, n_queries), w=512)
        pivots = rng.choice(g.n, size=min(3, g.n), replace=False)
        sess1.betweenness_batch(pivots)                      # warm both widths
        sess.betweenness_batch(pivots)
        bc1 = sess1.betweenness_batch(pivots)
        bcD = sess.betweenness_batch(pivots)
        scale_bc = max(float(np.abs(bc1).max()), 1.0)
        rel_err = float(np.abs(bcD - bc1).max()) / scale_bc
        ref_bc = betweenness_ref(g, pivots)
        bverified = bool(
            rel_err <= 1e-6
            and float(np.abs(bcD - ref_bc).max()) / scale_bc < 1e-4)
        assert bverified, f"{gname}: sharded betweenness err {rel_err}"
        t_bc1 = median_sec(lambda: sess1.betweenness_batch(pivots))
        t_bcD = median_sec(lambda: sess.betweenness_batch(pivots))
        bet = {
            "n_pivots": int(len(pivots)),
            "single_sec": t_bc1, "sharded_sec": t_bcD,
            "single_vs_sharded": t_bc1 / max(t_bcD, 1e-12),
            "max_rel_err_vs_single": rel_err, "verified": bverified,
        }

        graphs_out[gname] = {
            "n": int(g.n), "m": int(g.m),
            "ordering": prepD.ordering, "engine": prepD.engine_name,
            "rows_per_shard": int(prepD.problem.rows_per_shard),
            "vss_per_shard": int(prepD.problem.num_vss),
            "frontier_bytes_per_level": int(prepD.problem.n_fwords * 4),
            "engine_dist": engine, "serve_dist": serve,
            "betweenness_dist": bet,
        }
        if verbose:
            print(fmt_row(f"bench_dist/{gname}/engine", t_D * 1e6,
                          f"vs_single={engine['ratio_sharded_vs_single']:.2f}"))
            print(fmt_row(f"bench_dist/{gname}/serve", t_wave * 1e6,
                          f"speedup={serve['speedup']:.2f}"))
            print(fmt_row(f"bench_dist/{gname}/betweenness", t_bcD * 1e6,
                          f"single_vs_sharded={bet['single_vs_sharded']:.2f}"))

    summary = {
        "geomean_ratio_sharded_vs_single": geomean(
            [go["engine_dist"]["ratio_sharded_vs_single"]
             for go in graphs_out.values()]),
        "geomean_wave_speedup": geomean(
            [go["serve_dist"]["speedup"] for go in graphs_out.values()]),
        "geomean_bc_single_vs_sharded": geomean(
            [go["betweenness_dist"]["single_vs_sharded"]
             for go in graphs_out.values()]),
        "all_verified": all(
            go["engine_dist"]["verified"] and go["serve_dist"]["verified"]
            and go["betweenness_dist"]["verified"]
            for go in graphs_out.values()),
    }
    out = {
        **bench_envelope("pr5_dist", scale),
        "devices": devices,
        "note": ("engine = fused single-source BFS, prepared single-device "
                 "vs mesh-native (row-sharded BVSS, shard_map'd "
                 "LevelPipeline, frontier all-gather + psum convergence); "
                 "serve = sharded GraphSession batched waves vs sequential "
                 "queries through the sharded engine; betweenness = "
                 "mesh-native Brandes (sharded σ forward + psum-scattered "
                 "backward, zero replicated problems) vs the single-device "
                 "session; devices are simulated host-platform CPU devices, "
                 "so ratios measure dispatch + collective overhead, not ICI"),
        "graphs": graphs_out,
        "summary": summary,
    }
    if verbose:
        for k, v in summary.items():
            print(f"# {k}={v if isinstance(v, bool) else f'{v:.2f}x'}")
    return out


def _trace_comm_bytes(problem) -> int:
    """Per-device communication bytes for ONE BFS level: trace the fused
    single-source engine under the trace-time comm ledger
    (``distributed.collectives.comm_ledger``) — the ``while_loop`` body
    traces exactly once, so the recorded collective payloads are one
    level's worth on one device."""
    from repro.core.bfs import make_blest_bfs
    from repro.distributed.collectives import comm_ledger

    fn = make_blest_bfs(problem, lazy=False)
    with comm_ledger() as events:
        fn.lower(0)
    return int(sum(nb for _, nb in events))


def _run_2d_inline(scale: int, verbose: bool) -> dict:
    """The 2-D partition block (PR-8): butterfly vs flat per-device
    communication volume as the mesh grows, plus oracle-verified parity
    of the 2-D engines on 2x2 and 4x2 meshes.  Needs >= 8 devices
    in-process; ``scale`` is floored at 8 (below that the 32·cols
    alignment pads every row block to the same size and the volumes
    degenerate)."""
    from repro.core import reference_bfs
    from repro.core.policy import prepare
    from repro.distributed.bfs_dist import bfs_mesh, bfs_mesh2d

    scale = max(scale, 8)
    g = _dist_suite(scale)["kron"]
    rng = np.random.default_rng(0)
    srcs = [int(s) for s in rng.integers(0, g.n, 3)]
    refs = {s: reference_bfs(g, s) for s in srcs}

    meshes_out = {}
    verified = True
    for rows, cols in [(2, 2), (4, 2)]:
        mesh = bfs_mesh2d(rows, cols)
        prep = prepare(g, w=512, mesh=mesh)
        ok = all(bool((prep.levels(s) == refs[s]).all()) for s in srcs)
        assert ok, f"2-D engine diverges from oracle on {rows}x{cols}"
        verified &= ok
        meshes_out[f"{rows}x{cols}"] = {
            "devices": rows * cols,
            "rows_per_shard": int(prep.problem.rows_per_shard),
            "cols_per_block": int(prep.problem.cols_per_block),
            "frontier_words_local": int(prep.problem.n_fwords),
            "median_bfs_sec": _median_bfs_time(prep.levels, srcs),
            "verified": ok,
            "comm_bytes_per_level": _trace_comm_bytes(prep.problem),
        }

    flat = {}
    for d in (4, 8):
        prep = prepare(g, w=512, mesh=bfs_mesh(d))
        flat[d] = _trace_comm_bytes(prep.problem)

    b22 = meshes_out["2x2"]["comm_bytes_per_level"]
    b42 = meshes_out["4x2"]["comm_bytes_per_level"]
    comm = {
        "flat_bytes_per_level_4dev": flat[4],
        "flat_bytes_per_level_8dev": flat[8],
        "butterfly_bytes_per_level_2x2": b22,
        "butterfly_bytes_per_level_4x2": b42,
        # >1 means per-device traffic SHRINKS as the mesh grows 4 -> 8
        "butterfly_shrink_4_to_8": b22 / max(b42, 1),
        "flat_shrink_4_to_8": flat[4] / max(flat[8], 1),
    }
    assert comm["butterfly_shrink_4_to_8"] > 1.0, (
        f"butterfly per-device bytes/level must shrink with the mesh: "
        f"2x2={b22}B vs 4x2={b42}B")
    assert comm["flat_shrink_4_to_8"] <= 1.0, (
        f"flat all-gather bytes/level should NOT shrink (it grows with "
        f"device count): 4dev={flat[4]}B vs 8dev={flat[8]}B")
    if verbose:
        for mname, mo in meshes_out.items():
            print(fmt_row(f"bench_dist/dist2d/{mname}",
                          mo["median_bfs_sec"] * 1e6,
                          f"comm={mo['comm_bytes_per_level']}B/level"))
        print(f"# butterfly_shrink_4_to_8="
              f"{comm['butterfly_shrink_4_to_8']:.2f}x "
              f"(flat: {comm['flat_shrink_4_to_8']:.2f}x)")
    return {
        "scale": scale,
        "note": ("per-device collective payload bytes for ONE level, "
                 "recorded at trace time: 2-D butterfly (OR-allreduce "
                 "over columns + segment exchange over rows) vs the 1-D "
                 "flat frontier all-gather; shrink = 4-device bytes / "
                 "8-device bytes, >1 iff traffic shrinks as the mesh "
                 "grows"),
        "meshes": meshes_out,
        "comm": comm,
        "verified": verified,
    }


def run_2d(scale: int = 8, json_path: str | None = None,
           verbose: bool = True) -> dict:
    """The dist2d block, re-exec'd with 8 forced host devices if this
    process has fewer (same discipline as :func:`run`)."""
    import jax

    if len(jax.devices()) >= 8:
        out = _run_2d_inline(scale, verbose)
    else:
        flag = "--xla_force_host_platform_device_count=8"
        if flag in os.environ.get("XLA_FLAGS", ""):
            raise RuntimeError(
                f"{flag} set but only {len(jax.devices())} devices came up")
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
            tmp = f.name
        try:
            env = dict(os.environ)
            # drop any smaller forced-device-count flag a parent re-exec
            # set (last flag wins only by accident; be explicit)
            base = " ".join(
                t for t in env.get("XLA_FLAGS", "").split()
                if not t.startswith(
                    "--xla_force_host_platform_device_count"))
            env["XLA_FLAGS"] = (base + " " + flag).strip()
            env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                                 + env.get("PYTHONPATH", "")
                                 ).rstrip(os.pathsep)
            cmd = [sys.executable, "-m", "benchmarks.bench_dist",
                   "--dist2d-only", "--scale", str(scale), "--json", tmp]
            res = subprocess.run(cmd, env=env, cwd=REPO, capture_output=True,
                                 text=True, timeout=3000)
            if res.returncode != 0:
                raise RuntimeError(
                    f"bench_dist --dist2d-only subprocess failed:\n"
                    f"{res.stdout}\n{res.stderr}")
            if verbose and res.stdout:
                print("\n".join(l for l in res.stdout.splitlines()
                                if not l.startswith("# wrote ")))
            with open(tmp) as f:
                out = json.load(f)
        finally:
            os.unlink(tmp)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=False)
        if verbose:
            print(f"# wrote {json_path}")
    return out


def run(scale: int = 8, devices: int = 2, n_queries: int = 6,
        json_path: str | None = None, verbose: bool = True) -> dict:
    import jax

    if len(jax.devices()) >= devices:
        out = _run_inline(scale, devices, n_queries, verbose)
    else:
        # too few devices in this process: the device-count flag binds at
        # backend init, so re-run this module in a child with it set
        flag = f"--xla_force_host_platform_device_count={devices}"
        if flag in os.environ.get("XLA_FLAGS", ""):
            # the flag is already set but didn't take (non-CPU backend):
            # recursing would spawn children forever
            raise RuntimeError(
                f"{flag} set but only {len(jax.devices())} devices came up")
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
            tmp = f.name
        try:
            env = dict(os.environ)
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " "
                                + flag).strip()
            env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                                 + env.get("PYTHONPATH", "")
                                 ).rstrip(os.pathsep)
            cmd = [sys.executable, "-m", "benchmarks.bench_dist",
                   "--scale", str(scale), "--devices", str(devices),
                   "--queries", str(n_queries), "--json", tmp]
            res = subprocess.run(cmd, env=env, cwd=REPO, capture_output=True,
                                 text=True, timeout=3000)
            if res.returncode != 0:
                raise RuntimeError(
                    f"bench_dist subprocess failed:\n{res.stdout}\n"
                    f"{res.stderr}")
            if verbose and res.stdout:
                print("\n".join(l for l in res.stdout.splitlines()
                                if not l.startswith("# wrote ")))
            with open(tmp) as f:
                out = json.load(f)
        finally:
            os.unlink(tmp)
    if "dist2d" not in out:   # child runs append it before writing JSON
        out["dist2d"] = run_2d(scale, verbose=verbose)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1, sort_keys=False)
        if verbose:
            print(f"# wrote {json_path}")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--devices", type=int, default=2)
    ap.add_argument("--queries", type=int, default=6)
    ap.add_argument("--json", default=None)
    ap.add_argument("--dist2d-only", action="store_true",
                    help="emit only the 2-D butterfly comm-volume block")
    args = ap.parse_args(argv)
    if args.dist2d_only:
        run_2d(scale=args.scale, json_path=args.json)
        return
    run(scale=args.scale, devices=args.devices, n_queries=args.queries,
        json_path=args.json)


if __name__ == "__main__":
    main()
