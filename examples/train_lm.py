"""Train a small LM end to end with the full substrate (checkpointing,
restart, deterministic pipeline), including a mid-run chaos drill.

    PYTHONPATH=src python examples/train_lm.py
"""
import tempfile

from repro.launch.train import main as train_main


def run():
    with tempfile.TemporaryDirectory() as d:
        train_main([
            "--arch", "qwen3-0.6b", "--steps", "60", "--batch", "8",
            "--seq-len", "64", "--ckpt-dir", d, "--ckpt-every", "20",
            "--fail-at", "35",   # chaos drill: injected failure + restart
        ])


if __name__ == "__main__":
    run()
