"""Quickstart: the BLEST pipeline end to end on a synthetic scale-free graph.

The first half uses only the stable ``repro`` façade — prepare once, query
many times, stream edge updates.  The second half drops to the deep
modules to race every engine variant (internals, not part of the façade
contract).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

import repro


def main():
    from repro.graphs import generators as gen
    g = gen.rmat(11, 12, seed=7)

    # the ONE static pipeline: classify, order, build BVSS, pick engine
    prep = repro.prepare(g, options=repro.PrepareOptions(w=512, seed=7))
    print(f"graph: n={g.n} m={g.m}  ordering={prep.ordering} "
          f"engine={prep.engine_name} "
          f"compression={prep.bvss.compression_ratio():.3f}")

    src = 0
    lv = prep.levels(src)
    print(f"BFS from {src}: "
          f"{int((lv != np.iinfo(np.int32).max).sum())} reachable")

    # streaming maintenance: patch edges into the prepared BVSS; the
    # epoch bumps and the same object keeps answering queries
    prep2 = repro.apply_edge_updates(prep, inserts=[(src, g.n - 1)])
    print(f"after insert ({src}, {g.n - 1}): path={prep2.last_update.path} "
          f"epoch={prep2.epoch} level[{g.n - 1}]="
          f"{int(prep2.levels(src)[g.n - 1])}")

    # --- internals below: race the engine variants head to head --------
    from repro.core import ENGINES, build_bvss, make_engine, reference_bfs
    from repro.core.ordering import auto_order, social_like_report

    rep = social_like_report(g)
    print(f"social-like={rep.is_social}")
    perm, kind = auto_order(g, w=512)
    g_ord = g.permute_fast(perm)
    for name, gg in [("natural", g), (kind, g_ord)]:
        b = build_bvss(gg)
        print(f"  {name:16s} compression={b.compression_ratio():.3f} "
              f"update_divergence={b.update_divergence():8.1f}")

    ref = reference_bfs(g_ord, src)
    for engine in ENGINES:
        if engine == "dense_pull" and g.n > 4096:
            continue
        fn = make_engine(g_ord, engine)
        fn(src)  # compile
        t0 = time.time()
        lv = np.asarray(fn(src))
        dt = (time.time() - t0) * 1e3
        ok = "OK " if (lv == ref).all() else "FAIL"
        print(f"  {engine:12s} {dt:8.2f} ms  {ok}")


if __name__ == "__main__":
    main()
