"""Quickstart: the BLEST pipeline end to end on a synthetic scale-free graph.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core import ENGINES, build_bvss, make_engine, reference_bfs
from repro.core.ordering import auto_order, social_like_report
from repro.graphs import generators as gen


def main():
    g = gen.rmat(11, 12, seed=7)
    rep = social_like_report(g)
    print(f"graph: n={g.n} m={g.m}  social-like={rep.is_social}")

    # paper §3.2: one ordering decision to pull them all
    perm, kind = auto_order(g, w=512)
    g_ord = g.permute_fast(perm)
    for name, gg in [("natural", g), (kind, g_ord)]:
        b = build_bvss(gg)
        print(f"  {name:16s} compression={b.compression_ratio():.3f} "
              f"update_divergence={b.update_divergence():8.1f}")

    src = 0
    ref = reference_bfs(g_ord, src)
    print(f"BFS from {src}: {int((ref != np.iinfo(np.int32).max).sum())} "
          f"reachable, {ref[ref != np.iinfo(np.int32).max].max()} levels")
    for engine in ENGINES:
        if engine == "dense_pull" and g.n > 4096:
            continue
        fn = make_engine(g_ord, engine)
        fn(src)  # compile
        t0 = time.time()
        lv = np.asarray(fn(src))
        dt = (time.time() - t0) * 1e3
        ok = "OK " if (lv == ref).all() else "FAIL"
        print(f"  {engine:12s} {dt:8.2f} ms  {ok}")


if __name__ == "__main__":
    main()
