"""End-to-end driver: a graph-analytics service answering batched BFS and
centrality queries with the full BLEST pipeline (the paper's kind of
workload — serve a graph, not train a model).

Everything comes through the stable ``repro`` façade: a multi-tenant
:class:`repro.GraphSessionManager`, the async :class:`repro.RequestQueue`
(non-blocking submits that coalesce into shared multi-source waves), and
streaming edge updates via :meth:`GraphSessionManager.update_edges`.

    PYTHONPATH=src python examples/bfs_service.py
"""
import time

import numpy as np

import repro


def main():
    from repro.graphs import generators as gen
    g = gen.rmat(10, 10, seed=3)

    mgr = repro.GraphSessionManager()
    sess = mgr.open_session("social", g, max_batch=4,
                            options=repro.PrepareOptions(w=512, seed=0))
    print(f"service up: n={g.n} m={g.m} ordering={sess.ordering} "
          f"compression={sess.bvss.compression_ratio():.3f} "
          f"preprocess={sess.preprocess_s:.2f}s")

    rng = np.random.default_rng(0)
    queries = [int(q) for q in rng.integers(0, g.n, 12)]
    sess.levels(queries[0])           # warm the single-source path
    sess.levels_batch(queries[:2])    # warm the wave path

    t0 = time.time()
    seq = [sess.levels(q) for q in queries]
    t_seq = time.time() - t0

    # async path: submit returns a future immediately; drain() coalesces
    # the backlog into max_batch-wide waves, refilling slots mid-flight
    queue = repro.RequestQueue(mgr)
    t0 = time.time()
    futs = [queue.submit("social", q) for q in queries]
    queue.drain()
    lvs = [f.result(0) for f in futs]
    t_queue = time.time() - t0

    from repro.core import reference_bfs
    for q, lv_s, lv in zip(queries, seq, lvs):
        ref = reference_bfs(g, q)
        assert (lv_s == ref).all(), f"query {q} mismatch"
        assert (lv == ref).all(), f"queued query {q} mismatch"
    qs = queue.stats()
    print(f"served {len(queries)} level queries: sequential {t_seq:.2f}s, "
          f"queued {t_queue:.2f}s over {qs['waves']} waves "
          f"({qs['coalesced']} coalesced mid-flight, "
          f"{t_seq / max(t_queue, 1e-9):.2f}x, all verified)")

    t0 = time.time()
    srcs, cc = sess.closeness_sample(8, seed=0)
    print(f"closeness-centrality sample (8 sources, BVSS bit-SpMM waves): "
          f"{time.time() - t0:.2f}s, sources={srcs.tolist()}, "
          f"mean={cc.mean():.4f}")

    # streaming maintenance: patch a handful of edges into the prepared
    # BVSS in place — no full re-prepare, epoch bumps, session keeps serving
    wrng = np.random.default_rng(1)
    new_edges = sorted({(int(a), int(b)) for a, b in
                        wrng.integers(0, g.n, (4, 2)) if a != b})
    report = mgr.update_edges("social", inserts=new_edges)
    if report is not None:
        print(f"edge update: path={report.path} epoch={report.epoch} "
              f"+{report.n_inserted} edges "
              f"({report.vss_rows_rewritten} VSS rows rewritten)")
        a, b = new_edges[0]
        lv = sess.levels(a)
        print(f"post-update query from {a}: new edge ({a}, {b}) live "
              f"(level[{b}]={int(lv[b])}), reached "
              f"{(lv < np.iinfo(np.int32).max).sum()}/{g.n}")


if __name__ == "__main__":
    main()
