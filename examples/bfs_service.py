"""End-to-end driver: a graph-analytics service answering batched BFS and
centrality queries with the full BLEST pipeline (the paper's kind of
workload — serve a graph, not train a model).

    PYTHONPATH=src python examples/bfs_service.py
"""
import time

import numpy as np

from repro.core import build_bvss, make_engine, reference_bfs
from repro.core.multi_source import closeness_centrality
from repro.core.ordering import auto_order
from repro.graphs import generators as gen


class GraphService:
    """Preprocesses a graph once (ordering decision + BVSS + fused engine),
    then serves single-source level queries and sampled centrality."""

    def __init__(self, g, *, seed=0):
        t0 = time.time()
        self.perm, self.kind = auto_order(g, w=512, seed=seed)
        self.g = g.permute_fast(self.perm)
        self.inv = np.empty(g.n, dtype=np.int64)
        self.inv[self.perm] = np.arange(g.n)
        self.bvss = build_bvss(self.g)
        self.engine = make_engine(self.g, "blest_lazy", bvss=self.bvss)
        self.engine(0)  # warm up / compile
        self.preprocess_s = time.time() - t0

    def levels(self, src: int) -> np.ndarray:
        lv = np.asarray(self.engine(int(self.perm[src])))
        return lv[self.perm]  # back to caller's vertex ids

    def centrality_sample(self, n_sources: int, seed=0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        srcs = self.perm[rng.integers(0, self.g.n, n_sources)]
        return closeness_centrality(self.g, srcs.astype(np.int32))


def main():
    g = gen.rmat(10, 10, seed=3)
    svc = GraphService(g)
    print(f"service up: n={g.n} m={g.m} ordering={svc.kind} "
          f"compression={svc.bvss.compression_ratio():.3f} "
          f"preprocess={svc.preprocess_s:.2f}s")

    rng = np.random.default_rng(0)
    queries = rng.integers(0, g.n, 12)
    t0 = time.time()
    for q in queries:
        lv = svc.levels(int(q))
        ref = reference_bfs(g, int(q))
        assert (lv == ref).all(), f"query {q} mismatch"
    dt = time.time() - t0
    print(f"served {len(queries)} level queries in {dt:.2f}s "
          f"({dt / len(queries) * 1e3:.1f} ms/query, all verified)")

    t0 = time.time()
    cc = svc.centrality_sample(8)
    print(f"closeness-centrality sample (8 sources, MXU bit-SpMM path): "
          f"{time.time() - t0:.2f}s, mean={cc.mean():.4f}")


if __name__ == "__main__":
    main()
