"""End-to-end driver: a graph-analytics service answering batched BFS and
centrality queries with the full BLEST pipeline (the paper's kind of
workload — serve a graph, not train a model).

All the heavy lifting lives in :class:`repro.serve.GraphSession` (prepared
ordering/BVSS/engines + wave batching); this example is a thin client.

    PYTHONPATH=src python examples/bfs_service.py
"""
import time

import numpy as np

from repro.core import reference_bfs
from repro.serve import GraphSession
from repro.graphs import generators as gen


class GraphService:
    """Thin client over GraphSession: single queries, batched waves, and
    sampled centrality — everything in the caller's original vertex ids."""

    def __init__(self, g, *, max_batch=4, seed=0):
        self.session = GraphSession(g, max_batch=max_batch, w=512, seed=seed)
        self.kind = self.session.ordering
        self.bvss = self.session.bvss
        self.preprocess_s = self.session.preprocess_s

    def levels(self, src: int) -> np.ndarray:
        return self.session.levels(src)

    def levels_batch(self, sources) -> list:
        return self.session.levels_batch(sources)

    def centrality_sample(self, n_sources: int, seed=0):
        return self.session.centrality_sample(n_sources, seed=seed)


def main():
    g = gen.rmat(10, 10, seed=3)
    svc = GraphService(g, max_batch=4)
    print(f"service up: n={g.n} m={g.m} ordering={svc.kind} "
          f"compression={svc.bvss.compression_ratio():.3f} "
          f"preprocess={svc.preprocess_s:.2f}s")

    rng = np.random.default_rng(0)
    queries = [int(q) for q in rng.integers(0, g.n, 12)]
    svc.levels(queries[0])           # warm the single-source path
    svc.levels_batch(queries[:2])    # warm the wave path

    t0 = time.time()
    seq = [svc.levels(q) for q in queries]
    t_seq = time.time() - t0

    t0 = time.time()
    lvs = svc.levels_batch(queries)
    t_wave = time.time() - t0
    for q, lv_s, lv in zip(queries, seq, lvs):
        ref = reference_bfs(g, q)
        assert (lv_s == ref).all(), f"query {q} mismatch"
        assert (lv == ref).all(), f"wave query {q} mismatch"
    print(f"served {len(queries)} level queries: sequential {t_seq:.2f}s, "
          f"batched wave {t_wave:.2f}s "
          f"({t_seq / max(t_wave, 1e-9):.2f}x, all verified)")

    t0 = time.time()
    srcs, cc = svc.centrality_sample(8)
    print(f"closeness-centrality sample (8 sources, BVSS bit-SpMM waves): "
          f"{time.time() - t0:.2f}s, sources={srcs.tolist()}, "
          f"mean={cc.mean():.4f}")


if __name__ == "__main__":
    main()
