"""Serve a small LM with continuous batching.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "gemma3-1b", "--requests", "10", "--max-new", "8",
                "--max-batch", "4"])
