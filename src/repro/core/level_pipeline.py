"""Shared BFS level-step skeleton (DESIGN.md §2.3).

Every device engine — eager BLEST (Alg. 2), lazy BLEST (Alg. 3), the BRS
baseline sweep, and the multi-source bit-SpMM path — performs the same
four-stage level step:

    gather   frontier operands for the pull (frontier bytes / bit columns)
    pull     the wide slice×frontier product (Pallas VPU / MXU kernel)
    update   scatter the hits into levels or marks
    finalize finalise levels + rebuild the frontier representation
             (pack words, flag sets, compact the queue)

``LevelPipeline`` captures that shape; ``run_levels`` is the single
on-device ``while_loop`` driver all engines share, so control never
returns to the host between levels (the TPU analogue of the paper's
persistent kernel, §4.3) and the convergence test is on-device.

``step`` is one fused gather→pull→update pass.  Engines whose pull is a
plain composition use :func:`compose_step`; the BLEST engines build a
bucketed step instead (two statically-shaped queue widths selected by
``lax.cond`` on the live VSS count — the XLA-compatible stand-in for the
paper's dynamically-sized kernel launches).

The same skeleton is mesh-native (DESIGN §2.4): under ``shard_map`` the
``step`` stays purely local (each shard pulls/scatters its row block), the
``finalize`` all-gathers the per-shard frontier words, and the ``active``
predicate is made globally consistent with :func:`global_any` — a ``psum``
convergence test INSIDE the fused ``while_loop``, so the paper's
no-host-sync discipline (§4.3) holds across devices too.  ``run_levels``
is unchanged in either mode: one driver, any mesh shape.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

State = Any  # engine-specific pytree carried through the level loop


@dataclasses.dataclass(frozen=True)
class LevelPipeline:
    """One BFS level = ``step`` (gather → pull → update) then ``finalize``
    (finalise / pack / compact); ``active`` is the on-device continuation
    predicate."""

    step: Callable[[State, jnp.ndarray], State]
    finalize: Callable[[State, jnp.ndarray], State]
    active: Callable[[State], jnp.ndarray]


def compose_step(gather: Callable[[State], tuple],
                 pull: Callable[..., jnp.ndarray],
                 update: Callable[[State, jnp.ndarray, jnp.ndarray], State]
                 ) -> Callable[[State, jnp.ndarray], State]:
    """Fuse the three leading stages into one ``step`` callable."""
    def step(state: State, lvl: jnp.ndarray) -> State:
        return update(state, pull(*gather(state)), lvl)
    return step


def global_any(pred: jnp.ndarray,
               axis: "str | tuple[str, ...] | None") -> jnp.ndarray:
    """Continuation predicate across the mesh: ``pred`` is this shard's
    local "still work to do" bool; the result is True iff ANY shard says so
    (identical on every device, so the shared ``while_loop`` stays in
    lock-step).  ``axis=None`` is the single-device identity; a tuple of
    axis names reduces over all of them (the 2-D row × column mesh)."""
    if axis is None:
        return pred
    return jax.lax.psum(pred.astype(jnp.int32), axis) > 0


def run_levels(pipe: LevelPipeline, state: State, *, max_levels: int
               ) -> tuple[State, jnp.ndarray]:
    """Run the whole level loop on device; returns (final state, n_levels)."""
    def cond(carry):
        st, lvl = carry
        return pipe.active(st) & (lvl < max_levels)

    def body(carry):
        st, lvl = carry
        lvl = lvl + 1
        st = pipe.step(st, lvl)
        st = pipe.finalize(st, lvl)
        return st, lvl

    state, lvl = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    return state, lvl


def run_levels_recorded(pipe: LevelPipeline, state: State, *,
                        max_levels: int, history: State,
                        record: Callable[[State, State, jnp.ndarray], State]
                        ) -> tuple[State, jnp.ndarray, State]:
    """:func:`run_levels` with a per-level *history* channel: before each
    level's ``step``, ``record(hist, state, lvl)`` folds the pre-step state
    into a caller-preallocated buffer pytree (e.g. ``hist.Q.at[lvl].set``).

    This is how a traversal exposes its per-level frontier history to a
    consumer that must replay it — the Brandes backward dependency sweep
    (``repro.analytics.betweenness``) re-walks the recorded per-level VSS
    queues in reverse, so the backward phase touches exactly the tiles the
    forward phase pulled.  Still ONE fused on-device ``while_loop``; the
    history buffer is just extra carry.
    """
    def cond(carry):
        st, lvl, _ = carry
        return pipe.active(st) & (lvl < max_levels)

    def body(carry):
        st, lvl, hist = carry
        lvl = lvl + 1
        hist = record(hist, st, lvl)
        st = pipe.step(st, lvl)
        st = pipe.finalize(st, lvl)
        return st, lvl, hist

    state, lvl, history = jax.lax.while_loop(
        cond, body, (state, jnp.int32(0), history))
    return state, lvl, history
