"""BLEST's static execution policy (paper §5, Table 2 "full" variant).

The paper's pipeline makes two static decisions per graph:
  1. ordering: social-like -> JaccardWithWindows (+pre-pass); else RCM;
  2. update scheme: lazy vertex updates only when the update divergence
     exceeds a threshold (paper: 25,000) — the lazy Θ(n) sweep pays off on
     low-diameter social graphs with scattered updates, and hurts on
     high-diameter graphs (Spielman_k600's 600 levels in the paper).

``prepare(graph)`` runs the whole static pipeline and returns a ready
engine; this is exactly what BLEST (full) does before the first BFS.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.bfs import make_engine, reference_bfs
from repro.core.bvss import BVSS, build_bvss
from repro.core.ordering import auto_order, is_social_like
from repro.graphs import Graph

# paper §5: fixed threshold for switching to lazy vertex updates
LAZY_UDIV_THRESHOLD = 25_000.0
# at lab scale the same mechanism is exercised with a proportional
# threshold (the paper's constant assumes 23M+ vertex graphs)
LAZY_UDIV_FRACTION = 0.1


@dataclasses.dataclass
class PreparedBFS:
    graph: Graph           # reordered graph
    perm: np.ndarray       # old id -> new id
    ordering: str
    engine_name: str
    bvss: BVSS
    update_divergence: float
    _fn: Callable = None

    def levels(self, src: int) -> np.ndarray:
        """BFS levels in the caller's (original) vertex ids."""
        lv = np.asarray(self._fn(int(self.perm[src])))
        return lv[self.perm]


def choose_update_scheme(bvss: BVSS, *, threshold: float | None = None
                         ) -> str:
    """Paper §5: lazy updates iff the update divergence is high (scattered
    updates dominate) — otherwise the eager scheme avoids the Θ(n) sweep."""
    udiv = bvss.update_divergence()
    if threshold is None:
        threshold = min(LAZY_UDIV_THRESHOLD, LAZY_UDIV_FRACTION * bvss.n)
    return "blest_lazy" if udiv > threshold else "blest"


def prepare(g: Graph, *, sigma: int = 8, w: int = 512, seed: int = 0,
            lazy_threshold: float | None = None) -> PreparedBFS:
    perm, kind = auto_order(g, sigma=sigma, w=w, seed=seed)
    g_ord = g.permute_fast(perm)
    bvss = build_bvss(g_ord, sigma=sigma)
    engine_name = choose_update_scheme(bvss, threshold=lazy_threshold)
    fn = make_engine(g_ord, engine_name, bvss=bvss)
    return PreparedBFS(graph=g_ord, perm=perm, ordering=kind,
                       engine_name=engine_name, bvss=bvss,
                       update_divergence=bvss.update_divergence(), _fn=fn)


def parents_from_levels(g: Graph, levels: np.ndarray) -> np.ndarray:
    """BFS parent array (paper §2: the kernel may return either form).

    Pull semantics: parent[u] is any in-neighbour of u at level[u]-1.
    Host-side NumPy pass over the in-CSR (one sweep, vectorisable)."""
    INF = np.iinfo(np.int32).max
    t_indptr, t_indices = g.t_csr
    parents = np.full(g.n, -1, dtype=np.int64)
    for u in range(g.n):
        lu = levels[u]
        if lu == 0 or lu == INF:
            continue
        nbrs = t_indices[t_indptr[u]:t_indptr[u + 1]]
        ok = nbrs[levels[nbrs] == lu - 1]
        if len(ok):
            parents[u] = ok[0]
    return parents
