"""BLEST's static execution policy (paper §5, Table 2 "full" variant).

The paper's pipeline makes two static decisions per graph:
  1. ordering: social-like -> JaccardWithWindows (+pre-pass); else RCM;
  2. update scheme: lazy vertex updates only when the update divergence
     exceeds a threshold (paper: 25,000) — the lazy Θ(n) sweep pays off on
     low-diameter social graphs with scattered updates, and hurts on
     high-diameter graphs (Spielman_k600's 600 levels in the paper).

``prepare(graph)`` runs the whole static pipeline and returns a ready
engine; this is exactly what BLEST (full) does before the first BFS.  It is
the ONE ordering/BVSS/engine preparation in the tree: the launcher, the
serving layer (``repro.serve.GraphSession``) and the examples all go
through it rather than re-implementing order -> permute -> BVSS -> engine.

``prepare(graph, mesh=...)`` is the one SHARDED preparation too (DESIGN
§2.4): the same classify/order/scheme decisions run on the global BVSS,
then the problem is built row-sharded over the mesh axis and the engines
run the same fused pipeline under ``shard_map``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

import numpy as np
from jax.sharding import Mesh

from repro.core.autotune import TileConfig
from repro.core.bfs import BlestProblem, make_engine
from repro.core.bvss import (BVSS, build_bvss, build_sharded_bvss,
                             build_sharded_weight_plane, build_weight_plane,
                             weight_plane_to_device)
from repro.core.ordering import auto_order
from repro.errors import BlestError, ConfigError, check_source, check_weights
from repro.graphs import Graph

# paper §5: fixed threshold for switching to lazy vertex updates
LAZY_UDIV_THRESHOLD = 25_000.0
# at lab scale the same mechanism is exercised with a proportional
# threshold (the paper's constant assumes 23M+ vertex graphs)
LAZY_UDIV_FRACTION = 0.1


@dataclasses.dataclass(frozen=True, eq=False)
class PrepareOptions:
    """Every static knob of :func:`prepare`, as one typed value.

    The public way to configure a preparation::

        prepare(g, options=PrepareOptions(sigma=4, autotune=True))

    The options ride along on ``PreparedBFS.options`` so downstream
    maintenance (:func:`repro.core.bvss_delta.apply_edge_updates`) can
    rebuild engines — or fall back to a full re-``prepare`` — with
    exactly the knobs the original preparation used.  ``eq=False``:
    ``weights`` may be an array, and identity is the only comparison a
    frozen bag of build knobs needs.
    """

    sigma: int = 8                        # slice width (bits)
    w: int = 512                          # ordering window
    seed: int = 0                         # ordering shingle seed
    lazy_threshold: float | None = None   # lazy-update divergence override
    order: bool = True                    # run the ordering pre-pass
    engine: str | None = None             # explicit engine override
    use_kernels: bool = True              # Pallas kernels vs pure-jnp twins
    buckets: int = 2                      # queue-width ladder graduations
    direction: str = "auto"               # push/pull hybrid mode
    autotune: bool = False                # measure hybrid knobs per backend
    push_impl: Callable | None = None     # push-kernel fault seam
    mesh: Mesh | None = None              # row-shard over this device mesh
    mesh_axis: str = "data"               # row axis name of the mesh
    weights: np.ndarray | None = None     # per-edge weights of the INPUT g

    def replace(self, **changes) -> "PrepareOptions":
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass
class PreparedBFS:
    graph: Graph           # reordered graph
    perm: np.ndarray       # old id -> new id
    inv: np.ndarray        # new id -> old id (perm's inverse)
    ordering: str
    engine_name: str
    bvss: BVSS
    # device-resident BVSS bundle, shared with the engine; None when the
    # prepared engine is a CSR/dense baseline that never touches the BVSS
    problem: BlestProblem | None
    update_divergence: float
    # mesh the problem is row-sharded over; None = single-device
    mesh: Mesh | None = None
    # per-edge weights in the REORDERED graph's CSR edge order (float32)
    # and their device-committed weight plane (DESIGN §2.9: the min-plus /
    # weighted-verb operand, +inf dummy row appended); None = unweighted
    weights: np.ndarray | None = None
    wplane: "object | None" = None
    # winning hybrid knobs when prepared with autotune=True (DESIGN §2.8);
    # None = defaults were used.  ``tile_config.source == "cached"`` means
    # this prepare() re-used an earlier measurement (zero tuning
    # dispatches) — the memoisation contract tests assert on it
    tile_config: "TileConfig | None" = None
    # the exact knobs this preparation ran with — incremental maintenance
    # (core/bvss_delta.py) rebuilds engines / re-prepares through them
    options: "PrepareOptions | None" = None
    # epoch version of the prepared state (DESIGN §2.10): bumped by every
    # apply_edge_updates; in-flight waves keep pulling the buffers of the
    # epoch they were built against (JAX arrays are immutable)
    epoch: int = 0
    # cumulative edges patched since the last FULL build — the staleness
    # ledger apply_edge_updates charges its budget against
    stale_edges: int = 0
    # the UpdateReport of the apply_edge_updates call that produced this
    # epoch (None on a fresh preparation)
    last_update: "object | None" = None
    _fn: Callable | None = dataclasses.field(default=None)

    def levels(self, src: int) -> np.ndarray:
        """BFS levels in the caller's (original) vertex ids."""
        if self._fn is None:
            raise BlestError("PreparedBFS built without an engine")
        src = check_source(src, self.graph.n)
        lv = np.asarray(self._fn(int(self.perm[src])))
        return lv[self.perm]


def choose_update_scheme(bvss: BVSS, *, threshold: float | None = None
                         ) -> str:
    """Paper §5: lazy updates iff the update divergence is high (scattered
    updates dominate) — otherwise the eager scheme avoids the Θ(n) sweep."""
    udiv = bvss.update_divergence()
    if threshold is None:
        threshold = min(LAZY_UDIV_THRESHOLD, LAZY_UDIV_FRACTION * bvss.n)
    return "blest_lazy" if udiv > threshold else "blest"


BVSS_ENGINES = ("brs", "blest", "blest_lazy")


def build_problem(g_ord: Graph, *, sigma: int = 8, mesh: Mesh | None = None,
                  mesh_axis: str = "data", bvss=None,
                  options: "PrepareOptions | None" = None) -> BlestProblem:
    """Build the device problem for an (already ordered) graph: single-
    device, 1-D row-sharded, or 2-D row × column-sharded depending on the
    mesh — the ONE dispatch every problem-building caller (``prepare``,
    the serving tier's symmetrised problem) routes through.  A 2-D mesh
    (two named axes) partitions by ``(rows, cols) = mesh.devices.shape``;
    ``mesh_axis`` then names the ROW axis and must be the mesh's first.
    ``options`` supplies ``sigma``/``mesh``/``mesh_axis`` in one value (an
    explicitly passed kwarg wins — callers like the serving tier's
    symmetrised problem override the mesh per build)."""
    if options is not None:
        sigma = options.sigma if sigma == 8 else sigma
        mesh = options.mesh if mesh is None else mesh
        mesh_axis = options.mesh_axis if mesh_axis == "data" else mesh_axis
    if mesh is None:
        if bvss is None:
            bvss = build_bvss(g_ord, sigma=sigma)
        return BlestProblem.build(bvss)
    from repro.distributed.bfs_dist import mesh_is_2d
    if mesh_is_2d(mesh):
        sb = build_sharded_bvss(g_ord, tuple(mesh.devices.shape),
                                sigma=sigma)
        return BlestProblem.build_sharded_2d(sb, mesh)
    sb = build_sharded_bvss(g_ord, mesh.shape[mesh_axis], sigma=sigma)
    return BlestProblem.build_sharded(sb, mesh, mesh_axis)


#: legal legacy keywords of :func:`prepare` = the PrepareOptions fields
_PREPARE_FIELDS = tuple(f.name for f in dataclasses.fields(PrepareOptions))


def prepare(g: Graph, options: PrepareOptions | None = None,
            **legacy) -> PreparedBFS:
    """The full static pipeline: (optionally) order, build the BVSS, pick
    the update scheme (or honour an explicit ``engine`` override, e.g. the
    Table-2 ablation variants), build the fused engine.

    Configuration comes in as one :class:`PrepareOptions` value::

        prepare(g, options=PrepareOptions(sigma=4, mesh=mesh))

    The pre-0.5 keyword spelling ``prepare(g, sigma=4, mesh=mesh)`` still
    works as a thin shim that builds the options for you and emits a
    ``DeprecationWarning`` — passing both forms at once is a
    :class:`~repro.errors.ConfigError`.

    Knob semantics (see :class:`PrepareOptions` for the full list):

    * ``direction`` selects the push/pull hybrid mode of the BVSS engines
      (DESIGN §2.8; default "auto" picks per level on device);
      ``push_impl`` overrides the push kernel — the single-source push
      fault seam (DESIGN §2.7), threaded through by the serving tier's
      :class:`~repro.serve.faults.FaultPlan`.
    * ``autotune=True`` measures the hybrid's static knobs — pull-queue
      ladder, push cap — for this backend and graph class before the
      engine build (``core.autotune``; memoised, so repeat preparations
      of the same class perform zero extra timing dispatches) and records
      the winner on ``PreparedBFS.tile_config``.
    * ``mesh`` row-shards the problem over ``mesh_axis`` and builds the
      mesh-native engine (DESIGN §2.4): the policy decisions (ordering,
      update scheme) still come from the global BVSS, the level loop runs
      under ``shard_map``.  This is the ONE sharded-prep entry point.
    * ``weights`` (one float per CSR edge of ``g``, validated strictly
      positive) threads an edge-weight plane through the whole pipeline
      (DESIGN §2.9): the weights ride the ordering permutation alongside
      the edges and land device-side in the BVSS slice layout
      (``PreparedBFS.wplane``), ready for the min-plus / weighted verbs.

    The returned :class:`PreparedBFS` starts at ``epoch 0``; streaming
    edge updates evolve it through
    :func:`repro.core.bvss_delta.apply_edge_updates` (DESIGN §2.10)."""
    if legacy:
        unknown = sorted(set(legacy) - set(_PREPARE_FIELDS))
        if unknown:
            raise TypeError(
                f"prepare() got unexpected keyword arguments {unknown} "
                f"(valid PrepareOptions fields: {list(_PREPARE_FIELDS)})")
        if options is not None:
            raise ConfigError(
                "prepare() takes EITHER options=PrepareOptions(...) or the "
                "deprecated per-knob keywords, not both — fold "
                f"{sorted(legacy)} into the options value")
        warnings.warn(
            "prepare(g, sigma=..., w=..., ...) keywords are deprecated; "
            "pass prepare(g, options=PrepareOptions(...)) instead",
            DeprecationWarning, stacklevel=2)
        options = PrepareOptions(**legacy)
    elif options is None:
        options = PrepareOptions()
    o = options
    w_arr = None if o.weights is None else check_weights(o.weights, g.m)
    if o.order:
        perm, kind = auto_order(g, sigma=o.sigma, w=o.w, seed=o.seed)
        g_ord = g.permute_fast(perm)
    else:
        perm, kind = np.arange(g.n, dtype=np.int64), "natural"
        g_ord = g
    inv = np.empty(g.n, dtype=np.int64)
    inv[perm] = np.arange(g.n)
    w_ord = None
    if w_arr is not None:
        if o.order:
            # permute_fast re-sorts the relabelled edges by (src·n + dst)
            # key; simple-graph keys are unique, so a stable argsort maps
            # each ordered edge back to its original weight
            from repro.graphs import src_of_edges
            keys = (perm[src_of_edges(g)] * np.int64(g.n)
                    + perm[g.indices.astype(np.int64)])
            w_ord = w_arr[np.argsort(keys, kind="stable")]
        else:
            w_ord = w_arr
    bvss = build_bvss(g_ord, sigma=o.sigma)
    engine_name = o.engine if o.engine is not None else \
        choose_update_scheme(bvss, threshold=o.lazy_threshold)
    wplane = None
    if o.mesh is not None:
        if engine_name not in BVSS_ENGINES:
            raise ValueError(
                f"mesh-native prepare supports the BVSS engines "
                f"{BVSS_ENGINES}, not {engine_name!r} (the CSR/dense "
                f"baselines have no row-sharded representation)")
        from repro.distributed.bfs_dist import mesh_is_2d
        if w_ord is not None and mesh_is_2d(o.mesh):
            raise ConfigError(
                "edge weights are not supported on a 2-D (row × column) "
                "mesh yet — the weighted verbs ship 1-D row-sharded "
                "(DESIGN §2.9); use a 1-D mesh or a single device")
        if w_ord is not None:
            # build the sharded BVSS once and derive both the problem and
            # the aligned per-shard weight planes from it
            sb = build_sharded_bvss(g_ord, o.mesh.shape[o.mesh_axis],
                                    sigma=o.sigma)
            problem = BlestProblem.build_sharded(sb, o.mesh, o.mesh_axis)
            wplane = weight_plane_to_device(
                build_sharded_weight_plane(g_ord, w_ord, sb), o.mesh,
                o.mesh_axis)
        else:
            problem = build_problem(g_ord, sigma=o.sigma, mesh=o.mesh,
                                    mesh_axis=o.mesh_axis)
    else:
        # only BVSS-consuming single-source engines need the device upload;
        # the host bvss alone backs the stats printouts and the policy
        problem = BlestProblem.build(bvss) if engine_name in BVSS_ENGINES \
            else None
        if w_ord is not None:
            wplane = weight_plane_to_device(
                build_weight_plane(g_ord, w_ord, sigma=o.sigma))
    tile_config: TileConfig | None = None
    tuned_kwargs: dict = {}
    if o.autotune and engine_name in BVSS_ENGINES and problem is not None:
        from repro.core.autotune import tune
        tile_config = tune(problem, use_kernels=o.use_kernels)
        tuned_kwargs = tile_config.engine_kwargs()
    fn = make_engine(g_ord, engine_name, bvss=bvss, problem=problem,
                     use_kernels=o.use_kernels, buckets=o.buckets,
                     direction=o.direction, push_impl=o.push_impl,
                     **tuned_kwargs)
    return PreparedBFS(graph=g_ord, perm=perm, inv=inv, ordering=kind,
                       engine_name=engine_name, bvss=bvss, problem=problem,
                       update_divergence=bvss.update_divergence(),
                       mesh=o.mesh, weights=w_ord, wplane=wplane,
                       tile_config=tile_config, options=o, _fn=fn)


def parents_from_levels(g: Graph, levels: np.ndarray) -> np.ndarray:
    """BFS parent array (paper §2: the kernel may return either form).

    Pull semantics: parent[u] is any in-neighbour of u at level[u]-1 (the
    first in in-CSR order).  One vectorised NumPy sweep over the in-CSR."""
    INF = np.iinfo(np.int32).max
    t_indptr, t_indices = g.t_csr
    levels = np.asarray(levels)
    u_of = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(t_indptr))
    lu = levels[u_of]
    nbrs = t_indices.astype(np.int64)
    ok = (lu != 0) & (lu != INF) & (levels[nbrs] == lu - 1)
    parents = np.full(g.n, -1, dtype=np.int64)
    idx = np.flatnonzero(ok)
    if len(idx):
        # first qualifying in-edge per vertex: idx ascends within each
        # CSR row, so unique's first occurrence is the CSR-order choice
        uu, first = np.unique(u_of[idx], return_index=True)
        parents[uu] = nbrs[idx[first]]
    return parents
