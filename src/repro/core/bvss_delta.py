"""Incremental BVSS maintenance: streaming edge updates without a full
re-``prepare`` (DESIGN §2.10).

:func:`apply_edge_updates` evolves a :class:`~repro.core.policy.PreparedBFS`
through a batch of edge insertions / deletions.  The slice-set layout makes
this local (the SlimSell-style argument for keeping the representation
patchable): :func:`~repro.core.bvss.build_bvss` lays every slice set out
contiguously — slices sorted by row, packed column-major over
``(slot, lane)`` into the set's own VSS range — so an edge ``(s, d)`` only
perturbs slice set ``s // σ``, and re-laying out just the touched sets
reproduces a fresh build BIT FOR BIT as long as no touched set's VSS count
changes (``real_ptrs`` / ``virtual_to_real`` / ``num_vss`` are then
invariant).  The weight plane shares the slice placement, so its touched
rows are recomputed the same way.

Three maintenance paths, cheapest first:

* **patched** — every touched set keeps its VSS count (globally and, when
  sharded, per shard): mask words, row ids and weight-plane entries of the
  touched VSS rows are rewritten host-side and scattered into fresh device
  buffers with ``.at[...].set``.  The OLD device buffers are untouched —
  JAX arrays are immutable — so waves in flight on the previous epoch
  finish on exactly the bits they started with (epoch isolation for free).
* **rebuilt** — a touched set's VSS count changed (or the problem is 2-D
  partitioned, whose interleaved column relabelling makes locality moot):
  the BVSS/problem/plane are rebuilt from scratch over the SAME vertex
  ordering, keeping the caller-id contract and the epoch ledger.
* **reprepared** — the cumulative patched-edge ledger crossed the
  staleness budget: the ordering itself is presumed stale (the paper's
  lazy-update principle, inverted: batch cheap local patches, amortise the
  expensive global decision), so the ORIGINAL graph is reconstructed in
  caller ids and the whole static pipeline re-runs, new ordering included.

Updates are addressed in the caller's ORIGINAL vertex ids and remapped
through ``prepared.perm`` internally — the same id contract as every query
verb.  Every path returns a NEW ``PreparedBFS`` with ``epoch + 1`` (the
input value is never mutated); pass ``expected_epoch`` for a
compare-and-swap that raises :class:`~repro.errors.StaleEpochError`
instead of merging onto a superseded base.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bfs import BlestProblem
from repro.core.bvss import BVSS, LANES
from repro.core.policy import (BVSS_ENGINES, PreparedBFS, PrepareOptions,
                               prepare)
from repro.errors import (ConfigError, GraphValidationError, StaleEpochError,
                          check_weights)
from repro.graphs import Graph, from_edges, src_of_edges

#: default staleness budget, as a fraction of the CURRENT edge count:
#: once the cumulative patched-edge ledger exceeds it, the next update
#: falls back to a full re-``prepare`` (ordering re-runs on the mutated
#: graph).  Deliberately generous — the ordering degrades slowly.
STALENESS_FRACTION = 0.25


@dataclasses.dataclass(frozen=True)
class UpdateReport:
    """What one :func:`apply_edge_updates` call actually did."""

    path: str                 # "patched" | "rebuilt" | "reprepared"
    epoch: int                # epoch of the RETURNED PreparedBFS
    n_inserted: int
    n_deleted: int
    n_reweighted: int         # inserts that re-weighted an existing edge
    sets_touched: int         # slice sets whose layout was recomputed
    vss_rows_rewritten: int   # device VSS rows scattered (patched path)
    stale_edges: int          # cumulative ledger after this update
    reason: str | None = None  # why a fallback path was taken


def _decode_set(masks: np.ndarray, row_ids: np.ndarray, sigma: int,
                dummy_row: int) -> dict[int, int]:
    """Row -> σ-bit mask of one slice set's VSS rows (the inverse of the
    Fig. 2(c) packing: slice k sits at lane ``k % 32``, slot ``k // 32``)."""
    spw = 32 // sigma
    sub_mask = (1 << sigma) - 1
    out: dict[int, int] = {}
    for v in range(masks.shape[0]):
        for slot in range(spw):
            sub = (masks[v] >> np.uint32(slot * sigma)) & np.uint32(sub_mask)
            live = np.flatnonzero(sub)
            for lane in live:
                row = int(row_ids[v, slot, lane])
                if row != dummy_row:
                    out[row] = out.get(row, 0) | int(sub[lane])
    return out


def _encode_set(slices: dict[int, int], n_vss: int, sigma: int,
                dummy_row: int) -> tuple[np.ndarray, np.ndarray]:
    """Re-pack a set's slices exactly like :func:`build_bvss` would: rows
    ascending, slice k -> (slot k // 32, lane k % 32), zero-mask /
    dummy-row padding to the set's ``n_vss`` VSS rows."""
    spw = 32 // sigma
    tau = LANES * spw
    masks = np.zeros((n_vss, LANES), dtype=np.uint32)
    row_ids = np.full((n_vss, spw, LANES), dummy_row, dtype=np.int32)
    for k, row in enumerate(sorted(slices)):
        v, kk = k // tau, k % tau
        lane, slot = kk % LANES, kk // LANES
        masks[v, lane] |= np.uint32(slices[row]) << np.uint32(slot * sigma)
        row_ids[v, slot, lane] = row
    return masks, row_ids


def _weight_rows(slices: dict[int, int], n_vss: int, sigma: int,
                 set_id: int, weight_of) -> np.ndarray:
    """The touched set's weight-plane rows under the same packing:
    entry ``[v, slot, lane, b]`` = weight of edge ``σ·set_id + b -> row``,
    +inf where the mask bit is unset (the tropical annihilator)."""
    spw = 32 // sigma
    tau = LANES * spw
    plane = np.full((n_vss, spw, LANES, sigma), np.inf, dtype=np.float32)
    for k, row in enumerate(sorted(slices)):
        v, kk = k // tau, k % tau
        lane, slot = kk % LANES, kk // LANES
        m = slices[row]
        for b in range(sigma):
            if (m >> b) & 1:
                plane[v, slot, lane, b] = weight_of(set_id * sigma + b, row)
    return plane


def _edge_batch(edges, n: int, what: str) -> np.ndarray:
    """Validate an edge batch to (k, 2) int64 in-range, loop-free."""
    arr = np.asarray(edges, dtype=np.int64) if len(edges) else \
        np.zeros((0, 2), dtype=np.int64)
    if arr.ndim != 2 or (arr.size and arr.shape[1] != 2):
        raise GraphValidationError(
            f"{what} must be a (k, 2) array of (src, dst) pairs, got shape "
            f"{arr.shape}")
    if arr.size:
        if int(arr.min()) < 0 or int(arr.max()) >= n:
            bad = arr[((arr < 0) | (arr >= n)).any(axis=1)]
            raise GraphValidationError(
                f"{what} contain out-of-range vertex ids "
                f"{bad[:4].tolist()} (valid ids are 0..{n - 1})")
        loops = arr[:, 0] == arr[:, 1]
        if loops.any():
            raise GraphValidationError(
                f"{what} contain self loops at rows "
                f"{np.flatnonzero(loops)[:8].tolist()} (simple graphs only)")
    return arr


def apply_edge_updates(prepared: PreparedBFS, inserts=(), deletes=(), *,
                       insert_weights=None,
                       expected_epoch: int | None = None,
                       staleness_budget: int | None = None) -> PreparedBFS:
    """Apply a batch of edge updates and return the next-epoch
    :class:`~repro.core.policy.PreparedBFS` (the input is never mutated;
    in-flight waves finish on the old epoch's device buffers).

    ``inserts`` / ``deletes`` are ``(k, 2)`` arrays of ``(src, dst)``
    pairs in the caller's ORIGINAL vertex ids.  Inserting an edge that
    already exists is a weight update on a weighted preparation and a
    no-op otherwise; deleting a missing edge is a
    :class:`~repro.errors.GraphValidationError` (a silent no-op would let
    a desynchronised updater believe its view of the graph).
    ``insert_weights`` (one strictly positive float per insert) is
    required when the preparation carries weights and rejected when it
    does not.  ``expected_epoch`` arms the compare-and-swap
    (:class:`~repro.errors.StaleEpochError` on mismatch);
    ``staleness_budget`` overrides the re-``prepare`` fallback threshold
    (edges; default ``STALENESS_FRACTION`` of the current edge count).
    ``prepared.last_update`` on the result records which maintenance path
    ran (:class:`UpdateReport`)."""
    if expected_epoch is not None and expected_epoch != prepared.epoch:
        raise StaleEpochError(
            f"edge updates were computed against epoch {expected_epoch} "
            f"but the prepared state is at epoch {prepared.epoch} — "
            f"recompute the delta on the current epoch",
            expected=expected_epoch, actual=prepared.epoch)
    g_ord = prepared.graph
    n = g_ord.n
    ins = _edge_batch(inserts, n, "inserts")
    del_ = _edge_batch(deletes, n, "deletes")
    weighted = prepared.weights is not None
    if weighted and len(ins) and insert_weights is None:
        raise GraphValidationError(
            "this preparation carries edge weights — every insert needs a "
            "weight (pass insert_weights)")
    if not weighted and insert_weights is not None:
        raise ConfigError(
            "insert_weights given but the preparation is unweighted — "
            "prepare(..., weights=...) first")
    w_ins = check_weights(insert_weights, len(ins),
                          what="insert_weights") if weighted and len(ins) \
        else np.zeros(len(ins), dtype=np.float32)

    # remap caller ids -> internal (ordered) ids; all work below is in the
    # ordered id space, where the CSR edge order IS ascending (src·n + dst)
    perm = prepared.perm
    ins_keys = perm[ins[:, 0]] * n + perm[ins[:, 1]] if len(ins) else \
        np.zeros(0, dtype=np.int64)
    del_keys = perm[del_[:, 0]] * n + perm[del_[:, 1]] if len(del_) else \
        np.zeros(0, dtype=np.int64)
    for name, keys in (("inserts", ins_keys), ("deletes", del_keys)):
        if len(np.unique(keys)) != len(keys):
            raise GraphValidationError(
                f"{name} contain duplicate edges in one batch")
    if len(ins_keys) and len(del_keys) and \
            np.intersect1d(ins_keys, del_keys).size:
        raise GraphValidationError(
            "an edge appears in both inserts and deletes of one batch — "
            "order is ambiguous; split into two update calls")

    old_keys = src_of_edges(g_ord).astype(np.int64) * n \
        + g_ord.indices.astype(np.int64)
    if len(del_keys):
        pos = np.searchsorted(old_keys, del_keys)
        missing = pos >= len(old_keys)
        inb = ~missing
        missing[inb] = old_keys[pos[inb]] != del_keys[inb]
        if missing.any():
            bad = del_[missing][:4]
            raise GraphValidationError(
                f"deletes contain edges not in the graph: "
                f"{bad.tolist()} (caller ids)")
    exists = np.zeros(len(ins_keys), dtype=bool)
    if len(ins_keys) and len(old_keys):
        pos = np.searchsorted(old_keys, ins_keys)
        exists = (pos < len(old_keys)) & (old_keys[np.minimum(
            pos, len(old_keys) - 1)] == ins_keys)
    reweights = ins_keys[exists]
    w_rew = w_ins[exists]
    fresh_keys = ins_keys[~exists]
    w_fresh = w_ins[~exists]
    if not weighted:
        reweights = reweights[:0]
        w_rew = w_rew[:0]

    n_changed = len(fresh_keys) + len(del_keys) + len(reweights)
    if n_changed == 0:
        return prepared                      # nothing to do: same epoch

    # merged (ordered-id) edge set + aligned weights, ascending key order
    keep = np.ones(len(old_keys), dtype=bool)
    if len(del_keys):
        keep[np.searchsorted(old_keys, del_keys)] = False
    new_keys = np.concatenate([old_keys[keep], fresh_keys])
    order = np.argsort(new_keys, kind="stable")
    new_keys = new_keys[order]
    w_new = None
    if weighted:
        w_old = prepared.weights.copy()
        if len(reweights):
            w_old[np.searchsorted(old_keys, reweights)] = w_rew
        w_new = np.concatenate([w_old[keep], w_fresh])[order]
    g_ord2 = from_edges(n, new_keys // n, new_keys % n,
                        dedup=True, drop_loops=False)

    opts = prepared.options if prepared.options is not None \
        else PrepareOptions()
    budget = staleness_budget if staleness_budget is not None \
        else max(1, int(STALENESS_FRACTION * max(g_ord2.m, 1)))
    stale = prepared.stale_edges + n_changed
    structural = _structural_reason(prepared, fresh_keys, del_keys, g_ord2)

    if stale > budget:
        return _reprepare(prepared, g_ord2, w_new, opts, n_changed,
                          len(fresh_keys), len(del_keys), len(reweights),
                          reason=f"staleness ledger {stale} edges over "
                                 f"budget {budget}")
    if structural is not None or (prepared.problem is not None
                                  and prepared.problem.is_2d):
        reason = structural if structural is not None else \
            "2-D partition relabels columns; no local patch path"
        return _rebuild(prepared, g_ord2, w_new, opts, stale,
                        len(fresh_keys), len(del_keys), len(reweights),
                        reason=reason)
    return _patch(prepared, g_ord2, w_new, opts, stale,
                  fresh_keys, del_keys, reweights)


def _touched_sets(fresh_keys: np.ndarray, del_keys: np.ndarray, n: int,
                  sigma: int) -> np.ndarray:
    """Slice sets whose layout the STRUCTURAL updates perturb (reweights
    touch only the weight plane, never the masks)."""
    srcs = np.concatenate([fresh_keys // n, del_keys // n])
    return np.unique(srcs // sigma).astype(np.int64)


def _structural_reason(prepared: PreparedBFS, fresh_keys, del_keys,
                       g_ord2: Graph) -> str | None:
    """None when every touched set keeps its VSS count (globally AND per
    shard) — the precondition for the bit-identical local patch."""
    b = prepared.bvss
    sigma, tau, n = b.sigma, b.tau, b.n
    sets = _touched_sets(fresh_keys, del_keys, n, sigma)
    if not len(sets):
        return None
    # global set sizes after the update, from the merged graph's in-CSR
    t_indptr, t_indices = g_ord2.t_csr
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(t_indptr))
    cols = t_indices.astype(np.int64)
    for I in sets:
        in_set = (cols // sigma) == I
        count = len(np.unique(rows[in_set]))
        span = int(b.real_ptrs[I + 1] - b.real_ptrs[I])
        if -(-count // tau) != span:
            return (f"slice set {int(I)} needs {-(-count // tau)} VSSs "
                    f"(has {span}) — realPtrs would shift")
    pb = prepared.problem
    if pb is not None and pb.mesh is not None and not pb.is_2d:
        starts = np.asarray(pb.dev.vss_of_vertex_start)
        ends = np.asarray(pb.dev.vss_of_vertex_end)
        rps = pb.rows_per_shard
        for d in range(pb.n_shards):
            lo, hi = d * rps, min((d + 1) * rps, n)
            local = (rows >= lo) & (rows < hi)
            for I in sets:
                in_set = local & ((cols // sigma) == I)
                count = len(np.unique(rows[in_set]))
                span = int(ends[d, I * sigma] - starts[d, I * sigma])
                if -(-count // tau) != span:
                    return (f"shard {d} slice set {int(I)} needs "
                            f"{-(-count // tau)} VSSs (has {span})")
    return None


def _edges_of_sets(g_ord2: Graph, sets: np.ndarray, sigma: int
                   ) -> tuple[np.ndarray, np.ndarray]:
    """(src, dst) of every post-update edge whose source lands in one of
    the touched sets (the slices those sets must now encode)."""
    src = src_of_edges(g_ord2).astype(np.int64)
    dst = g_ord2.indices.astype(np.int64)
    mask = np.isin(src // sigma, sets)
    return src[mask], dst[mask]


def _patch(prepared: PreparedBFS, g_ord2: Graph, w_new, opts: PrepareOptions,
           stale: int, fresh_keys, del_keys, reweights) -> PreparedBFS:
    """The cheap path: rewrite only the touched sets' VSS rows, host and
    device, leaving every untouched buffer (and the whole old epoch's
    buffer set) alone."""
    b = prepared.bvss
    n, sigma, tau = b.n, b.sigma, b.tau
    sets = _touched_sets(fresh_keys, del_keys, n, sigma)
    # reweighted edges touch their sets' weight-plane rows only
    wsets = np.unique(reweights // n // sigma).astype(np.int64) \
        if len(reweights) else np.zeros(0, dtype=np.int64)
    src_t, dst_t = _edges_of_sets(
        g_ord2, np.union1d(sets, wsets), sigma)

    weight_of = None
    if w_new is not None:
        keys2 = src_of_edges(g_ord2).astype(np.int64) * n \
            + g_ord2.indices.astype(np.int64)

        def weight_of(s: int, d: int) -> float:
            return float(w_new[np.searchsorted(keys2, s * n + d)])

    # ---- global (host) BVSS: per-set re-layout ----
    masks2 = b.masks.copy()
    row_ids2 = b.row_ids.copy()
    rows_rewritten = 0
    for I in sets:
        p0, p1 = int(b.real_ptrs[I]), int(b.real_ptrs[I + 1])
        in_set = (src_t // sigma) == I
        slices: dict[int, int] = {}
        for s, d in zip(src_t[in_set], dst_t[in_set]):
            bit = int(s % sigma)
            slices[int(d)] = slices.get(int(d), 0) | (1 << bit)
        m, r = _encode_set(slices, p1 - p0, sigma, dummy_row=n)
        masks2[p0:p1] = m
        row_ids2[p0:p1] = r
        rows_rewritten += p1 - p0
    num_slices2 = b.num_slices
    for I in sets:
        in_set = (src_t // sigma) == I
        num_slices2 += len(np.unique(dst_t[in_set])) \
            - len(_decode_set(b.masks[b.real_ptrs[I]:b.real_ptrs[I + 1]],
                              b.row_ids[b.real_ptrs[I]:b.real_ptrs[I + 1]],
                              sigma, dummy_row=n))
    bvss2 = dataclasses.replace(b, m=g_ord2.m, num_slices=num_slices2,
                                masks=masks2, row_ids=row_ids2)

    # ---- device problem + weight plane: scatter the touched VSS rows ----
    pb = prepared.problem
    problem2 = pb
    wplane2 = prepared.wplane
    all_sets = np.union1d(sets, wsets)
    sharded = pb is not None and pb.mesh is not None
    if sharded:
        problem2, wplane2 = _patch_sharded(
            pb, prepared.wplane, b, all_sets, g_ord2, weight_of)
    else:
        if pb is not None:
            idx = np.concatenate([np.arange(int(b.real_ptrs[I]),
                                            int(b.real_ptrs[I + 1]))
                                  for I in sets]) if len(sets) else \
                np.zeros(0, dtype=np.int64)
            dev = pb.dev
            if len(idx):
                dev = dev._replace(
                    masks=dev.masks.at[idx].set(masks2[idx]),
                    row_ids=dev.row_ids.at[idx].set(row_ids2[idx]))
            problem2 = dataclasses.replace(pb, dev=dev)
        if prepared.wplane is not None:
            # the plane exists even without a device problem (non-BVSS
            # engine, weighted prep) — patch it in either case
            wplane2 = _patch_wplane_single(
                prepared.wplane, b, all_sets, g_ord2, weight_of)

    report = UpdateReport(
        path="patched", epoch=prepared.epoch + 1,
        n_inserted=len(fresh_keys), n_deleted=len(del_keys),
        n_reweighted=len(reweights), sets_touched=len(sets) + len(
            np.setdiff1d(wsets, sets)),
        vss_rows_rewritten=rows_rewritten, stale_edges=stale)
    return _finish(prepared, g_ord2, bvss2, problem2, w_new, wplane2, opts,
                   report)


def _patch_wplane_single(wplane, b: BVSS, sets, g_ord2: Graph, weight_of):
    """Scatter recomputed weight-plane rows for the touched sets
    (single-device plane: (num_vss + 1, spw, LANES, σ), +inf dummy last)."""
    n, sigma = b.n, b.sigma
    src_t, dst_t = _edges_of_sets(g_ord2, sets, sigma)
    for I in sets:
        p0, p1 = int(b.real_ptrs[I]), int(b.real_ptrs[I + 1])
        in_set = (src_t // sigma) == I
        slices: dict[int, int] = {}
        for s, d in zip(src_t[in_set], dst_t[in_set]):
            slices[int(d)] = slices.get(int(d), 0) | (1 << int(s % sigma))
        rows = _weight_rows(slices, p1 - p0, sigma, int(I), weight_of)
        wplane = wplane.at[p0:p1].set(rows)
    return wplane


def _patch_sharded(pb: BlestProblem, wplane, b: BVSS, sets, g_ord2: Graph,
                   weight_of):
    """1-D row-sharded patch: per (shard, touched set) re-layout against
    the shard's own VSS ranges (``vss_of_vertex_start/end`` = the
    per-shard ``real_ptrs``), rows in LOCAL ids (dummy = rows_per_shard)."""
    n, sigma = b.n, b.sigma
    starts = np.asarray(pb.dev.vss_of_vertex_start)
    ends = np.asarray(pb.dev.vss_of_vertex_end)
    rps = pb.rows_per_shard
    src_t, dst_t = _edges_of_sets(g_ord2, sets, sigma)
    # np.asarray on a device array is a read-only view: copy before staging
    masks_host = np.array(pb.dev.masks)
    rows_host = np.array(pb.dev.row_ids)
    wp_host = None if wplane is None else np.array(wplane)
    d_idx: list[int] = []
    v_idx: list[int] = []
    for d in range(pb.n_shards):
        lo, hi = d * rps, min((d + 1) * rps, n)
        local = (dst_t >= lo) & (dst_t < hi)
        for I in sets:
            p0 = int(starts[d, I * sigma])
            p1 = int(ends[d, I * sigma])
            in_set = local & ((src_t // sigma) == I)
            slices: dict[int, int] = {}
            for s, dd in zip(src_t[in_set], dst_t[in_set]):
                row = int(dd - lo)
                slices[row] = slices.get(row, 0) | (1 << int(s % sigma))
            m, r = _encode_set(slices, p1 - p0, sigma, dummy_row=rps)
            masks_host[d, p0:p1] = m
            rows_host[d, p0:p1] = r
            if wp_host is not None:
                def w_local(src_global, row_local, _lo=lo):
                    return weight_of(src_global, row_local + _lo)
                wp_host[d, p0:p1] = _weight_rows(
                    slices, p1 - p0, sigma, int(I), w_local)
            d_idx.extend([d] * (p1 - p0))
            v_idx.extend(range(p0, p1))
    dev = pb.dev
    if d_idx:
        di = np.asarray(d_idx)
        vi = np.asarray(v_idx)
        dev = dev._replace(
            masks=dev.masks.at[di, vi].set(masks_host[di, vi]),
            row_ids=dev.row_ids.at[di, vi].set(rows_host[di, vi]))
        if wplane is not None:
            wplane = wplane.at[di, vi].set(wp_host[di, vi])
    return dataclasses.replace(pb, dev=dev), wplane


def _rebuild(prepared: PreparedBFS, g_ord2: Graph, w_new,
             opts: PrepareOptions, stale: int, n_ins: int, n_del: int,
             n_rew: int, *, reason: str) -> PreparedBFS:
    """Structural fallback: fresh BVSS/problem/plane over the SAME
    ordering (perm/inv/caller contract unchanged)."""
    from repro.core.bvss import (build_bvss, build_sharded_bvss,
                                 build_sharded_weight_plane,
                                 build_weight_plane, weight_plane_to_device)

    sigma = prepared.bvss.sigma
    bvss2 = build_bvss(g_ord2, sigma=sigma)
    mesh = prepared.mesh
    wplane2 = None
    if mesh is not None:
        from repro.distributed.bfs_dist import mesh_is_2d
        if mesh_is_2d(mesh):
            sb = build_sharded_bvss(g_ord2, tuple(mesh.devices.shape),
                                    sigma=sigma)
            problem2 = BlestProblem.build_sharded_2d(sb, mesh)
        else:
            sb = build_sharded_bvss(g_ord2, mesh.shape[opts.mesh_axis],
                                    sigma=sigma)
            problem2 = BlestProblem.build_sharded(sb, mesh, opts.mesh_axis)
            if w_new is not None:
                wplane2 = weight_plane_to_device(
                    build_sharded_weight_plane(g_ord2, w_new, sb), mesh,
                    opts.mesh_axis)
    else:
        problem2 = BlestProblem.build(bvss2) \
            if prepared.engine_name in BVSS_ENGINES else None
        if w_new is not None:
            wplane2 = weight_plane_to_device(
                build_weight_plane(g_ord2, w_new, sigma=sigma))
    report = UpdateReport(
        path="rebuilt", epoch=prepared.epoch + 1, n_inserted=n_ins,
        n_deleted=n_del, n_reweighted=n_rew, sets_touched=bvss2.n_sets,
        vss_rows_rewritten=bvss2.num_vss, stale_edges=stale, reason=reason)
    return _finish(prepared, g_ord2, bvss2, problem2, w_new, wplane2, opts,
                   report)


def _reprepare(prepared: PreparedBFS, g_ord2: Graph, w_new,
               opts: PrepareOptions, n_changed: int, n_ins: int, n_del: int,
               n_rew: int, *, reason: str) -> PreparedBFS:
    """Staleness fallback: reconstruct the ORIGINAL graph in caller ids
    and re-run the whole static pipeline (new ordering, fresh ledger)."""
    n = g_ord2.n
    inv = prepared.inv
    src_o = inv[src_of_edges(g_ord2).astype(np.int64)]
    dst_o = inv[g_ord2.indices.astype(np.int64)]
    g_orig = from_edges(n, src_o, dst_o, dedup=True, drop_loops=False)
    w_orig = None
    if w_new is not None:
        # caller-order weights: original CSR sorts ascending by caller key
        w_orig = w_new[np.argsort(src_o * n + dst_o, kind="stable")]
    fresh = prepare(g_orig, options=opts.replace(weights=w_orig))
    report = UpdateReport(
        path="reprepared", epoch=prepared.epoch + 1, n_inserted=n_ins,
        n_deleted=n_del, n_reweighted=n_rew,
        sets_touched=fresh.bvss.n_sets,
        vss_rows_rewritten=fresh.bvss.num_vss, stale_edges=0, reason=reason)
    return dataclasses.replace(fresh, epoch=prepared.epoch + 1,
                               stale_edges=0, last_update=report)


def _finish(prepared: PreparedBFS, g_ord2: Graph, bvss2: BVSS, problem2,
            w_new, wplane2, opts: PrepareOptions,
            report: UpdateReport) -> PreparedBFS:
    """Rebuild the engine on the next-epoch structures and assemble the
    result.  The engine rebuild recompiles (device arrays are closure
    constants of the jitted level loop) — the accepted cost of an epoch
    swap, amortised by batching updates (DESIGN §2.10)."""
    from repro.core.bfs import make_engine

    tuned = prepared.tile_config.engine_kwargs() \
        if prepared.tile_config is not None else {}
    fn = make_engine(g_ord2, prepared.engine_name, bvss=bvss2,
                     problem=problem2, use_kernels=opts.use_kernels,
                     buckets=opts.buckets, direction=opts.direction,
                     push_impl=opts.push_impl, **tuned)
    return dataclasses.replace(
        prepared, graph=g_ord2, bvss=bvss2, problem=problem2,
        update_divergence=bvss2.update_divergence(), weights=w_new,
        wplane=wplane2 if w_new is not None else prepared.wplane,
        epoch=prepared.epoch + 1, stale_edges=report.stale_edges,
        last_update=report, _fn=fn)
