"""Multi-source BFS as batched BVSS bit-SpMM (paper §2 + §7, DESIGN §2.5).

Stacking S frontiers column-wise turns the SpMSpV pull into an SpMM; on TPU
this is where the MXU path pays off (DESIGN.md §2.2): the slices of every
queued VSS are contracted against the S stacked σ-bit frontier bytes of its
slice set as small bit-SpMM tiles (``kernels.bvss_spmm``).  Unlike the seed
implementation, the hot path never materialises the O(n²/32) dense
``to_dense_bits`` adjacency — peak device memory scales with BVSS words.

The level loop rides the same :class:`~repro.core.level_pipeline.LevelPipeline`
skeleton as the single-source engines, and reuses their bucketed static-width
queue: one compacted *union* queue of VSSs (a slice set is live if ANY source
column's frontier touches it), the per-column frontier kept as packed words.

:func:`make_ms_engine` exposes the jit-able building blocks (init / insert /
requeue / one lock-step level) so GraphSession (``repro.serve``) can drive
the same step with host control between levels — the wave-serving loop with
mid-flight slot refills — while :func:`make_multi_source_bfs` fuses the whole
loop on device for the fixed-cohort case (closeness centrality).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bfs import (BlestProblem, _frontier_bytes, make_compactor,
                            queue_widths)
from repro.core.level_pipeline import LevelPipeline, run_levels
from repro.graphs import Graph
from repro.kernels import bvss_spmm
from repro.kernels.ref import bvss_spmm_ref

INF = np.int32(np.iinfo(np.int32).max)


class MSState(NamedTuple):
    levels: jnp.ndarray   # (n+1, S) int32; row n is the dummy-row sink
    F: jnp.ndarray        # (n_fwords, S) uint32 per-column packed frontier
    Q: jnp.ndarray        # (qcap,) int32 union VSS queue, dummy-padded
    count: jnp.ndarray    # int32 live VSS count (termination + bucket pick)
    col_lvl: jnp.ndarray  # (S,) int32 per-column BFS depth reached so far


@dataclasses.dataclass(frozen=True)
class MSEngine:
    """Jit-able building blocks of the batched BVSS SpMM level step.

    ``step``/``finalize`` plug into :class:`LevelPipeline` for the fused
    on-device loop; ``insert``/``requeue``/``level_step``/``col_live`` are
    the wave-serving surface (jitted, host-driven between levels)."""

    problem: BlestProblem
    n_slots: int
    init: Callable        # (sources (S,) i32) -> MSState, queue rebuilt
    idle: Callable        # () -> MSState with no live columns
    insert: Callable      # (state, slot, src) -> MSState (requeue after!)
    requeue: Callable     # (state) -> state with Q/count rebuilt from F
    step: Callable        # (state) -> state after gather+pull+update
    finalize: Callable    # (state) -> state after pack+requeue
    level_step: Callable  # jitted (state) -> (state, live (S,) bool) after
                          # one full level — liveness piggybacks on the
                          # step so serving pays ONE dispatch per level
    col_live: Callable    # jitted (state) -> (S,) bool frontier non-empty


def make_ms_engine(problem: BlestProblem, n_slots: int, *,
                   use_kernel: bool = True, buckets: int = 2) -> MSEngine:
    """Build the S-column lock-step BVSS level machinery."""
    p = problem
    dev = p.dev
    sigma = p.sigma
    S = n_slots
    n, n_fwords = p.n, p.n_fwords
    widths = queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    spmm = bvss_spmm if use_kernel else bvss_spmm_ref
    compact = make_compactor(dev, p.num_vss, qcap)
    all_sets = jnp.arange(p.n_sets, dtype=jnp.int32)
    n_pad = n_fwords * 32
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)

    def pull_update(state: MSState, width: int) -> MSState:
        ids = jax.lax.slice_in_dim(state.Q, 0, width)
        fb = _frontier_bytes(state.F, dev.virtual_to_real[ids], sigma)
        counts = spmm(dev.masks[ids], fb, sigma=sigma)  # (w, spw, 32, S)
        rows = dev.row_ids[ids].reshape(-1)
        cand = (state.col_lvl + 1)[None, :]
        upd = jnp.where(counts.reshape(-1, S) > 0, cand, INF
                        ).astype(jnp.int32)
        # eager scatter-min: an already-visited row keeps its smaller level;
        # dummy rows land in the level sink (row n)
        return state._replace(levels=state.levels.at[rows].min(upd))

    def step(state: MSState) -> MSState:
        if len(widths) == 1:
            return pull_update(state, widths[0])
        small, full = widths
        return jax.lax.cond(state.count <= small,
                            lambda s: pull_update(s, small),
                            lambda s: pull_update(s, full), state)

    def requeue(state: MSState) -> MSState:
        """Rebuild the union queue from the per-column frontiers: a slice
        set is live iff any column's σ-bit frontier byte is non-zero."""
        set_active = (_frontier_bytes(state.F, all_sets, sigma) != 0
                      ).any(axis=1)
        Q, count = compact(set_active)
        return state._replace(Q=Q, count=count)

    def finalize(state: MSState) -> MSState:
        nxt = (state.col_lvl + 1)[None, :]
        new = state.levels[:n] == nxt                     # (n, S)
        bits = jnp.zeros((n_pad, S), dtype=bool).at[:n].set(new)
        F = jnp.sum(bits.reshape(n_fwords, 32, S).astype(jnp.uint32)
                    * weights[None, :, None], axis=1, dtype=jnp.uint32)
        state = state._replace(F=F, col_lvl=state.col_lvl + new.any(axis=0))
        return requeue(state)

    def init(sources: jnp.ndarray) -> MSState:
        sources = jnp.asarray(sources, dtype=jnp.int32)
        cols = jnp.arange(S)
        levels = jnp.full((n + 1, S), INF, dtype=jnp.int32)
        levels = levels.at[sources, cols].set(0)
        F = jnp.zeros((n_fwords, S), dtype=jnp.uint32)
        F = F.at[sources // 32, cols].set(
            jnp.uint32(1) << (sources % 32).astype(jnp.uint32))
        st = MSState(levels=levels, F=F,
                     Q=jnp.full((qcap,), p.num_vss, dtype=jnp.int32),
                     count=jnp.int32(0),
                     col_lvl=jnp.zeros((S,), dtype=jnp.int32))
        return requeue(st)

    def idle() -> MSState:
        return MSState(levels=jnp.full((n + 1, S), INF, dtype=jnp.int32),
                       F=jnp.zeros((n_fwords, S), dtype=jnp.uint32),
                       Q=jnp.full((qcap,), p.num_vss, dtype=jnp.int32),
                       count=jnp.int32(0),
                       col_lvl=jnp.zeros((S,), dtype=jnp.int32))

    def insert(state: MSState, slot: jnp.ndarray, src: jnp.ndarray
               ) -> MSState:
        """Reset column ``slot`` to a fresh query from ``src`` (internal
        ids).  Call ``requeue`` once after a refill round."""
        slot = jnp.asarray(slot, dtype=jnp.int32)
        src = jnp.asarray(src, dtype=jnp.int32)
        levels = state.levels.at[:, slot].set(INF).at[src, slot].set(0)
        F = state.F.at[:, slot].set(jnp.uint32(0))
        F = F.at[src // 32, slot].set(
            jnp.uint32(1) << (src % 32).astype(jnp.uint32))
        return state._replace(levels=levels, F=F,
                              col_lvl=state.col_lvl.at[slot].set(0))

    def level_step(state: MSState) -> tuple[MSState, jnp.ndarray]:
        state = finalize(step(state))
        return state, (state.F != 0).any(axis=0)

    return MSEngine(
        problem=p, n_slots=S, init=jax.jit(init), idle=idle,
        insert=jax.jit(insert), requeue=jax.jit(requeue),
        step=step, finalize=finalize,
        level_step=jax.jit(level_step),
        col_live=jax.jit(lambda st: (st.F != 0).any(axis=0)))


def make_multi_source_bfs(g: Graph | None, n_sources: int, *,
                          use_kernel: bool = True,
                          max_levels: int | None = None,
                          bvss=None, problem: BlestProblem | None = None,
                          buckets: int = 2) -> Callable:
    """Build jitted ``f(sources (S,) i32) -> levels (n, S) i32`` with the
    whole level loop fused on device (fixed source cohort)."""
    if problem is None:
        if bvss is None:
            from repro.core.bvss import build_bvss
            bvss = build_bvss(g)
        problem = BlestProblem.build(bvss)
    eng = make_ms_engine(problem, n_sources, use_kernel=use_kernel,
                         buckets=buckets)
    max_lv = max_levels if max_levels is not None else problem.n + 1
    pipe = LevelPipeline(step=lambda s, lvl: eng.step(s),
                         finalize=lambda s, lvl: eng.finalize(s),
                         active=lambda s: s.count > 0)

    def bfs(sources: jnp.ndarray) -> jnp.ndarray:
        state, _ = run_levels(pipe, eng.init(sources), max_levels=max_lv)
        return state.levels[:problem.n]

    return jax.jit(bfs)


def closeness_centrality(g: Graph, sources: np.ndarray, *,
                         use_kernel: bool = True,
                         problem: BlestProblem | None = None) -> np.ndarray:
    """Approximate closeness centrality from a source sample (paper §7's
    target application for multi-source BFS).  ``sources`` and the scores
    are in the id space of ``g`` (pass ``problem`` to reuse prepared
    state — sources must then be in the prepared graph's ids)."""
    f = make_multi_source_bfs(g, len(sources), use_kernel=use_kernel,
                              problem=problem)
    levels = np.asarray(f(jnp.asarray(sources)))     # (n, S)
    finite = levels != INF
    dist_sum = np.where(finite, levels, 0).sum(axis=0).astype(np.float64)
    reach = finite.sum(axis=0)
    with np.errstate(divide="ignore", invalid="ignore"):
        cc = np.where(dist_sum > 0, (reach - 1) / dist_sum, 0.0)
    return cc
