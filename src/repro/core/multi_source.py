"""Multi-source BFS as batched BVSS bit-SpMM (paper §2 + §7, DESIGN §2.5).

Stacking S frontiers column-wise turns the SpMSpV pull into an SpMM; on TPU
this is where the MXU path pays off (DESIGN.md §2.2): the slices of every
queued VSS are contracted against the S stacked σ-bit frontier bytes of its
slice set as small bit-SpMM tiles (``kernels.bvss_spmm``).  Unlike the seed
implementation, the hot path never materialises the O(n²/32) dense
``to_dense_bits`` adjacency — peak device memory scales with BVSS words.

The level loop rides the same :class:`~repro.core.level_pipeline.LevelPipeline`
skeleton as the single-source engines, and reuses their bucketed static-width
queue: one compacted *union* queue of VSSs (a slice set is live if ANY source
column's frontier touches it), the per-column frontier kept as packed words.

:func:`make_ms_engine` exposes the jit-able building blocks (init / insert /
requeue / one lock-step level) so GraphSession (``repro.serve``) can drive
the same step with host control between levels — the wave-serving loop with
mid-flight slot refills — while :func:`make_multi_source_bfs` fuses the whole
loop on device for the fixed-cohort case (closeness centrality).
:func:`drive_wave` is the generic host loop both ride: callers supply only a
*refill hook* (``next_source``) and a harvest callback, so every wave client
— level serving, connected-components flood-fill re-seeding
(``repro.analytics.components``), centrality cohorts — shares one slot-pool
discipline instead of re-implementing it.

``make_ms_engine(..., track_sigma=True)`` widens the wave state with a σ
path-count channel (DESIGN §2.6): alongside the Boolean bit-SpMM pull, each
level runs the *weighted* tile product ``kernels.bvss_spmm_w`` over the same
queued BVSS masks, propagating ``paths[u] = Σ paths[pred]`` for the Brandes
forward phase (``repro.analytics.betweenness``); the Boolean counts still
gate discovery, so the float channel can never invent a vertex.

Everything here is MESH-NATIVE (DESIGN §2.4), the float channel included:
a row-sharded :class:`~repro.core.bfs.BlestProblem` runs the same
step/finalize under ``shard_map`` — each shard pulls/scatters its local
``(rows_per_shard, S)`` level block, every shard carries a replica of the
stacked global frontier words (that replica IS each device's pull
operand), and one frontier-word all-gather per level refreshes it.  The
σ channel shards exactly like the frontier bits: ``paths`` is a local
``(rows_per_shard, S)`` block, and each level's weighted pull consumes a
per-level all-gather of the σ-frontier float values — the float twin of
the frontier-word gather, hoisted OUT of the bucket ``cond`` (collectives
inside device-varying branches would wedge the mesh).  The host-visible
wave state then has a leading shard axis on every field.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bfs import (DIRECTIONS, BlestProblem, _frontier_bytes,
                            _round_width, expand_push_queue, make_compactor,
                            make_vertex_compactor, queue_widths,
                            resolve_push_cap, select_width, selected_width)
from repro.core.bvss import ShardedBVSSDevice
from repro.core.level_pipeline import LevelPipeline, global_any, run_levels
from repro.distributed.bfs_dist import frontier_all_gather
from repro.distributed.collectives import (butterfly_frontier_exchange,
                                           butterfly_or_allreduce)
from repro.errors import ConfigError
from repro.graphs import Graph
from repro.kernels import bvss_spmm, bvss_spmm_w, bvss_spmm_w_local
from repro.kernels.ref import bvss_spmm_ref, bvss_spmm_w_ref

INF = np.int32(np.iinfo(np.int32).max)


def _union_words(F: jnp.ndarray) -> jnp.ndarray:
    """OR the per-column packed frontiers ``(n_fwords, S)`` into one union
    word array — the push phase compacts its vertex queue from this (a
    vertex is queued iff ANY column's frontier holds it)."""
    return jax.lax.reduce(F, jnp.uint32(0), jax.lax.bitwise_or, (1,))


def _push_fbytes(F: jnp.ndarray, vrep: jnp.ndarray, sigma: int
                 ) -> jnp.ndarray:
    """Per-(queue entry, column) one-hot frontier bytes for the batched
    push phase: entry b pushing vertex v contributes ``1 << (v % σ)`` to
    exactly the columns whose frontier actually holds v, 0 elsewhere — so
    a vertex live in SOME columns never leaks discoveries into the others.
    Dummy entries need no special case: whatever byte they produce meets
    the all-zero dummy masks row of their dummy VSS id."""
    member = ((F[vrep // 32] >> (vrep % 32).astype(jnp.uint32)[:, None])
              & jnp.uint32(1))                               # (B, S) {0,1}
    return (jnp.uint32(1)
            << (vrep % sigma).astype(jnp.uint32))[:, None] * member


def _pack_cols(bits: jnp.ndarray, lwords: int) -> jnp.ndarray:
    """Per-column frontier pack: bool (lwords*32, S) -> uint32 (lwords, S)."""
    S = bits.shape[1]
    w = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits.reshape(lwords, 32, S).astype(jnp.uint32)
                   * w[None, :, None], axis=1, dtype=jnp.uint32)


def _unpack_cols(words: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`_pack_cols`: uint32 (lwords, S) -> bool (lwords*32, S)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return ((words[:, None, :] >> shifts[None, :, None]) & 1
            ).reshape(-1, words.shape[1]) != 0


class MSState(NamedTuple):
    levels: jnp.ndarray   # (n+1, S) int32; row n is the dummy-row sink
                          #   sharded: (D, rps+1, S), LOCAL rows per shard
    F: jnp.ndarray        # (n_fwords, S) uint32 per-column packed frontier
                          #   sharded: (D, n_fwords, S), one global replica
                          #   per shard (each device's pull operand)
    Q: jnp.ndarray        # (qcap,) int32 union VSS queue, dummy-padded
                          #   sharded: (D, qcap), one queue per shard
    count: jnp.ndarray    # int32 live VSS count (bucket pick; sharded (D,))
    col_lvl: jnp.ndarray  # (S,) int32 per-column BFS depth reached so far
                          #   sharded: (D, S) identical replicas
    cont: jnp.ndarray     # bool: any live VSS anywhere (mesh-global)
    paths: jnp.ndarray | None = None
                          # (n, S) float32 σ shortest-path counts (Brandes
                          # forward channel), present iff the engine was
                          # built with ``track_sigma=True``; None otherwise
                          # (a None pytree leaf costs the default engines
                          # nothing).  Sharded: (D, rps, S), LOCAL rows per
                          # shard — the float channel shards like levels,
                          # not like the replicated frontier words


@dataclasses.dataclass(frozen=True)
class MSEngine:
    """Jit-able building blocks of the batched BVSS SpMM level step.

    ``step``/``finalize`` plug into :class:`LevelPipeline` for the fused
    on-device loop; ``insert``/``requeue``/``level_step``/``col_live`` are
    the wave-serving surface (jitted, host-driven between levels).
    ``levels_of(state, slot)`` extracts one column's ``(n,)`` levels in
    global row ids so the serving layer never needs to know the shard
    layout."""

    problem: BlestProblem
    n_slots: int
    init: Callable        # (sources (S,) i32) -> MSState, queue rebuilt
    idle: Callable        # () -> MSState with no live columns
    insert: Callable      # (state, slot, src) -> MSState (requeue after!)
    insert_batch: Callable  # (state, srcs (S,), mask (S,)) -> MSState with
                          # every masked slot reset + queue rebuilt: ONE
                          # dispatch per refill round (the drive_wave path)
    requeue: Callable     # (state) -> state with Q/count rebuilt from F
    step: Callable | None        # (state) -> state after gather+pull+update
    finalize: Callable | None    # (state) -> state after pack+requeue
                          # (None on the sharded surface: the fused loop is
                          # built by make_multi_source_bfs instead)
    level_step: Callable  # jitted (state) -> (state, live (S,) bool) after
                          # one full level — liveness piggybacks on the
                          # step so serving pays ONE dispatch per level
    col_live: Callable    # jitted (state) -> (S,) bool frontier non-empty
    levels_of: Callable   # (state, slot) -> (n,) levels in global row ids
    paths_of: Callable | None = None
                          # (state, slot) -> (n,) σ path counts in global
                          # row ids; None unless built with track_sigma


def make_ms_engine(problem: BlestProblem, n_slots: int, *,
                   use_kernel: bool = True, buckets: int = 2,
                   track_sigma: bool = False,
                   widths: list[int] | None = None,
                   direction: str = "auto", push_cap: int | None = None,
                   alpha: float = 4.0,
                   spmm_impl: Callable | None = None,
                   spmm_w_impl: Callable | None = None,
                   gather_impl: Callable | None = None,
                   push_impl: Callable | None = None) -> MSEngine:
    """Build the S-column lock-step BVSS level machinery (mesh-native when
    ``problem`` is sharded).  ``track_sigma`` widens the wave state with
    the Brandes σ path-count channel — on a sharded problem the channel
    rides the generic sharded float path (per-level all-gather of the
    σ-frontier values, DESIGN §2.6).

    ``direction`` / ``push_cap`` / ``alpha`` / ``widths`` are the
    direction-optimizing knobs of DESIGN §2.8, batched: the push branch
    compacts the UNION frontier (any column) into a vertex queue, expands
    each vertex into the ≤ R VSSs of its own slice set, and pushes
    per-column one-hot frontier bytes through the SAME bit-SpMM tile
    product the pull uses — so both directions share one kernel and one
    fault seam (``spmm_impl``).  ``track_sigma`` pins ``direction="pull"``
    (the σ channel's weighted twin has no push formulation; asking for
    forced push with σ tracking is a :class:`~repro.errors.ConfigError`).
    ``widths`` overrides the bucketed pull ladder (autotuner injection
    point); default is ``queue_widths(num_vss, buckets)``.

    ``spmm_impl`` / ``spmm_w_impl`` / ``gather_impl`` are the documented
    FAULT SEAMS (DESIGN §2.7): engines capture their kernels in jitted
    closures at build time, so fault injection (``serve/faults.py``) — and
    any future kernel substitution — happens here, as explicit build-time
    overrides of the bit-SpMM, weighted-SpMM and frontier-word all-gather
    call sites, not by monkeypatching module globals after tracing.
    ``gather_impl`` must match :func:`repro.distributed.bfs_dist.
    frontier_all_gather`'s ``(fw_local, axis)`` signature and is only
    consulted on a sharded problem.  ``push_impl`` is accepted so fault
    plans can splat ONE override dict into every engine build; the wave
    engine's push branch rides the bit-SpMM seam (see above), so the
    single-source push-kernel override has nothing to attach to here and
    is ignored."""
    del push_impl  # wave push rides the spmm seam (docstring above)
    p = problem
    if direction not in DIRECTIONS:
        raise ConfigError(
            f"direction must be one of {DIRECTIONS}, got {direction!r}")
    if track_sigma:
        if direction == "push":
            raise ConfigError(
                "track_sigma is pull-only (the Brandes σ channel has no "
                "weighted push twin); direction='push' is contradictory")
        direction = "pull"
    spmm = spmm_impl if spmm_impl is not None else \
        (bvss_spmm if use_kernel else bvss_spmm_ref)
    spmm_w = spmm_w_impl if spmm_w_impl is not None else \
        (bvss_spmm_w if use_kernel else bvss_spmm_w_ref)
    if p.mesh is not None:
        if p.is_2d:
            return _make_ms_engine_sharded_2d(p, n_slots, spmm=spmm,
                                              buckets=buckets,
                                              spmm_w=spmm_w,
                                              track_sigma=track_sigma,
                                              gather=gather_impl,
                                              widths=widths,
                                              direction=direction)
        return _make_ms_engine_sharded(p, n_slots, spmm=spmm,
                                       buckets=buckets, spmm_w=spmm_w,
                                       track_sigma=track_sigma,
                                       gather=gather_impl, widths=widths,
                                       direction=direction,
                                       push_cap=push_cap, alpha=alpha)
    dev = p.dev
    sigma = p.sigma
    S = n_slots
    n, n_fwords = p.n, p.n_fwords
    widths = list(widths) if widths is not None else \
        queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    compact = make_compactor(dev, p.num_vss, qcap)
    all_sets = jnp.arange(p.n_sets, dtype=jnp.int32)
    n_pad = n_fwords * 32
    n_cols = p.n_sets * sigma  # padded column space (≥ n) for value gathers
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)

    def pull_update(state: MSState, width: int) -> MSState:
        ids = jax.lax.slice_in_dim(state.Q, 0, width)
        fb = _frontier_bytes(state.F, dev.virtual_to_real[ids], sigma)
        counts = spmm(dev.masks[ids], fb, sigma=sigma)  # (w, spw, 32, S)
        rows = dev.row_ids[ids].reshape(-1)
        cand = (state.col_lvl + 1)[None, :]
        upd = jnp.where(counts.reshape(-1, S) > 0, cand, INF
                        ).astype(jnp.int32)
        # eager scatter-min: an already-visited row keeps its smaller level;
        # dummy rows land in the level sink (row n)
        levels = state.levels.at[rows].min(upd)
        if not track_sigma:
            return state._replace(levels=levels)
        # σ channel (DESIGN §2.6): the weighted twin of the pull above —
        # the SAME queued tiles, contracted against the frontier's float
        # path counts; rows discovered this level take the accumulated sum
        # (Boolean counts gate discovery, so a converged column — whose
        # frontier bits are gone but whose levels still match col_lvl —
        # contributes nothing).
        xv = jnp.where(levels[:n] == state.col_lvl[None, :],
                       state.paths, 0.0)
        xv = jnp.concatenate(
            [xv, jnp.zeros((n_cols - n, S), jnp.float32)])
        wv = bvss_spmm_w_local(dev.masks[ids], dev.virtual_to_real[ids],
                               xv, sigma=sigma, impl=spmm_w)
        acc = jnp.zeros((n + 1, S), jnp.float32).at[rows].add(
            wv.reshape(-1, S))
        newly = levels[:n] == cand
        return state._replace(
            levels=levels, paths=jnp.where(newly, acc[:n], state.paths))

    def pull_step(state: MSState) -> MSState:
        return select_width(widths, state.count,
                            lambda w: pull_update(state, w))

    pcap = resolve_push_cap(direction, push_cap, n)
    pqcap = _round_width(pcap)
    R = p.max_vss_per_set
    push_cost = pqcap * R
    if direction == "pull" or (direction == "auto"
                               and push_cost >= widths[-1]):
        # push can never undercut even the full pull width: compile the
        # pure pull step (same static bail as the single-source engines)
        step = pull_step
    else:
        compact_vertices = make_vertex_compactor(n_fwords, n, pqcap)

        def push_update(state: MSState) -> MSState:
            """Batched push level (DESIGN §2.8): union-frontier vertex
            queue → per-vertex VSS expansion → per-column one-hot bytes
            through the same bit-SpMM tiles → the same scatter-min."""
            VQ, _ = compact_vertices(_union_words(state.F))
            ids = expand_push_queue(dev, VQ, R, p.num_vss)
            vrep = jnp.broadcast_to(VQ[:, None], (pqcap, R)).reshape(-1)
            fb = _push_fbytes(state.F, vrep, sigma)
            counts = spmm(dev.masks[ids], fb, sigma=sigma)
            rows = dev.row_ids[ids].reshape(-1)
            cand = (state.col_lvl + 1)[None, :]
            upd = jnp.where(counts.reshape(-1, S) > 0, cand, INF
                            ).astype(jnp.int32)
            return state._replace(levels=state.levels.at[rows].min(upd))

        if direction == "push":
            step = push_update
        else:
            def step(state: MSState) -> MSState:
                ucount = jnp.sum(jax.lax.population_count(
                    _union_words(state.F))).astype(jnp.int32)
                tbits = jnp.sum(jax.lax.population_count(state.F)
                                ).astype(jnp.float32)
                unvisited = jnp.sum(state.levels[:n] == INF
                                    ).astype(jnp.float32)
                use_push = ((ucount <= pcap)
                            & (jnp.int32(push_cost)
                               < selected_width(widths, state.count))
                            & (tbits * jnp.float32(alpha) <= unvisited))
                return jax.lax.cond(use_push, push_update, pull_step,
                                    state)

    def requeue(state: MSState) -> MSState:
        """Rebuild the union queue from the per-column frontiers: a slice
        set is live iff any column's σ-bit frontier byte is non-zero."""
        set_active = (_frontier_bytes(state.F, all_sets, sigma) != 0
                      ).any(axis=1)
        Q, count = compact(set_active)
        return state._replace(Q=Q, count=count, cont=count > 0)

    def finalize(state: MSState) -> MSState:
        nxt = (state.col_lvl + 1)[None, :]
        new = state.levels[:n] == nxt                     # (n, S)
        bits = jnp.zeros((n_pad, S), dtype=bool).at[:n].set(new)
        F = jnp.sum(bits.reshape(n_fwords, 32, S).astype(jnp.uint32)
                    * weights[None, :, None], axis=1, dtype=jnp.uint32)
        state = state._replace(F=F, col_lvl=state.col_lvl + new.any(axis=0))
        return requeue(state)

    def _paths0() -> jnp.ndarray | None:
        return jnp.zeros((n, S), jnp.float32) if track_sigma else None

    def init(sources: jnp.ndarray) -> MSState:
        sources = jnp.asarray(sources, dtype=jnp.int32)
        cols = jnp.arange(S)
        levels = jnp.full((n + 1, S), INF, dtype=jnp.int32)
        levels = levels.at[sources, cols].set(0)
        F = jnp.zeros((n_fwords, S), dtype=jnp.uint32)
        F = F.at[sources // 32, cols].set(
            jnp.uint32(1) << (sources % 32).astype(jnp.uint32))
        paths = _paths0()
        if track_sigma:
            paths = paths.at[sources, cols].set(1.0)
        st = MSState(levels=levels, F=F,
                     Q=jnp.full((qcap,), p.num_vss, dtype=jnp.int32),
                     count=jnp.int32(0),
                     col_lvl=jnp.zeros((S,), dtype=jnp.int32),
                     cont=jnp.bool_(False), paths=paths)
        return requeue(st)

    def idle() -> MSState:
        return MSState(levels=jnp.full((n + 1, S), INF, dtype=jnp.int32),
                       F=jnp.zeros((n_fwords, S), dtype=jnp.uint32),
                       Q=jnp.full((qcap,), p.num_vss, dtype=jnp.int32),
                       count=jnp.int32(0),
                       col_lvl=jnp.zeros((S,), dtype=jnp.int32),
                       cont=jnp.bool_(False), paths=_paths0())

    def insert(state: MSState, slot: jnp.ndarray, src: jnp.ndarray
               ) -> MSState:
        """Reset column ``slot`` to a fresh query from ``src`` (internal
        ids).  Call ``requeue`` once after a refill round."""
        slot = jnp.asarray(slot, dtype=jnp.int32)
        src = jnp.asarray(src, dtype=jnp.int32)
        levels = state.levels.at[:, slot].set(INF).at[src, slot].set(0)
        F = state.F.at[:, slot].set(jnp.uint32(0))
        F = F.at[src // 32, slot].set(
            jnp.uint32(1) << (src % 32).astype(jnp.uint32))
        paths = state.paths
        if track_sigma:
            paths = paths.at[:, slot].set(0.0).at[src, slot].set(1.0)
        return state._replace(levels=levels, F=F, paths=paths,
                              col_lvl=state.col_lvl.at[slot].set(0))

    def insert_batch(state: MSState, srcs: jnp.ndarray, mask: jnp.ndarray
                     ) -> MSState:
        """Reset every slot with ``mask[j]`` to a fresh query from
        ``srcs[j]`` and rebuild the queue — one fused dispatch per refill
        round (``srcs[j]`` is ignored where the mask is False)."""
        cols = jnp.arange(S)
        levels = jnp.where(mask[None, :], INF, state.levels)
        levels = levels.at[srcs, cols].set(
            jnp.where(mask, 0, levels[srcs, cols]))
        F = jnp.where(mask[None, :], jnp.uint32(0), state.F)
        bit = jnp.uint32(1) << (srcs % 32).astype(jnp.uint32)
        F = F.at[srcs // 32, cols].set(
            jnp.where(mask, bit, F[srcs // 32, cols]))
        paths = state.paths
        if track_sigma:
            paths = jnp.where(mask[None, :], 0.0, paths)
            paths = paths.at[srcs, cols].set(
                jnp.where(mask, 1.0, paths[srcs, cols]))
        st = state._replace(levels=levels, F=F, paths=paths,
                            col_lvl=jnp.where(mask, 0, state.col_lvl))
        return requeue(st)

    def level_step(state: MSState) -> tuple[MSState, jnp.ndarray]:
        state = finalize(step(state))
        return state, (state.F != 0).any(axis=0)

    return MSEngine(
        problem=p, n_slots=S, init=jax.jit(init), idle=jax.jit(idle),
        insert=jax.jit(insert), insert_batch=jax.jit(insert_batch),
        requeue=jax.jit(requeue),
        step=step, finalize=finalize,
        level_step=jax.jit(level_step),
        col_live=jax.jit(lambda st: (st.F != 0).any(axis=0)),
        levels_of=lambda st, slot: st.levels[:n, slot],
        paths_of=(lambda st, slot: st.paths[:, slot]) if track_sigma
        else None)


# ---------------------------------------------------------------------------
# generic wave driver: the ONE slot-pool serving loop (DESIGN §2.5/§2.6)
# ---------------------------------------------------------------------------
def drive_wave(eng: MSEngine,
               next_source: Callable[[int], int | None],
               on_converged: Callable[[int, np.ndarray], None], *,
               max_steps: int | None = None,
               should_harvest: Callable[[int], bool] | None = None,
               on_harvested: Callable[[int, np.ndarray], None] | None = None
               ) -> int:
    """Drive batched waves with mid-flight slot refills until the refill
    hook runs dry — the host loop shared by level serving
    (``GraphSession.levels_batch``) and flood-fill re-seeding
    (``repro.analytics.components``).

    ``next_source(slot)`` returns the next source (internal row id) to
    launch in a freed slot, or None when the caller has nothing to queue
    *right now* (it is asked again after every harvest, so dynamic seeding
    off previous results is fine).  ``on_converged(slot, levels)`` receives
    each converged column's ``(n,)`` level array (global internal ids; the
    engine's ``levels_of`` hides any shard layout).  Returns the number of
    lock-step levels run.

    ``should_harvest(slot)`` / ``on_harvested(slot, levels)`` are the
    CANCELLATION hooks (DESIGN §2.7): after every lock-step level, each
    still-live slot is offered to ``should_harvest``; answering True
    harvests the column mid-flight — ``on_harvested`` receives the PARTIAL
    levels computed so far (vertices not yet reached are ``INF``) and the
    slot is freed for refill on the next round, so one over-deadline query
    cannot block the wave.  Cancellation granularity is one level step:
    that is the natural preemption point of the lock-step loop (the device
    dispatch itself is never interrupted).
    """
    S = eng.n_slots
    busy = [False] * S
    st = eng.idle()
    steps = 0
    srcs = np.zeros(S, dtype=np.int32)
    mask = np.zeros(S, dtype=bool)
    while True:
        mask[:] = False
        for slot in range(S):
            if not busy[slot]:
                src = next_source(slot)
                if src is None:
                    continue
                srcs[slot] = int(src)
                mask[slot] = True
                busy[slot] = True
        if not any(busy):
            return steps
        if mask.any():  # ONE fused insert+requeue dispatch per refill round
            st = eng.insert_batch(st, jnp.asarray(srcs), jnp.asarray(mask))
        st, live_dev = eng.level_step(st)
        live = np.asarray(live_dev)
        for slot in range(S):
            if not busy[slot]:
                continue
            if not live[slot]:
                on_converged(slot, np.asarray(eng.levels_of(st, slot)))
                busy[slot] = False
            elif should_harvest is not None and should_harvest(slot):
                # harvest mid-flight: hand back the partial levels and free
                # the slot; its stale column is overwritten by the next
                # insert_batch (until then its frontier bits cost only the
                # union queue a few extra live sets — never correctness)
                if on_harvested is not None:
                    on_harvested(slot, np.asarray(eng.levels_of(st, slot)))
                busy[slot] = False
        steps += 1
        if max_steps is not None and steps > max_steps:
            raise RuntimeError(
                f"wave serving did not converge in {max_steps} level steps")


# ---------------------------------------------------------------------------
# mesh-native wave machinery (DESIGN §2.4): shard_map'd step/finalize
# ---------------------------------------------------------------------------
class _MSLocals(NamedTuple):
    """The per-shard (unstacked-state) wave ops, shared by the host-driven
    serving surface and the fused on-device loop."""
    init: Callable
    insert: Callable
    insert_batch: Callable
    requeue: Callable
    step: Callable
    finalize: Callable


def _make_ms_locals(p: BlestProblem, S: int, spmm, widths: list[int],
                    qcap: int, *, spmm_w=None,
                    track_sigma: bool = False,
                    gather: Callable | None = None,
                    direction: str = "pull",
                    push_cap: int | None = None,
                    alpha: float = 4.0) -> Callable:
    """Build ``locals_for(dev) -> _MSLocals`` closing over one shard's BVSS
    views.  State fields here are LOCAL: levels (rps+1, S), F (n_fwords, S)
    global replica, Q (qcap,), count/cont scalars, col_lvl (S,).

    ``track_sigma`` threads the generic sharded float channel (DESIGN
    §2.6): ``paths`` is a LOCAL (rps, S) block, and each level's weighted
    pull contracts the shard's queued tiles against a per-level
    ``all_gather`` of every shard's σ-frontier float values — the float
    twin of the frontier-word gather in ``finalize``.  The gather is
    hoisted OUT of the bucket ``cond`` (shards may pick different widths,
    and a collective inside a device-varying branch wedges the mesh).

    ``direction`` / ``push_cap`` / ``alpha`` thread the direction
    heuristic (DESIGN §2.8): the frontier words are GLOBAL replicas, so
    every shard compacts the SAME union vertex queue and expands it
    through its OWN vertex→local-VSS maps — both cond branches stay
    collective-free (the heuristic's unvisited psum runs unconditionally
    before the cond), so the per-shard width term may diverge safely.
    ``track_sigma`` callers must pass (or default to) ``direction="pull"``
    — the σ channel has no push twin."""
    if direction not in DIRECTIONS:
        raise ConfigError(
            f"direction must be one of {DIRECTIONS}, got {direction!r}")
    if track_sigma and direction != "pull":
        raise ConfigError(
            "track_sigma locals are pull-only (no weighted push twin); "
            f"got direction={direction!r}")
    axis = p.axis
    sigma = p.sigma
    rps = p.rows_per_shard
    lwords = rps // 32
    all_sets = jnp.arange(p.n_sets, dtype=jnp.int32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    if gather is None:
        gather = frontier_all_gather
    pcap = resolve_push_cap(direction, push_cap, p.n)
    pqcap = _round_width(pcap)
    R = p.max_vss_per_set
    push_cost = pqcap * R
    pull_only = direction == "pull" or (direction == "auto"
                                        and push_cost >= widths[-1])

    def locals_for(dev: ShardedBVSSDevice) -> _MSLocals:
        compact = make_compactor(dev, p.num_vss, qcap)
        compact_vertices = make_vertex_compactor(p.n_fwords, p.n, pqcap)

        def pull_update(st: MSState, width: int,
                        xg: jnp.ndarray | None) -> MSState:
            ids = jax.lax.slice_in_dim(st.Q, 0, width)
            fb = _frontier_bytes(st.F, dev.virtual_to_real[ids], sigma)
            counts = spmm(dev.masks[ids], fb, sigma=sigma)
            rows = dev.row_ids[ids].reshape(-1)   # LOCAL rows, dummy = rps
            cand = (st.col_lvl + 1)[None, :]
            upd = jnp.where(counts.reshape(-1, S) > 0, cand, INF
                            ).astype(jnp.int32)
            levels = st.levels.at[rows].min(upd)
            if not track_sigma:
                return st._replace(levels=levels)
            # σ channel: the weighted twin over the SAME queued tiles,
            # pulling from the gathered global frontier values; only rows
            # the Boolean counts discovered THIS level take the sum, so a
            # converged column's stale values contribute nothing
            wv = bvss_spmm_w_local(dev.masks[ids],
                                   dev.virtual_to_real[ids], xg,
                                   sigma=sigma, impl=spmm_w)
            acc = jnp.zeros((rps + 1, S), jnp.float32).at[rows].add(
                wv.reshape(-1, S))
            newly = levels[:rps] == cand
            return st._replace(
                levels=levels,
                paths=jnp.where(newly, acc[:rps], st.paths))

        def push_update(st: MSState) -> MSState:
            """Batched push level (DESIGN §2.8): the union vertex queue is
            compacted from the GLOBAL frontier replica (identical on every
            shard), expanded through this shard's vertex→local-VSS maps,
            and resolved by the same bit-SpMM tiles + local scatter-min."""
            VQ, _ = compact_vertices(_union_words(st.F))
            ids = expand_push_queue(dev, VQ, R, p.num_vss)
            vrep = jnp.broadcast_to(VQ[:, None], (pqcap, R)).reshape(-1)
            fb = _push_fbytes(st.F, vrep, sigma)
            counts = spmm(dev.masks[ids], fb, sigma=sigma)
            rows = dev.row_ids[ids].reshape(-1)   # LOCAL rows, dummy = rps
            cand = (st.col_lvl + 1)[None, :]
            upd = jnp.where(counts.reshape(-1, S) > 0, cand, INF
                            ).astype(jnp.int32)
            return st._replace(levels=st.levels.at[rows].min(upd))

        def step(st: MSState) -> MSState:
            if track_sigma:
                # the one extra cross-device term of the float channel:
                # all-gather the σ-frontier values (rows at depth col_lvl),
                # mirroring finalize's frontier-word gather — BEFORE the
                # bucket cond (no collectives inside its branches)
                xv = jnp.where(st.levels[:rps] == st.col_lvl[None, :],
                               st.paths, 0.0)
                xg = jax.lax.all_gather(xv, axis, tiled=True)  # (n_pad, S)
            else:
                xg = None

            def pull_step(s: MSState) -> MSState:
                return select_width(widths, s.count,
                                    lambda w: pull_update(s, w, xg))

            if pull_only:
                return pull_step(st)
            if direction == "push":
                return push_update(st)
            ucount = jnp.sum(jax.lax.population_count(
                _union_words(st.F))).astype(jnp.int32)
            tbits = jnp.sum(jax.lax.population_count(st.F)
                            ).astype(jnp.float32)
            # unvisited is mesh-global (levels are local row blocks); the
            # psum runs on every shard BEFORE the branch, so the cond
            # bodies stay collective-free even if the width term diverges
            unvisited = jax.lax.psum(
                jnp.sum(st.levels[:rps] == INF), axis).astype(jnp.float32)
            use_push = ((ucount <= pcap)
                        & (jnp.int32(push_cost)
                           < selected_width(widths, st.count))
                        & (tbits * jnp.float32(alpha) <= unvisited))
            return jax.lax.cond(use_push, push_update, pull_step, st)

        def requeue(st: MSState) -> MSState:
            # F is already the global replica: no gather needed here
            set_active = (_frontier_bytes(st.F, all_sets, sigma) != 0
                          ).any(axis=1)
            Q, count = compact(set_active)
            return st._replace(Q=Q, count=count,
                               cont=global_any(count > 0, axis))

        def finalize(st: MSState) -> MSState:
            nxt = (st.col_lvl + 1)[None, :]
            new = st.levels[:rps] == nxt                     # (rps, S)
            fw = jnp.sum(new.reshape(lwords, 32, S).astype(jnp.uint32)
                         * weights[None, :, None], axis=1, dtype=jnp.uint32)
            advanced = global_any(new.any(axis=0), axis)     # (S,)
            # the one cross-device term per level: refresh every shard's
            # global frontier replica from the per-shard new words
            F = gather(fw, axis)                             # (n_fwords, S)
            st = st._replace(F=F, col_lvl=st.col_lvl + advanced)
            return requeue(st)

        def _seed_paths(paths: jnp.ndarray, lsrc: jnp.ndarray,
                        cols: jnp.ndarray, own: jnp.ndarray) -> jnp.ndarray:
            """Set σ(source) = 1 on the owning shard: ``paths`` has no
            dummy row, so non-owned writes clamp to a real row and write
            back the old value (a no-op)."""
            row = jnp.clip(lsrc, 0, rps - 1)
            return paths.at[row, cols].set(
                jnp.where(own, 1.0, paths[row, cols]))

        def init(sources: jnp.ndarray) -> MSState:
            d = jax.lax.axis_index(axis)
            cols = jnp.arange(S)
            lsrc = sources - d * rps
            own = (lsrc >= 0) & (lsrc < rps)
            levels = jnp.full((rps + 1, S), INF, dtype=jnp.int32)
            levels = levels.at[jnp.where(own, lsrc, rps), cols].set(
                jnp.where(own, 0, INF))
            F = jnp.zeros((p.n_fwords, S), dtype=jnp.uint32)
            F = F.at[sources // 32, cols].set(
                jnp.uint32(1) << (sources % 32).astype(jnp.uint32))
            paths = None
            if track_sigma:
                paths = _seed_paths(jnp.zeros((rps, S), jnp.float32),
                                    lsrc, cols, own)
            st = MSState(levels=levels, F=F,
                         Q=jnp.full((qcap,), p.num_vss, dtype=jnp.int32),
                         count=jnp.int32(0),
                         col_lvl=jnp.zeros((S,), dtype=jnp.int32),
                         cont=jnp.bool_(False), paths=paths)
            return requeue(st)

        def insert(st: MSState, slot, src) -> MSState:
            d = jax.lax.axis_index(axis)
            slot = jnp.asarray(slot, dtype=jnp.int32)
            src = jnp.asarray(src, dtype=jnp.int32)
            lsrc = src - d * rps
            own = (lsrc >= 0) & (lsrc < rps)
            levels = st.levels.at[:, slot].set(INF)
            levels = levels.at[jnp.where(own, lsrc, rps), slot].set(
                jnp.where(own, 0, INF))
            # F is the global replica: every shard sets the same bit
            F = st.F.at[:, slot].set(jnp.uint32(0))
            F = F.at[src // 32, slot].set(
                jnp.uint32(1) << (src % 32).astype(jnp.uint32))
            paths = st.paths
            if track_sigma:
                paths = _seed_paths(paths.at[:, slot].set(0.0),
                                    lsrc, slot, own)
            return st._replace(levels=levels, F=F, paths=paths,
                               col_lvl=st.col_lvl.at[slot].set(0))

        def insert_batch(st: MSState, srcs, mask) -> MSState:
            d = jax.lax.axis_index(axis)
            cols = jnp.arange(S)
            lsrc = srcs - d * rps
            own = mask & (lsrc >= 0) & (lsrc < rps)
            rows = jnp.where(own, lsrc, rps)    # non-owned -> dummy row
            levels = jnp.where(mask[None, :], INF, st.levels)
            levels = levels.at[rows, cols].set(
                jnp.where(own, 0, levels[rows, cols]))
            F = jnp.where(mask[None, :], jnp.uint32(0), st.F)
            bit = jnp.uint32(1) << (srcs % 32).astype(jnp.uint32)
            F = F.at[srcs // 32, cols].set(
                jnp.where(mask, bit, F[srcs // 32, cols]))
            paths = st.paths
            if track_sigma:
                paths = _seed_paths(jnp.where(mask[None, :], 0.0, paths),
                                    lsrc, cols, own)
            st = st._replace(levels=levels, F=F, paths=paths,
                             col_lvl=jnp.where(mask, 0, st.col_lvl))
            return requeue(st)

        return _MSLocals(init=init, insert=insert,
                         insert_batch=insert_batch, requeue=requeue,
                         step=step, finalize=finalize)

    return locals_for


def _make_ms_locals_2d(p: BlestProblem, S: int, spmm, widths: list[int],
                       qcap: int, *, spmm_w=None,
                       track_sigma: bool = False,
                       gather: Callable | None = None,
                       direction: str = "pull") -> Callable:
    """2-D (row × column) per-device wave ops (DESIGN §2.4): device (i, j)
    holds LOCAL levels (rps+1, S) for row block i, a COLUMN-BLOCK frontier
    ``F`` of (n_fwords, S) words (colblock j's offsets of EVERY row block,
    interleaved layout — this is the only frontier the device ever pulls,
    1/cols of the global words), one union queue over its (i, j) BVSS
    block, and — with ``track_sigma`` — a LOCAL (rps, S) σ block.

    The level step swaps the 1-D eager scatter-min for mark-accumulate:
    each device's pull covers only colblock j of the frontier, so its
    partial hits must be OR-combined ACROSS the column axis (butterfly
    OR-allreduce of the per-column packed hit words) before any level may
    commit — an eager local scatter-min would assign levels off partial
    evidence.  ``finalize`` then packs the newly array, keeps only this
    device's column segment of it, and butterfly-exchanges the segments
    over the ROW axis to rebuild next level's column-block frontier.  The
    σ partial sums ride a float ``psum`` over the column axis, hoisted OUT
    of the bucket ``cond`` (collectives inside device-varying branches
    wedge the mesh), and the σ-frontier values are butterfly-gathered over
    the row axis exactly like the frontier words.

    The 2-D partition is PULL-ONLY: the push formulation writes to remote
    row blocks, which the column-partitioned frontier cannot express
    without a second scatter collective.  ``direction="auto"`` silently
    resolves to pull; a forced ``"push"`` raises
    :class:`~repro.errors.ConfigError`.  ``gather`` is the same fault seam
    as the 1-D engines — it wraps the ROW-axis frontier-segment exchange
    (default :func:`~repro.distributed.collectives.
    butterfly_frontier_exchange`)."""
    if direction == "push":
        raise ConfigError(
            "the 2-D row × column partition is pull-only: push writes to "
            "remote row blocks, which the column-partitioned frontier "
            "cannot express; use direction='pull' or 'auto', or a 1-D mesh")
    rax, cax = p.axis, p.col_axis
    sigma = p.sigma
    rps = p.rows_per_shard
    cpb = p.cols_per_block
    C = p.n_col_shards
    lwords = rps // 32          # packed words covering one row block
    wpc = lwords // C           # words per column segment of a row block
    ncw = p.n_fwords            # column-block frontier words = R·cpb/32
    n_loc = ncw * 32            # local column space = R·cpb
    n_cols = p.n_sets * sigma   # padded pull-operand columns (≥ n_loc)
    all_sets = jnp.arange(p.n_sets, dtype=jnp.int32)
    if gather is None:
        gather = butterfly_frontier_exchange

    def locals_for(dev: ShardedBVSSDevice) -> _MSLocals:
        compact = make_compactor(dev, p.num_vss, qcap)

        def pull_partial(st: MSState, width: int, xg: jnp.ndarray | None):
            """One bucket width's pull over block (i, j): returns the
            PARTIAL per-column hit marks (rps+1, S) — row rps is the dummy
            sink — plus the partial σ accumulator; nothing is committed
            until the cross-column reduce."""
            ids = jax.lax.slice_in_dim(st.Q, 0, width)
            fb = _frontier_bytes(st.F, dev.virtual_to_real[ids], sigma)
            counts = spmm(dev.masks[ids], fb, sigma=sigma)
            rows = dev.row_ids[ids].reshape(-1)   # LOCAL rows, dummy = rps
            hit = jnp.zeros((rps + 1, S), dtype=bool).at[rows].max(
                counts.reshape(-1, S) > 0)
            if not track_sigma:
                return hit, None
            wv = bvss_spmm_w_local(dev.masks[ids],
                                   dev.virtual_to_real[ids], xg,
                                   sigma=sigma, impl=spmm_w)
            acc = jnp.zeros((rps + 1, S), jnp.float32).at[rows].add(
                wv.reshape(-1, S))
            return hit, acc

        def step(st: MSState) -> MSState:
            j = jax.lax.axis_index(cax)
            if track_sigma:
                # σ-frontier values: this device contributes its column
                # segment of its row block's values, butterfly-gathered
                # over the ROW axis into the (n_loc, S) pull operand —
                # the float twin of finalize's frontier-word exchange,
                # hoisted BEFORE the bucket cond
                xv = jnp.where(st.levels[:rps] == st.col_lvl[None, :],
                               st.paths, 0.0)
                seg = jax.lax.dynamic_slice_in_dim(xv, j * cpb, cpb, axis=0)
                xg = butterfly_frontier_exchange(seg, rax)    # (n_loc, S)
                if n_cols > n_loc:
                    xg = jnp.concatenate(
                        [xg, jnp.zeros((n_cols - n_loc, S), jnp.float32)])
            else:
                xg = None
            hit, acc = select_width(widths, st.count,
                                    lambda w: pull_partial(st, w, xg))
            # cross-column combine: pack the partial hits per column, OR
            # them across the column axis, and only then commit levels —
            # every device in mesh row i sees the SAME full-row-block hits
            hw = butterfly_or_allreduce(_pack_cols(hit[:rps], lwords), cax)
            hit_full = _unpack_cols(hw)                       # (rps, S)
            cand = (st.col_lvl + 1)[None, :]
            newly = hit_full & (st.levels[:rps] == INF)
            levels = st.levels.at[:rps].set(
                jnp.where(newly, cand, st.levels[:rps]))
            if not track_sigma:
                return st._replace(levels=levels)
            accf = jax.lax.psum(acc[:rps], cax)
            return st._replace(
                levels=levels,
                paths=jnp.where(newly, accf, st.paths))

        def requeue(st: MSState) -> MSState:
            set_active = (_frontier_bytes(st.F, all_sets, sigma) != 0
                          ).any(axis=1)
            Q, count = compact(set_active)
            return st._replace(Q=Q, count=count,
                               cont=global_any(count > 0, (rax, cax)))

        def finalize(st: MSState) -> MSState:
            j = jax.lax.axis_index(cax)
            nxt = (st.col_lvl + 1)[None, :]
            new = st.levels[:rps] == nxt                      # (rps, S)
            fw = _pack_cols(new, lwords)                      # (lwords, S)
            advanced = global_any(new.any(axis=0), (rax, cax))
            # next level's column-block frontier: keep this device's
            # column segment of its row block's new words and butterfly-
            # exchange the segments over the ROW axis (the fault seam)
            seg = jax.lax.dynamic_slice_in_dim(fw, j * wpc, wpc, axis=0)
            F = gather(seg, rax)                              # (ncw, S)
            st = st._replace(F=F, col_lvl=st.col_lvl + advanced)
            return requeue(st)

        def _fseed(F: jnp.ndarray, srcs, cols, mask):
            """Seed frontier bits for masked slots in the COLUMN-BLOCK
            layout: only the mesh column owning each source's offset sets
            its bit (clamped no-op writes elsewhere)."""
            j = jax.lax.axis_index(cax)
            off = srcs % rps
            ownc = mask & ((off // cpb) == j)
            c = jnp.clip((srcs // rps) * cpb + (off - j * cpb),
                         0, n_loc - 1)
            bit = jnp.uint32(1) << (c % 32).astype(jnp.uint32)
            return F.at[c // 32, cols].set(
                jnp.where(ownc, bit, F[c // 32, cols]))

        def _seed_paths(paths: jnp.ndarray, lsrc, cols, own):
            row = jnp.clip(lsrc, 0, rps - 1)
            return paths.at[row, cols].set(
                jnp.where(own, 1.0, paths[row, cols]))

        def init(sources: jnp.ndarray) -> MSState:
            i = jax.lax.axis_index(rax)
            cols = jnp.arange(S)
            lsrc = sources - i * rps
            own = (lsrc >= 0) & (lsrc < rps)
            levels = jnp.full((rps + 1, S), INF, dtype=jnp.int32)
            levels = levels.at[jnp.where(own, lsrc, rps), cols].set(
                jnp.where(own, 0, INF))
            F = _fseed(jnp.zeros((ncw, S), dtype=jnp.uint32), sources,
                       cols, jnp.ones((S,), dtype=bool))
            paths = None
            if track_sigma:
                paths = _seed_paths(jnp.zeros((rps, S), jnp.float32),
                                    lsrc, cols, own)
            st = MSState(levels=levels, F=F,
                         Q=jnp.full((qcap,), p.num_vss, dtype=jnp.int32),
                         count=jnp.int32(0),
                         col_lvl=jnp.zeros((S,), dtype=jnp.int32),
                         cont=jnp.bool_(False), paths=paths)
            return requeue(st)

        def insert(st: MSState, slot, src) -> MSState:
            i = jax.lax.axis_index(rax)
            slot = jnp.asarray(slot, dtype=jnp.int32)
            src = jnp.asarray(src, dtype=jnp.int32)
            lsrc = src - i * rps
            own = (lsrc >= 0) & (lsrc < rps)
            levels = st.levels.at[:, slot].set(INF)
            levels = levels.at[jnp.where(own, lsrc, rps), slot].set(
                jnp.where(own, 0, INF))
            F = _fseed(st.F.at[:, slot].set(jnp.uint32(0)), src, slot,
                       jnp.bool_(True))
            paths = st.paths
            if track_sigma:
                paths = _seed_paths(paths.at[:, slot].set(0.0),
                                    lsrc, slot, own)
            return st._replace(levels=levels, F=F, paths=paths,
                               col_lvl=st.col_lvl.at[slot].set(0))

        def insert_batch(st: MSState, srcs, mask) -> MSState:
            i = jax.lax.axis_index(rax)
            cols = jnp.arange(S)
            lsrc = srcs - i * rps
            own = mask & (lsrc >= 0) & (lsrc < rps)
            rows = jnp.where(own, lsrc, rps)
            levels = jnp.where(mask[None, :], INF, st.levels)
            levels = levels.at[rows, cols].set(
                jnp.where(own, 0, levels[rows, cols]))
            F = _fseed(jnp.where(mask[None, :], jnp.uint32(0), st.F),
                       srcs, cols, mask)
            paths = st.paths
            if track_sigma:
                paths = _seed_paths(jnp.where(mask[None, :], 0.0, paths),
                                    lsrc, cols, own)
            st = st._replace(levels=levels, F=F, paths=paths,
                             col_lvl=jnp.where(mask, 0, st.col_lvl))
            return requeue(st)

        return _MSLocals(init=init, insert=insert,
                         insert_batch=insert_batch, requeue=requeue,
                         step=step, finalize=finalize)

    return locals_for


def _make_ms_engine_sharded(p: BlestProblem, n_slots: int, *, spmm,
                            buckets: int, spmm_w=None,
                            track_sigma: bool = False,
                            gather: Callable | None = None,
                            widths: list[int] | None = None,
                            direction: str = "auto",
                            push_cap: int | None = None,
                            alpha: float = 4.0) -> MSEngine:
    """Host-driven wave surface over the shard_map'd local ops: every state
    field gains a leading shard axis; each public fn is one jitted
    shard_map dispatch."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.bfs_dist import problem_specs, state_specs

    mesh, axis = p.mesh, p.axis
    D, rps = p.n_shards, p.rows_per_shard
    S = n_slots
    widths = list(widths) if widths is not None else \
        queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    locals_for = _make_ms_locals(p, S, spmm, widths, qcap, spmm_w=spmm_w,
                                 track_sigma=track_sigma, gather=gather,
                                 direction=direction, push_cap=push_cap,
                                 alpha=alpha)

    state_spec = state_specs(axis, track_sigma=track_sigma)
    dev_specs = problem_specs(axis)
    dev_args = (p.dev.masks, p.dev.row_ids, p.dev.virtual_to_real,
                p.dev.vss_of_vertex_start, p.dev.vss_of_vertex_end)

    def _dev(masks, row_ids, v2r, vstart, vend) -> ShardedBVSSDevice:
        return ShardedBVSSDevice(masks[0], row_ids[0], v2r[0],
                                 vstart[0], vend[0])

    def _unstack(st: MSState) -> MSState:
        return jax.tree_util.tree_map(lambda x: x[0], st)

    def _stack(st: MSState) -> MSState:
        return jax.tree_util.tree_map(lambda x: x[None], st)

    def sm(f, in_specs, out_specs):
        fn = shard_map(f, mesh=mesh, in_specs=dev_specs + in_specs,
                       out_specs=out_specs, check_rep=False)
        return lambda *args: fn(*dev_args, *args)

    def _init(masks, row_ids, v2r, vstart, vend, sources):
        loc = locals_for(_dev(masks, row_ids, v2r, vstart, vend))
        return _stack(loc.init(sources))

    def _insert(masks, row_ids, v2r, vstart, vend, st, slot, src):
        loc = locals_for(_dev(masks, row_ids, v2r, vstart, vend))
        return _stack(loc.insert(_unstack(st), slot, src))

    def _insert_batch(masks, row_ids, v2r, vstart, vend, st, srcs, mask):
        loc = locals_for(_dev(masks, row_ids, v2r, vstart, vend))
        return _stack(loc.insert_batch(_unstack(st), srcs, mask))

    def _requeue(masks, row_ids, v2r, vstart, vend, st):
        loc = locals_for(_dev(masks, row_ids, v2r, vstart, vend))
        return _stack(loc.requeue(_unstack(st)))

    def _level_step(masks, row_ids, v2r, vstart, vend, st):
        loc = locals_for(_dev(masks, row_ids, v2r, vstart, vend))
        st = loc.finalize(loc.step(_unstack(st)))
        return _stack(st), (st.F != 0).any(axis=0)[None]

    init_sm = sm(_init, (P(),), state_spec)
    insert_sm = sm(_insert, (state_spec, P(), P()), state_spec)
    insert_batch_sm = sm(_insert_batch, (state_spec, P(), P()), state_spec)
    requeue_sm = sm(_requeue, (state_spec,), state_spec)
    level_sm = sm(_level_step, (state_spec,), (state_spec, P(axis)))

    def idle() -> MSState:
        def sh(a):
            return jax.device_put(a, NamedSharding(mesh, P(axis)))
        return MSState(
            levels=sh(np.full((D, rps + 1, S), INF, np.int32)),
            F=sh(np.zeros((D, p.n_fwords, S), np.uint32)),
            Q=sh(np.full((D, qcap), p.num_vss, np.int32)),
            count=sh(np.zeros((D,), np.int32)),
            col_lvl=sh(np.zeros((D, S), np.int32)),
            cont=sh(np.zeros((D,), bool)),
            paths=sh(np.zeros((D, rps, S), np.float32))
            if track_sigma else None)

    def level_step(st: MSState) -> tuple[MSState, jnp.ndarray]:
        st, live = level_sm(st)
        return st, live[0]

    def levels_of(st: MSState, slot) -> jnp.ndarray:
        # slice the column first: moves one (n,) column, not (n, S)
        return st.levels[:, :rps, slot].reshape(-1)[:p.n]

    def paths_of(st: MSState, slot) -> jnp.ndarray:
        return st.paths[:, :, slot].reshape(-1)[:p.n]

    return MSEngine(
        problem=p, n_slots=S,
        init=jax.jit(lambda sources: init_sm(
            jnp.asarray(sources, dtype=jnp.int32))),
        idle=idle,
        insert=jax.jit(lambda st, slot, src: insert_sm(st, slot, src)),
        insert_batch=jax.jit(
            lambda st, srcs, mask: insert_batch_sm(st, srcs, mask)),
        requeue=jax.jit(requeue_sm),
        step=None, finalize=None,   # fused via make_multi_source_bfs
        level_step=jax.jit(level_step),
        col_live=jax.jit(lambda st: (st.F[0] != 0).any(axis=0)),
        levels_of=levels_of,
        paths_of=paths_of if track_sigma else None)


def _make_ms_engine_sharded_2d(p: BlestProblem, n_slots: int, *, spmm,
                               buckets: int, spmm_w=None,
                               track_sigma: bool = False,
                               gather: Callable | None = None,
                               widths: list[int] | None = None,
                               direction: str = "auto") -> MSEngine:
    """The 2-D twin of :func:`_make_ms_engine_sharded`: same host surface,
    R·C device blocks stacked row-major on every leading dim (block
    d = i·C + j), specs from ``state_specs2d``/``problem_specs2d``.  The
    host-visible extraction helpers read mesh column 0's replicas
    (``[::C]``) — levels and σ are column-replicated per row block — and
    ``col_live`` ORs the frontier words over ALL blocks (each holds only
    its column segment)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.bfs_dist import problem_specs2d, state_specs2d

    mesh = p.mesh
    rax, cax = p.axis, p.col_axis
    R, C, rps = p.n_shards, p.n_col_shards, p.rows_per_shard
    D = R * C
    S = n_slots
    widths = list(widths) if widths is not None else \
        queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    locals_for = _make_ms_locals_2d(p, S, spmm, widths, qcap,
                                    spmm_w=spmm_w,
                                    track_sigma=track_sigma, gather=gather,
                                    direction=direction)

    state_spec = state_specs2d(rax, cax, track_sigma=track_sigma)
    dev_specs = problem_specs2d(rax, cax)
    dev_args = (p.dev.masks, p.dev.row_ids, p.dev.virtual_to_real,
                p.dev.vss_of_vertex_start, p.dev.vss_of_vertex_end)

    def _dev(masks, row_ids, v2r, vstart, vend) -> ShardedBVSSDevice:
        return ShardedBVSSDevice(masks[0], row_ids[0], v2r[0],
                                 vstart[0], vend[0])

    def _unstack(st: MSState) -> MSState:
        return jax.tree_util.tree_map(lambda x: x[0], st)

    def _stack(st: MSState) -> MSState:
        return jax.tree_util.tree_map(lambda x: x[None], st)

    def sm(f, in_specs, out_specs):
        fn = shard_map(f, mesh=mesh, in_specs=dev_specs + in_specs,
                       out_specs=out_specs, check_rep=False)
        return lambda *args: fn(*dev_args, *args)

    def _init(masks, row_ids, v2r, vstart, vend, sources):
        loc = locals_for(_dev(masks, row_ids, v2r, vstart, vend))
        return _stack(loc.init(sources))

    def _insert(masks, row_ids, v2r, vstart, vend, st, slot, src):
        loc = locals_for(_dev(masks, row_ids, v2r, vstart, vend))
        return _stack(loc.insert(_unstack(st), slot, src))

    def _insert_batch(masks, row_ids, v2r, vstart, vend, st, srcs, mask):
        loc = locals_for(_dev(masks, row_ids, v2r, vstart, vend))
        return _stack(loc.insert_batch(_unstack(st), srcs, mask))

    def _requeue(masks, row_ids, v2r, vstart, vend, st):
        loc = locals_for(_dev(masks, row_ids, v2r, vstart, vend))
        return _stack(loc.requeue(_unstack(st)))

    def _level_step(masks, row_ids, v2r, vstart, vend, st):
        loc = locals_for(_dev(masks, row_ids, v2r, vstart, vend))
        st = loc.finalize(loc.step(_unstack(st)))
        # each block sees only its column segment: make per-slot liveness
        # globally consistent before it reaches the host serving loop
        live = global_any((st.F != 0).any(axis=0), (rax, cax))
        return _stack(st), live[None]

    init_sm = sm(_init, (P(),), state_spec)
    insert_sm = sm(_insert, (state_spec, P(), P()), state_spec)
    insert_batch_sm = sm(_insert_batch, (state_spec, P(), P()), state_spec)
    requeue_sm = sm(_requeue, (state_spec,), state_spec)
    level_sm = sm(_level_step, (state_spec,), (state_spec, P((rax, cax))))

    def idle() -> MSState:
        def sh(a):
            return jax.device_put(a, NamedSharding(mesh, P((rax, cax))))
        return MSState(
            levels=sh(np.full((D, rps + 1, S), INF, np.int32)),
            F=sh(np.zeros((D, p.n_fwords, S), np.uint32)),
            Q=sh(np.full((D, qcap), p.num_vss, np.int32)),
            count=sh(np.zeros((D,), np.int32)),
            col_lvl=sh(np.zeros((D, S), np.int32)),
            cont=sh(np.zeros((D,), bool)),
            paths=sh(np.zeros((D, rps, S), np.float32))
            if track_sigma else None)

    def level_step(st: MSState) -> tuple[MSState, jnp.ndarray]:
        st, live = level_sm(st)
        return st, live[0]

    def levels_of(st: MSState, slot) -> jnp.ndarray:
        # mesh column 0's replicas of every row block, one (n,) column
        return st.levels[::C, :rps, slot].reshape(-1)[:p.n]

    def paths_of(st: MSState, slot) -> jnp.ndarray:
        return st.paths[::C, :, slot].reshape(-1)[:p.n]

    return MSEngine(
        problem=p, n_slots=S,
        init=jax.jit(lambda sources: init_sm(
            jnp.asarray(sources, dtype=jnp.int32))),
        idle=idle,
        insert=jax.jit(lambda st, slot, src: insert_sm(st, slot, src)),
        insert_batch=jax.jit(
            lambda st, srcs, mask: insert_batch_sm(st, srcs, mask)),
        requeue=jax.jit(requeue_sm),
        step=None, finalize=None,   # fused via make_multi_source_bfs
        level_step=jax.jit(level_step),
        col_live=jax.jit(lambda st: (st.F != 0).any(axis=(0, 1))),
        levels_of=levels_of,
        paths_of=paths_of if track_sigma else None)


def make_multi_source_bfs(g: Graph | None, n_sources: int, *,
                          use_kernel: bool = True,
                          max_levels: int | None = None,
                          bvss=None, problem: BlestProblem | None = None,
                          buckets: int = 2,
                          widths: list[int] | None = None,
                          direction: str = "auto",
                          push_cap: int | None = None,
                          alpha: float = 4.0) -> Callable:
    """Build jitted ``f(sources (S,) i32) -> levels (n, S) i32`` with the
    whole level loop fused on device (fixed source cohort).  A sharded
    ``problem`` runs the loop as one ``shard_map``'d ``while_loop``.
    ``widths`` / ``direction`` / ``push_cap`` / ``alpha`` are the
    direction-optimizing knobs (DESIGN §2.8; see :func:`make_ms_engine`)."""
    if problem is None:
        if bvss is None:
            from repro.core.bvss import build_bvss
            bvss = build_bvss(g)
        problem = BlestProblem.build(bvss)
    max_lv = max_levels if max_levels is not None else problem.n + 1
    if problem.mesh is not None:
        if problem.is_2d:
            return _make_multi_source_bfs_sharded_2d(
                problem, n_sources, use_kernel=use_kernel, buckets=buckets,
                max_lv=max_lv, widths=widths, direction=direction)
        return _make_multi_source_bfs_sharded(
            problem, n_sources, use_kernel=use_kernel, buckets=buckets,
            max_lv=max_lv, widths=widths, direction=direction,
            push_cap=push_cap, alpha=alpha)
    eng = make_ms_engine(problem, n_sources, use_kernel=use_kernel,
                         buckets=buckets, widths=widths,
                         direction=direction, push_cap=push_cap,
                         alpha=alpha)
    step, finalize = eng.step, eng.finalize
    assert step is not None and finalize is not None
    pipe = LevelPipeline(step=lambda s, lvl: step(s),
                         finalize=lambda s, lvl: finalize(s),
                         active=lambda s: s.cont)

    def bfs(sources: jnp.ndarray) -> jnp.ndarray:
        state, _ = run_levels(pipe, eng.init(sources), max_levels=max_lv)
        return state.levels[:problem.n]

    return jax.jit(bfs)


def _make_multi_source_bfs_sharded(p: BlestProblem, n_sources: int, *,
                                   use_kernel: bool, buckets: int,
                                   max_lv: int,
                                   widths: list[int] | None = None,
                                   direction: str = "auto",
                                   push_cap: int | None = None,
                                   alpha: float = 4.0) -> Callable:
    """Fixed-cohort multi-source over the mesh: the SAME local step/finalize
    as the serving surface, with the whole level loop inside one
    ``shard_map``'d ``while_loop`` (no host sync, paper §4.3)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.bfs_dist import problem_specs

    mesh, axis = p.mesh, p.axis
    rps = p.rows_per_shard
    S = n_sources
    widths = list(widths) if widths is not None else \
        queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    spmm = bvss_spmm if use_kernel else bvss_spmm_ref
    locals_for = _make_ms_locals(p, S, spmm, widths, qcap,
                                 direction=direction, push_cap=push_cap,
                                 alpha=alpha)

    def local_loop(masks, row_ids, v2r, vstart, vend, sources):
        loc = locals_for(ShardedBVSSDevice(masks[0], row_ids[0], v2r[0],
                                           vstart[0], vend[0]))
        pipe = LevelPipeline(step=lambda s, lvl: loc.step(s),
                             finalize=lambda s, lvl: loc.finalize(s),
                             active=lambda s: s.cont)
        state, _ = run_levels(pipe, loc.init(sources), max_levels=max_lv)
        return state.levels[None, :rps]

    fn = shard_map(local_loop, mesh=mesh,
                   in_specs=problem_specs(axis) + (P(),),
                   out_specs=P(axis), check_rep=False)

    def bfs(sources: jnp.ndarray) -> jnp.ndarray:
        out = fn(p.dev.masks, p.dev.row_ids, p.dev.virtual_to_real,
                 p.dev.vss_of_vertex_start, p.dev.vss_of_vertex_end,
                 jnp.asarray(sources, dtype=jnp.int32))
        return out.reshape(-1, S)[:p.n]

    return jax.jit(bfs)


def _make_multi_source_bfs_sharded_2d(p: BlestProblem, n_sources: int, *,
                                      use_kernel: bool, buckets: int,
                                      max_lv: int,
                                      widths: list[int] | None = None,
                                      direction: str = "auto") -> Callable:
    """Fixed-cohort multi-source on the 2-D mesh: the same 2-D local
    step/finalize as the serving surface, fused into one ``shard_map``'d
    ``while_loop`` (butterfly exchanges INSIDE the loop body — no host
    sync across levels, paper §4.3)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.bfs_dist import problem_specs2d

    mesh = p.mesh
    rax, cax = p.axis, p.col_axis
    R, C, rps = p.n_shards, p.n_col_shards, p.rows_per_shard
    S = n_sources
    widths = list(widths) if widths is not None else \
        queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    spmm = bvss_spmm if use_kernel else bvss_spmm_ref
    locals_for = _make_ms_locals_2d(p, S, spmm, widths, qcap,
                                    direction=direction)

    def local_loop(masks, row_ids, v2r, vstart, vend, sources):
        loc = locals_for(ShardedBVSSDevice(masks[0], row_ids[0], v2r[0],
                                           vstart[0], vend[0]))
        pipe = LevelPipeline(step=lambda s, lvl: loc.step(s),
                             finalize=lambda s, lvl: loc.finalize(s),
                             active=lambda s: s.cont)
        state, _ = run_levels(pipe, loc.init(sources), max_levels=max_lv)
        return state.levels[None, :rps]

    fn = shard_map(local_loop, mesh=mesh,
                   in_specs=problem_specs2d(rax, cax) + (P(),),
                   out_specs=P((rax, cax)), check_rep=False)

    def bfs(sources: jnp.ndarray) -> jnp.ndarray:
        out = fn(p.dev.masks, p.dev.row_ids, p.dev.virtual_to_real,
                 p.dev.vss_of_vertex_start, p.dev.vss_of_vertex_end,
                 jnp.asarray(sources, dtype=jnp.int32))
        # (R·C, rps, S) blocks row-major: mesh column 0 holds the replicas
        return out.reshape(R, C, rps, S)[:, 0].reshape(-1, S)[:p.n]

    return jax.jit(bfs)

# closeness centrality (paper §7's target application for multi-source
# BFS) lives in ``repro.analytics.closeness`` since PR 5: it is a wave
# CLIENT — a reduction over the level channels this module produces —
# not part of the wave machinery itself.
