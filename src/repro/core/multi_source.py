"""Multi-source BFS as bit-SpMM on the MXU (paper §2 + §7 future work).

Stacking S frontiers column-wise turns the SpMSpV pull into an SpMM; on TPU
this is where the MXU path pays off (DESIGN.md §2.2): one 128×128 int8 MMA
resolves 128·128 Boolean dot products.  Used by the closeness-centrality
example and benchmarked against S independent single-source runs.

The level loop rides the same :class:`~repro.core.level_pipeline.LevelPipeline`
skeleton as the single-source engines: gather = the stacked frontier
columns, pull = ``bit_spmm``, update = the dense finalise (no pack/compact —
the frontier representation *is* the dense column block).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.level_pipeline import LevelPipeline, compose_step, run_levels
from repro.graphs import Graph, to_dense_bits
from repro.kernels import bit_spmm
from repro.kernels.ref import bit_spmm_ref

INF = np.int32(np.iinfo(np.int32).max)


class _MSState(NamedTuple):
    levels: jnp.ndarray  # (n, S) int32
    X: jnp.ndarray       # (n, S) int8 stacked frontier columns


def make_multi_source_bfs(g: Graph, n_sources: int, *,
                          use_kernel: bool = True,
                          max_levels: int | None = None) -> Callable:
    """Build jitted ``f(sources (S,) i32) -> levels (n, S) i32``."""
    n = g.n
    adj = jnp.asarray(to_dense_bits(g))      # (n, ceil(n/32)) u32, pull view
    S = n_sources
    spmm = bit_spmm if use_kernel else bit_spmm_ref
    max_lv = max_levels if max_levels is not None else n + 1

    def gather(s: _MSState):
        return adj, s.X

    def update(s: _MSState, pop, lvl) -> _MSState:
        new = (pop > 0) & (s.levels == INF)
        return _MSState(levels=jnp.where(new, lvl, s.levels),
                        X=new.astype(jnp.int8))

    pipe = LevelPipeline(step=compose_step(gather, spmm, update),
                         finalize=lambda s, lvl: s,
                         active=lambda s: (s.X != 0).any())

    def bfs(sources: jnp.ndarray) -> jnp.ndarray:
        sources = jnp.asarray(sources, dtype=jnp.int32)
        levels = jnp.full((n, S), INF, dtype=jnp.int32)
        levels = levels.at[sources, jnp.arange(S)].set(0)
        X = jnp.zeros((n, S), dtype=jnp.int8)
        X = X.at[sources, jnp.arange(S)].set(1)
        state, _ = run_levels(pipe, _MSState(levels, X), max_levels=max_lv)
        return state.levels

    return jax.jit(bfs)


def closeness_centrality(g: Graph, sources: np.ndarray, *,
                         use_kernel: bool = True) -> np.ndarray:
    """Approximate closeness centrality from a source sample (paper §7's
    target application for multi-source BFS)."""
    f = make_multi_source_bfs(g, len(sources), use_kernel=use_kernel)
    levels = np.asarray(f(jnp.asarray(sources)))     # (n, S)
    finite = levels != INF
    dist_sum = np.where(finite, levels, 0).sum(axis=0).astype(np.float64)
    reach = finite.sum(axis=0)
    with np.errstate(divide="ignore", invalid="ignore"):
        cc = np.where(dist_sum > 0, (reach - 1) / dist_sum, 0.0)
    return cc
