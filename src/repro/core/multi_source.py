"""Multi-source BFS as bit-SpMM on the MXU (paper §2 + §7 future work).

Stacking S frontiers column-wise turns the SpMSpV pull into an SpMM; on TPU
this is where the MXU path pays off (DESIGN §2.2): one 128×128 int8 MMA
resolves 128·128 Boolean dot products.  Used by the closeness-centrality
example and benchmarked against S independent single-source runs.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs import Graph, to_dense_bits
from repro.kernels import bit_spmm
from repro.kernels.ref import bit_spmm_ref

INF = np.int32(np.iinfo(np.int32).max)


def make_multi_source_bfs(g: Graph, n_sources: int, *,
                          use_kernel: bool = True,
                          max_levels: int | None = None) -> Callable:
    """Build jitted ``f(sources (S,) i32) -> levels (n, S) i32``."""
    n = g.n
    adj = jnp.asarray(to_dense_bits(g))      # (n, ceil(n/32)) u32, pull view
    S = n_sources
    spmm = bit_spmm if use_kernel else bit_spmm_ref
    max_lv = max_levels if max_levels is not None else n + 1

    def bfs(sources: jnp.ndarray) -> jnp.ndarray:
        sources = jnp.asarray(sources, dtype=jnp.int32)
        levels = jnp.full((n, S), INF, dtype=jnp.int32)
        levels = levels.at[sources, jnp.arange(S)].set(0)
        X = jnp.zeros((n, S), dtype=jnp.int8)
        X = X.at[sources, jnp.arange(S)].set(1)

        def cond(state):
            return state[2] & (state[3] < max_lv)

        def body(state):
            levels, X, _, lvl = state
            lvl = lvl + 1
            pop = spmm(adj, X)                       # (n, S) popcounts
            new = (pop > 0) & (levels == INF)
            levels = jnp.where(new, lvl, levels)
            X = new.astype(jnp.int8)
            return levels, X, new.any(), lvl

        state = (levels, X, jnp.bool_(True), jnp.int32(0))
        levels, *_ = jax.lax.while_loop(cond, body, state)
        return levels

    return jax.jit(bfs)


def closeness_centrality(g: Graph, sources: np.ndarray, *,
                         use_kernel: bool = True) -> np.ndarray:
    """Approximate closeness centrality from a source sample (paper §7's
    target application for multi-source BFS)."""
    f = make_multi_source_bfs(g, len(sources), use_kernel=use_kernel)
    levels = np.asarray(f(jnp.asarray(sources)))     # (n, S)
    finite = levels != INF
    dist_sum = np.where(finite, levels, 0).sum(axis=0).astype(np.float64)
    reach = finite.sum(axis=0)
    with np.errstate(divide="ignore", invalid="ignore"):
        cc = np.where(dist_sum > 0, (reach - 1) / dist_sum, 0.0)
    return cc
