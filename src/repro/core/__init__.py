from .bvss import (BVSS, BVSSDevice, ShardedBVSS, build_bvss,
                   build_sharded_bvss, build_sharded_weight_plane,
                   build_weight_plane, to_device, weight_plane_to_device)
from .bfs import (BlestProblem, ENGINES, INF, make_engine, reference_bfs,
                  pull_vss_jnp)
from . import ordering

__all__ = ["BVSS", "BVSSDevice", "ShardedBVSS", "build_bvss",
           "build_sharded_bvss", "build_sharded_weight_plane",
           "build_weight_plane", "to_device", "weight_plane_to_device",
           "BlestProblem", "ENGINES", "INF", "make_engine", "reference_bfs",
           "pull_vss_jnp", "ordering"]
