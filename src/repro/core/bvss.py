"""Binarised Virtual Slice Sets (BVSS) — the paper's core data structure (§3).

Host-side construction is vectorised NumPy; ``to_device`` hands the arrays to
JAX.  Layout (σ = slice width in bits, LANES = 32 words per VSS row-group,
slices_per_word = 32 // σ, τ = LANES * slices_per_word):

* column *intervals* of σ consecutive columns of A^T form *slice sets*;
* a (row u, interval i) pair with ≥1 edge is a *slice*, its σ-bit mask holds
  A^T[u, σi : σ(i+1)];
* each slice set is split into *virtual* slice sets of ≤ τ slices (the unit
  of work), the last VSS of a set is padded to τ with zero masks / dummy rows;
* within a VSS, slices sorted by row id are laid out column-major over
  (slot, lane): slice k lives in lane ``k % 32``, sub-word slot ``k // 32``
  — the paper's Fig. 2(c) layout, which maximises update coalescing.

On TPU there are no warps: a "lane" here is one 32-bit vector lane, and one
(8,128) vreg holds 8 VSS mask rows; every 32-bit AND+popcount resolves
``slices_per_word`` slice/frontier dot products — the adaptation of the
paper's all-outputs-useful TC layout (Fig. 2(c), §4.1).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

from repro.errors import ConfigError, GraphValidationError
from repro.graphs import Graph

LANES = 32  # 32-bit words per VSS row-group (paper: WARP_SIZE)


@dataclasses.dataclass(frozen=True)
class BVSS:
    """Host-side BVSS arrays + structural metadata."""

    n: int                      # number of vertices
    m: int                      # number of edges
    sigma: int                  # slice width (bits)
    tau: int                    # slices per VSS = LANES * (32 // sigma)
    n_sets: int                 # ceil(n / sigma) real slice sets
    num_vss: int
    num_slices: int             # unpadded slices
    # static arrays (paper §3.1)
    masks: np.ndarray           # (num_vss, LANES) uint32; slot j of word l
                                #   = mask of slice k = j*LANES + l
    row_ids: np.ndarray         # (num_vss, 32//sigma, LANES) int32; dummy = n
    real_ptrs: np.ndarray       # (n_sets + 1,) int32: slice set -> VSS range
    virtual_to_real: np.ndarray  # (num_vss,) int32

    @property
    def slices_per_word(self) -> int:
        return 32 // self.sigma

    @property
    def n_frontier_words(self) -> int:
        """Frontier bit-array length in uint32 words (σ-bit set granularity)."""
        return (self.n_sets * self.sigma + 31) // 32

    @property
    def max_vss_per_set(self) -> int:
        """Largest VSS count of any slice set — the static expansion factor
        of the push phase (each pushing vertex enqueues every VSS of its own
        set, DESIGN §2.8)."""
        if self.n_sets == 0:
            return 1
        return max(int(np.diff(self.real_ptrs).max()), 1)

    # ---------------- analytics (paper Tables 1 & 4) ----------------
    def compression_ratio(self) -> float:
        """m / (num_slices * σ): fraction of set bits in unpadded masks."""
        if self.num_slices == 0:
            return 1.0
        return self.m / (self.num_slices * self.sigma)

    def connectivity_bits(self) -> int:
        return self.num_slices * self.sigma

    def update_divergence(self) -> float:
        """Paper §3.2.1: mean over VSSs of the mean per-slot std of live row ids."""
        spw = self.slices_per_word
        sig = self.sigma
        sub_mask = np.uint32((1 << sig) - 1)
        # sub[v, j, l] = mask of slice (slot j, lane l)
        shifts = (np.arange(spw, dtype=np.uint32) * sig)[None, :, None]
        sub = (self.masks[:, None, :] >> shifts) & sub_mask
        live = sub != 0
        rows = self.row_ids.astype(np.float64)
        cnt = live.sum(axis=2)                                   # (v, j)
        s1 = np.where(live, rows, 0.0).sum(axis=2)
        s2 = np.where(live, rows * rows, 0.0).sum(axis=2)
        with np.errstate(invalid="ignore", divide="ignore"):
            mean = s1 / cnt
            var = np.maximum(s2 / cnt - mean * mean, 0.0)
            col_div = np.sqrt(var)                               # (v, j)
        nonempty = cnt > 0
        set_cnt = nonempty.sum(axis=1)
        set_div = np.where(set_cnt > 0,
                           np.where(nonempty, col_div, 0.0).sum(axis=1)
                           / np.maximum(set_cnt, 1), 0.0)
        alive = set_cnt > 0
        return float(set_div[alive].mean()) if alive.any() else 0.0

    def memory_bytes(self) -> dict[str, int]:
        """Table-4 style footprint breakdown (bytes).

        ``push`` is the hybrid engine's scatter-side working set at the
        DEFAULT auto-mode cap (DESIGN §2.8): the compacted frontier-vertex
        queue plus the (cap × max_vss_per_set) expanded (VSS id, bit) pairs
        each push level materialises.  It is a sub-term of ``dynamic`` —
        ``total`` stays ``bvss + dynamic + level``."""
        static = (self.masks.nbytes + self.row_ids.nbytes
                  + self.real_ptrs.nbytes + self.virtual_to_real.nbytes)
        pq = max(128, self.n // 8)  # default auto-mode push cap
        push = 4 * (pq + 1) + 2 * 4 * pq * self.max_vss_per_set
        dynamic = (2 * 4 * (self.num_vss + 1)
                   + 2 * 4 * self.n_frontier_words + push)
        level = 4 * (self.n + 1)
        return {"bvss": static, "dynamic": dynamic, "push": push,
                "level": level, "total": static + dynamic + level}

    # ---------------- validation helpers ----------------
    def reconstruct_edges(self) -> tuple[np.ndarray, np.ndarray]:
        """Recover (src, dst) edge lists: bit b of slice (u, i) ⇒ edge (σi+b) → u."""
        spw, sig = self.slices_per_word, self.sigma
        shifts = (np.arange(spw, dtype=np.uint32) * sig)[None, :, None]
        sub = (self.masks[:, None, :] >> shifts) & np.uint32((1 << sig) - 1)
        vss_idx, slot, lane = np.nonzero(sub)
        sub_v = sub[vss_idx, slot, lane]
        rows = self.row_ids[vss_idx, slot, lane].astype(np.int64)
        sets = self.virtual_to_real[vss_idx].astype(np.int64)
        src_l, dst_l = [], []
        for b in range(sig):
            has = (sub_v >> np.uint32(b)) & 1 != 0
            src_l.append(sets[has] * sig + b)
            dst_l.append(rows[has])
        return np.concatenate(src_l), np.concatenate(dst_l)


def build_bvss(g: Graph, sigma: int = 8) -> BVSS:
    if not (1 <= sigma <= 32 and 32 % sigma == 0):
        raise GraphValidationError(
            f"sigma must be a divisor of 32 in [1, 32], got {sigma!r}")
    spw = 32 // sigma
    tau = LANES * spw
    n, m = g.n, g.m
    n_sets = (n + sigma - 1) // sigma

    t_indptr, t_indices = g.t_csr
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(t_indptr))
    cols = t_indices.astype(np.int64)
    interval = cols // sigma
    bit = (cols % sigma).astype(np.uint32)

    # unique (interval, row) pairs, interval-major / row-ascending
    keys = interval * n + rows
    ukeys, inverse = np.unique(keys, return_inverse=True)
    num_slices = len(ukeys)
    slice_mask = np.zeros(num_slices, dtype=np.uint32)
    np.bitwise_or.at(slice_mask, inverse, np.uint32(1) << bit)
    slice_interval = (ukeys // n).astype(np.int64)
    slice_row = (ukeys % n).astype(np.int32)

    # slices per set -> VSS counts -> realPtrs
    set_counts = np.bincount(slice_interval, minlength=n_sets)
    vss_counts = (set_counts + tau - 1) // tau
    real_ptrs = np.zeros(n_sets + 1, dtype=np.int32)
    real_ptrs[1:] = np.cumsum(vss_counts)
    num_vss = int(real_ptrs[-1])
    virtual_to_real = np.repeat(np.arange(n_sets, dtype=np.int32), vss_counts)

    # placement of each slice
    set_starts = np.zeros(n_sets + 1, dtype=np.int64)
    np.cumsum(set_counts, out=set_starts[1:])
    local = np.arange(num_slices, dtype=np.int64) - set_starts[slice_interval]
    vss = real_ptrs[slice_interval].astype(np.int64) + local // tau
    k = local % tau
    lane = (k % LANES).astype(np.int64)
    slot = (k // LANES).astype(np.int64)

    masks = np.zeros((num_vss, LANES), dtype=np.uint32)
    np.bitwise_or.at(masks.reshape(-1), vss * LANES + lane,
                     slice_mask << (slot * sigma).astype(np.uint32))
    row_ids = np.full((num_vss, spw, LANES), n, dtype=np.int32)  # dummy = n
    row_ids[vss, slot, lane] = slice_row

    return BVSS(n=n, m=m, sigma=sigma, tau=tau, n_sets=n_sets,
                num_vss=num_vss, num_slices=num_slices, masks=masks,
                row_ids=row_ids, real_ptrs=real_ptrs,
                virtual_to_real=virtual_to_real)


# ---------------------------------------------------------------------------
# Weight plane: per-edge float weights aligned with the bit slices
# (the min-plus / weighted-verb operand, DESIGN §2.9)
# ---------------------------------------------------------------------------
def build_weight_plane(g: Graph, weights: np.ndarray,
                       sigma: int = 8) -> np.ndarray:
    """Lay per-edge weights out exactly like the BVSS mask bits.

    ``weights`` is one float per CSR edge of ``g`` (``g.indices`` order).
    Returns a (num_vss, 32//σ, LANES, σ) float32 plane where entry
    ``[v, slot, lane, i]`` is the weight of the edge encoded by bit σ·slot+i
    of ``masks[v, lane]`` — i.e. the same (slot, lane) slice placement
    :func:`build_bvss` computes — and +inf wherever that bit is unset (the
    tropical-semiring annihilator, so masked and missing edges agree).
    Parallel edges (if any survive ingress) keep the minimum weight.
    """
    if not (1 <= sigma <= 32 and 32 % sigma == 0):
        raise GraphValidationError(
            f"sigma must be a divisor of 32 in [1, 32], got {sigma!r}")
    spw = 32 // sigma
    tau = LANES * spw
    n = g.n
    n_sets = (n + sigma - 1) // sigma

    t_indptr, t_indices = g.t_csr
    rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(t_indptr))
    cols = t_indices.astype(np.int64)
    # t_csr edge j is original CSR edge argsort(indices)[j] (stable sort by
    # destination) — permute the weights into the same transposed order
    w_t = np.asarray(weights, dtype=np.float32)[
        np.argsort(g.indices, kind="stable")]
    interval = cols // sigma
    bit = (cols % sigma).astype(np.int64)

    # identical slice placement to build_bvss
    keys = interval * n + rows
    ukeys, inverse = np.unique(keys, return_inverse=True)
    num_slices = len(ukeys)
    slice_interval = (ukeys // n).astype(np.int64)
    set_counts = np.bincount(slice_interval, minlength=n_sets)
    vss_counts = (set_counts + tau - 1) // tau
    real_ptrs = np.zeros(n_sets + 1, dtype=np.int64)
    real_ptrs[1:] = np.cumsum(vss_counts)
    num_vss = int(real_ptrs[-1])
    set_starts = np.zeros(n_sets + 1, dtype=np.int64)
    np.cumsum(set_counts, out=set_starts[1:])
    local = np.arange(num_slices, dtype=np.int64) - set_starts[slice_interval]
    vss = real_ptrs[slice_interval] + local // tau
    k = local % tau
    lane = k % LANES
    slot = k // LANES

    plane = np.full((num_vss, spw, LANES, sigma), np.inf, dtype=np.float32)
    np.minimum.at(plane, (vss[inverse], slot[inverse], lane[inverse], bit),
                  w_t)
    return plane


def build_sharded_weight_plane(g: Graph, weights: np.ndarray,
                               sb: ShardedBVSS) -> np.ndarray:
    """Row-sharded twin of :func:`build_weight_plane`: one weight plane per
    shard of ``sb``, built over the same destination-range subgraphs
    :func:`build_sharded_bvss` committed (so slice placement matches the
    sharded masks bit for bit), padded to the common VSS count with +inf.
    Returns (D, num_vss_pad, 32//σ, LANES, σ) float32."""
    from repro.graphs import from_edges, src_of_edges

    n = g.n
    sigma = sb.sigma
    spw = sb.slices_per_word
    src = src_of_edges(g).astype(np.int64)
    dst = g.indices.astype(np.int64)
    w = np.asarray(weights, dtype=np.float32)
    D, rps = sb.n_shards, sb.rows_per_shard
    plane = np.full((D, sb.num_vss_pad, spw, LANES, sigma), np.inf,
                    dtype=np.float32)
    for d in range(D):
        lo, hi = d * rps, min((d + 1) * rps, n)
        keep = (dst >= lo) & (dst < hi)
        if not keep.any():
            continue
        # from_edges(dedup=True) emits edges in ascending (src·n + dst)
        # key order — reduce the kept weights into that order (min merges
        # parallel edges exactly like the mask OR does)
        key = src[keep] * n + (dst[keep] - lo)
        uk, inv = np.unique(key, return_inverse=True)
        wsub = np.full(len(uk), np.inf, dtype=np.float32)
        np.minimum.at(wsub, inv, w[keep])
        sub = from_edges(n, src[keep], dst[keep] - lo,
                         dedup=True, drop_loops=False)
        pd = build_weight_plane(sub, wsub, sigma=sigma)
        plane[d, :pd.shape[0]] = pd
    return plane


def weight_plane_to_device(plane: np.ndarray, mesh=None, axis: str = "data"):
    """Commit a weight plane to device, appending the +inf dummy-VSS row
    that mirrors the all-zero dummy mask row ``to_device`` /
    ``shard_to_device`` append (padded queue entries relax nothing)."""
    import jax
    import jax.numpy as jnp

    if plane.ndim == 4:                       # single device: (V, spw, L, σ)
        full = np.concatenate(
            [plane, np.full((1,) + plane.shape[1:], np.inf, np.float32)],
            axis=0)
        return jnp.asarray(full)
    D = plane.shape[0]                        # sharded: (D, V, spw, L, σ)
    full = np.concatenate(
        [plane, np.full((D, 1) + plane.shape[2:], np.inf, np.float32)],
        axis=1)
    if mesh is not None:
        from repro.distributed.bfs_dist import problem_sharding
        return jax.device_put(full, problem_sharding(mesh, axis))
    return jnp.asarray(full)


# ---------------------------------------------------------------------------
# Row-sharded BVSS (mesh-native build path, DESIGN §2.4)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardedBVSS:
    """Row-partitioned BVSS: shard d owns destination rows
    [d·rows_per_shard, (d+1)·rows_per_shard), i.e. the slices that pull INTO
    its vertex range.  Row ids are LOCAL (dummy = rows_per_shard); slice-set
    ids stay GLOBAL, because columns (frontier bits) are global — the σ-bit
    frontier words are the one all-gathered array.  All shards are padded to
    a common VSS count so one SPMD program serves every shard."""

    n: int                       # global vertex count
    m: int                       # global edge count
    sigma: int
    n_shards: int
    rows_per_shard: int          # 32-aligned so row blocks = frontier words
    num_vss_pad: int             # per-shard VSS count (padded to common max)
    n_sets: int                  # GLOBAL slice sets (columns)
    masks: np.ndarray            # (D, num_vss_pad, LANES) uint32
    row_ids: np.ndarray          # (D, num_vss_pad, spw, LANES) int32 LOCAL
    virtual_to_real: np.ndarray  # (D, num_vss_pad) int32 GLOBAL set ids
    vss_start: np.ndarray        # (D, n + 1) int32 GLOBAL vertex -> LOCAL
    vss_end: np.ndarray          #   VSS range [start, end) of the shard's
                                 #   slice sets for the vertex's own set;
                                 #   dummy vertex n maps to the empty range
    max_vss_per_set: int         # static push expansion factor (max shard)

    @property
    def slices_per_word(self) -> int:
        return 32 // self.sigma

    @property
    def n_frontier_words(self) -> int:
        """Gathered (global) frontier length in uint32 words: the all-gather
        of every shard's rows_per_shard//32 local words.  Covers n_sets·σ
        bits because rows_per_shard·D ≥ n rounded up to 32."""
        return self.n_shards * (self.rows_per_shard // 32)


def build_sharded_bvss(g: Graph, n_shards: "int | tuple[int, int]",
                       sigma: int = 8) -> "ShardedBVSS | ShardedBVSS2D":
    """Row-partition ``g`` into ``n_shards`` rectangular (local rows ×
    global columns) BVSS blocks (absorbs the old distributed ``shard_bvss``).

    Each shard's block is built by :func:`build_bvss` over the subgraph of
    edges whose DESTINATION lands in the shard's row range, destinations
    relabelled locally and sources (columns / frontier ids) kept global.

    A ``(rows, cols)`` tuple selects the 2-D row × column partition
    instead (:func:`build_sharded_bvss_2d`): device (i, j) owns the slices
    pulling its row block from its column block of frontier words."""
    if isinstance(n_shards, tuple):
        rows, cols = n_shards
        return build_sharded_bvss_2d(g, rows, cols, sigma=sigma)
    from repro.graphs import from_edges, src_of_edges

    n = g.n
    rows_per_shard = -(-n // n_shards)
    rows_per_shard = ((rows_per_shard + 31) // 32) * 32  # align frontier words
    spw = 32 // sigma
    src = src_of_edges(g)
    dst = g.indices.astype(np.int64)
    per_shard = []
    for d in range(n_shards):
        lo, hi = d * rows_per_shard, min((d + 1) * rows_per_shard, n)
        keep = (dst >= lo) & (dst < hi)
        # drop_loops=False: local dst ids numerically colliding with global
        # src ids are NOT self loops
        sub = from_edges(n, src[keep], dst[keep] - lo,
                         dedup=True, drop_loops=False)
        per_shard.append(build_bvss(sub, sigma=sigma))
    num_vss_pad = max(max(b.num_vss for b in per_shard), 1)
    D = n_shards
    masks = np.zeros((D, num_vss_pad, LANES), np.uint32)
    row_ids = np.full((D, num_vss_pad, spw, LANES), rows_per_shard, np.int32)
    # pad VSS entries keep set id 0: their masks are all-zero, so a level
    # whose frontier touches set 0 enqueues them as exact no-op pulls
    v2r = np.zeros((D, num_vss_pad), np.int32)
    # per-shard GLOBAL vertex -> LOCAL VSS range: columns are global in
    # every shard block, so each per-shard real_ptrs spans all n_sets and
    # the map mirrors to_device's vss_of_vertex_start/end per shard
    vss_start = np.zeros((D, n + 1), np.int32)
    vss_end = np.zeros((D, n + 1), np.int32)
    verts = np.arange(n, dtype=np.int64)
    sets = verts // sigma
    for d, b in enumerate(per_shard):
        vss_start[d, :n] = b.real_ptrs[sets]
        vss_end[d, :n] = b.real_ptrs[sets + 1]
        if b.num_vss == 0:
            continue
        masks[d, :b.num_vss] = b.masks
        rid = b.row_ids.copy()
        rid[rid == b.n] = rows_per_shard           # dummy -> local dummy
        row_ids[d, :b.num_vss] = np.minimum(rid, rows_per_shard)
        v2r[d, :b.num_vss] = b.virtual_to_real
    return ShardedBVSS(n=n, m=g.m, sigma=sigma, n_shards=D,
                       rows_per_shard=rows_per_shard,
                       num_vss_pad=num_vss_pad,
                       n_sets=(n + sigma - 1) // sigma,
                       masks=masks, row_ids=row_ids, virtual_to_real=v2r,
                       vss_start=vss_start, vss_end=vss_end,
                       max_vss_per_set=max(
                           max(b.max_vss_per_set for b in per_shard), 1))


# ---------------------------------------------------------------------------
# 2-D (row × column) sharded BVSS (butterfly partition, DESIGN §2.4)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardedBVSS2D:
    """2-D partitioned BVSS: device (i, j) of a ``rows × cols`` mesh owns
    the slices pulling its ROW block of destinations from its COLUMN block
    of frontier words.

    The column partition INTERLEAVES inside row blocks: column block j
    owns, within every row block i, the sources
    ``[i·rps + j·cpb, i·rps + (j+1)·cpb)`` where ``cpb = rps / cols``.
    That makes a row block's fresh frontier words split into ``cols``
    contiguous word segments (``rps`` is aligned to ``32·cols``), so the
    per-level exchange along the row axis moves exactly one segment per
    device — per-device volume shrinks by ``cols`` vs the flat 1-D gather.
    Source ids are relabelled to the column block's LOCAL space
    ``local(v) = (v // rps)·cpb + (v mod rps) − j·cpb`` of size
    ``rows · cpb``; destination rows are LOCAL to the row block (dummy =
    ``rps``).  Blocks stack row-major (block d = i·cols + j) and are
    padded to a common VSS count so one SPMD program serves all of them.
    ``rows >= cols`` is required so the local column space covers a row
    block (``rows·cpb >= rps``) — checked at build."""

    n: int
    m: int
    sigma: int
    rows: int
    cols: int
    rows_per_shard: int          # aligned to 32·cols
    cols_per_block: int          # cpb = rows_per_shard // cols
    num_vss_pad: int             # per-block VSS count (padded to common max)
    n_sets_local: int            # LOCAL slice sets = rows·cpb / sigma
    masks: np.ndarray            # (rows·cols, num_vss_pad, LANES) uint32
    row_ids: np.ndarray          # (rows·cols, num_vss_pad, spw, LANES) LOCAL
    virtual_to_real: np.ndarray  # (rows·cols, num_vss_pad) LOCAL set ids
    max_vss_per_set: int

    @property
    def n_blocks(self) -> int:
        return self.rows * self.cols

    @property
    def slices_per_word(self) -> int:
        return 32 // self.sigma

    @property
    def n_frontier_words_local(self) -> int:
        """Per-device frontier words: the device's full COLUMN block,
        ``rows`` segments of ``words_per_colseg`` words each."""
        return self.rows * self.cols_per_block // 32

    @property
    def words_per_colseg(self) -> int:
        """Words one row block contributes to one column block per level —
        the unit the butterfly exchange moves."""
        return self.cols_per_block // 32


def build_sharded_bvss_2d(g: Graph, rows: int, cols: int, sigma: int = 8
                          ) -> ShardedBVSS2D:
    """Partition ``g`` into ``rows × cols`` BVSS blocks (see
    :class:`ShardedBVSS2D` for the ownership contract)."""
    from repro.graphs import from_edges, src_of_edges

    if rows < 1 or cols < 1:
        raise ConfigError(f"2-D shard shape ({rows}, {cols}) must be "
                          f"positive")
    if rows < cols:
        raise ConfigError(
            f"2-D BVSS partition needs rows >= cols, got ({rows}, {cols})"
            f" — the interleaved column blocks must cover a row block")
    n = g.n
    rps = -(-n // rows)
    align = 32 * cols  # column segments land on frontier-word boundaries
    rps = ((rps + align - 1) // align) * align
    cpb = rps // cols
    n_loc = rows * cpb              # local column (source) space per block
    spw = 32 // sigma
    src = src_of_edges(g).astype(np.int64)
    dst = g.indices.astype(np.int64)
    soff = src % rps                # offset of each source in its row block
    sblk = src // rps               # row block each source lives in
    per_block: list[BVSS] = []
    for i in range(rows):
        lo, hi = i * rps, min((i + 1) * rps, n)
        in_row = (dst >= lo) & (dst < hi)
        for j in range(cols):
            keep = in_row & (soff // cpb == j)
            lsrc = sblk[keep] * cpb + (soff[keep] - j * cpb)
            # drop_loops=False: relabelled ids colliding are not self loops
            sub = from_edges(n_loc, lsrc, dst[keep] - lo,
                             dedup=True, drop_loops=False)
            per_block.append(build_bvss(sub, sigma=sigma))
    num_vss_pad = max(max(b.num_vss for b in per_block), 1)
    D = rows * cols
    masks = np.zeros((D, num_vss_pad, LANES), np.uint32)
    row_ids = np.full((D, num_vss_pad, spw, LANES), rps, np.int32)
    # pad VSS entries keep set id 0: all-zero masks -> exact no-op pulls
    v2r = np.zeros((D, num_vss_pad), np.int32)
    for d, b in enumerate(per_block):
        if b.num_vss == 0:
            continue
        masks[d, :b.num_vss] = b.masks
        rid = b.row_ids.copy()
        rid[rid == b.n] = rps                      # dummy -> local dummy
        row_ids[d, :b.num_vss] = np.minimum(rid, rps)
        v2r[d, :b.num_vss] = b.virtual_to_real
    return ShardedBVSS2D(n=n, m=g.m, sigma=sigma, rows=rows, cols=cols,
                         rows_per_shard=rps, cols_per_block=cpb,
                         num_vss_pad=num_vss_pad,
                         n_sets_local=n_loc // sigma,
                         masks=masks, row_ids=row_ids, virtual_to_real=v2r,
                         max_vss_per_set=max(
                             max(b.max_vss_per_set for b in per_block), 1))


class ShardedBVSSDevice(NamedTuple):
    """Per-shard device views of a :class:`ShardedBVSS` (a pytree).  The
    leading axis is the shard axis; inside ``shard_map`` each device sees
    its (1, ...) block and strips it to the same (masks, row_ids,
    virtual_to_real) surface the single-device engines consume.  One
    all-zero dummy VSS (index ``num_vss_pad``) is appended per shard, its
    rows mapped to the local dummy level slot ``rows_per_shard``."""

    masks: "jnp.ndarray"            # (D, num_vss_pad + 1, LANES) uint32
    row_ids: "jnp.ndarray"          # (D, num_vss_pad + 1, spw, LANES) int32
    virtual_to_real: "jnp.ndarray"  # (D, num_vss_pad + 1) int32
    # GLOBAL vertex -> LOCAL VSS range (push expansion); named like the
    # BVSSDevice fields so the hybrid step reads one surface in both modes
    vss_of_vertex_start: "jnp.ndarray"  # (D, n + 1) int32
    vss_of_vertex_end: "jnp.ndarray"    # (D, n + 1) int32


def shard_to_device(sb: ShardedBVSS, mesh=None, axis: str = "data"
                    ) -> ShardedBVSSDevice:
    """Append the per-shard dummy VSS and (when ``mesh`` is given) commit
    the stacked arrays with their row-partition sharding so every engine
    build and serving call starts from already-placed shards."""
    import jax
    import jax.numpy as jnp

    D = sb.n_shards
    spw = sb.slices_per_word
    masks = np.concatenate(
        [sb.masks, np.zeros((D, 1, LANES), np.uint32)], axis=1)
    row_ids = np.concatenate(
        [sb.row_ids,
         np.full((D, 1, spw, LANES), sb.rows_per_shard, np.int32)], axis=1)
    v2r = np.concatenate([sb.virtual_to_real, np.zeros((D, 1), np.int32)],
                         axis=1)
    if mesh is not None:
        from repro.distributed.bfs_dist import problem_sharding
        sharding = problem_sharding(mesh, axis)

        def put(x):
            return jax.device_put(x, sharding)
    else:
        put = jnp.asarray
    return ShardedBVSSDevice(masks=put(masks), row_ids=put(row_ids),
                             virtual_to_real=put(v2r),
                             vss_of_vertex_start=put(sb.vss_start),
                             vss_of_vertex_end=put(sb.vss_end))


def shard_to_device_2d(sb: ShardedBVSS2D, mesh=None) -> ShardedBVSSDevice:
    """2-D twin of :func:`shard_to_device`: append the per-block dummy VSS
    and commit the row-major block stack with both mesh axes on dim 0.
    The 2-D engines are pull-only (DESIGN §2.4), so the push-phase
    vertex -> VSS maps are empty placeholders that keep the
    :class:`ShardedBVSSDevice` surface uniform."""
    import jax
    import jax.numpy as jnp

    D = sb.n_blocks
    spw = sb.slices_per_word
    masks = np.concatenate(
        [sb.masks, np.zeros((D, 1, LANES), np.uint32)], axis=1)
    row_ids = np.concatenate(
        [sb.row_ids,
         np.full((D, 1, spw, LANES), sb.rows_per_shard, np.int32)], axis=1)
    v2r = np.concatenate([sb.virtual_to_real, np.zeros((D, 1), np.int32)],
                         axis=1)
    vss_start = np.zeros((D, 1), np.int32)
    vss_end = np.zeros((D, 1), np.int32)
    if mesh is not None:
        from repro.distributed.bfs_dist import problem_sharding
        sharding = problem_sharding(mesh)

        def put(x):
            return jax.device_put(x, sharding)
    else:
        put = jnp.asarray
    return ShardedBVSSDevice(masks=put(masks), row_ids=put(row_ids),
                             virtual_to_real=put(v2r),
                             vss_of_vertex_start=put(vss_start),
                             vss_of_vertex_end=put(vss_end))


class BVSSDevice(NamedTuple):
    """Device-resident BVSS (a pytree). One extra all-zero dummy VSS is
    appended so padded queue entries are harmless, and the level array gets
    an extra slot for dummy row id ``n``."""

    masks: "jnp.ndarray"            # (num_vss + 1, LANES) uint32
    row_ids: "jnp.ndarray"          # (num_vss + 1, spw, LANES) int32
    virtual_to_real: "jnp.ndarray"  # (num_vss + 1,) int32
    real_ptrs: "jnp.ndarray"        # (n_sets + 1,) int32
    vss_of_vertex_start: "jnp.ndarray"  # (n + 1,) int32 = real_ptrs[v // σ]
    vss_of_vertex_end: "jnp.ndarray"


def to_device(b: BVSS) -> BVSSDevice:
    import jax.numpy as jnp

    masks = np.concatenate([b.masks, np.zeros((1, LANES), np.uint32)], axis=0)
    row_ids = np.concatenate(
        [b.row_ids, np.full((1, b.slices_per_word, LANES), b.n, np.int32)],
        axis=0)
    v2r = np.concatenate([b.virtual_to_real, np.zeros(1, np.int32)])
    verts = np.arange(b.n, dtype=np.int64)
    sets = verts // b.sigma
    start = b.real_ptrs[sets].astype(np.int32)
    end = b.real_ptrs[sets + 1].astype(np.int32)
    # dummy vertex n: empty VSS range so a spurious mark enqueues nothing
    start = np.concatenate([start, np.zeros(1, np.int32)])
    end = np.concatenate([end, np.zeros(1, np.int32)])
    return BVSSDevice(
        masks=jnp.asarray(masks),
        row_ids=jnp.asarray(row_ids),
        virtual_to_real=jnp.asarray(v2r),
        real_ptrs=jnp.asarray(b.real_ptrs),
        vss_of_vertex_start=jnp.asarray(start),
        vss_of_vertex_end=jnp.asarray(end),
    )
