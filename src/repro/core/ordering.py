"""Graph reordering strategies (paper §3.2).

* :func:`jaccard_windows` — Algorithm 1 (JaccardWithWindows): windowed greedy
  Jaccard clustering of *columns* (vertices as sources) so that vertices with
  common out-neighbours land in the same σ-wide slice set.
* :func:`shingle_order` — cheap similarity pre-pass (stand-in for Gorder [42],
  which is proprietary-complex; shingle/minhash ordering groups vertices with
  common neighbours and is the standard lightweight alternative).  Documented
  deviation: the paper uses Gorder as the pre-pass; we use shingle ordering,
  which optimises the same objective (co-locating Jaccard-similar vertices).
* :func:`rcm` — bandwidth-reducing Reverse Cuthill–McKee for non-social
  graphs (scipy implementation).
* :func:`is_social_like` — the paper's heavy-tail + power-law classifier.
* :func:`auto_order` — the "One Ordering Decision to Pull them All" policy.

All functions return a *permutation* ``perm`` such that the new id of old
vertex v is ``perm[v]`` (apply with ``graph.permute_fast(perm)``).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp
from scipy.sparse.csgraph import reverse_cuthill_mckee

from repro.graphs import Graph, src_of_edges


def natural_order(g: Graph) -> np.ndarray:
    return np.arange(g.n, dtype=np.int64)


def random_order(g: Graph, seed: int = 0) -> np.ndarray:
    return np.random.default_rng(seed).permutation(g.n).astype(np.int64)


def degree_order(g: Graph, descending: bool = True) -> np.ndarray:
    key = g.out_degree + g.in_degree
    order = np.argsort(-key if descending else key, kind="stable")
    perm = np.empty(g.n, dtype=np.int64)
    perm[order] = np.arange(g.n)
    return perm


def rcm(g: Graph) -> np.ndarray:
    """Reverse Cuthill–McKee on the symmetrised adjacency (paper §3.2.1)."""
    gs = g.symmetrized
    mat = sp.csr_matrix(
        (np.ones(gs.m, dtype=np.int8), gs.indices, gs.indptr), shape=(g.n, g.n))
    order = np.asarray(reverse_cuthill_mckee(mat, symmetric_mode=True))
    perm = np.empty(g.n, dtype=np.int64)
    perm[order] = np.arange(g.n)
    return perm


def shingle_order(g: Graph, seed: int = 0) -> np.ndarray:
    """Minhash/shingle ordering: sort vertices by the minimum (hashed)
    out-neighbour id.  Vertices sharing neighbours get equal shingles and
    become adjacent — a cheap proxy for Gorder's windowed common-neighbour
    objective."""
    rng = np.random.default_rng(seed)
    h = rng.permutation(g.n).astype(np.int64)
    src = src_of_edges(g)
    hashed = h[g.indices.astype(np.int64)]
    shingle = np.full(g.n, g.n, dtype=np.int64)
    np.minimum.at(shingle, src, hashed)
    # secondary shingle breaks ties among vertices with the same min-hash
    h2 = rng.permutation(g.n).astype(np.int64)
    hashed2 = h2[g.indices.astype(np.int64)]
    shingle2 = np.full(g.n, g.n, dtype=np.int64)
    np.minimum.at(shingle2, src, hashed2)
    order = np.lexsort((shingle2, shingle))
    perm = np.empty(g.n, dtype=np.int64)
    perm[order] = np.arange(g.n)
    return perm


def jaccard_windows(g: Graph, sigma: int = 8, w: int = 1024, *,
                    pre_order: np.ndarray | None = None,
                    seed: int = 0) -> np.ndarray:
    """Algorithm 1 (JaccardWithWindows), vectorised.

    Columns (vertices) are clustered greedily inside disjoint windows of
    size ``w``; each cluster of σ vertices becomes one slice set.  Per
    selection we need |N(j) ∩ U| for all remaining candidates j — computed
    incrementally with one sparse matvec per accepted vertex, giving
    O(w · δ) work per selection instead of O(w² · δ) per window.
    ``N(v)`` is the *out*-neighbourhood (the set of rows whose slice the
    column v occupies in A^T).
    """
    assert w % sigma == 0
    n = g.n
    if pre_order is not None:
        g_work = g.permute_fast(pre_order)
    else:
        g_work = g
        pre_order = np.arange(n, dtype=np.int64)

    # CSR over out-neighbours of the (pre-ordered) graph
    A = sp.csr_matrix((np.ones(g_work.m, dtype=np.int32),
                       g_work.indices.astype(np.int64), g_work.indptr),
                      shape=(n, n))
    deg = np.diff(g_work.indptr).astype(np.int64)

    perm_work = np.empty(n, dtype=np.int64)  # new id of pre-ordered vertex
    for ws in range(0, n, w):
        we = min(ws + w, n)
        win = np.arange(ws, we, dtype=np.int64)
        L = len(win)
        S = A[win]                      # (L, n) out-neighbourhoods
        ST = S.T.tocsr()                # (n, L): column v -> windows rows
        remaining = np.ones(L, dtype=bool)
        inter = np.zeros(L, dtype=np.int64)     # |N(j) ∩ U| for current cluster
        in_U = np.zeros(n, dtype=bool)
        pos = ws
        n_clusters = (L + sigma - 1) // sigma
        for _c in range(n_clusters):
            if not remaining.any():
                break
            # seed: first remaining vertex (paper: arbitrary seed)
            j_star = int(np.argmax(remaining))
            remaining[j_star] = False
            perm_work[win[j_star]] = pos
            pos += 1
            # U <- N(j*) ; update intersections for new members of U
            inter[:] = 0
            in_U[:] = False
            new_members = S.indices[S.indptr[j_star]:S.indptr[j_star + 1]]
            if len(new_members):
                in_U[new_members] = True
                inter += np.asarray(ST[new_members].sum(axis=0)).ravel()
            u_size = int(in_U.sum())
            for _r in range(sigma - 1):
                if not remaining.any():
                    break
                union = deg[win] + u_size - inter
                score = np.where(remaining & (union > 0),
                                 inter / np.maximum(union, 1), -1.0)
                # prefer genuinely similar candidates; fall back to any
                j_dag = int(np.argmax(score))
                if not remaining[j_dag]:
                    break
                remaining[j_dag] = False
                perm_work[win[j_dag]] = pos
                pos += 1
                nb = S.indices[S.indptr[j_dag]:S.indptr[j_dag + 1]]
                fresh = nb[~in_U[nb]]
                if len(fresh):
                    in_U[fresh] = True
                    u_size += len(fresh)
                    inter += np.asarray(ST[fresh].sum(axis=0)).ravel()
        # any leftover (empty-degree stragglers) keep window-relative order
        left = np.nonzero(remaining)[0]
        for j in left:
            perm_work[win[j]] = pos
            pos += 1
        assert pos == we

    # compose: old vertex v -> pre_order[v] -> perm_work[pre_order[v]]
    return perm_work[pre_order]


# ---------------------------------------------------------------------------
# social-like classification (paper §3.2.1 decision rule)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SocialLikeReport:
    heavy_tail: bool
    power_law: bool
    top1_share: float
    top10_share: float
    ll_slope: float
    ll_r2: float

    @property
    def is_social(self) -> bool:
        return self.heavy_tail or self.power_law


def social_like_report(g: Graph) -> SocialLikeReport:
    deg = (g.out_degree + g.in_degree).astype(np.float64)
    m2 = deg.sum()
    order = np.sort(deg)[::-1]
    k1 = max(1, g.n // 100)
    k10 = max(1, g.n // 10)
    top1 = order[:k1].sum() / max(m2, 1)
    top10 = order[:k10].sum() / max(m2, 1)
    heavy = (top1 > 0.05) and (top10 > 0.40)

    # log-log degree histogram straight-line fit
    pos = deg[deg > 0].astype(np.int64)
    slope, r2 = 0.0, 0.0
    if len(pos) > 0:
        hist = np.bincount(pos)
        ks = np.nonzero(hist)[0]
        ks = ks[ks > 0]
        if len(ks) >= 5:
            x = np.log(ks.astype(np.float64))
            y = np.log(hist[ks].astype(np.float64))
            A = np.stack([x, np.ones_like(x)], axis=1)
            coef, *_ = np.linalg.lstsq(A, y, rcond=None)
            slope = float(coef[0])
            pred = A @ coef
            ss_res = float(((y - pred) ** 2).sum())
            ss_tot = float(((y - y.mean()) ** 2).sum())
            r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    power = (-4.0 <= slope <= -1.2) and (r2 >= 0.7)
    return SocialLikeReport(heavy_tail=heavy, power_law=power,
                            top1_share=float(top1), top10_share=float(top10),
                            ll_slope=slope, ll_r2=r2)


def is_social_like(g: Graph) -> bool:
    return social_like_report(g).is_social


def auto_order(g: Graph, sigma: int = 8, w: int = 1024,
               seed: int = 0) -> tuple[np.ndarray, str]:
    """Paper §3.2 policy: social-like → shingle pre-pass + JaccardWithWindows
    (compression-oriented); otherwise → RCM (bandwidth/divergence-oriented)."""
    if is_social_like(g):
        pre = shingle_order(g, seed=seed)
        n_up = ((g.n + sigma - 1) // sigma) * sigma
        return jaccard_windows(g, sigma=sigma, w=max(sigma, min(w, n_up)),
                               pre_order=pre, seed=seed), "jaccard_windows"
    return rcm(g), "rcm"
