"""BFS engines (paper §4, Algorithms 2 & 3) plus the baselines of Table 2.

Every device engine runs its *entire* level loop inside one ``jit`` via the
shared :mod:`repro.core.level_pipeline` driver — the TPU analogue of the
paper's fused persistent kernel (§4.3): control never returns to the host
between levels and the convergence test is on-device.

Engines
-------
reference      host NumPy queue BFS (test oracle)
dense_pull     bitmap SpMSpV full sweep (frontier-oblivious lower bound)
csr_push       edge-parallel push (Gunrock-style edge map)
csr_pull       edge-parallel pull over the transposed CSR (GAP-style)
direction_opt  Beamer push/pull switching (GSWITCH's key pattern)
brs            BerryBees-like BRS: slice-set sweep, frontier-OBLIVIOUS
blest          Alg. 2: BVSS queue, frontier-aware blocks, eager scatter-min
blest_lazy     Alg. 3: lazy marks (fire-and-forget) + dense finalise sweep

TPU adaptation notes (DESIGN.md §2): the paper's atomic queue-append becomes
cumsum stream-compaction; `atomicOr`/`REDG` becomes scatter-max of byte
marks; the Alg. 3 stage-2 word sweep is a dense vectorised pass, which is
exactly what the VPU wants.

The ``blest``/``blest_lazy`` level step is FUSED (DESIGN.md §2.3): one
batched BVSS pull over the compacted queue (Pallas ``bvss_pull`` by
default), one scatter, and one fused finalise/pack/set-flag sweep
(``finalize_pack_sweep``).  The queue is processed at one of two static
widths chosen on-device from the live VSS count ("bucketing") — the
XLA-compatible stand-in for dynamically-sized kernel launches, so
small-frontier levels of high-diameter graphs don't pay the full-queue
cost.  The seed's sequential per-block ``while_loop`` is gone.

The BVSS engines are MESH-NATIVE (DESIGN.md §2.4): a
:class:`BlestProblem` built from a row-sharded BVSS
(``BlestProblem.build_sharded``) runs the SAME step/finalize skeleton
under ``shard_map`` — the pull, scatter and finalise sweep stay local to
each shard's row block, the per-shard frontier words are all-gathered once
per level, and the convergence test is a ``psum`` inside the single fused
``while_loop`` (no host sync across devices, paper §4.3 preserved).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.bvss import (BVSS, BVSSDevice, ShardedBVSS, ShardedBVSS2D,
                             ShardedBVSSDevice, shard_to_device, to_device)
from repro.core.level_pipeline import (LevelPipeline, compose_step,
                                       global_any, run_levels)
from repro.distributed.bfs_dist import frontier_all_gather
from repro.errors import ConfigError, GraphValidationError
from repro.graphs import Graph, src_of_edges, to_dense_bits
from repro.kernels import (finalize_pack_sweep, pull_vss_kernel,
                           push_vss_kernel)
from repro.kernels.ref import bvss_push_ref, finalize_pack_ref

INF = np.int32(np.iinfo(np.int32).max)

PULL_TILE = 128  # queue widths are padded to this (bvss_pull tile size)


# ---------------------------------------------------------------------------
# host oracle
# ---------------------------------------------------------------------------
def reference_bfs(g: Graph, src: int) -> np.ndarray:
    """NumPy frontier BFS over out-CSR; returns level array (INF = unreached)."""
    levels = np.full(g.n, INF, dtype=np.int32)
    levels[src] = 0
    frontier = np.array([src], dtype=np.int64)
    lvl = 0
    while len(frontier):
        lvl += 1
        nbrs = np.unique(np.concatenate(
            [g.indices[g.indptr[u]:g.indptr[u + 1]] for u in frontier]))
        new = nbrs[levels[nbrs] == INF].astype(np.int64)
        levels[new] = lvl
        frontier = new
    return levels


# ---------------------------------------------------------------------------
# shared device helpers
# ---------------------------------------------------------------------------
def _pack_bits(bits: jnp.ndarray, n_words: int) -> jnp.ndarray:
    """bool (n_words*32,) -> uint32 (n_words,), bit i of word w = bits[32w+i]."""
    b = bits.reshape(n_words, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(b * weights[None, :], axis=1, dtype=jnp.uint32)


def _unpack_bits(words: jnp.ndarray) -> jnp.ndarray:
    """uint32 (n_words,) -> bool (n_words*32,): inverse of :func:`_pack_bits`."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return ((words[:, None] >> shifts[None, :]) & 1).reshape(-1) != 0


def pull_vss_jnp(masks: jnp.ndarray, fbytes: jnp.ndarray, sigma: int
                 ) -> jnp.ndarray:
    """Pure-jnp pull over one batch of VSSs (oracle / non-Pallas fallback).

    masks:  (B, 32) uint32 — slot j of word l = mask of slice (j, l)
    fbytes: (B,)    uint32 — the σ-bit frontier word of each VSS's slice set
    returns hits (B, spw, 32) bool: slice/frontier dot product ≠ 0.
    """
    spw = 32 // sigma
    smask = jnp.uint32((1 << sigma) - 1)
    fb = fbytes & smask
    fword = jnp.zeros_like(fb)
    for j in range(spw):
        fword = fword | (fb << jnp.uint32(sigma * j))
    anded = masks & fword[:, None]
    hits = []
    for j in range(spw):
        sub = (anded >> jnp.uint32(sigma * j)) & smask
        hits.append(sub != 0)
    return jnp.stack(hits, axis=1)


def _frontier_bytes(F: jnp.ndarray, sets: jnp.ndarray, sigma: int) -> jnp.ndarray:
    """Gather the σ-bit frontier word of slice set ids ``sets`` from packed
    F: (n_fwords,) single frontier -> (B,), or (n_fwords, S) stacked
    per-source columns -> (B, S)."""
    bitpos = sets.astype(jnp.uint32) * jnp.uint32(sigma)
    idx = (bitpos >> jnp.uint32(5)).astype(jnp.int32)
    shift = bitpos & jnp.uint32(31)
    mask = jnp.uint32((1 << sigma) - 1)
    if F.ndim == 2:
        return (F[idx, :] >> shift[:, None]) & mask
    return (F[idx] >> shift) & mask


# ---------------------------------------------------------------------------
# BLEST problem bundle
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BlestProblem:
    n: int
    sigma: int
    n_sets: int       # GLOBAL slice sets (columns) in either mode
                      #   (2-D: LOCAL column-block slice sets per device)
    num_vss: int      # per-shard padded VSS count when sharded
    n_fwords: int     # gathered (global) frontier words when sharded
                      #   (2-D: per-device COLUMN-BLOCK frontier words)
    dev: BVSSDevice | ShardedBVSSDevice
    # mesh-native row partition (DESIGN §2.4); mesh=None = single-device
    mesh: Mesh | None = None
    axis: str = "data"
    n_shards: int = 1
    rows_per_shard: int = 0
    # 2-D row × column partition (DESIGN §2.4): col_axis=None = 1-D.
    # Column block j owns, inside every row block, the cols_per_block
    # sources [i·rps + j·cpb, i·rps + (j+1)·cpb) — the butterfly exchange
    # moves one rps/cols/32-word segment per device per level.
    col_axis: str | None = None
    n_col_shards: int = 1
    cols_per_block: int = 0
    # static push expansion factor: every pushing vertex enqueues at most
    # this many VSSs of its own slice set (DESIGN §2.8)
    max_vss_per_set: int = 1

    @property
    def is_2d(self) -> bool:
        return self.col_axis is not None

    @staticmethod
    def build(bvss: BVSS) -> "BlestProblem":
        return BlestProblem(n=bvss.n, sigma=bvss.sigma, n_sets=bvss.n_sets,
                            num_vss=bvss.num_vss,
                            n_fwords=bvss.n_frontier_words,
                            dev=to_device(bvss),
                            max_vss_per_set=bvss.max_vss_per_set)

    @staticmethod
    def build_sharded(sb: ShardedBVSS, mesh: Mesh, axis: str = "data"
                      ) -> "BlestProblem":
        """Row-sharded problem: ``dev`` holds the shard-stacked arrays
        committed with their ``P(axis)`` placement; the engines run the
        level loop under ``shard_map`` over ``axis``."""
        if mesh.shape[axis] != sb.n_shards:
            raise ValueError(
                f"mesh axis {axis!r} has {mesh.shape[axis]} devices but the "
                f"BVSS is built for {sb.n_shards} shards")
        return BlestProblem(n=sb.n, sigma=sb.sigma, n_sets=sb.n_sets,
                            num_vss=sb.num_vss_pad,
                            n_fwords=sb.n_frontier_words,
                            dev=shard_to_device(sb, mesh, axis),
                            mesh=mesh, axis=axis, n_shards=sb.n_shards,
                            rows_per_shard=sb.rows_per_shard,
                            max_vss_per_set=sb.max_vss_per_set)

    @staticmethod
    def build_sharded_2d(sb: "ShardedBVSS2D", mesh: Mesh) -> "BlestProblem":
        """2-D row × column problem: device (i, j) of the mesh owns BVSS
        block i·cols + j (row-major stack, both mesh axes on dim 0).
        ``n_sets``/``n_fwords`` become the per-device LOCAL column-block
        quantities — the engines never materialise a global frontier."""
        from repro.core.bvss import shard_to_device_2d

        row_axis, col_axis = mesh.axis_names
        shape = (mesh.shape[row_axis], mesh.shape[col_axis])
        if shape != (sb.rows, sb.cols):
            raise ConfigError(
                f"mesh shape {shape} does not match the 2-D BVSS built "
                f"for ({sb.rows}, {sb.cols}) blocks")
        return BlestProblem(n=sb.n, sigma=sb.sigma,
                            n_sets=sb.n_sets_local,
                            num_vss=sb.num_vss_pad,
                            n_fwords=sb.n_frontier_words_local,
                            dev=shard_to_device_2d(sb, mesh),
                            mesh=mesh, axis=row_axis, n_shards=sb.rows,
                            rows_per_shard=sb.rows_per_shard,
                            col_axis=col_axis, n_col_shards=sb.cols,
                            cols_per_block=sb.cols_per_block,
                            max_vss_per_set=sb.max_vss_per_set)


PullFn = Callable[[jnp.ndarray, jnp.ndarray, int], jnp.ndarray]
PushFn = Callable[[jnp.ndarray, jnp.ndarray, int], jnp.ndarray]

#: direction modes of the hybrid BVSS engines (DESIGN §2.8)
DIRECTIONS = ("auto", "pull", "push")

#: default auto-mode push cap: the largest frontier popcount a push level
#: will take on (also the push vertex-queue width, so it is the static
#: scatter-side working-set knob — autotunable, DESIGN §2.8)
DEFAULT_PUSH_CAP = PULL_TILE


class _BlestState(NamedTuple):
    levels: jnp.ndarray  # (n + 1,) int32, slot n = dummy row sink
                         #   (sharded: (rps + 1,) LOCAL rows, dummy = rps)
    F: jnp.ndarray       # (n_fwords,) uint32 packed frontier (global; under
                         #   shard_map each shard carries the gathered copy)
    Q: jnp.ndarray       # (qcap,) int32 compacted VSS queue, dummy-padded
    count: jnp.ndarray   # int32 live VSS count (LOCAL: bucket choice)
    marks: jnp.ndarray   # (n + 1,) uint8 lazy scratch ((1,) dummy when eager)
    unvisited: jnp.ndarray  # int32 GLOBAL unvisited-vertex count (the
                            #   Beamer-style saturation guard of the
                            #   direction heuristic, DESIGN §2.8)
    cont: jnp.ndarray    # bool continue flag (mesh-global via psum)


def _round_width(x: int) -> int:
    return max(PULL_TILE, ((x + PULL_TILE - 1) // PULL_TILE) * PULL_TILE)


def queue_widths(num_vss: int, buckets: int) -> list[int]:
    """Static queue widths, smallest first; the on-device live VSS count
    picks one via a cond chain (DESIGN §2.3/§2.8).

    The ladder is geometric with ratio 8: ``buckets`` graduations
    ``num_vss / 8^(buckets-1), ..., num_vss / 8, num_vss`` rounded up to
    the PULL_TILE floor, deduplicated ascending (the full width is always
    last).  ``buckets=1`` is the always-full-queue degenerate case;
    ``buckets=2`` reproduces the original small/full pair.  A bucket count
    < 1 is a :class:`repro.errors.ConfigError` — never a silent fallback.
    """
    if buckets < 1:
        raise ConfigError(
            f"queue_widths needs buckets >= 1, got {buckets!r}")
    widths: list[int] = []
    for i in range(buckets - 1, -1, -1):
        w = _round_width(-(-num_vss // 8 ** i))
        if not widths or w > widths[-1]:
            widths.append(w)
    return widths


def select_width(widths: list[int], count, apply: Callable):
    """Run ``apply(width)`` for the smallest ladder width holding ``count``
    live entries (full width fallback) via a nested ``lax.cond`` chain —
    the XLA stand-in for a dynamically-sized launch."""
    if len(widths) == 1:
        return apply(widths[0])

    def chain(i: int):
        if i == len(widths) - 1:
            return lambda: apply(widths[i])
        return lambda: jax.lax.cond(count <= widths[i],
                                    lambda: apply(widths[i]), chain(i + 1))

    return chain(0)()


def selected_width(widths: list[int], count) -> jnp.ndarray:
    """The scalar width :func:`select_width` would pick — the pull-side
    term of the direction heuristic's work model."""
    pw = jnp.int32(widths[-1])
    for w in reversed(widths[:-1]):
        pw = jnp.where(count <= w, jnp.int32(w), pw)
    return pw


def make_vertex_compactor(n_fwords: int, dummy_vertex: int, pqcap: int
                          ) -> Callable:
    """Build ``compact(F (n_fwords,) uint32) -> (VQ, fcount)``: cumsum
    stream-compaction of the set frontier BITS into a static-width vertex
    queue (the push twin of :func:`make_compactor`; dummy-padded with
    ``dummy_vertex``, overflow beyond ``pqcap`` dropped — which is why
    auto mode only takes push when ``popcount(F) <= push_cap``)."""
    verts = jnp.arange(n_fwords * 32, dtype=jnp.int32)
    bitpos = jnp.arange(32, dtype=jnp.uint32)

    def compact(F: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        bits = ((F[:, None] >> bitpos[None, :]) & jnp.uint32(1)
                ).reshape(-1).astype(bool)
        pos = jnp.cumsum(bits.astype(jnp.int32)) - 1
        idx = jnp.where(bits, pos, pqcap)  # OOB -> dropped
        VQ = jnp.full((pqcap,), dummy_vertex, dtype=jnp.int32)
        VQ = VQ.at[idx].set(verts, mode="drop")
        return VQ, bits.sum().astype(jnp.int32)

    return compact


def expand_push_queue(dev, VQ: jnp.ndarray, R: int, num_vss: int
                      ) -> jnp.ndarray:
    """Expand a compacted frontier-vertex queue into the (|VQ|·R,) VSS ids
    the push phase processes: every VSS of each vertex's own slice set
    (``vss_of_vertex_start/end``), dummy-padded to the static factor R =
    ``max_vss_per_set``.  Dummy vertices map to the empty range, so the
    whole row degenerates to the all-zero dummy VSS ``num_vss``."""
    starts = dev.vss_of_vertex_start[VQ]
    ends = dev.vss_of_vertex_end[VQ]
    r = jnp.arange(R, dtype=jnp.int32)
    vss = starts[:, None] + r[None, :]
    valid = r[None, :] < (ends - starts)[:, None]
    return jnp.where(valid, vss, num_vss).reshape(-1)


def make_compactor(dev: BVSSDevice, num_vss: int, qcap: int) -> Callable:
    """Build ``compact(set_active (n_sets,) bool) -> (Q, count)``: cumsum
    stream-compaction of active slice sets into the static-width VSS queue
    (the TPU idiom for the paper's atomic queue append).  Shared by the
    single-source engines and the multi-source / serving path."""
    vss_ids = jnp.arange(num_vss, dtype=jnp.int32)
    dummy_vss = num_vss

    def compact(set_active: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        vss_active = set_active[dev.virtual_to_real[:num_vss]]
        pos = jnp.cumsum(vss_active.astype(jnp.int32)) - 1
        idx = jnp.where(vss_active, pos, qcap)  # OOB -> dropped
        Q = jnp.full((qcap,), dummy_vss, dtype=jnp.int32)
        Q = Q.at[idx].set(vss_ids, mode="drop")
        return Q, vss_active.sum().astype(jnp.int32)

    return compact


class QueueHistory(NamedTuple):
    """Per-level frontier history of one fused traversal: row ``lvl`` of
    ``Q`` is the compacted VSS queue the level-``lvl`` pull consumed (the
    tiles whose slice sets intersect the level-``lvl - 1`` frontier), with
    its live count.  Recorded via
    :func:`repro.core.level_pipeline.run_levels_recorded` and replayed in
    reverse by the Brandes backward sweep (``repro.analytics.betweenness``)
    — level-``t`` dependency flow lives in exactly the tiles whose columns
    meet the level-``t - 1`` frontier, which is this queue."""

    Q: jnp.ndarray      # (max_levels + 1, qcap) int32, dummy-padded
    count: jnp.ndarray  # (max_levels + 1,) int32


def make_queue_history(qcap: int, max_levels: int, dummy_vss: int
                       ) -> tuple[QueueHistory, Callable]:
    """Preallocate a :class:`QueueHistory` buffer and build the ``record``
    hook that snapshots a wave state's ``(Q, count)`` into row ``lvl``."""
    hist0 = QueueHistory(
        Q=jnp.full((max_levels + 1, qcap), dummy_vss, dtype=jnp.int32),
        count=jnp.zeros((max_levels + 1,), dtype=jnp.int32))

    def record(hist: QueueHistory, state, lvl) -> QueueHistory:
        return QueueHistory(Q=hist.Q.at[lvl].set(state.Q),
                            count=hist.count.at[lvl].set(state.count))

    return hist0, record


def _make_hybrid_step(dev, pull: PullFn, push: PushFn | None, sigma: int,
                      n_rows: int, widths: list[int], *, lazy: bool,
                      direction: str, num_vss: int, n_fwords: int,
                      dummy_vertex: int, R: int, push_cap: int,
                      alpha: float) -> Callable:
    """The direction-optimizing level step (DESIGN §2.3/§2.8) — the ONE
    step body both the single-device and the shard_map'd engines run.

    Pull side: the bucketed gather → pull → update over the compacted VSS
    queue, width chosen from the graduated ladder by the live count.
    Push side: compact the frontier BITS into a vertex queue, expand each
    vertex into the ≤ R VSSs of its own slice set, and resolve each
    (vertex, VSS) pair with the one-hot push kernel — processing width
    ``pqcap·R`` regardless of ``num_vss``.

    ``direction``: "pull"/"push" force a branch (forced push sizes the
    vertex queue to the full rounded vertex count so nothing is dropped);
    "auto" picks per level on device: push iff the frontier fits the cap,
    the frontier is small against the unvisited remainder (the Beamer-α
    guard), and push's static cost undercuts the pull width the ladder
    would select.  When push's static cost cannot beat even the full pull
    width, auto compiles to pure pull (no dead branch).

    ``n_rows`` is the scatter extent: the global ``n`` single-device, the
    shard's ``rows_per_shard`` under a mesh (row ids are local there —
    while the frontier words, and hence the push vertex queue, are GLOBAL
    replicas, so every shard expands the same vertices into its own local
    VSS ids and both cond branches stay collective-free)."""
    if direction not in DIRECTIONS:
        raise ConfigError(
            f"direction must be one of {DIRECTIONS}, got {direction!r}")

    def scatter(state: _BlestState, rows, h, lvl) -> _BlestState:
        if lazy:
            # Alg. 3 stage 1: fire-and-forget mark (REDG analogue)
            marks = jnp.zeros((n_rows + 1,), dtype=jnp.uint8)
            marks = marks.at[rows].max(h.astype(jnp.uint8))
            return state._replace(marks=marks)
        # Alg. 2: eager visited-check-and-set (ATOMG analogue):
        # scatter-min leaves already-visited levels untouched
        upd = jnp.where(h, lvl, INF).astype(jnp.int32)
        return state._replace(levels=state.levels.at[rows].min(upd))

    def pull_update(state: _BlestState, lvl, width: int) -> _BlestState:
        """gather → pull → update over the first ``width`` queue slots
        (all live entries: the queue is compacted and count <= width)."""
        ids = jax.lax.slice_in_dim(state.Q, 0, width)
        fbytes = _frontier_bytes(state.F, dev.virtual_to_real[ids], sigma)
        hits = pull(dev.masks[ids], fbytes, sigma)       # (width, spw, 32)
        return scatter(state, dev.row_ids[ids].reshape(-1),
                       hits.reshape(-1), lvl)

    def pull_step(state: _BlestState, lvl) -> _BlestState:
        return select_width(widths, state.count,
                            lambda w: pull_update(state, lvl, w))

    if direction == "pull":
        return pull_step

    pqcap = _round_width(push_cap)
    push_cost = pqcap * R
    if direction == "auto" and push_cost >= widths[-1]:
        # push can never undercut even the full pull width (e.g. a hub
        # set blew up max_vss_per_set): compile the pure pull step
        return pull_step
    compact_vertices = make_vertex_compactor(n_fwords, dummy_vertex, pqcap)

    def push_update(state: _BlestState, lvl) -> _BlestState:
        VQ, _ = compact_vertices(state.F)
        ids = expand_push_queue(dev, VQ, R, num_vss)
        bits = jnp.broadcast_to((VQ % sigma).astype(jnp.int32)[:, None],
                                (pqcap, R)).reshape(-1)
        hits = push(dev.masks[ids], bits, sigma)         # (pqcap*R, spw, 32)
        return scatter(state, dev.row_ids[ids].reshape(-1),
                       hits.reshape(-1), lvl)

    if direction == "push":
        return push_update

    def step(state: _BlestState, lvl) -> _BlestState:
        fcount = jnp.sum(jax.lax.population_count(state.F)).astype(jnp.int32)
        use_push = ((fcount <= push_cap)
                    & (jnp.int32(push_cost)
                       < selected_width(widths, state.count))
                    & (fcount * jnp.float32(alpha)
                       <= state.unvisited.astype(jnp.float32)))
        return jax.lax.cond(use_push, push_update, pull_step, state, lvl)

    return step


def resolve_push_cap(direction: str, push_cap: int | None, n: int) -> int:
    """The frontier cap a push level tolerates: forced push must hold EVERY
    vertex (a dropped overflow entry is a wrong answer), auto defaults to
    the tunable small-frontier cap."""
    if direction == "push":
        return n
    return push_cap if push_cap is not None else DEFAULT_PUSH_CAP


def make_blest_bfs(problem: BlestProblem, *, lazy: bool,
                   pull_impl: PullFn | None = None,
                   push_impl: PushFn | None = None,
                   use_kernels: bool = True, buckets: int = 2,
                   widths: list[int] | None = None,
                   direction: str = "auto", push_cap: int | None = None,
                   alpha: float = 4.0, max_levels: int | None = None
                   ) -> Callable:
    """Build the jitted fused BLEST BFS (Alg. 2 eager / Alg. 3 lazy).

    The level step is direction-optimizing (DESIGN §2.8): the pull side is
    one batched pull over the compacted queue at a ladder-selected static
    width; the push side compacts the frontier bits into a vertex queue
    and expands each vertex's own slice-set VSSs through the one-hot push
    kernel.  Either way one scatter (min for eager levels, max for lazy
    marks) and one fused finalise + frontier-pack + set-flag sweep feed
    cumsum compaction.  A mesh-sharded ``problem`` runs the same pipeline
    under ``shard_map`` (local pull/push/scatter/finalise, frontier
    all-gather, psum convergence).

    pull_impl:   custom pull (masks, fbytes, sigma) -> hits; overrides the
                 kernel/jnp switch.
    push_impl:   custom push (masks, bits, sigma) -> hits — the push
                 fault seam (DESIGN §2.7/§2.8).
    use_kernels: route pull/push through the Pallas kernels and the tail
                 through Pallas ``finalize_pack_sweep`` (interpret-mode on
                 CPU); False = pure-jnp fallback for all three.
    buckets:     graduations of the pull-width ladder (see
                 :func:`queue_widths`); >= 1, ConfigError otherwise.
    widths:      explicit pull-width ladder (ascending; overrides
                 ``buckets`` — the autotuner's injection point).
    direction:   "auto" (per-level on-device switch), "pull", "push".
    push_cap:    auto-mode frontier cap (None = DEFAULT_PUSH_CAP; forced
                 push always uses the full vertex count).
    alpha:       Beamer-style saturation guard: auto only pushes while
                 ``alpha * popcount(F) <= unvisited``.
    """
    p = problem
    sigma = p.sigma
    if direction not in DIRECTIONS:
        raise ConfigError(
            f"direction must be one of {DIRECTIONS}, got {direction!r}")
    if widths is None:
        widths = queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    max_lv = max_levels if max_levels is not None else p.n + 1
    cap = resolve_push_cap(direction, push_cap, p.n)

    if pull_impl is not None:
        pull = pull_impl
    elif use_kernels:
        pull = pull_vss_kernel
    else:
        pull = pull_vss_jnp
    if push_impl is not None:
        push = push_impl
    elif use_kernels:
        push = push_vss_kernel
    else:
        push = bvss_push_ref
    fin_impl = finalize_pack_sweep if use_kernels else finalize_pack_ref

    if p.mesh is not None:
        if p.is_2d:
            return _make_blest_bfs_sharded_2d(p, pull=pull, widths=widths,
                                              qcap=qcap, max_lv=max_lv,
                                              direction=direction)
        return _make_blest_bfs_sharded(p, lazy=lazy, pull=pull, push=push,
                                       fin_impl=fin_impl, widths=widths,
                                       qcap=qcap, max_lv=max_lv,
                                       direction=direction, push_cap=cap,
                                       alpha=alpha)

    dev = p.dev
    fin = functools.partial(fin_impl, sigma=sigma, n_fwords=p.n_fwords,
                            n_sets=p.n_sets)
    compact = make_compactor(dev, p.num_vss, qcap)
    step = _make_hybrid_step(dev, pull, push, sigma, p.n, widths, lazy=lazy,
                             direction=direction, num_vss=p.num_vss,
                             n_fwords=p.n_fwords, dummy_vertex=p.n,
                             R=p.max_vss_per_set, push_cap=cap, alpha=alpha)

    def finalize(state: _BlestState, lvl) -> _BlestState:
        if lazy:
            # Alg. 3 stage 2 fused: finalise + pack + set flags in one sweep
            lv_n, fwords, set_active = fin(state.levels[:p.n], lvl,
                                           marks=state.marks[:p.n])
            levels = jnp.concatenate([lv_n, state.levels[p.n:]])
        else:
            # eager: levels already final; the sweep just packs + flags
            _, fwords, set_active = fin(state.levels[:p.n], lvl)
            levels = state.levels
        Q, count = compact(set_active)
        unvisited = state.unvisited - jnp.sum(
            jax.lax.population_count(fwords)).astype(jnp.int32)
        return state._replace(levels=levels, F=fwords, Q=Q, count=count,
                              unvisited=unvisited, cont=count > 0)

    pipe = LevelPipeline(step=step, finalize=finalize,
                         active=lambda s: s.cont)

    def bfs(src: jnp.ndarray) -> jnp.ndarray:
        src = jnp.asarray(src, dtype=jnp.int32)
        levels = jnp.full((p.n + 1,), INF, dtype=jnp.int32).at[src].set(0)
        F = jnp.zeros((p.n_fwords,), dtype=jnp.uint32)
        F = F.at[src // 32].set(jnp.uint32(1) << (src % 32).astype(jnp.uint32))
        set0 = jnp.zeros((p.n_sets,), dtype=bool).at[src // sigma].set(True)
        Q, count = compact(set0)
        marks0 = jnp.zeros((p.n + 1 if lazy else 1,), dtype=jnp.uint8)
        state = _BlestState(levels, F, Q, count, marks0,
                            jnp.int32(p.n - 1), count > 0)
        state, _ = run_levels(pipe, state, max_levels=max_lv)
        return state.levels[:p.n]

    return jax.jit(bfs)


def _make_blest_bfs_sharded(p: BlestProblem, *, lazy: bool, pull: PullFn,
                            push: PushFn, fin_impl, widths: list[int],
                            qcap: int, max_lv: int, direction: str,
                            push_cap: int, alpha: float) -> Callable:
    """The mesh-native BLEST engine (DESIGN §2.4): the whole level loop is
    ONE ``shard_map``'d ``while_loop`` over the row partition.  Per level,
    each shard runs the same fused hybrid step as the single-device engine
    on its local rows (``bvss_pull``/``bvss_push`` + scatter +
    ``finalize_pack_sweep``), the per-shard frontier words are all-gathered
    into the global frontier, and the compacted per-shard queues feed a
    psum'd convergence test — no host sync anywhere inside the loop.

    Push levels need NO extra collective (DESIGN §2.8): the vertex queue is
    compacted from the gathered global frontier replica every shard already
    holds, and each shard expands it through its own vertex → local-VSS map
    — the direction cond may even resolve differently across shards
    (per-shard VSS counts differ) because both branches are collective-free;
    the all-gather stays hoisted in finalize."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.bfs_dist import problem_specs

    mesh, axis = p.mesh, p.axis
    sigma = p.sigma
    rps = p.rows_per_shard
    lwords = rps // 32
    all_sets = jnp.arange(p.n_sets, dtype=jnp.int32)
    fin = functools.partial(fin_impl, sigma=sigma, n_fwords=lwords,
                            n_sets=rps // sigma)

    def local_loop(masks, row_ids, v2r, vstart, vend, src):
        """One shard's slice of the fused BFS (runs under shard_map)."""
        dev = ShardedBVSSDevice(masks[0], row_ids[0], v2r[0], vstart[0],
                                vend[0])
        compact = make_compactor(dev, p.num_vss, qcap)
        step = _make_hybrid_step(dev, pull, push, sigma, rps, widths,
                                 lazy=lazy, direction=direction,
                                 num_vss=p.num_vss, n_fwords=p.n_fwords,
                                 dummy_vertex=p.n, R=p.max_vss_per_set,
                                 push_cap=push_cap, alpha=alpha)
        d = jax.lax.axis_index(axis)

        def finalize(state: _BlestState, lvl) -> _BlestState:
            # local fused sweep over THIS shard's rows; its local set flags
            # are meaningless (sets are global) and discarded
            if lazy:
                lv_loc, fw_loc, _ = fin(state.levels[:rps], lvl,
                                        marks=state.marks[:rps])
                levels = jnp.concatenate([lv_loc, state.levels[rps:]])
            else:
                _, fw_loc, _ = fin(state.levels[:rps], lvl)
                levels = state.levels
            # the one cross-device term: σ-bit frontier words, all-gathered
            F = frontier_all_gather(fw_loc, axis)  # (n_fwords,)
            set_active = _frontier_bytes(F, all_sets, sigma) != 0
            Q, count = compact(set_active)
            unvisited = state.unvisited - jnp.sum(
                jax.lax.population_count(F)).astype(jnp.int32)
            return state._replace(levels=levels, F=F, Q=Q, count=count,
                                  unvisited=unvisited,
                                  cont=global_any(count > 0, axis))

        pipe = LevelPipeline(step=step, finalize=finalize,
                             active=lambda s: s.cont)

        # init: local levels/marks, global frontier + per-shard queue
        lsrc = src - d * rps
        own = (lsrc >= 0) & (lsrc < rps)
        levels = jnp.full((rps + 1,), INF, dtype=jnp.int32)
        levels = levels.at[jnp.where(own, lsrc, rps)].set(
            jnp.where(own, 0, INF))
        F = jnp.zeros((p.n_fwords,), dtype=jnp.uint32)
        F = F.at[src // 32].set(jnp.uint32(1) << (src % 32).astype(jnp.uint32))
        set0 = jnp.zeros((p.n_sets,), dtype=bool).at[src // sigma].set(True)
        Q, count = compact(set0)
        marks0 = jnp.zeros((rps + 1 if lazy else 1,), dtype=jnp.uint8)
        state = _BlestState(levels, F, Q, count, marks0,
                            jnp.int32(p.n - 1), global_any(count > 0, axis))
        state, _ = run_levels(pipe, state, max_levels=max_lv)
        return state.levels[None, :rps]

    fn = shard_map(local_loop, mesh=mesh,
                   in_specs=problem_specs(axis) + (P(),),
                   out_specs=P(axis), check_rep=False)

    def bfs(src: jnp.ndarray) -> jnp.ndarray:
        out = fn(p.dev.masks, p.dev.row_ids, p.dev.virtual_to_real,
                 p.dev.vss_of_vertex_start, p.dev.vss_of_vertex_end,
                 jnp.asarray(src, dtype=jnp.int32))
        return out.reshape(-1)[:p.n]

    return jax.jit(bfs)


def _make_blest_bfs_sharded_2d(p: BlestProblem, *, pull: PullFn,
                               widths: list[int], qcap: int, max_lv: int,
                               direction: str) -> Callable:
    """The 2-D (row × column) mesh-native BLEST engine (DESIGN §2.4).

    Device (i, j) pulls its ROW block of vertices from its COLUMN block of
    frontier words, so per level it runs the same bucketed pull as the 1-D
    engine over ``1/cols`` of the frontier, then two butterfly collectives
    replace the flat all-gather: an OR-allreduce of the packed partial hit
    words over the COLUMN axis (every column block saw a different frontier
    slice, so hits are partial), and a segment all-gather of the fresh
    frontier words over the ROW axis (device (i, j) contributes row block
    i's j-th word segment, receiving its full column block).  Per-device
    volume shrinks by ``cols`` vs the flat gather — the point of the
    partition.  Convergence is one psum over BOTH axes.

    The 2-D partition is pull-only: hits are accumulated as marks and
    reduced BEFORE levels update (a partial eager scatter-min would commit
    local hits that another column block already discovered at an earlier
    level — wrong), which makes the eager and lazy variants compile to the
    same mark-based body; forced ``direction="push"`` is a ConfigError
    (the frontier-bit vertex queue of the push phase indexes GLOBAL
    frontier replicas that no 2-D device holds).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.bfs_dist import problem_specs2d
    from repro.distributed.collectives import (butterfly_frontier_exchange,
                                               butterfly_or_allreduce)

    if direction == "push":
        raise ConfigError(
            "the 2-D row × column partition is pull-only (DESIGN §2.4); "
            "direction='push' needs the global frontier replica only the "
            "1-D partition holds — use a 1-D mesh or direction='pull'")
    mesh, rax, cax = p.mesh, p.axis, p.col_axis
    sigma = p.sigma
    rps = p.rows_per_shard
    lwords = rps // 32
    cpb = p.cols_per_block
    wpc = lwords // p.n_col_shards       # words per column segment
    ncw = p.n_fwords                     # per-device column-block words
    all_sets = jnp.arange(p.n_sets, dtype=jnp.int32)

    def local_loop(masks, row_ids, v2r, vstart, vend, src):
        """One device block's slice of the fused BFS (under shard_map)."""
        dev = ShardedBVSSDevice(masks[0], row_ids[0], v2r[0], vstart[0],
                                vend[0])
        compact = make_compactor(dev, p.num_vss, qcap)
        i = jax.lax.axis_index(rax)
        j = jax.lax.axis_index(cax)

        def step(state: _BlestState, lvl) -> _BlestState:
            def pull_marks(width: int):
                ids = jax.lax.slice_in_dim(state.Q, 0, width)
                fbytes = _frontier_bytes(state.F,
                                         dev.virtual_to_real[ids], sigma)
                hits = pull(dev.masks[ids], fbytes, sigma)
                marks = jnp.zeros((rps + 1,), dtype=jnp.uint8)
                return marks.at[dev.row_ids[ids].reshape(-1)].max(
                    hits.reshape(-1).astype(jnp.uint8))
            return state._replace(marks=select_width(widths, state.count,
                                                     pull_marks))

        def finalize(state: _BlestState, lvl) -> _BlestState:
            # partial hits -> full row-block hits (butterfly OR over cols)
            hw = butterfly_or_allreduce(
                _pack_bits(state.marks[:rps] > 0, lwords), cax)
            newly = _unpack_bits(hw) & (state.levels[:rps] == INF)
            levels = jnp.concatenate(
                [jnp.where(newly, lvl, state.levels[:rps]),
                 state.levels[rps:]])
            # fresh frontier: this row block's j-th word segment, exchanged
            # along the row axis into the full column block
            seg = jax.lax.dynamic_slice_in_dim(_pack_bits(newly, lwords),
                                               j * wpc, wpc)
            F = butterfly_frontier_exchange(seg, rax)       # (ncw,)
            set_active = _frontier_bytes(F, all_sets, sigma) != 0
            Q, count = compact(set_active)
            return state._replace(levels=levels, F=F, Q=Q, count=count,
                                  cont=global_any(count > 0, (rax, cax)))

        pipe = LevelPipeline(step=step, finalize=finalize,
                             active=lambda s: s.cont)

        # init: local levels; frontier bit only on the owning column block
        lsrc = src - i * rps
        own = (lsrc >= 0) & (lsrc < rps)
        levels = jnp.full((rps + 1,), INF, dtype=jnp.int32)
        levels = levels.at[jnp.where(own, lsrc, rps)].set(
            jnp.where(own, 0, INF))
        off = src % rps
        ownc = (off // cpb) == j
        c = jnp.clip((src // rps) * cpb + (off - j * cpb), 0, ncw * 32 - 1)
        F = jnp.zeros((ncw,), dtype=jnp.uint32)
        F = F.at[c // 32].set(jnp.where(
            ownc, jnp.uint32(1) << (c % 32).astype(jnp.uint32),
            jnp.uint32(0)))
        set_active = _frontier_bytes(F, all_sets, sigma) != 0
        Q, count = compact(set_active)
        marks0 = jnp.zeros((rps + 1,), dtype=jnp.uint8)
        state = _BlestState(levels, F, Q, count, marks0, jnp.int32(p.n - 1),
                            global_any(count > 0, (rax, cax)))
        state, _ = run_levels(pipe, state, max_levels=max_lv)
        return state.levels[None, :rps]

    fn = shard_map(local_loop, mesh=mesh,
                   in_specs=problem_specs2d(rax, cax) + (P(),),
                   out_specs=P((rax, cax)), check_rep=False)

    def bfs(src: jnp.ndarray) -> jnp.ndarray:
        out = fn(p.dev.masks, p.dev.row_ids, p.dev.virtual_to_real,
                 p.dev.vss_of_vertex_start, p.dev.vss_of_vertex_end,
                 jnp.asarray(src, dtype=jnp.int32))
        # (R·C, rps) row-major blocks, column-replicated: take column 0
        return out.reshape(p.n_shards, p.n_col_shards,
                           rps)[:, 0].reshape(-1)[:p.n]

    return jax.jit(bfs)


# ---------------------------------------------------------------------------
# BRS baseline (BerryBees-like): frontier-oblivious slice-set sweep
# ---------------------------------------------------------------------------
class _BrsState(NamedTuple):
    levels: jnp.ndarray
    F: jnp.ndarray
    cont: jnp.ndarray


def make_brs_bfs(problem: BlestProblem, *, max_levels: int | None = None
                 ) -> Callable:
    p = problem
    if p.mesh is not None:
        if p.is_2d:
            raise ConfigError(
                "the BRS baseline has no 2-D partition path — prepare with "
                "a 1-D mesh or the blest/blest_lazy engines")
        return _make_brs_bfs_sharded(p, max_levels=max_levels)
    dev = p.dev
    sigma = p.sigma
    n_pad = p.n_fwords * 32
    max_lv = max_levels if max_levels is not None else p.n + 1
    all_ids = jnp.arange(p.num_vss, dtype=jnp.int32)

    # every slice set visited, every level (paper drawback #2)
    def gather(s: _BrsState):
        return (dev.masks[all_ids],
                _frontier_bytes(s.F, dev.virtual_to_real[all_ids], sigma))

    def update(s: _BrsState, hits, lvl) -> _BrsState:
        rows = dev.row_ids[all_ids].reshape(-1)
        upd = jnp.where(hits.reshape(-1), lvl, INF).astype(jnp.int32)
        return s._replace(levels=s.levels.at[rows].min(upd))

    def finalize(s: _BrsState, lvl) -> _BrsState:
        new = s.levels[:p.n] == lvl
        new_pad = jnp.zeros((n_pad,), dtype=bool).at[:p.n].set(new)
        return s._replace(F=_pack_bits(new_pad, p.n_fwords), cont=new.any())

    pipe = LevelPipeline(
        step=compose_step(gather, lambda m, fb: pull_vss_jnp(m, fb, sigma),
                          update),
        finalize=finalize, active=lambda s: s.cont)

    def bfs(src: jnp.ndarray) -> jnp.ndarray:
        src = jnp.asarray(src, dtype=jnp.int32)
        levels = jnp.full((p.n + 1,), INF, dtype=jnp.int32).at[src].set(0)
        F = jnp.zeros((p.n_fwords,), dtype=jnp.uint32)
        F = F.at[src // 32].set(jnp.uint32(1) << (src % 32).astype(jnp.uint32))
        state = _BrsState(levels, F, jnp.bool_(True))
        state, _ = run_levels(pipe, state, max_levels=max_lv)
        return state.levels[:p.n]

    return jax.jit(bfs)


def _make_brs_bfs_sharded(p: BlestProblem, *, max_levels: int | None
                          ) -> Callable:
    """Mesh-native BRS: the frontier-oblivious sweep visits every VSS of
    every SHARD each level (paper drawback #2 doesn't shrink under a mesh —
    that is the point of the baseline); only the frontier words cross
    devices."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.bfs_dist import problem_specs

    mesh, axis = p.mesh, p.axis
    sigma = p.sigma
    rps = p.rows_per_shard
    lwords = rps // 32
    max_lv = max_levels if max_levels is not None else p.n + 1
    all_ids = jnp.arange(p.num_vss, dtype=jnp.int32)

    def local_loop(masks, row_ids, v2r, vstart, vend, src):
        dev = ShardedBVSSDevice(masks[0], row_ids[0], v2r[0], vstart[0],
                                vend[0])
        d = jax.lax.axis_index(axis)

        def gather(s: _BrsState):
            return (dev.masks[all_ids],
                    _frontier_bytes(s.F, dev.virtual_to_real[all_ids], sigma))

        def update(s: _BrsState, hits, lvl) -> _BrsState:
            rows = dev.row_ids[all_ids].reshape(-1)
            upd = jnp.where(hits.reshape(-1), lvl, INF).astype(jnp.int32)
            return s._replace(levels=s.levels.at[rows].min(upd))

        def finalize(s: _BrsState, lvl) -> _BrsState:
            new = s.levels[:rps] == lvl
            fw_loc = _pack_bits(new, lwords)
            F = frontier_all_gather(fw_loc, axis)
            return s._replace(F=F, cont=global_any(new.any(), axis))

        pipe = LevelPipeline(
            step=compose_step(gather,
                              lambda m, fb: pull_vss_jnp(m, fb, sigma),
                              update),
            finalize=finalize, active=lambda s: s.cont)

        lsrc = src - d * rps
        own = (lsrc >= 0) & (lsrc < rps)
        levels = jnp.full((rps + 1,), INF, dtype=jnp.int32)
        levels = levels.at[jnp.where(own, lsrc, rps)].set(
            jnp.where(own, 0, INF))
        F = jnp.zeros((p.n_fwords,), dtype=jnp.uint32)
        F = F.at[src // 32].set(jnp.uint32(1) << (src % 32).astype(jnp.uint32))
        state = _BrsState(levels, F, jnp.bool_(True))
        state, _ = run_levels(pipe, state, max_levels=max_lv)
        return state.levels[None, :rps]

    fn = shard_map(local_loop, mesh=mesh,
                   in_specs=problem_specs(axis) + (P(),),
                   out_specs=P(axis), check_rep=False)

    def bfs(src: jnp.ndarray) -> jnp.ndarray:
        out = fn(p.dev.masks, p.dev.row_ids, p.dev.virtual_to_real,
                 p.dev.vss_of_vertex_start, p.dev.vss_of_vertex_end,
                 jnp.asarray(src, dtype=jnp.int32))
        return out.reshape(-1)[:p.n]

    return jax.jit(bfs)


# ---------------------------------------------------------------------------
# dense bitmap pull (naive SpMSpV lower bound)
# ---------------------------------------------------------------------------
def make_dense_pull_bfs(g: Graph, *, max_levels: int | None = None) -> Callable:
    n = g.n
    n_words = (n + 31) // 32
    adj = jnp.asarray(to_dense_bits(g))  # (n, n_words) of A^T
    max_lv = max_levels if max_levels is not None else n + 1

    def bfs(src: jnp.ndarray) -> jnp.ndarray:
        src = jnp.asarray(src, dtype=jnp.int32)
        levels = jnp.full((n,), INF, dtype=jnp.int32).at[src].set(0)
        F = jnp.zeros((n_words,), dtype=jnp.uint32)
        F = F.at[src // 32].set(jnp.uint32(1) << (src % 32).astype(jnp.uint32))

        def cond(state):
            return state[2] & (state[3] < max_lv)

        def body(state):
            levels, F, _, lvl = state
            lvl = lvl + 1
            y = jnp.any(adj & F[None, :], axis=1)
            new = y & (levels == INF)
            levels = jnp.where(new, lvl, levels)
            new_pad = jnp.zeros((n_words * 32,), dtype=bool).at[:n].set(new)
            return levels, _pack_bits(new_pad, n_words), new.any(), lvl

        state = (levels, F, jnp.bool_(True), jnp.int32(0))
        levels, *_ = jax.lax.while_loop(cond, body, state)
        return levels

    return jax.jit(bfs)


# ---------------------------------------------------------------------------
# CSR edge-parallel baselines (push / pull / direction-optimised)
# ---------------------------------------------------------------------------
def make_csr_bfs(g: Graph, mode: str = "push", *, alpha: float = 15.0,
                 max_levels: int | None = None) -> Callable:
    """Edge-parallel BFS baselines.

    push: next[dst] |= frontier[src] over all out-edges.
    pull: next[u] |= frontier[v] over all in-edges (v -> u), unvisited u only.
    dirop: Beamer switching between the two on scout-count heuristic.
    """
    if mode not in ("push", "pull", "dirop"):
        raise GraphValidationError(
            f"CSR BFS mode must be one of ('push', 'pull', 'dirop'), "
            f"got {mode!r}")
    n = g.n
    e_src = jnp.asarray(src_of_edges(g).astype(np.int32))
    e_dst = jnp.asarray(g.indices.astype(np.int32))
    out_deg = jnp.asarray(g.out_degree.astype(np.int32))
    m = g.m
    max_lv = max_levels if max_levels is not None else n + 1

    def push_step(frontier, levels):
        nxt = jnp.zeros((n,), dtype=jnp.uint8)
        nxt = nxt.at[e_dst].max(frontier[e_src].astype(jnp.uint8))
        return (nxt > 0) & (levels == INF)

    def pull_step(frontier, levels):
        # pull for u over its in-edges (v -> u): mask by unvisited dst FIRST
        # (the work-saving property of pull), then scatter.
        unvis = levels == INF
        vals = frontier[e_src] & unvis[e_dst]
        nxt = jnp.zeros((n,), dtype=jnp.uint8)
        nxt = nxt.at[e_dst].max(vals.astype(jnp.uint8))
        return nxt > 0

    def bfs(src: jnp.ndarray) -> jnp.ndarray:
        src = jnp.asarray(src, dtype=jnp.int32)
        levels = jnp.full((n,), INF, dtype=jnp.int32).at[src].set(0)
        frontier = jnp.zeros((n,), dtype=bool).at[src].set(True)

        def cond(state):
            return state[2] & (state[3] < max_lv)

        def body(state):
            levels, frontier, _, lvl = state
            lvl = lvl + 1
            if mode == "push":
                new = push_step(frontier, levels)
            elif mode == "pull":
                new = pull_step(frontier, levels)
            else:
                scout = jnp.sum(jnp.where(frontier, out_deg, 0))
                use_pull = scout * alpha > m
                new = jax.lax.cond(use_pull, pull_step, push_step,
                                   frontier, levels)
            levels = jnp.where(new, lvl, levels)
            return levels, new, new.any(), lvl

        state = (levels, frontier, jnp.bool_(True), jnp.int32(0))
        levels, *_ = jax.lax.while_loop(cond, body, state)
        return levels

    return jax.jit(bfs)


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------
def make_engine(g: Graph, engine: str, *, sigma: int = 8,
                bvss: BVSS | None = None,
                problem: BlestProblem | None = None,
                pull_impl: PullFn | None = None,
                push_impl: PushFn | None = None,
                use_kernels: bool = True, buckets: int = 2,
                widths: list[int] | None = None,
                direction: str = "auto", push_cap: int | None = None,
                alpha: float = 4.0,
                n_sources: int | None = None,
                block: int | None = None) -> Callable:
    """Build a jitted BFS callable ``f(src) -> levels`` for the named engine.

    ``problem`` lets callers that already hold a :class:`BlestProblem`
    (core.policy.prepare, GraphSession) skip rebuilding the device BVSS;
    a mesh-sharded problem routes the BVSS engines through the
    ``shard_map``'d pipeline (DESIGN §2.4).
    ``direction``/``push_cap``/``alpha``/``widths`` are the hybrid knobs
    (DESIGN §2.8) of the blest/blest_lazy/multi_source engines;
    ``push_impl`` is the push-kernel fault seam.
    ``engine="multi_source"`` builds the batched BVSS bit-SpMM engine
    ``f(sources (S,)) -> levels (n, S)`` and requires ``n_sources``.
    ``block`` is accepted for backwards compatibility and ignored: the fused
    pipeline batches the whole compacted queue instead of slicing it into
    sequential blocks.
    """
    del block
    if engine == "dense_pull":
        return make_dense_pull_bfs(g)
    if engine in ("csr_push", "csr_pull", "dirop"):
        mode = {"csr_push": "push", "csr_pull": "pull", "dirop": "dirop"}[engine]
        return make_csr_bfs(g, mode)
    if engine in ("brs", "blest", "blest_lazy", "multi_source"):
        if problem is None:
            from repro.core.bvss import build_bvss
            b = bvss if bvss is not None else build_bvss(g, sigma=sigma)
            problem = BlestProblem.build(b)
        if engine == "multi_source":
            from repro.core.multi_source import make_multi_source_bfs
            if n_sources is None:
                raise ValueError("multi_source engine needs n_sources")
            return make_multi_source_bfs(g, n_sources, problem=problem,
                                         use_kernel=use_kernels,
                                         buckets=buckets, widths=widths,
                                         direction=direction,
                                         push_cap=push_cap)
        if engine == "brs":
            return make_brs_bfs(problem)
        return make_blest_bfs(problem, lazy=(engine == "blest_lazy"),
                              pull_impl=pull_impl, push_impl=push_impl,
                              use_kernels=use_kernels, buckets=buckets,
                              widths=widths, direction=direction,
                              push_cap=push_cap, alpha=alpha)
    raise ValueError(f"unknown engine {engine!r}")


ENGINES = ("dense_pull", "csr_push", "csr_pull", "dirop", "brs", "blest",
           "blest_lazy")
# engines with a (sources (S,)) -> (n, S) signature, built via n_sources=
MULTI_ENGINES = ("multi_source",)
