"""Per-backend tile autotuning for the hybrid BFS engines (DESIGN §2.8).

The direction-optimizing step has three static knobs the compiler cannot
pick: the graduated pull-queue ladder (``widths``), the push-phase vertex
cap (``push_cap``) and the Beamer-α saturation guard.  Their best values
depend on the BACKEND (MXU tile shapes vs CPU vector widths vs interpret
overhead) and only coarsely on the graph, so this module measures them
once per *(backend, σ, size-class)* and memoises the winner:

* ``tune(problem)`` times candidate ladders and push caps on SYNTHETIC
  operands of the problem's true tile shapes — a handful of jitted kernel
  dispatches with a small rep budget, no graph traversal — and returns a
  frozen :class:`TileConfig`;
* the module-level cache keys on ``(backend, σ, pow2-bucketized num_vss,
  use_kernels)``: a second ``prepare(..., autotune=True)`` for the same
  backend and graph class performs ZERO additional timing dispatches (the
  ``stats`` counters make that contract testable);
* ``BLEST_AUTOTUNE=0`` in the environment disables measurement entirely
  (the default config is returned, marked ``source="disabled"``) — the CI
  escape hatch for timing-hostile runners.

``core.policy.prepare(..., autotune=True)`` is the consumer: the winning
config is cached on the returned :class:`~repro.core.policy.PreparedBFS`
and its widths/cap are injected into the engine build.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bfs import (DEFAULT_PUSH_CAP, _round_width, queue_widths)
from repro.errors import ConfigError

#: the dispatch-model's far anchor: candidate widths are never timed
#: directly, only the 128-row floor and this row count are (the affine
#: model interpolates/extrapolates the rest — graph-independent budget)
MAX_TIMED_ROWS = 2048
#: timing repetitions per candidate (after one untimed warmup/compile call)
DEFAULT_REPS = 2


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """The tuned static knobs of one hybrid engine build.

    ``source`` records provenance: ``"tuned"`` (measured this process),
    ``"cached"`` (measured earlier for the same class), ``"disabled"``
    (``BLEST_AUTOTUNE=0``: defaults, no measurement)."""

    pull_widths: tuple[int, ...]
    push_cap: int
    alpha: float
    source: str

    def engine_kwargs(self) -> dict:
        """The ``make_engine`` override dict this config injects."""
        return {"widths": list(self.pull_widths), "push_cap": self.push_cap,
                "alpha": self.alpha}


#: (backend, sigma, pow2 size class, use_kernels) -> winning TileConfig
_TUNE_CACHE: dict[tuple, TileConfig] = {}
#: observable tuning activity — the zero-retune contract's test surface
stats = {"tune_runs": 0, "cache_hits": 0}


def clear_cache() -> None:
    """Drop all memoised configs (test isolation helper)."""
    _TUNE_CACHE.clear()


def _size_class(num_vss: int) -> int:
    """Bucketize the VSS count to the next power of two: graphs in the
    same class share tile shapes closely enough to share a config."""
    b = 1
    while b < max(num_vss, 1):
        b <<= 1
    return b


def class_key(problem, use_kernels: bool) -> tuple:
    """The memoisation key of one tuning run."""
    return (jax.default_backend(), problem.sigma,
            _size_class(problem.num_vss), bool(use_kernels))


def default_config(problem, *, buckets: int = 2,
                   source: str = "disabled") -> TileConfig:
    """The untuned knobs every engine uses when autotuning is off."""
    return TileConfig(
        pull_widths=tuple(queue_widths(problem.num_vss, buckets)),
        push_cap=DEFAULT_PUSH_CAP, alpha=4.0, source=source)


def _time_call(fn: Callable, args: tuple, reps: int) -> float:
    """Best-of-``reps`` wall time of one jitted dispatch (one untimed
    warmup call absorbs compilation)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _pull_operands(width: int, sigma: int, seed: int
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    masks = jnp.asarray(rng.integers(0, 2 ** 32, size=(width, 32),
                                     dtype=np.uint32))
    fbytes = jnp.asarray(rng.integers(0, 2 ** sigma, size=(width,),
                                      dtype=np.uint32))
    return masks, fbytes


def _push_operands(width: int, sigma: int, seed: int
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    masks = jnp.asarray(rng.integers(0, 2 ** 32, size=(width, 32),
                                     dtype=np.uint32))
    bits = jnp.asarray(rng.integers(0, sigma, size=(width,),
                                    dtype=np.int32))
    return masks, bits


def _fit_dispatch_model(timed: Callable, reps: int) -> tuple[float, float]:
    """Fit the affine dispatch-cost model ``t(w) = a + b*w`` from two
    measured anchors (the PULL_TILE floor and ``MAX_TIMED_ROWS``).

    Scoring candidate widths through the fitted model instead of raw
    per-width timings is what makes tuning DETERMINISTIC on dispatch-
    dominated backends: at CPU scale ``t(128)`` and ``t(256)`` differ by
    less than timer noise, so comparing them directly picks a random
    ladder — while the model's slope, anchored ``MAX_TIMED_ROWS`` apart,
    resolves far above the noise floor."""
    lo, hi = 128, MAX_TIMED_ROWS
    t_lo, t_hi = timed(lo, reps), timed(hi, reps)
    b = max((t_hi - t_lo) / (hi - lo), 0.0)
    a = max(t_lo - b * lo, 0.0)
    return a, b


def tune(problem, *, use_kernels: bool = True,
         buckets_candidates: Iterable[int] = (2, 3, 4),
         push_cap_candidates: Iterable[int] = (128, 256),
         reps: int = DEFAULT_REPS) -> TileConfig:
    """Fit dispatch-cost models for the pull and push kernels on the
    current backend and pick ``problem``'s ladder and push cap through
    them; memoised per :func:`class_key`.

    Four timed dispatches total (two anchors per kernel, see
    :func:`_fit_dispatch_model`); every candidate is then scored
    analytically.  The ladder score is the modeled pull time at the
    ladder's SMALLEST width plus its FULL width — the two regimes a
    traversal alternates between (trickle levels ride the small rung,
    bulk levels the full queue); rungs between never cost more than
    either endpoint.  The push cap maximises the ENGAGEMENT RANGE: the
    auto heuristic only takes push when its static cost
    ``round(cap) * max_vss_per_set`` undercuts the rung the ladder would
    select, so the winning cap is the one with the most ladder rungs
    strictly above its cost (modeled push time breaks ties) — a larger
    cap that pushes its own cost past every rung would never fire.
    """
    if reps < 1:
        raise ConfigError(f"autotune needs reps >= 1, got {reps!r}")
    key = class_key(problem, use_kernels)
    cached = _TUNE_CACHE.get(key)
    if cached is not None:
        stats["cache_hits"] += 1
        return dataclasses.replace(cached, source="cached")
    if os.environ.get("BLEST_AUTOTUNE", "") == "0":
        return default_config(problem)
    stats["tune_runs"] += 1
    sigma = problem.sigma
    if use_kernels:
        from repro.kernels import pull_vss_kernel, push_vss_kernel
        pull, push = pull_vss_kernel, push_vss_kernel
    else:
        from repro.kernels.ref import bvss_pull_ref, bvss_push_ref
        pull, push = bvss_pull_ref, bvss_push_ref
    pull_j = jax.jit(lambda m, f: pull(m, f, sigma))
    push_j = jax.jit(lambda m, b: push(m, b, sigma))

    pa, pb = _fit_dispatch_model(
        lambda w, r: _time_call(pull_j, _pull_operands(w, sigma, seed=w), r),
        reps)
    qa, qb = _fit_dispatch_model(
        lambda w, r: _time_call(push_j, _push_operands(w, sigma, seed=w), r),
        reps)

    buckets = sorted(set(int(x) for x in buckets_candidates))
    caps = sorted(set(int(x) for x in push_cap_candidates))
    if not buckets or not caps:
        raise ConfigError("autotune needs at least one buckets and one "
                          f"push-cap candidate, got {buckets_candidates!r} "
                          f"/ {push_cap_candidates!r}")
    best_widths: tuple[int, ...] = ()
    best_score = float("inf")
    for b in buckets:
        widths = tuple(queue_widths(problem.num_vss, b))
        score = (pa + pb * widths[0]) + (pa + pb * widths[-1])
        if score < best_score:
            best_widths, best_score = widths, score

    R = max(problem.max_vss_per_set, 1)
    best_cap, best_key = DEFAULT_PUSH_CAP, None
    for cap in caps:
        pqcap = _round_width(cap)
        cost = pqcap * R
        engagement = sum(1 for w in best_widths if cost < w)
        cand = (-engagement, qa + qb * cost)
        if best_key is None or cand < best_key:
            best_cap, best_key = cap, cand

    cfg = TileConfig(pull_widths=best_widths, push_cap=best_cap,
                     alpha=4.0, source="tuned")
    _TUNE_CACHE[key] = cfg
    return cfg
