"""Fault-tolerance orchestration: restart manager, failure injection,
straggler monitor, elastic re-mesh bookkeeping.

On a real cluster the controller process wraps the train loop with
``RestartManager.run``: any exception (preemption, hardware fault — or the
injected ``SimulatedFailure``) triggers a bounded-retry restart that resumes
from the latest complete checkpoint.  Because checkpoints store full arrays
(ft/checkpoint.py), a restart may come back on a different mesh shape —
``elastic_remesh_plan`` records the device-count transition.

The straggler monitor covers the *host-side* hazards a TPU pod job actually
has (slow data feeding / slow checkpoint writes): batches are produced by a
bounded prefetch queue with a timeout; on timeout the loop substitutes the
deterministic backup batch (skip-and-refill) rather than stalling the whole
pod, and the event is counted.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Iterator


class SimulatedFailure(RuntimeError):
    """Raised by failure injection hooks (tests / chaos drills)."""


@dataclasses.dataclass
class RestartStats:
    restarts: int = 0
    completed: bool = False
    resume_steps: list[int] = dataclasses.field(default_factory=list)


class RestartManager:
    """Run ``body(resume_step) -> final_step`` with bounded-retry restart.

    ``body`` must itself restore from the latest checkpoint when called with
    a resume step > 0 (see launch/train.py); the manager only supervises.
    """

    def __init__(self, max_restarts: int = 3,
                 resume_step_fn: Callable[[], int] | None = None):
        self.max_restarts = max_restarts
        self.resume_step_fn = resume_step_fn or (lambda: 0)
        self.stats = RestartStats()

    def run(self, body: Callable[[int], Any]):
        attempt = 0
        while True:
            resume = self.resume_step_fn()
            self.stats.resume_steps.append(resume)
            try:
                result = body(resume)
                self.stats.completed = True
                return result
            except SimulatedFailure:
                attempt += 1
                self.stats.restarts += 1
                if attempt > self.max_restarts:
                    raise


class FailureInjector:
    """Deterministically fail at configured steps (once each)."""

    def __init__(self, fail_at_steps: tuple[int, ...] = ()):
        self.fail_at = set(fail_at_steps)

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise SimulatedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerStats:
    timeouts: int = 0
    served: int = 0


class PrefetchQueue:
    """Bounded producer/consumer prefetch with straggler mitigation.

    ``get`` waits up to ``timeout_s``; on timeout it returns
    ``backup_fn(step)`` (deterministic synthetic batch) instead of stalling
    the accelerator — the skip-and-refill policy.
    """

    def __init__(self, it: Iterator, *, depth: int = 4, timeout_s: float = 5.0,
                 backup_fn: Callable[[int], Any] | None = None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self.timeout_s = timeout_s
        self.backup_fn = backup_fn
        self.stats = StragglerStats()
        self._done = False
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._done = True

    def get(self, step: int):
        try:
            item = self._q.get(timeout=self.timeout_s)
            self.stats.served += 1
            return item
        except queue.Empty:
            self.stats.timeouts += 1
            if self.backup_fn is None:
                raise TimeoutError(
                    f"data pipeline straggled > {self.timeout_s}s at step "
                    f"{step} and no backup batch is configured")
            return self.backup_fn(step)


def elastic_remesh_plan(old_devices: int, new_devices: int,
                        model_parallel: int) -> dict:
    """Describe how a checkpoint written on ``old_devices`` is re-laid-out
    on ``new_devices`` (full-array checkpoints make this a pure metadata
    decision: only the data-parallel extent changes)."""
    if new_devices % model_parallel != 0:
        raise ValueError(
            f"new device count {new_devices} not divisible by "
            f"model-parallel degree {model_parallel}")
    return {
        "old_dp": old_devices // model_parallel,
        "new_dp": new_devices // model_parallel,
        "model_parallel": model_parallel,
        "batch_ratio": new_devices / old_devices,
    }
