from .checkpoint import (AsyncCheckpointer, latest_checkpoint,
                         list_checkpoints, restore_checkpoint, restore_latest,
                         save_checkpoint)
from .manager import (FailureInjector, PrefetchQueue, RestartManager,
                      SimulatedFailure, elastic_remesh_plan)

__all__ = ["AsyncCheckpointer", "latest_checkpoint", "list_checkpoints",
           "restore_checkpoint", "restore_latest", "save_checkpoint",
           "FailureInjector", "PrefetchQueue", "RestartManager",
           "SimulatedFailure", "elastic_remesh_plan"]
