"""Checkpointing (orbax is not installed — implemented here).

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf plus a
``manifest.json`` with the treedef, dtypes, shapes, step and mesh metadata.
Writes go to ``step_<N>.tmp`` and are atomically renamed, so a crash
mid-save never corrupts the latest checkpoint — the restart manager simply
picks the newest *complete* directory.

Restore is resharding-tolerant: leaves are saved as full (unsharded) arrays
and re-placed under whatever sharding the restoring job requests, so a run
can resume on a different device count (elastic scaling).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

import ml_dtypes

Params = Any
_BF16 = np.dtype(ml_dtypes.bfloat16)
_SANITIZE = re.compile(r"[^A-Za-z0-9_.-]")


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = _SANITIZE.sub("_", jax.tree_util.keystr(path))
        out.append((name, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree, *,
                    extra: dict | None = None) -> str:
    """Blocking save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _leaf_paths(tree)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        true_dtype = str(arr.dtype)
        if arr.dtype == _BF16:  # numpy can't roundtrip bf16: store a view
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append(
            {"name": name, "dtype": true_dtype, "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (one in flight at a time)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree, *, extra: dict | None = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda l: np.asarray(jax.device_get(l)), tree)

        def work():
            save_checkpoint(self.directory, step, host_tree, extra=extra)
            retain(self.directory, self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def list_checkpoints(directory: str) -> list[tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        path = os.path.join(directory, d)
        if m and os.path.exists(os.path.join(path, "manifest.json")):
            out.append((int(m.group(1)), path))
    return sorted(out)


def latest_checkpoint(directory: str) -> tuple[int, str] | None:
    cks = list_checkpoints(directory)
    return cks[-1] if cks else None


def retain(directory: str, keep: int):
    cks = list_checkpoints(directory)
    for _, path in cks[:-keep] if keep > 0 else []:
        shutil.rmtree(path, ignore_errors=True)


def restore_checkpoint(path: str, tree_like, *, shardings=None):
    """Restore into the structure of ``tree_like``.  ``shardings`` may be a
    matching pytree of jax shardings (or None for default placement) —
    resharding across device counts happens here."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names = [name for name, _ in _leaf_paths(tree_like)]
    saved = {l["name"] for l in manifest["leaves"]}
    missing = [n for n in names if n not in saved]
    if missing:
        raise ValueError(f"checkpoint at {path} is missing leaves {missing}")
    dtypes = {l["name"]: l["dtype"] for l in manifest["leaves"]}
    arrays = {}
    for n in names:
        arr = np.load(os.path.join(path, n + ".npy"))
        if dtypes[n] == "bfloat16":
            arr = arr.view(_BF16)
        arrays[n] = arr
    leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_like))
    out = []
    for (name, like), sh in zip(_leaf_paths(tree_like), shard_leaves):
        arr = arrays[name]
        assert tuple(arr.shape) == tuple(like.shape), \
            f"{name}: {arr.shape} vs {like.shape}"
        if arr.dtype != like.dtype:
            arr = arr.astype(like.dtype)
        out.append(jax.device_put(arr, sh))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def restore_latest(directory: str, tree_like, *, shardings=None):
    latest = latest_checkpoint(directory)
    if latest is None:
        return None
    _, path = latest
    return restore_checkpoint(path, tree_like, shardings=shardings)
