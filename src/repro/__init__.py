"""``repro``: the BLEST reproduction's stable public surface.

Everything an application needs lives at this level — graph construction,
the static preparation pipeline, the serving tier (sessions, the
multi-tenant manager, the async request queue), streaming edge updates,
and the typed error hierarchy::

    import repro

    g = repro.Graph.from_edges_like(...)          # or repro.from_edges(...)
    prepared = repro.prepare(g, options=repro.PrepareOptions(sigma=8))

    mgr = repro.GraphSessionManager(verify_fraction=0.05)
    mgr.open_session("social", g, tenant="acme", max_batch=8)
    queue = repro.RequestQueue(mgr)
    fut = queue.submit("social", src=42, tenant="acme", deadline_s=0.5)
    queue.drain()
    levels = fut.result()

    mgr.update_edges("social", inserts=[(10, 99)], tenant="acme")

Deep module paths (``repro.core.policy``, ``repro.serve.queue``, ...)
remain importable but are NOT covered by the API-surface snapshot test
(``tests/test_api_surface.py``) — only the names re-exported here, plus
their signatures, are the compatibility contract.
"""
from repro.core.bvss_delta import UpdateReport, apply_edge_updates
from repro.core.policy import (PreparedBFS, PrepareOptions, build_problem,
                               prepare)
from repro.errors import (AdmissionError, BlestError, ConfigError,
                          DeadlineExceeded, GraphValidationError,
                          KernelFaultError, QueueFullError, StaleEpochError)
from repro.graphs import Graph, from_edges, src_of_edges
from repro.serve import (NO_FAULTS, DegradedServiceWarning, FaultPlan,
                         GraphSession, GraphSessionManager, RequestQueue,
                         TenantQuota, TimeoutResult, WaveFuture,
                         WaveScheduler, session_cost_bytes)

#: the session verb tuple the CI verbs lane enforces oracle parity for
VERBS = GraphSession.VERBS

__version__ = "0.5.0"

__all__ = [
    # graphs
    "Graph", "from_edges", "src_of_edges",
    # preparation
    "prepare", "PrepareOptions", "PreparedBFS", "build_problem",
    # streaming updates
    "apply_edge_updates", "UpdateReport",
    # serving
    "GraphSession", "GraphSessionManager", "TenantQuota", "TimeoutResult",
    "DegradedServiceWarning", "FaultPlan", "NO_FAULTS",
    "session_cost_bytes",
    # async queue
    "RequestQueue", "WaveFuture", "WaveScheduler",
    # errors
    "BlestError", "GraphValidationError", "ConfigError", "AdmissionError",
    "QueueFullError", "DeadlineExceeded", "StaleEpochError",
    "KernelFaultError",
    # misc
    "VERBS", "__version__",
]
