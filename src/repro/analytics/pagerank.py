"""PageRank as a float-channel power iteration on the BVSS tiles
(DESIGN §2.9).

The pull form of one PageRank step is exactly the weighted tile product
the σ path-count channel already owns (``bvss_spmm_w``):

    r'[u] = (1 - d)/n + d · ( Σ_{v→u} r[v] / outdeg[v]  +  dangling/n )

where ``dangling = Σ_{outdeg[v]=0} r[v]`` redistributes the mass of sink
vertices uniformly (the classic dangling-mass correction — without it the
iteration leaks mass and converges to the wrong vector).  Every iteration
pulls the FULL tile stream (PageRank has no frontier: every vertex
contributes every round, so the static all-VSS queue replaces the
compactor), scatter-adds through ``row_ids``, applies the damping and
dangling terms, and tests the L1 residual ``Σ|r' - r|`` against ``tol``
— all inside ONE fused ``while_loop``, no host round-trips.

A row-sharded problem runs the same loop under ``shard_map``: the
per-vertex contribution values all-gather per iteration (the float twin
of the frontier-word gather), the dangling mass and the residual reduce
with ``psum``, so the convergence test stays replicated and every shard
leaves the loop together.  A 2-D problem is a typed
:class:`~repro.errors.ConfigError` (the weighted verbs ship 1-D;
DESIGN §2.9).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bfs import BlestProblem
from repro.errors import ConfigError
from repro.graphs import Graph
from repro.kernels import bvss_spmm_w_local
from repro.kernels.ref import bvss_spmm_w_ref

DAMPING = 0.85
TOL = 1e-8
MAX_ITER = 200


def out_degrees(g: Graph) -> np.ndarray:
    """Out-degree per vertex of ``g`` (float32) — the host-side operand
    PageRank normalises contributions with."""
    return np.diff(g.indptr).astype(np.float32)


def make_pagerank(problem: BlestProblem, outdeg: np.ndarray, *,
                  use_kernel: bool = True, damping: float = DAMPING,
                  tol: float = TOL, max_iter: int = MAX_ITER) -> Callable:
    """Build jitted ``f() -> r (n,) f32`` over ``problem`` (ids are the
    problem's own; ``outdeg`` in the same id space).  Single-device and
    1-D row-sharded; 2-D raises :class:`~repro.errors.ConfigError`."""
    if problem.mesh is not None:
        if problem.is_2d:
            raise ConfigError(
                "pagerank is not supported on a 2-D (row × column) mesh "
                "yet — the weighted verbs ship 1-D row-sharded "
                "(DESIGN §2.9)")
        return _make_pagerank_sharded(problem, outdeg,
                                      use_kernel=use_kernel,
                                      damping=damping, tol=tol,
                                      max_iter=max_iter)
    return _make_pagerank_single(problem, outdeg, use_kernel=use_kernel,
                                 damping=damping, tol=tol,
                                 max_iter=max_iter)


def _make_pagerank_single(p: BlestProblem, outdeg: np.ndarray, *,
                          use_kernel: bool, damping: float, tol: float,
                          max_iter: int) -> Callable:
    dev = p.dev
    n, sigma, n_sets = p.n, p.sigma, p.n_sets
    ncols = n_sets * sigma
    impl = None if use_kernel else bvss_spmm_w_ref
    # PageRank has no frontier: the static full queue replaces the
    # compactor (every VSS pulls every iteration).  An edgeless graph has
    # zero VSS — pull the all-zero dummy row so the tile batch is never
    # empty (it contributes nothing, like the compactor's dummy padding)
    Q = jnp.arange(max(p.num_vss, 1), dtype=jnp.int32)
    masks = dev.masks[Q]
    sets = dev.virtual_to_real[Q]
    rows = dev.row_ids[Q].reshape(-1)                    # dummy = n
    deg = jnp.zeros((ncols,), jnp.float32).at[:n].set(jnp.asarray(outdeg))
    valid = jnp.arange(ncols) < n
    dangling = valid & (deg == 0.0)
    d = jnp.float32(damping)
    base = jnp.float32((1.0 - damping) / n)

    def step(r: jnp.ndarray) -> jnp.ndarray:
        x = jnp.where(deg > 0, r / deg, 0.0)             # (ncols,)
        y = bvss_spmm_w_local(masks, sets, x[:, None], sigma=sigma,
                              impl=impl)
        acc = jnp.zeros((ncols, 1), jnp.float32).at[rows].add(
            y.reshape(-1, 1), mode="drop")[:, 0]
        dm = jnp.sum(jnp.where(dangling, r, 0.0))
        return jnp.where(valid, base + d * (acc + dm / n), 0.0)

    def pagerank() -> jnp.ndarray:
        r0 = jnp.where(valid, jnp.float32(1.0 / n), 0.0)

        def body(carry):
            r, _, it = carry
            r2 = step(r)
            return r2, jnp.sum(jnp.abs(r2 - r)), it + 1

        r, _, _ = jax.lax.while_loop(
            lambda c: (c[1] > tol) & (c[2] < max_iter),
            body, (r0, jnp.float32(jnp.inf), jnp.int32(0)))
        return r[:n]

    return jax.jit(pagerank)


def _make_pagerank_sharded(p: BlestProblem, outdeg: np.ndarray, *,
                           use_kernel: bool, damping: float, tol: float,
                           max_iter: int) -> Callable:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.bvss import ShardedBVSSDevice
    from repro.distributed.bfs_dist import problem_specs

    mesh, axis = p.mesh, p.axis
    n, sigma = p.n, p.sigma
    rps = p.rows_per_shard
    D = p.n_shards
    impl = None if use_kernel else bvss_spmm_w_ref
    dfac = jnp.float32(damping)
    base = jnp.float32((1.0 - damping) / n)
    # out-degrees blocked by the row partition, one (rps,) block per shard
    deg_blocks = np.zeros((D, rps), np.float32)
    deg_blocks.reshape(-1)[:n] = np.asarray(outdeg, np.float32)

    def local_loop(masks, row_ids, v2r, vstart, vend, degb):
        dev = ShardedBVSSDevice(masks[0], row_ids[0], v2r[0],
                                vstart[0], vend[0])
        deg = degb[0]                                    # (rps,) local
        Q = jnp.arange(max(p.num_vss, 1), dtype=jnp.int32)
        qmasks = dev.masks[Q]
        sets = dev.virtual_to_real[Q]
        rows = dev.row_ids[Q].reshape(-1)                # LOCAL, dummy=rps
        didx = jax.lax.axis_index(axis)
        lvalid = (didx * rps + jnp.arange(rps)) < n
        dangling = lvalid & (deg == 0.0)

        def step(r: jnp.ndarray) -> jnp.ndarray:
            # the float twin of the frontier-word gather: every shard
            # pulls from the GLOBAL contribution vector
            xv = jnp.where(deg > 0, r / deg, 0.0)        # (rps,) local
            xg = jax.lax.all_gather(xv, axis, tiled=True)  # (D·rps,)
            y = bvss_spmm_w_local(qmasks, sets, xg[:, None], sigma=sigma,
                                  impl=impl)
            acc = jnp.zeros((rps + 1, 1), jnp.float32).at[rows].add(
                y.reshape(-1, 1), mode="drop")[:rps, 0]
            dm = jax.lax.psum(jnp.sum(jnp.where(dangling, r, 0.0)), axis)
            return jnp.where(lvalid, base + dfac * (acc + dm / n), 0.0)

        def body(carry):
            r, _, it = carry
            r2 = step(r)
            resid = jax.lax.psum(jnp.sum(jnp.abs(r2 - r)), axis)
            return r2, resid, it + 1

        r0 = jnp.where(lvalid, jnp.float32(1.0 / n), 0.0)
        r, _, _ = jax.lax.while_loop(
            lambda c: (c[1] > tol) & (c[2] < max_iter),
            body, (r0, jnp.float32(jnp.inf), jnp.int32(0)))
        return r[None, :]

    fn = shard_map(local_loop, mesh=mesh,
                   in_specs=problem_specs(axis) + (P(axis),),
                   out_specs=P(axis), check_rep=False)

    def pagerank() -> jnp.ndarray:
        out = fn(p.dev.masks, p.dev.row_ids, p.dev.virtual_to_real,
                 p.dev.vss_of_vertex_start, p.dev.vss_of_vertex_end,
                 jnp.asarray(deg_blocks))
        return out.reshape(-1)[:n]

    return jax.jit(pagerank)


def pagerank_scores(g: Graph | None = None, *,
                    problem: BlestProblem | None = None,
                    outdeg: np.ndarray | None = None,
                    use_kernel: bool = True, damping: float = DAMPING,
                    tol: float = TOL, max_iter: int = MAX_ITER,
                    pagerank_fn: Callable | None = None) -> np.ndarray:
    """PageRank scores (n,) float64 summing to 1, ids the problem's own.
    ``pagerank_fn`` is an optional prebuilt engine (sessions pass their
    cached one)."""
    if pagerank_fn is None:
        if problem is None:
            from repro.core.bvss import build_bvss
            if g is None:
                raise ValueError("need one of g / problem / pagerank_fn")
            problem = BlestProblem.build(build_bvss(g))
        if outdeg is None:
            if g is None:
                raise ValueError("pagerank needs out-degrees: pass g or "
                                 "outdeg")
            outdeg = out_degrees(g)
        pagerank_fn = make_pagerank(problem, outdeg, use_kernel=use_kernel,
                                    damping=damping, tol=tol,
                                    max_iter=max_iter)
    return np.asarray(pagerank_fn()).astype(np.float64)
