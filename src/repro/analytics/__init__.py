"""Graph analytics riding the BVSS multi-source wave engine (DESIGN §2.6).

Every algorithm here is a *client* of the machinery the BFS stack already
owns — the batched bit-SpMM wave engine (``core.multi_source``), the fused
``LevelPipeline`` loop, and the weighted BVSS tile products
(``kernels.bvss_spmm_w`` / ``bvss_spmm_t`` / ``bvss_spmm_minplus``) —
never a bespoke traversal:

* :mod:`~repro.analytics.components` — connected components as batched
  flood-fill with iterative re-seeding through the generic wave refill
  hook (``drive_wave``);
* :mod:`~repro.analytics.eccentricity` — per-vertex eccentricity,
  diameter and radius via iFUB-style sweeps batched through the fused
  multi-source engine;
* :mod:`~repro.analytics.betweenness` — Brandes betweenness centrality:
  forward phase is the fused BFS with σ path counts threaded through the
  widened wave state, backward dependency accumulation replays the
  recorded per-level VSS queues in reverse over the same tiles (sharded:
  per-shard histories + a psum-scattered column reduction — no
  replicated weighted sweeps);
* :mod:`~repro.analytics.closeness` — exact and sampled closeness
  centrality as a reduction over wave level channels;
* :mod:`~repro.analytics.sssp` — delta-stepping single-source shortest
  paths: bucketed label-correcting waves through the min-plus tile
  product against the edge-weight plane (DESIGN §2.9);
* :mod:`~repro.analytics.pagerank` — PageRank as float-channel power
  iteration over the weighted tile product, dangling-mass correction and
  L1 convergence fused into one device loop (DESIGN §2.9).

All functions speak the id space of the problem/graph they are handed;
``repro.serve.GraphSession`` layers the caller-id contract, symmetrised
problems and mesh sharding on top.
"""
from repro.analytics.betweenness import betweenness_centrality, make_betweenness
from repro.analytics.closeness import (closeness_centrality,
                                       closeness_from_levels)
from repro.analytics.components import connected_components
from repro.analytics.eccentricity import (ExtremesReport, eccentricities,
                                          ifub_extremes)
from repro.analytics.pagerank import (make_pagerank, out_degrees,
                                      pagerank_scores)
from repro.analytics.sssp import default_delta, make_sssp, sssp_distances

__all__ = ["betweenness_centrality", "make_betweenness",
           "closeness_centrality", "closeness_from_levels",
           "connected_components", "eccentricities", "ifub_extremes",
           "ExtremesReport", "make_sssp", "sssp_distances", "default_delta",
           "make_pagerank", "pagerank_scores", "out_degrees"]
