"""Shared helpers for the analytics wave clients."""
from __future__ import annotations

import numpy as np


def pad_cohort(chunk: np.ndarray, width: int) -> np.ndarray:
    """Pad a tail cohort to the fixed wave width by repeating its last
    source (callers drop the padded columns' results).  Repetition — not
    e.g. vertex 0 — keeps padded columns converging no later than the
    real ones."""
    if len(chunk) >= width:
        return chunk
    return np.concatenate([chunk, np.repeat(chunk[-1:], width - len(chunk))])
