"""Closeness centrality by reducing wave distance channels (DESIGN §2.6).

Closeness is the purest wave client: a batch of sources is one
fixed-cohort multi-source run (S stacked bit-SpMM columns through the
fused BVSS engine), and each column's closeness is a reduction of its
level channel —

    c(s) = (reach(s) - 1) / Σ_{v reachable} d(s, v)

with c(s) = 0 when s reaches nothing (isolated vertices), distances taken
OUTWARD over the problem as given (symmetrise first for the classical
undirected definition; on a symmetric problem this equals NetworkX's
``closeness_centrality(G, wf_improved=False)``).  ``wf_improved`` applies
the Wasserman–Faust scaling ``(reach - 1) / (n - 1)``, which makes scores
comparable across components (NetworkX's default).

*Exact* closeness (``sources=None``) evaluates every vertex — n BFS
columns in cohorts of ``batch``; *sampled* closeness evaluates only the
given pivots (the paper §7 use case: the scores of a source sample).
Mesh-native for free: a sharded problem runs the same cohorts through the
shard_map'd engine (``make_multi_source_bfs``), and the reduction sees
only the global ``(n, S)`` level channel.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.analytics.common import pad_cohort
from repro.core.bfs import BlestProblem
from repro.core.multi_source import INF, make_multi_source_bfs
from repro.graphs import Graph


def closeness_from_levels(levels: np.ndarray, *,
                          wf_improved: bool = False) -> np.ndarray:
    """Reduce one cohort's ``(n, S)`` wave level channel to the S source
    columns' closeness scores (float64)."""
    levels = np.asarray(levels)
    n = levels.shape[0]
    finite = levels != INF
    dist_sum = np.where(finite, levels, 0).sum(axis=0).astype(np.float64)
    reach = finite.sum(axis=0).astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        cc = np.where(dist_sum > 0, (reach - 1) / dist_sum, 0.0)
    if wf_improved and n > 1:
        cc = cc * (reach - 1) / (n - 1)
    return cc


def closeness_centrality(g: Graph | None = None,
                         sources: Sequence[int] | np.ndarray | None = None,
                         *,
                         problem: BlestProblem | None = None,
                         batch: int | None = None,
                         use_kernel: bool = True,
                         wf_improved: bool = False,
                         levels_fn: Callable | None = None) -> np.ndarray:
    """Closeness centrality, exact or sampled.

    ``sources=None`` evaluates EVERY vertex (exact closeness, one score
    per vertex in id order); otherwise one score per given source,
    aligned.  Ids are those of ``g`` / ``problem``.  ``levels_fn`` is an
    optional prebuilt fixed-cohort multi-source
    ``f(sources (batch,)) -> levels (n, batch)`` over the same problem
    (sessions pass their cached one; its width must equal ``batch``).
    """
    if problem is None and levels_fn is None:
        from repro.core.bvss import build_bvss
        if g is None:
            raise ValueError("need one of g / problem / levels_fn")
        problem = BlestProblem.build(build_bvss(g))
    if sources is None:
        if problem is not None:
            n = problem.n
        elif g is not None:
            n = g.n
        else:
            raise ValueError("exact closeness (sources=None) needs the "
                             "vertex count: pass g or problem")
        sources = np.arange(n, dtype=np.int64)
    sources = np.asarray(sources, dtype=np.int64)
    if len(sources) == 0:
        return np.zeros(0, dtype=np.float64)
    S = batch if batch is not None else min(8, len(sources))
    if levels_fn is None:
        levels_fn = make_multi_source_bfs(None, S, problem=problem,
                                          use_kernel=use_kernel)
    out = np.empty(len(sources), dtype=np.float64)
    for lo in range(0, len(sources), S):
        chunk = sources[lo:lo + S]
        valid = len(chunk)
        levels = np.asarray(levels_fn(
            jnp.asarray(pad_cohort(chunk, S), dtype=jnp.int32)))
        out[lo:lo + valid] = closeness_from_levels(
            levels, wf_improved=wf_improved)[:valid]
    return out
