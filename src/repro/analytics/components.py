"""Connected components as batched wave flood-fill (DESIGN §2.6).

The algorithm is the serving loop wearing a different hat: seed up to S
wave columns with vertices no flood has touched yet, advance all floods in
lock-step through the one batched bit-SpMM pull per level, and every time
a column converges, harvest its reach set and *re-seed the freed slot with
the next untouched vertex* — the same mid-flight refill contract
(:func:`repro.core.multi_source.drive_wave`) that serves level queries,
with the refill hook drawing from the shrinking untouched set instead of a
request queue.

Two floods seeded concurrently may land in the same component; overlapping
reach sets are merged with a union-find over flood ids at harvest time, so
the result is exact regardless of seeding order.  On a symmetric problem
(the classical undirected reading — what ``GraphSession.components``
builds) every flood covers its whole component and merges are rare; the
algorithm is also correct on a directed problem (floods follow out-edges,
overlap merging recovers WEAK connectivity) at the cost of more, smaller
floods.

Two refinements keep the wave from doing redundant work:

* the FIRST flood runs through the fused single-source engine (one device
  dispatch, no per-level host sync) — on the common giant-component
  topology this touches most of the graph at sequential-baseline cost
  before any wave spins up;
* each wave refill round is ONE fused ``insert_batch`` dispatch, so
  re-seeding S slots costs the same host round-trip as re-seeding one.

Labels are normalised to 0..k-1 in order of each component's smallest
vertex id (``kernels.ref.normalize_labels``), matching the SciPy oracle
``kernels.ref.connected_components_ref``.  Mesh-native: a sharded problem
drives the same loop through the shard_map'd wave surface.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.bfs import BlestProblem
from repro.core.multi_source import INF, MSEngine, drive_wave, make_ms_engine
from repro.graphs import Graph
from repro.kernels.ref import normalize_labels


def connected_components(g: Graph | None = None, *,
                         problem: BlestProblem | None = None,
                         engine: MSEngine | None = None,
                         first_flood: Callable | None = None,
                         max_batch: int = 8, use_kernel: bool = True,
                         symmetrize: bool = True) -> np.ndarray:
    """Component labels ``(n,)`` in the id space of ``g`` / ``problem``.

    Exactly one source of structure is used: an ``engine`` (reused wave
    slot pool, e.g. a session's), else a ``problem``, else ``g`` —
    symmetrised first by default so labels are classical (weak) components.
    When passing ``problem``/``engine`` the caller owns symmetrisation.
    ``first_flood`` is an optional prebuilt fused single-source
    ``f(src) -> levels`` over the same problem (sessions pass their cached
    one; built on the fly otherwise).
    """
    if engine is None:
        if problem is None:
            if g is None:
                raise ValueError("need one of g / problem / engine")
            from repro.core.bvss import build_bvss
            problem = BlestProblem.build(
                build_bvss(g.symmetrized if symmetrize else g))
        engine = make_ms_engine(problem, max_batch, use_kernel=use_kernel)
    problem = engine.problem
    n = problem.n
    if n == 0:
        return np.zeros(0, dtype=np.int64)

    touched = np.zeros(n, dtype=bool)   # seeded or inside a harvested flood
    vcomp = np.full(n, -1, dtype=np.int64)  # vertex -> flood id (pre-union)
    parent: list[int] = []              # union-find over flood ids
    slot_comp = [-1] * engine.n_slots
    # seeds are drawn in a fixed random order, NOT id order: the similarity
    # orderings co-locate each component's vertices in consecutive internal
    # ids, so an id-order cursor would drop a whole refill round of seeds
    # into one component; a shuffled cursor spreads the round across
    # components (duplicates stay correct via the union-find, just slower)
    seed_order = np.random.default_rng(0).permutation(n)
    scan = 0                            # monotone cursor into seed_order

    def find(c: int) -> int:
        while parent[c] != c:
            parent[c] = parent[parent[c]]
            c = parent[c]
        return c

    # phase 0: one fused single-source flood (whole loop on device) — on
    # giant-component topologies this covers most vertices at exactly the
    # sequential baseline's cost, before any wave column spins up
    if first_flood is None:
        from repro.core.bfs import make_blest_bfs
        first_flood = make_blest_bfs(problem, lazy=False,
                                     use_kernels=use_kernel)
    reach0 = np.asarray(first_flood(jnp.int32(0))) != INF
    parent.append(0)
    vcomp[reach0] = 0
    touched[reach0] = True
    if touched.all():  # connected graph: no wave needed
        return np.zeros(n, dtype=np.int64)

    # phase 1: wave flood-fill over whatever phase 0 left untouched at
    # full slot concurrency — concurrent floods that land in the same
    # component pull near-identical tile sets (the queue is the union of
    # the columns' slice sets), so duplicates cost little, and the
    # harvest-time union-find makes them exact
    def next_source(slot: int) -> int | None:
        nonlocal scan
        while scan < n and touched[seed_order[scan]]:
            scan += 1
        if scan >= n:
            return None
        v = int(seed_order[scan])
        touched[v] = True
        c = len(parent)
        parent.append(c)
        slot_comp[slot] = c
        vcomp[v] = c
        return v

    def on_converged(slot: int, levels: np.ndarray) -> None:
        reach = levels != INF
        c = find(slot_comp[slot])
        for pc in np.unique(vcomp[reach]):
            if pc >= 0:  # overlap with an earlier flood: same component
                r = find(int(pc))
                parent[r] = c
        vcomp[reach] = c
        touched[reach] = True

    # every vertex is seeded at most once, every flood converges within
    # its component's diameter + 1 levels
    drive_wave(engine, next_source, on_converged,
               max_steps=(n + engine.n_slots) * (n + 2))
    roots = np.array([find(int(c)) for c in vcomp], dtype=np.int64)
    return normalize_labels(roots)
