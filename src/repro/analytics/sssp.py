"""Delta-stepping single-source shortest paths on the wave surface
(DESIGN §2.9).

SSSP is the tropical-semiring instance of the wave machinery: swap the
Boolean pull tile for the min-plus product ``bvss_spmm_minplus`` (SlimSell's
algebraic formulation) and BFS levels become weighted distances.  The
driver is a bucketed label-correcting loop — batched delta-stepping:

* the OUTER loop owns a per-column bucket top ``btop``; every vertex with
  a settled distance below ``btop`` is final (positive weights: any
  shorter path runs entirely through already-settled vertices);
* the INNER loop relaxes the current bucket to a fixpoint: the frontier
  (vertices whose distance improved and sits below ``btop``) is compacted
  set-wise through the SAME ``make_compactor`` queue the BFS engines use,
  pulled through the min-plus tiles against the weight plane, and
  scatter-``min``'d into the distance vector;
* the bucket advance jumps ``btop`` to the bucket holding the smallest
  unsettled distance — empty buckets cost nothing, so the classic Δ
  trade-off (bucket width vs relaxation rounds) only shapes performance,
  never correctness.

Both loops fuse into ONE jitted ``while_loop`` nest per cohort of S
sources (S stacked distance columns through one tile stream).  A
row-sharded problem runs the identical loop under ``shard_map``: local
rows scatter locally, the frontier's distance values all-gather per
relaxation (the float twin of the frontier-word gather, hoisted out of
the width ``cond``), and continuation / bucket minima reduce with
``psum`` / ``pmin`` so every shard stays in lock-step.  A 2-D problem is
a typed :class:`~repro.errors.ConfigError` (the weighted verbs ship 1-D;
DESIGN §2.9).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics.common import pad_cohort
from repro.core.bfs import (BlestProblem, make_compactor, queue_widths,
                            select_width)
from repro.errors import ConfigError
from repro.kernels import bvss_spmm_minplus_local
from repro.kernels.ref import bvss_spmm_minplus_ref


def default_delta(weights: np.ndarray) -> float:
    """Bucket width heuristic: the mean edge weight (classic delta-stepping
    uses Δ ≈ mean weight for random weights; correctness never depends on
    the choice — see the module docstring)."""
    w = np.asarray(weights, dtype=np.float64)
    return float(w.mean()) if w.size else 1.0


def _next_btop(rem: jnp.ndarray, btop: jnp.ndarray,
               delta: jnp.ndarray) -> jnp.ndarray:
    """Advance each column's bucket top past its smallest unsettled
    distance ``rem`` (jumping empty buckets); columns with no unsettled
    vertex (rem = +inf) keep their top.  ``nextafter`` guards the
    floating-point edge where the bucket formula lands exactly ON ``rem``
    (Δ much smaller than the distance scale) — the top must STRICTLY
    exceed ``rem`` or the frontier goes empty without progress."""
    nbt = (jnp.floor(rem / delta) + 1.0) * delta
    nbt = jnp.maximum(nbt, jnp.nextafter(rem, jnp.inf))
    return jnp.where(jnp.isfinite(rem), nbt, btop)


def make_sssp(problem: BlestProblem, wplane: jnp.ndarray, n_sources: int, *,
              use_kernel: bool = True, buckets: int = 2,
              max_rounds: int | None = None) -> Callable:
    """Build jitted ``f(sources (S,) i32, delta () f32) -> dist (n, S) f32``
    over ``problem`` (ids are the problem's own).  ``wplane`` is the
    device weight plane ``prepare(..., weights=...)`` committed
    (``PreparedBFS.wplane``); its dummy row makes padded queue entries
    relax nothing.  Single-device and 1-D row-sharded; 2-D raises
    :class:`~repro.errors.ConfigError`."""
    if wplane is None:
        raise ConfigError(
            "sssp needs a weight plane: prepare(..., weights=...) or let "
            "GraphSession default to unit weights")
    if problem.mesh is not None:
        if problem.is_2d:
            raise ConfigError(
                "sssp is not supported on a 2-D (row × column) mesh yet — "
                "the weighted verbs ship 1-D row-sharded (DESIGN §2.9)")
        return _make_sssp_sharded(problem, wplane, n_sources,
                                  use_kernel=use_kernel, buckets=buckets,
                                  max_rounds=max_rounds)
    return _make_sssp_single(problem, wplane, n_sources,
                             use_kernel=use_kernel, buckets=buckets,
                             max_rounds=max_rounds)


def _make_sssp_single(p: BlestProblem, wplane: jnp.ndarray, n_sources: int,
                      *, use_kernel: bool, buckets: int,
                      max_rounds: int | None) -> Callable:
    dev = p.dev
    n, sigma, n_sets = p.n, p.sigma, p.n_sets
    S = n_sources
    ncols = n_sets * sigma
    widths = queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    compact = make_compactor(dev, p.num_vss, qcap)
    impl = None if use_kernel else bvss_spmm_minplus_ref
    valid = jnp.arange(ncols) < n                        # padding columns
    cap = max_rounds if max_rounds is not None else n + 2

    def relax(dist: jnp.ndarray, fr: jnp.ndarray
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
        """One frontier relaxation: compacted min-plus pull + scatter-min.
        Returns (new dist, improved mask)."""
        set_active = fr.reshape(n_sets, sigma, S).any(axis=(1, 2))
        Q, count = compact(set_active)
        xg = jnp.where(fr, dist, jnp.inf)                # (ncols, S)

        def pull(w: int) -> jnp.ndarray:
            ids = jax.lax.slice_in_dim(Q, 0, w)
            y = bvss_spmm_minplus_local(
                dev.masks[ids], wplane[ids], dev.virtual_to_real[ids], xg,
                sigma=sigma, impl=impl)
            rows = dev.row_ids[ids].reshape(-1)          # dummy = n
            return dist.at[rows].min(y.reshape(-1, S))

        d2 = select_width(widths, count, pull)
        # dummy-row scatters may land in padding columns (row n < ncols):
        # wipe them so the padding never re-enters a gather as a distance
        d2 = jnp.where(valid[:, None], d2, jnp.inf)
        return d2, d2 < dist

    def sssp(sources: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
        cols = jnp.arange(S)
        dist = jnp.full((ncols, S), jnp.inf, jnp.float32)
        dist = dist.at[sources, cols].set(0.0)
        fr = jnp.zeros((ncols, S), bool).at[sources, cols].set(True)
        btop = jnp.broadcast_to(delta.astype(jnp.float32), (S,))

        def outer_body(carry):
            dist, fr, btop, rounds = carry

            def inner(c):
                dist, fr, it = c
                d2, improved = relax(dist, fr)
                return d2, improved & (d2 < btop[None, :]), it + 1

            dist, fr, _ = jax.lax.while_loop(
                lambda c: c[1].any() & (c[2] < cap),
                inner, (dist, fr, jnp.int32(0)))
            unsettled = jnp.where(valid[:, None] & (dist >= btop[None, :]),
                                  dist, jnp.inf)
            rem = jnp.min(unsettled, axis=0)             # (S,)
            nbt = _next_btop(rem, btop, delta.astype(jnp.float32))
            fr = (valid[:, None] & (dist >= btop[None, :])
                  & (dist < nbt[None, :]))
            return dist, fr, nbt, rounds + 1

        dist, _, _, _ = jax.lax.while_loop(
            lambda c: c[1].any() & (c[3] < cap),
            outer_body, (dist, fr, btop, jnp.int32(0)))
        return dist[:n]

    return jax.jit(sssp)


def _make_sssp_sharded(p: BlestProblem, wplane: jnp.ndarray, n_sources: int,
                      *, use_kernel: bool, buckets: int,
                      max_rounds: int | None) -> Callable:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.bvss import ShardedBVSSDevice
    from repro.core.level_pipeline import global_any
    from repro.distributed.bfs_dist import problem_specs

    mesh, axis = p.mesh, p.axis
    n, sigma, n_sets = p.n, p.sigma, p.n_sets
    rps = p.rows_per_shard
    S = n_sources
    ncols = n_sets * sigma
    widths = queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    impl = None if use_kernel else bvss_spmm_minplus_ref
    cap = max_rounds if max_rounds is not None else n + 2

    def local_loop(masks, row_ids, v2r, vstart, vend, wpl, sources, delta):
        dev = ShardedBVSSDevice(masks[0], row_ids[0], v2r[0],
                                vstart[0], vend[0])
        wp = wpl[0]
        compact = make_compactor(dev, p.num_vss, qcap)
        d = jax.lax.axis_index(axis)
        lvalid = (d * rps + jnp.arange(rps)) < n         # real local rows
        rowmask = jnp.concatenate([lvalid, jnp.zeros((1,), bool)])
        delta32 = delta.astype(jnp.float32)

        def relax(dist: jnp.ndarray, fr: jnp.ndarray
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
            # the float twin of the frontier-word gather: every shard
            # needs the frontier's distance VALUES for its global columns
            # — all-gathered BEFORE the width cond (no collectives in
            # device-varying branches)
            xv = jnp.where(fr, dist[:rps], jnp.inf)      # (rps, S)
            xg = jax.lax.all_gather(xv, axis, tiled=True)  # (D·rps, S)
            set_active = (xg[:ncols].reshape(n_sets, sigma, S)
                          < jnp.inf).any(axis=(1, 2))
            Q, count = compact(set_active)

            def pull(w: int) -> jnp.ndarray:
                ids = jax.lax.slice_in_dim(Q, 0, w)
                y = bvss_spmm_minplus_local(
                    dev.masks[ids], wp[ids], dev.virtual_to_real[ids], xg,
                    sigma=sigma, impl=impl)
                rows = dev.row_ids[ids].reshape(-1)      # LOCAL, dummy=rps
                return dist.at[rows].min(y.reshape(-1, S))

            d2 = select_width(widths, count, pull)
            d2 = jnp.where(rowmask[:, None], d2, jnp.inf)
            return d2, d2 < dist

        def sssp_local(sources: jnp.ndarray) -> jnp.ndarray:
            cols = jnp.arange(S)
            lsrc = sources - d * rps
            own = (lsrc >= 0) & (lsrc < rps)
            dist = jnp.full((rps + 1, S), jnp.inf, jnp.float32)
            dist = dist.at[jnp.where(own, lsrc, rps), cols].set(
                jnp.where(own, 0.0, jnp.inf))
            fr = jnp.zeros((rps, S), bool).at[
                jnp.where(own, lsrc, 0), cols].set(own)
            btop = jnp.broadcast_to(delta32, (S,))

            # the repo's lock-step idiom: while_loop conds read a CARRIED
            # replicated cont flag; the global_any reduction runs in the
            # body (never in a cond)
            def outer_body(carry):
                dist, fr, btop, cont, rounds = carry

                def inner(c):
                    dist, fr, cont, it = c
                    d2, improved = relax(dist, fr)
                    fr2 = improved[:rps] & (d2[:rps] < btop[None, :])
                    return (d2, fr2, global_any(fr2.any(), axis), it + 1)

                dist, fr, _, _ = jax.lax.while_loop(
                    lambda c: c[2] & (c[3] < cap),
                    inner, (dist, fr, global_any(fr.any(), axis),
                            jnp.int32(0)))
                unsettled = jnp.where(
                    lvalid[:, None] & (dist[:rps] >= btop[None, :]),
                    dist[:rps], jnp.inf)
                rem = jax.lax.pmin(jnp.min(unsettled, axis=0), axis)
                nbt = _next_btop(rem, btop, delta32)
                fr = (lvalid[:, None] & (dist[:rps] >= btop[None, :])
                      & (dist[:rps] < nbt[None, :]))
                return (dist, fr, nbt, global_any(fr.any(), axis),
                        rounds + 1)

            dist, _, _, _, _ = jax.lax.while_loop(
                lambda c: c[3] & (c[4] < cap),
                outer_body, (dist, fr, btop, global_any(fr.any(), axis),
                             jnp.int32(0)))
            return dist[None, :rps]

        return sssp_local(sources)

    fn = shard_map(local_loop, mesh=mesh,
                   in_specs=problem_specs(axis) + (P(axis), P(), P()),
                   out_specs=P(axis), check_rep=False)

    def sssp(sources: jnp.ndarray, delta: jnp.ndarray) -> jnp.ndarray:
        out = fn(p.dev.masks, p.dev.row_ids, p.dev.virtual_to_real,
                 p.dev.vss_of_vertex_start, p.dev.vss_of_vertex_end,
                 wplane, jnp.asarray(sources, jnp.int32),
                 jnp.asarray(delta, jnp.float32))
        return out.reshape(-1, S)[:p.n]

    return jax.jit(sssp)


def sssp_distances(sources: Sequence[int] | np.ndarray, *,
                   problem: BlestProblem, wplane: jnp.ndarray,
                   weights: np.ndarray, batch: int | None = None,
                   use_kernel: bool = True,
                   delta: float | None = None,
                   sssp_fn: Callable | None = None) -> np.ndarray:
    """Distances from each source (rows) to every vertex (cols): (S, n)
    float64, +inf where unreachable.  Ids are the problem's own.
    ``sssp_fn`` is an optional prebuilt engine of width ``batch``
    (sessions pass their cached one)."""
    sources = np.asarray(sources, dtype=np.int64)
    if len(sources) == 0:
        return np.zeros((0, problem.n), dtype=np.float64)
    S = batch if batch is not None else min(8, len(sources))
    if delta is None:
        delta = default_delta(weights)
    if sssp_fn is None:
        sssp_fn = make_sssp(problem, wplane, S, use_kernel=use_kernel)
    out = np.empty((len(sources), problem.n), dtype=np.float64)
    for lo in range(0, len(sources), S):
        chunk = sources[lo:lo + S]
        dist = np.asarray(sssp_fn(
            jnp.asarray(pad_cohort(chunk, S), dtype=jnp.int32),
            jnp.float32(delta)))
        out[lo:lo + len(chunk)] = dist.T[:len(chunk)]
    return out
