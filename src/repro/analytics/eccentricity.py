"""Eccentricity, diameter and radius via batched wave sweeps (DESIGN §2.6).

The eccentricity of v is the max *finite* BFS distance from v (so it is
well-defined per component; isolated vertices get 0).  A batch of
eccentricity queries is one fixed-cohort multi-source run: S sources
stacked as wave columns through the fused BVSS bit-SpMM engine
(``make_multi_source_bfs``), one level array per column, ecc = max finite
level — S adjacency-sharing BFSs for the price of one sweep, single-device
or mesh-sharded identically.

Diameter/radius use the iFUB scheme (the basis of NetworkX's exact
diameter): a double sweep from a high-degree vertex finds a far vertex r
and a diameter lower bound; then the BFS fringes of r are processed in
DECREASING depth order, batching each fringe through the multi-source
engine, until lb > 2·i proves no unevaluated vertex (all at depth ≤ i)
can route a longer shortest path.  On the
benchmark families this certifies the exact diameter after evaluating a
small fraction of vertices; an eval budget turns the result into
explicit (lb, ub) bounds.  iFUB's termination argument needs symmetry —
hand it a symmetrised problem (``GraphSession.extremes`` does).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.analytics.common import pad_cohort
from repro.core.bfs import BlestProblem
from repro.core.multi_source import INF, make_multi_source_bfs
from repro.graphs import Graph


def _ecc_fn(problem: BlestProblem, batch: int, use_kernel: bool,
            levels_fn: Callable | None = None) -> Callable:
    f = levels_fn if levels_fn is not None else make_multi_source_bfs(
        None, batch, problem=problem, use_kernel=use_kernel)

    def ecc_batch(sources: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(eccs, levels) of one padded cohort; levels (n, S) int32."""
        levels = np.asarray(f(jnp.asarray(sources, dtype=jnp.int32)))
        finite = np.where(levels != INF, levels, 0)
        return finite.max(axis=0).astype(np.int64), levels

    return ecc_batch


def eccentricities(sources: Sequence[int] | np.ndarray, *,
                   g: Graph | None = None,
                   problem: BlestProblem | None = None,
                   batch: int = 8, use_kernel: bool = True,
                   levels_fn: Callable | None = None) -> np.ndarray:
    """Eccentricity of each source (ids of ``g``/``problem``), processed
    in fixed cohorts of ``batch`` stacked wave columns.  Pass a symmetric
    graph/problem for the classical undirected definition (otherwise this
    is out-eccentricity).  ``levels_fn`` is an optional prebuilt
    fixed-cohort multi-source ``f(sources (batch,)) -> levels (n, batch)``
    over the same problem (sessions pass their cached one; its width must
    equal ``batch``)."""
    if problem is None and levels_fn is None:
        from repro.core.bvss import build_bvss
        problem = BlestProblem.build(build_bvss(g))
    sources = np.asarray(sources, dtype=np.int64)
    if len(sources) == 0:
        return np.zeros(0, dtype=np.int64)
    S = batch if levels_fn is not None else min(batch, len(sources))
    ecc_batch = _ecc_fn(problem, S, use_kernel, levels_fn)
    out = np.empty(len(sources), dtype=np.int64)
    for lo in range(0, len(sources), S):
        chunk = sources[lo:lo + S]
        valid = len(chunk)
        out[lo:lo + valid] = ecc_batch(pad_cohort(chunk, S))[0][:valid]
    return out


@dataclasses.dataclass(frozen=True)
class ExtremesReport:
    """iFUB result: exact when ``diameter_lb == diameter_ub``."""

    diameter_lb: int
    diameter_ub: int
    radius_ub: int        # min eccentricity among evaluated vertices
    center: int           # vertex achieving radius_ub
    periphery: int        # vertex achieving diameter_lb's eccentricity
    n_ecc_evals: int      # BFS-equivalents spent (each = one wave column)

    @property
    def exact(self) -> bool:
        return self.diameter_lb == self.diameter_ub

    @property
    def diameter(self) -> int:
        """The certified diameter (raises if only bounds are known)."""
        if not self.exact:
            raise ValueError(
                f"diameter not certified: bounds "
                f"[{self.diameter_lb}, {self.diameter_ub}]")
        return self.diameter_lb


def ifub_extremes(g: Graph | None = None, *,
                  problem: BlestProblem | None = None,
                  start: int | None = None, batch: int = 8,
                  use_kernel: bool = True, max_evals: int | None = None,
                  levels_fn: Callable | None = None) -> ExtremesReport:
    """iFUB diameter (+ radius upper bound) of ``start``'s component.

    ``start`` defaults to a max-degree vertex (needs ``g``; pass an
    explicit ``start`` when handing only a ``problem``).  ``max_evals``
    caps eccentricity evaluations; when exhausted the report carries
    bounds instead of a certified diameter.
    """
    if problem is None and levels_fn is None:
        from repro.core.bvss import build_bvss
        gs = g.symmetrized
        problem = BlestProblem.build(build_bvss(gs))
        g = gs
    if start is None:
        if g is None:
            raise ValueError("need g (for the degree seed) or start")
        start = int(np.argmax(g.out_degree + g.in_degree))
    S = batch
    ecc_batch = _ecc_fn(problem, S, use_kernel, levels_fn)

    def pad(chunk: np.ndarray) -> np.ndarray:
        return pad_cohort(chunk, S)

    # double sweep: ecc(start), then BFS from a farthest vertex r
    eccs, levels = ecc_batch(pad(np.array([start])))
    ecc_u = int(eccs[0])
    finite_u = np.where(levels[:, 0] != INF, levels[:, 0], -1)
    r = int(np.argmax(finite_u))
    eccs, levels = ecc_batch(pad(np.array([r])))
    ecc_r = int(eccs[0])
    lr = levels[:, 0]

    lb = max(ecc_u, ecc_r)
    best_ecc = {start: ecc_u, r: ecc_r}
    evals = 2
    i = ecc_r
    budget_hit = False
    # invariant at the top of each iteration: every vertex DEEPER than i
    # (in the BFS from r) has been evaluated, so any pair routed through a
    # not-yet-evaluated vertex is bounded by 2·i — once lb beats that, lb
    # is the certified diameter
    while i >= 1 and lb <= 2 * i:
        fringe = np.flatnonzero(lr == i)
        for lo in range(0, len(fringe), S):
            chunk = fringe[lo:lo + S]
            valid = len(chunk)
            es = ecc_batch(pad(chunk))[0][:valid]
            for v, e in zip(chunk, es):
                best_ecc[int(v)] = int(e)
            lb = max(lb, int(es.max()))
            evals += valid
            if max_evals is not None and evals >= max_evals:
                budget_hit = True
                break
        if budget_hit:
            break
        i -= 1
    # unevaluated vertices sit at depth <= i (i reached 0 => none beyond
    # r itself, which is evaluated), so max(lb, 2·i) is always a sound
    # upper bound — and equals lb exactly when certification held
    ub = max(lb, 2 * i)
    radius_ub = min(best_ecc.values())
    center = min(best_ecc, key=lambda v: (best_ecc[v], v))
    periphery = max(best_ecc, key=lambda v: (best_ecc[v], -v))
    return ExtremesReport(diameter_lb=lb, diameter_ub=ub,
                          radius_ub=radius_ub, center=center,
                          periphery=periphery, n_ecc_evals=evals)
