"""Brandes betweenness centrality on the BVSS wave engine (DESIGN §2.6).

Brandes' algorithm per source s:

    forward   BFS from s recording levels d(v) and σ(v) shortest-path
              counts: σ(v) = Σ_{u ∈ pred(v)} σ(u);
    backward  dependency accumulation in decreasing level order:
              δ(v) = σ(v) · Σ_{w ∈ succ(v), d(w)=d(v)+1} (1 + δ(w)) / σ(w);
    bc(v)    += δ(v)  for v ≠ s.

Both phases are wave clients here, batched over S stacked sources:

* The FORWARD phase is the fused multi-source BFS with the σ channel
  threaded through the widened wave state
  (``make_ms_engine(track_sigma=True)``): each level runs the Boolean
  bit-SpMM pull (discovery) plus its weighted twin ``bvss_spmm_w`` over
  the SAME queued tiles (σ propagation), and records the per-level VSS
  queue into a :class:`~repro.core.bfs.QueueHistory`
  (``run_levels_recorded``) — one on-device ``while_loop``, no host sync.

* The BACKWARD phase replays that history in reverse: at level t the
  per-row values h(w) = [d(w)=t] · (1+δ(w))/σ(w) are gathered through
  ``row_ids`` and contracted by the *transposed* tile product
  ``bvss_spmm_t`` — the same BVSS tiles, contracted along the row axis
  instead of the column axis — then scattered into the slice-set columns
  and folded into δ at level t-1.  The recorded level-t queue is exactly
  the tile set whose columns meet the level-(t-1) frontier, so the
  reverse sweep is frontier-aware, not a full-BVSS sweep.

σ and δ are float32 (the MXU-native analytics dtype): path counts on the
benchmark families stay far below float32's 2^24 exact-integer range, and
the acceptance contract is oracle agreement within fp tolerance
(``kernels.ref.betweenness_ref``).

Single-device only: the weighted sweeps have no shard_map'd variant yet
(ROADMAP item) — a sharded ``GraphSession`` serves betweenness through a
replicated single-device problem built from its prepared host BVSS.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics.common import pad_cohort
from repro.core.bfs import BlestProblem, make_queue_history, queue_widths
from repro.core.level_pipeline import LevelPipeline, run_levels_recorded
from repro.core.multi_source import INF, make_ms_engine
from repro.graphs import Graph
from repro.kernels import bvss_spmm_t
from repro.kernels.ref import bvss_spmm_t_ref


def make_betweenness(problem: BlestProblem, n_sources: int, *,
                     use_kernel: bool = True, buckets: int = 2,
                     max_levels: int | None = None) -> Callable:
    """Build jitted ``f(sources (S,) i32) -> (levels (n,S), sigma (n,S),
    delta (n,S))`` running both Brandes phases on device.

    ``delta[:, j]`` is the dependency of every vertex on source ``j``
    (endpoints excluded: the source row is zeroed), so a caller sums
    columns over its source set to get partial betweenness.  ``max_levels``
    bounds the recorded history buffer ((max_levels+1) × qcap int32 —
    default n+1 is fine at lab scale, pass the graph's diameter bound to
    shrink it).
    """
    p = problem
    if p.mesh is not None:
        raise NotImplementedError(
            "betweenness runs the weighted sweeps single-device; build the "
            "problem from the host BVSS (see GraphSession.betweenness)")
    S = n_sources
    n, sigma = p.n, p.sigma
    dev = p.dev
    eng = make_ms_engine(p, S, use_kernel=use_kernel, buckets=buckets,
                         track_sigma=True)
    spmm_t = bvss_spmm_t if use_kernel else bvss_spmm_t_ref
    widths = queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    max_lv = max_levels if max_levels is not None else n + 1
    n_cols = p.n_sets * sigma
    hist0, record = make_queue_history(qcap, max_lv, p.num_vss)
    fwd_step, fwd_finalize = eng.step, eng.finalize
    assert fwd_step is not None and fwd_finalize is not None
    pipe = LevelPipeline(step=lambda s, lvl: fwd_step(s),
                         finalize=lambda s, lvl: fwd_finalize(s),
                         active=lambda s: s.cont)

    def backward(levels: jnp.ndarray, sig: jnp.ndarray, hist) -> jnp.ndarray:
        """Reverse per-level sweep over the recorded forward queues."""
        col_ids = (jnp.arange(sigma, dtype=jnp.int32)[None, :]
                   + jnp.zeros((qcap, 1), jnp.int32))

        def body(carry):
            delta, t = carry
            Q = jax.lax.dynamic_index_in_dim(hist.Q, t, keepdims=False)
            safe = jnp.maximum(sig, 1.0)
            h = jnp.where(levels == t, (1.0 + delta) / safe, 0.0)
            h = jnp.concatenate([h, jnp.zeros((1, S), jnp.float32)])
            hv = h[dev.row_ids[Q]]                    # (qcap, spw, 32, S)
            part = spmm_t(dev.masks[Q], hv, sigma=sigma)   # (qcap, σ, S)
            cols = dev.virtual_to_real[Q][:, None] * sigma + col_ids
            coeff = jnp.zeros((n_cols, S), jnp.float32).at[
                cols.reshape(-1)].add(part.reshape(-1, S))[:n]
            delta = delta + jnp.where(levels == t - 1, sig * coeff, 0.0)
            return delta, t - 1

        def cond(carry):
            return carry[1] >= 1

        delta0 = jnp.zeros((n, S), jnp.float32)
        tmax = jnp.where(levels == INF, 0, levels).max().astype(jnp.int32)
        delta, _ = jax.lax.while_loop(cond, body, (delta0, tmax))
        return delta

    def bc(sources: jnp.ndarray) -> tuple[jnp.ndarray, ...]:
        sources = jnp.asarray(sources, dtype=jnp.int32)
        st, _, hist = run_levels_recorded(
            pipe, eng.init(sources), max_levels=max_lv, history=hist0,
            record=record)
        levels = st.levels[:n]
        delta = backward(levels, st.paths, hist)
        # endpoints excluded: a source contributes no dependency to itself
        delta = delta.at[sources, jnp.arange(S)].set(0.0)
        return levels, st.paths, delta

    return jax.jit(bc)


def betweenness_centrality(g: Graph | None, sources, *,
                           problem: BlestProblem | None = None,
                           use_kernel: bool = True,
                           batch: int | None = None,
                           max_levels: int | None = None,
                           bc_fn: Callable | None = None) -> np.ndarray:
    """Partial Brandes betweenness Σ_{s∈sources} δ_s(v), unnormalised —
    the quantity ``kernels.ref.betweenness_ref`` computes (equal to
    NetworkX ``betweenness_centrality(normalized=False)`` on a DiGraph
    when ``sources`` is every vertex).

    ``sources`` are ids of ``g`` (or of the prepared graph when
    ``problem`` is passed); duplicates contribute once each.  Sources are
    processed in fixed cohorts of ``batch`` stacked wave columns (default
    min(8, len(sources))).  ``bc_fn`` is an optional prebuilt
    :func:`make_betweenness` callable of width ``batch`` (sessions pass
    their cached one).
    """
    if problem is None:
        from repro.core.bvss import build_bvss
        problem = BlestProblem.build(build_bvss(g))
    sources = np.asarray(sources, dtype=np.int32)
    if len(sources) == 0:
        return np.zeros(problem.n, dtype=np.float64)
    S = batch if batch is not None else min(8, len(sources))
    f = bc_fn if bc_fn is not None else make_betweenness(
        problem, S, use_kernel=use_kernel, max_levels=max_levels)
    bc = np.zeros(problem.n, dtype=np.float64)
    for lo in range(0, len(sources), S):
        chunk = sources[lo:lo + S]
        valid = len(chunk)  # tail cohorts are padded, padded cols dropped
        _, _, delta = f(jnp.asarray(pad_cohort(chunk, S)))
        bc += np.asarray(delta[:, :valid], dtype=np.float64).sum(axis=1)
    return bc
