"""Brandes betweenness centrality on the BVSS wave engine (DESIGN §2.6).

Brandes' algorithm per source s:

    forward   BFS from s recording levels d(v) and σ(v) shortest-path
              counts: σ(v) = Σ_{u ∈ pred(v)} σ(u);
    backward  dependency accumulation in decreasing level order:
              δ(v) = σ(v) · Σ_{w ∈ succ(v), d(w)=d(v)+1} (1 + δ(w)) / σ(w);
    bc(v)    += δ(v)  for v ≠ s.

Both phases are wave clients here, batched over S stacked sources:

* The FORWARD phase is the fused multi-source BFS with the σ channel
  threaded through the widened wave state
  (``make_ms_engine(track_sigma=True)``): each level runs the Boolean
  bit-SpMM pull (discovery) plus its weighted twin ``bvss_spmm_w`` over
  the SAME queued tiles (σ propagation), and records the per-level VSS
  queue into a :class:`~repro.core.bfs.QueueHistory`
  (``run_levels_recorded``) — one on-device ``while_loop``, no host sync.

* The BACKWARD phase replays that history in reverse: at level t the
  per-row values h(w) = [d(w)=t] · (1+δ(w))/σ(w) are gathered through
  ``row_ids`` and contracted by the *transposed* tile product
  ``bvss_spmm_t`` — the same BVSS tiles, contracted along the row axis
  instead of the column axis — then scattered into the slice-set columns
  and folded into δ at level t-1.  The recorded level-t queue is exactly
  the tile set whose columns meet the level-(t-1) frontier, so the
  reverse sweep is frontier-aware, not a full-BVSS sweep.

σ and δ are float32 (the MXU-native analytics dtype): path counts on the
benchmark families stay far below float32's 2^24 exact-integer range, and
the acceptance contract is oracle agreement within fp tolerance
(``kernels.ref.betweenness_ref``).

MESH-NATIVE (DESIGN §2.4/§2.6): on a row-sharded problem both phases run
under ``shard_map`` with ZERO replicated weighted sweeps.  Forward: the σ
channel rides the generic sharded float path of ``core.multi_source`` —
``paths`` and δ live as local ``(rps, S)`` row blocks, each level's
weighted pull consumes the per-level all-gather of the σ-frontier values,
and each shard records its OWN per-level queue history (the shard axis of
``QueueHistory``).  Backward: every shard replays its local history —
``h`` is built from local levels/σ/δ, contracted by ``bvss_spmm_t_local``
over the shard's tiles — and the column scatter is reduced across shards
with one ``lax.psum_scatter`` per level (a shard only sees the dependency
flowing through its own rows; the reduce-scatter hands each shard
exactly its row block of the global coefficient).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analytics.common import pad_cohort
from repro.core.bfs import (BlestProblem, QueueHistory, make_queue_history,
                            queue_widths)
from repro.core.bvss import ShardedBVSSDevice
from repro.core.level_pipeline import LevelPipeline, run_levels_recorded
from repro.core.multi_source import (INF, _make_ms_locals,
                                     _make_ms_locals_2d, make_ms_engine)
from repro.graphs import Graph
from repro.kernels import bvss_spmm, bvss_spmm_t, bvss_spmm_t_local, bvss_spmm_w
from repro.kernels.ref import bvss_spmm_ref, bvss_spmm_t_ref, bvss_spmm_w_ref


def make_betweenness(problem: BlestProblem, n_sources: int, *,
                     use_kernel: bool = True, buckets: int = 2,
                     max_levels: int | None = None,
                     spmm_w_impl: Callable | None = None) -> Callable:
    """Build jitted ``f(sources (S,) i32) -> (levels (n,S), sigma (n,S),
    delta (n,S))`` running both Brandes phases on device — under
    ``shard_map`` when ``problem`` is row-sharded (outputs stay global).

    ``delta[:, j]`` is the dependency of every vertex on source ``j``
    (endpoints excluded: the source row is zeroed), so a caller sums
    columns over its source set to get partial betweenness.  ``max_levels``
    bounds the recorded history buffer ((max_levels+1) × qcap int32 —
    default n+1 is fine at lab scale, pass the graph's diameter bound to
    shrink it).  ``spmm_w_impl`` overrides the weighted tile product —
    the σ-channel fault seam (DESIGN §2.7).
    """
    p = problem
    if p.mesh is not None:
        if p.is_2d:
            return _make_betweenness_sharded_2d(p, n_sources,
                                                use_kernel=use_kernel,
                                                buckets=buckets,
                                                max_levels=max_levels,
                                                spmm_w_impl=spmm_w_impl)
        return _make_betweenness_sharded(p, n_sources,
                                         use_kernel=use_kernel,
                                         buckets=buckets,
                                         max_levels=max_levels,
                                         spmm_w_impl=spmm_w_impl)
    S = n_sources
    n, sigma = p.n, p.sigma
    dev = p.dev
    eng = make_ms_engine(p, S, use_kernel=use_kernel, buckets=buckets,
                         track_sigma=True, spmm_w_impl=spmm_w_impl)
    spmm_t = bvss_spmm_t if use_kernel else bvss_spmm_t_ref
    widths = queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    max_lv = max_levels if max_levels is not None else n + 1
    n_cols = p.n_sets * sigma
    hist0, record = make_queue_history(qcap, max_lv, p.num_vss)
    fwd_step, fwd_finalize = eng.step, eng.finalize
    assert fwd_step is not None and fwd_finalize is not None
    pipe = LevelPipeline(step=lambda s, lvl: fwd_step(s),
                         finalize=lambda s, lvl: fwd_finalize(s),
                         active=lambda s: s.cont)

    def backward(levels: jnp.ndarray, sig: jnp.ndarray,
                 hist: QueueHistory) -> jnp.ndarray:
        """Reverse per-level sweep over the recorded forward queues."""
        col_ids = (jnp.arange(sigma, dtype=jnp.int32)[None, :]
                   + jnp.zeros((qcap, 1), jnp.int32))

        def body(carry: tuple[jnp.ndarray, jnp.ndarray]
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
            delta, t = carry
            Q = jax.lax.dynamic_index_in_dim(hist.Q, t, keepdims=False)
            safe = jnp.maximum(sig, 1.0)
            h = jnp.where(levels == t, (1.0 + delta) / safe, 0.0)
            h = jnp.concatenate([h, jnp.zeros((1, S), jnp.float32)])
            part = bvss_spmm_t_local(dev.masks[Q], dev.row_ids[Q], h,
                                     sigma=sigma, impl=spmm_t)  # (qcap,σ,S)
            cols = dev.virtual_to_real[Q][:, None] * sigma + col_ids
            coeff = jnp.zeros((n_cols, S), jnp.float32).at[
                cols.reshape(-1)].add(part.reshape(-1, S))[:n]
            delta = delta + jnp.where(levels == t - 1, sig * coeff, 0.0)
            return delta, t - 1

        def cond(carry: tuple[jnp.ndarray, jnp.ndarray]) -> jnp.ndarray:
            return carry[1] >= 1

        delta0 = jnp.zeros((n, S), jnp.float32)
        tmax = jnp.where(levels == INF, 0, levels).max().astype(jnp.int32)
        delta, _ = jax.lax.while_loop(cond, body, (delta0, tmax))
        return delta

    def bc(sources: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray,
                                          jnp.ndarray]:
        sources = jnp.asarray(sources, dtype=jnp.int32)
        st, _, hist = run_levels_recorded(
            pipe, eng.init(sources), max_levels=max_lv, history=hist0,
            record=record)
        levels = st.levels[:n]
        delta = backward(levels, st.paths, hist)
        # endpoints excluded: a source contributes no dependency to itself
        delta = delta.at[sources, jnp.arange(S)].set(0.0)
        return levels, st.paths, delta

    return jax.jit(bc)


def _make_betweenness_sharded(p: BlestProblem, n_sources: int, *,
                              use_kernel: bool, buckets: int,
                              max_levels: int | None,
                              spmm_w_impl: Callable | None = None
                              ) -> Callable:
    """Mesh-native Brandes: forward σ wave AND backward dependency sweep
    inside ONE ``shard_map`` dispatch over the row partition — no
    replicated weighted sweeps anywhere.

    Per shard: the forward phase is the shared sharded σ-channel locals
    (Boolean pull + weighted twin + per-level σ-frontier all-gather)
    recording the shard's OWN per-level queue; the backward phase replays
    that local history in reverse, and the per-level column scatter —
    which only covers dependency flowing through this shard's rows — is
    reduced across the mesh by ``lax.psum_scatter`` (each shard receives
    exactly its row block of the global coefficient, so δ stays a local
    ``(rps, S)`` block throughout).  ``lax.pmax`` aligns the backward
    level countdown so the collectives stay in lock-step.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.bfs_dist import problem_specs

    mesh, axis = p.mesh, p.axis
    S = n_sources
    sigma = p.sigma
    rps = p.rows_per_shard
    n_pad = p.n_fwords * 32           # D·rps ≥ n_sets·σ: global column pad
    spmm = bvss_spmm if use_kernel else bvss_spmm_ref
    spmm_w = spmm_w_impl if spmm_w_impl is not None else \
        (bvss_spmm_w if use_kernel else bvss_spmm_w_ref)
    spmm_t = bvss_spmm_t if use_kernel else bvss_spmm_t_ref
    widths = queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    max_lv = max_levels if max_levels is not None else p.n + 1
    locals_for = _make_ms_locals(p, S, spmm, widths, qcap, spmm_w=spmm_w,
                                 track_sigma=True)
    hist0, record = make_queue_history(qcap, max_lv, p.num_vss)

    def local_fn(masks: jnp.ndarray, row_ids: jnp.ndarray,
                 v2r: jnp.ndarray, vstart: jnp.ndarray, vend: jnp.ndarray,
                 sources: jnp.ndarray
                 ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        dev = ShardedBVSSDevice(masks[0], row_ids[0], v2r[0],
                                vstart[0], vend[0])
        loc = locals_for(dev)
        pipe = LevelPipeline(step=lambda s, lvl: loc.step(s),
                             finalize=lambda s, lvl: loc.finalize(s),
                             active=lambda s: s.cont)
        st, _, hist = run_levels_recorded(
            pipe, loc.init(sources), max_levels=max_lv, history=hist0,
            record=record)
        levels = st.levels[:rps]                     # (rps, S) local rows
        sig = st.paths                               # (rps, S)
        d = jax.lax.axis_index(axis)
        col_ids = (jnp.arange(sigma, dtype=jnp.int32)[None, :]
                   + jnp.zeros((qcap, 1), jnp.int32))

        def body(carry: tuple[jnp.ndarray, jnp.ndarray]
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
            delta, t = carry
            Q = jax.lax.dynamic_index_in_dim(hist.Q, t, keepdims=False)
            safe = jnp.maximum(sig, 1.0)
            h = jnp.where(levels == t, (1.0 + delta) / safe, 0.0)
            h = jnp.concatenate([h, jnp.zeros((1, S), jnp.float32)])
            part = bvss_spmm_t_local(dev.masks[Q], dev.row_ids[Q], h,
                                     sigma=sigma, impl=spmm_t)
            cols = dev.virtual_to_real[Q][:, None] * sigma + col_ids
            coeff = jnp.zeros((n_pad, S), jnp.float32).at[
                cols.reshape(-1)].add(part.reshape(-1, S))
            # the one backward collective per level: sum the per-shard
            # column partials and hand each shard its own row block
            coeff = jax.lax.psum_scatter(coeff, axis, scatter_dimension=0,
                                         tiled=True)           # (rps, S)
            delta = delta + jnp.where(levels == t - 1, sig * coeff, 0.0)
            return delta, t - 1

        def cond(carry: tuple[jnp.ndarray, jnp.ndarray]) -> jnp.ndarray:
            return carry[1] >= 1

        # the countdown start must be mesh-uniform: the while_loop body
        # carries collectives, so every shard walks the same levels
        tloc = jnp.where(levels == INF, 0, levels).max().astype(jnp.int32)
        tmax = jax.lax.pmax(tloc, axis)
        delta0 = jnp.zeros((rps, S), jnp.float32)
        delta, _ = jax.lax.while_loop(cond, body, (delta0, tmax))
        # endpoints excluded, on the owning shard only (clamped no-op
        # writes elsewhere — delta has no dummy row)
        lsrc = sources - d * rps
        own = (lsrc >= 0) & (lsrc < rps)
        row = jnp.clip(lsrc, 0, rps - 1)
        cols_s = jnp.arange(S)
        delta = delta.at[row, cols_s].set(
            jnp.where(own, 0.0, delta[row, cols_s]))
        return st.levels[None, :rps], sig[None], delta[None]

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=problem_specs(axis) + (P(),),
                   out_specs=(P(axis), P(axis), P(axis)), check_rep=False)

    def bc(sources: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray,
                                          jnp.ndarray]:
        sources = jnp.asarray(sources, dtype=jnp.int32)
        lv, sig, delta = fn(p.dev.masks, p.dev.row_ids,
                            p.dev.virtual_to_real,
                            p.dev.vss_of_vertex_start,
                            p.dev.vss_of_vertex_end, sources)
        return (lv.reshape(-1, S)[:p.n], sig.reshape(-1, S)[:p.n],
                delta.reshape(-1, S)[:p.n])

    return jax.jit(bc)


def _make_betweenness_sharded_2d(p: BlestProblem, n_sources: int, *,
                                 use_kernel: bool, buckets: int,
                                 max_levels: int | None,
                                 spmm_w_impl: Callable | None = None
                                 ) -> Callable:
    """Brandes on the 2-D row × column partition, one ``shard_map``
    dispatch.  Forward: the 2-D σ-channel locals (mark-accumulate pull,
    butterfly OR-allreduce of the hits over the column axis, butterfly
    σ-value gather over the row axis), each device recording its OWN
    (i, j)-block per-level queue.

    Backward per level: device (i, j)'s transposed tile product pushes
    dependency from its row block into its COLUMN block's columns only, so
    the global coefficient at a colblock-j column is the row-axis ``psum``
    of the (·, j) devices' partials.  ``psum_scatter`` over the row axis
    does that sum AND hands device (i, j) exactly the colblock-j segment
    of ITS OWN row block (local column ids [i·cpb, (i+1)·cpb) map to row
    block i by the interleaved layout); one butterfly exchange over the
    COLUMN axis then concatenates the C segments, index-ordered, into the
    full (rps, S) row-block coefficient.  Two log-stage collectives per
    backward level, no full-column replica anywhere.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed.bfs_dist import problem_specs2d
    from repro.distributed.collectives import butterfly_frontier_exchange

    mesh = p.mesh
    rax, cax = p.axis, p.col_axis
    S = n_sources
    sigma = p.sigma
    R, C, rps = p.n_shards, p.n_col_shards, p.rows_per_shard
    cpb = p.cols_per_block
    n_loc = R * cpb                   # local column space of one device
    n_cols = p.n_sets * sigma         # padded scatter space (≥ n_loc)
    spmm = bvss_spmm if use_kernel else bvss_spmm_ref
    spmm_w = spmm_w_impl if spmm_w_impl is not None else \
        (bvss_spmm_w if use_kernel else bvss_spmm_w_ref)
    spmm_t = bvss_spmm_t if use_kernel else bvss_spmm_t_ref
    widths = queue_widths(p.num_vss, buckets)
    qcap = widths[-1]
    max_lv = max_levels if max_levels is not None else p.n + 1
    locals_for = _make_ms_locals_2d(p, S, spmm, widths, qcap,
                                    spmm_w=spmm_w, track_sigma=True)
    hist0, record = make_queue_history(qcap, max_lv, p.num_vss)

    def local_fn(masks: jnp.ndarray, row_ids: jnp.ndarray,
                 v2r: jnp.ndarray, vstart: jnp.ndarray, vend: jnp.ndarray,
                 sources: jnp.ndarray
                 ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        dev = ShardedBVSSDevice(masks[0], row_ids[0], v2r[0],
                                vstart[0], vend[0])
        loc = locals_for(dev)
        pipe = LevelPipeline(step=lambda s, lvl: loc.step(s),
                             finalize=lambda s, lvl: loc.finalize(s),
                             active=lambda s: s.cont)
        st, _, hist = run_levels_recorded(
            pipe, loc.init(sources), max_levels=max_lv, history=hist0,
            record=record)
        levels = st.levels[:rps]                     # (rps, S) local rows
        sig = st.paths                               # (rps, S)
        i = jax.lax.axis_index(rax)
        col_ids = (jnp.arange(sigma, dtype=jnp.int32)[None, :]
                   + jnp.zeros((qcap, 1), jnp.int32))

        def body(carry: tuple[jnp.ndarray, jnp.ndarray]
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
            delta, t = carry
            Q = jax.lax.dynamic_index_in_dim(hist.Q, t, keepdims=False)
            safe = jnp.maximum(sig, 1.0)
            h = jnp.where(levels == t, (1.0 + delta) / safe, 0.0)
            h = jnp.concatenate([h, jnp.zeros((1, S), jnp.float32)])
            part = bvss_spmm_t_local(dev.masks[Q], dev.row_ids[Q], h,
                                     sigma=sigma, impl=spmm_t)
            cols = dev.virtual_to_real[Q][:, None] * sigma + col_ids
            coeff = jnp.zeros((n_cols, S), jnp.float32).at[
                cols.reshape(-1)].add(part.reshape(-1, S))[:n_loc]
            # sum the row-axis partials of this COLUMN block and keep this
            # device's own-row-block segment of the result ...
            coeff = jax.lax.psum_scatter(coeff, rax, scatter_dimension=0,
                                         tiled=True)           # (cpb, S)
            # ... then stitch the C per-colblock segments (index-ordered
            # by mesh column = offset order) into the full row block
            coeff = butterfly_frontier_exchange(coeff, cax)    # (rps, S)
            delta = delta + jnp.where(levels == t - 1, sig * coeff, 0.0)
            return delta, t - 1

        def cond(carry: tuple[jnp.ndarray, jnp.ndarray]) -> jnp.ndarray:
            return carry[1] >= 1

        # mesh-uniform countdown start over BOTH axes: the body carries
        # collectives, so every device walks the same levels
        tloc = jnp.where(levels == INF, 0, levels).max().astype(jnp.int32)
        tmax = jax.lax.pmax(tloc, (rax, cax))
        delta0 = jnp.zeros((rps, S), jnp.float32)
        delta, _ = jax.lax.while_loop(cond, body, (delta0, tmax))
        lsrc = sources - i * rps
        own = (lsrc >= 0) & (lsrc < rps)
        row = jnp.clip(lsrc, 0, rps - 1)
        cols_s = jnp.arange(S)
        delta = delta.at[row, cols_s].set(
            jnp.where(own, 0.0, delta[row, cols_s]))
        return st.levels[None, :rps], sig[None], delta[None]

    fn = shard_map(local_fn, mesh=mesh,
                   in_specs=problem_specs2d(rax, cax) + (P(),),
                   out_specs=(P((rax, cax)),) * 3, check_rep=False)

    def bc(sources: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray,
                                          jnp.ndarray]:
        sources = jnp.asarray(sources, dtype=jnp.int32)
        lv, sig, delta = fn(p.dev.masks, p.dev.row_ids,
                            p.dev.virtual_to_real,
                            p.dev.vss_of_vertex_start,
                            p.dev.vss_of_vertex_end, sources)

        def col0(a):  # (R·C, rps, S) blocks row-major -> mesh column 0
            return a.reshape(R, C, rps, S)[:, 0].reshape(-1, S)[:p.n]
        return col0(lv), col0(sig), col0(delta)

    return jax.jit(bc)


def betweenness_centrality(g: Graph | None,
                           sources: Sequence[int] | np.ndarray, *,
                           problem: BlestProblem | None = None,
                           use_kernel: bool = True,
                           batch: int | None = None,
                           max_levels: int | None = None,
                           bc_fn: Callable | None = None) -> np.ndarray:
    """Partial Brandes betweenness Σ_{s∈sources} δ_s(v), unnormalised —
    the quantity ``kernels.ref.betweenness_ref`` computes (equal to
    NetworkX ``betweenness_centrality(normalized=False)`` on a DiGraph
    when ``sources`` is every vertex).

    ``sources`` are ids of ``g`` (or of the prepared graph when
    ``problem`` is passed); duplicates contribute once each.  Sources are
    processed in fixed cohorts of ``batch`` stacked wave columns (default
    min(8, len(sources))).  ``bc_fn`` is an optional prebuilt
    :func:`make_betweenness` callable of width ``batch`` (sessions pass
    their cached one).  A sharded ``problem`` runs both phases mesh-native.
    """
    if problem is None:
        from repro.core.bvss import build_bvss
        problem = BlestProblem.build(build_bvss(g))
    sources = np.asarray(sources, dtype=np.int32)
    if len(sources) == 0:
        return np.zeros(problem.n, dtype=np.float64)
    S = batch if batch is not None else min(8, len(sources))
    f = bc_fn if bc_fn is not None else make_betweenness(
        problem, S, use_kernel=use_kernel, max_levels=max_levels)
    bc = np.zeros(problem.n, dtype=np.float64)
    for lo in range(0, len(sources), S):
        chunk = sources[lo:lo + S]
        valid = len(chunk)  # tail cohorts are padded, padded cols dropped
        _, _, delta = f(jnp.asarray(pad_cohort(chunk, S)))
        bc += np.asarray(delta[:, :valid], dtype=np.float64).sum(axis=1)
    return bc
