"""Transformer building blocks: RMSNorm, RoPE, GQA/MLA attention, SwiGLU,
capacity-based MoE.  Pure-functional: ``init_*`` builds param pytrees,
``apply_*`` consumes them.  A parallel ``*_axes`` function returns the
logical-axis tree used by the sharding rule engine (distributed/sharding.py).

Logical axis names: "embed", "heads", "kv_heads", "head_dim", "q_lora",
"kv_lora", "ffn", "vocab", "experts" — mapped to mesh axes per-arch.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

Params = Any

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# config dataclasses
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    router: str = "softmax"          # "softmax" | "sigmoid_ds3"
    capacity_factor: float = 1.25
    routed_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    attn: str = "gqa"                # "gqa" | "mla"
    qk_norm: bool = False
    window: int | None = None        # sliding-window size (all local layers)
    local_global: tuple[int, int] = (0, 1)   # (n_local, n_global) pattern
    rope_theta: float = 10_000.0
    moe: MoEConfig | None = None
    n_dense_layers: int = 0          # leading dense-FFN layers (DeepSeek: 3)
    dense_d_ff: int | None = None    # d_ff of those dense layers
    mtp: bool = False                # DeepSeek multi-token prediction head
    tie_embeddings: bool = True
    # MLA dims (DeepSeek-V3 defaults)
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    norm_eps: float = 1e-6
    # §Perf: mixed-precision attention — bf16 QK^T/PV matmuls with fp32
    # accumulation + fp32 softmax (MXU-native), instead of casting q/k/v to
    # fp32 before the matmuls.  Halves attention HBM traffic; numerics
    # validated in tests (logits agree to ~1e-2 relative at smoke scale).
    mp_attn: bool = False

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    def layer_window(self, layer: int) -> int | None:
        """Effective attention window of a layer (None = global)."""
        n_loc, n_glob = self.local_global
        if self.window is None:
            return None
        if n_loc == 0:
            return self.window  # uniform SWA
        period = n_loc + n_glob
        return self.window if (layer % period) < n_loc else None


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, in_axis_size, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(max(in_axis_size, 1))
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * scale


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + w.astype(jnp.float32))
            ).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(d: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x (..., S, H, D), positions (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                          # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                      # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention core (GQA-aware, window + causal + explicit kv positions)
# ---------------------------------------------------------------------------
Q_CHUNK = 1024  # query-chunk size for long-sequence attention

# Analysis mode: XLA's cost_analysis counts a while-loop body ONCE, so for
# roofline extraction the dry-run unrolls every internal loop (layer scans,
# q-chunk maps, CE chunk maps).  Trace-time flag; see configs/families.py.
_UNROLL = False


def set_unroll(v: bool):
    global _UNROLL
    _UNROLL = bool(v)


def unroll_enabled() -> bool:
    return _UNROLL


def _attend_dense(q, k, v, q_pos, k_pos, window, k_valid, mixed=False):
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    if mixed:
        # bf16 operands, fp32 accumulation (MXU-native): no fp32 q/k/v
        # copies and no fp32 probability tensor in HBM
        qf = (q * (1.0 / math.sqrt(D)).__float__()).reshape(
            B, Sq, Hkv, g, D)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k,
                            preferred_element_type=jnp.float32)
    else:
        qf = (q.astype(jnp.float32) / math.sqrt(D)).reshape(
            B, Sq, Hkv, g, D)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                            k.astype(jnp.float32))
    mask = q_pos[:, :, None] >= k_pos[:, None, :]
    if window is not None:
        win = jnp.asarray(window, dtype=jnp.int32)
        mask = mask & ((win <= 0)
                       | (q_pos[:, :, None] - k_pos[:, None, :] < win))
    if k_valid is not None:
        mask = mask & k_valid[:, None, :]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    if mixed:
        out = jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(q.dtype), v,
                         preferred_element_type=jnp.float32)
    else:
        out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, -1).astype(q.dtype)


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           q_pos: jnp.ndarray, k_pos: jnp.ndarray,
           window, k_valid: jnp.ndarray | None = None,
           q_chunk: int = Q_CHUNK, mixed: bool = False) -> jnp.ndarray:
    """q (B, Sq, Hq, D), k/v (B, Sk, Hkv, D[v]), positions int32.

    Causal mask from positions; ``window`` is an int or traced int32 scalar
    (<= 0 means global, so per-layer windows can ride through lax.scan);
    optional kv-slot validity (rotating caches).  GQA: Hq % Hkv == 0.

    Long queries are processed in chunks of ``q_chunk`` (exact blockwise
    attention: each chunk does its full softmax over K) so the score tensor
    never exceeds B·H·q_chunk·Sk — mandatory for the 32k-prefill shapes.
    """
    B, Sq, Hq, D = q.shape
    if Sq <= q_chunk or Sq % q_chunk != 0:
        return _attend_dense(q, k, v, q_pos, k_pos, window, k_valid, mixed)
    n = Sq // q_chunk
    qs = q.reshape(B, n, q_chunk, Hq, D).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(B, n, q_chunk).transpose(1, 0, 2)

    def one(args):
        qc, qpc = args
        return _attend_dense(qc, k, v, qpc, k_pos, window, k_valid, mixed)

    if _UNROLL:
        out = jnp.stack([one((qs[i], qp[i])) for i in range(n)])
    else:
        out = jax.lax.map(one, (qs, qp))          # (n, B, qc, Hq, Dv)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, Hq, -1)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------
def init_gqa(key, cfg: LMConfig):
    ks = jax.random.split(key, 6)
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": dense_init(ks[0], (d, H, Dh), d),
        "wk": dense_init(ks[1], (d, Hkv, Dh), d),
        "wv": dense_init(ks[2], (d, Hkv, Dh), d),
        "wo": dense_init(ks[3], (H, Dh, d), H * Dh),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((Dh,))
        p["k_norm"] = jnp.zeros((Dh,))
    return p


def gqa_axes(cfg: LMConfig):
    a = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qk_norm:
        a["q_norm"] = (None,)
        a["k_norm"] = (None,)
    return a


def apply_gqa(p, cfg: LMConfig, x, q_pos, *, window, kv_cache=None,
              capture_kv: bool = False):
    """x (B, S, d). If kv_cache is a callback (decode): it receives the new
    (k, v), returns the effective (k, v, k_pos, k_valid, new_cache).  With
    ``capture_kv`` (prefill): self-attention, and the raw (k, v) is returned
    as the cache payload."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, q_pos, cfg.rope_theta)
    k = apply_rope(k, q_pos, cfg.rope_theta)
    if kv_cache is not None:
        ck, cv, k_pos, k_valid, new_cache = kv_cache(k, v)
        out = attend(q, ck, cv, q_pos, k_pos, window, k_valid,
                     mixed=cfg.mp_attn)
    else:
        new_cache = (k, v) if capture_kv else None
        out = attend(q, k, v, q_pos, q_pos, window, mixed=cfg.mp_attn)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V3): low-rank Q, compressed KV latent + shared
# RoPE key.  The latent (c_kv, k_rope) is what decode caches.
# ---------------------------------------------------------------------------
def init_mla(key, cfg: LMConfig):
    ks = jax.random.split(key, 10)
    d, H = cfg.d_model, cfg.n_heads
    qk_d = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {
        "wq_a": dense_init(ks[0], (d, cfg.q_lora_rank), d),
        "q_a_norm": jnp.zeros((cfg.q_lora_rank,)),
        "wq_b": dense_init(ks[1], (cfg.q_lora_rank, H, qk_d), cfg.q_lora_rank),
        "wkv_a": dense_init(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), d),
        "kv_a_norm": jnp.zeros((cfg.kv_lora_rank,)),
        "wk_b": dense_init(ks[3], (cfg.kv_lora_rank, H, cfg.qk_nope_dim),
                           cfg.kv_lora_rank),
        "wv_b": dense_init(ks[4], (cfg.kv_lora_rank, H, cfg.v_head_dim),
                           cfg.kv_lora_rank),
        "wo": dense_init(ks[5], (H, cfg.v_head_dim, d), H * cfg.v_head_dim),
    }
    return p


def mla_axes(cfg: LMConfig):
    return {
        "wq_a": ("embed", "q_lora"),
        "q_a_norm": (None,),
        "wq_b": ("q_lora", "heads", "head_dim"),
        "wkv_a": ("embed", "kv_lora"),
        "kv_a_norm": (None,),
        "wk_b": ("kv_lora", "heads", "head_dim"),
        "wv_b": ("kv_lora", "heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }


def apply_mla(p, cfg: LMConfig, x, q_pos, *, window=None, kv_cache=None,
              capture_kv: bool = False):
    B, S, d = x.shape
    H = cfg.n_heads
    # queries
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype)),
                  p["q_a_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(x.dtype))
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, q_pos, cfg.rope_theta)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    # compressed kv latent
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    c_kv, k_rope = jnp.split(kv_a, [cfg.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["kv_a_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], q_pos, cfg.rope_theta)  # (B,S,1,r)

    if kv_cache is not None:
        c_kv, k_rope, k_pos, k_valid, new_cache = kv_cache(c_kv, k_rope)
    else:
        k_pos, k_valid = q_pos, None
        new_cache = (c_kv, k_rope) if capture_kv else None
    # expand latent to per-head keys/values (decode recomputes from latent —
    # the MLA memory win; matmul absorption is a §Perf item)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"].astype(x.dtype))
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:3], cfg.qk_rope_dim))],
        axis=-1)
    out = attend(q_full, k_full, v, q_pos, k_pos, window, k_valid,
                 mixed=cfg.mp_attn)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], (d_model, d_ff), d_model),
        "w_up": dense_init(ks[1], (d_model, d_ff), d_model),
        "w_down": dense_init(ks[2], (d_ff, d_model), d_ff),
    }


def mlp_axes():
    return {"w_gate": ("embed", "ffn"), "w_up": ("embed", "ffn"),
            "w_down": ("ffn", "embed")}


def apply_mlp(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# capacity-based MoE (GShard-style dispatch; experts shard over "experts")
# ---------------------------------------------------------------------------
def init_moe(key, cfg: LMConfig):
    mc = cfg.moe
    ks = jax.random.split(key, 5)
    d, E, F = cfg.d_model, mc.n_experts, mc.d_expert
    p = {
        "router": dense_init(ks[0], (d, E), d),
        "w_gate": dense_init(ks[1], (E, d, F), d),
        "w_up": dense_init(ks[2], (E, d, F), d),
        "w_down": dense_init(ks[3], (E, F, d), F),
    }
    if mc.router == "sigmoid_ds3":
        # aux-loss-free load-balancing bias (updated outside grad)
        p["router_bias"] = jnp.zeros((E,))
    if mc.n_shared:
        p["shared"] = init_mlp(ks[4], d, F * mc.n_shared)
    return p


def moe_axes(cfg: LMConfig):
    a = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "ffn"),
        "w_up": ("experts", "embed", "ffn"),
        "w_down": ("experts", "ffn", "embed"),
    }
    if cfg.moe.router == "sigmoid_ds3":
        a["router_bias"] = (None,)
    if cfg.moe.n_shared:
        a["shared"] = mlp_axes()
    return a


def apply_moe(p, cfg: LMConfig, x, *, n_groups: int = 1,
              moe_spec: tuple | None = None):
    """x (B, S, d) -> (B, S, d).  GShard-style capacity dispatch with
    *groups*: tokens are reshaped to (G, T/G) and each group routes into its
    own per-expert capacity buffer, so the cumsum that assigns buffer slots
    is local to a group.  With G sharded over the data axes and experts over
    the model axis (EP), dispatch/combine lower to all-to-alls instead of a
    global serial cumsum.  ``n_groups`` must divide B*S (use the DP shard
    count at scale; 1 on CPU smoke tests)."""
    mc = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = mc.n_experts, mc.top_k
    G = n_groups
    assert T % G == 0, (T, G)
    Tg = T // G
    xt = x.reshape(G, Tg, d)
    logits = jnp.einsum("gtd,de->gte", xt, p["router"].astype(x.dtype)
                        ).astype(jnp.float32)
    if mc.router == "sigmoid_ds3":
        scores = jax.nn.sigmoid(logits)
        sel = scores + p["router_bias"][None, None, :]
        _, top_idx = jax.lax.top_k(sel, K)                 # bias affects choice
        top_raw = jnp.take_along_axis(scores, top_idx, axis=2)
        top_w = top_raw / (top_raw.sum(axis=2, keepdims=True) + 1e-9)
        top_w = top_w * mc.routed_scale
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_idx = jax.lax.top_k(probs, K)
        top_w = top_w / (top_w.sum(axis=2, keepdims=True) + 1e-9)

    C = max(1, int(math.ceil(Tg * K / E * mc.capacity_factor)))
    # slot of each (token, k) inside its expert's per-group buffer
    onehot = jax.nn.one_hot(top_idx, E, dtype=jnp.int32)       # (G, Tg, K, E)
    pos_in_e = (jnp.cumsum(onehot.reshape(G, Tg * K, E), axis=1) - 1
                ).reshape(G, Tg, K, E)
    pos = (pos_in_e * onehot).sum(-1)                          # (G, Tg, K)
    keep = pos < C
    flat_e = jnp.where(keep, top_idx, E).reshape(G, Tg * K)
    flat_pos = jnp.where(keep, pos, 0).reshape(G, Tg * K)
    slot = flat_e * C + flat_pos                               # (G, Tg*K)
    tok_ids = jnp.broadcast_to(
        jnp.arange(Tg, dtype=jnp.int32)[:, None], (Tg, K)).reshape(Tg * K)
    token_of_slot = jnp.zeros((G, E * C + C), jnp.int32).at[
        jnp.arange(G)[:, None], slot].set(tok_ids[None, :], mode="drop")
    slot_used = jnp.zeros((G, E * C + C), jnp.bool_).at[
        jnp.arange(G)[:, None], slot].set(keep.reshape(G, Tg * K), mode="drop")
    token_of_slot = token_of_slot[:, :E * C].reshape(G, E, C)
    slot_used = slot_used[:, :E * C].reshape(G, E, C)

    xe = jnp.take_along_axis(
        xt[:, None, :, :],
        token_of_slot[..., None].astype(jnp.int32), axis=2)
    xe = xe * slot_used[..., None].astype(x.dtype)             # (G, E, C, d)
    if moe_spec is not None:
        from jax.sharding import PartitionSpec as _P
        g_ax, e_ax = moe_spec
        xe = jax.lax.with_sharding_constraint(
            xe, _P(g_ax, e_ax, None, None))
    g_ = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(x.dtype))
    u_ = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g_) * u_
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    if moe_spec is not None:
        ye = jax.lax.with_sharding_constraint(
            ye, _P(g_ax, e_ax, None, None))

    # combine: scatter-add expert outputs back to tokens.  The transpose
    # of the dispatch gather: with ye sharded on E (EP) and tokens on DP,
    # a scatter-add partitions into LOCAL per-expert partial sums + one
    # all-reduce of (G, Tg, d) over the EP axis — 16x fewer bytes than the
    # take_along_axis formulation, whose E*C-flattened operand forced XLA
    # to all-gather every expert's outputs to every device (§Perf log).
    w_k = jnp.where(keep, top_w, 0.0).astype(x.dtype)          # (G, Tg, K)
    g_idx = jnp.arange(G, dtype=jnp.int32)[:, None]
    w_slot = jnp.zeros((G, E * C + C), x.dtype).at[
        g_idx, slot].set(w_k.reshape(G, Tg * K), mode="drop")
    w_slot = w_slot[:, :E * C].reshape(G, E, C)
    contrib = ye * w_slot[..., None]                           # (G, E, C, d)
    yt = jnp.zeros((G, Tg, d), x.dtype).at[
        jnp.arange(G, dtype=jnp.int32)[:, None, None],
        token_of_slot, :].add(contrib)                         # (G, Tg, d)

    if mc.n_shared:
        yt = yt + apply_mlp(p["shared"], xt)
    return yt.reshape(B, S, d)
