"""Factorization Machine [Rendle, ICDM'10] with sparse embedding tables.

JAX has no native EmbeddingBag — per the assignment, it is built here from
``jnp.take`` + ``jax.ops.segment_sum``.  The FM pairwise interaction uses
the O(nk) sum-square identity:

    Σ_{i<j} <v_i, v_j> x_i x_j = ½ (‖Σ_i v_i x_i‖² − Σ_i ‖v_i x_i‖²).

Supports single-hot fields (Criteo-style, (B, F) int32) and multi-hot bags
(flat ids + segment offsets).  The embedding tables are the sharded object
("table_rows" over the model axis): the lookup is the hot path at scale.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import dense_init


@dataclasses.dataclass(frozen=True)
class FMConfig:
    name: str
    n_fields: int = 39
    embed_dim: int = 10
    rows_per_field: int = 100_000     # single concatenated table
    n_dense: int = 0                  # optional dense features

    @property
    def total_rows(self) -> int:
        return self.n_fields * self.rows_per_field


def init_fm(key, cfg: FMConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        # factor table and first-order weight table, row-sharded
        "v": dense_init(k1, (cfg.total_rows, cfg.embed_dim), cfg.embed_dim)
        * 0.1,
        "w": (dense_init(k2, (cfg.total_rows, 1), 1) * 0.01)[:, 0],
        "b": jnp.zeros(()),
    }
    if cfg.n_dense:
        p["w_dense"] = dense_init(k3, (cfg.n_dense,), cfg.n_dense)
    return p


def fm_axes(cfg: FMConfig):
    a = {"v": ("table_rows", None), "w": ("table_rows",), "b": ()}
    if cfg.n_dense:
        a["w_dense"] = (None,)
    return a


def _flatten_ids(cfg: FMConfig, ids: jnp.ndarray) -> jnp.ndarray:
    """Per-field ids (B, F) -> rows in the concatenated table."""
    offs = jnp.arange(cfg.n_fields, dtype=jnp.int32) * cfg.rows_per_field
    return ids + offs[None, :]


def apply_fm(params, cfg: FMConfig, ids: jnp.ndarray,
             dense: jnp.ndarray | None = None) -> jnp.ndarray:
    """ids (B, F) int32 in [0, rows_per_field). Returns logits (B,)."""
    rows = _flatten_ids(cfg, ids)
    v = jnp.take(params["v"], rows, axis=0)          # (B, F, k)
    w = jnp.take(params["w"], rows, axis=0)          # (B, F)
    s = v.sum(axis=1)                                # (B, k)
    s2 = (v * v).sum(axis=1)                         # (B, k)
    pairwise = 0.5 * (s * s - s2).sum(axis=-1)       # (B,)
    out = params["b"] + w.sum(axis=1) + pairwise
    if dense is not None and cfg.n_dense:
        out = out + dense @ params["w_dense"]
    return out


def apply_fm_bags(params, cfg: FMConfig, flat_ids: jnp.ndarray,
                  bag_ids: jnp.ndarray, n_bags: int) -> jnp.ndarray:
    """Multi-hot EmbeddingBag variant: flat table rows (L,) with bag id per
    entry (L,) in [0, n_bags); bag = one (example, field) pair.  Dummy
    entries use bag id ``n_bags``.  Returns logits (n_bags // n_fields,)."""
    v = jnp.take(params["v"], flat_ids, axis=0)          # (L, k)
    w = jnp.take(params["w"], flat_ids, axis=0)          # (L,)
    v_bag = jax.ops.segment_sum(v, bag_ids, n_bags + 1)[:-1]
    w_bag = jax.ops.segment_sum(w, bag_ids, n_bags + 1)[:-1]
    B = n_bags // cfg.n_fields
    v_bf = v_bag.reshape(B, cfg.n_fields, cfg.embed_dim)
    s = v_bf.sum(axis=1)
    s2 = (v_bf * v_bf).sum(axis=1)
    pairwise = 0.5 * (s * s - s2).sum(axis=-1)
    return params["b"] + w_bag.reshape(B, cfg.n_fields).sum(axis=1) + pairwise


def fm_retrieval_scores(params, cfg: FMConfig, query_ids: jnp.ndarray,
                        cand_ids: jnp.ndarray) -> jnp.ndarray:
    """Retrieval scoring: one query (Fq,) against N candidate items (N, Fc)
    — blocked batched dot, no loop.  Query fields and candidate fields are
    disjoint field groups; the score is the FM cross term between the two
    groups plus candidate bias terms."""
    Fq = query_ids.shape[0]
    q_rows = query_ids + jnp.arange(Fq, dtype=jnp.int32) * cfg.rows_per_field
    q_vec = jnp.take(params["v"], q_rows, axis=0).sum(axis=0)   # (k,)
    Fc = cand_ids.shape[1]
    c_off = (Fq + jnp.arange(Fc, dtype=jnp.int32)) * cfg.rows_per_field
    c_rows = cand_ids + c_off[None, :]
    c_vec = jnp.take(params["v"], c_rows, axis=0).sum(axis=1)   # (N, k)
    c_w = jnp.take(params["w"], c_rows, axis=0).sum(axis=1)     # (N,)
    return c_vec @ q_vec + c_w


def fm_loss(params, cfg: FMConfig, ids, labels, dense=None):
    logits = apply_fm(params, cfg, ids, dense)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))
