from .layers import LMConfig, MoEConfig
from . import transformer

__all__ = ["LMConfig", "MoEConfig", "transformer"]
