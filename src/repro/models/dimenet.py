"""DimeNet-lite [arXiv:2003.03123]: directional message passing with the
triplet-gather kernel regime.

Config (assigned): n_blocks=6, d_hidden=128, n_bilinear=8, n_spherical=7,
n_radial=6.  The radial basis is the paper's Bessel basis; the 2-D spherical
basis is simplified to (Bessel radial) × (Legendre P_l of the triplet angle)
— same tensor shapes and the same gather structure as the paper's
j_l-root basis (documented simplification, DESIGN §4).  The interaction
block follows the DimeNet++ bilinear form with ``n_bilinear`` as the
down-projected interaction width.

Inputs are batched molecular graphs with *triplet* index lists built by the
data pipeline: for each pair of incident edges (k→j, j→i) one triplet with
edge ids (e_kj, e_ji) and the angle between them.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .gnn import mlp2_apply, mlp2_axes, mlp2_init
from .layers import dense_init
from .nequip import bessel_rbf


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_species: int = 8
    n_graphs: int = 1


def legendre_basis(cos_t: jnp.ndarray, n: int) -> jnp.ndarray:
    """P_0..P_{n-1}(cos θ) via the recurrence (T,) -> (T, n)."""
    outs = [jnp.ones_like(cos_t), cos_t]
    for l in range(2, n):
        outs.append(((2 * l - 1) * cos_t * outs[-1]
                     - (l - 1) * outs[-2]) / l)
    return jnp.stack(outs[:n], axis=-1)


def init_dimenet(key, cfg: DimeNetConfig):
    keys = jax.random.split(key, cfg.n_blocks * 6 + 4)
    ki = iter(keys)
    d, nb = cfg.d_hidden, cfg.n_bilinear
    params = {
        "embed": dense_init(next(ki), (cfg.n_species, d), cfg.n_species),
        "rbf_proj": dense_init(next(ki), (cfg.n_radial, d), cfg.n_radial),
        "msg_init": mlp2_init(next(ki), 3 * d, d, d),
        "blocks": [],
        "out_head": mlp2_init(next(ki), d, d, 1),
    }
    for _ in range(cfg.n_blocks):
        params["blocks"].append({
            "w_self": dense_init(next(ki), (d, d), d),
            "w_down": dense_init(next(ki), (d, nb), d),
            "w_sbf": dense_init(next(ki), (cfg.n_spherical * cfg.n_radial,
                                           nb), cfg.n_spherical),
            "w_up": dense_init(next(ki), (nb, d), nb),
            "rbf_gate": dense_init(next(ki), (cfg.n_radial, d), cfg.n_radial),
            "out": mlp2_init(next(ki), d, d, d),
        })
    return params


def dimenet_axes(cfg: DimeNetConfig):
    return {
        "embed": (None, "ffn"), "rbf_proj": (None, "ffn"),
        "msg_init": mlp2_axes(),
        "blocks": [{"w_self": (None, None), "w_down": (None, None),
                    "w_sbf": (None, None), "w_up": (None, None),
                    "rbf_gate": (None, None), "out": mlp2_axes()}
                   for _ in range(cfg.n_blocks)],
        "out_head": mlp2_axes(),
    }


def apply_dimenet(params, cfg: DimeNetConfig, species, pos, senders,
                  receivers, t_kj, t_ji, graph_ids=None, remat: bool = False):
    """species (N+1,), pos (N+1, 3); edges k→j as (senders, receivers) (E,)
    padded with dummy node N; triplets as edge-id pairs (t_kj, t_ji) (T,)
    padded with dummy edge E (an extra zero edge row is appended).
    Returns per-graph energies (G,)."""
    n1 = species.shape[0]
    E = senders.shape[0]
    dt = pos.dtype
    live_e = ((senders < n1 - 1) & (receivers < n1 - 1)).astype(dt)[:, None]

    d_vec = pos[senders] - pos[receivers]
    r = jnp.sqrt(jnp.sum(d_vec * d_vec, axis=-1) + 1e-12)
    rbf = bessel_rbf(r, cfg.n_radial, cfg.cutoff) * live_e    # (E, n_radial)

    h = jax.nn.one_hot(species, cfg.n_species, dtype=dt) \
        @ params["embed"].astype(dt)
    e_rbf = rbf @ params["rbf_proj"].astype(dt)
    m = mlp2_apply(params["msg_init"],
                   jnp.concatenate([h[senders], h[receivers], e_rbf], -1))
    m = m * live_e                                             # (E, d)

    # triplet angle basis: angle between edge (k→j) and (j→i) at vertex j
    pad_vec = jnp.zeros((1, 3), dt)
    dv = jnp.concatenate([d_vec, pad_vec], axis=0)             # dummy edge E
    pad_r = jnp.ones((1,), dt)
    rr = jnp.concatenate([r, pad_r], axis=0)
    v1 = dv[t_kj]
    v2 = -dv[t_ji]
    cos_t = jnp.sum(v1 * v2, -1) / (rr[t_kj] * rr[t_ji] + 1e-12)
    cos_t = jnp.clip(cos_t, -1.0, 1.0)
    ang = legendre_basis(cos_t, cfg.n_spherical)               # (T, n_sph)
    rbf_pad = jnp.concatenate([rbf, jnp.zeros((1, cfg.n_radial), dt)], 0)
    sbf = (ang[:, :, None] * rbf_pad[t_kj][:, None, :]).reshape(
        t_kj.shape[0], -1)                                     # (T, nsph*nrad)
    t_live = ((t_kj < E) & (t_ji < E)).astype(dt)[:, None]
    sbf = sbf * t_live

    energy = jnp.zeros((n1,), dt)

    def block(carry, bp):
        m, energy = carry
        m_pad = jnp.concatenate([m, jnp.zeros((1, cfg.d_hidden), dt)], 0)
        t1 = m_pad[t_kj] @ bp["w_down"].astype(dt)             # (T, nb)
        t2 = sbf @ bp["w_sbf"].astype(dt)                      # (T, nb)
        agg = jax.ops.segment_sum(t1 * t2 * t_live, t_ji, E + 1)[:E]
        m = jax.nn.silu(m @ bp["w_self"].astype(dt)
                        + agg @ bp["w_up"].astype(dt)
                        + rbf @ bp["rbf_gate"].astype(dt)) * live_e
        node_m = jax.ops.segment_sum(mlp2_apply(bp["out"], m) * live_e,
                                     receivers, n1)
        energy = energy + mlp2_apply(params["out_head"], node_m)[:, 0]
        return m, energy

    step = jax.checkpoint(block) if remat else block
    for bp in params["blocks"]:
        m, energy = step((m, energy), bp)

    live_n = (jnp.arange(n1) < n1 - 1).astype(dt)
    energy = energy * live_n
    if graph_ids is None:
        return energy.sum()
    return jax.ops.segment_sum(energy, graph_ids, cfg.n_graphs + 1)[:-1]
