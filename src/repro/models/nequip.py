"""NequIP-lite [arXiv:2101.03164]: O(3)-equivariant interatomic potential,
l_max = 2, implemented without e3nn (not installed).

Features are irrep channels {l: (N+1, C, 2l+1)} for l = 0, 1, 2.  Messages
couple neighbour features with the real spherical harmonics of the edge
direction through *Gaunt* coupling tensors

    C3[l1][l2][l3][m1, m2, m3] = ∫ Y_{l1 m1} Y_{l2 m2} Y_{l3 m3} dΩ,

computed numerically once at import (Gauss–Legendre × uniform-φ quadrature).
Gaunt tensors span the same equivariant bilinear maps as Clebsch–Gordan
coupling for the parity-natural paths (l1+l2+l3 even), so the model is
exactly rotation-equivariant — verified by the rotation-invariance property
test.  Radial dependence enters through per-path weights produced by an MLP
over a Bessel radial basis with a polynomial envelope (as in NequIP).
"""
from __future__ import annotations

import dataclasses
import itertools
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from .gnn import mlp2_apply, mlp2_axes, mlp2_init
from .layers import dense_init

L_MAX = 2


# ---------------------------------------------------------------------------
# real spherical harmonics (l <= 2), (…, 3) -> list of (…, 2l+1)
# ---------------------------------------------------------------------------
def real_sph_harm(r_hat):
    x, y, z = r_hat[..., 0], r_hat[..., 1], r_hat[..., 2]
    y0 = 0.28209479177387814 * jnp.ones_like(x)
    y1 = 0.4886025119029199 * jnp.stack([y, z, x], axis=-1)
    y2 = jnp.stack([
        1.0925484305920792 * x * y,
        1.0925484305920792 * y * z,
        0.31539156525252005 * (3.0 * z * z - 1.0),
        1.0925484305920792 * x * z,
        0.5462742152960396 * (x * x - y * y),
    ], axis=-1)
    return [y0[..., None], y1, y2]


def _real_sph_harm_np(x, y, z):
    y0 = 0.28209479177387814 * np.ones_like(x)
    y1 = 0.4886025119029199 * np.stack([y, z, x], axis=-1)
    y2 = np.stack([
        1.0925484305920792 * x * y,
        1.0925484305920792 * y * z,
        0.31539156525252005 * (3.0 * z * z - 1.0),
        1.0925484305920792 * x * z,
        0.5462742152960396 * (x * x - y * y),
    ], axis=-1)
    return [y0[..., None], y1, y2]


@lru_cache(maxsize=1)
def gaunt_tensors() -> dict[tuple[int, int, int], np.ndarray]:
    """Numerically integrated Gaunt tensors for all l1, l2, l3 <= 2."""
    nt, nphi = 64, 128
    t, wt = np.polynomial.legendre.leggauss(nt)   # cos(theta) nodes
    phi = (np.arange(nphi) + 0.5) * (2 * np.pi / nphi)
    wphi = 2 * np.pi / nphi
    ct = t[:, None] * np.ones(nphi)[None, :]
    st = np.sqrt(1 - ct ** 2)
    x = st * np.cos(phi)[None, :]
    y = st * np.sin(phi)[None, :]
    z = ct
    Y = _real_sph_harm_np(x, y, z)                # [(nt, nphi, 2l+1)] l<=2
    w = wt[:, None] * wphi                         # (nt, nphi)
    out = {}
    for l1, l2, l3 in itertools.product(range(L_MAX + 1), repeat=3):
        if (l1 + l2 + l3) % 2 != 0:
            continue                               # parity-forbidden
        if l3 < abs(l1 - l2) or l3 > l1 + l2:
            continue                               # triangle inequality
        c = np.einsum("tp,tpa,tpb,tpc->abc", w, Y[l1], Y[l2], Y[l3])
        if np.abs(c).max() > 1e-10:
            out[(l1, l2, l3)] = c
    return out


def paths():
    return sorted(gaunt_tensors().keys())


# ---------------------------------------------------------------------------
# config + params
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str
    n_layers: int = 5
    channels: int = 32
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 8
    n_graphs: int = 1


def bessel_rbf(r, n_rbf: int, cutoff: float):
    """Bessel basis sin(n π r / c) / r with polynomial cutoff envelope."""
    r = jnp.maximum(r, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * r[..., None]
                                             / cutoff) / r[..., None]
    u = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1.0 - 10.0 * u ** 3 + 15.0 * u ** 4 - 6.0 * u ** 5
    return basis * env[..., None]


def init_nequip(key, cfg: NequIPConfig):
    P = paths()
    keys = jax.random.split(key, cfg.n_layers * (len(P) + 4) + 3)
    ki = iter(keys)
    C = cfg.channels
    params = {"embed": dense_init(next(ki), (cfg.n_species, C), cfg.n_species),
              "layers": []}
    for _ in range(cfg.n_layers):
        lp = {"radial": mlp2_init(next(ki), cfg.n_rbf, C, len(P) * C),
              "self": {f"l{l}": dense_init(next(ki), (C, C), C)
                       for l in range(L_MAX + 1)},
              "gate": dense_init(next(ki), (C, (L_MAX + 1) * C), C)}
        params["layers"].append(lp)
        _ = next(ki)  # reserved
    params["head"] = mlp2_init(next(ki), C, C, 1)
    return params


def nequip_axes(cfg: NequIPConfig):
    return {"embed": (None, "ffn"),
            "layers": [{"radial": mlp2_axes(),
                        "self": {f"l{l}": (None, None)
                                 for l in range(L_MAX + 1)},
                        "gate": (None, None)}
                       for _ in range(cfg.n_layers)],
            "head": mlp2_axes()}


def apply_nequip(params, cfg: NequIPConfig, species, pos, senders, receivers,
                 graph_ids=None, remat: bool = False):
    """species (N+1,) int32 (dummy = 0 with zero mask), pos (N+1, 3).
    Returns per-graph energies (G,) (or total scalar if graph_ids None)."""
    n1 = species.shape[0]
    C = cfg.channels
    live = (jnp.arange(n1) < n1 - 1).astype(pos.dtype)[:, None]
    P = paths()
    gt = {k: jnp.asarray(v, dtype=pos.dtype) for k, v in gaunt_tensors().items()}

    # initial features: scalars from species embedding; higher l start at 0
    h0 = jax.nn.one_hot(species, cfg.n_species, dtype=pos.dtype) \
        @ params["embed"].astype(pos.dtype)
    feats = {0: (h0 * live)[:, :, None],
             1: jnp.zeros((n1, C, 3), pos.dtype),
             2: jnp.zeros((n1, C, 5), pos.dtype)}

    d_vec = pos[senders] - pos[receivers]
    r = jnp.sqrt(jnp.sum(d_vec * d_vec, axis=-1) + 1e-12)
    r_hat = d_vec / r[:, None]
    Y = real_sph_harm(r_hat)                     # [(E, 2l+1)]
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff)   # (E, n_rbf)

    def layer(feats, lp):
        Rw = mlp2_apply(lp["radial"], rbf).reshape(-1, len(P), C)  # (E, P, C)
        msg = {l: 0.0 for l in range(L_MAX + 1)}
        for pi, (l1, l2, l3) in enumerate(P):
            f_j = feats[l1][senders]                        # (E, C, 2l1+1)
            # (E,C,a) x (E,b) x (a,b,k) -> (E,C,k), radially weighted
            t = jnp.einsum("eca,eb,abk->eck",
                           f_j, Y[l2], gt[(l1, l2, l3)])
            msg[l3] = msg[l3] + t * Rw[:, pi, :, None]
        new_feats = {}
        for l in range(L_MAX + 1):
            agg = jax.ops.segment_sum(msg[l], receivers, n1) \
                if not isinstance(msg[l], float) else jnp.zeros_like(feats[l])
            mixed = jnp.einsum("ncm,ck->nkm", agg,
                               lp["self"][f"l{l}"].astype(pos.dtype))
            new_feats[l] = feats[l] + mixed
        # gated nonlinearity: scalars gate all l-channels
        gates = (new_feats[0][:, :, 0] @ lp["gate"].astype(pos.dtype)
                 ).reshape(n1, L_MAX + 1, C)
        out = {}
        for l in range(L_MAX + 1):
            g = jax.nn.silu(gates[:, l, :])[:, :, None]
            out[l] = (new_feats[l] * g) * live[:, :, None]
        return out

    step = jax.checkpoint(layer) if remat else layer
    for lp in params["layers"]:
        feats = step(feats, lp)

    node_e = mlp2_apply(params["head"], feats[0][:, :, 0])[:, 0] * live[:, 0]
    if graph_ids is None:
        return node_e.sum()
    return jax.ops.segment_sum(node_e, graph_ids, cfg.n_graphs + 1)[:-1]
