"""Transformer LM supporting the five assigned LM architectures.

Features: GQA (qk-norm optional), MLA (DeepSeek), sliding-window + Gemma
local:global attention patterns, dense SwiGLU or MoE FFN (with shared
experts / DS3 sigmoid router), optional MTP head, tied embeddings.

Training/prefill path scans over *stacked* layer groups (contiguous layers
with identical structure) to keep the HLO small — essential for lowering the
61-layer DeepSeek config.  The decode path unrolls layers in Python so each
layer can own a heterogeneous KV cache (full-length for global layers,
window-length rotating for local layers, latent for MLA).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from .layers import (LMConfig, MoEConfig, apply_gqa, apply_mla, apply_mlp,
                     apply_moe, dense_init, gqa_axes, init_gqa, init_mla,
                     init_mlp, init_moe, mla_axes, mlp_axes, moe_axes,
                     rms_norm, unroll_enabled)

# Optional activation sharding constraint applied right after the embedding
# lookup.  With FSDP-sharded embeddings (DeepSeek: embed dim over "data")
# the lookup output inherits a d-sharded layout that conflicts with the
# batch sharding and sends the SPMD partitioner into involuntary full
# rematerialisation (observed: 15+ min compiles).  families.py sets this to
# P(dp, None, None) for the dry-run; None = no constraint (smoke tests).
_ACT_SPEC = None


def set_act_spec(spec):
    global _ACT_SPEC
    _ACT_SPEC = spec


def _constrain_act(x):
    if _ACT_SPEC is not None:
        x = jax.lax.with_sharding_constraint(x, _ACT_SPEC)
    return x


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    count: int
    is_moe: bool


def layer_groups(cfg: LMConfig) -> list[GroupSpec]:
    if cfg.is_moe and cfg.n_dense_layers > 0:
        return [GroupSpec(cfg.n_dense_layers, False),
                GroupSpec(cfg.n_layers - cfg.n_dense_layers, True)]
    return [GroupSpec(cfg.n_layers, cfg.is_moe)]


def _window_code(cfg: LMConfig, layer: int) -> int:
    w = cfg.layer_window(layer)
    return 0 if w is None else w  # 0 = global


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------
def init_layer(key, cfg: LMConfig, is_moe: bool):
    k1, k2 = jax.random.split(key)
    attn = init_mla(k1, cfg) if cfg.attn == "mla" else init_gqa(k1, cfg)
    if is_moe:
        ffn = init_moe(k2, cfg)
    else:
        d_ff = cfg.dense_d_ff if (cfg.is_moe and cfg.dense_d_ff) else cfg.d_ff
        ffn = init_mlp(k2, cfg.d_model, d_ff)
    return {"attn": attn, "ffn": ffn,
            "ln1": jnp.zeros((cfg.d_model,)), "ln2": jnp.zeros((cfg.d_model,))}


def layer_axes(cfg: LMConfig, is_moe: bool):
    attn = mla_axes(cfg) if cfg.attn == "mla" else gqa_axes(cfg)
    ffn = moe_axes(cfg) if is_moe else mlp_axes()
    return {"attn": attn, "ffn": ffn, "ln1": (None,), "ln2": (None,)}


def apply_layer(p, cfg: LMConfig, x, q_pos, window, *, is_moe: bool,
                kv_cache=None, moe_groups: int = 1, capture_kv: bool = False,
                moe_spec: tuple | None = None):
    attn_fn = apply_mla if cfg.attn == "mla" else apply_gqa
    h, new_cache = attn_fn(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps),
                           q_pos, window=window, kv_cache=kv_cache,
                           capture_kv=capture_kv)
    x = x + h
    z = rms_norm(x, p["ln2"], cfg.norm_eps)
    if is_moe:
        x = x + apply_moe(p["ffn"], cfg, z, n_groups=moe_groups,
                          moe_spec=moe_spec)
    else:
        x = x + apply_mlp(p["ffn"], z)
    return x, new_cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------
def init_lm(key, cfg: LMConfig):
    keys = jax.random.split(key, 4 + len(layer_groups(cfg)))
    params: dict[str, Any] = {
        "embed": dense_init(keys[0], (cfg.vocab, cfg.d_model), cfg.d_model),
        "final_norm": jnp.zeros((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(keys[1], (cfg.d_model, cfg.vocab),
                                    cfg.d_model)
    blocks = []
    for gi, grp in enumerate(layer_groups(cfg)):
        gkeys = jax.random.split(keys[2 + gi], grp.count)
        stacked = jax.vmap(lambda k: init_layer(k, cfg, grp.is_moe))(gkeys)
        blocks.append(stacked)
    params["blocks"] = blocks
    if cfg.mtp:
        k_m = jax.random.split(keys[-1], 3)
        params["mtp"] = {
            "proj": dense_init(k_m[0], (2 * cfg.d_model, cfg.d_model),
                               2 * cfg.d_model),
            "norm_h": jnp.zeros((cfg.d_model,)),
            "norm_e": jnp.zeros((cfg.d_model,)),
            "block": init_layer(k_m[1], cfg, False),
        }
    return params


def lm_axes(cfg: LMConfig):
    axes: dict[str, Any] = {
        "embed": ("vocab", "embed"),
        "final_norm": (None,),
    }
    if not cfg.tie_embeddings:
        axes["head"] = ("embed", "vocab")
    blocks = []
    for grp in layer_groups(cfg):
        la = layer_axes(cfg, grp.is_moe)
        stacked = jax.tree_util.tree_map(
            lambda t: ("layers",) + tuple(t), la,
            is_leaf=lambda t: isinstance(t, tuple))
        blocks.append(stacked)
    axes["blocks"] = blocks
    if cfg.mtp:
        axes["mtp"] = {
            "proj": ("embed", None),
            "norm_h": (None,), "norm_e": (None,),
            "block": layer_axes(cfg, False),
        }
    return axes


def _scan_group(stacked, cfg: LMConfig, x, q_pos, windows, is_moe: bool,
                moe_groups: int, remat: bool, moe_spec=None):
    def body(x, per_layer):
        lp, win = per_layer
        if remat:
            fn = jax.checkpoint(
                lambda p_, x_, qp_, w_: apply_layer(
                    p_, cfg, x_, qp_, w_, is_moe=is_moe,
                    moe_groups=moe_groups, moe_spec=moe_spec)[0])
            return fn(lp, x, q_pos, win), None
        y, _ = apply_layer(lp, cfg, x, q_pos, win, is_moe=is_moe,
                           moe_groups=moe_groups, moe_spec=moe_spec)
        return y, None
    x, _ = jax.lax.scan(body, x, (stacked, windows),
                        unroll=True if unroll_enabled() else 1)
    return x


def forward(params, cfg: LMConfig, tokens: jnp.ndarray, *,
            compute_dtype=jnp.bfloat16, moe_groups: int = 1,
            remat: bool = True, skip_logits: bool = False,
            moe_spec: tuple | None = None) -> jnp.ndarray:
    """tokens (B, S) int32 -> (logits (B, S, V) float32 | None, h)."""
    B, S = tokens.shape
    x = _constrain_act(params["embed"][tokens].astype(compute_dtype))
    q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    layer0 = 0
    for grp, stacked in zip(layer_groups(cfg), params["blocks"]):
        wins = jnp.asarray([_window_code(cfg, layer0 + i)
                            for i in range(grp.count)], dtype=jnp.int32)
        x = _scan_group(stacked, cfg, x, q_pos, wins, grp.is_moe, moe_groups,
                        remat, moe_spec=moe_spec)
        layer0 += grp.count
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if skip_logits:
        return None, x
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(compute_dtype))
    return logits.astype(jnp.float32), x


def mtp_hidden(params, cfg: LMConfig, h: jnp.ndarray, tokens: jnp.ndarray,
               *, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """DeepSeek-V3 MTP trunk: hidden states predicting token t+2 from
    backbone state at t + embedding of token t+1.  Returns (B, S-1, d)
    (final-normed); the shared head/streaming CE handles the logits."""
    mp = params["mtp"]
    B, S, d = h.shape
    h_in = rms_norm(_constrain_act(h[:, :-1]), mp["norm_h"], cfg.norm_eps)
    e_next = _constrain_act(
        params["embed"][tokens[:, 1:]].astype(compute_dtype))
    e_in = rms_norm(e_next, mp["norm_e"], cfg.norm_eps)
    z = jnp.concatenate([h_in, e_in], axis=-1)
    z = _constrain_act(
        jnp.einsum("bsd,dk->bsk", z, mp["proj"].astype(compute_dtype)))
    q_pos = jnp.broadcast_to(
        jnp.arange(S - 1, dtype=jnp.int32)[None, :], (B, S - 1))
    z, _ = apply_layer(mp["block"], cfg, z, q_pos, jnp.int32(0),
                       is_moe=False)
    return rms_norm(z, params["final_norm"], cfg.norm_eps)


CE_CHUNK = 512  # sequence-chunk size for streaming cross-entropy


def _chunked_nll(x: jnp.ndarray, head: jnp.ndarray, targets: jnp.ndarray,
                 chunk: int = CE_CHUNK) -> jnp.ndarray:
    """Mean next-token NLL without materialising (B, S, V) logits: the
    head matmul + log-softmax + gather run per sequence chunk under
    jax.checkpoint, so peak memory is one chunk's logits (big-vocab
    essential: gemma3's V=262144 would otherwise dominate)."""
    B, S, d = x.shape

    def one(args):
        xc, tc = args
        logits = jnp.einsum("bsd,dv->bsv", xc, head).astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, tc[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return nll.sum()

    one = jax.checkpoint(one)
    if S <= chunk:
        return one((x, targets)) / (B * S)
    n = S // chunk
    main = n * chunk
    xs = x[:, :main].reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    ts = targets[:, :main].reshape(B, n, chunk).transpose(1, 0, 2)
    if unroll_enabled():
        total = sum(one((xs[i], ts[i])) for i in range(n))
    else:
        total = jax.lax.map(one, (xs, ts)).sum()
    if S > main:  # remainder chunk (e.g. the MTP trunk's S-2 positions)
        total = total + one((x[:, main:], targets[:, main:]))
    return total / (B * S)


def lm_loss(params, cfg: LMConfig, tokens: jnp.ndarray, *,
            compute_dtype=jnp.bfloat16, moe_groups: int = 1,
            remat: bool = True, mtp_weight: float = 0.3,
            moe_spec: tuple | None = None) -> jnp.ndarray:
    """Next-token cross-entropy (+ optional MTP auxiliary loss), streaming
    over sequence chunks so full-vocab logits never materialise."""
    _, h = forward(params, cfg, tokens, compute_dtype=compute_dtype,
                   moe_groups=moe_groups, remat=remat, skip_logits=True,
                   moe_spec=moe_spec)
    head = (params["embed"].T if cfg.tie_embeddings else params["head"]
            ).astype(compute_dtype)
    loss = _chunked_nll(h[:, :-1], head, tokens[:, 1:])
    if cfg.mtp:
        hm = mtp_hidden(params, cfg, h, tokens, compute_dtype=compute_dtype)
        loss = loss + mtp_weight * _chunked_nll(hm[:, :-1], head,
                                                tokens[:, 2:])
    return loss


# ---------------------------------------------------------------------------
# serving: heterogeneous per-layer KV caches
# ---------------------------------------------------------------------------
def make_cache(cfg: LMConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> list[dict]:
    """One cache dict per layer.  Local layers get a rotating window cache;
    MLA layers cache the compressed latent (the paper-exact memory win)."""
    caches = []
    for layer in range(cfg.n_layers):
        w = cfg.layer_window(layer)
        L = max_len if w is None else min(max_len, w)
        if cfg.attn == "mla":
            caches.append({
                "c_kv": jnp.zeros((batch, L, cfg.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((batch, L, 1, cfg.qk_rope_dim), dtype),
            })
        else:
            caches.append({
                "k": jnp.zeros((batch, L, cfg.n_kv_heads, cfg.d_head), dtype),
                "v": jnp.zeros((batch, L, cfg.n_kv_heads, cfg.d_head), dtype),
            })
    return caches


def _slot_positions(pos: jnp.ndarray, L: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Absolute position stored in each rotating slot, per example:
    pos (B,) -> k_pos (B, L) where slot i holds p = pos - ((pos - i) mod L)."""
    i = jnp.arange(L, dtype=jnp.int32)[None, :]
    p = pos[:, None] - ((pos[:, None] - i) % L)
    return p, p >= 0


def cache_len(cache: dict) -> int:
    """Static cache length, derived from array shape (never traced)."""
    name = "c_kv" if "c_kv" in cache else "k"
    return cache[name].shape[1]


def _cache_cb(cache: dict, pos: jnp.ndarray, batch: int):
    """pos (B,) int32: per-slot decode positions (continuous batching)."""
    L = cache_len(cache)
    wi = pos % L
    b_idx = jnp.arange(batch, dtype=jnp.int32)

    def cb(*new):
        names = ("c_kv", "k_rope") if "c_kv" in cache else ("k", "v")
        new_cache = {}
        outs = []
        for name, arr in zip(names, new):
            upd = cache[name].at[b_idx, wi].set(
                arr[:, 0].astype(cache[name].dtype))
            new_cache[name] = upd
            outs.append(upd)
        k_pos, valid = _slot_positions(pos, L)
        return (*outs, k_pos, valid, new_cache)

    return cb


def _layer_param(params, cfg: LMConfig, layer: int):
    """Extract layer ``layer``'s params from the stacked groups."""
    g0 = 0
    for grp, stacked in zip(layer_groups(cfg), params["blocks"]):
        if layer < g0 + grp.count:
            idx = layer - g0
            return jax.tree_util.tree_map(lambda a: a[idx], stacked), grp.is_moe
        g0 += grp.count
    raise IndexError(layer)


def decode_step(params, cfg: LMConfig, caches: list[dict],
                tokens: jnp.ndarray, pos: jnp.ndarray, *,
                compute_dtype=jnp.bfloat16):
    """One decode step: tokens (B, 1) int32, pos scalar or (B,) int32
    (0-based index of each slot's new token — per-slot positions enable
    continuous batching).  Returns (logits (B, V), new caches)."""
    B = tokens.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = _constrain_act(params["embed"][tokens].astype(compute_dtype))
    q_pos = pos[:, None]
    new_caches = []
    if _uniform_cache(cfg):
        # scan over stacked layers + stacked caches (uniform shapes);
        # keeps the decode HLO one-layer-sized for the 61-layer configs
        layer0 = 0
        for grp, stacked in zip(layer_groups(cfg), params["blocks"]):
            wins = jnp.asarray([_window_code(cfg, layer0 + i)
                                for i in range(grp.count)], dtype=jnp.int32)
            cache_stack = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *caches[layer0:layer0 + grp.count])

            def body(x, per, _moe=grp.is_moe):
                lp, win, lc = per
                cb = _cache_cb(lc, pos, B)
                y, nc = apply_layer(lp, cfg, x, q_pos, win, is_moe=_moe,
                                    kv_cache=cb, moe_groups=1)
                return y, nc

            x, new_stack = jax.lax.scan(body, x, (stacked, wins, cache_stack))
            for i in range(grp.count):
                new_caches.append(jax.tree_util.tree_map(
                    lambda a, _i=i: a[_i], new_stack))
            layer0 += grp.count
    else:
        for layer in range(cfg.n_layers):
            lp, is_moe = _layer_param(params, cfg, layer)
            w = cfg.layer_window(layer)
            win = jnp.int32(0 if w is None else w)
            cb = _cache_cb(caches[layer], pos, B)
            x, nc = apply_layer(lp, cfg, x, q_pos, win, is_moe=is_moe,
                                kv_cache=cb, moe_groups=1)
            new_caches.append(nc)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(compute_dtype))
    return logits[:, 0].astype(jnp.float32), new_caches


def _uniform_cache(cfg: LMConfig) -> bool:
    """True when every layer's cache has identical shape (no mixed
    local/global pattern) — the scan-prefill eligibility condition."""
    wins = {cfg.layer_window(i) for i in range(cfg.n_layers)}
    return len(wins) == 1


def prefill(params, cfg: LMConfig, tokens: jnp.ndarray, *, max_len: int = 0,
            compute_dtype=jnp.bfloat16, moe_groups: int = 1):
    """Run the prompt through the model once, capturing per-layer caches
    (full attention over the prompt; only each layer's cache-length tail is
    retained, in rotating-slot order).  ``max_len`` (>= S) sizes the caches
    for subsequent decode.  Returns (last-position logits (B, V), caches).

    When every layer shares one cache shape, the layer loop runs as a
    lax.scan over the stacked groups (KV capture via scan outputs) — the
    python-unrolled 61-layer DeepSeek prefill graph sent the 512-device
    SPMD partitioner into hour-long compiles; the scan version keeps the
    HLO one-layer-sized.  Mixed local/global archs (gemma3) keep the
    unrolled path (heterogeneous cache shapes cannot stack)."""
    B, S = tokens.shape
    max_len = max(max_len, S)
    x = _constrain_act(params["embed"][tokens].astype(compute_dtype))
    q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    caches = make_cache(cfg, B, max_len, dtype=compute_dtype)
    new_caches = []
    if _uniform_cache(cfg):
        layer0 = 0
        for grp, stacked in zip(layer_groups(cfg), params["blocks"]):
            wins = jnp.asarray([_window_code(cfg, layer0 + i)
                                for i in range(grp.count)], dtype=jnp.int32)

            def body(x, per_layer, _moe=grp.is_moe):
                lp, win = per_layer
                y, kv = apply_layer(lp, cfg, x, q_pos, win, is_moe=_moe,
                                    capture_kv=True, moe_groups=moe_groups)
                return y, kv

            x, kv_stack = jax.lax.scan(body, x, (stacked, wins))
            for i in range(grp.count):
                kv = jax.tree_util.tree_map(lambda a, _i=i: a[_i], kv_stack)
                new_caches.append(_fill_cache(caches[layer0 + i], kv, S))
            layer0 += grp.count
    else:
        for layer in range(cfg.n_layers):
            lp, is_moe = _layer_param(params, cfg, layer)
            w = cfg.layer_window(layer)
            win = jnp.int32(0 if w is None else w)
            x, kv = apply_layer(lp, cfg, x, q_pos, win, is_moe=is_moe,
                                capture_kv=True, moe_groups=moe_groups)
            new_caches.append(_fill_cache(caches[layer], kv, S))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["head"])
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], head.astype(compute_dtype))
    return logits[:, 0].astype(jnp.float32), new_caches


def _fill_cache(cache: dict, kv: tuple, S: int) -> dict:
    """Write the last min(S, L) prompt positions into rotating-slot order
    (slot of absolute position p is p % L)."""
    L = cache_len(cache)
    T = min(S, L)
    start = S - T
    slots = ((start + jnp.arange(T, dtype=jnp.int32)) % L)
    names = ("c_kv", "k_rope") if "c_kv" in cache else ("k", "v")
    out = {}
    for name, arr in zip(names, kv):
        tail = arr[:, -T:].astype(cache[name].dtype)
        out[name] = cache[name].at[:, slots].set(tail)
    return out
