"""GNN models: shared segment-sum message passing + GIN + EGNN.

JAX has no sparse message-passing primitive — per the assignment, the
scatter/gather substrate IS part of the system: edges are (senders,
receivers) int32 arrays padded with a dummy node id ``n_nodes`` (row N of the
feature matrix is a zero row), aggregation is ``jax.ops.segment_sum``.

Graph batches (fixed shapes for jit):
    node_feat (N+1, F), senders/receivers (E,) int32 (dummy = N),
    graph_ids (N+1,) int32 for batched-small-graph readout (dummy = G).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import dense_init


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_in: int
    n_classes: int = 0            # node/graph classification head size
    task: str = "node"            # "node" | "graph" | "energy"
    # GIN
    learn_eps: bool = True
    # EGNN / NequIP / DimeNet extras live in their own configs
    n_graphs: int = 1             # graphs per batch (graph-level tasks)


def segment_mean(vals, seg, num):
    s = jax.ops.segment_sum(vals, seg, num)
    c = jax.ops.segment_sum(jnp.ones(vals.shape[:1], vals.dtype), seg, num)
    return s / jnp.maximum(c, 1.0)[..., None] if vals.ndim > 1 else \
        s / jnp.maximum(c, 1.0)


def mlp2_init(key, d_in, d_hid, d_out):
    k1, k2 = jax.random.split(key)
    return {"w1": dense_init(k1, (d_in, d_hid), d_in),
            "b1": jnp.zeros((d_hid,)),
            "w2": dense_init(k2, (d_hid, d_out), d_hid),
            "b2": jnp.zeros((d_out,))}


def mlp2_apply(p, x):
    h = jax.nn.relu(x @ p["w1"].astype(x.dtype) + p["b1"].astype(x.dtype))
    return h @ p["w2"].astype(x.dtype) + p["b2"].astype(x.dtype)


def mlp2_axes():
    return {"w1": (None, "ffn"), "b1": ("ffn",),
            "w2": ("ffn", None), "b2": (None,)}


# ---------------------------------------------------------------------------
# GIN  [arXiv:1810.00826] — n_layers=5 d=64 sum aggregator, learnable eps
# ---------------------------------------------------------------------------
def init_gin(key, cfg: GNNConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)
    layers = []
    d = cfg.d_in
    for i in range(cfg.n_layers):
        layers.append({"mlp": mlp2_init(keys[i], d, cfg.d_hidden,
                                        cfg.d_hidden),
                       "eps": jnp.zeros(())})
        d = cfg.d_hidden
    return {"layers": layers,
            "head": dense_init(keys[-1], (cfg.d_hidden, cfg.n_classes),
                               cfg.d_hidden)}


def gin_axes(cfg: GNNConfig):
    return {"layers": [{"mlp": mlp2_axes(), "eps": ()}
                       for _ in range(cfg.n_layers)],
            "head": (None, None)}


def apply_gin(params, cfg: GNNConfig, node_feat, senders, receivers,
              graph_ids=None, remat: bool = False):
    """node_feat (N+1, F) with zero dummy row. Returns logits:
    node task -> (N+1, C); graph task -> (G, C)."""
    n1 = node_feat.shape[0]
    h = node_feat

    def layer(h, lp):
        agg = jax.ops.segment_sum(h[senders], receivers, n1)
        eps = lp["eps"] if cfg.learn_eps else 0.0
        h = mlp2_apply(lp["mlp"], (1.0 + eps) * h + agg)
        return h * (jnp.arange(n1) < n1 - 1)[:, None]  # keep dummy row zero

    step = jax.checkpoint(layer) if remat else layer
    for lp in params["layers"]:
        h = step(h, lp)
    if cfg.task == "graph":
        pooled = jax.ops.segment_sum(h, graph_ids, cfg.n_graphs + 1)
        return pooled[:-1] @ params["head"].astype(h.dtype)
    return h @ params["head"].astype(h.dtype)


# ---------------------------------------------------------------------------
# EGNN  [arXiv:2102.09844] — n_layers=4 d=64 E(n)-equivariant
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    d_in: int
    n_graphs: int = 1
    coord_agg: str = "mean"


def init_egnn(key, cfg: EGNNConfig):
    keys = jax.random.split(key, cfg.n_layers * 3 + 2)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        layers.append({
            "phi_e": mlp2_init(keys[3 * i], 2 * d + 1, d, d),
            "phi_x": mlp2_init(keys[3 * i + 1], d, d, 1),
            "phi_h": mlp2_init(keys[3 * i + 2], 2 * d, d, d),
        })
    return {"embed": dense_init(keys[-2], (cfg.d_in, d), cfg.d_in),
            "layers": layers,
            "head": mlp2_init(keys[-1], d, d, 1)}


def egnn_axes(cfg: EGNNConfig):
    return {"embed": (None, "ffn"),
            "layers": [{"phi_e": mlp2_axes(), "phi_x": mlp2_axes(),
                        "phi_h": mlp2_axes()}
                       for _ in range(cfg.n_layers)],
            "head": mlp2_axes()}


def apply_egnn(params, cfg: EGNNConfig, node_feat, pos, senders, receivers,
               graph_ids=None, remat: bool = False):
    """node_feat (N+1, F), pos (N+1, 3). Returns per-graph scalar (G,)
    (energy-style readout) and final coordinates."""
    n1 = node_feat.shape[0]
    live = (jnp.arange(n1) < n1 - 1)[:, None].astype(node_feat.dtype)
    h = node_feat @ params["embed"].astype(node_feat.dtype)
    x = pos

    def layer(carry, lp):
        h, x = carry
        d_vec = x[senders] - x[receivers]
        d2 = jnp.sum(d_vec * d_vec, axis=-1, keepdims=True)
        m = mlp2_apply(lp["phi_e"],
                       jnp.concatenate([h[senders], h[receivers], d2], -1))
        m = jax.nn.silu(m)
        # coordinate update (receiver-centric): x_i += agg_j (x_i - x_j) phi_x
        w = mlp2_apply(lp["phi_x"], m)
        upd = segment_mean(-d_vec * w, receivers, n1) \
            if cfg.coord_agg == "mean" else \
            jax.ops.segment_sum(-d_vec * w, receivers, n1)
        x = x + upd * live
        agg = jax.ops.segment_sum(m, receivers, n1)
        h = h + mlp2_apply(lp["phi_h"], jnp.concatenate([h, agg], -1))
        return h * live, x

    step = jax.checkpoint(layer) if remat else layer
    for lp in params["layers"]:
        h, x = step((h, x), lp)
    node_e = mlp2_apply(params["head"], h)[:, 0]
    if graph_ids is None:
        return node_e.sum(), x
    e = jax.ops.segment_sum(node_e, graph_ids, cfg.n_graphs + 1)[:-1]
    return e, x
