"""Qwen3-0.6B [hf:Qwen/Qwen3-0.6B family]: 28L d_model=1024 16H (GQA kv=8)
head_dim=128, d_ff=3072, vocab=151936, qk-norm, tied embeddings."""
from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.layers import LMConfig

ARCH = ArchSpec(
    id="qwen3-0.6b",
    family="lm",
    model_cfg=LMConfig(
        name="qwen3-0.6b", n_layers=28, d_model=1024, n_heads=16,
        n_kv_heads=8, d_head=128, d_ff=3072, vocab=151936, qk_norm=True,
        rope_theta=1_000_000.0, tie_embeddings=True),
    smoke_cfg=LMConfig(
        name="qwen3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=256, qk_norm=True),
    shapes=dict(LM_SHAPES),
    skip_shapes={"long_500k": "pure full-attention GQA (no sub-quadratic "
                              "mechanism); skipped per assignment"},
    param_rules={"embed": None, "heads": "model", "kv_heads": "model",
                 "head_dim": None, "ffn": "model", "vocab": "model",
                 "layers": None},
)
