from .base import (ArchSpec, GNN_SHAPES, LM_SHAPES, REC_SHAPES, all_archs,
                   get_arch, list_archs)

__all__ = ["ArchSpec", "GNN_SHAPES", "LM_SHAPES", "REC_SHAPES", "all_archs",
           "get_arch", "list_archs"]
