"""DeepSeek-V3-671B [arXiv:2412.19437]: 61L d_model=7168 128H, MLA,
1 shared + 256 routed experts top-8 (d_expert=2048), MTP, vocab=129280.
First 3 layers dense (d_ff=18432).  bf16 params + bf16 Adam moments
(the DeepSeek-V3 recipe) + FSDP(embed/q_lora/kv_lora over data) x
TP/EP(heads/experts over model) to fit 16 GB/chip."""
from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.layers import LMConfig, MoEConfig

ARCH = ArchSpec(
    id="deepseek-v3-671b",
    family="lm",
    model_cfg=LMConfig(
        name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
        n_kv_heads=128, d_head=192, d_ff=2048, vocab=129280, attn="mla",
        q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128, tie_embeddings=False,
        moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                      router="sigmoid_ds3", routed_scale=2.5),
        n_dense_layers=3, dense_d_ff=18432, mtp=True),
    smoke_cfg=LMConfig(
        name="deepseek-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=24, d_ff=48, vocab=256, attn="mla",
        q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, tie_embeddings=False,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=48, n_shared=1,
                      router="sigmoid_ds3"),
        n_dense_layers=1, dense_d_ff=96, mtp=True),
    shapes=dict(LM_SHAPES),
    # MLA latent KV (576 B/token/layer) => 512K-token cache fits: run it
    skip_shapes={},
    param_rules={"embed": "data", "heads": "model", "kv_heads": "model",
                 "head_dim": None, "ffn": None, "vocab": "model",
                 "experts": "model", "q_lora": "data", "kv_lora": "data",
                 "layers": None},
    moment_dtype="bfloat16",
    param_dtype="bfloat16",
    accum_steps=16,  # 4096 tokens/device/micro: dispatch buffers ~0.6 GB
    notes="FSDP x TP/EP; bf16 moments per DeepSeek-V3 paper",
)
