"""Gemma3-1B [hf:google/gemma-3-1b-pt]: 26L d_model=1152 4H (GQA kv=1)
head_dim=256, d_ff=6912, vocab=262144, 5 local (w=512) : 1 global."""
from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.layers import LMConfig

ARCH = ArchSpec(
    id="gemma3-1b",
    family="lm",
    model_cfg=LMConfig(
        name="gemma3-1b", n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
        d_head=256, d_ff=6912, vocab=262144, window=512, local_global=(5, 1),
        rope_theta=1_000_000.0, tie_embeddings=True),
    smoke_cfg=LMConfig(
        name="gemma3-smoke", n_layers=3, d_model=64, n_heads=2, n_kv_heads=1,
        d_head=32, d_ff=128, vocab=256, window=8, local_global=(2, 1)),
    shapes=dict(LM_SHAPES),
    # 5:1 local:global bounds the local-layer KV -> long_500k runs
    skip_shapes={},
    param_rules={"embed": None, "heads": None, "kv_heads": None,
                 "head_dim": None, "ffn": "model", "vocab": "model",
                 "layers": None},
)
