"""EGNN [arXiv:2102.09844]: n_layers=4 d_hidden=64, E(n)-equivariant."""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn import EGNNConfig

ARCH = ArchSpec(
    id="egnn",
    family="gnn",
    gnn_kind="egnn",
    model_cfg=EGNNConfig(name="egnn", n_layers=4, d_hidden=64, d_in=16),
    smoke_cfg=EGNNConfig(name="egnn-smoke", n_layers=2, d_hidden=16, d_in=8),
    shapes=dict(GNN_SHAPES),
    param_rules={"ffn": None},
)
