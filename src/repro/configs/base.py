"""Arch config registry.

Every assigned architecture gets one module in this package exposing an
``ARCH: ArchSpec``.  ``get_arch(id)`` / ``list_archs()`` are the CLI entry
points (``--arch <id>``).  Family-specific dry-run/step builders live in
configs/families.py.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any


@dataclasses.dataclass(frozen=True)
class LMShape:
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


@dataclasses.dataclass(frozen=True)
class GNNShape:
    kind: str            # "full" | "minibatch" | "molecule"
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch: int = 0            # molecule: graphs per batch
    batch_nodes: int = 0      # minibatch: global seed nodes
    fanout: tuple = ()        # minibatch fanouts
    max_nodes: int = 0        # molecule: nodes per graph
    max_edges: int = 0


@dataclasses.dataclass(frozen=True)
class RecShape:
    kind: str            # "train" | "serve" | "retrieval"
    batch: int
    n_candidates: int = 0


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    id: str
    family: str                       # "lm" | "gnn" | "recsys"
    model_cfg: Any
    smoke_cfg: Any
    shapes: dict[str, Any]
    param_rules: dict[str, Any]
    skip_shapes: dict[str, str] = dataclasses.field(default_factory=dict)
    gnn_kind: str = ""                # "gin" | "egnn" | "nequip" | "dimenet"
    moment_dtype: str = "float32"     # optimizer moment dtype
    param_dtype: str = "float32"      # parameter storage dtype at scale
    accum_steps: int = 1              # microbatch gradient accumulation
                                      # (bounds MoE dispatch buffers)
    lm_batch_axes: Any = None         # None = DP axes; "ALL" = every mesh
                                      # axis (pure-DP for small models)
    grad_dtype: str = ""              # "" = native; "bfloat16" halves the
                                      # DP gradient all-reduce
    notes: str = ""


_ARCH_MODULES = [
    "olmoe_1b_7b", "deepseek_v3_671b", "qwen3_0_6b", "gemma3_1b",
    "h2o_danube_1_8b", "dimenet", "gin_tu", "nequip", "egnn", "fm",
]


def list_archs() -> list[str]:
    out = []
    for m in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        out.append(mod.ARCH.id)
    return out


def get_arch(arch_id: str) -> ArchSpec:
    for m in _ARCH_MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        if mod.ARCH.id == arch_id:
            return mod.ARCH
    raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")


def all_archs() -> list[ArchSpec]:
    return [importlib.import_module(f"repro.configs.{m}").ARCH
            for m in _ARCH_MODULES]


# the canonical shape sets from the assignment
LM_SHAPES = {
    "train_4k": LMShape("train", 4096, 256),
    "prefill_32k": LMShape("prefill", 32768, 32),
    "decode_32k": LMShape("decode", 32768, 128),
    "long_500k": LMShape("decode", 524288, 1),
}

GNN_SHAPES = {
    "full_graph_sm": GNNShape("full", n_nodes=2708, n_edges=10556,
                              d_feat=1433),
    "minibatch_lg": GNNShape("minibatch", n_nodes=232965,
                             n_edges=114615892, batch_nodes=1024,
                             fanout=(15, 10)),
    "ogb_products": GNNShape("full", n_nodes=2449029, n_edges=61859140,
                             d_feat=100),
    "molecule": GNNShape("molecule", batch=128, max_nodes=30, max_edges=64),
}

REC_SHAPES = {
    "train_batch": RecShape("train", 65536),
    "serve_p99": RecShape("serve", 512),
    "serve_bulk": RecShape("serve", 262144),
    "retrieval_cand": RecShape("retrieval", 1, n_candidates=1_000_000),
}
