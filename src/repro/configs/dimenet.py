"""DimeNet [arXiv:2003.03123]: n_blocks=6 d_hidden=128 n_bilinear=8
n_spherical=7 n_radial=6; triplet cap = 2x edges on full-graph shapes,
4x edges on molecule batches (DESIGN §4)."""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.dimenet import DimeNetConfig

ARCH = ArchSpec(
    id="dimenet",
    family="gnn",
    gnn_kind="dimenet",
    model_cfg=DimeNetConfig(name="dimenet", n_blocks=6, d_hidden=128,
                            n_bilinear=8, n_spherical=7, n_radial=6,
                            cutoff=5.0, n_species=8),
    smoke_cfg=DimeNetConfig(name="dimenet-smoke", n_blocks=2, d_hidden=16,
                            n_bilinear=4, n_spherical=3, n_radial=3,
                            n_species=4),
    shapes=dict(GNN_SHAPES),
    param_rules={"ffn": None},
)
