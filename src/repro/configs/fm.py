"""Factorization Machine [Rendle ICDM'10]: 39 sparse fields, embed_dim=10,
pairwise interactions via the O(nk) sum-square trick.  Tables: 2^20 rows
per field (Criteo-scale), row-sharded over the model axis."""
from repro.configs.base import ArchSpec, REC_SHAPES
from repro.models.fm import FMConfig

ARCH = ArchSpec(
    id="fm",
    family="recsys",
    model_cfg=FMConfig(name="fm", n_fields=39, embed_dim=10,
                       rows_per_field=1 << 20),
    smoke_cfg=FMConfig(name="fm-smoke", n_fields=8, embed_dim=4,
                       rows_per_field=128),
    shapes=dict(REC_SHAPES),
    param_rules={"table_rows": "model"},
)
