"""h2o-danube-1.8b [arXiv:2401.16818]: 24L d_model=2560 32H (GQA kv=8)
d_ff=6912 vocab=32000, llama+mistral mix with sliding-window attention."""
from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.layers import LMConfig

ARCH = ArchSpec(
    id="h2o-danube-1.8b",
    family="lm",
    model_cfg=LMConfig(
        name="h2o-danube-1.8b", n_layers=24, d_model=2560, n_heads=32,
        n_kv_heads=8, d_head=80, d_ff=6912, vocab=32000, window=4096,
        local_global=(1, 0), tie_embeddings=False),
    smoke_cfg=LMConfig(
        name="danube-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_head=16, d_ff=128, vocab=256, window=16, local_global=(1, 0)),
    shapes=dict(LM_SHAPES),
    # SWA bounds every layer's KV to the window -> long_500k runs
    skip_shapes={},
    param_rules={"embed": None, "heads": "model", "kv_heads": "model",
                 "head_dim": None, "ffn": "model", "vocab": "model",
                 "layers": None},
)
