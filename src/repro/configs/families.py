"""Family-specific dry-run builders: (arch, shape, mesh) -> (fn, args).

``build_cell`` returns a step function plus ShapeDtypeStruct arguments with
NamedShardings attached, ready for ``jax.jit(fn).lower(*args)`` — no device
allocation ever happens (the ShapeDtypeStruct pattern).  The same builders
power the smoke tests with real (reduced-config) arrays.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, GNNShape, LMShape, RecShape
from repro.distributed.sharding import (cast_float_leaves, spec_for_leaf,
                                        tree_shardings)
from repro.launch.mesh import dp_axes, dp_size, mesh_axes
from repro.models import transformer as T
from repro.models import dimenet as DM
from repro.models import fm as FM
from repro.models import gnn as G
from repro.models import nequip as NQ
from repro.models.layers import LMConfig
from repro.train import optim
from repro.train.loop import TrainConfig, TrainState, make_train_step

KEY_SDS = jax.ShapeDtypeStruct((2,), jnp.uint32)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _all_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


@dataclasses.dataclass
class Cell:
    """One dry-run cell: jit-ready function + shaped/sharded args."""
    fn: Callable
    args: tuple
    static_desc: str = ""
    out_shardings: Any = None     # optional
    donate: tuple = ()            # donated argnums (train: state)
    has_loops: bool = False       # trace contains scan/map (needs pass 2)
    # cost-probe cells: (cell_l1, cell_l2, l1, l2, l_full).  Layers within a
    # group are HLO-identical, so every cost metric is exactly linear in the
    # group layer count: compiling two small unrolled twins and
    # extrapolating matches the full unroll at a fraction of compile time.
    probe: Any = None

    act_spec: Any = None          # embedding-output sharding constraint

    def lower(self, unroll: bool = False):
        """AOT-lower.  ``unroll=True`` unrolls internal loops at trace time
        so cost_analysis sees every iteration (XLA counts while bodies
        once); used by the roofline extraction, not by execution."""
        from repro.models import layers as _L
        from repro.models import transformer as _T
        kw = {}
        if self.out_shardings is not None:
            kw["out_shardings"] = self.out_shardings
        if self.donate:
            kw["donate_argnums"] = self.donate
        _L.set_unroll(unroll)
        _T.set_act_spec(self.act_spec)
        inner = self.fn

        def fresh(*a):  # fresh identity per call: defeats the jit trace
            return inner(*a)   # cache so the unroll flag is honoured

        try:
            return jax.jit(fresh, **kw).lower(*self.args)
        finally:
            _L.set_unroll(False)
            _T.set_act_spec(None)


# ---------------------------------------------------------------------------
# shared: optimizer-state shaping
# ---------------------------------------------------------------------------
def train_state_shapes(params_sds, moment_dtype):
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, moment_dtype)
    m = jax.tree_util.tree_map(zeros, params_sds)
    v = jax.tree_util.tree_map(zeros, params_sds)
    return TrainState(params=params_sds,
                      opt_state=optim.OptState(
                          step=jax.ShapeDtypeStruct((), jnp.int32), m=m, v=v),
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def train_state_shardings(params_shardings, mesh):
    rep = NamedSharding(mesh, P())
    m = params_shardings
    return TrainState(params=params_shardings,
                      opt_state=optim.OptState(step=rep, m=m, v=m), step=rep)


def _attach(shapes, shardings):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def _raw_train_step(loss_fn, moment_dtype, accum_steps: int = 1,
                    grad_dtype=None):
    tcfg = TrainConfig(optimizer="adamw", moment_dtype=moment_dtype,
                       accum_steps=accum_steps, grad_dtype=grad_dtype)
    return make_train_step(loss_fn, tcfg, jit=False)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------
def lm_params_shapes(cfg: LMConfig, param_dtype):
    shapes = jax.eval_shape(lambda k: T.init_lm(k, cfg), KEY_SDS)
    return cast_float_leaves(shapes, param_dtype)


def lm_param_shardings(arch: ArchSpec, cfg: LMConfig, shapes, mesh):
    axes = T.lm_axes(cfg)
    return tree_shardings(axes, shapes, arch.param_rules, mesh)


def _lm_cache_shardings(arch: ArchSpec, cfg: LMConfig, cache_shapes, mesh,
                        batch: int):
    """Per-layer cache shardings: batch over DP when divisible, else KV
    length over (data, model) — sequence-parallel decode for batch=1."""
    dp = dp_axes(mesh)
    dpn = dp_size(mesh)
    out = []
    for layer_cache in cache_shapes:
        lc = {}
        for name, s in layer_cache.items():
            dims = [None] * len(s.shape)
            if batch % dpn == 0 and batch >= dpn:
                dims[0] = dp
                # shard kv heads or length over model
                if name in ("k", "v") and s.shape[2] % mesh_axes(mesh).get(
                        "model", 1) == 0 and s.shape[2] >= mesh_axes(mesh)["model"]:
                    dims[2] = "model"
                elif s.shape[1] % mesh_axes(mesh).get("model", 1) == 0:
                    dims[1] = "model"
            else:
                seq_axes = tuple(a for a in mesh.axis_names)
                if s.shape[1] % math.prod(mesh.devices.shape) == 0:
                    dims[1] = seq_axes
                elif s.shape[1] % mesh_axes(mesh)["model"] == 0:
                    dims[1] = "model"
            lc[name] = NamedSharding(mesh, P(*dims))
        out.append(lc)
    return out


def _probe_cfgs(cfg: LMConfig):
    """Two reduced-layer-count twins (l1 < l2) varying the biggest layer
    group; returns (cfg1, cfg2, l1, l2, l_full)."""
    if cfg.is_moe and cfg.n_dense_layers > 0:
        l_full = cfg.n_layers - cfg.n_dense_layers   # moe group varies
        base = cfg.n_dense_layers
    else:
        l_full = cfg.n_layers
        base = 0
    if l_full < 5:
        return None
    l1, l2 = 2, 4
    c1 = dataclasses.replace(cfg, n_layers=base + l1)
    c2 = dataclasses.replace(cfg, n_layers=base + l2)
    return c1, c2, l1, l2, l_full


def lm_cell(arch: ArchSpec, shape: LMShape, mesh, *,
            _probing: bool = False, _probe_accum: int | None = None,
            _probe_batch: int | None = None) -> Cell:
    cfg: LMConfig = arch.model_cfg
    if _probing is not False:
        cfg = _probing
    if _probe_accum is not None:
        arch = dataclasses.replace(arch, accum_steps=_probe_accum)
    if _probe_batch is not None:
        shape = dataclasses.replace(shape, global_batch=_probe_batch)
    pdt = _dtype(arch.param_dtype)
    mdt = _dtype(arch.moment_dtype)
    dp = (tuple(mesh.axis_names) if arch.lm_batch_axes == "ALL"
          else (arch.lm_batch_axes or dp_axes(mesh)))
    dpn = math.prod(dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                    for a in dp)
    p_shapes = lm_params_shapes(cfg, pdt)
    p_shard = lm_param_shardings(arch, cfg, p_shapes, mesh)

    if shape.kind == "train":
        B, S = shape.global_batch, shape.seq_len
        micro_tokens = (B // arch.accum_steps) * S
        moe_groups = dpn if micro_tokens % dpn == 0 else 1
        moe_spec = (dp, "model") if cfg.is_moe else None
        loss_fn = lambda p, b: T.lm_loss(p, cfg, b,
                                         compute_dtype=jnp.bfloat16,
                                         moe_groups=moe_groups, remat=True,
                                         moe_spec=moe_spec)
        step = _raw_train_step(loss_fn, mdt, arch.accum_steps,
                               _dtype(arch.grad_dtype)
                               if arch.grad_dtype else None)
        state_shapes = train_state_shapes(p_shapes, mdt)
        state_shard = train_state_shardings(p_shard, mesh)
        state_in = _attach(state_shapes, state_shard)
        batch_in = _sds((B, S), jnp.int32, mesh, P(dp, None))
        metrics_rep = {k: NamedSharding(mesh, P())
                       for k in ("loss", "grad_norm", "lr")}
        probe = None
        if _probing is False:
            pc = _probe_cfgs(cfg)
            if pc is not None:
                c1, c2, l1, l2, lf = pc
                A = arch.accum_steps
                if A > 2:
                    # bilinear probe: cost(L, A) = a + bA + cL + dAL.
                    # Four tiny probes (accum in {1,2} at the SAME
                    # microbatch size) keep compile memory bounded — the
                    # full unroll of a 61-layer x 16-microbatch MoE train
                    # step OOMs the 35 GB build host.
                    mb = B // A
                    cells = []
                    for li, lc in ((l1, c1), (l2, c2)):
                        for a in (1, 2):
                            cells.append(lm_cell(
                                arch, shape, mesh, _probing=lc,
                                _probe_accum=a, _probe_batch=a * mb))
                    probe = ("bilinear", cells, (l1, l2), (1, 2), (lf, A))
                else:
                    probe = ("linear",
                             lm_cell(arch, shape, mesh, _probing=c1),
                             lm_cell(arch, shape, mesh, _probing=c2),
                             l1, l2, lf)
        return Cell(fn=step, args=(state_in, batch_in),
                    out_shardings=(state_shard, metrics_rep), donate=(0,),
                    has_loops=True, probe=probe,
                    act_spec=P(dp, None, None),
                    static_desc=f"train_step B={B} S={S}")

    if shape.kind == "prefill":
        B, S = shape.global_batch, shape.seq_len

        def fn(params, tokens):
            return T.prefill(params, cfg, tokens, max_len=S,
                             compute_dtype=jnp.bfloat16)

        params_in = _attach(p_shapes, p_shard)
        tokens_in = _sds((B, S), jnp.int32, mesh, P(dp, None))
        # explicit output shardings: last-pos logits replicated-ish over dp,
        # caches batch/head-sharded (without this, compiler-chosen cache
        # layouts can replicate 8+ GB/device of KV)
        cache_out_shapes = jax.eval_shape(
            lambda: T.make_cache(cfg, B, S, dtype=jnp.bfloat16))
        cache_out = _lm_cache_shardings(arch, cfg, cache_out_shapes, mesh, B)
        logits_out = NamedSharding(mesh, P(dp, None))
        out_sh = (logits_out, cache_out)
        probe = None
        if _probing is False and S > 1024:
            pc = _probe_cfgs(cfg)
            if pc is not None:
                c1, c2, l1, l2, lf = pc
                probe = ("linear",
                         lm_cell(arch, shape, mesh, _probing=c1),
                         lm_cell(arch, shape, mesh, _probing=c2),
                         l1, l2, lf)
        return Cell(fn=fn, args=(params_in, tokens_in),
                    has_loops=(S > 1024),  # q-chunk/CE maps
                    probe=probe, out_shardings=out_sh,
                    act_spec=P(dp, None, None),
                    static_desc=f"prefill B={B} S={S}")

    # decode
    B, S = shape.global_batch, shape.seq_len

    def fn(params, caches, tokens, pos):
        return T.decode_step(params, cfg, caches, tokens, pos,
                             compute_dtype=jnp.bfloat16)

    cache_shapes = jax.eval_shape(
        lambda: T.make_cache(cfg, B, S, dtype=jnp.bfloat16))
    cache_shard = _lm_cache_shardings(arch, cfg, cache_shapes, mesh, B)
    caches_in = _attach(cache_shapes, cache_shard)
    tok_spec = P(dp, None) if B % dpn == 0 and B >= dpn else P(None, None)
    pos_spec = P(dp) if B % dpn == 0 and B >= dpn else P(None)
    tokens_in = _sds((B, 1), jnp.int32, mesh, tok_spec)
    pos_in = _sds((B,), jnp.int32, mesh, pos_spec)
    params_in = _attach(p_shapes, p_shard)
    return Cell(fn=fn, args=(params_in, caches_in, tokens_in, pos_in),
                static_desc=f"decode B={B} KV={S}")


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------
def _gnn_init_and_axes(arch: ArchSpec):
    kind = arch.gnn_kind
    cfg = arch.model_cfg
    if kind == "gin":
        return (lambda k: G.init_gin(k, cfg)), G.gin_axes(cfg)
    if kind == "egnn":
        return (lambda k: G.init_egnn(k, cfg)), G.egnn_axes(cfg)
    if kind == "nequip":
        return (lambda k: NQ.init_nequip(k, cfg)), NQ.nequip_axes(cfg)
    if kind == "dimenet":
        return (lambda k: DM.init_dimenet(k, cfg)), DM.dimenet_axes(cfg)
    raise ValueError(kind)


def _gnn_single_loss(arch: ArchSpec, remat: bool):
    """loss(params, batch_dict) over ONE graph batch (not vmapped)."""
    kind = arch.gnn_kind
    cfg = arch.model_cfg

    def loss(params, b):
        if kind == "gin":
            # per-shape task: graph regression when "targets" present,
            # node classification otherwise (same params either way)
            if "targets" in b:
                cfg_eff = dataclasses.replace(cfg, task="graph", n_graphs=1)
                logits = G.apply_gin(params, cfg_eff, b["node_feat"],
                                     b["senders"], b["receivers"],
                                     b["graph_ids"], remat=remat)
                return jnp.mean((logits[0, 0] - b["targets"]) ** 2)
            logits = G.apply_gin(params, cfg, b["node_feat"], b["senders"],
                                 b["receivers"], remat=remat)
            lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(lp, b["labels"][:, None], axis=1)[:, 0]
            w = b["train_mask"].astype(jnp.float32)
            return (nll * w).sum() / jnp.maximum(w.sum(), 1.0)
        if kind == "egnn":
            e, _ = G.apply_egnn(params, cfg, b["node_feat"], b["pos"],
                                b["senders"], b["receivers"],
                                b.get("graph_ids"), remat=remat)
            return jnp.mean((e - b["targets"]) ** 2)
        if kind == "nequip":
            e = NQ.apply_nequip(params, cfg, b["species"], b["pos"],
                                b["senders"], b["receivers"],
                                b.get("graph_ids"), remat=remat)
            return jnp.mean((e - b["targets"]) ** 2)
        if kind == "dimenet":
            e = DM.apply_dimenet(params, cfg, b["species"], b["pos"],
                                 b["senders"], b["receivers"], b["t_kj"],
                                 b["t_ji"], b.get("graph_ids"), remat=remat)
            return jnp.mean((e - b["targets"]) ** 2)
        raise ValueError(kind)

    return loss


def _gnn_full_batch_shapes(arch: ArchSpec, shape: GNNShape, mesh):
    """Full-graph batch ShapeDtypeStructs: nodes replicated, edge (and
    triplet) arrays sharded across ALL mesh axes."""
    kind = arch.gnn_kind
    ndev = math.prod(mesh.devices.shape)
    all_ax = _all_axes(mesh)
    N1 = shape.n_nodes + 1
    E = _round_up(shape.n_edges, ndev)
    rep = P()
    e_spec = P(all_ax)
    b = {
        "senders": _sds((E,), jnp.int32, mesh, e_spec),
        "receivers": _sds((E,), jnp.int32, mesh, e_spec),
    }
    if kind == "gin":
        # feature width is the model's d_in; shapes with smaller d_feat are
        # zero-padded by the data pipeline (configs/gin_tu.py note)
        b["node_feat"] = _sds((N1, arch.model_cfg.d_in), jnp.bfloat16, mesh,
                              rep)
        b["labels"] = _sds((N1,), jnp.int32, mesh, rep)
        b["train_mask"] = _sds((N1,), jnp.bool_, mesh, rep)
    else:
        b["pos"] = _sds((N1, 3), jnp.bfloat16, mesh, rep)
        b["targets"] = _sds((), jnp.bfloat16, mesh, rep)
        if kind == "egnn":
            b["node_feat"] = _sds((N1, arch.model_cfg.d_in), jnp.bfloat16,
                                  mesh, rep)
        else:
            b["species"] = _sds((N1,), jnp.int32, mesh, rep)
        if kind == "dimenet":
            Tn = _round_up(2 * shape.n_edges, ndev)
            b["t_kj"] = _sds((Tn,), jnp.int32, mesh, e_spec)
            b["t_ji"] = _sds((Tn,), jnp.int32, mesh, e_spec)
    return b


def _gnn_graph_level_shapes(arch: ArchSpec, n_graphs: int, max_nodes: int,
                            max_edges: int, mesh, spec_axes, d_feat: int,
                            with_labels: bool):
    """Per-graph stacked arrays (G, ...) sharded on the leading axis."""
    kind = arch.gnn_kind
    N1 = max_nodes + 1

    def lead(dtype, *rest):
        return _sds((n_graphs, *rest), dtype, mesh,
                    P(spec_axes, *([None] * len(rest))))

    b = {"senders": lead(jnp.int32, max_edges),
         "receivers": lead(jnp.int32, max_edges)}
    if kind == "gin":
        b["node_feat"] = lead(jnp.bfloat16, N1, arch.model_cfg.d_in)
        if with_labels:
            b["labels"] = lead(jnp.int32, N1)
            b["train_mask"] = lead(jnp.bool_, N1)
        else:
            b["targets"] = lead(jnp.bfloat16)
            b["graph_ids"] = lead(jnp.int32, N1)
    else:
        b["pos"] = lead(jnp.bfloat16, N1, 3)
        b["targets"] = lead(jnp.bfloat16)
        if kind == "egnn":
            b["node_feat"] = lead(jnp.bfloat16, N1, arch.model_cfg.d_in)
        else:
            b["species"] = lead(jnp.int32, N1)
        if kind == "dimenet":
            b["t_kj"] = lead(jnp.int32, 4 * max_edges)
            b["t_ji"] = lead(jnp.int32, 4 * max_edges)
    return b


def gnn_cell(arch: ArchSpec, shape: GNNShape, mesh) -> Cell:
    mdt = _dtype(arch.moment_dtype)
    init_fn, axes = _gnn_init_and_axes(arch)
    p_shapes = jax.eval_shape(init_fn, KEY_SDS)
    p_shard = tree_shardings(axes, p_shapes, arch.param_rules, mesh)

    if shape.kind == "full":
        loss1 = _gnn_single_loss(arch, remat=True)
        batch = _gnn_full_batch_shapes(arch, shape, mesh)
        step = _raw_train_step(loss1, mdt)
        desc = f"full-graph train N={shape.n_nodes} E={shape.n_edges}"
    else:
        # graph-level batches: vmapped over the leading (graph) axis
        if shape.kind == "minibatch":
            ndev = math.prod(mesh.devices.shape)
            n_graphs = ndev                       # one subgraph per device
            seeds = max(1, shape.batch_nodes // ndev)
            hop_sizes = np.cumprod(shape.fanout)     # nodes per hop per seed
            mn = _round_up(seeds * (1 + int(hop_sizes.sum())) + 8, 128)
            me = _round_up(seeds * int(hop_sizes.sum()) + 8, 128)
            spec_axes = _all_axes(mesh)
            d_feat = shape.d_feat or 100
            with_labels = True
            desc = (f"minibatch G={n_graphs} seeds/shard={seeds} "
                    f"max_nodes={mn} max_edges={me}")
        else:  # molecule
            n_graphs = shape.batch
            mn, me = shape.max_nodes, shape.max_edges
            spec_axes = dp_axes(mesh)
            d_feat = 16
            with_labels = False
            desc = f"molecule B={n_graphs} n={mn} e={me}"
        batch = _gnn_graph_level_shapes(arch, n_graphs, mn, me, mesh,
                                        spec_axes, d_feat, with_labels)
        loss1 = _gnn_single_loss(arch, remat=False)

        def loss_vmap(params, b):
            losses = jax.vmap(lambda bb: loss1(params, bb))(b)
            return losses.mean()

        step = _raw_train_step(loss_vmap, mdt)

    state_shapes = train_state_shapes(p_shapes, mdt)
    state_shard = train_state_shardings(p_shard, mesh)
    state_in = _attach(state_shapes, state_shard)
    metrics_rep = {k: NamedSharding(mesh, P())
                   for k in ("loss", "grad_norm", "lr")}
    return Cell(fn=step, args=(state_in, batch),
                out_shardings=(state_shard, metrics_rep), donate=(0,),
                static_desc=desc)


# ---------------------------------------------------------------------------
# recsys family
# ---------------------------------------------------------------------------
def rec_cell(arch: ArchSpec, shape: RecShape, mesh) -> Cell:
    cfg: FM.FMConfig = arch.model_cfg
    mdt = _dtype(arch.moment_dtype)
    dp = dp_axes(mesh)
    p_shapes = jax.eval_shape(lambda k: FM.init_fm(k, cfg), KEY_SDS)
    p_shard = tree_shardings(FM.fm_axes(cfg), p_shapes, arch.param_rules,
                             mesh)
    params_in = _attach(p_shapes, p_shard)

    if shape.kind == "train":
        loss_fn = lambda p, b: FM.fm_loss(p, cfg, b["ids"], b["labels"])
        step = _raw_train_step(loss_fn, mdt,
                               grad_dtype=_dtype(arch.grad_dtype)
                               if arch.grad_dtype else None)
        state_in = _attach(train_state_shapes(p_shapes, mdt),
                           train_state_shardings(p_shard, mesh))
        batch = {
            "ids": _sds((shape.batch, cfg.n_fields), jnp.int32, mesh,
                        P(dp, None)),
            "labels": _sds((shape.batch,), jnp.float32, mesh, P(dp)),
        }
        metrics_rep = {k: NamedSharding(mesh, P())
                       for k in ("loss", "grad_norm", "lr")}
        return Cell(fn=step, args=(state_in, batch),
                    out_shardings=(train_state_shardings(p_shard, mesh),
                                   metrics_rep), donate=(0,),
                    static_desc=f"fm train B={shape.batch}")

    if shape.kind == "serve":
        fn = lambda p, ids: FM.apply_fm(p, cfg, ids)
        dpn = dp_size(mesh)
        spec = P(dp, None) if shape.batch % dpn == 0 else P(None, None)
        ids = _sds((shape.batch, cfg.n_fields), jnp.int32, mesh, spec)
        return Cell(fn=fn, args=(params_in, ids),
                    static_desc=f"fm serve B={shape.batch}")

    # retrieval: 1 query vs n_candidates
    ndev = math.prod(mesh.devices.shape)
    NC = _round_up(shape.n_candidates, ndev)
    fq, fc = 20, 19
    fn = lambda p, q, c: FM.fm_retrieval_scores(p, cfg, q, c)
    q_in = _sds((fq,), jnp.int32, mesh, P(None))
    c_in = _sds((NC, fc), jnp.int32, mesh, P(_all_axes(mesh), None))
    return Cell(fn=fn, args=(params_in, q_in, c_in),
                static_desc=f"fm retrieval NC={NC}")


def build_cell(arch: ArchSpec, shape_name: str, mesh) -> Cell:
    if shape_name in arch.skip_shapes:
        raise ValueError(
            f"{arch.id} skips {shape_name}: {arch.skip_shapes[shape_name]}")
    shape = arch.shapes[shape_name]
    if arch.family == "lm":
        return lm_cell(arch, shape, mesh)
    if arch.family == "gnn":
        return gnn_cell(arch, shape, mesh)
    if arch.family == "recsys":
        return rec_cell(arch, shape, mesh)
    raise ValueError(arch.family)
