"""OLMoE-1B-7B [arXiv:2409.02060]: 16L d_model=2048 16H (GQA kv=16)
d_ff(expert)=1024, vocab=50304, MoE 64 experts top-8, qk-norm."""
from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.layers import LMConfig, MoEConfig

ARCH = ArchSpec(
    id="olmoe-1b-7b",
    family="lm",
    model_cfg=LMConfig(
        name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16,
        n_kv_heads=16, d_head=128, d_ff=1024, vocab=50304, qk_norm=True,
        tie_embeddings=False,
        moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024)),
    smoke_cfg=LMConfig(
        name="olmoe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_head=16, d_ff=32, vocab=256, qk_norm=True, tie_embeddings=False,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32)),
    shapes=dict(LM_SHAPES),
    skip_shapes={"long_500k": "pure full-attention GQA (no sub-quadratic "
                              "mechanism); skipped per assignment"},
    param_rules={"embed": None, "heads": "model", "kv_heads": "model",
                 "head_dim": None, "ffn": None, "vocab": "model",
                 "experts": "model", "layers": None},
    accum_steps=4,   # bounds MoE dispatch buffers (~0.7 GB/device)
    param_dtype="bfloat16",    # + bf16 Adam moments: fits 16 GB/chip
    moment_dtype="bfloat16",
)
