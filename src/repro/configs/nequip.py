"""NequIP [arXiv:2101.03164]: n_layers=5 d_hidden(channels)=32 l_max=2
n_rbf=8 cutoff=5, O(3)-equivariant tensor products (Gaunt coupling)."""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.nequip import NequIPConfig

ARCH = ArchSpec(
    id="nequip",
    family="gnn",
    gnn_kind="nequip",
    model_cfg=NequIPConfig(name="nequip", n_layers=5, channels=32, n_rbf=8,
                           cutoff=5.0, n_species=8),
    smoke_cfg=NequIPConfig(name="nequip-smoke", n_layers=2, channels=8,
                           n_rbf=4, cutoff=5.0, n_species=4),
    shapes=dict(GNN_SHAPES),
    param_rules={"ffn": None},
)
