"""GIN [arXiv:1810.00826]: n_layers=5 d_hidden=64 sum aggregator,
learnable eps.  Node tasks use a fixed 64-class head (synthetic labels);
molecule shape is graph-level regression through the same head."""
import dataclasses
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.gnn import GNNConfig

ARCH = ArchSpec(
    id="gin-tu",
    family="gnn",
    gnn_kind="gin",
    model_cfg=GNNConfig(name="gin-tu", n_layers=5, d_hidden=64, d_in=1433,
                        n_classes=64, task="node", learn_eps=True),
    smoke_cfg=GNNConfig(name="gin-smoke", n_layers=2, d_hidden=16, d_in=8,
                        n_classes=4, task="node"),
    shapes=dict(GNN_SHAPES),
    param_rules={"ffn": None},
    notes="d_in fixed to the largest assigned d_feat (1433); smaller "
          "feature shapes are zero-padded by the data pipeline",
)
