"""GPipe-style pipeline parallelism over a mesh axis (optional PP).

The multi-pod mesh's "pod" axis can act as the pipeline axis: stage s holds
its own layer-group parameters; microbatch activations flow stage-to-stage
via ``lax.ppermute`` inside a fused tick loop.  Bubble fraction is the
standard (S-1)/(M+S-1).  Equivalence with the unpipelined module is tested
in tests/test_distributed.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def make_gpipe(mesh: Mesh, stage_fn, n_micro: int, axis: str = "pod"):
    """stage_fn(stage_params, x) -> y with y.shape == x.shape.

    Returns f(stacked_params, x) where stacked_params has a leading stage
    axis (sharded over ``axis``) and x is the full batch (microbatched
    internally).  Output equals applying the stages sequentially.
    """
    from jax.experimental.shard_map import shard_map
    S = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    def run(params_local, x):             # under shard_map
        s = jax.lax.axis_index(axis)
        params = jax.tree_util.tree_map(lambda a: a[0], params_local)
        M = n_micro
        mb = x.shape[0] // M
        micro = x.reshape(M, mb, *x.shape[1:])
        T = M + S - 1
        outputs = jnp.zeros_like(micro)
        cur = jnp.zeros_like(micro[0])
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(t, carry):
            outputs, cur = carry
            feed_idx = jnp.clip(t, 0, M - 1)
            inp = jnp.where(s == 0,
                            jax.lax.dynamic_index_in_dim(
                                micro, feed_idx, keepdims=False),
                            cur)
            y = stage_fn(params, inp)
            # last stage banks microbatch (t - (S-1)) when it's real
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            bank = (s == S - 1) & (t >= S - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(bank, y, jax.lax.dynamic_index_in_dim(
                    outputs, out_idx, keepdims=False)),
                out_idx, axis=0)
            cur = jax.lax.ppermute(y, axis, perm)
            return outputs, cur

        outputs, _ = jax.lax.fori_loop(0, T, tick, (outputs, cur))
        # only stage S-1 holds real outputs; replicate via psum of masked
        outputs = jax.lax.psum(
            jnp.where(s == S - 1, outputs, jnp.zeros_like(outputs)), axis)
        return outputs.reshape(x.shape)

    in_specs = (P(axis), P())      # params stage-sharded; x replicated
    fn = shard_map(run, mesh=mesh, in_specs=in_specs, out_specs=P(),
                   check_rep=False)
    return jax.jit(fn)


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
