"""Distributed BLEST BFS (DESIGN §2.4).

1-D row partition: each device owns a contiguous block of BVSS rows
(destination vertices) — i.e. the slices that pull INTO its vertex range —
and the full frontier bitmap is all-gathered once per level (n/8 bytes; at
n = 134M that is 17 MB/level, trivially ICI-safe).  Pulls, marks and level
updates are purely local; the convergence test is a psum of local
new-vertex counts inside the fused `while_loop` (no host sync, paper §4.3
preserved across devices).

Partitioning happens host-side on the BVSS: device d owns slice sets
[d·n_sets/D, (d+1)·n_sets/D) — but note slices are grouped by COLUMN
interval, so the row partition is realised by re-bucketing slices by row
block: we rebuild a per-device BVSS whose "columns" stay global while the
row ids (and the level/mark arrays) are local.  For the dry-run mesh the
partition axis is the full device set.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.bvss import BVSS, build_bvss
from repro.graphs import Graph, from_edges, src_of_edges

INF = np.int32(np.iinfo(np.int32).max)


@dataclasses.dataclass(frozen=True)
class ShardedBVSS:
    """Stacked per-device BVSS arrays (leading axis = device)."""
    n: int
    sigma: int
    n_devices: int
    rows_per_dev: int
    num_vss_pad: int            # per-device VSS count (padded to common max)
    masks: np.ndarray           # (D, num_vss_pad, 32) uint32
    row_ids: np.ndarray         # (D, num_vss_pad, spw, 32) int32 LOCAL rows
    fbyte_word: np.ndarray      # (D, num_vss_pad) int32: frontier word idx
    fbyte_shift: np.ndarray     # (D, num_vss_pad) uint32: shift in word
    n_fwords: int


def shard_bvss(g: Graph, n_devices: int, sigma: int = 8) -> ShardedBVSS:
    """Row-partition the graph: device d owns rows [d*rpd, (d+1)*rpd)."""
    n = g.n
    rows_per_dev = -(-n // n_devices)
    rows_per_dev = ((rows_per_dev + 31) // 32) * 32   # align frontier words
    n_pad = rows_per_dev * n_devices
    spw = 32 // sigma
    per_dev = []
    src = src_of_edges(g)
    dst = g.indices.astype(np.int64)
    for d in range(n_devices):
        lo, hi = d * rows_per_dev, min((d + 1) * rows_per_dev, n)
        # edges whose DESTINATION lives on this device; relabel dst locally,
        # keep src (columns / frontier ids) global
        keep = (dst >= lo) & (dst < hi)
        sub_src = src[keep]
        sub_dst = dst[keep] - lo
        # build a BVSS over a (rows_per_dev x n) rectangular slice: reuse
        # build_bvss on a graph with n columns but local rows via an
        # n-vertex graph whose rows >= rows_per_dev are empty.
        # drop_loops=False: local dst ids numerically colliding with global
        # src ids are NOT self loops.
        sub = from_edges(n, sub_src, sub_dst, dedup=True, drop_loops=False)
        per_dev.append(build_bvss(sub, sigma=sigma))
    num_vss_pad = max(max(b.num_vss for b in per_dev), 1)
    D = n_devices
    masks = np.zeros((D, num_vss_pad, 32), np.uint32)
    row_ids = np.full((D, num_vss_pad, spw, 32), rows_per_dev, np.int32)
    fword = np.zeros((D, num_vss_pad), np.int32)
    fshift = np.zeros((D, num_vss_pad), np.uint32)
    for d, b in enumerate(per_dev):
        if b.num_vss == 0:
            continue
        masks[d, :b.num_vss] = b.masks
        rid = b.row_ids.copy()
        rid[rid == b.n] = rows_per_dev            # dummy -> local dummy
        row_ids[d, :b.num_vss] = np.minimum(rid, rows_per_dev)
        sets = b.virtual_to_real.astype(np.int64)
        bitpos = sets * sigma
        fword[d, :b.num_vss] = (bitpos // 32).astype(np.int32)
        fshift[d, :b.num_vss] = (bitpos % 32).astype(np.uint32)
    n_fwords = (n_pad + 31) // 32
    return ShardedBVSS(n=n, sigma=sigma, n_devices=D,
                       rows_per_dev=rows_per_dev, num_vss_pad=num_vss_pad,
                       masks=masks, row_ids=row_ids, fbyte_word=fword,
                       fbyte_shift=fshift, n_fwords=n_fwords)


def make_distributed_bfs(sb: ShardedBVSS, mesh: Mesh, axis: str = "data"):
    """Jitted distributed BFS: f(src) -> levels (n,). Runs the whole level
    loop inside one shard_map'd while_loop."""
    from jax.experimental.shard_map import shard_map

    sigma, spw = sb.sigma, 32 // sb.sigma
    smask = jnp.uint32((1 << sigma) - 1)
    rpd = sb.rows_per_dev
    assert rpd % 32 == 0, "row blocks must be frontier-word aligned"
    n_fwords = sb.n_fwords
    lwords = rpd // 32
    max_lv = sb.n + 1

    def local_loop(masks, row_ids, fword, fshift, src):
        """One device's slice of the fused BFS (runs under shard_map)."""
        d = jax.lax.axis_index(axis)
        masks, row_ids = masks[0], row_ids[0]
        fword, fshift = fword[0], fshift[0]
        levels = jnp.full((rpd + 1,), INF, dtype=jnp.int32)
        local_src = src - d * rpd
        own = (local_src >= 0) & (local_src < rpd)
        levels = levels.at[jnp.where(own, local_src, rpd)].set(
            jnp.where(own, 0, INF))
        # local frontier words (this device's row block), then all-gather
        lw = jnp.zeros((lwords,), jnp.uint32)
        lw = lw.at[jnp.where(own, local_src // 32, 0)].set(
            jnp.where(own, jnp.uint32(1) << (local_src % 32).astype(jnp.uint32),
                      jnp.uint32(0)))

        def body(state):
            levels, lw, _, lvl = state
            lvl = lvl + 1
            F = jax.lax.all_gather(lw, axis, tiled=True)      # (n_fwords,)
            F = F[:n_fwords]
            fb = (F[fword] >> fshift) & smask                 # (V,)
            rep = jnp.zeros_like(fb)
            for j in range(spw):
                rep = rep | (fb << jnp.uint32(sigma * j))
            anded = masks & rep[:, None]                      # (V, 32)
            upd = []
            for j in range(spw):
                sub = (anded >> jnp.uint32(sigma * j)) & smask
                upd.append(sub != 0)
            hits = jnp.stack(upd, axis=1).reshape(-1)         # (V*spw*32,)
            rows = row_ids.reshape(-1)
            new_lv = jnp.where(hits, lvl, INF).astype(jnp.int32)
            levels = levels.at[rows].min(new_lv)
            new = levels[:rpd] == lvl
            pad = jnp.zeros((lwords * 32,), bool).at[:rpd].set(new)
            bits = pad.reshape(lwords, 32).astype(jnp.uint32)
            w = (bits * (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
                 ).sum(axis=1, dtype=jnp.uint32)
            cnt = jax.lax.psum(new.sum(), axis)
            return levels, w, cnt > 0, lvl

        def cond(state):
            return state[2] & (state[3] < max_lv)

        state = (levels, lw, jnp.bool_(True), jnp.int32(0))
        levels, *_ = jax.lax.while_loop(cond, body, state)
        return levels[None, :rpd]

    fn = shard_map(
        local_loop, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P()),
        out_specs=P(axis),
        check_rep=False)

    def bfs(src):
        out = fn(jnp.asarray(sb.masks), jnp.asarray(sb.row_ids),
                 jnp.asarray(sb.fbyte_word), jnp.asarray(sb.fbyte_shift),
                 jnp.asarray(src, jnp.int32))
        return out.reshape(-1)[:sb.n]

    return jax.jit(bfs)


# ---------------------------------------------------------------------------
# 2-D (pod x data) partition: pods own ROW blocks, the data axis owns
# COLUMN blocks (DESIGN §2.4).  Each device holds the BVSS of its
# (row-block x column-block) rectangle; per level the frontier segment is
# all-gathered along the row axis only (1/pods of the 1-D payload per
# device) and the partial next-frontier marks are OR-reduced (psum of
# bytes) along the column axis.  Profitable past ~1k chips where the 1-D
# frontier broadcast saturates ICI.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardedBVSS2D:
    n: int
    sigma: int
    rows_axis: int              # devices along rows (pods)
    cols_axis: int              # devices along columns (data)
    rows_per_dev: int
    cols_per_dev: int
    num_vss_pad: int
    masks: np.ndarray           # (R, C, V, 32) uint32
    row_ids: np.ndarray         # (R, C, V, spw, 32) int32, LOCAL rows
    fbyte_word: np.ndarray      # (R, C, V) int32 LOCAL column-word index
    fbyte_shift: np.ndarray     # (R, C, V) uint32


def shard_bvss_2d(g: Graph, rows_axis: int, cols_axis: int,
                  sigma: int = 8) -> ShardedBVSS2D:
    n = g.n
    rpd = ((-(-n // rows_axis) + 31) // 32) * 32
    cpd = ((-(-n // cols_axis) + 31) // 32) * 32
    spw = 32 // sigma
    src = src_of_edges(g)
    dst = g.indices.astype(np.int64)
    blocks = []
    for r in range(rows_axis):
        row = []
        for c in range(cols_axis):
            keep = ((dst >= r * rpd) & (dst < (r + 1) * rpd)
                    & (src >= c * cpd) & (src < (c + 1) * cpd))
            # vertex-id space must cover BOTH local row ids (< rpd) and
            # local column ids (< cpd); columns beyond cpd stay empty
            sub = from_edges(max(rpd, cpd), src[keep] - c * cpd,
                             dst[keep] - r * rpd,
                             dedup=True, drop_loops=False)
            row.append(build_bvss(sub, sigma=sigma))
        blocks.append(row)
    V = max(max(b.num_vss for row in blocks for b in row), 1)
    R, C = rows_axis, cols_axis
    masks = np.zeros((R, C, V, 32), np.uint32)
    row_ids = np.full((R, C, V, spw, 32), rpd, np.int32)
    fword = np.zeros((R, C, V), np.int32)
    fshift = np.zeros((R, C, V), np.uint32)
    for r in range(R):
        for c in range(C):
            b = blocks[r][c]
            if b.num_vss == 0:
                continue
            masks[r, c, :b.num_vss] = b.masks
            rid = b.row_ids.copy()
            rid[rid == b.n] = rpd
            row_ids[r, c, :b.num_vss] = np.minimum(rid, rpd)
            bit = b.virtual_to_real.astype(np.int64) * sigma
            fword[r, c, :b.num_vss] = (bit // 32).astype(np.int32)
            fshift[r, c, :b.num_vss] = (bit % 32).astype(np.uint32)
    return ShardedBVSS2D(n=n, sigma=sigma, rows_axis=R, cols_axis=C,
                         rows_per_dev=rpd, cols_per_dev=cpd, num_vss_pad=V,
                         masks=masks, row_ids=row_ids, fbyte_word=fword,
                         fbyte_shift=fshift)


def make_distributed_bfs_2d(sb: ShardedBVSS2D, mesh: Mesh,
                            row_axis: str = "pod", col_axis: str = "data"):
    """Jitted 2-D distributed BFS: f(src) -> levels (n,)."""
    from jax.experimental.shard_map import shard_map

    sigma, spw = sb.sigma, 32 // sb.sigma
    smask = jnp.uint32((1 << sigma) - 1)
    rpd, cpd = sb.rows_per_dev, sb.cols_per_dev
    lwords = rpd // 32
    cwords = cpd // 32
    max_lv = sb.n + 1

    def local_loop(masks, row_ids, fword, fshift, src):
        r = jax.lax.axis_index(row_axis)
        c = jax.lax.axis_index(col_axis)
        masks = masks[0, 0]
        row_ids = row_ids[0, 0]
        fword, fshift = fword[0, 0], fshift[0, 0]
        levels = jnp.full((rpd + 1,), INF, dtype=jnp.int32)
        lsrc = src - r * rpd
        own = (lsrc >= 0) & (lsrc < rpd)
        levels = levels.at[jnp.where(own, lsrc, rpd)].set(
            jnp.where(own, 0, INF))
        lw = jnp.zeros((lwords,), jnp.uint32)
        lw = lw.at[jnp.where(own, lsrc // 32, 0)].set(
            jnp.where(own, jnp.uint32(1) << (lsrc % 32).astype(jnp.uint32),
                      jnp.uint32(0)))

        def body(state):
            levels, lw, _, lvl = state
            lvl = lvl + 1
            # 1. gather the GLOBAL frontier along the row axis, then slice
            # this device's COLUMN window (global bits c*cpd ..)
            F = jax.lax.all_gather(lw, row_axis, tiled=True)  # row-block bits
            # row blocks are rpd-aligned; global frontier = concat over rows.
            # column window starts at c*cpd bits = c*cwords words.
            Fpad = jnp.concatenate(
                [F, jnp.zeros((cwords,), jnp.uint32)])
            Fc = jax.lax.dynamic_slice(Fpad, (c * cwords,), (cwords,))
            fb = (Fc[fword] >> fshift) & smask
            rep = jnp.zeros_like(fb)
            for j in range(spw):
                rep = rep | (fb << jnp.uint32(sigma * j))
            anded = masks & rep[:, None]
            hits = []
            for j in range(spw):
                hits.append(((anded >> jnp.uint32(sigma * j)) & smask) != 0)
            hits = jnp.stack(hits, axis=1).reshape(-1)
            rows = row_ids.reshape(-1)
            # 2. partial marks from THIS column block; OR across columns
            marks = jnp.zeros((rpd + 1,), jnp.uint8).at[rows].max(
                hits.astype(jnp.uint8))
            marks = jax.lax.pmax(marks, col_axis)          # reduce-OR
            new = (marks[:rpd] > 0) & (levels[:rpd] == INF)
            levels = levels.at[:rpd].set(
                jnp.where(new, lvl, levels[:rpd]))
            pad = jnp.zeros((lwords * 32,), bool).at[:rpd].set(new)
            bits = pad.reshape(lwords, 32).astype(jnp.uint32)
            w = (bits * (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
                 ).sum(axis=1, dtype=jnp.uint32)
            cnt = jax.lax.psum(new.sum(), row_axis)
            return levels, w, cnt > 0, lvl

        def cond(state):
            return state[2] & (state[3] < max_lv)

        state = (levels, lw, jnp.bool_(True), jnp.int32(0))
        levels, *_ = jax.lax.while_loop(cond, body, state)
        return levels[None, None, :rpd]

    fn = shard_map(
        local_loop, mesh=mesh,
        in_specs=(P(row_axis, col_axis), P(row_axis, col_axis),
                  P(row_axis, col_axis), P(row_axis, col_axis), P()),
        out_specs=P(row_axis, col_axis),
        check_rep=False)

    def bfs(src):
        out = fn(jnp.asarray(sb.masks), jnp.asarray(sb.row_ids),
                 jnp.asarray(sb.fbyte_word), jnp.asarray(sb.fbyte_shift),
                 jnp.asarray(src, jnp.int32))
        # out (R, C*?, rpd) — columns replicated post-pmax; take column 0
        return out[:, 0].reshape(-1)[:sb.n]

    return jax.jit(bfs)
