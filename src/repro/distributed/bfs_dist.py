"""Sharding specs and mesh helpers for mesh-native BLEST BFS (DESIGN §2.4).

This module is deliberately thin.  The distributed BFS used to live here as
a parallel implementation — its own ``ShardedBVSS`` build and two bespoke
``lax.while_loop`` level loops that bypassed ``policy.prepare``, the fused
``bvss_pull``/``finalize_pack_sweep`` kernels and the bucketed queue.  All
of that now rides the ONE mesh-parameterised stack:

* build: :func:`repro.core.bvss.build_sharded_bvss` (row partition, padded
  to a common per-shard VSS count);
* prep:  :func:`repro.core.policy.prepare` with ``mesh=...`` — the single
  sharded-prep entry point;
* loop:  the same :class:`~repro.core.level_pipeline.LevelPipeline`
  step/finalize under ``shard_map`` (``core/bfs.py``,
  ``core/multi_source.py``), frontier-word all-gather + psum convergence
  inside the fused ``while_loop``;
* serve: ``repro.serve.GraphSession(g, mesh=...)``.

What remains here is the sharding vocabulary those layers share: the 1-D
row-partition mesh and the PartitionSpecs of the shard-stacked problem
arrays and wave state.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: the mesh axis the BVSS row partition maps onto
BFS_AXIS = "data"


def bfs_mesh(n_devices: int | None = None, axis: str = BFS_AXIS) -> Mesh:
    """A 1-D mesh over the first ``n_devices`` devices (default: all).

    The BFS row partition is 1-D: device d owns BVSS rows
    [d·rows_per_shard, (d+1)·rows_per_shard) — the slices that pull INTO
    its vertex range — and the σ-bit frontier words are the one
    all-gathered array (ButterFly-BFS-style: the frontier exchange is the
    single cross-device term worth engineering)."""
    devices = jax.devices()
    if n_devices is not None:
        if n_devices > len(devices):
            raise ValueError(
                f"requested {n_devices} devices, only {len(devices)} "
                f"available (on CPU, relaunch with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n_devices})")
        devices = devices[:n_devices]
    return Mesh(devices, (axis,))


def frontier_all_gather(fw_local, axis: str = BFS_AXIS):
    """The ONE cross-device collective of the level loop: all-gather this
    shard's freshly packed σ-bit frontier words into the global frontier
    replica (tiled, so shard k contributes words [k·lwords, (k+1)·lwords)).

    Every mesh-native engine (``core/bfs.py``, ``core/multi_source.py``)
    routes its frontier exchange through this function, which makes it the
    documented fault seam for collective failures: the chaos gauntlet
    (``serve/faults.py``) substitutes a wrapper that zeroes a shard's
    segment — a stalled/dropped peer — and the verify-mode sampling policy
    must catch the divergence (DESIGN §2.7)."""
    return jax.lax.all_gather(fw_local, axis, tiled=True)


def problem_specs(axis: str = BFS_AXIS) -> tuple[P, P, P, P, P]:
    """PartitionSpecs of the shard-stacked problem arrays ``(masks,
    row_ids, virtual_to_real, vss_of_vertex_start, vss_of_vertex_end)``
    (leading axis = shard; the last two are the push phase's GLOBAL
    vertex -> LOCAL VSS maps, DESIGN §2.8)."""
    return (P(axis), P(axis), P(axis), P(axis), P(axis))


def problem_sharding(mesh: Mesh, axis: str = BFS_AXIS) -> NamedSharding:
    """The NamedSharding every shard-stacked array is committed with."""
    return NamedSharding(mesh, P(axis))


def state_specs(axis: str = BFS_AXIS, *, track_sigma: bool = False):
    """PartitionSpecs of the host-visible sharded wave state
    (:class:`repro.core.multi_source.MSState`): every field carries a
    leading shard axis — local ``(rps+1, S)`` level blocks, one global
    frontier replica per shard, one queue per shard.  ``track_sigma``
    adds the spec of the σ path-count channel, which shards like the
    level blocks (local ``(rps, S)`` rows), NOT like the replicated
    frontier words."""
    from repro.core.multi_source import MSState
    return MSState(P(axis), P(axis), P(axis), P(axis), P(axis), P(axis),
                   P(axis) if track_sigma else None)
