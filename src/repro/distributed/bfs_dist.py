"""Sharding specs and mesh helpers for mesh-native BLEST BFS (DESIGN §2.4).

This module is deliberately thin.  The distributed BFS used to live here as
a parallel implementation — its own ``ShardedBVSS`` build and two bespoke
``lax.while_loop`` level loops that bypassed ``policy.prepare``, the fused
``bvss_pull``/``finalize_pack_sweep`` kernels and the bucketed queue.  All
of that now rides the ONE mesh-parameterised stack:

* build: :func:`repro.core.bvss.build_sharded_bvss` (row partition — or the
  2-D row × column partition when handed a ``(rows, cols)`` shape — padded
  to a common per-shard VSS count);
* prep:  :func:`repro.core.policy.prepare` with ``mesh=...`` — the single
  sharded-prep entry point (1-D and 2-D meshes dispatch on
  ``len(mesh.axis_names)``);
* loop:  the same :class:`~repro.core.level_pipeline.LevelPipeline`
  step/finalize under ``shard_map`` (``core/bfs.py``,
  ``core/multi_source.py``) — 1-D: frontier-word all-gather + psum
  convergence; 2-D: butterfly OR-allreduce over the column axis + butterfly
  segment exchange over the row axis (``distributed/collectives.py``);
* serve: ``repro.serve.GraphSession(g, mesh=...)``.

What remains here is the sharding vocabulary those layers share: the 1-D
row-partition mesh, the 2-D row × column mesh, and the PartitionSpecs of
the shard-stacked problem arrays and wave state.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.errors import ConfigError

#: the mesh axis the BVSS row partition maps onto
BFS_AXIS = "data"
#: the second mesh axis of the 2-D partition: frontier-word column blocks
COL_AXIS = "col"


def _take_devices(n_devices: int) -> list:
    devices = jax.devices()
    if n_devices > len(devices):
        raise ConfigError(
            f"requested {n_devices} devices, only {len(devices)} "
            f"available (on CPU, relaunch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_devices})")
    return devices[:n_devices]


def bfs_mesh(n_devices: int | None = None, axis: str = BFS_AXIS) -> Mesh:
    """A 1-D mesh over the first ``n_devices`` devices (default: all).

    The BFS row partition is 1-D: device d owns BVSS rows
    [d·rows_per_shard, (d+1)·rows_per_shard) — the slices that pull INTO
    its vertex range — and the σ-bit frontier words are the one
    all-gathered array (ButterFly-BFS-style: the frontier exchange is the
    single cross-device term worth engineering).

    Over-requesting devices raises :class:`repro.errors.ConfigError`
    (a ``ValueError`` subclass — the PR-6 typed-ingress contract).
    """
    devices = jax.devices()
    if n_devices is not None:
        devices = _take_devices(n_devices)
    return Mesh(devices, (axis,))


def bfs_mesh2d(rows: int, cols: int, *, row_axis: str = BFS_AXIS,
               col_axis: str = COL_AXIS) -> Mesh:
    """A ``rows × cols`` 2-D mesh over the first ``rows * cols`` devices.

    Device (i, j) owns the BVSS slices pulling its ROW block of vertices
    from its COLUMN block of frontier words, so per level it touches only
    ``1/cols`` of the frontier (DESIGN §2.4).  The 2-D engines require
    ``rows >= cols`` (the column blocks interleave inside row blocks, so
    the local column space ``rows · rps/cols`` must cover a row block);
    violations raise :class:`repro.errors.ConfigError` here, at mesh
    construction — the earliest ingress.
    """
    if rows < 1 or cols < 1:
        raise ConfigError(f"mesh shape ({rows}, {cols}) must be positive")
    if rows < cols:
        raise ConfigError(
            f"2-D BFS mesh needs rows >= cols, got ({rows}, {cols}) — "
            f"the column partition interleaves inside row blocks, so "
            f"fewer rows than columns leaves column shards without a "
            f"full row block to pull from")
    devices = _take_devices(rows * cols)
    return Mesh(np.asarray(devices).reshape(rows, cols),
                (row_axis, col_axis))


def mesh_is_2d(mesh: Mesh) -> bool:
    """True for the 2-D row × column partition (two named axes)."""
    return len(mesh.axis_names) == 2


def frontier_all_gather(fw_local, axis: str = BFS_AXIS):
    """The flat frontier exchange of the 1-D level loop: all-gather this
    shard's freshly packed σ-bit frontier words into the global frontier
    replica (tiled, so shard k contributes words [k·lwords, (k+1)·lwords)).

    Every 1-D mesh-native engine (``core/bfs.py``, ``core/multi_source.py``)
    routes its frontier exchange through this function, which makes it the
    documented fault seam for collective failures: the chaos gauntlet
    (``serve/faults.py``) substitutes a wrapper that zeroes a shard's
    segment — a stalled/dropped peer — and the verify-mode sampling policy
    must catch the divergence (DESIGN §2.7).  The 2-D engines route
    through :func:`repro.distributed.collectives.butterfly_frontier_exchange`
    instead (same seam signature).  Per-device bytes are recorded in the
    trace-time :func:`~repro.distributed.collectives.comm_ledger`."""
    from repro.distributed.collectives import axis_size, record_comm
    n = axis_size(axis)
    record_comm("flat_all_gather",
                (n - 1) * int(np.prod(fw_local.shape))
                * fw_local.dtype.itemsize)
    return jax.lax.all_gather(fw_local, axis, tiled=True)


def problem_specs(axis: str = BFS_AXIS) -> tuple[P, P, P, P, P]:
    """PartitionSpecs of the shard-stacked problem arrays ``(masks,
    row_ids, virtual_to_real, vss_of_vertex_start, vss_of_vertex_end)``
    (leading axis = shard; the last two are the push phase's GLOBAL
    vertex -> LOCAL VSS maps, DESIGN §2.8)."""
    return (P(axis), P(axis), P(axis), P(axis), P(axis))


def problem_specs2d(row_axis: str = BFS_AXIS, col_axis: str = COL_AXIS
                    ) -> tuple[P, P, P, P, P]:
    """2-D variant: the R·C per-device blocks stack row-major on dim 0,
    so one spec — both mesh axes on the leading dim — covers them all."""
    ax = (row_axis, col_axis)
    return (P(ax), P(ax), P(ax), P(ax), P(ax))


def problem_sharding(mesh: Mesh, axis: str = BFS_AXIS) -> NamedSharding:
    """The NamedSharding every shard-stacked array is committed with."""
    if mesh_is_2d(mesh):
        return NamedSharding(mesh, P(tuple(mesh.axis_names)))
    return NamedSharding(mesh, P(axis))


def state_specs(axis: str = BFS_AXIS, *, track_sigma: bool = False):
    """PartitionSpecs of the host-visible sharded wave state
    (:class:`repro.core.multi_source.MSState`): every field carries a
    leading shard axis — local ``(rps+1, S)`` level blocks, one global
    frontier replica per shard, one queue per shard.  ``track_sigma``
    adds the spec of the σ path-count channel, which shards like the
    level blocks (local ``(rps, S)`` rows), NOT like the replicated
    frontier words."""
    from repro.core.multi_source import MSState
    return MSState(P(axis), P(axis), P(axis), P(axis), P(axis), P(axis),
                   P(axis) if track_sigma else None)


def state_specs2d(row_axis: str = BFS_AXIS, col_axis: str = COL_AXIS,
                  *, track_sigma: bool = False):
    """2-D wave-state specs: every field stacks the R·C device blocks
    row-major on dim 0 (levels and σ are column-replicated per row block;
    the frontier block is each device's COLUMN-block words, row-replicated
    within a mesh column — replication is a per-device invariant of the
    engines, not something the specs encode, hence ``check_rep=False``)."""
    from repro.core.multi_source import MSState
    ax = P((row_axis, col_axis))
    return MSState(ax, ax, ax, ax, ax, ax, ax if track_sigma else None)
