"""Collective patterns for the mesh-native engines (DESIGN §3).

Two families live here:

* **Butterfly frontier collectives** (the PR-8 2-D partition): staged
  recursive-doubling exchanges built from ``lax.ppermute``.
  ``butterfly_frontier_exchange`` all-gathers per-device frontier word
  segments along a mesh axis in index order (stage ``s`` pairs device
  ``d`` with ``d ^ (1 << s)``, doubling the held block each stage);
  ``butterfly_or_allreduce`` OR-combines partial hit words via
  recursive-halving reduce-scatter + recursive-doubling all-gather.
  Both fall back to the flat ``all_gather`` on non-power-of-two axes —
  same result, no staged structure.

* **Overlap matmul** — ``ring_allgather_matmul`` is the classic Megatron
  column-parallel overlap trick: computing
  ``y_shard = allgather_K(x) @ W[:, shard]`` without a monolithic
  all-gather.  The K-sharded activation blocks rotate around the ring via
  ``lax.ppermute`` while each device multiplies the block it currently
  holds against the matching row-block of its (full-K, N-sharded) weight
  — compute hides the ICI hop latency.  Numerically identical to
  ``all_gather + matmul`` (equivalence-tested in tests/test_distributed.py).

A trace-time **byte ledger** (``comm_ledger``) records the per-device
bytes each collective moves: every exchange calls ``record_comm`` while
being traced, so lowering an engine inside a ``comm_ledger()`` block
yields its exact per-device communication volume per traced level —
that's what ``bench_dist.py``'s communication block gates on.
"""
from __future__ import annotations

import contextlib
import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def axis_size(axis_name):
    """``jax.lax.axis_size`` appeared after 0.4.37; ``psum(1, axis)`` is the
    portable idiom (constant-folded to the mesh axis size under tracing).
    Shared by every named-axis user in the repo — don't re-inline the
    version branch."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# ---------------------------------------------------------------------------
# trace-time communication ledger
# ---------------------------------------------------------------------------
_LEDGER: list[tuple[str, int]] | None = None


def record_comm(label: str, nbytes: int) -> None:
    """Note ``nbytes`` of per-device traffic under ``label`` if a ledger is
    open.  Called by the collectives *while tracing* — shapes are static,
    so the recorded volume is exact per executed call site (one while_loop
    body trace == one level's traffic)."""
    global _LEDGER
    if _LEDGER is not None:
        _LEDGER.append((label, int(nbytes)))


@contextlib.contextmanager
def comm_ledger():
    """Collect per-device collective byte counts during tracing.

        with comm_ledger() as events:
            jax.jit(fn).lower(*args)          # force a fresh trace
        bytes_per_level = sum(n for _, n in events)

    Nested ledgers shadow (the inner one records); tracing the same
    cached jit a second time records nothing — lower a *fresh* closure.
    """
    global _LEDGER
    prev = _LEDGER
    _LEDGER = events = []
    try:
        yield events
    finally:
        _LEDGER = prev


def _nbytes(x) -> int:
    return int(math.prod(x.shape)) * x.dtype.itemsize


# ---------------------------------------------------------------------------
# butterfly frontier collectives (2-D partition, DESIGN §3)
# ---------------------------------------------------------------------------
def butterfly_frontier_exchange(seg: jnp.ndarray, axis_name: str,
                                *, stall_stage: int | None = None
                                ) -> jnp.ndarray:
    """Recursive-doubling all-gather of per-device segments, index-ordered.

    Device ``d`` contributes ``seg`` (leading-dim block ``d``); every
    device returns ``concat([seg_0, ..., seg_{n-1}])`` along dim 0.  On a
    power-of-two axis this runs ``log2(n)`` ``ppermute`` stages — stage
    ``s`` pairs ``d`` with ``d ^ (1 << s)`` and doubles the held block,
    keeping lower-indexed halves first so the result needs no final
    permutation.  Non-power-of-two axes fall back to the flat tiled
    ``all_gather`` (identical result, no staged structure).

    ``stall_stage`` is the fault seam (DESIGN §2.7): at that stage the
    partner's block is replaced with zeros — modelling a stalled/timed-out
    transfer — so downstream frontiers silently under-discover exactly the
    way a real stuck exchange would.  Ignored on the fallback path.
    """
    n = axis_size(axis_name)
    if n == 1:
        return seg
    if n & (n - 1):  # non-power-of-two: flat gather moves the same bytes
        record_comm("butterfly_fallback_flat", (n - 1) * _nbytes(seg))
        return jax.lax.all_gather(seg, axis_name, tiled=True)
    idx = jax.lax.axis_index(axis_name)
    buf = seg
    for s in range(n.bit_length() - 1):
        bit = 1 << s
        record_comm("butterfly_gather", _nbytes(buf))
        perm = [(d, d ^ bit) for d in range(n)]
        other = jax.lax.ppermute(buf, axis_name, perm)
        if stall_stage == s:
            other = jnp.zeros_like(other)
        lower_half = (idx & bit) == 0
        buf = jnp.where(lower_half,
                        jnp.concatenate([buf, other], axis=0),
                        jnp.concatenate([other, buf], axis=0))
    return buf


def butterfly_or_allreduce(words: jnp.ndarray, axis_name: str
                           ) -> jnp.ndarray:
    """Bitwise-OR all-reduce of packed frontier words along a mesh axis.

    Power-of-two axes run recursive-halving reduce-scatter (each stage
    ORs the partner's half of the shrinking block) followed by the
    recursive-doubling all-gather — per-device volume
    ``2 * nbytes * (1 - 1/n)`` instead of the flat gather's
    ``nbytes * (n - 1)``.  Requires dim 0 divisible by the axis size
    (guaranteed by the 32·cols row alignment of the 2-D partition);
    non-power-of-two axes fall back to gather + OR-reduce.
    """
    n = axis_size(axis_name)
    if n == 1:
        return words
    if (n & (n - 1)) or words.shape[0] % n:
        record_comm("or_allreduce_fallback_flat", (n - 1) * _nbytes(words))
        gathered = jax.lax.all_gather(words, axis_name, tiled=False)
        return jax.lax.reduce(gathered, jnp.zeros((), words.dtype),
                              jnp.bitwise_or, (0,))
    idx = jax.lax.axis_index(axis_name)
    buf = words
    stages = n.bit_length() - 1
    # recursive halving: after stage s the device holds the OR over its
    # 2^(s+1)-device group of a 1/2^(s+1) slice, position-encoded by the
    # low bits of idx so the doubling phase can reassemble in order
    for s in range(stages):
        bit = 1 << s
        half = buf.shape[0] // 2
        record_comm("or_reduce_scatter", _nbytes(buf) // 2)
        upper = (idx & bit) != 0
        keep = jnp.where(upper, buf[half:], buf[:half])
        send = jnp.where(upper, buf[:half], buf[half:])
        perm = [(d, d ^ bit) for d in range(n)]
        other = jax.lax.ppermute(send, axis_name, perm)
        buf = keep | other
    # recursive doubling reassembles the full OR'd block: stage order is
    # reversed so the halving's position encoding unwinds exactly
    for s in reversed(range(stages)):
        bit = 1 << s
        record_comm("or_allgather", _nbytes(buf))
        perm = [(d, d ^ bit) for d in range(n)]
        other = jax.lax.ppermute(buf, axis_name, perm)
        upper = (idx & bit) != 0
        buf = jnp.where(upper,
                        jnp.concatenate([other, buf], axis=0),
                        jnp.concatenate([buf, other], axis=0))
    return buf


def ring_allgather_matmul(x_blk: jnp.ndarray, w_local: jnp.ndarray,
                          axis_name: str) -> jnp.ndarray:
    """Per-device: x_blk (M, K/n) — this device's K block of x;
    w_local (K, N/n) — full-K rows of this device's N shard.
    Returns y_local (M, N/n) = full_x @ w_local."""
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    kb = x_blk.shape[1]
    perm = [(i, (i + 1) % n) for i in range(n)]

    def body(i, carry):
        acc, blk = carry
        src = (idx - i) % n          # block id currently held by this device
        w_rows = jax.lax.dynamic_slice_in_dim(w_local, src * kb, kb, axis=0)
        acc = acc + blk @ w_rows
        blk = jax.lax.ppermute(blk, axis_name, perm)
        return acc, blk

    acc0 = jnp.zeros((x_blk.shape[0], w_local.shape[1]),
                     dtype=jnp.promote_types(x_blk.dtype, w_local.dtype))
    acc, _ = jax.lax.fori_loop(0, n, body, (acc0, x_blk))
    return acc


def make_overlap_matmul(mesh: Mesh, axis_name: str = "model"):
    """shard_map-wrapped ring matmul:
    f(x (M, K) sharded on K, w (K, N) sharded on N) -> (M, N) sharded on N.
    """
    from jax.experimental.shard_map import shard_map

    fn = shard_map(
        functools.partial(ring_allgather_matmul, axis_name=axis_name),
        mesh=mesh,
        in_specs=(P(None, axis_name), P(None, axis_name)),
        out_specs=P(None, axis_name),
        check_rep=False,
    )
    return jax.jit(fn)
