"""Overlap-friendly collective patterns (DESIGN §3).

``ring_allgather_matmul`` is the classic Megatron column-parallel overlap
trick: computing ``y_shard = allgather_K(x) @ W[:, shard]`` without a
monolithic all-gather.  The K-sharded activation blocks rotate around the
ring via ``lax.ppermute`` while each device multiplies the block it
currently holds against the matching row-block of its (full-K, N-sharded)
weight — compute hides the ICI hop latency.  Numerically identical to
``all_gather + matmul`` (equivalence-tested in tests/test_distributed.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def axis_size(axis_name):
    """``jax.lax.axis_size`` appeared after 0.4.37; ``psum(1, axis)`` is the
    portable idiom (constant-folded to the mesh axis size under tracing).
    Shared by every named-axis user in the repo — don't re-inline the
    version branch."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def ring_allgather_matmul(x_blk: jnp.ndarray, w_local: jnp.ndarray,
                          axis_name: str) -> jnp.ndarray:
    """Per-device: x_blk (M, K/n) — this device's K block of x;
    w_local (K, N/n) — full-K rows of this device's N shard.
    Returns y_local (M, N/n) = full_x @ w_local."""
    n = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    kb = x_blk.shape[1]
    perm = [(i, (i + 1) % n) for i in range(n)]

    def body(i, carry):
        acc, blk = carry
        src = (idx - i) % n          # block id currently held by this device
        w_rows = jax.lax.dynamic_slice_in_dim(w_local, src * kb, kb, axis=0)
        acc = acc + blk @ w_rows
        blk = jax.lax.ppermute(blk, axis_name, perm)
        return acc, blk

    acc0 = jnp.zeros((x_blk.shape[0], w_local.shape[1]),
                     dtype=jnp.promote_types(x_blk.dtype, w_local.dtype))
    acc, _ = jax.lax.fori_loop(0, n, body, (acc0, x_blk))
    return acc


def make_overlap_matmul(mesh: Mesh, axis_name: str = "model"):
    """shard_map-wrapped ring matmul:
    f(x (M, K) sharded on K, w (K, N) sharded on N) -> (M, N) sharded on N.
    """
    from jax.experimental.shard_map import shard_map

    fn = shard_map(
        functools.partial(ring_allgather_matmul, axis_name=axis_name),
        mesh=mesh,
        in_specs=(P(None, axis_name), P(None, axis_name)),
        out_specs=P(None, axis_name),
        check_rep=False,
    )
    return jax.jit(fn)
