"""Sharding rule engine: logical parameter/activation axes -> mesh axes.

Models annotate every parameter leaf with a tuple of logical axis names
(models/*.py ``*_axes`` functions).  An arch config supplies a *rules* map
``logical -> mesh axis (or tuple of mesh axes, or None)``; this engine turns
(axes tree, shapes tree, rules, mesh) into a NamedSharding tree, with two
safety rails applied per leaf:

* divisibility: a dim whose size is not divisible by the mesh-axis extent
  falls back to replication on that dim (e.g. gemma3's single KV head can't
  split 16 ways — the engine replicates it instead of erroring);
* collision: a mesh axis may appear only once per PartitionSpec; later
  logical axes mapping to an already-used mesh axis are replicated
  (e.g. DeepSeek MoE w_gate maps experts→model and ffn→model; experts wins).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axis, (tuple, list)):
        return math.prod(sizes[a] for a in axis)
    return sizes[axis]


def spec_for_leaf(logical: tuple, shape: tuple, rules: dict, mesh: Mesh
                  ) -> P:
    assert len(logical) == len(shape) or logical == (), \
        f"logical {logical} vs shape {shape}"
    used: set = set()
    out = []
    for name, dim in zip(logical, shape):
        axis = rules.get(name)
        if axis is None:
            out.append(None)
            continue
        axes_t = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
        if any(a not in mesh.axis_names for a in axes_t):
            out.append(None)
            continue
        if any(a in used for a in axes_t):
            out.append(None)           # collision -> replicate
            continue
        if dim % _axis_size(mesh, axes_t) != 0:
            out.append(None)           # divisibility -> replicate
            continue
        used.update(axes_t)
        out.append(axis if not isinstance(axis, list) else tuple(axis))
    return P(*out)


def tree_shardings(axes_tree, shapes_tree, rules: dict, mesh: Mesh):
    """Build a NamedSharding pytree from a logical-axes tree + a matching
    ShapeDtypeStruct tree."""
    is_axes = lambda t: isinstance(t, tuple) and all(
        isinstance(x, (str, tuple, list, type(None))) for x in t)

    def build(logical, shaped):
        spec = spec_for_leaf(tuple(logical), tuple(shaped.shape), rules, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(build, axes_tree, shapes_tree,
                                  is_leaf=is_axes)


def shaped_with_sharding(shapes_tree, shardings_tree):
    """Attach shardings to a ShapeDtypeStruct tree (for AOT lowering)."""
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shardings_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def cast_float_leaves(shapes_tree, dtype):
    """Re-declare float leaves of a ShapeDtypeStruct tree in ``dtype``
    (used to lower with bf16 parameters without materialising them)."""
    def cast(s):
        if np.issubdtype(s.dtype, np.floating):
            return jax.ShapeDtypeStruct(s.shape, dtype)
        return s
    return jax.tree_util.tree_map(cast, shapes_tree)
