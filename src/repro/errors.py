"""Typed error hierarchy of the BLEST stack (DESIGN §2.7).

Every ingress path — graph construction (``graphs/csr.py``), preparation
(``core/policy.prepare``), the serving verbs (``repro.serve``) and the
launchers — raises these instead of bare ``assert``s, so validation
survives ``python -O`` (a bare ``assert`` is compiled away under ``-O``;
a load-bearing one is a latent silent-wrong-answer bug).  The CI ``chaos``
workflow runs an ``-O`` smoke lane to prove the property holds.

Hierarchy::

    BlestError
    ├── GraphValidationError   malformed graph / out-of-range source ids
    ├── ConfigError            unusable engine/tuning configuration
    ├── AdmissionError         multi-tenant quota or memory budget refusal
    │   └── QueueFullError     async request queue refused a submission
    ├── DeadlineExceeded       a query outlived its per-request budget
    ├── StaleEpochError        edge updates raced a newer prepared epoch
    └── KernelFaultError       device result failed an oracle cross-check

``DeadlineExceeded`` is only *raised* when a caller demands a complete
answer; the serving tier normally degrades to a partial
``serve.TimeoutResult`` instead (ISSUE: bounded latency, not a hang).
``KernelFaultError`` is what the verify-mode sampling policy
(``serve.session_manager``) raises internally when a wave result diverges
from the ``kernels/ref.py`` oracle — the session is quarantined and the
query re-served on the reference path, so callers see a degraded-but-
correct answer plus a structured warning, never the wrong levels.
"""
from __future__ import annotations

import numpy as np


class BlestError(Exception):
    """Base class of every typed error the BLEST stack raises."""


class GraphValidationError(BlestError, ValueError):
    """A graph, permutation or source id failed ingress validation."""


class ConfigError(BlestError, ValueError):
    """An engine or tuning configuration is unusable (e.g. a bucket count
    the queue-width ladder cannot honour).  Raised instead of silently
    degrading to a nearby valid configuration — a silent fallback would
    make autotuner search results lie about what actually ran."""


class AdmissionError(BlestError):
    """A request was refused at admission (quota / byte budget / slot
    pool exhausted).  Carries a machine-readable ``reason`` code."""

    def __init__(self, message: str, *, reason: str = "rejected"):
        super().__init__(message)
        self.reason = reason


class QueueFullError(AdmissionError):
    """The async request queue refused a submission (DESIGN §2.10).

    A bounded queue rejects at ingress instead of buffering an unbounded
    backlog — the same fail-fast contract as :class:`AdmissionError`,
    which this specialises so queue callers can catch it separately.
    ``reason`` is ``"capacity"`` (global queue depth) or
    ``"tenant-backlog"`` (one tenant's pending share)."""


class DeadlineExceeded(BlestError, TimeoutError):
    """A query exceeded its per-request deadline."""


class StaleEpochError(BlestError):
    """An edge-update batch was applied against a superseded epoch.

    :func:`repro.core.bvss_delta.apply_edge_updates` is a functional
    compare-and-swap: callers that captured ``prepared.epoch`` before
    computing a delta pass it as ``expected_epoch``, and a concurrent
    update that bumped the epoch in between raises this instead of
    silently merging onto the wrong base.  Carries the ``expected`` and
    ``actual`` epochs."""

    def __init__(self, message: str, *, expected: int, actual: int):
        super().__init__(message)
        self.expected = expected
        self.actual = actual


class KernelFaultError(BlestError):
    """A device kernel result failed verification against its oracle."""


def check_source(src: int, n: int, *, what: str = "source") -> int:
    """Validate one vertex id against ``[0, n)`` and return it as int.

    Rejects bool (a silent 0/1 coercion), non-integral values, and ids
    outside the vertex range — including NEGATIVE ids, which NumPy fancy
    indexing would otherwise silently wrap (``perm[-1]`` is the last
    vertex, not an error: the exact silent-wrong-answer bug this guards).
    """
    if isinstance(src, (bool, np.bool_)) or \
            not isinstance(src, (int, np.integer)):
        raise GraphValidationError(
            f"{what} must be an integer vertex id, got "
            f"{type(src).__name__} {src!r}")
    s = int(src)
    if not 0 <= s < n:
        raise GraphValidationError(
            f"{what} {s} out of range for a graph with {n} vertices "
            f"(valid ids are 0..{n - 1})")
    return s


def check_sources(sources, n: int, *, what: str = "sources") -> list[int]:
    """Validate a sequence of vertex ids (see :func:`check_source`).

    Arrays are validated vectorised; generic sequences element-by-element
    (so a stray bool / float / string in a Python list is caught before
    ``np.asarray`` silently coerces it)."""
    if isinstance(sources, np.ndarray):
        arr = sources
        if arr.ndim != 1:
            raise GraphValidationError(
                f"{what} must be a 1-D sequence of vertex ids, got shape "
                f"{arr.shape}")
        if arr.size == 0:
            return []
        if arr.dtype == np.bool_ or \
                not np.issubdtype(arr.dtype, np.integer):
            raise GraphValidationError(
                f"{what} must be integer vertex ids, got dtype {arr.dtype}")
        if arr.size and (int(arr.min()) < 0 or int(arr.max()) >= n):
            bad = arr[(arr < 0) | (arr >= n)]
            raise GraphValidationError(
                f"{what} contain out-of-range ids {bad[:8].tolist()} for a "
                f"graph with {n} vertices (valid ids are 0..{n - 1})")
        return [int(s) for s in arr]
    try:
        items = list(sources)
    except TypeError as e:
        raise GraphValidationError(
            f"{what} must be a sequence of vertex ids, got "
            f"{type(sources).__name__}") from e
    return [check_source(s, n, what=f"{what}[{i}]")
            for i, s in enumerate(items)]


def check_weights(weights, m: int, *, what: str = "weights") -> np.ndarray:
    """Validate a per-edge weight vector for the weighted verbs (SSSP /
    weighted PageRank) and return it as float32 (m,).

    Rejects a shape that does not match the edge count, non-numeric or
    bool dtypes, NaN / ±inf entries, and NON-POSITIVE weights — zero is
    rejected along with negatives because delta-stepping's bucket
    invariant (and termination of the label-correcting inner loop on
    cycles) requires strictly positive edge lengths.
    """
    try:
        arr = np.asarray(weights)
    except Exception as e:  # ragged lists etc.
        raise GraphValidationError(
            f"{what} must be a numeric array of per-edge weights, got "
            f"{type(weights).__name__}") from e
    if arr.dtype == np.bool_ or arr.dtype == object or \
            not np.issubdtype(arr.dtype, np.number):
        raise GraphValidationError(
            f"{what} must have a real numeric dtype, got {arr.dtype}")
    if arr.shape != (m,):
        raise GraphValidationError(
            f"{what} must have shape ({m},) — one weight per CSR edge — "
            f"got shape {arr.shape}")
    arr = arr.astype(np.float32)
    if arr.size:
        if np.isnan(arr).any():
            raise GraphValidationError(
                f"{what} contain NaN at edges "
                f"{np.flatnonzero(np.isnan(arr))[:8].tolist()}")
        if np.isinf(arr).any():
            raise GraphValidationError(
                f"{what} contain non-finite entries at edges "
                f"{np.flatnonzero(np.isinf(arr))[:8].tolist()} (+inf is "
                f"reserved for the no-edge sentinel in the weight plane)")
        if (arr <= 0).any():
            bad = np.flatnonzero(arr <= 0)
            raise GraphValidationError(
                f"{what} must be strictly positive (delta-stepping bucket "
                f"invariant); edges {bad[:8].tolist()} have values "
                f"{arr[bad[:8]].tolist()}")
    return arr
