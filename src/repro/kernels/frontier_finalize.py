"""Pallas TPU kernels for the end-of-level sweep (paper Alg. 3 stage 2).

The paper replaces scattered atomic updates with one dense, fully-coalesced
pass over the visited bitmap.  On TPU this is the *native* idiom — a pure
elementwise VPU sweep over vertex tiles.

Two entry points:

``finalize_sweep``
    The original Alg.-3 stage-2 kernel: ``levels' , new`` from ``marks``.
    Kept as the minimal unit (and as the §Perf baseline for the fused one).

``finalize_pack_sweep``
    The fused level-step tail (DESIGN.md §2.3).  One sweep over the vertex
    tiles emits all three per-level dense products at once:

        levels'     = finalised level array
        fwords      = packed uint32 frontier words (bit v = vertex v new)
        set_active  = per-slice-set "has a new vertex" flags (the input to
                      cumsum queue compaction)

    which replaces the seed's three separate dense passes (finalise,
    ``_pack_bits``, the set-reduction half of ``rebuild_queue``) — three HBM
    round-trips over the vertex arrays collapse into one, mirroring the
    paper's cache-locality argument for the stage-2 sweep.  Eager (Alg. 2)
    mode derives newness from ``levels == lvl`` (the scatter-min already
    wrote the levels); lazy (Alg. 3) mode finalises from byte marks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF32 = (1 << 31) - 1  # python literal so the kernel captures no tracers
TILE = 8 * 128  # divisible by 32 (word pack) and every σ | 32 (set flags)


# ---------------------------------------------------------------------------
# original minimal finalise (kept: unit kernel + baseline)
# ---------------------------------------------------------------------------
def _finalize_kernel(marks_ref, levels_ref, lvl_ref, levels_out_ref,
                     new_ref):
    marks = marks_ref[...]
    levels = levels_ref[...]
    lvl = lvl_ref[0]
    new = (marks > 0) & (levels == INF32)
    levels_out_ref[...] = jnp.where(new, lvl, levels)
    new_ref[...] = new.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("interpret",))
def finalize_sweep(marks: jnp.ndarray, levels: jnp.ndarray, lvl: jnp.ndarray,
                   *, interpret: bool | None = None
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """marks (N,) uint8, levels (N,) int32, lvl scalar int32 ->
    (levels' (N,) int32, new (N,) bool)."""
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    N = marks.shape[0]
    pad = (-N) % TILE
    if pad:
        marks = jnp.pad(marks, (0, pad))
        levels = jnp.pad(levels, (0, pad), constant_values=0)
    Np = N + pad
    grid = (Np // TILE,)
    lvl_arr = jnp.asarray(lvl, dtype=jnp.int32).reshape(1)

    levels_out, new = pl.pallas_call(
        _finalize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.int32),
            jax.ShapeDtypeStruct((Np,), jnp.int8),
        ],
        interpret=interpret,
    )(marks, levels, lvl_arr)
    return levels_out[:N], new[:N].astype(bool)


# ---------------------------------------------------------------------------
# fused finalise + frontier-pack + set-active sweep
# ---------------------------------------------------------------------------
def _emit_packed(new, fw_out_ref, act_out_ref, sigma: int):
    """Shared tail: write packed frontier words + set flags."""
    bits = new.astype(jnp.uint32).reshape(-1, 32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    fw_out_ref[...] = jnp.sum(bits * weights[None, :], axis=1,
                              dtype=jnp.uint32)
    act_out_ref[...] = jnp.any(new.reshape(-1, sigma), axis=1
                               ).astype(jnp.int8)


def _finalize_pack_lazy(marks_ref, levels_ref, lvl_ref, lv_out_ref,
                        fw_out_ref, act_out_ref, *, sigma: int):
    levels = levels_ref[...]
    lvl = lvl_ref[0]
    new = (marks_ref[...] > 0) & (levels == INF32)
    lv_out_ref[...] = jnp.where(new, lvl, levels)
    _emit_packed(new, fw_out_ref, act_out_ref, sigma)


def _finalize_pack_eager(levels_ref, lvl_ref, fw_out_ref, act_out_ref, *,
                         sigma: int):
    # eager scatter-min already wrote the levels: no levels output stream,
    # so the hot path pays two dense writes (words + flags), not three
    new = levels_ref[...] == lvl_ref[0]
    _emit_packed(new, fw_out_ref, act_out_ref, sigma)


@functools.partial(jax.jit, static_argnames=("sigma", "n_fwords", "n_sets",
                                             "interpret"))
def finalize_pack_sweep(levels: jnp.ndarray, lvl: jnp.ndarray, *,
                        sigma: int, n_fwords: int, n_sets: int,
                        marks: jnp.ndarray | None = None,
                        interpret: bool | None = None
                        ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One fused sweep: finalise + frontier-pack + set-active flags.

    levels: (N,) int32 over real vertices (N = n).
    lvl:    scalar int32 current level (>= 1).
    marks:  (N,) uint8 lazy marks, or None for eager mode
            (newness = ``levels == lvl``; the returned levels ARE the input
            array — eager mode emits no levels stream at all).
    Returns ``(levels' (N,) int32, fwords (n_fwords,) uint32,
    set_active (n_sets,) bool)``; frontier bit ``v`` of fwords is vertex v,
    set_active[s] covers vertices ``σs .. σ(s+1)-1``.
    """
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    N = levels.shape[0]
    need = max(N, n_fwords * 32, n_sets * sigma)
    Np = ((need + TILE - 1) // TILE) * TILE
    # pad with levels=0: for lvl >= 1 padded vertices are never "new" in
    # either mode (0 != lvl and 0 != INF)
    levels_p = jnp.pad(levels, (0, Np - N), constant_values=0)
    lvl_arr = jnp.asarray(lvl, dtype=jnp.int32).reshape(1)
    grid = (Np // TILE,)

    pack_specs = [
        pl.BlockSpec((TILE // 32,), lambda i: (i,)),
        pl.BlockSpec((TILE // sigma,), lambda i: (i,)),
    ]
    pack_shape = [
        jax.ShapeDtypeStruct((Np // 32,), jnp.uint32),
        jax.ShapeDtypeStruct((Np // sigma,), jnp.int8),
    ]
    if marks is None:
        fwords, act = pl.pallas_call(
            functools.partial(_finalize_pack_eager, sigma=sigma),
            grid=grid,
            in_specs=[
                pl.BlockSpec((TILE,), lambda i: (i,)),
                pl.BlockSpec((1,), lambda i: (0,)),
            ],
            out_specs=pack_specs,
            out_shape=pack_shape,
            interpret=interpret,
        )(levels_p, lvl_arr)
        lv_out = levels  # untouched by eager finalise: no dense write
    else:
        marks_p = jnp.pad(marks, (0, Np - N))
        lv_full, fwords, act = pl.pallas_call(
            functools.partial(_finalize_pack_lazy, sigma=sigma),
            grid=grid,
            in_specs=[
                pl.BlockSpec((TILE,), lambda i: (i,)),
                pl.BlockSpec((TILE,), lambda i: (i,)),
                pl.BlockSpec((1,), lambda i: (0,)),
            ],
            out_specs=[pl.BlockSpec((TILE,), lambda i: (i,))] + pack_specs,
            out_shape=[jax.ShapeDtypeStruct((Np,), jnp.int32)] + pack_shape,
            interpret=interpret,
        )(marks_p, levels_p, lvl_arr)
        lv_out = lv_full[:N]
    return (lv_out, fwords[:n_fwords], act[:n_sets].astype(bool))
