"""Pallas TPU kernel: lazy-update finalisation sweep (paper Alg. 3 stage 2).

The paper replaces scattered atomic updates with one dense, fully-coalesced
pass over the visited bitmap.  On TPU this is the *native* idiom — a pure
elementwise VPU sweep over vertex tiles:

    new       = (marks > 0) & (levels == INF)
    levels'   = new ? lvl : levels
    new_flags = new                      (consumed by frontier pack + queue
                                          compaction outside)

Fusing the three outputs into one kernel saves two extra HBM passes over the
level array per BFS level, mirroring the paper's cache-locality argument.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF32 = (1 << 31) - 1  # python literal so the kernel captures no tracers
TILE = 8 * 128


def _finalize_kernel(marks_ref, levels_ref, lvl_ref, levels_out_ref,
                     new_ref):
    marks = marks_ref[...]
    levels = levels_ref[...]
    lvl = lvl_ref[0]
    new = (marks > 0) & (levels == INF32)
    levels_out_ref[...] = jnp.where(new, lvl, levels)
    new_ref[...] = new.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("interpret",))
def finalize_sweep(marks: jnp.ndarray, levels: jnp.ndarray, lvl: jnp.ndarray,
                   *, interpret: bool | None = None
                   ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """marks (N,) uint8, levels (N,) int32, lvl scalar int32 ->
    (levels' (N,) int32, new (N,) bool)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    N = marks.shape[0]
    pad = (-N) % TILE
    if pad:
        marks = jnp.pad(marks, (0, pad))
        levels = jnp.pad(levels, (0, pad), constant_values=0)
    Np = N + pad
    grid = (Np // TILE,)
    lvl_arr = jnp.asarray(lvl, dtype=jnp.int32).reshape(1)

    levels_out, new = pl.pallas_call(
        _finalize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.int32),
            jax.ShapeDtypeStruct((Np,), jnp.int8),
        ],
        interpret=interpret,
    )(marks, levels, lvl_arr)
    return levels_out[:N], new[:N].astype(bool)
