from .ops import (bvss_pull, bit_spmm, bvss_spmm, bvss_spmm_t,
                  bvss_spmm_t_local, bvss_spmm_w, bvss_spmm_w_local,
                  finalize_pack_sweep, finalize_sweep, pull_vss_kernel)
from . import ref

__all__ = ["bvss_pull", "bit_spmm", "bvss_spmm", "bvss_spmm_t",
           "bvss_spmm_t_local", "bvss_spmm_w", "bvss_spmm_w_local",
           "finalize_sweep", "finalize_pack_sweep", "pull_vss_kernel", "ref"]
