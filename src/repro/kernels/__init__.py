from .ops import (bvss_pull, bvss_push, bit_spmm, bvss_spmm,
                  bvss_spmm_minplus, bvss_spmm_minplus_local, bvss_spmm_t,
                  bvss_spmm_t_local, bvss_spmm_w, bvss_spmm_w_local,
                  finalize_pack_sweep, finalize_sweep, pull_vss_kernel,
                  push_vss_kernel, resolve_interpret)
from . import ref

__all__ = ["bvss_pull", "bvss_push", "bit_spmm", "bvss_spmm",
           "bvss_spmm_minplus", "bvss_spmm_minplus_local", "bvss_spmm_t",
           "bvss_spmm_t_local", "bvss_spmm_w", "bvss_spmm_w_local",
           "finalize_sweep", "finalize_pack_sweep", "pull_vss_kernel",
           "push_vss_kernel", "resolve_interpret", "ref"]
