from .ops import (bvss_pull, bit_spmm, bvss_spmm, finalize_pack_sweep,
                  finalize_sweep, pull_vss_kernel)
from . import ref

__all__ = ["bvss_pull", "bit_spmm", "bvss_spmm", "finalize_sweep",
           "finalize_pack_sweep", "pull_vss_kernel", "ref"]
