from .ops import bvss_pull, bit_spmm, finalize_sweep, pull_vss_kernel
from . import ref

__all__ = ["bvss_pull", "bit_spmm", "finalize_sweep", "pull_vss_kernel", "ref"]
