"""Public jit'd entry points for the kernel layer.

Downstream code (BFS engines, multi-source BFS) imports from here so the
kernel/oracle switch is one flag.  On CPU (this container) the Pallas bodies
execute in ``interpret=True``; on TPU they compile to Mosaic.

:func:`resolve_interpret` is the ONE place that decides interpret-vs-
compiled for every Pallas entry point (DESIGN §2.8) — the per-kernel
``jax.default_backend() == "cpu"`` sniffing that used to be copy-pasted
across ``bvss_pull`` and the four ``mxu_pull`` entry points lives here,
plus a ``BLEST_INTERPRET`` env override so the compiled bench lane can
force either mode uniformly.
"""
from __future__ import annotations

import os

import jax


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve a kernel's ``interpret`` flag to a concrete bool.

    Precedence (first match wins):

    1. an explicit ``interpret=True/False`` argument;
    2. the ``BLEST_INTERPRET`` env var — ``"1"`` forces interpret mode,
       ``"0"`` forces compiled Mosaic (read at TRACE time: flip it before
       the first jitted call, not between calls to an already-compiled
       function);
    3. backend sniff: interpret on CPU (no Mosaic backend there),
       compiled elsewhere.
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("BLEST_INTERPRET")
    if env is not None and env != "":
        return env != "0"
    return jax.default_backend() == "cpu"


from .bvss_pull import bvss_pull                              # noqa: E402
from .bvss_push import bvss_push                              # noqa: E402
from .mxu_pull import (bit_spmm, bvss_spmm, bvss_spmm_minplus,  # noqa: E402
                       bvss_spmm_minplus_local, bvss_spmm_t,
                       bvss_spmm_t_local, bvss_spmm_w, bvss_spmm_w_local)
from .frontier_finalize import (finalize_pack_sweep,          # noqa: E402
                                finalize_sweep)
from . import ref                                             # noqa: E402


def pull_vss_kernel(masks, fbytes, sigma: int = 8):
    """Drop-in replacement for core.bfs.pull_vss_jnp backed by the Pallas
    VPU kernel (lane-major layout)."""
    return bvss_pull(masks, fbytes, sigma=sigma)


def push_vss_kernel(masks, bits, sigma: int = 8):
    """Drop-in replacement for kernels.ref.bvss_push_ref backed by the
    Pallas VPU push kernel (lane-major layout)."""
    return bvss_push(masks, bits, sigma=sigma)


__all__ = ["resolve_interpret", "bvss_pull", "bvss_push", "bit_spmm",
           "bvss_spmm", "bvss_spmm_minplus", "bvss_spmm_minplus_local",
           "bvss_spmm_t", "bvss_spmm_t_local", "bvss_spmm_w",
           "bvss_spmm_w_local", "finalize_sweep", "finalize_pack_sweep",
           "pull_vss_kernel", "push_vss_kernel", "ref"]
