"""Public jit'd entry points for the kernel layer.

Downstream code (BFS engines, multi-source BFS) imports from here so the
kernel/oracle switch is one flag.  On CPU (this container) the Pallas bodies
execute in ``interpret=True``; on TPU they compile to Mosaic.
"""
from __future__ import annotations

from .bvss_pull import bvss_pull
from .mxu_pull import (bit_spmm, bvss_spmm, bvss_spmm_t, bvss_spmm_t_local,
                       bvss_spmm_w, bvss_spmm_w_local)
from .frontier_finalize import finalize_pack_sweep, finalize_sweep
from . import ref


def pull_vss_kernel(masks, fbytes, sigma: int = 8):
    """Drop-in replacement for core.bfs.pull_vss_jnp backed by the Pallas
    VPU kernel (lane-major layout)."""
    return bvss_pull(masks, fbytes, sigma=sigma)


__all__ = ["bvss_pull", "bit_spmm", "bvss_spmm", "bvss_spmm_t",
           "bvss_spmm_t_local", "bvss_spmm_w", "bvss_spmm_w_local",
           "finalize_sweep", "finalize_pack_sweep", "pull_vss_kernel", "ref"]
