"""Pallas TPU kernel for the BVSS push phase (direction-optimizing hybrid,
DESIGN §2.8).

The pull kernel answers "which slices of these VSSs see ANY frontier
vertex" — its frontier operand is the full σ-bit byte of each VSS's slice
set.  The push phase asks the converse question from a SMALL frontier:
each queued entry is one (frontier-vertex, VSS) pair, where the VSS is one
of the slice sets covering the vertex's own set ``v // σ``
(``BVSSDevice.vss_of_vertex_start/end``), and the frontier operand is the
SINGLE bit the vertex occupies inside its set, ``v % σ``.

That makes push the same lane computation as pull with a one-hot frontier
byte — so the kernel reuses the lane-major bit-tile layout verbatim
(masks transposed ``(32, TILE)``, all 8 sublanes carrying distinct mask
words) and simply builds the frontier word in-kernel from the bit index:
``fword = replicate(1 << b)``.  Keeping the one-hot construction inside
the kernel means the engine ships a (B,) int32 bit-index vector instead of
a materialised byte per queue entry, and the AND/extract tail is shared
idiom with ``bvss_pull``.

The payoff is queue SHAPE, not per-entry work: a push queue is sized by
``popcount(frontier) * max_vss_per_set`` instead of the pull ladder's
static fraction of ``num_vss``, so small-frontier levels touch a few
hundred lanes instead of the full bucketed pull width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bvss_pull import DEFAULT_TILE, _fword


def _push_kernel_lanes(masks_ref, bits_ref, hits_ref, *, sigma: int):
    """masks_ref (32, T) u32; bits_ref (1, T) u32 one bit index per VSS
    (the frontier vertex's ``v % σ``); hits_ref (spw*32, T) i8."""
    spw = 32 // sigma
    smask = jnp.uint32((1 << sigma) - 1)
    masks = masks_ref[...]                               # (32, T)
    fb = jnp.uint32(1) << bits_ref[...]                  # one-hot σ-bit byte
    fword = _fword(fb, sigma)                            # (1, T)
    anded = masks & fword
    for j in range(spw):
        sub = (anded >> jnp.uint32(sigma * j)) & smask
        hits_ref[j * 32:(j + 1) * 32, :] = (sub != 0).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("sigma", "tile", "interpret"))
def bvss_push(masks: jnp.ndarray, bits: jnp.ndarray, *, sigma: int = 8,
              tile: int = DEFAULT_TILE,
              interpret: bool | None = None) -> jnp.ndarray:
    """Pallas BVSS push: expand queued (frontier-vertex, VSS) pairs.

    masks: (B, 32) uint32 mask rows of the queued VSSs (row-major BVSS
           layout; transposed internally for the lane-major kernel).
    bits:  (B,) int32/uint32 — the in-set bit index ``v % σ`` of the
           frontier vertex that queued each VSS.
    returns hits (B, spw, 32) bool; hits[b, j, l] set iff slice k = j*32+l
           of VSS b is adjacent to the pushing vertex (scatter its row).
    """
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    B = masks.shape[0]
    spw = 32 // sigma
    pad = (-B) % tile
    bits = bits.astype(jnp.uint32)
    if pad:
        masks = jnp.pad(masks, ((0, pad), (0, 0)))
        bits = jnp.pad(bits, (0, pad))
    Bp = B + pad
    grid = (Bp // tile,)

    out = pl.pallas_call(
        functools.partial(_push_kernel_lanes, sigma=sigma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((32, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((spw * 32, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((spw * 32, Bp), jnp.int8),
        interpret=interpret,
    )(masks.T, bits[None, :])
    hits = out.T[:B].reshape(B, spw, 32)
    return hits.astype(bool)
