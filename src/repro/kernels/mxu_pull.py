"""Pallas TPU kernel: bit-SpMM pull on the MXU (multi-source BFS, DESIGN §2.2).

Paper §2: stacking S frontiers column-wise turns SpMSpV into SpMM.  On TPU
the MXU's native tile is 128×128 int8 — 16× wider than the paper's
m8n8k128 — so the bit-unpack cost (8× read amplification) only amortises
when many sources share one adjacency read.  This kernel computes

    Y[r, s] = Σ_c bits(A_packed)[r, c] * X[c, s]        (popcount semiring)

over 128-column stripes: the packed bit-rows of a row-tile are unpacked to
an int8 {0,1} tile in VMEM and fed to ``dot_general`` (int8 → int32), the
exact analogue of the paper's (AND, +) popcount accumulation, with every
MXU output entry useful (128·S dot products per call vs the paper's 64).

Grid = (row_tiles, s_tiles, k_stripes); the K dimension accumulates into the
output block (revisiting pattern), so K is the innermost grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 128   # rows per grid step
TILE_S = 128   # sources per grid step
TILE_K = 128   # columns per stripe = 4 packed u32 words


def _unpack_bits_u32(packed: jnp.ndarray) -> jnp.ndarray:
    """(R, W) uint32 -> (R, W*32) int8 of {0,1}; bit i of word w -> col 32w+i."""
    R, W = packed.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(R, W * 32).astype(jnp.int8)


def _mxu_kernel(a_ref, x_ref, y_ref):
    """a_ref (TILE_R, TILE_K//32) u32; x_ref (TILE_K, TILE_S) i8;
    y_ref (TILE_R, TILE_S) i32 accumulated over the K grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    a_bits = _unpack_bits_u32(a_ref[...])            # (R, K) int8
    part = jax.lax.dot_general(
        a_bits, x_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    y_ref[...] += part


@functools.partial(jax.jit, static_argnames=("interpret",))
def bit_spmm(a_packed: jnp.ndarray, x: jnp.ndarray, *,
             interpret: bool | None = None) -> jnp.ndarray:
    """Popcount-semiring SpMM: Y = bits(A) @ X.

    a_packed: (R, ceil(C/32)) uint32 packed bit rows.
    x:        (C, S) int8 (0/1 frontier columns).
    returns   (R, S) int32 popcounts (threshold >0 outside for Boolean BFS).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    R, W = a_packed.shape
    C, S = x.shape
    assert W * 32 >= C, (W, C)
    # pad everything to tile multiples
    pr, pk, ps = (-R) % TILE_R, (-(W * 32)) % TILE_K, (-S) % TILE_S
    if W * 32 > C:
        x = jnp.pad(x, ((0, W * 32 - C), (0, 0)))
    a_packed = jnp.pad(a_packed, ((0, pr), (0, pk // 32)))
    x = jnp.pad(x, ((0, pk), (0, ps)))
    Rp, Wp = a_packed.shape
    Cp, Sp = x.shape
    grid = (Rp // TILE_R, Sp // TILE_S, Cp // TILE_K)

    y = pl.pallas_call(
        _mxu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_R, TILE_K // 32), lambda r, s, k: (r, k)),
            pl.BlockSpec((TILE_K, TILE_S), lambda r, s, k: (k, s)),
        ],
        out_specs=pl.BlockSpec((TILE_R, TILE_S), lambda r, s, k: (r, s)),
        out_shape=jax.ShapeDtypeStruct((Rp, Sp), jnp.int32),
        interpret=interpret,
    )(a_packed, x)
    return y[:R, :S]
