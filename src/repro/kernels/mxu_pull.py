"""Pallas TPU kernel: bit-SpMM pull on the MXU (multi-source BFS, DESIGN §2.2).

Paper §2: stacking S frontiers column-wise turns SpMSpV into SpMM.  On TPU
the MXU's native tile is 128×128 int8 — 16× wider than the paper's
m8n8k128 — so the bit-unpack cost (8× read amplification) only amortises
when many sources share one adjacency read.  This kernel computes

    Y[r, s] = Σ_c bits(A_packed)[r, c] * X[c, s]        (popcount semiring)

over 128-column stripes: the packed bit-rows of a row-tile are unpacked to
an int8 {0,1} tile in VMEM and fed to ``dot_general`` (int8 → int32), the
exact analogue of the paper's (AND, +) popcount accumulation, with every
MXU output entry useful (128·S dot products per call vs the paper's 64).

Grid = (row_tiles, s_tiles, k_stripes); the K dimension accumulates into the
output block (revisiting pattern), so K is the innermost grid axis.

``bvss_spmm`` is the *compressed* counterpart (DESIGN §2.5): instead of the
dense bit-adjacency it takes one batch of queued BVSS mask rows plus the S
stacked σ-bit frontier bytes of each VSS's slice set, and resolves every
(slice, source) Boolean dot product as a block of small bit-SpMM tiles —
per VSS an (τ, σ) slice-bit tile contracted against its (σ, S) frontier-bit
tile on the MXU.  This is the serving hot path: multi-source BFS touches
only BVSS words, never the O(n²/32) dense adjacency.

``bvss_spmm_w``/``bvss_spmm_t`` are the *weighted* analytics companions
(DESIGN §2.6): the same (τ, σ) adjacency bit tile, contracted against
float32 operands instead of frontier bits.  ``bvss_spmm_w`` contracts over
the σ column axis (a weighted pull — Brandes σ path-count propagation
feeds per-column predecessor values); ``bvss_spmm_t`` contracts over the τ
row axis (the transposed product — the Brandes backward dependency sweep
pushes per-row values back onto the columns).  One bit-unpack serves both
traversal and analytics, so every algorithm in ``repro.analytics`` rides
the tiles the BFS engines already own.

``bvss_spmm_w_local``/``bvss_spmm_t_local`` are their local-rows ×
global-columns forms (DESIGN §2.4/§2.6): the gather half of the weighted
products, phrased so one call site serves the single-device engines AND
every shard of a row-sharded BVSS under ``shard_map``.  The `_w` form
gathers each queued VSS's (σ, S) slice-set column block out of a GLOBAL
per-column value array (single-device: the padded σ-frontier values;
sharded: the per-level all-gather of every shard's local frontier values);
the `_t` form gathers per-row values through the caller's ``row_ids``
(LOCAL rows under a mesh) and returns the per-column partials the caller
scatter-adds into the global column space — and, when row-sharded,
reduces across shards (``lax.psum_scatter``), because each shard only
sees the dependency flowing through its own rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_R = 128   # rows per grid step
TILE_S = 128   # sources per grid step
TILE_K = 128   # columns per stripe = 4 packed u32 words


def _unpack_bits_u32(packed: jnp.ndarray) -> jnp.ndarray:
    """(R, W) uint32 -> (R, W*32) int8 of {0,1}; bit i of word w -> col 32w+i."""
    R, W = packed.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    return bits.reshape(R, W * 32).astype(jnp.int8)


def _mxu_kernel(a_ref, x_ref, y_ref):
    """a_ref (TILE_R, TILE_K//32) u32; x_ref (TILE_K, TILE_S) i8;
    y_ref (TILE_R, TILE_S) i32 accumulated over the K grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    a_bits = _unpack_bits_u32(a_ref[...])            # (R, K) int8
    part = jax.lax.dot_general(
        a_bits, x_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    y_ref[...] += part


@functools.partial(jax.jit, static_argnames=("interpret",))
def bit_spmm(a_packed: jnp.ndarray, x: jnp.ndarray, *,
             interpret: bool | None = None) -> jnp.ndarray:
    """Popcount-semiring SpMM: Y = bits(A) @ X.

    a_packed: (R, ceil(C/32)) uint32 packed bit rows.
    x:        (C, S) int8 (0/1 frontier columns).
    returns   (R, S) int32 popcounts (threshold >0 outside for Boolean BFS).
    """
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    R, W = a_packed.shape
    C, S = x.shape
    assert W * 32 >= C, (W, C)
    # pad everything to tile multiples
    pr, pk, ps = (-R) % TILE_R, (-(W * 32)) % TILE_K, (-S) % TILE_S
    if W * 32 > C:
        x = jnp.pad(x, ((0, W * 32 - C), (0, 0)))
    a_packed = jnp.pad(a_packed, ((0, pr), (0, pk // 32)))
    x = jnp.pad(x, ((0, pk), (0, ps)))
    Rp, Wp = a_packed.shape
    Cp, Sp = x.shape
    grid = (Rp // TILE_R, Sp // TILE_S, Cp // TILE_K)

    y = pl.pallas_call(
        _mxu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_R, TILE_K // 32), lambda r, s, k: (r, k)),
            pl.BlockSpec((TILE_K, TILE_S), lambda r, s, k: (k, s)),
        ],
        out_specs=pl.BlockSpec((TILE_R, TILE_S), lambda r, s, k: (r, s)),
        out_shape=jax.ShapeDtypeStruct((Rp, Sp), jnp.int32),
        interpret=interpret,
    )(a_packed, x)
    return y[:R, :S]


# ---------------------------------------------------------------------------
# batched BVSS bit-SpMM: the compressed multi-source pull (DESIGN §2.5)
# ---------------------------------------------------------------------------
def _bvss_spmm_kernel(masks_ref, fb_ref, y_ref, *, sigma: int):
    """masks_ref (TB, 32) u32; fb_ref (TB, TS) u32;
    y_ref (TB, spw*32, TS) i32: per-VSS (τ, σ) @ (σ, TS) bit-SpMM tiles.

    Slice k = j*32 + l of VSS b carries mask bits σj+i of word masks[b, l];
    unpacking those σ bits against the σ unpacked frontier bits of each of
    the TS stacked sources turns every (slice, source) Boolean dot product
    into one entry of a batched int8 matmul — the BVSS restatement of the
    ``bit_spmm`` tile, with the contraction length σ instead of 128.
    """
    spw = 32 // sigma
    tb = masks_ref.shape[0]
    masks = masks_ref[...]                                   # (TB, 32)
    bitpos = jnp.arange(32, dtype=jnp.uint32)
    bits = (masks[:, :, None] >> bitpos[None, None, :]) & jnp.uint32(1)
    # bit p = σj + i of lane l -> slice row k = j*32 + l, contraction col i
    a = bits.reshape(tb, 32, spw, sigma).transpose(0, 2, 1, 3)
    a = a.reshape(tb, spw * 32, sigma).astype(jnp.int8)      # (TB, τ, σ)
    ib = jnp.arange(sigma, dtype=jnp.uint32)
    x = ((fb_ref[...][:, None, :] >> ib[None, :, None])
         & jnp.uint32(1)).astype(jnp.int8)                   # (TB, σ, TS)
    y_ref[...] = jax.lax.dot_general(
        a, x, dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("sigma", "tile_b", "tile_s",
                                             "interpret"))
def bvss_spmm(masks: jnp.ndarray, fbytes: jnp.ndarray, *, sigma: int = 8,
              tile_b: int | None = None, tile_s: int | None = None,
              interpret: bool | None = None) -> jnp.ndarray:
    """Batched multi-source BVSS pull as bit-SpMM tiles.

    masks:  (B, 32) uint32 queued VSS mask rows (row-major BVSS layout).
    fbytes: (B, S) uint32 — the σ-bit frontier byte of each VSS's slice set,
            one column per stacked source.
    returns (B, spw, 32, S) int32 popcounts of slice∧frontier per source
            (threshold >0 for Boolean BFS); [b, j, l, s] is slice k=j*32+l.

    Tile defaults: on TPU the batch tile is 8 so the (TB, τ, TS) int32
    accumulator fits VMEM; in interpret mode (CPU) a 128-wide batch tile
    amortises the interpreter's per-grid-cell cost.  The source tile rounds
    S up to a sublane multiple (pass ``tile_s=128`` for full MXU lanes).
    """
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, S = masks.shape[0], fbytes.shape[1]
    spw = 32 // sigma
    if tile_b is None:
        tile_b = 128 if interpret else 8
    if tile_s is None:
        tile_s = min(128, ((S + 7) // 8) * 8)
    pb, ps = (-B) % tile_b, (-S) % tile_s
    if pb:
        masks = jnp.pad(masks, ((0, pb), (0, 0)))
        fbytes = jnp.pad(fbytes, ((0, pb), (0, 0)))
    if ps:
        fbytes = jnp.pad(fbytes, ((0, 0), (0, ps)))
    Bp, Sp = B + pb, S + ps
    grid = (Bp // tile_b, Sp // tile_s)

    y = pl.pallas_call(
        functools.partial(_bvss_spmm_kernel, sigma=sigma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, 32), lambda b, s: (b, 0)),
            pl.BlockSpec((tile_b, tile_s), lambda b, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((tile_b, spw * 32, tile_s),
                               lambda b, s: (b, 0, s)),
        out_shape=jax.ShapeDtypeStruct((Bp, spw * 32, Sp), jnp.int32),
        interpret=interpret,
    )(masks, fbytes)
    return y[:B, :, :S].reshape(B, spw, 32, S)


# ---------------------------------------------------------------------------
# weighted BVSS tiles: the analytics semiring (DESIGN §2.6)
# ---------------------------------------------------------------------------
def _unpack_slice_tile(masks: jnp.ndarray, sigma: int) -> jnp.ndarray:
    """(TB, 32) u32 mask rows -> (TB, τ, σ) float32 {0,1} adjacency tiles.

    Slice k = j*32 + l of VSS b carries mask bits σj+i of word masks[b, l];
    the unpacked tile row k therefore matches ``row_ids[b].reshape(-1)``
    order and column i is the i-th vertex of the VSS's slice set."""
    spw = 32 // sigma
    tb = masks.shape[0]
    bitpos = jnp.arange(32, dtype=jnp.uint32)
    bits = (masks[:, :, None] >> bitpos[None, None, :]) & jnp.uint32(1)
    a = bits.reshape(tb, 32, spw, sigma).transpose(0, 2, 1, 3)
    return a.reshape(tb, spw * 32, sigma).astype(jnp.float32)


def _bvss_spmm_w_kernel(masks_ref, xv_ref, y_ref, *, sigma: int):
    """masks_ref (TB, 32) u32; xv_ref (TB, σ, TS) f32 per-column values;
    y_ref (TB, τ, TS) f32 = per-VSS (τ, σ) bit tile @ (σ, TS) values."""
    a = _unpack_slice_tile(masks_ref[...], sigma)
    y_ref[...] = jax.lax.dot_general(
        a, xv_ref[...], dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


def _bvss_spmm_t_kernel(masks_ref, hv_ref, y_ref, *, sigma: int):
    """masks_ref (TB, 32) u32; hv_ref (TB, τ, TS) f32 per-row values;
    y_ref (TB, σ, TS) f32 = per-VSS (σ, τ) transposed tile @ (τ, TS)."""
    a = _unpack_slice_tile(masks_ref[...], sigma)
    y_ref[...] = jax.lax.dot_general(
        a, hv_ref[...], dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


def _spmm_float_call(kernel, masks, vals, mid: int, out_mid: int, *,
                     sigma: int, tile_b: int | None,
                     tile_s: int | None, interpret: bool | None):
    """Shared pallas_call plumbing for the two weighted tile products:
    vals is (B, mid, S) float32, the result (B, out_mid, S) float32."""
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, S = masks.shape[0], vals.shape[2]
    if tile_b is None:
        tile_b = 128 if interpret else 8
    if tile_s is None:
        tile_s = min(128, ((S + 7) // 8) * 8)
    pb, ps = (-B) % tile_b, (-S) % tile_s
    if pb:
        masks = jnp.pad(masks, ((0, pb), (0, 0)))
        vals = jnp.pad(vals, ((0, pb), (0, 0), (0, 0)))
    if ps:
        vals = jnp.pad(vals, ((0, 0), (0, 0), (0, ps)))
    Bp, Sp = B + pb, S + ps
    grid = (Bp // tile_b, Sp // tile_s)
    y = pl.pallas_call(
        functools.partial(kernel, sigma=sigma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, 32), lambda b, s: (b, 0)),
            pl.BlockSpec((tile_b, mid, tile_s), lambda b, s: (b, 0, s)),
        ],
        out_specs=pl.BlockSpec((tile_b, out_mid, tile_s),
                               lambda b, s: (b, 0, s)),
        out_shape=jax.ShapeDtypeStruct((Bp, out_mid, Sp), jnp.float32),
        interpret=interpret,
    )(masks, vals)
    return y[:B, :, :S]


@functools.partial(jax.jit, static_argnames=("sigma", "tile_b", "tile_s",
                                             "interpret"))
def bvss_spmm_w(masks: jnp.ndarray, xvals: jnp.ndarray, *, sigma: int = 8,
                tile_b: int | None = None, tile_s: int | None = None,
                interpret: bool | None = None) -> jnp.ndarray:
    """Weighted BVSS pull: per-VSS (τ, σ) bit tile @ (σ, S) float values.

    masks: (B, 32) uint32 queued VSS mask rows.
    xvals: (B, σ, S) float32 — the values of each VSS's σ slice-set columns,
           one stacked column per source (zero where a column is not in the
           active contribution set, e.g. not on the current BFS frontier).
    returns (B, spw, 32, S) float32; [b, j, l, s] is the weighted sum over
           the in-neighbour columns of slice k = j*32 + l — scatter-add it
           into rows via ``row_ids`` (the σ path-count recurrence).
    """
    spw = 32 // sigma
    B = masks.shape[0]
    y = _spmm_float_call(_bvss_spmm_w_kernel, masks, xvals, sigma, spw * 32,
                         sigma=sigma, tile_b=tile_b, tile_s=tile_s,
                         interpret=interpret)
    return y.reshape(B, spw, 32, y.shape[2])


@functools.partial(jax.jit, static_argnames=("sigma", "tile_b", "tile_s",
                                             "interpret"))
def bvss_spmm_t(masks: jnp.ndarray, hvals: jnp.ndarray, *, sigma: int = 8,
                tile_b: int | None = None, tile_s: int | None = None,
                interpret: bool | None = None) -> jnp.ndarray:
    """Transposed weighted BVSS product: (σ, τ) tile @ (τ, S) float values.

    masks: (B, 32) uint32 queued VSS mask rows.
    hvals: (B, spw, 32, S) float32 — per-row values gathered through
           ``row_ids`` (zero where a row is not in the contributing level).
    returns (B, σ, S) float32; [b, i, s] is the weighted sum over the rows
           adjacent to the i-th column of the VSS's slice set — scatter-add
           it into columns (the Brandes backward dependency sweep).
    """
    B, spw = hvals.shape[0], hvals.shape[1]
    hv = hvals.reshape(B, spw * 32, hvals.shape[3])
    return _spmm_float_call(_bvss_spmm_t_kernel, masks, hv, spw * 32, sigma,
                            sigma=sigma, tile_b=tile_b, tile_s=tile_s,
                            interpret=interpret)


# ---------------------------------------------------------------------------
# local-rows × global-columns weighted forms (DESIGN §2.4/§2.6)
# ---------------------------------------------------------------------------
def bvss_spmm_w_local(masks: jnp.ndarray, sets: jnp.ndarray,
                      xglobal: jnp.ndarray, *, sigma: int = 8,
                      impl=None) -> jnp.ndarray:
    """Weighted pull of a queued VSS batch against a GLOBAL column-value
    array: gathers each VSS's (σ, S) slice-set column block from
    ``xglobal`` and contracts it with the (τ, σ) bit tile.

    masks:   (B, 32) uint32 queued VSS mask rows (a shard's LOCAL rows
             under a mesh — the masks only name rows the caller owns).
    sets:    (B,) int32 GLOBAL slice-set id of each queued VSS
             (``virtual_to_real[ids]``); set j owns columns [σj, σ(j+1)).
    xglobal: (C, S) float32 per-column values with C ≥ n_sets·σ — the
             padded frontier values single-device, the per-level
             all-gather of every shard's local frontier values when
             row-sharded (the float twin of the frontier-word gather).
    returns  (B, spw, 32, S) float32 weighted sums per slice — scatter-add
             into (local) rows via ``row_ids``.

    ``impl`` overrides the tile product (``kernels.ref.bvss_spmm_w_ref``
    for the oracle path); columns stay global in either mode, so this is
    the ONE gather both the single-device σ channel and every shard of
    the mesh-native channel execute.
    """
    cols = (sets[:, None] * sigma
            + jnp.arange(sigma, dtype=jnp.int32)[None, :])      # (B, σ)
    f = bvss_spmm_w if impl is None else impl
    return f(masks, xglobal[cols], sigma=sigma)


# ---------------------------------------------------------------------------
# min-plus BVSS tiles: the tropical semiring (SSSP relaxation, DESIGN §2.9)
# ---------------------------------------------------------------------------
def _bvss_spmm_minplus_kernel(masks_ref, wv_ref, xv_ref, y_ref, *,
                              sigma: int):
    """masks_ref (TB, 32) u32; wv_ref (TB, τ, σ) f32 edge weights (+inf on
    non-edges is also enforced here via the mask bits); xv_ref (TB, σ, TS)
    f32 per-column distances; y_ref (TB, τ, TS) f32 tropical product

        y[b, k, s] = min_i ( w[b, k, i] + x[b, i, s] )   over set bits i.

    σ is tiny (≤32), so the contraction is an unrolled elementwise min —
    no dot_general exists for (min, +), and with +inf as the annihilator
    the expression never forms inf − inf, so no NaNs leak out."""
    a = _unpack_slice_tile(masks_ref[...], sigma)            # (TB, τ, σ)
    w = wv_ref[...]
    x = xv_ref[...]
    inf = jnp.float32(jnp.inf)
    acc = jnp.full(y_ref.shape, inf, dtype=jnp.float32)
    for i in range(sigma):
        wi = jnp.where(a[:, :, i] > 0, w[:, :, i], inf)      # (TB, τ)
        acc = jnp.minimum(acc, wi[:, :, None] + x[:, i, None, :])
    y_ref[...] = acc


def _spmm_minplus_call(masks, wvals, xvals, *, sigma: int,
                       tile_b: int | None, tile_s: int | None,
                       interpret: bool | None):
    """pallas_call plumbing for the three-operand tropical tile product:
    the `_spmm_float_call` layout plus a (B, τ, σ) weight plane operand."""
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, S = masks.shape[0], xvals.shape[2]
    tau = (32 // sigma) * 32
    if tile_b is None:
        tile_b = 128 if interpret else 8
    if tile_s is None:
        tile_s = min(128, ((S + 7) // 8) * 8)
    pb, ps = (-B) % tile_b, (-S) % tile_s
    if pb:
        masks = jnp.pad(masks, ((0, pb), (0, 0)))
        wvals = jnp.pad(wvals, ((0, pb), (0, 0), (0, 0)))
        xvals = jnp.pad(xvals, ((0, pb), (0, 0), (0, 0)))
    if ps:
        xvals = jnp.pad(xvals, ((0, 0), (0, 0), (0, ps)))
    Bp, Sp = B + pb, S + ps
    grid = (Bp // tile_b, Sp // tile_s)
    y = pl.pallas_call(
        functools.partial(_bvss_spmm_minplus_kernel, sigma=sigma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, 32), lambda b, s: (b, 0)),
            pl.BlockSpec((tile_b, tau, sigma), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((tile_b, sigma, tile_s), lambda b, s: (b, 0, s)),
        ],
        out_specs=pl.BlockSpec((tile_b, tau, tile_s),
                               lambda b, s: (b, 0, s)),
        out_shape=jax.ShapeDtypeStruct((Bp, tau, Sp), jnp.float32),
        interpret=interpret,
    )(masks, wvals, xvals)
    return y[:B, :, :S]


@functools.partial(jax.jit, static_argnames=("sigma", "tile_b", "tile_s",
                                             "interpret"))
def bvss_spmm_minplus(masks: jnp.ndarray, wvals: jnp.ndarray,
                      xvals: jnp.ndarray, *, sigma: int = 8,
                      tile_b: int | None = None, tile_s: int | None = None,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Tropical (min, +) BVSS pull: the SSSP relaxation tile (DESIGN §2.9).

    masks: (B, 32) uint32 queued VSS mask rows.
    wvals: (B, spw, 32, σ) float32 — the weight plane rows of the queued
           VSS (``build_weight_plane`` layout: +inf where no edge), laid
           out exactly like ``row_ids`` with the σ slice-set column last.
    xvals: (B, σ, S) float32 per-column tentative distances (+inf for
           unreached columns — the tropical zero).
    returns (B, spw, 32, S) float32; [b, j, l, s] is
           min over in-neighbour columns i of (w[v→row] + dist[v]) for
           slice k = j*32 + l — scatter-``min`` it into rows via
           ``row_ids`` (the edge-relaxation recurrence).
    """
    spw = 32 // sigma
    B = masks.shape[0]
    wv = wvals.reshape(B, spw * 32, sigma)
    y = _spmm_minplus_call(masks, wv, xvals, sigma=sigma, tile_b=tile_b,
                           tile_s=tile_s, interpret=interpret)
    return y.reshape(B, spw, 32, y.shape[2])


def bvss_spmm_minplus_local(masks: jnp.ndarray, wvals: jnp.ndarray,
                            sets: jnp.ndarray, xglobal: jnp.ndarray, *,
                            sigma: int = 8, impl=None) -> jnp.ndarray:
    """Min-plus pull of a queued VSS batch against a GLOBAL column-distance
    array — the tropical twin of ``bvss_spmm_w_local``: gathers each VSS's
    (σ, S) slice-set distance block out of ``xglobal`` (single-device: the
    padded distance vector; row-sharded: the per-wave all-gather of every
    shard's local distances) and relaxes it through the (τ, σ) weight tile.

    masks: (B, 32) u32 queued mask rows; wvals: (B, spw, 32, σ) f32 queued
    weight-plane rows (``wplane[Q]``); sets: (B,) int32 GLOBAL slice-set
    ids; xglobal: (C, S) f32, C ≥ n_sets·σ.  Returns (B, spw, 32, S) f32 —
    scatter-``min`` into (local) rows via ``row_ids``.
    """
    cols = (sets[:, None] * sigma
            + jnp.arange(sigma, dtype=jnp.int32)[None, :])      # (B, σ)
    f = bvss_spmm_minplus if impl is None else impl
    return f(masks, wvals, xglobal[cols], sigma=sigma)


def bvss_spmm_t_local(masks: jnp.ndarray, row_ids: jnp.ndarray,
                      hrows: jnp.ndarray, *, sigma: int = 8,
                      impl=None) -> jnp.ndarray:
    """Transposed weighted product against per-row values gathered through
    the caller's ``row_ids`` (LOCAL rows under a mesh, dummy row last).

    masks:   (B, 32) uint32 queued VSS mask rows.
    row_ids: (B, spw, 32) int32 destination rows of each slice (local ids
             when row-sharded; the dummy row indexes ``hrows``'s zero tail).
    hrows:   (R + 1, S) float32 per-row values, row R the zeroed dummy.
    returns  (B, σ, S) float32 per-column partial sums — scatter-add into
             the GLOBAL column space; on a row-sharded BVSS the partials
             only cover dependency flowing through this shard's rows, so
             the scatter must be psum'd (``lax.psum_scatter``) across the
             mesh axis before it folds into δ (DESIGN §2.6).
    """
    f = bvss_spmm_t if impl is None else impl
    return f(masks, hrows[row_ids], sigma=sigma)
