"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax.numpy as jnp

INF32 = jnp.int32(jnp.iinfo(jnp.int32).max)


def bvss_pull_ref(masks: jnp.ndarray, fbytes: jnp.ndarray, sigma: int = 8
                  ) -> jnp.ndarray:
    """Oracle for kernels.bvss_pull: hits (B, 32/σ, 32) bool."""
    spw = 32 // sigma
    smask = jnp.uint32((1 << sigma) - 1)
    fb = fbytes & smask
    fword = jnp.zeros_like(fb)
    for j in range(spw):
        fword = fword | (fb << jnp.uint32(sigma * j))
    anded = masks & fword[:, None]
    hits = []
    for j in range(spw):
        hits.append(((anded >> jnp.uint32(sigma * j)) & smask) != 0)
    return jnp.stack(hits, axis=1)


def bvss_spmm_ref(masks: jnp.ndarray, fbytes: jnp.ndarray, sigma: int = 8
                  ) -> jnp.ndarray:
    """Oracle for kernels.bvss_spmm: (B, 32/σ, 32, S) int32 popcounts of
    slice∧frontier per stacked source column."""
    spw = 32 // sigma
    p = (jnp.arange(spw, dtype=jnp.uint32)[:, None] * jnp.uint32(sigma)
         + jnp.arange(sigma, dtype=jnp.uint32)[None, :])     # (spw, σ)
    abits = ((masks[:, None, :, None] >> p[None, :, None, :])
             & jnp.uint32(1)).astype(jnp.int32)              # (B, spw, 32, σ)
    ib = jnp.arange(sigma, dtype=jnp.uint32)
    xbits = ((fbytes[:, None, :] >> ib[None, :, None])
             & jnp.uint32(1)).astype(jnp.int32)              # (B, σ, S)
    return jnp.einsum("bjli,bis->bjls", abits, xbits)


def bit_spmm_ref(a_packed: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.bit_spmm: Y (R, S) int32 popcounts."""
    R, W = a_packed.shape
    C, S = x.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((a_packed[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1))
    dense = bits.reshape(R, W * 32)[:, :C].astype(jnp.int32)
    return dense @ x.astype(jnp.int32)


def finalize_sweep_ref(marks: jnp.ndarray, levels: jnp.ndarray,
                       lvl) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels.finalize_sweep."""
    new = (marks > 0) & (levels == INF32)
    return jnp.where(new, jnp.int32(lvl), levels), new


def finalize_pack_ref(levels: jnp.ndarray, lvl, *, sigma: int,
                      n_fwords: int, n_sets: int,
                      marks: jnp.ndarray | None = None
                      ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels.finalize_pack_sweep — the inline jnp finalise +
    ``_pack_bits`` + set-reduction passes the fused kernel replaces."""
    if marks is None:                       # eager: scatter-min already ran
        new = levels == jnp.int32(lvl)
        lv_out = levels
    else:                                   # lazy: finalise from byte marks
        new = (marks > 0) & (levels == INF32)
        lv_out = jnp.where(new, jnp.int32(lvl), levels)
    n_pad = n_fwords * 32
    bits = jnp.zeros((n_pad,), dtype=bool).at[:new.shape[0]].set(new)
    b = bits.reshape(n_fwords, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    fwords = jnp.sum(b * weights[None, :], axis=1, dtype=jnp.uint32)
    sbits = jnp.zeros((n_sets * sigma,), dtype=bool).at[:new.shape[0]].set(new)
    set_active = sbits.reshape(n_sets, sigma).any(axis=1)
    return lv_out, fwords, set_active
