"""Oracles for the kernel layer (the ``ref.py`` contract).

Two kinds live here:

* pure-jnp twins of every Pallas kernel (drop-in, same signature) — the
  ``use_kernel(s)=False`` fallback path and the per-kernel test oracle;
* host-side *analytics* oracles (NetworkX / SciPy / NumPy) for the
  ``repro.analytics`` subsystem — connected components, eccentricity and
  Brandes betweenness computed by an independent implementation, so every
  wave-engine analytic is verified end-to-end, not just per tile.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

INF32 = jnp.int32(jnp.iinfo(jnp.int32).max)


def bvss_pull_ref(masks: jnp.ndarray, fbytes: jnp.ndarray, sigma: int = 8
                  ) -> jnp.ndarray:
    """Oracle for kernels.bvss_pull: hits (B, 32/σ, 32) bool."""
    spw = 32 // sigma
    smask = jnp.uint32((1 << sigma) - 1)
    fb = fbytes & smask
    fword = jnp.zeros_like(fb)
    for j in range(spw):
        fword = fword | (fb << jnp.uint32(sigma * j))
    anded = masks & fword[:, None]
    hits = []
    for j in range(spw):
        hits.append(((anded >> jnp.uint32(sigma * j)) & smask) != 0)
    return jnp.stack(hits, axis=1)


def bvss_push_ref(masks: jnp.ndarray, bits: jnp.ndarray, sigma: int = 8
                  ) -> jnp.ndarray:
    """Oracle for kernels.bvss_push: hits (B, 32/σ, 32) bool — the pull
    oracle evaluated against the one-hot frontier byte ``1 << (v % σ)`` of
    the vertex pushing each queued VSS."""
    fb = jnp.uint32(1) << bits.astype(jnp.uint32)
    return bvss_pull_ref(masks, fb, sigma)


def bvss_spmm_ref(masks: jnp.ndarray, fbytes: jnp.ndarray, sigma: int = 8
                  ) -> jnp.ndarray:
    """Oracle for kernels.bvss_spmm: (B, 32/σ, 32, S) int32 popcounts of
    slice∧frontier per stacked source column."""
    spw = 32 // sigma
    p = (jnp.arange(spw, dtype=jnp.uint32)[:, None] * jnp.uint32(sigma)
         + jnp.arange(sigma, dtype=jnp.uint32)[None, :])     # (spw, σ)
    abits = ((masks[:, None, :, None] >> p[None, :, None, :])
             & jnp.uint32(1)).astype(jnp.int32)              # (B, spw, 32, σ)
    ib = jnp.arange(sigma, dtype=jnp.uint32)
    xbits = ((fbytes[:, None, :] >> ib[None, :, None])
             & jnp.uint32(1)).astype(jnp.int32)              # (B, σ, S)
    return jnp.einsum("bjli,bis->bjls", abits, xbits)


def _abits(masks: jnp.ndarray, sigma: int) -> jnp.ndarray:
    """Decode (B, 32) mask words to (B, spw, 32, σ) {0,1} adjacency bits."""
    spw = 32 // sigma
    p = (jnp.arange(spw, dtype=jnp.uint32)[:, None] * jnp.uint32(sigma)
         + jnp.arange(sigma, dtype=jnp.uint32)[None, :])     # (spw, σ)
    return ((masks[:, None, :, None] >> p[None, :, None, :])
            & jnp.uint32(1)).astype(jnp.float32)             # (B, spw, 32, σ)


def bvss_spmm_w_ref(masks: jnp.ndarray, xvals: jnp.ndarray, sigma: int = 8
                    ) -> jnp.ndarray:
    """Oracle for kernels.bvss_spmm_w: (B, 32/σ, 32, S) float32 weighted
    pulls — per slice, the sum of its σ column values under the mask."""
    return jnp.einsum("bjli,bis->bjls", _abits(masks, sigma), xvals)


def bvss_spmm_minplus_ref(masks: jnp.ndarray, wvals: jnp.ndarray,
                          xvals: jnp.ndarray, sigma: int = 8) -> jnp.ndarray:
    """Oracle for kernels.bvss_spmm_minplus: (B, 32/σ, 32, S) float32
    tropical pulls — per slice, the min over its masked σ columns of
    (edge weight + column distance), +inf where the slice has no edge."""
    a = _abits(masks, sigma)                             # (B, spw, 32, σ)
    w = jnp.where(a > 0, wvals, jnp.inf)                 # (B, spw, 32, σ)
    return jnp.min(w[..., None] + xvals[:, None, None, :, :], axis=3)


def bvss_spmm_t_ref(masks: jnp.ndarray, hvals: jnp.ndarray, sigma: int = 8
                    ) -> jnp.ndarray:
    """Oracle for kernels.bvss_spmm_t: (B, σ, S) float32 transposed
    products — per slice-set column, the sum of adjacent row values."""
    return jnp.einsum("bjli,bjls->bis", _abits(masks, sigma), hvals)


def bit_spmm_ref(a_packed: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.bit_spmm: Y (R, S) int32 popcounts."""
    R, W = a_packed.shape
    C, S = x.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((a_packed[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1))
    dense = bits.reshape(R, W * 32)[:, :C].astype(jnp.int32)
    return dense @ x.astype(jnp.int32)


def finalize_sweep_ref(marks: jnp.ndarray, levels: jnp.ndarray,
                       lvl) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels.finalize_sweep."""
    new = (marks > 0) & (levels == INF32)
    return jnp.where(new, jnp.int32(lvl), levels), new


def finalize_pack_ref(levels: jnp.ndarray, lvl, *, sigma: int,
                      n_fwords: int, n_sets: int,
                      marks: jnp.ndarray | None = None
                      ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels.finalize_pack_sweep — the inline jnp finalise +
    ``_pack_bits`` + set-reduction passes the fused kernel replaces."""
    if marks is None:                       # eager: scatter-min already ran
        new = levels == jnp.int32(lvl)
        lv_out = levels
    else:                                   # lazy: finalise from byte marks
        new = (marks > 0) & (levels == INF32)
        lv_out = jnp.where(new, jnp.int32(lvl), levels)
    n_pad = n_fwords * 32
    bits = jnp.zeros((n_pad,), dtype=bool).at[:new.shape[0]].set(new)
    b = bits.reshape(n_fwords, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    fwords = jnp.sum(b * weights[None, :], axis=1, dtype=jnp.uint32)
    sbits = jnp.zeros((n_sets * sigma,), dtype=bool).at[:new.shape[0]].set(new)
    set_active = sbits.reshape(n_sets, sigma).any(axis=1)
    return lv_out, fwords, set_active


# ---------------------------------------------------------------------------
# analytics oracles (NetworkX / SciPy / NumPy) — repro.analytics contract
# ---------------------------------------------------------------------------
def _csr_matrix(g):
    import scipy.sparse as sp
    return sp.csr_matrix(
        (np.ones(g.m, dtype=np.int8), g.indices, g.indptr), shape=(g.n, g.n))


def connected_components_ref(g) -> np.ndarray:
    """Weakly-connected component labels via SciPy, normalised so that
    component ids are assigned in order of each component's smallest
    vertex id (the canonical form ``repro.analytics.components`` emits)."""
    from scipy.sparse.csgraph import connected_components
    _, labels = connected_components(_csr_matrix(g), directed=True,
                                     connection="weak")
    return normalize_labels(labels)


def normalize_labels(labels: np.ndarray) -> np.ndarray:
    """Relabel components to 0..k-1 in order of first appearance (labels
    may be arbitrary ints, e.g. union-find roots)."""
    labels = np.asarray(labels)
    _, first, inverse = np.unique(labels, return_index=True,
                                  return_inverse=True)
    order = np.argsort(first)
    remap = np.empty(len(order), dtype=np.int64)
    remap[order] = np.arange(len(order))
    return remap[inverse]


def eccentricity_ref(g, sources) -> np.ndarray:
    """Per-source eccentricity on ``g`` as given (symmetrise first for the
    classical undirected definition): the max *finite* BFS distance, so a
    vertex isolated from the rest of its graph has eccentricity 0."""
    from scipy.sparse.csgraph import dijkstra
    sources = np.asarray(sources, dtype=np.int64)
    dist = dijkstra(_csr_matrix(g), directed=True, unweighted=True,
                    indices=sources)
    dist = np.where(np.isfinite(dist), dist, 0.0)
    return dist.max(axis=1).astype(np.int64)


def closeness_ref(g, sources=None, *, wf_improved: bool = False
                  ) -> np.ndarray:
    """Closeness centrality oracle via SciPy BFS distances: outward
    distances over ``g`` as given (symmetrise first for the classical
    undirected definition) — c(s) = (reach-1)/Σ d(s, ·), 0 for a source
    reaching nothing.  ``sources=None`` evaluates every vertex (the exact
    variant); ``wf_improved`` applies the Wasserman–Faust
    ``(reach-1)/(n-1)`` scaling (NetworkX's default).  Matches NetworkX
    ``closeness_centrality(G.reverse(), wf_improved=...)`` on a DiGraph
    (NetworkX measures INWARD distance) — the analytics test suite
    cross-checks that equivalence."""
    from scipy.sparse.csgraph import dijkstra
    if sources is None:
        sources = np.arange(g.n)
    sources = np.asarray(sources, dtype=np.int64)
    if len(sources) == 0:
        return np.zeros(0, dtype=np.float64)
    dist = dijkstra(_csr_matrix(g), directed=True, unweighted=True,
                    indices=sources)                       # (S, n)
    finite = np.isfinite(dist)
    dist_sum = np.where(finite, dist, 0.0).sum(axis=1)
    reach = finite.sum(axis=1).astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        cc = np.where(dist_sum > 0, (reach - 1) / dist_sum, 0.0)
    if wf_improved and g.n > 1:
        cc = cc * (reach - 1) / (g.n - 1)
    return cc


def betweenness_ref(g, sources) -> np.ndarray:
    """Brandes partial betweenness: Σ_{s∈sources} δ_s(v), unnormalised,
    endpoints excluded — the exact quantity ``repro.analytics.betweenness``
    accumulates (NetworkX's ``betweenness_centrality`` equals this with
    ``sources=range(n)``, ``normalized=False`` on a DiGraph; the analytics
    test suite cross-checks that equivalence)."""
    n = g.n
    indptr, indices = g.indptr, g.indices
    bc = np.zeros(n, dtype=np.float64)
    for s in sources:
        dist = np.full(n, -1, dtype=np.int64)
        sigma = np.zeros(n, dtype=np.float64)
        dist[int(s)] = 0
        sigma[int(s)] = 1.0
        order = [int(s)]
        head = 0
        while head < len(order):
            v = order[head]
            head += 1
            for w in indices[indptr[v]:indptr[v + 1]]:
                w = int(w)
                if dist[w] < 0:
                    dist[w] = dist[v] + 1
                    order.append(w)
                if dist[w] == dist[v] + 1:
                    sigma[w] += sigma[v]
        delta = np.zeros(n, dtype=np.float64)
        for v in reversed(order):
            for w in indices[indptr[v]:indptr[v + 1]]:
                w = int(w)
                if dist[w] == dist[v] + 1:
                    delta[v] += sigma[v] / sigma[w] * (1.0 + delta[w])
        delta[int(s)] = 0.0
        bc += delta
    return bc


def _csr_matrix_w(g, weights: np.ndarray):
    import scipy.sparse as sp
    return sp.csr_matrix(
        (np.asarray(weights, dtype=np.float64), g.indices, g.indptr),
        shape=(g.n, g.n))


def sssp_ref(g, sources, weights: np.ndarray) -> np.ndarray:
    """Single-source shortest-path oracle via SciPy Dijkstra on the
    weighted CSR (directed, ``weights`` in ``g``'s edge order): (S, n)
    float64 distances, +inf for unreachable vertices — the exact quantity
    ``repro.analytics.sssp`` converges to (delta-stepping and Dijkstra
    agree on positive weights)."""
    from scipy.sparse.csgraph import dijkstra
    sources = np.asarray(sources, dtype=np.int64)
    return dijkstra(_csr_matrix_w(g, weights), directed=True,
                    indices=sources)


def pagerank_ref(g, *, damping: float = 0.85, tol: float = 1e-10,
                 weights: np.ndarray | None = None) -> np.ndarray:
    """PageRank oracle via NetworkX on the DiGraph of ``g`` (uniform
    out-edge split unless ``weights`` is given), matching the dangling-
    mass redistribution ``repro.analytics.pagerank`` implements."""
    import networkx as nx
    G = nx.DiGraph()
    G.add_nodes_from(range(g.n))
    src = np.repeat(np.arange(g.n), np.diff(g.indptr))
    w = np.ones(g.m) if weights is None else np.asarray(weights, np.float64)
    G.add_weighted_edges_from(zip(src.tolist(), g.indices.tolist(),
                                  w.tolist()))
    pr = nx.pagerank(G, alpha=damping, tol=tol, max_iter=1000,
                     weight="weight")
    return np.array([pr[v] for v in range(g.n)], dtype=np.float64)
