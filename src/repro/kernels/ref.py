"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax.numpy as jnp

INF32 = jnp.int32(jnp.iinfo(jnp.int32).max)


def bvss_pull_ref(masks: jnp.ndarray, fbytes: jnp.ndarray, sigma: int = 8
                  ) -> jnp.ndarray:
    """Oracle for kernels.bvss_pull: hits (B, 32/σ, 32) bool."""
    spw = 32 // sigma
    smask = jnp.uint32((1 << sigma) - 1)
    fb = fbytes & smask
    fword = jnp.zeros_like(fb)
    for j in range(spw):
        fword = fword | (fb << jnp.uint32(sigma * j))
    anded = masks & fword[:, None]
    hits = []
    for j in range(spw):
        hits.append(((anded >> jnp.uint32(sigma * j)) & smask) != 0)
    return jnp.stack(hits, axis=1)


def bit_spmm_ref(a_packed: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Oracle for kernels.bit_spmm: Y (R, S) int32 popcounts."""
    R, W = a_packed.shape
    C, S = x.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((a_packed[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1))
    dense = bits.reshape(R, W * 32)[:, :C].astype(jnp.int32)
    return dense @ x.astype(jnp.int32)


def finalize_sweep_ref(marks: jnp.ndarray, levels: jnp.ndarray,
                       lvl) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels.finalize_sweep."""
    new = (marks > 0) & (levels == INF32)
    return jnp.where(new, jnp.int32(lvl), levels), new
