"""Pallas TPU kernel for the BVSS pull (paper §4.1, adapted per DESIGN §2.2).

The paper batches 128 Boolean dot products into two m8n8k128 bit-MMA calls
with zero wasted outputs.  The TPU has no bit-MMA; the VPU's (8,128) 32-bit
lanes with native AND + ``population_count`` are the right unit: each 32-bit
lane op resolves ``32/σ`` slice/frontier dot products.  The kernel below
processes TILE VSSs per grid step in the *lane-major* layout — masks stored
transposed ``(32, TILE)`` so the VSS axis occupies the full 128-lane dimension
and all 8 sublanes carry distinct mask words (zero idle lanes: the TPU
restatement of the paper's "all 64 fragC entries useful" rule).

Two layouts are selectable (the row-major one is the naive port and is kept
as the §Perf baseline):

* ``lanes`` (default): masks_t (32, B) u32, hits_t (spw*32, B) int8.
* ``rows``  (baseline): masks (B, 32) u32, hits (B, spw*32) int8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 128


def _fword(fb: jnp.ndarray, sigma: int) -> jnp.ndarray:
    """Replicate the σ-bit frontier byte across all 32/σ sub-words."""
    spw = 32 // sigma
    smask = jnp.uint32((1 << sigma) - 1)
    fb = fb & smask
    out = jnp.zeros_like(fb)
    for j in range(spw):
        out = out | (fb << jnp.uint32(sigma * j))
    return out


def _pull_kernel_lanes(masks_ref, fbytes_ref, hits_ref, *, sigma: int):
    """masks_ref (32, T) u32; fbytes_ref (1, T) u32; hits_ref (spw*32, T) i8."""
    spw = 32 // sigma
    smask = jnp.uint32((1 << sigma) - 1)
    masks = masks_ref[...]                       # (32, T)
    fword = _fword(fbytes_ref[...], sigma)       # (1, T)
    anded = masks & fword                        # broadcast over sublanes
    for j in range(spw):
        sub = (anded >> jnp.uint32(sigma * j)) & smask
        hits_ref[j * 32:(j + 1) * 32, :] = (sub != 0).astype(jnp.int8)


def _pull_kernel_rows(masks_ref, fbytes_ref, hits_ref, *, sigma: int):
    """masks_ref (T, 32) u32; fbytes_ref (T, 1) u32; hits_ref (T, spw*32) i8."""
    spw = 32 // sigma
    smask = jnp.uint32((1 << sigma) - 1)
    masks = masks_ref[...]
    fword = _fword(fbytes_ref[...], sigma)       # (T, 1)
    anded = masks & fword
    for j in range(spw):
        sub = (anded >> jnp.uint32(sigma * j)) & smask
        hits_ref[:, j * 32:(j + 1) * 32] = (sub != 0).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("sigma", "tile", "layout",
                                             "interpret"))
def bvss_pull(masks: jnp.ndarray, fbytes: jnp.ndarray, *, sigma: int = 8,
              tile: int = DEFAULT_TILE, layout: str = "lanes",
              interpret: bool | None = None) -> jnp.ndarray:
    """Pallas BVSS pull.

    masks:  (B, 32) uint32 (row-major BVSS layout; transposed internally for
            the ``lanes`` kernel).
    fbytes: (B,) uint32 frontier bytes (pre-gathered via virtualToReal).
    returns hits (B, spw, 32) bool, hits[b, j, l] for slice k = j*32+l.
    """
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    B = masks.shape[0]
    spw = 32 // sigma
    pad = (-B) % tile
    if pad:
        masks = jnp.pad(masks, ((0, pad), (0, 0)))
        fbytes = jnp.pad(fbytes, (0, pad))
    Bp = B + pad
    grid = (Bp // tile,)

    if layout == "lanes":
        masks_t = masks.T                        # (32, Bp)
        fb = fbytes[None, :]                     # (1, Bp)
        out = pl.pallas_call(
            functools.partial(_pull_kernel_lanes, sigma=sigma),
            grid=grid,
            in_specs=[
                pl.BlockSpec((32, tile), lambda i: (0, i)),
                pl.BlockSpec((1, tile), lambda i: (0, i)),
            ],
            out_specs=pl.BlockSpec((spw * 32, tile), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((spw * 32, Bp), jnp.int8),
            interpret=interpret,
        )(masks_t, fb)
        hits = out.T                             # (Bp, spw*32), k = j*32+l
    elif layout == "rows":
        fb = fbytes[:, None]
        out = pl.pallas_call(
            functools.partial(_pull_kernel_rows, sigma=sigma),
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile, 32), lambda i: (i, 0)),
                pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((tile, spw * 32), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((Bp, spw * 32), jnp.int8),
            interpret=interpret,
        )(masks, fb)
        hits = out
    else:
        raise ValueError(f"unknown layout {layout!r}")

    hits = hits[:B].reshape(B, spw, 32)
    return hits.astype(bool)
