"""Deterministic synthetic LM token pipeline.

A fixed random Markov chain over the vocabulary generates structured
sequences (so cross-entropy actually decreases during the end-to-end
example), seeded per (shard, step) → fully deterministic and restart-safe:
resuming at step k regenerates exactly the batch k stream, which the
checkpoint-restart bit-exactness test relies on.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4        # out-degree of the Markov chain


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig, *, shard: int = 0,
                 num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        rng = np.random.default_rng(cfg.seed)
        # sparse deterministic transition structure
        self.next_tokens = rng.integers(
            0, cfg.vocab, size=(cfg.vocab, cfg.branching)).astype(np.int32)

    def batch(self, step: int) -> np.ndarray:
        """(local_batch, seq_len) int32, deterministic in (step, shard)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, self.shard, 0xB1E57))
        toks = np.empty((self.local_batch, cfg.seq_len), dtype=np.int32)
        cur = rng.integers(0, cfg.vocab, size=self.local_batch).astype(np.int32)
        toks[:, 0] = cur
        branch = rng.integers(0, cfg.branching,
                              size=(self.local_batch, cfg.seq_len - 1))
        for t in range(1, cfg.seq_len):
            cur = self.next_tokens[cur, branch[:, t - 1]]
            toks[:, t] = cur
        return toks

    def __iter__(self) -> Iterator[np.ndarray]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
