"""GNN batch builders: full-graph batches, batched molecular graphs with
triplet lists (DimeNet), and synthetic labels/features — all deterministic.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs import Graph, src_of_edges


@dataclasses.dataclass(frozen=True)
class FullGraphBatch:
    node_feat: np.ndarray     # (N+1, F) zero dummy row
    senders: np.ndarray       # (E,) int32 dummy = N
    receivers: np.ndarray
    labels: np.ndarray        # (N+1,) int32
    train_mask: np.ndarray    # (N+1,) bool


def full_graph_batch(g: Graph, d_feat: int, n_classes: int, *,
                     seed: int = 0, train_frac: float = 0.3
                     ) -> FullGraphBatch:
    rng = np.random.default_rng(seed)
    n = g.n
    feat = np.zeros((n + 1, d_feat), dtype=np.float32)
    labels = np.zeros(n + 1, dtype=np.int32)
    # community-correlated features/labels so training is learnable
    labels[:n] = rng.integers(0, n_classes, n)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feat[:n] = centers[labels[:n]] + 0.5 * rng.normal(
        size=(n, d_feat)).astype(np.float32)
    senders = src_of_edges(g).astype(np.int32)
    receivers = g.indices.astype(np.int32)
    mask = np.zeros(n + 1, dtype=bool)
    mask[:n] = rng.random(n) < train_frac
    return FullGraphBatch(node_feat=feat, senders=senders,
                          receivers=receivers, labels=labels,
                          train_mask=mask)


@dataclasses.dataclass(frozen=True)
class MoleculeBatch:
    """B molecules flattened into one disjoint graph with fixed shapes."""
    species: np.ndarray     # (B*max_n + 1,) int32, dummy last
    pos: np.ndarray         # (B*max_n + 1, 3)
    senders: np.ndarray     # (B*max_e,) dummy = B*max_n
    receivers: np.ndarray
    t_kj: np.ndarray        # (T_cap,) triplet edge ids, dummy = B*max_e
    t_ji: np.ndarray
    graph_ids: np.ndarray   # (B*max_n + 1,) int32, dummy = B
    targets: np.ndarray     # (B,) float32 synthetic energies


def molecule_batch(batch: int, max_nodes: int, max_edges: int, *,
                   n_species: int = 8, cutoff: float = 2.5,
                   triplet_cap_per_graph: int | None = None,
                   seed: int = 0) -> MoleculeBatch:
    rng = np.random.default_rng(seed)
    NB = batch * max_nodes
    EB = batch * max_edges
    t_cap = batch * (triplet_cap_per_graph or 4 * max_edges)
    species = np.zeros(NB + 1, dtype=np.int32)
    pos = np.zeros((NB + 1, 3), dtype=np.float32)
    senders = np.full(EB, NB, dtype=np.int32)
    receivers = np.full(EB, NB, dtype=np.int32)
    graph_ids = np.full(NB + 1, batch, dtype=np.int32)
    t_kj = np.full(t_cap, EB, dtype=np.int32)
    t_ji = np.full(t_cap, EB, dtype=np.int32)
    targets = np.zeros(batch, dtype=np.float32)
    e_ptr = 0
    t_ptr = 0
    for b in range(batch):
        n = rng.integers(max(4, max_nodes // 2), max_nodes + 1)
        base = b * max_nodes
        species[base:base + n] = rng.integers(1, n_species, n)
        p = rng.normal(size=(n, 3)).astype(np.float32) * 1.2
        pos[base:base + n] = p
        graph_ids[base:base + n] = b
        # radius edges (directed both ways)
        d2 = ((p[:, None] - p[None, :]) ** 2).sum(-1)
        ii, jj = np.nonzero((d2 < cutoff ** 2) & (d2 > 1e-9))
        order = rng.permutation(len(ii))[:max_edges]
        ii, jj = ii[order], jj[order]
        e_base = e_ptr
        eids = {}
        for k in range(len(ii)):
            senders[e_ptr] = base + ii[k]
            receivers[e_ptr] = base + jj[k]
            eids[(ii[k], jj[k])] = e_ptr
            e_ptr += 1
        # triplets (k->j, j->i), k != i
        in_edges: dict[int, list] = {}
        for (s, d), eid in eids.items():
            in_edges.setdefault(d, []).append((s, eid))
        for (j, i), e_ji in eids.items():
            for (k, e_kj) in in_edges.get(j, []):
                if k == i:
                    continue
                if t_ptr < t_cap:
                    t_kj[t_ptr] = e_kj
                    t_ji[t_ptr] = e_ji
                    t_ptr += 1
        targets[b] = species[base:base + n].sum() * 0.1 \
            + 0.01 * float(d2[d2 < cutoff ** 2].sum())
        e_ptr = e_base + max_edges  # fixed stride per graph
    return MoleculeBatch(species=species, pos=pos, senders=senders,
                         receivers=receivers, t_kj=t_kj, t_ji=t_ji,
                         graph_ids=graph_ids, targets=targets)


def recsys_batch(batch: int, n_fields: int, rows_per_field: int, *,
                 seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Criteo-like synthetic CTR batch with learnable structure: the label
    correlates with a hidden score of a few 'strong' feature ids."""
    rng = np.random.default_rng(seed)
    # power-law id popularity
    u = rng.random((batch, n_fields))
    ids = np.minimum((rows_per_field * u ** 3).astype(np.int64),
                     rows_per_field - 1).astype(np.int32)
    strength = np.sin(ids[:, :8].sum(axis=1) * 0.37)
    labels = (strength + 0.3 * rng.normal(size=batch) > 0).astype(np.float32)
    return ids, labels
