from .tokens import TokenPipeline, TokenPipelineConfig
from .sampler import NeighborSampler, SampledBatch
from . import graphs

__all__ = ["TokenPipeline", "TokenPipelineConfig", "NeighborSampler",
           "SampledBatch", "graphs"]
