"""Fanout-bounded neighbor sampler for minibatch GNN training — built on the
BLEST BFS substrate (§Arch-applicability, DESIGN §4).

GraphSAGE-style sampling IS fanout-limited BFS frontier expansion: level k
of the BFS from the seed nodes is the k-hop neighbourhood, and the fanout
cap subsamples each frontier pull.  This sampler reuses the framework's
in-CSR view and (like the BLEST queue) tracks the frontier explicitly.

Output is a fixed-shape padded subgraph (dummy node = n_sub) ready for the
segment-sum GNN models.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs import Graph


@dataclasses.dataclass(frozen=True)
class SampledBatch:
    """Fixed-shape sampled subgraph: local ids, dummy node = n_nodes-1 slot
    ``n_sub`` (arrays are sized for it)."""
    node_ids: np.ndarray      # (max_nodes,) global ids, -1 padded
    senders: np.ndarray       # (max_edges,) local ids, dummy = max_nodes
    receivers: np.ndarray     # (max_edges,)
    seed_mask: np.ndarray     # (max_nodes,) bool: the labelled seed nodes
    n_real_nodes: int
    n_real_edges: int


class NeighborSampler:
    def __init__(self, g: Graph, fanouts: tuple[int, ...], *, seed: int = 0):
        self.g = g
        self.fanouts = fanouts
        # sampling pulls from in-neighbours (messages flow src -> dst)
        self.t_indptr, self.t_indices = g.t_csr
        self.rng = np.random.default_rng(seed)

    def _sample_in_neighbors(self, nodes: np.ndarray, fanout: int
                             ) -> tuple[np.ndarray, np.ndarray]:
        """Per node, up to ``fanout`` sampled in-neighbours.
        Returns (srcs, dsts) edge endpoints."""
        srcs, dsts = [], []
        for u in nodes:
            lo, hi = self.t_indptr[u], self.t_indptr[u + 1]
            deg = hi - lo
            if deg == 0:
                continue
            if deg <= fanout:
                nbr = self.t_indices[lo:hi]
            else:
                sel = self.rng.choice(deg, size=fanout, replace=False)
                nbr = self.t_indices[lo + sel]
            srcs.append(nbr.astype(np.int64))
            dsts.append(np.full(len(nbr), u, dtype=np.int64))
        if not srcs:
            return (np.zeros(0, np.int64),) * 2
        return np.concatenate(srcs), np.concatenate(dsts)

    def sample(self, seeds: np.ndarray, *, max_nodes: int, max_edges: int
               ) -> SampledBatch:
        """BFS frontier expansion with per-level fanout caps."""
        seeds = np.asarray(seeds, dtype=np.int64)
        visited = dict((int(u), i) for i, u in enumerate(seeds))
        frontier = seeds
        all_src, all_dst = [], []
        for fanout in self.fanouts:          # one BFS level per fanout entry
            src, dst = self._sample_in_neighbors(frontier, fanout)
            all_src.append(src)
            all_dst.append(dst)
            new = []
            for u in src:                    # next frontier = newly seen
                if int(u) not in visited:
                    visited[int(u)] = len(visited)
                    new.append(u)
            frontier = np.asarray(new, dtype=np.int64)
            if len(frontier) == 0:
                break
        src = np.concatenate(all_src) if all_src else np.zeros(0, np.int64)
        dst = np.concatenate(all_dst) if all_dst else np.zeros(0, np.int64)
        # localise + pad
        node_ids = np.full(max_nodes, -1, dtype=np.int64)
        n_real = min(len(visited), max_nodes)
        inv = {}
        for gid, lid in visited.items():
            if lid < max_nodes:
                node_ids[lid] = gid
                inv[gid] = lid
        keep = np.array([int(s) in inv and int(d) in inv
                         for s, d in zip(src, dst)], dtype=bool) \
            if len(src) else np.zeros(0, bool)
        src_l = np.array([inv[int(s)] for s in src[keep]], dtype=np.int32) \
            if keep.any() else np.zeros(0, np.int32)
        dst_l = np.array([inv[int(d)] for d in dst[keep]], dtype=np.int32) \
            if keep.any() else np.zeros(0, np.int32)
        n_edges = min(len(src_l), max_edges)
        senders = np.full(max_edges, max_nodes, dtype=np.int32)
        receivers = np.full(max_edges, max_nodes, dtype=np.int32)
        senders[:n_edges] = src_l[:n_edges]
        receivers[:n_edges] = dst_l[:n_edges]
        seed_mask = np.zeros(max_nodes, dtype=bool)
        seed_mask[:min(len(seeds), max_nodes)] = True
        return SampledBatch(node_ids=node_ids, senders=senders,
                            receivers=receivers, seed_mask=seed_mask,
                            n_real_nodes=n_real, n_real_edges=n_edges)
