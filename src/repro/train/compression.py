"""Gradient compression for cross-pod all-reduce: int8 quantisation with
error feedback (EF-SGD style — Karimireddy et al. 2019).

At 1000+ node scale the data-parallel all-reduce of bf16 gradients is the
dominant cross-pod collective; int8 + per-tensor scale cuts those bytes 2×
(4× vs fp32) at the cost of quantisation noise, which error feedback folds
back into the next step so convergence is preserved (tested in
tests/test_ft.py::test_compressed_training_converges).

The quantise/dequantise pair wraps the gradient tree *before* the psum; the
residual state lives alongside the optimizer state.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def init_residuals(grads_like) -> Params:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def quantize(g: jnp.ndarray, residual: jnp.ndarray,
             scale: jnp.ndarray | None = None
             ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """g + residual -> (int8 codes, scale, new residual).  ``scale`` may be
    supplied externally (the replica-shared scale for collective use)."""
    x = g.astype(jnp.float32) + residual
    if scale is None:
        scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, x - deq


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residuals):
    """Returns (codes tree, scales tree, new residuals tree)."""
    out = jax.tree_util.tree_map(quantize, grads, residuals)
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), pick(1), pick(2)


def decompress_tree(codes, scales):
    return jax.tree_util.tree_map(dequantize, codes, scales)


def compressed_psum(grads, residuals, axis_names):
    """Quantise -> psum(int32 accumulate) -> dequantise -> mean.

    Must run inside shard_map/pmap over ``axis_names``.  All replicas first
    agree on a shared per-tensor scale (a scalar pmax — negligible bytes),
    then quantise with it: summing int8 codes in int32 is exact, and
    dequantising the sum with the shared scale is exact too (the only error
    is per-replica rounding, which error feedback carries forward).
    """
    from repro.distributed.collectives import axis_size

    n = 1
    for ax in axis_names:
        n = n * axis_size(ax)

    def reduce_one(g, r):
        local = g.astype(jnp.float32) + r
        s = jax.lax.pmax(jnp.max(jnp.abs(local)), axis_names) / 127.0 + 1e-12
        q, _, new_r = quantize(g, r, scale=s)
        q32 = jax.lax.psum(q.astype(jnp.int32), axis_names)
        return q32.astype(jnp.float32) * s / n, new_r

    out = jax.tree_util.tree_map(reduce_one, grads, residuals)
    mean = jax.tree_util.tree_map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree_util.tree_map(lambda t: t[1], out,
                                     is_leaf=lambda t: isinstance(t, tuple))
    return mean, new_res
