from . import optim, compression
from .loop import TrainConfig, TrainState, init_train_state, make_train_step, train

__all__ = ["optim", "compression", "TrainConfig", "TrainState",
           "init_train_state", "make_train_step", "train"]
