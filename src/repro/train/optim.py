"""Optimizers (no optax installed — implemented here): AdamW, SGD-momentum,
Adafactor-lite; LR schedules; global-norm clipping.

AdamW supports reduced-precision moments (``moment_dtype=bfloat16``) — the
DeepSeek-V3 recipe this framework uses to fit the 671B config in
16 GB/chip (DESIGN §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1) -> Callable:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return fn


def constant_lr(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32
    max_grad_norm: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Params
    v: Params


def adamw_init(params, cfg: AdamWConfig) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree_util.tree_map(zeros, params),
                    v=jax.tree_util.tree_map(zeros, params))


def adamw_update(grads, state: OptState, params, cfg: AdamWConfig,
                 lr: jnp.ndarray):
    grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return (new_p.astype(p.dtype), m32.astype(cfg.moment_dtype),
                v32.astype(cfg.moment_dtype))

    out = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
    new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return new_p, OptState(step=step, m=new_m, v=new_v), gnorm


# ---------------------------------------------------------------------------
# SGD momentum (baseline optimizer)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SGDConfig:
    momentum: float = 0.9
    max_grad_norm: float = 1.0


class SGDState(NamedTuple):
    step: jnp.ndarray
    mom: Params


def sgd_init(params, cfg: SGDConfig) -> SGDState:
    return SGDState(step=jnp.zeros((), jnp.int32),
                    mom=jax.tree_util.tree_map(
                        lambda p: jnp.zeros_like(p, jnp.float32), params))


def sgd_update(grads, state: SGDState, params, cfg: SGDConfig,
               lr: jnp.ndarray):
    grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)

    def upd(g, m, p):
        m32 = cfg.momentum * m + g.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * m32).astype(p.dtype), m32

    out = jax.tree_util.tree_map(upd, grads, state.mom, params)
    new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return new_p, SGDState(step=state.step + 1, mom=new_m), gnorm


# ---------------------------------------------------------------------------
# Adafactor-lite: factored second moment for giant embedding tables
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0
    max_grad_norm: float = 1.0


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Params   # row second moments (or full for <2-D leaves)
    vc: Params   # col second moments (zeros for <2-D leaves)


def adafactor_init(params, cfg: AdafactorConfig) -> AdafactorState:
    def rows(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if p.ndim >= 2 \
            else jnp.zeros(p.shape, jnp.float32)

    def cols(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
            if p.ndim >= 2 else jnp.zeros((1,), jnp.float32)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree_util.tree_map(rows, params),
                          vc=jax.tree_util.tree_map(cols, params))


def adafactor_update(grads, state: AdafactorState, params,
                     cfg: AdafactorConfig, lr: jnp.ndarray):
    grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
    d = cfg.decay

    def upd(g, vr, vc, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + cfg.eps
        if p.ndim >= 2:
            nvr = d * vr + (1 - d) * g2.mean(axis=-1)
            nvc = d * vc + (1 - d) * g2.mean(axis=-2)
            denom = (nvr[..., None] * nvc[..., None, :]
                     / jnp.maximum(nvr.mean(axis=-1, keepdims=True)[..., None],
                                   cfg.eps))
            update = g32 / jnp.sqrt(jnp.maximum(denom, cfg.eps))
        else:
            nvr = d * vr + (1 - d) * g2
            nvc = vc
            update = g32 / jnp.sqrt(jnp.maximum(nvr, cfg.eps))
        rms = jnp.sqrt(jnp.mean(update * update) + 1e-12)
        update = update / jnp.maximum(1.0, rms / cfg.clip_threshold)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), nvr, nvc

    out = jax.tree_util.tree_map(upd, grads, state.vr, state.vc, params)
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), AdafactorState(state.step + 1, pick(1), pick(2)), gnorm


OPTIMIZERS = {
    "adamw": (AdamWConfig, adamw_init, adamw_update),
    "sgd": (SGDConfig, sgd_init, sgd_update),
    "adafactor": (AdafactorConfig, adafactor_init, adafactor_update),
}
