"""Train-step factory + host training loop with fault tolerance.

The step factory builds a jitted ``step(state, batch) -> (state, metrics)``
from an arbitrary ``loss_fn(params, batch)``, with:
  * microbatch gradient accumulation (``accum_steps`` via lax.scan),
  * any optimizer from train/optim.py,
  * optional donation of the input state (in-place update on device).

The host loop wires in the substrate: prefetch queue with straggler
mitigation, failure injection, async checkpointing, restart-safe resume.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ft.checkpoint import AsyncCheckpointer, restore_latest
from repro.ft.manager import FailureInjector, PrefetchQueue
from repro.train import optim


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    optimizer: str = "adamw"
    peak_lr: float = 3e-4
    warmup: int = 10
    accum_steps: int = 1
    log_every: int = 10
    ckpt_every: int = 0          # 0 = disabled
    ckpt_dir: str = ""
    keep_ckpts: int = 3
    max_grad_norm: float = 1.0
    moment_dtype: Any = jnp.float32
    grad_dtype: Any = None       # cast local grads pre-reduction (bf16
                                 # halves the DP all-reduce bytes)


def make_optimizer(tcfg: TrainConfig):
    name = tcfg.optimizer
    if name == "adamw":
        ocfg = optim.AdamWConfig(max_grad_norm=tcfg.max_grad_norm,
                                 moment_dtype=tcfg.moment_dtype)
    elif name == "sgd":
        ocfg = optim.SGDConfig(max_grad_norm=tcfg.max_grad_norm)
    elif name == "adafactor":
        ocfg = optim.AdafactorConfig(max_grad_norm=tcfg.max_grad_norm)
    else:
        raise ValueError(name)
    _, init_fn, update_fn = optim.OPTIMIZERS[name]
    lr_fn = optim.warmup_cosine(tcfg.peak_lr, tcfg.warmup, tcfg.steps)
    return ocfg, init_fn, update_fn, lr_fn


def init_train_state(params, tcfg: TrainConfig) -> TrainState:
    ocfg, init_fn, _, _ = make_optimizer(tcfg)
    # copy: the step function donates its state, which must not consume the
    # caller's params (restart managers re-init from them)
    params = jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True), params)
    return TrainState(params=params, opt_state=init_fn(params, ocfg),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(loss_fn: Callable, tcfg: TrainConfig, *,
                    donate: bool = True, jit: bool = True) -> Callable:
    ocfg, _, update_fn, lr_fn = make_optimizer(tcfg)

    def step(state: TrainState, batch):
        if tcfg.accum_steps > 1:
            micro = jax.tree_util.tree_map(
                lambda b: b.reshape(tcfg.accum_steps,
                                    b.shape[0] // tcfg.accum_steps,
                                    *b.shape[1:]), batch)

            def acc(carry, mb):
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
                return (carry[0] + loss,
                        jax.tree_util.tree_map(jnp.add, carry[1], grads)), None

            zero = (jnp.zeros(()),
                    jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32),
                        state.params))
            from repro.models.layers import unroll_enabled
            (loss, grads), _ = jax.lax.scan(
                acc, zero, micro, unroll=True if unroll_enabled() else 1)
            loss = loss / tcfg.accum_steps
            grads = jax.tree_util.tree_map(
                lambda g: g / tcfg.accum_steps, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        if tcfg.grad_dtype is not None:
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(tcfg.grad_dtype), grads)
        lr = lr_fn(state.step)
        params, opt_state, gnorm = update_fn(grads, state.opt_state,
                                             state.params, ocfg, lr)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    if jit:
        return jax.jit(step, donate_argnums=(0,) if donate else ())
    return step


@dataclasses.dataclass
class RunResult:
    final_state: TrainState
    losses: list
    straggler_timeouts: int = 0


def train(loss_fn: Callable, init_params, batch_fn: Callable[[int], Any],
          tcfg: TrainConfig, *,
          injector: FailureInjector | None = None,
          prefetch_timeout_s: float = 30.0,
          log_fn: Callable[[str], None] = print) -> RunResult:
    """Host training loop; resumes from tcfg.ckpt_dir if checkpoints exist.

    ``batch_fn(step)`` must be deterministic in ``step`` (restart safety);
    it doubles as the straggler backup batch source.
    """
    step_fn = make_train_step(loss_fn, tcfg)
    state = init_train_state(init_params, tcfg)
    start = 0
    ckpt = None
    if tcfg.ckpt_every and tcfg.ckpt_dir:
        ckpt = AsyncCheckpointer(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
        restored = restore_latest(tcfg.ckpt_dir, state)
        if restored is not None:
            state, manifest = restored
            start = int(manifest["step"])
            log_fn(f"[train] resumed from step {start}")

    q = PrefetchQueue((batch_fn(s) for s in range(start, tcfg.steps)),
                      timeout_s=prefetch_timeout_s, backup_fn=batch_fn)
    losses = []
    t0 = time.time()
    for step in range(start, tcfg.steps):
        batch = q.get(step)
        if injector is not None:
            injector.check(step)
        state, metrics = step_fn(state, batch)
        if (step + 1) % tcfg.log_every == 0 or step + 1 == tcfg.steps:
            loss = float(metrics["loss"])
            losses.append((step + 1, loss))
            log_fn(f"[train] step {step + 1}/{tcfg.steps} "
                   f"loss={loss:.4f} gnorm={float(metrics['grad_norm']):.3f} "
                   f"({(time.time() - t0):.1f}s)")
        if ckpt is not None and (step + 1) % tcfg.ckpt_every == 0:
            ckpt.save(step + 1, state)
    if ckpt is not None:
        ckpt.wait()
    return RunResult(final_state=state, losses=losses,
                     straggler_timeouts=q.stats.timeouts)
