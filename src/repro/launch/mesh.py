"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The multi-pod mesh adds a
leading "pod" axis; DP shards batch over ("pod", "data"), TP/EP over
"model", and the optional pipeline wrapper stages over "pod".
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (requires
    --xla_force_host_platform_device_count >= n_data*n_model)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def mesh_axes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a production mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    sizes = mesh_axes(mesh)
    out = 1
    for a in dp_axes(mesh):
        out *= sizes[a]
    return out
