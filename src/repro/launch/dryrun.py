import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax-importing module: jax locks the device count at
# first init.  setdefault so tests can request a smaller host platform.

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs.base import all_archs, get_arch   # noqa: E402
from repro.configs.families import build_cell        # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402

"""Multi-pod dry run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline terms (DESIGN §5).

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k --mesh multi

Artifacts: one JSON per cell under artifacts/dryrun/<mesh>/.
"""

# v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "s4": 1, "u4": 1}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-payload bytes of every collective in the (post-SPMD,
    per-device) optimized HLO.  Wire-byte convention: ring all-reduce moves
    ~2x its payload; the others ~1x (documented in EXPERIMENTS.md)."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for m in _COLL_RE.finditer(hlo_text):
        ty, op, _start = m.group(1), m.group(2), m.group(3)
        out[op] += _shape_bytes(ty)
        out["count"] += 1
    out["wire_bytes"] = (2 * out["all-reduce"] + out["all-gather"]
                         + out["reduce-scatter"] + out["all-to-all"]
                         + out["collective-permute"])
    return out


def _mem_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_bytes_per_device"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0))
    return out


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float) -> dict:
    """Per-device seconds for each roofline term (v5e)."""
    return {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": wire_bytes / ICI_BW,
    }


def _probe_metrics(c, mesh):
    with mesh:
        comp = c.lower(unroll=True).compile()
    cost = comp.cost_analysis()
    coll = collective_bytes(comp.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def _probe_costs(cell, mesh):
    """Compile the probe twins unrolled and extrapolate every cost metric.

    linear:   two layer counts; cost(L) = a + cL.
    bilinear: (layers x accum) grid; cost(L, A) = a + bA + cL + dAL —
    exact because layers (within a group) and microbatches are
    HLO-identical repetitions."""
    kind = cell.probe[0]
    if kind == "linear":
        _, c1, c2, l1, l2, lf = cell.probe
        f1, h1, k1 = _probe_metrics(c1, mesh)
        f2, h2, k2 = _probe_metrics(c2, mesh)
        scale = (lf - l1) / (l2 - l1)
        ext = lambda a, b: a + (b - a) * scale
        coll = {k: int(ext(k1[k], k2[k])) for k in k1}
        return ext(f1, f2), ext(h1, h2), coll
    _, cells, (l1, l2), (a1, a2), (lf, af) = cell.probe
    m = [_probe_metrics(c, mesh) for c in cells]  # order: (l1,a1)(l1,a2)(l2,a1)(l2,a2)

    def ext(c11, c12, c21, c22):
        d = (c22 - c21 - c12 + c11) / ((l2 - l1) * (a2 - a1))
        cc = (c21 - c11) / (l2 - l1) - d * a1
        b = (c12 - c11) / (a2 - a1) - d * l1
        a = c11 - b * a1 - cc * l1 - d * a1 * l1
        return a + b * af + cc * lf + d * af * lf

    flops = ext(m[0][0], m[1][0], m[2][0], m[3][0])
    hbm = ext(m[0][1], m[1][1], m[2][1], m[3][1])
    coll = {k: int(max(0, ext(m[0][2][k], m[1][2][k], m[2][2][k],
                             m[3][2][k]))) for k in m[0][2]}
    return flops, hbm, coll


def _make_mesh(mesh_name: str, small: bool):
    if small:
        return (jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
                if mesh_name == "multi"
                else jax.make_mesh((2, 4), ("data", "model")))
    return make_production_mesh(multi_pod=(mesh_name == "multi"))


def run_cell(arch_id: str, shape_name: str, mesh_name: str, *,
             out_dir: str = "artifacts/dryrun", small: bool = False,
             arch_obj=None) -> dict:
    """``arch_obj`` overrides the registered spec (perf-variant runs)."""
    arch = arch_obj if arch_obj is not None else get_arch(arch_id)
    mesh = _make_mesh(mesh_name, small)
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "mesh_shape": list(mesh.devices.shape),
           "axes": list(mesh.axis_names)}
    if shape_name in arch.skip_shapes:
        rec["status"] = "skipped"
        rec["reason"] = arch.skip_shapes[shape_name]
        _write(rec, out_dir)
        return rec
    t0 = time.time()
    try:
        cell = build_cell(arch, shape_name, mesh)
        rec["desc"] = cell.static_desc
        # pass 1 (canonical): scan-over-layers program — this is the
        # executable artifact; proves compile + gives memory analysis.
        with mesh:
            lowered = cell.lower()
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
        mem = _mem_stats(compiled)
        rec.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "scan_flops_per_device": float(cost.get("flops", 0.0)),
            "scan_hbm_bytes_per_device": float(cost.get("bytes accessed",
                                                        0.0)),
            "scan_collectives": coll,
            "memory": mem,
        })
        # pass 2 (analysis): unrolled program — XLA cost analysis counts a
        # while body ONCE, so the canonical pass undercounts flops/bytes/
        # collectives by the trip counts; the unrolled pass is exact.
        if (mesh_name == "multi" or os.environ.get("REPRO_SCAN_ONLY")) \
                and cell.has_loops:
            # multi-pod pass proves the pod axis shards; the roofline table
            # is single-pod only (spec) — skip the costly unrolled pass
            rec["cost_source"] = "scan-only (roofline is single-pod)"
            flops = rec["scan_flops_per_device"]
            hbm = rec["scan_hbm_bytes_per_device"]
            coll_u = coll
        elif not cell.has_loops:
            rec["cost_source"] = "exact (no internal loops)"
            flops = rec["scan_flops_per_device"]
            hbm = rec["scan_hbm_bytes_per_device"]
            coll_u = coll
        else:
          try:
            t3 = time.time()
            if cell.probe is not None:
                # two reduced-layer unrolled twins + linear extrapolation
                # (exact: layers within a group are HLO-identical)
                flops, hbm, coll_u = _probe_costs(cell, mesh)
                rec["cost_source"] = "unrolled-probe-extrapolated"
            else:
                with mesh:
                    comp_u = cell.lower(unroll=True).compile()
                cost_u = comp_u.cost_analysis()
                coll_u = collective_bytes(comp_u.as_text())
                flops = float(cost_u.get("flops", 0.0))
                hbm = float(cost_u.get("bytes accessed", 0.0))
                rec["cost_source"] = "unrolled"
            rec["unrolled_compile_s"] = round(time.time() - t3, 2)
          except Exception as e:  # fall back to canonical numbers
            rec["unrolled_error"] = f"{type(e).__name__}: {e}"
            flops = rec["scan_flops_per_device"]
            hbm = rec["scan_hbm_bytes_per_device"]
            coll_u = coll
            rec["cost_source"] = "scan(UNDERCOUNTS loop bodies)"
        rec["flops_per_device"] = flops
        rec["hbm_bytes_per_device"] = hbm
        rec["collectives"] = coll_u
        rec["roofline"] = roofline_terms(flops, hbm, coll_u["wire_bytes"])
        terms = rec["roofline"]
        rec["dominant"] = max(terms, key=terms.get)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    _write(rec, out_dir)
    return rec


def _write(rec: dict, out_dir: str):
    d = os.path.join(out_dir, rec["mesh"])
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{rec['arch']}__{rec['shape']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--small", action="store_true",
                    help="tiny shakeout mesh instead of the production one")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in all_archs():
            for shape_name in arch.shapes:
                cells.append((arch.id, shape_name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for arch_id, shape_name in cells:
        rec = run_cell(arch_id, shape_name, args.mesh, out_dir=args.out,
                       small=args.small)
        status = rec["status"]
        n_ok += status == "ok"
        n_skip += status == "skipped"
        n_err += status == "error"
        if status == "ok":
            t = rec["roofline"]
            print(f"[{args.mesh}] {arch_id:18s} {shape_name:14s} OK "
                  f"compile={rec['compile_s']:7.1f}s "
                  f"comp={t['compute_s']:.2e}s mem={t['memory_s']:.2e}s "
                  f"coll={t['collective_s']:.2e}s dom={rec['dominant']}",
                  flush=True)
        elif status == "skipped":
            print(f"[{args.mesh}] {arch_id:18s} {shape_name:14s} SKIP "
                  f"({rec['reason'][:60]})", flush=True)
        else:
            print(f"[{args.mesh}] {arch_id:18s} {shape_name:14s} ERROR "
                  f"{rec['error'][:160]}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_err} errors", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
