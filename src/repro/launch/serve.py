"""Serving launcher: continuous-batching generation with a smoke-config LM.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        --requests 16 --max-new 12

The LM tier (``Request``/``ServeEngine``) is deliberately OUTSIDE the
graph façade contract (``import repro``; see ``tests/test_api_surface.py``)
— the stable surface covers the graph-analytics serving stack; this
launcher reaches into ``repro.serve`` for the text-generation half.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.serve import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    assert arch.family == "lm", "serving applies to LM archs"
    cfg = arch.smoke_cfg
    params = T.init_lm(jax.random.PRNGKey(args.seed), cfg)
    eng = ServeEngine(params, cfg, max_batch=args.max_batch,
                      max_len=args.prompt_len + args.max_new + 8,
                      prompt_len=args.prompt_len)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab,
                                        rng.integers(4, args.prompt_len + 1)),
                    max_new_tokens=args.max_new)
            for _ in range(args.requests)]
    t0 = time.time()
    outs = eng.run(reqs)
    dt = time.time() - t0
    new_tokens = sum(len(o.tokens) for o in outs) - sum(
        min(len(r.prompt), args.prompt_len) for r in reqs)
    print(f"[serve] arch={arch.id}(smoke) served {len(outs)} requests, "
          f"{new_tokens} new tokens in {dt:.2f}s "
          f"({new_tokens / dt:.1f} tok/s, continuous batching over "
          f"{args.max_batch} slots)")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i}: ...{o.tokens[-8:]}")


if __name__ == "__main__":
    main()
