"""Training launcher: ``--arch <id>`` with reduced (smoke) or full configs.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt

On this CPU container only smoke configs are executable; the full configs
are exercised through the dry-run (launch/dryrun.py).  The launcher wires
the full substrate: deterministic data pipeline, AdamW, checkpoint/restart,
straggler-tolerant prefetch, optional failure injection (chaos drill).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch
from repro.configs.families import _gnn_init_and_axes, _gnn_single_loss
from repro.data import TokenPipeline, TokenPipelineConfig
from repro.data.graphs import full_graph_batch, molecule_batch, recsys_batch
from repro.ft.manager import FailureInjector, RestartManager
from repro.graphs import generators as gen
from repro.models import fm as FM
from repro.models import transformer as T
from repro.train import TrainConfig, train


def lm_setup(arch, cfg, args):
    params = T.init_lm(jax.random.PRNGKey(args.seed), cfg)
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.batch,
        seed=args.seed))
    loss_fn = lambda p, b: T.lm_loss(p, cfg, jnp.asarray(b),
                                     compute_dtype=jnp.float32, remat=False)
    return params, loss_fn, pipe.batch


def gnn_setup(arch, cfg, args):
    import dataclasses
    arch = dataclasses.replace(arch, model_cfg=cfg)
    init_fn, _ = _gnn_init_and_axes(arch)
    params = init_fn(jax.random.PRNGKey(args.seed))
    loss1 = _gnn_single_loss(arch, remat=False)
    if arch.gnn_kind == "gin":
        g = gen.rmat(9, 8, seed=args.seed)
        fb = full_graph_batch(g, cfg.d_in, cfg.n_classes, seed=args.seed)
        batch = {"node_feat": jnp.asarray(fb.node_feat),
                 "senders": jnp.asarray(fb.senders),
                 "receivers": jnp.asarray(fb.receivers),
                 "labels": jnp.asarray(fb.labels),
                 "train_mask": jnp.asarray(fb.train_mask)}
        return params, loss1, lambda step: batch
    # molecular batches, regenerated per step (deterministic in step)
    def batch_fn(step):
        mb = molecule_batch(args.batch, 12, 32,
                            n_species=getattr(cfg, "n_species", 8),
                            seed=args.seed * 100_003 + step)
        b = {"species": jnp.asarray(mb.species), "pos": jnp.asarray(mb.pos),
             "senders": jnp.asarray(mb.senders),
             "receivers": jnp.asarray(mb.receivers),
             "graph_ids": jnp.asarray(mb.graph_ids),
             "targets": jnp.asarray(mb.targets)}
        if arch.gnn_kind == "dimenet":
            b["t_kj"] = jnp.asarray(mb.t_kj)
            b["t_ji"] = jnp.asarray(mb.t_ji)
        if arch.gnn_kind == "egnn":
            d_in = cfg.d_in
            feat = jax.nn.one_hot(mb.species % d_in, d_in)
            b["node_feat"] = feat
            del b["species"]
        return b

    def loss_graphids(p, b):
        return loss1(p, b)

    return params, loss_graphids, batch_fn


def rec_setup(arch, cfg, args):
    params = FM.init_fm(jax.random.PRNGKey(args.seed), cfg)

    def batch_fn(step):
        ids, labels = recsys_batch(args.batch, cfg.n_fields,
                                   cfg.rows_per_field,
                                   seed=args.seed * 7 + step)
        return {"ids": jnp.asarray(ids), "labels": jnp.asarray(labels)}

    loss_fn = lambda p, b: FM.fm_loss(p, cfg, b["ids"], b["labels"])
    return params, loss_fn, batch_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a failure at this step (chaos drill)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.smoke_cfg if args.smoke else arch.model_cfg
    setup = {"lm": lm_setup, "gnn": gnn_setup, "recsys": rec_setup}
    params, loss_fn, batch_fn = setup[arch.family](arch, cfg, args)
    n_params = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(params))
    print(f"[train] arch={arch.id} family={arch.family} params={n_params:,}")

    tcfg = TrainConfig(steps=args.steps, peak_lr=args.lr,
                       warmup=max(2, args.steps // 20),
                       log_every=max(1, args.steps // 10),
                       ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir)
    injector = (FailureInjector((args.fail_at,))
                if args.fail_at >= 0 else None)

    def body(resume):
        return train(loss_fn, params, batch_fn, tcfg, injector=injector)

    if injector is not None:
        mgr = RestartManager(max_restarts=3)
        result = mgr.run(body)
        print(f"[train] survived {mgr.stats.restarts} injected failure(s)")
    else:
        result = body(0)
    first, last = result.losses[0][1], result.losses[-1][1]
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"(straggler timeouts: {result.straggler_timeouts})")
    return result


if __name__ == "__main__":
    main()
