"""Analytics workload launcher — the DESIGN §2.6 suite end to end.

    PYTHONPATH=src python -m repro.launch.analytics --graph rmat --scale 10 \
        --what components,extremes,betweenness --verify

Builds a :class:`repro.serve.GraphSession` (the ONE prepared pipeline) and
serves the requested analytics query kinds off its wave slot pool:
``components`` (flood-fill re-seeding), ``eccentricity`` (a sampled batch),
``extremes`` (iFUB diameter/radius), ``betweenness`` (sampled-source
Brandes), ``closeness`` (sampled closeness by wave level-channel
reduction), ``sssp`` (delta-stepping shortest paths over the min-plus
tiles with random edge weights) and ``pagerank`` (fused power iteration,
DESIGN §2.9).  ``--verify`` checks every result against the independent
NetworkX/SciPy/NumPy oracles in ``repro.kernels.ref``.

``--devices N`` serves through a row-sharded session — EVERY verb rides
the shard_map'd wave surface, betweenness' weighted sweeps included
(mesh-native forward σ channel + psum-scattered backward, DESIGN §2.6).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.errors import KernelFaultError
from repro.launch.bfs import build_graph, ensure_devices

WHAT = ("components", "eccentricity", "extremes", "betweenness",
        "closeness", "sssp", "pagerank")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat",
                    choices=["rmat", "urand", "road", "clustered"])
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--what", default=",".join(WHAT),
                    help=f"comma-separated subset of {WHAT}")
    ap.add_argument("--sources", type=int, default=8,
                    help="sample size for eccentricity / betweenness")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="wave slot-pool width (stacked bit-SpMM columns)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="check every result against the NetworkX/SciPy/"
                         "NumPy oracles (--no-verify for timing runs)")
    ap.add_argument("--devices", type=int, default=1,
                    help="row-shard the session over an N-device 1-D mesh "
                         "(simulated on CPU; the process re-execs once)")
    args = ap.parse_args(argv)

    what = [w.strip() for w in args.what.split(",") if w.strip()]
    unknown = set(what) - set(WHAT)
    if unknown:
        ap.error(f"unknown --what entries {sorted(unknown)}")

    mesh = ensure_devices(args.devices, argv,
                          module="repro.launch.analytics")
    g = build_graph(args.graph, args.scale, args.seed)
    from repro import GraphSession, PrepareOptions
    weights = None
    if "sssp" in what:
        # dyadic rationals: f32 path sums are exact, so --verify can
        # demand bit-parity with the float64 Dijkstra oracle
        wrng = np.random.default_rng(args.seed + 1)
        weights = (wrng.integers(1, 128, g.m) / 32.0).astype(np.float32)
    sess = GraphSession(g, max_batch=args.max_batch,
                        options=PrepareOptions(w=512, seed=args.seed,
                                               mesh=mesh, weights=weights))
    print(f"[analytics] graph={args.graph} n={g.n} m={g.m} "
          f"ordering={sess.ordering} engine={sess.engine_name} "
          f"max_batch={sess.max_batch}"
          + (f" mesh={args.devices}x1" if mesh is not None else ""))
    rng = np.random.default_rng(args.seed)

    if "components" in what:
        t0 = time.time()
        labels = sess.components()
        dt = time.time() - t0
        k = int(labels.max()) + 1 if len(labels) else 0
        sizes = np.bincount(labels)
        line = (f"[analytics] components: k={k} "
                f"largest={int(sizes.max())}/{g.n} in {dt * 1e3:.1f}ms")
        if args.verify:
            from repro.kernels.ref import connected_components_ref
            if not (labels == connected_components_ref(g)).all():
                raise KernelFaultError(
                    "components diverge from the SciPy oracle")
            line += "; VERIFIED vs scipy"
        print(line)

    if "eccentricity" in what:
        srcs = rng.integers(0, g.n, args.sources)
        t0 = time.time()
        eccs = sess.eccentricity_batch(srcs)
        dt = time.time() - t0
        line = (f"[analytics] eccentricity: {len(srcs)} sources, "
                f"range [{eccs.min()}, {eccs.max()}] in {dt * 1e3:.1f}ms")
        if args.verify:
            from repro.kernels.ref import eccentricity_ref
            ref = eccentricity_ref(g.symmetrized, srcs)
            if not (eccs == ref).all():
                raise KernelFaultError(
                    "eccentricity diverges from the oracle")
            line += "; VERIFIED vs scipy"
        print(line)

    if "extremes" in what:
        t0 = time.time()
        rep = sess.extremes()
        dt = time.time() - t0
        print(f"[analytics] extremes (iFUB): diameter="
              f"[{rep.diameter_lb}, {rep.diameter_ub}] "
              f"{'EXACT' if rep.exact else 'bounds'} "
              f"radius<={rep.radius_ub} center={rep.center} "
              f"periphery={rep.periphery} "
              f"({rep.n_ecc_evals} ecc evals / {g.n} vertices) "
              f"in {dt * 1e3:.1f}ms")

    if "betweenness" in what:
        t0 = time.time()
        srcs, bc = sess.betweenness_sample(args.sources, seed=args.seed)
        dt = time.time() - t0
        top = np.argsort(-bc)[:5]
        line = (f"[analytics] betweenness ({len(srcs)} pivots): top "
                f"{[(int(v), round(float(bc[v]), 1)) for v in top]} "
                f"in {dt * 1e3:.1f}ms")
        if args.verify:
            from repro.kernels.ref import betweenness_ref
            ref = betweenness_ref(g, srcs)
            np.testing.assert_allclose(bc, ref, rtol=1e-4, atol=1e-4)
            line += "; VERIFIED vs Brandes oracle"
        print(line)

    if "closeness" in what:
        srcs = rng.integers(0, g.n, args.sources)
        t0 = time.time()
        cc = sess.closeness_batch(srcs)
        dt = time.time() - t0
        line = (f"[analytics] closeness: {len(srcs)} sources, "
                f"range [{cc.min():.4f}, {cc.max():.4f}] "
                f"in {dt * 1e3:.1f}ms")
        if args.verify:
            from repro.kernels.ref import closeness_ref
            np.testing.assert_allclose(cc, closeness_ref(g, srcs),
                                       rtol=1e-9)
            line += "; VERIFIED vs scipy"
        print(line)

    if "sssp" in what:
        srcs = rng.integers(0, g.n, min(args.sources, g.n))
        t0 = time.time()
        dist = sess.sssp_batch(srcs)
        dt = time.time() - t0
        reached = np.isfinite(dist).sum(axis=1)
        line = (f"[analytics] sssp (delta-stepping): {len(srcs)} sources, "
                f"mean reached {reached.mean():.0f}/{g.n} "
                f"in {dt * 1e3:.1f}ms")
        if args.verify:
            from repro.kernels.ref import sssp_ref
            ref = sssp_ref(g, srcs, weights)
            if not (np.array_equal(np.isinf(dist), np.isinf(ref))
                    and np.allclose(np.where(np.isinf(dist), 0.0, dist),
                                    np.where(np.isinf(ref), 0.0, ref),
                                    rtol=1e-6)):
                raise KernelFaultError(
                    "sssp diverges from the SciPy Dijkstra oracle")
            line += "; VERIFIED vs scipy"
        print(line)

    if "pagerank" in what:
        t0 = time.time()
        pr = sess.pagerank(tol=1e-10, max_iter=500)
        dt = time.time() - t0
        top = np.argsort(-pr)[:5]
        line = (f"[analytics] pagerank: sum={pr.sum():.6f} top "
                f"{[(int(v), round(float(pr[v]), 5)) for v in top]} "
                f"in {dt * 1e3:.1f}ms")
        if args.verify:
            from repro.kernels.ref import pagerank_ref
            ref = pagerank_ref(g)
            rel = np.max(np.abs(pr - ref) / np.maximum(np.abs(ref), 1e-30))
            if rel > 1e-6:
                raise KernelFaultError(
                    f"pagerank diverges from the NetworkX oracle "
                    f"(max rel err {rel:.2e})")
            line += "; VERIFIED vs networkx"
        print(line)


if __name__ == "__main__":
    main()
