"""BFS workload launcher — the paper's own pipeline, end to end.

    PYTHONPATH=src python -m repro.launch.bfs --graph rmat --scale 12 \
        --engine blest_full --sources 8

Pipeline per the paper: classify the graph (social-like?), pick the
ordering (JaccardWithWindows+shingle vs RCM), build the BVSS, run the fused
BFS engine, verify against the host oracle.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import build_bvss, make_engine, reference_bfs
from repro.core.ordering import auto_order, social_like_report
from repro.graphs import generators as gen


def build_graph(name: str, scale: int, seed: int = 0):
    if name == "rmat":
        return gen.rmat(scale, 16, seed=seed)
    if name == "urand":
        return gen.erdos_renyi(1 << scale, 16.0, seed=seed)
    if name == "road":
        side = int((1 << scale) ** 0.5)
        return gen.grid2d(side, side, shuffle=True, seed=seed)
    if name == "clustered":
        return gen.clustered((1 << scale) // 64, 64, seed=seed)
    raise ValueError(name)


ENGINE_VARIANTS = {
    # paper Table-2 variants; "full" picks lazy-vs-eager by the update-
    # divergence threshold (paper §5 static policy, core/policy.py)
    "blest_a": dict(engine="blest", order=False, lazy=False),
    "blest_ab": dict(engine="blest", order=True, lazy=False),
    "blest_ac": dict(engine="blest_lazy", order=False, lazy=True),
    "blest_full": dict(engine="policy", order=True, lazy=True),
    "brs": dict(engine="brs", order=False, lazy=False),
    "csr_push": dict(engine="csr_push", order=False, lazy=False),
    "csr_pull": dict(engine="csr_pull", order=False, lazy=False),
    "dirop": dict(engine="dirop", order=False, lazy=False),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat",
                    choices=["rmat", "urand", "road", "clustered"])
    ap.add_argument("--scale", type=int, default=11)
    ap.add_argument("--engine", default="blest_full",
                    choices=sorted(ENGINE_VARIANTS))
    ap.add_argument("--sources", type=int, default=4)
    ap.add_argument("--verify", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    g = build_graph(args.graph, args.scale, args.seed)
    rep = social_like_report(g)
    print(f"[bfs] graph={args.graph} n={g.n} m={g.m} "
          f"social_like={rep.is_social} (top1={rep.top1_share:.2f} "
          f"slope={rep.ll_slope:.2f})")

    variant = ENGINE_VARIANTS[args.engine]
    t0 = time.time()
    if variant["order"]:
        perm, kind = auto_order(g, w=512)
        g = g.permute_fast(perm)
        print(f"[bfs] ordering={kind} ({time.time() - t0:.2f}s), "
              f"bandwidth={g.bandwidth()}")
    b = build_bvss(g)
    print(f"[bfs] BVSS: num_vss={b.num_vss} slices={b.num_slices} "
          f"compression={b.compression_ratio():.3f} "
          f"update_divergence={b.update_divergence():.0f} "
          f"memory={b.memory_bytes()['total'] / 1e6:.1f}MB")
    engine = variant["engine"]
    if engine == "policy":
        from repro.core.policy import choose_update_scheme
        engine = choose_update_scheme(b)
        print(f"[bfs] policy chose update scheme: {engine}")
    fn = make_engine(g, engine, bvss=b
                     if engine.startswith(("brs", "blest")) else None)

    rng = np.random.default_rng(args.seed)
    srcs = rng.integers(0, g.n, args.sources)
    lv = np.asarray(fn(int(srcs[0])))  # compile
    times = []
    for s in srcs:
        t0 = time.time()
        lv = np.asarray(fn(int(s)))
        times.append(time.time() - t0)
        if args.verify:
            ref = reference_bfs(g, int(s))
            assert (lv == ref).all(), f"mismatch from source {s}"
    reached = int((lv != np.iinfo(np.int32).max).sum())
    print(f"[bfs] {args.engine}: {np.mean(times) * 1e3:.2f} ms/BFS "
          f"(median {np.median(times) * 1e3:.2f}) over {args.sources} "
          f"sources; last run reached {reached}/{g.n} vertices"
          + ("; VERIFIED vs oracle" if args.verify else ""))


if __name__ == "__main__":
    main()
