"""BFS workload launcher — the paper's own pipeline, end to end.

    PYTHONPATH=src python -m repro.launch.bfs --graph rmat --scale 12 \
        --engine blest_full --sources 8

Pipeline per the paper: classify the graph (social-like?), pick the
ordering (JaccardWithWindows+shingle vs RCM), build the BVSS, run the fused
BFS engine, verify against the host oracle.  All preparation goes through
the ONE static pipeline in :func:`repro.core.policy.prepare` (the serving
layer and examples use the same one).

``--service`` instead serves the queries through
:class:`repro.serve.GraphSession` — batched multi-source waves over the
slot pool — and reports wave vs sequential timing.

``--devices N`` runs the whole pipeline mesh-native (DESIGN §2.4):
``prepare(g, mesh=...)`` row-shards the BVSS over a 1-D mesh and the same
fused level loop runs under ``shard_map``.  On CPU the devices are
simulated: if the process was started with fewer devices than requested it
re-execs itself once with ``--xla_force_host_platform_device_count`` (the
flag only takes effect before the JAX backend initialises).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

from repro import PrepareOptions, prepare
from repro.core import reference_bfs
from repro.core.ordering import social_like_report
from repro.core.policy import BVSS_ENGINES
from repro.errors import KernelFaultError
from repro.graphs import generators as gen


def build_graph(name: str, scale: int, seed: int = 0):
    if name == "rmat":
        return gen.rmat(scale, 16, seed=seed)
    if name == "urand":
        return gen.erdos_renyi(1 << scale, 16.0, seed=seed)
    if name == "road":
        side = int((1 << scale) ** 0.5)
        return gen.grid2d(side, side, shuffle=True, seed=seed)
    if name == "clustered":
        return gen.clustered((1 << scale) // 64, 64, seed=seed)
    raise ValueError(name)


ENGINE_VARIANTS = {
    # paper Table-2 variants; "full" picks lazy-vs-eager by the update-
    # divergence threshold (paper §5 static policy, core/policy.py);
    # engine=None means "let the policy choose"
    "blest_a": dict(engine="blest", order=False),
    "blest_ab": dict(engine="blest", order=True),
    "blest_ac": dict(engine="blest_lazy", order=False),
    "blest_full": dict(engine=None, order=True),
    "brs": dict(engine="brs", order=False),
    "csr_push": dict(engine="csr_push", order=False),
    "csr_pull": dict(engine="csr_pull", order=False),
    "dirop": dict(engine="dirop", order=False),
}


def ensure_devices(n: int, argv, *, module: str = "repro.launch.bfs"
                   ) -> "object | None":
    """Return the 1-D BFS mesh for ``n`` devices, re-execing ``module``
    once with the host-platform device-count flag if this process has too
    few (CPU simulation; the flag is read only at backend init)."""
    if n <= 1:
        return None
    import jax
    if len(jax.devices()) < n:
        flag = f"--xla_force_host_platform_device_count={n}"
        if flag in os.environ.get("XLA_FLAGS", ""):
            raise RuntimeError(
                f"{flag} set but only {len(jax.devices())} devices came up")
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + flag).strip()
        cmd = [sys.executable, "-m", module,
               *(argv if argv is not None else sys.argv[1:])]
        os.execvpe(cmd[0], cmd, env)                 # does not return
    from repro.distributed.bfs_dist import bfs_mesh
    return bfs_mesh(n)


def run_service(g, mesh, args) -> None:
    """--service: hardened wave-batched serving through the multi-tenant
    GraphSessionManager (admission, deadlines, verify-mode sampling)."""
    from repro import GraphSessionManager, TimeoutResult
    variant = ENGINE_VARIANTS[args.engine]
    mgr = GraphSessionManager(verify_fraction=args.verify_fraction)
    sess = mgr.open_session(
        "cli", g, max_batch=args.max_batch,
        options=PrepareOptions(w=512, seed=args.seed,
                               order=variant["order"],
                               engine=variant["engine"], mesh=mesh))
    print(f"[bfs] session up: ordering={sess.ordering} "
          f"engine={sess.engine_name} "
          f"compression={sess.bvss.compression_ratio():.3f} "
          f"preprocess={sess.preprocess_s:.2f}s "
          f"cost={mgr.bytes_used() / 1e6:.1f}MB "
          f"verify_fraction={args.verify_fraction}")
    rng = np.random.default_rng(args.seed)
    queries = [int(q) for q in rng.integers(0, g.n, args.sources)]
    sess.levels(queries[0])                      # warm both paths
    sess.levels_batch(queries[: min(2, len(queries))])
    if args.queue:
        # async path (DESIGN §2.10): non-blocking submits coalesce into
        # shared waves; futures resolve as each column converges
        from repro import RequestQueue
        q = RequestQueue(mgr)
        t0 = time.time()
        futs = [q.submit("cli", s, deadline_s=args.deadline_s)
                for s in queries]
        q.drain()
        lvs = [f.result(0) for f in futs]
        t_wave = time.time() - t0
        qs = q.stats()
        print(f"[bfs] queue: {qs['completed']} completed over "
              f"{qs['waves']} waves, {qs['coalesced']} coalesced "
              f"mid-flight, {qs['timeouts']} deadline harvests")
    else:
        t0 = time.time()
        lvs = mgr.levels_batch("cli", queries, deadline_s=args.deadline_s)
        t_wave = time.time() - t0
    t0 = time.time()
    seq = [sess.levels(q) for q in queries]
    t_seq = time.time() - t0
    n_partial = sum(isinstance(lv, TimeoutResult) for lv in lvs)
    if args.verify:
        for q, lv, lv_seq in zip(queries, lvs, seq):
            ref = reference_bfs(g, q)
            if isinstance(lv, TimeoutResult):
                continue             # partial by deadline, not comparable
            if not (lv == ref).all():
                raise KernelFaultError(f"wave mismatch from source {q}")
            if not (lv_seq == ref).all():
                raise KernelFaultError(f"seq mismatch from source {q}")
    st = mgr.stats()
    print(f"[bfs] service: {len(queries)} queries, "
          f"wave={t_wave * 1e3:.1f}ms "
          f"sequential={t_seq * 1e3:.1f}ms "
          f"speedup={t_seq / max(t_wave, 1e-9):.2f}x "
          f"(max_batch={args.max_batch}, partial={n_partial}, "
          f"verified={st['verified']}, quarantines={st['quarantines']})"
          + ("; VERIFIED vs oracle" if args.verify else ""))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat",
                    choices=["rmat", "urand", "road", "clustered"])
    ap.add_argument("--scale", type=int, default=11)
    ap.add_argument("--engine", default="blest_full",
                    choices=sorted(ENGINE_VARIANTS))
    ap.add_argument("--sources", type=int, default=4)
    ap.add_argument("--verify", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="check levels against the host oracle "
                         "(--no-verify for timing runs)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--service", action="store_true",
                    help="serve the sources as one batched wave through "
                         "GraphSession instead of sequential BFS runs")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="wave slot-pool width for --service")
    ap.add_argument("--queue", action="store_true",
                    help="--service via the async RequestQueue: "
                         "non-blocking submits, futures, mid-flight "
                         "wave coalescing (DESIGN §2.10)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="--service per-call deadline: queries exceeding "
                         "it return partial TimeoutResults instead of "
                         "blocking the wave")
    ap.add_argument("--verify-fraction", type=float, default=0.0,
                    help="--service verify-mode: fraction of wave results "
                         "cross-checked against the host oracle (failing "
                         "sessions are quarantined and re-served on the "
                         "reference path)")
    ap.add_argument("--devices", type=int, default=1,
                    help="row-shard the BFS over an N-device 1-D mesh "
                         "(simulated via the host-platform device count "
                         "on CPU; the process re-execs once if needed)")
    args = ap.parse_args(argv)

    mesh = ensure_devices(args.devices, argv)
    g = build_graph(args.graph, args.scale, args.seed)
    rep = social_like_report(g)
    print(f"[bfs] graph={args.graph} n={g.n} m={g.m} "
          f"social_like={rep.is_social} (top1={rep.top1_share:.2f} "
          f"slope={rep.ll_slope:.2f})"
          + (f" mesh={args.devices}x1" if mesh is not None else ""))

    if args.service:
        run_service(g, mesh, args)
        return

    variant = ENGINE_VARIANTS[args.engine]
    if mesh is not None and variant["engine"] not in (None, *BVSS_ENGINES):
        ap.error(f"--devices requires a BVSS engine, not {args.engine}")
    t0 = time.time()
    prep = prepare(g, options=PrepareOptions(
        w=512, seed=args.seed, order=variant["order"],
        engine=variant["engine"], mesh=mesh))
    prep_s = time.time() - t0
    if mesh is not None:
        pb = prep.problem
        print(f"[bfs] sharded: {pb.n_shards} shards x "
              f"{pb.rows_per_shard} rows, {pb.num_vss} VSS/shard (padded), "
              f"frontier={pb.n_fwords * 4}B/level all-gather")
    if variant["order"]:
        print(f"[bfs] ordering={prep.ordering} "
              f"(prepare={prep_s:.2f}s incl. BVSS+engine), "
              f"bandwidth={prep.graph.bandwidth()}")
    b = prep.bvss
    print(f"[bfs] BVSS: num_vss={b.num_vss} slices={b.num_slices} "
          f"compression={b.compression_ratio():.3f} "
          f"update_divergence={b.update_divergence():.0f} "
          f"memory={b.memory_bytes()['total'] / 1e6:.1f}MB")
    if variant["engine"] is None:
        print(f"[bfs] policy chose update scheme: {prep.engine_name}")

    rng = np.random.default_rng(args.seed)
    srcs = rng.integers(0, g.n, args.sources)
    lv = prep.levels(int(srcs[0]))  # compile
    times = []
    for s in srcs:
        t0 = time.time()
        lv = prep.levels(int(s))
        times.append(time.time() - t0)
        if args.verify:
            ref = reference_bfs(g, int(s))
            if not (lv == ref).all():
                raise KernelFaultError(
                    f"{args.engine} levels diverge from the oracle from "
                    f"source {s}")
    reached = int((lv != np.iinfo(np.int32).max).sum())
    print(f"[bfs] {args.engine}: {np.mean(times) * 1e3:.2f} ms/BFS "
          f"(median {np.median(times) * 1e3:.2f}) over {args.sources} "
          f"sources; last run reached {reached}/{g.n} vertices"
          + ("; VERIFIED vs oracle" if args.verify else ""))


if __name__ == "__main__":
    main()
