"""Fault injection for the hardened serving tier (DESIGN §2.7).

The BLEST engines capture their kernels in jitted closures at build time,
so faults are injected the same way real substitutions happen: a
:class:`FaultPlan` is handed to the engine *builder* and its wrappers are
baked into the traced computation — deterministic, retrace-free, and
exactly at the documented seams of :func:`repro.core.multi_source.
make_ms_engine` (``spmm_impl`` / ``spmm_w_impl`` / ``gather_impl``).

Four fault families, one per seam (style after ``ft/manager.py``'s
deterministic injection):

* ``corrupt_spmm_tile`` — the Boolean bit-SpMM returns a corrupted output
  tile: the first queued VSS tile's popcounts are forced positive, so its
  rows are "discovered" a level early.  A silent wrong answer unless the
  verify-mode sampling policy (``serve.session_manager``) catches it.
* ``corrupt_push_tile`` — the direction-optimizing PUSH kernel (DESIGN
  §2.8) returns a corrupted first tile: every row of the first queued
  (vertex, VSS) pair reads as hit.  Only push levels are affected, so
  the fault is invisible until the hybrid actually switches direction
  (or the engine is forced to ``direction="push"``) — exactly the class
  of bug the gauntlet exists to keep honest.  The seam is
  ``push_impl`` of the single-source engines; the wave engine's push
  branch rides the bit-SpMM seam and is covered by ``corrupt_spmm_tile``.
* ``nan_sigma`` — the weighted tile product NaN-poisons the σ path-count
  float channel (a flush-to-NaN matrix unit fault).  Betweenness scores
  go NaN; the finite guard must degrade to the host oracle.
* ``stall_shard`` — shard k's segment of the frontier-word all-gather is
  zeroed (a stalled / dropped peer): vertices it owns stop propagating,
  so other shards under-discover.  Mesh sessions only.
* ``stall_butterfly_stage`` — stage k of the staged butterfly frontier
  exchange (``distributed.collectives.butterfly_frontier_exchange``)
  drops its partner block on every device: half the frontier segments go
  dark mid-exchange (a failed recursive-doubling round, the 2-D analogue
  of ``stall_shard``).  Rides the SAME ``gather_impl`` seam, so a plan
  may set one stall or the other, never both.

Every injected fault must surface as a typed error or a degraded-but-
correct result — never a silent wrong answer.  The CI ``chaos`` job runs
the full gauntlet (``tests/test_faults.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.distributed.bfs_dist import frontier_all_gather
from repro.kernels import bvss_spmm, bvss_spmm_w
from repro.kernels.ref import bvss_spmm_ref, bvss_spmm_w_ref


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Static description of the faults to bake into an engine build.

    The default plan injects nothing and adds nothing to the trace; a
    plan is immutable so one engine build corresponds to one fault
    configuration (no mid-flight mutation can desynchronise host
    bookkeeping from the compiled computation).
    """

    #: corrupt the Boolean bit-SpMM: force the first queued tile's
    #: popcounts positive (rows discovered a level early — wrong levels)
    corrupt_spmm_tile: bool = False
    #: corrupt the push kernel's first tile (hybrid push levels only):
    #: every row of the first queued (vertex, VSS) pair reads as hit
    corrupt_push_tile: bool = False
    #: NaN-poison the weighted σ tile product (Brandes float channel)
    nan_sigma: bool = False
    #: zero shard k's segment of the frontier-word all-gather (stalled
    #: peer); only consulted by mesh-native engines
    stall_shard: int | None = None
    #: drop the partner block at stage k of the butterfly frontier
    #: exchange (failed recursive-doubling round); 2-D mesh engines —
    #: shares the ``gather_impl`` seam with ``stall_shard``
    stall_butterfly_stage: int | None = None

    def __post_init__(self):
        if (self.stall_shard is not None
                and self.stall_butterfly_stage is not None):
            from repro.errors import ConfigError
            raise ConfigError(
                "stall_shard and stall_butterfly_stage both occupy the "
                "gather_impl seam; a plan may set at most one")

    @property
    def injects(self) -> bool:
        return (self.corrupt_spmm_tile or self.corrupt_push_tile
                or self.nan_sigma or self.stall_shard is not None
                or self.stall_butterfly_stage is not None)

    # -- seam wrappers ---------------------------------------------------
    def wrap_spmm(self, base: Callable) -> Callable:
        if not self.corrupt_spmm_tile:
            return base

        def faulty_spmm(masks, fbytes, *, sigma=8, **kw):
            counts = base(masks, fbytes, sigma=sigma, **kw)
            # corrupt tile 0: every row of the first queued VSS reads as
            # adjacent to the frontier, whatever the masks said
            return counts.at[0].set(jnp.maximum(counts[0], 1))

        return faulty_spmm

    def wrap_push(self, base: Callable) -> Callable:
        if not self.corrupt_push_tile:
            return base

        def faulty_push(masks, bits, sigma=8, **kw):
            hits = base(masks, bits, sigma, **kw)
            # corrupt tile 0: the first queued (vertex, VSS) pair claims
            # every row of its tile, whatever the masks said
            return hits.at[0].set(True)

        return faulty_push

    def wrap_spmm_w(self, base: Callable) -> Callable:
        if not self.nan_sigma:
            return base

        def faulty_spmm_w(masks, xvals, *, sigma=8, **kw):
            out = base(masks, xvals, sigma=sigma, **kw)
            # poison only where the tile contributed: NaN * 0 stays 0 on
            # rows the pull never touched, which is exactly how a bad
            # matrix-unit lane corrupts real traffic only
            return out * jnp.where(out != 0, jnp.nan, 1.0).astype(out.dtype)

        return faulty_spmm_w

    def wrap_gather(self) -> Callable | None:
        if self.stall_butterfly_stage is not None:
            import functools

            from repro.distributed.collectives import (
                butterfly_frontier_exchange)
            return functools.partial(butterfly_frontier_exchange,
                                     stall_stage=int(
                                         self.stall_butterfly_stage))
        if self.stall_shard is None:
            return None
        k = int(self.stall_shard)

        def stalled_gather(fw_local, axis):
            full = frontier_all_gather(fw_local, axis)
            lw = fw_local.shape[0]
            # shard k's words arrive zeroed: its frontier never reaches
            # the other shards' pull operands
            return full.at[k * lw:(k + 1) * lw].set(
                jnp.zeros_like(full[k * lw:(k + 1) * lw]))

        return stalled_gather

    # -- engine-builder kwargs ------------------------------------------
    def engine_overrides(self, *, use_kernel: bool = True) -> dict:
        """kwargs for :func:`repro.core.multi_source.make_ms_engine` (and
        friends) that bake this plan's faults into the build.  An empty
        dict when the plan injects nothing, so the unfaulted path shares
        the session's ordinary jit cache."""
        if not self.injects:
            return {}
        spmm = bvss_spmm if use_kernel else bvss_spmm_ref
        spmm_w = bvss_spmm_w if use_kernel else bvss_spmm_w_ref
        out: dict = {}
        if self.corrupt_spmm_tile:
            out["spmm_impl"] = self.wrap_spmm(spmm)
        if self.corrupt_push_tile:
            if use_kernel:
                from repro.kernels import push_vss_kernel as push
            else:
                from repro.kernels.ref import bvss_push_ref as push
            out["push_impl"] = self.wrap_push(push)
        if self.nan_sigma:
            out["spmm_w_impl"] = self.wrap_spmm_w(spmm_w)
        if (self.stall_shard is not None
                or self.stall_butterfly_stage is not None):
            out["gather_impl"] = self.wrap_gather()
        return out


#: the no-op plan every un-faulted session uses
NO_FAULTS = FaultPlan()
