from .engine import Completion, Request, ServeEngine
from .faults import NO_FAULTS, FaultPlan
from .graph_session import GraphSession
from .queue import RequestQueue, WaveFuture, WaveScheduler
from .session_manager import (DegradedServiceWarning, GraphSessionManager,
                              TenantQuota, TimeoutResult,
                              session_cost_bytes)

__all__ = ["Completion", "Request", "ServeEngine", "GraphSession",
           "FaultPlan", "NO_FAULTS", "GraphSessionManager", "TenantQuota",
           "TimeoutResult", "DegradedServiceWarning", "session_cost_bytes",
           "RequestQueue", "WaveFuture", "WaveScheduler"]
