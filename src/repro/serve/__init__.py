from .engine import Completion, Request, ServeEngine
from .graph_session import GraphSession

__all__ = ["Completion", "Request", "ServeEngine", "GraphSession"]
