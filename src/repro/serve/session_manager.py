"""GraphSessionManager: the hardened multi-tenant serving tier
(DESIGN §2.7).

One manager fronts MANY prepared graphs for MANY tenants, adding the
robustness layer a single :class:`~repro.serve.graph_session.GraphSession`
does not have:

* **Byte-budgeted LRU of prepared state.**  Each open session is costed
  with the DESIGN §2.5 memory model (``bvss.memory_bytes()`` + the
  O(n·S) wave state); opening a session past the global ``byte_budget``
  evicts least-recently-used sessions until the new one fits, and raises
  :class:`~repro.errors.AdmissionError` (reason ``"byte-budget"``) when
  it cannot — never a hang, never an OOM surprise.
* **Per-tenant quotas and admission control.**  A :class:`TenantQuota`
  caps open sessions, prepared bytes and per-call batch width per
  tenant; violations are rejected with a machine-readable reason
  (``"tenant-sessions"`` / ``"tenant-bytes"`` / ``"inflight"`` /
  ``"unknown-session"``), not queued behind an unbounded backlog.
* **Per-request deadlines.**  ``levels_batch(..., deadline_s=...)``
  threads the wave loop's cancellation hooks: a query that outlives its
  budget is harvested mid-flight at the next lock-step level, its slot
  refilled, and a partial :class:`TimeoutResult` (levels so far + the
  deepest completed frontier) returned — one slow query cannot block the
  wave.  ``on_deadline="raise"`` turns the partial into a
  :class:`~repro.errors.DeadlineExceeded` for callers that need
  all-or-nothing semantics.
* **Verify-mode sampling, quarantine, graceful degradation.**  A
  configurable fraction of completed wave results is cross-checked
  against the ``kernels/ref.py`` host oracles; a divergence (e.g. an
  injected :class:`~repro.serve.faults.FaultPlan` corruption) raises
  :class:`~repro.errors.KernelFaultError` internally, QUARANTINES the
  session, re-serves the whole call on the reference path and emits a
  :class:`DegradedServiceWarning` — callers always get correct levels,
  possibly slowly, never silently wrong ones.  Analytics verbs carry a
  finite guard: NaN-poisoned σ channels degrade to ``betweenness_ref`` /
  ``closeness_ref`` the same way.

Every admission decision, eviction, timeout, quarantine and degradation
is appended to ``manager.events`` (structured dicts) and aggregated by
``manager.stats()``.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import OrderedDict
from typing import Any, Callable, Sequence

import numpy as np
from jax.sharding import Mesh

from repro.core import reference_bfs
from repro.errors import (AdmissionError, DeadlineExceeded,
                          KernelFaultError, check_sources)
from repro.graphs import Graph
from repro.kernels.ref import betweenness_ref, closeness_ref
from repro.serve.graph_session import GraphSession

INF = np.int32(np.iinfo(np.int32).max)


class DegradedServiceWarning(UserWarning):
    """The manager served a degraded (reference-path / partial) answer."""


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission limits (``None`` = unlimited)."""

    max_sessions: int | None = None   # concurrently open sessions
    max_bytes: int | None = None      # prepared bytes across its sessions
    max_inflight: int | None = None   # sources per levels_batch call


@dataclasses.dataclass(frozen=True)
class TimeoutResult:
    """Partial answer for a query harvested at its deadline.

    ``levels`` holds caller-id levels computed before the harvest
    (``INF`` = not yet reached); ``depth`` is the deepest completed
    level and ``frontier`` the caller-id vertices discovered at it —
    enough state for the caller to resume or refine the query."""

    source: int
    levels: np.ndarray
    depth: int
    frontier: np.ndarray
    deadline_s: float | None

    @property
    def complete(self) -> bool:
        return False


@dataclasses.dataclass
class _SessionRecord:
    name: str
    tenant: str
    graph: Graph                  # the caller's ORIGINAL graph (oracle input)
    session: GraphSession
    cost_bytes: int
    quarantined: bool = False
    quarantine_reason: str | None = None
    served: int = 0


def session_cost_bytes(session: GraphSession) -> int:
    """DESIGN §2.5 memory model of one prepared session: the BVSS
    footprint breakdown plus the O(n·S) wave state (levels + packed
    frontier words, S = ``max_batch`` stacked columns)."""
    mem = session.bvss.memory_bytes()
    S = session.max_batch
    wave = 4 * (session.n + 1) * S + 4 * session.bvss.n_frontier_words * S
    return int(mem["total"]) + int(wave)


class GraphSessionManager:
    """Multi-tenant, byte-budgeted, deadline-aware front over many
    :class:`GraphSession`\\ s.

    Parameters
    ----------
    byte_budget:
        Global cap (bytes, DESIGN §2.5 model) on prepared state across
        all sessions; LRU sessions are evicted to make room.  ``None``
        disables eviction.
    default_quota:
        The :class:`TenantQuota` applied to tenants without an explicit
        ``set_quota`` entry.
    verify_fraction:
        Fraction (0..1) of completed wave results cross-checked against
        the host oracle; 1.0 checks every result (the chaos-gauntlet
        setting), 0.0 disables verification.
    verify_seed:
        Seed of the sampling RNG (deterministic verification schedule).
    clock:
        Monotonic time source, injectable for tests.
    """

    def __init__(self, *, byte_budget: int | None = None,
                 default_quota: TenantQuota = TenantQuota(),
                 verify_fraction: float = 0.0, verify_seed: int = 0,
                 clock: Callable[[], float] = time.monotonic):
        if not 0.0 <= verify_fraction <= 1.0:
            raise ValueError(
                f"verify_fraction must be in [0, 1], got {verify_fraction}")
        self.byte_budget = byte_budget
        self.default_quota = default_quota
        self.verify_fraction = float(verify_fraction)
        self._verify_rng = np.random.default_rng(verify_seed)
        self._clock = clock
        self._sessions: OrderedDict[str, _SessionRecord] = OrderedDict()
        self._quotas: dict[str, TenantQuota] = {}
        self.events: list[dict[str, Any]] = []

    # ------------------------------------------------------------------
    # bookkeeping
    # ------------------------------------------------------------------
    def _event(self, kind: str, **fields) -> None:
        self.events.append({"kind": kind, **fields})

    def quota_for(self, tenant: str) -> TenantQuota:
        return self._quotas.get(tenant, self.default_quota)

    def set_quota(self, tenant: str, quota: TenantQuota) -> None:
        self._quotas[tenant] = quota

    def bytes_used(self) -> int:
        return sum(r.cost_bytes for r in self._sessions.values())

    def _tenant_records(self, tenant: str) -> list[_SessionRecord]:
        return [r for r in self._sessions.values() if r.tenant == tenant]

    def _get(self, name: str, tenant: str) -> _SessionRecord:
        rec = self._sessions.get(name)
        if rec is None:
            raise AdmissionError(f"no open session named {name!r}",
                                 reason="unknown-session")
        if rec.tenant != tenant:
            # tenant isolation: another tenant's session name is
            # indistinguishable from a missing one
            raise AdmissionError(
                f"no open session named {name!r} for tenant {tenant!r}",
                reason="unknown-session")
        self._sessions.move_to_end(name)       # LRU touch
        return rec

    # ------------------------------------------------------------------
    # session lifecycle
    # ------------------------------------------------------------------
    def open_session(self, name: str, g: Graph, *, tenant: str = "default",
                     mesh: Mesh | None = None, **session_kwargs
                     ) -> GraphSession:
        """Prepare ``g`` and admit it as session ``name`` for ``tenant``.

        Admission order: tenant session-count quota (pre-build, cheap) →
        build → exact byte cost → tenant byte quota (hard reject) →
        global byte budget (LRU-evict to fit, reject if impossible).
        Rejections raise :class:`AdmissionError` with a reason code; the
        build is discarded, never half-registered."""
        if name in self._sessions:
            raise AdmissionError(
                f"session name {name!r} is already open "
                f"(close it first or pick another name)",
                reason="duplicate-name")
        quota = self.quota_for(tenant)
        mine = self._tenant_records(tenant)
        if (quota.max_sessions is not None
                and len(mine) >= quota.max_sessions):
            self._event("admission-reject", tenant=tenant, name=name,
                        reason="tenant-sessions")
            raise AdmissionError(
                f"tenant {tenant!r} already has {len(mine)} open sessions "
                f"(quota {quota.max_sessions})", reason="tenant-sessions")
        session = GraphSession(g, mesh=mesh, **session_kwargs)
        cost = session_cost_bytes(session)
        if quota.max_bytes is not None:
            used = sum(r.cost_bytes for r in mine)
            if used + cost > quota.max_bytes:
                self._event("admission-reject", tenant=tenant, name=name,
                            reason="tenant-bytes")
                raise AdmissionError(
                    f"session {name!r} needs {cost} bytes; tenant "
                    f"{tenant!r} holds {used} of {quota.max_bytes}",
                    reason="tenant-bytes")
        if self.byte_budget is not None:
            if cost > self.byte_budget:
                self._event("admission-reject", tenant=tenant, name=name,
                            reason="byte-budget")
                raise AdmissionError(
                    f"session {name!r} needs {cost} bytes, over the "
                    f"global budget of {self.byte_budget}",
                    reason="byte-budget")
            while self.bytes_used() + cost > self.byte_budget:
                lru_name, lru = next(iter(self._sessions.items()))
                del self._sessions[lru_name]
                self._event("evict", name=lru_name, tenant=lru.tenant,
                            freed_bytes=lru.cost_bytes)
        rec = _SessionRecord(name=name, tenant=tenant, graph=g,
                             session=session, cost_bytes=cost)
        self._sessions[name] = rec
        self._event("open", name=name, tenant=tenant, bytes=cost)
        return session

    def close_session(self, name: str, *, tenant: str = "default") -> None:
        rec = self._get(name, tenant)
        del self._sessions[name]
        self._event("close", name=name, tenant=tenant,
                    freed_bytes=rec.cost_bytes)

    def __contains__(self, name: str) -> bool:
        return name in self._sessions

    # ------------------------------------------------------------------
    # streaming updates (DESIGN §2.10)
    # ------------------------------------------------------------------
    def update_edges(self, name: str, inserts=(), deletes=(), *,
                     tenant: str = "default", insert_weights=None,
                     expected_epoch: int | None = None,
                     staleness_budget: int | None = None):
        """Apply a streaming edge-update batch to session ``name`` and
        swap it to the next epoch; returns the
        :class:`~repro.core.bvss_delta.UpdateReport` (``None`` for an
        effective no-op).  The manager's oracle copy of the graph and the
        session's byte cost follow the update, so verify-mode sampling
        and the LRU budget stay truthful about the mutated graph."""
        rec = self._get(name, tenant)
        report = rec.session.update_edges(
            inserts, deletes, insert_weights=insert_weights,
            expected_epoch=expected_epoch,
            staleness_budget=staleness_budget)
        if report is None:
            return None
        # refresh the ORIGINAL-id oracle graph from the mutated session
        from repro.graphs import from_edges, src_of_edges
        p = rec.session.prepared
        src_o = p.inv[src_of_edges(p.graph).astype(np.int64)]
        dst_o = p.inv[p.graph.indices.astype(np.int64)]
        rec.graph = from_edges(p.graph.n, src_o, dst_o, dedup=True,
                               drop_loops=False)
        rec.cost_bytes = session_cost_bytes(rec.session)
        self._event("update-edges", name=name, tenant=tenant,
                    path=report.path, epoch=report.epoch,
                    n_inserted=report.n_inserted,
                    n_deleted=report.n_deleted)
        return report

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    def levels(self, name: str, src: int, *, tenant: str = "default",
               deadline_s: float | None = None,
               on_deadline: str = "partial"
               ) -> np.ndarray | TimeoutResult:
        """Single level query (see :meth:`levels_batch`).  With a
        deadline the query rides the wave pool — the fused singleton
        engine cannot be preempted mid-flight."""
        return self.levels_batch(name, [src], tenant=tenant,
                                 deadline_s=deadline_s,
                                 on_deadline=on_deadline)[0]

    def levels_batch(self, name: str, sources: Sequence[int], *,
                     tenant: str = "default",
                     deadline_s: float | None = None,
                     on_deadline: str = "partial"
                     ) -> list[np.ndarray | TimeoutResult]:
        """Batched level queries with admission, deadlines and verify.

        Returns one entry per source: a caller-id level array, or a
        :class:`TimeoutResult` for a query harvested at ``deadline_s``
        (wall-clock seconds for the WHOLE call, measured on the
        manager's clock; cancellation granularity is one lock-step
        level).  ``on_deadline="raise"`` raises
        :class:`DeadlineExceeded` instead of returning partials.  A
        quarantined session serves on the reference path with a
        :class:`DegradedServiceWarning`."""
        if on_deadline not in ("partial", "raise"):
            raise ValueError(
                f"on_deadline must be 'partial' or 'raise', "
                f"got {on_deadline!r}")
        rec = self._get(name, tenant)
        srcs = check_sources(sources, rec.session.n)
        quota = self.quota_for(tenant)
        if (quota.max_inflight is not None
                and len(srcs) > quota.max_inflight):
            self._event("admission-reject", tenant=tenant, name=name,
                        reason="inflight")
            raise AdmissionError(
                f"{len(srcs)} sources exceed tenant {tenant!r}'s "
                f"in-flight cap of {quota.max_inflight}",
                reason="inflight")
        if not srcs:
            return []
        if rec.quarantined:
            return self._serve_reference(rec, srcs)
        rec.served += len(srcs)

        partials: dict[int, np.ndarray] = {}
        if deadline_s is None:
            outs = rec.session.levels_batch(srcs)
        else:
            t0 = self._clock()

            def should_harvest(i: int) -> bool:
                return self._clock() - t0 > deadline_s

            def on_harvested(i: int, lv: np.ndarray) -> None:
                partials[i] = lv

            outs = rec.session.levels_batch(
                srcs, should_harvest=should_harvest,
                on_harvested=on_harvested)

        # verify-mode sampling on the COMPLETED results
        try:
            self._verify(rec, srcs, outs)
        except KernelFaultError as e:
            self._quarantine(rec, str(e))
            return self._serve_reference(rec, srcs)

        results: list[np.ndarray | TimeoutResult] = []
        for i, (s, lv) in enumerate(zip(srcs, outs)):
            if lv is not None:
                results.append(lv)
                continue
            if on_deadline == "raise":
                raise DeadlineExceeded(
                    f"query for source {s} on session {name!r} exceeded "
                    f"its {deadline_s}s deadline")
            self._event("timeout", name=name, tenant=tenant, source=s,
                        deadline_s=deadline_s)
            warnings.warn(
                f"session {name!r}: source {s} harvested at its "
                f"{deadline_s}s deadline; returning partial levels",
                DegradedServiceWarning, stacklevel=2)
            results.append(self._timeout_result(s, partials[i], deadline_s))
        return results

    @staticmethod
    def _timeout_result(src: int, lv: np.ndarray,
                        deadline_s: float | None) -> TimeoutResult:
        finite = lv != INF
        depth = int(lv[finite].max()) if finite.any() else 0
        return TimeoutResult(source=int(src), levels=lv, depth=depth,
                             frontier=np.flatnonzero(lv == depth),
                             deadline_s=deadline_s)

    # ------------------------------------------------------------------
    # verification / quarantine / degradation
    # ------------------------------------------------------------------
    def verify_wave(self, name: str, sources: Sequence[int],
                    results: Sequence[np.ndarray], *,
                    tenant: str = "default") -> list[np.ndarray] | None:
        """Public verify hook for EXTERNAL wave drivers (the async
        :class:`~repro.serve.queue.RequestQueue`): cross-check a completed
        batch under this manager's ``verify_fraction`` sampling policy.

        Returns ``None`` when the batch passes (or verification is off).
        On a divergence the session is quarantined and the WHOLE batch is
        re-served on the reference path — the returned list (one caller-id
        level array per source) is what the driver must hand out instead
        of the device results."""
        rec = self._get(name, tenant)
        try:
            self._verify(rec, list(sources), list(results))
        except KernelFaultError as e:
            self._quarantine(rec, str(e))
            return self._serve_reference(rec, list(sources))
        return None

    def _verify(self, rec: _SessionRecord, srcs: list[int],
                outs: list[np.ndarray | None]) -> None:
        """Cross-check a sampled fraction of completed results against
        the host oracle; raise :class:`KernelFaultError` on divergence."""
        if self.verify_fraction <= 0.0:
            return
        for s, lv in zip(srcs, outs):
            if lv is None:
                continue
            if self._verify_rng.random() >= self.verify_fraction:
                continue
            want = reference_bfs(rec.graph, s)
            if not np.array_equal(np.asarray(lv), want):
                bad = int(np.flatnonzero(np.asarray(lv) != want)[0])
                raise KernelFaultError(
                    f"session {rec.name!r}: levels from source {s} "
                    f"diverge from the oracle (first at vertex {bad})")
            self._event("verify-pass", name=rec.name, source=s)

    def _quarantine(self, rec: _SessionRecord, reason: str) -> None:
        rec.quarantined = True
        rec.quarantine_reason = reason
        self._event("quarantine", name=rec.name, tenant=rec.tenant,
                    reason=reason)
        warnings.warn(
            f"session {rec.name!r} quarantined after failed kernel "
            f"verification ({reason}); serving on the reference path",
            DegradedServiceWarning, stacklevel=3)

    def _serve_reference(self, rec: _SessionRecord, srcs: list[int]
                         ) -> list[np.ndarray]:
        """Degraded-but-correct: host-oracle BFS per source."""
        self._event("degraded-serve", name=rec.name, tenant=rec.tenant,
                    n_queries=len(srcs))
        warnings.warn(
            f"session {rec.name!r} is quarantined "
            f"({rec.quarantine_reason}); serving {len(srcs)} queries on "
            f"the reference path", DegradedServiceWarning, stacklevel=3)
        return [reference_bfs(rec.graph, s) for s in srcs]

    # ------------------------------------------------------------------
    # analytics with the finite guard
    # ------------------------------------------------------------------
    def betweenness(self, name: str, sources: Sequence[int], *,
                    tenant: str = "default") -> np.ndarray:
        """Partial Brandes betweenness with the NaN guard: a poisoned σ
        float channel (e.g. ``FaultPlan(nan_sigma=True)``) quarantines
        the session and degrades to ``betweenness_ref``."""
        rec = self._get(name, tenant)
        srcs = check_sources(sources, rec.session.n)
        if not rec.quarantined:
            bc = rec.session.betweenness_batch(srcs)
            if np.isfinite(bc).all():
                return bc
            self._quarantine(
                rec, "non-finite betweenness scores (σ channel poisoned)")
        self._event("degraded-serve", name=name, tenant=tenant,
                    n_queries=len(srcs), verb="betweenness")
        return betweenness_ref(rec.graph, srcs)

    def closeness(self, name: str, sources: Sequence[int] | None = None, *,
                  tenant: str = "default",
                  wf_improved: bool = False) -> np.ndarray:
        """Closeness centrality with the same finite guard as
        :meth:`betweenness`."""
        rec = self._get(name, tenant)
        srcs = None if sources is None else \
            check_sources(sources, rec.session.n)
        if not rec.quarantined:
            cc = rec.session.closeness_batch(srcs, wf_improved=wf_improved)
            if np.isfinite(cc).all():
                return cc
            self._quarantine(
                rec, "non-finite closeness scores (level channel poisoned)")
        self._event("degraded-serve", name=name, tenant=tenant, verb="closeness")
        return closeness_ref(rec.graph, srcs, wf_improved=wf_improved)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def stats(self) -> dict[str, Any]:
        kinds = [e["kind"] for e in self.events]
        per_tenant: dict[str, dict[str, int]] = {}
        for r in self._sessions.values():
            t = per_tenant.setdefault(
                r.tenant, {"sessions": 0, "bytes": 0, "served": 0})
            t["sessions"] += 1
            t["bytes"] += r.cost_bytes
            t["served"] += r.served
        return {
            "sessions": len(self._sessions),
            "bytes_used": self.bytes_used(),
            "byte_budget": self.byte_budget,
            "evictions": kinds.count("evict"),
            "timeouts": kinds.count("timeout"),
            "quarantines": kinds.count("quarantine"),
            "rejections": kinds.count("admission-reject"),
            "degraded_serves": kinds.count("degraded-serve"),
            "verified": kinds.count("verify-pass"),
            "tenants": per_tenant,
        }
