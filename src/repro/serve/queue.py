"""Async request queue over the wave slot pool (DESIGN §2.10).

The serving tier so far is call-at-a-time: every
:meth:`GraphSessionManager.levels` call builds its own wave, so queries
that arrive milliseconds apart never share a level step.  This module adds
the asynchronous half the ROADMAP names (fpgagraphlib's arbiter / network /
barrier split, re-cast onto the wave machinery):

* :class:`RequestQueue` — the **arbiter**: non-blocking ``submit`` returns
  a :class:`WaveFuture`; admission is bounded (global ``capacity``, per-
  tenant ``tenant_backlog``) and refusals raise
  :class:`~repro.errors.QueueFullError` at ingress instead of growing an
  unbounded backlog, the same fail-fast contract as the manager's
  :class:`~repro.errors.AdmissionError`.
* :class:`WaveScheduler` — the **network**: one drain pass per session
  translates the queue into :func:`~repro.core.multi_source.drive_wave`'s
  refill hook, so arrivals coalesce into free slots of a wave ALREADY IN
  FLIGHT (``drive_wave`` re-offers every free slot after every lock-step
  level — that mid-flight refill is the entire throughput story: late
  arrivals share every remaining adjacency read of the current wave).
* the **barrier** is the wave's own convergence: each column resolves its
  future the moment its frontier empties, and post-wave the batch is
  cross-checked through the manager's verify hook
  (:meth:`GraphSessionManager.verify_wave`) so the fault-injection
  gauntlet drains to *degraded-but-correct* answers, never wrong ones.

Scheduling respects the manager's tenant model: slots are handed out
round-robin across tenants (a bursty tenant cannot starve the others) and
a tenant's in-wave slot share is capped by its
:class:`~repro.serve.session_manager.TenantQuota` ``max_inflight``.
Deadlines are per REQUEST, measured from submission on the queue's clock —
queue wait counts against the budget — and an over-deadline request is
harvested mid-flight into a partial
:class:`~repro.serve.session_manager.TimeoutResult` exactly like the
synchronous path.

The queue is thread-safe: ``submit`` may race ``drain`` (or the
``start()`` background pump) from any thread; the wave hooks only ever run
on the draining thread.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Callable

import numpy as np

from repro.core.multi_source import drive_wave
from repro.errors import KernelFaultError, QueueFullError, check_source
from repro.serve.session_manager import GraphSessionManager

__all__ = ["WaveFuture", "RequestQueue", "WaveScheduler"]


class WaveFuture:
    """Handle for one queued query: resolves to the caller-id level array,
    a partial :class:`~repro.serve.session_manager.TimeoutResult` (deadline
    harvest), or re-raises the error that killed the request."""

    def __init__(self, request_id: int, session: str, tenant: str,
                 source: int):
        self.request_id = request_id
        self.session = session
        self.tenant = tenant
        self.source = source
        self._done = threading.Event()
        self._value: Any = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None):
        """Block until resolved (``TimeoutError`` if ``timeout`` elapses
        first — the request itself stays queued and may still resolve)."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} (source {self.source} on "
                f"session {self.session!r}) not resolved in {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    def exception(self, timeout: float | None = None
                  ) -> BaseException | None:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.request_id} not resolved in {timeout}s")
        return self._error

    # -- resolution (scheduler side) -----------------------------------
    def _resolve(self, value) -> None:
        self._value = value
        self._done.set()

    def _reject(self, error: BaseException) -> None:
        self._error = error
        self._done.set()


class _Request:
    """One queued query: the future plus its scheduling envelope."""

    __slots__ = ("future", "src", "tenant", "submitted_at", "not_before",
                 "deadline_s")

    def __init__(self, future: WaveFuture, src: int, tenant: str,
                 submitted_at: float, not_before: float | None,
                 deadline_s: float | None):
        self.future = future
        self.src = src
        self.tenant = tenant
        self.submitted_at = submitted_at
        self.not_before = not_before
        self.deadline_s = deadline_s


class _SessionQueue:
    """Per-session pending pool: one FIFO per tenant + a round-robin ring
    over the tenants, so slot hand-out is tenant-fair by construction."""

    def __init__(self) -> None:
        self.tenants: dict[str, deque[_Request]] = {}
        self.ring: deque[str] = deque()

    def push(self, req: _Request) -> None:
        q = self.tenants.get(req.tenant)
        if q is None:
            q = self.tenants[req.tenant] = deque()
            self.ring.append(req.tenant)
        q.append(req)

    def __len__(self) -> int:
        return sum(len(q) for q in self.tenants.values())

    def pop_fair(self, now: float, slot_share: dict[str, int],
                 cap_of: Callable[[str], int | None]) -> _Request | None:
        """Next eligible request, rotating the tenant ring: skips tenants
        at their ``max_inflight`` slot share and requests whose
        ``not_before`` is still in the future."""
        for _ in range(len(self.ring)):
            tenant = self.ring[0]
            self.ring.rotate(-1)
            cap = cap_of(tenant)
            if cap is not None and slot_share.get(tenant, 0) >= cap:
                continue
            q = self.tenants[tenant]
            for i, req in enumerate(q):
                if req.not_before is None or req.not_before <= now:
                    del q[i]
                    return req
        return None

    def eligible(self, now: float) -> bool:
        return any(r.not_before is None or r.not_before <= now
                   for q in self.tenants.values() for r in q)

    def next_not_before(self) -> float | None:
        times = [r.not_before for q in self.tenants.values() for r in q
                 if r.not_before is not None]
        return min(times) if times else None

    def drain_all(self) -> list[_Request]:
        out = [r for q in self.tenants.values() for r in q]
        for q in self.tenants.values():
            q.clear()
        return out


class RequestQueue:
    """Bounded async ingress in front of a
    :class:`~repro.serve.session_manager.GraphSessionManager`.

    Parameters
    ----------
    manager:
        The session manager whose sessions, tenant quotas and verify
        policy the queue serves under.
    capacity:
        Global pending-request bound; a submit past it raises
        :class:`~repro.errors.QueueFullError` (reason ``"capacity"``).
    tenant_backlog:
        Per-tenant pending bound (reason ``"tenant-backlog"``); ``None``
        leaves tenants bounded only by ``capacity``.
    clock:
        Monotonic time source (injectable for tests); deadlines and
        ``not_before`` are measured on it.
    """

    def __init__(self, manager: GraphSessionManager, *,
                 capacity: int = 1024, tenant_backlog: int | None = None,
                 clock: Callable[[], float] = time.monotonic):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.manager = manager
        self.capacity = int(capacity)
        self.tenant_backlog = tenant_backlog
        self._clock = clock
        self._lock = threading.Lock()
        self._pending: dict[str, _SessionQueue] = {}
        self._n_pending = 0
        self._n_tenant: dict[str, int] = {}
        self._ids = itertools.count()
        self._stats = {"submitted": 0, "completed": 0, "timeouts": 0,
                       "degraded": 0, "rejected": 0, "coalesced": 0,
                       "waves": 0}
        self.events: list[dict[str, Any]] = []
        self._pump: threading.Thread | None = None
        self._stop = threading.Event()
        self._wake = threading.Event()

    # ------------------------------------------------------------------
    # ingress
    # ------------------------------------------------------------------
    def submit(self, name: str, src: int, *, tenant: str = "default",
               deadline_s: float | None = None,
               not_before: float | None = None) -> WaveFuture:
        """Enqueue one level query, non-blocking; returns the future.

        ``deadline_s`` is the request's total latency budget from NOW
        (queue wait included); ``not_before`` (a ``clock()`` timestamp)
        holds the request back until that instant — the simulated-arrival
        hook of the Poisson benchmark, so arrival patterns are replayable
        without wall-clock sleeps on the submitting side."""
        rec = self.manager._get(name, tenant)   # validates name + tenant
        src = check_source(src, rec.session.n)
        with self._lock:
            if self._n_pending >= self.capacity:
                self._stats["rejected"] += 1
                self._event("reject", reason="capacity", session=name,
                            tenant=tenant)
                raise QueueFullError(
                    f"queue at capacity ({self.capacity} pending)",
                    reason="capacity")
            if self.tenant_backlog is not None and \
                    self._n_tenant.get(tenant, 0) >= self.tenant_backlog:
                self._stats["rejected"] += 1
                self._event("reject", reason="tenant-backlog",
                            session=name, tenant=tenant)
                raise QueueFullError(
                    f"tenant {tenant!r} holds "
                    f"{self._n_tenant[tenant]} pending requests "
                    f"(backlog cap {self.tenant_backlog})",
                    reason="tenant-backlog")
            fut = WaveFuture(next(self._ids), name, tenant, src)
            req = _Request(fut, src, tenant, self._clock(), not_before,
                           deadline_s)
            self._pending.setdefault(name, _SessionQueue()).push(req)
            self._n_pending += 1
            self._n_tenant[tenant] = self._n_tenant.get(tenant, 0) + 1
            self._stats["submitted"] += 1
        self._wake.set()
        return fut

    def _event(self, kind: str, **fields) -> None:
        self.events.append({"kind": kind, **fields})

    def _checkout(self, name: str, now: float, slot_share: dict[str, int],
                  cap_of) -> _Request | None:
        with self._lock:
            sq = self._pending.get(name)
            if sq is None:
                return None
            req = sq.pop_fair(now, slot_share, cap_of)
            if req is not None:
                self._n_pending -= 1
                self._n_tenant[req.tenant] -= 1
            return req

    @property
    def pending(self) -> int:
        with self._lock:
            return self._n_pending

    def stats(self) -> dict[str, Any]:
        with self._lock:
            return dict(self._stats, pending=self._n_pending)

    # ------------------------------------------------------------------
    # draining
    # ------------------------------------------------------------------
    def drain(self, *, wait: bool = False, poll_s: float = 0.0005) -> int:
        """Pump waves until the queue is empty; returns requests resolved.

        One :class:`WaveScheduler` pass per session with eligible work;
        sessions round-robin between waves.  ``wait=True`` additionally
        sleeps through ``not_before`` gaps (simulated arrivals) instead of
        returning while future-dated requests remain."""
        resolved = 0
        while True:
            with self._lock:
                now = self._clock()
                names = [cand for cand, sq in self._pending.items()
                         if sq.eligible(now)]
                empty = self._n_pending == 0
            if names:
                # one wave per eligible session per pass: a session with a
                # standing backlog cannot starve the others
                for name in names:
                    resolved += WaveScheduler(self, name).run()
                continue
            if empty or not wait:
                return resolved
            with self._lock:
                nb = [sq.next_not_before()
                      for sq in self._pending.values()]
                nb = [t for t in nb if t is not None]
            delay = max(min(nb) - self._clock(), 0.0) if nb else poll_s
            time.sleep(min(max(delay, 0.0), 0.05))

    def start(self, *, poll_s: float = 0.002) -> None:
        """Spawn the background drain pump (daemon thread): submissions
        resolve without any caller ever touching :meth:`drain`."""
        if self._pump is not None:
            return
        self._stop.clear()

        def pump() -> None:
            while not self._stop.is_set():
                self._wake.wait(poll_s)
                self._wake.clear()
                self.drain(wait=False)

        self._pump = threading.Thread(target=pump, name="wave-queue-pump",
                                      daemon=True)
        self._pump.start()

    def stop(self, *, drain: bool = True) -> None:
        """Stop the pump; by default drain what is still queued first."""
        if self._pump is None:
            return
        self._stop.set()
        self._wake.set()
        self._pump.join()
        self._pump = None
        if drain:
            self.drain(wait=True)


class WaveScheduler:
    """One drain pass of one session: the queue-to-wave adapter.

    Translates the pending pool into ``drive_wave``'s hooks — tenant-fair
    refill (``next_source``), per-request deadline harvest, future
    resolution on convergence — then runs the manager's verify hook over
    the completed batch so injected faults degrade instead of lying.
    """

    def __init__(self, queue: RequestQueue, name: str):
        self.queue = queue
        self.name = name

    def run(self) -> int:
        q = self.queue
        mgr = q.manager
        try:
            rec = mgr._get(self.name, self._any_tenant())
        except Exception as e:
            # the session vanished between submit and drain (closed, or
            # LRU-evicted): fail its backlog loudly, don't dangle futures
            return self._reject_all(e)
        if rec.quarantined:
            return self._drain_degraded(rec)
        sess = rec.session
        perm = sess.perm
        S = sess.max_batch
        owner: list[_Request | None] = [None] * S
        slot_share: dict[str, int] = {}
        completed: list[tuple[_Request, np.ndarray]] = []
        timeouts: list[tuple[_Request, np.ndarray]] = []

        def cap_of(tenant: str) -> int | None:
            return mgr.quota_for(tenant).max_inflight

        def next_source(slot: int) -> int | None:
            req = q._checkout(self.name, q._clock(), slot_share, cap_of)
            if req is None:
                return None
            if any(o is not None for o in owner):
                # the wave is already in flight: this arrival shares its
                # remaining level steps — the coalescing win the bench
                # floors (queue.summary geomean)
                q._stats["coalesced"] += 1
            owner[slot] = req
            slot_share[req.tenant] = slot_share.get(req.tenant, 0) + 1
            return int(perm[req.src])

        def release(slot: int) -> _Request:
            req = owner[slot]
            owner[slot] = None
            slot_share[req.tenant] -= 1
            return req

        def on_converged(slot: int, lv: np.ndarray) -> None:
            completed.append((release(slot), lv[perm]))

        def should_harvest(slot: int) -> bool:
            req = owner[slot]
            return (req is not None and req.deadline_s is not None
                    and q._clock() - req.submitted_at > req.deadline_s)

        def on_harvested(slot: int, lv: np.ndarray) -> None:
            timeouts.append((release(slot), lv[perm]))

        limit = sess.max_steps if sess.max_steps is not None else \
            (q.capacity + S) * (sess.n + 1)
        try:
            drive_wave(sess._ms, next_source, on_converged,
                       max_steps=limit, should_harvest=should_harvest,
                       on_harvested=on_harvested)
        except Exception as e:
            for slot in range(S):       # never leave a future dangling
                if owner[slot] is not None:
                    release(slot).future._reject(e)
            raise
        rec.served += len(completed)

        # post-wave verify: on divergence the manager quarantines and the
        # WHOLE batch re-serves on the reference path (degraded-correct)
        refs = None
        if completed:
            refs = mgr.verify_wave(self.name,
                                   [r.src for r, _ in completed],
                                   [lv for _, lv in completed],
                                   tenant=rec.tenant)
        if refs is not None:
            q._stats["degraded"] += len(completed)
            q._event("degraded", session=self.name, n=len(completed))
            for (req, _), ref_lv in zip(completed, refs):
                req.future._resolve(ref_lv)
        else:
            for req, lv in completed:
                req.future._resolve(lv)
        for req, lv in timeouts:
            q._stats["timeouts"] += 1
            q._event("timeout", session=self.name, tenant=req.tenant,
                     source=req.src, deadline_s=req.deadline_s)
            req.future._resolve(GraphSessionManager._timeout_result(
                req.src, lv, req.deadline_s))
        q._stats["completed"] += len(completed) + len(timeouts)
        q._stats["waves"] += 1
        return len(completed) + len(timeouts)

    def _any_tenant(self) -> str:
        with self.queue._lock:
            sq = self.queue._pending.get(self.name)
            if sq is not None:
                for tenant, dq in sq.tenants.items():
                    if dq:
                        return tenant
        return "default"

    def _reject_all(self, error: BaseException) -> int:
        q = self.queue
        with q._lock:
            sq = q._pending.pop(self.name, None)
            reqs = sq.drain_all() if sq is not None else []
            for req in reqs:
                q._n_pending -= 1
                q._n_tenant[req.tenant] -= 1
        for req in reqs:
            req.future._reject(error)
        if reqs:
            q._event("reject-backlog", session=self.name, n=len(reqs),
                     error=type(error).__name__)
        return 0

    def _drain_degraded(self, rec) -> int:
        """A quarantined session's backlog resolves on the reference path
        immediately — no wave, no partials, still correct answers."""
        q = self.queue
        with q._lock:
            sq = q._pending.get(self.name)
            reqs = sq.drain_all() if sq is not None else []
            for req in reqs:
                q._n_pending -= 1
                q._n_tenant[req.tenant] -= 1
        if not reqs:
            return 0
        refs = q.manager._serve_reference(rec, [r.src for r in reqs])
        for req, lv in zip(reqs, refs):
            req.future._resolve(lv)
        q._stats["degraded"] += len(reqs)
        q._stats["completed"] += len(reqs)
        q._event("degraded", session=self.name, n=len(reqs))
        return len(reqs)
