"""Continuous-batching serving engine.

A fixed pool of ``max_batch`` slots decodes in lock-step (one jitted decode
step per iteration, per-slot positions); finished slots are refilled from
the request queue by prefetching the new prompt with a B=1 prefill and
scattering its cache into the pool (the classic slot-swap continuous
batching scheme — paged KV is unnecessary at this scale because the pool is
preallocated at ``max_len``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import LMConfig
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1             # -1: never stops early


@dataclasses.dataclass
class Completion:
    tokens: list
    prompt_len: int


class ServeEngine:
    def __init__(self, params, cfg: LMConfig, *, max_batch: int = 4,
                 max_len: int = 256, prompt_len: int = 32,
                 compute_dtype=jnp.float32, greedy: bool = True,
                 seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.prompt_len = prompt_len
        self.dtype = compute_dtype
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.caches = T.make_cache(cfg, max_batch, max_len, dtype=compute_dtype)
        self.pos = np.zeros(max_batch, dtype=np.int32)
        self.active = np.zeros(max_batch, dtype=bool)
        self.last_tok = np.zeros(max_batch, dtype=np.int32)
        self.budget = np.zeros(max_batch, dtype=np.int32)
        self.eos = np.full(max_batch, -1, dtype=np.int32)
        self.out: list[list | None] = [None] * max_batch

        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos,
                                               compute_dtype=compute_dtype))
        self._prefill = jax.jit(
            lambda p, t: T.prefill(p, cfg, t, max_len=max_len,
                                   compute_dtype=compute_dtype))

    # ------------------------------------------------------------------
    def _insert(self, slot: int, req: Request):
        prompt = np.asarray(req.prompt, dtype=np.int32)
        S = self.prompt_len
        if len(prompt) > S:
            prompt = prompt[-S:]
        pad = S - len(prompt)
        # left-pad by repeating the first token (harmless for synthetic LM);
        # an empty prompt degenerates to a BOS/0-token prefill
        fill = prompt[0] if len(prompt) else np.int32(0)
        padded = np.concatenate([np.full(pad, fill, np.int32), prompt])
        logits, pc = self._prefill(self.params, jnp.asarray(padded[None, :]))
        nxt = int(jnp.argmax(logits[0]))
        # scatter the single-request cache into the pool at `slot`
        # (prefill used the same max_len, so cache lengths line up)
        for layer in range(self.cfg.n_layers):
            pool, one = self.caches[layer], pc[layer]
            for name in pool:
                assert pool[name].shape[1:] == one[name].shape[1:]
                pool[name] = pool[name].at[slot].set(one[name][0])
        self.pos[slot] = S
        self.active[slot] = True
        self.last_tok[slot] = nxt
        self.budget[slot] = req.max_new_tokens - 1
        self.eos[slot] = req.eos_id
        self.out[slot] = list(prompt) + [nxt]

    def run(self, requests: Sequence[Request]) -> list[Completion]:
        queue = list(requests)
        results: dict[int, Completion] = {}
        owner: dict[int, int] = {}
        next_rid = 0
        done = 0
        while done < len(requests):
            # refill free slots
            for slot in range(self.max_batch):
                if not self.active[slot] and queue:
                    req = queue.pop(0)
                    self._insert(slot, req)
                    owner[slot] = next_rid
                    next_rid += 1
            if not self.active.any():
                break
            toks = jnp.asarray(self.last_tok[:, None])
            pos = jnp.asarray(self.pos)
            logits, self.caches = self._decode(self.params, self.caches,
                                               toks, pos)
            if self.greedy:
                nxt = np.asarray(jnp.argmax(logits, axis=-1))
            else:
                self.key, sub = jax.random.split(self.key)
                nxt = np.asarray(jax.random.categorical(sub, logits))
            for slot in range(self.max_batch):
                if not self.active[slot]:
                    continue
                self.out[slot].append(int(nxt[slot]))
                self.pos[slot] += 1
                self.last_tok[slot] = nxt[slot]
                self.budget[slot] -= 1
                if (self.budget[slot] <= 0
                        or int(nxt[slot]) == int(self.eos[slot])):
                    rid = owner[slot]
                    plen = self.prompt_len
                    results[rid] = Completion(tokens=self.out[slot],
                                              prompt_len=plen)
                    self.active[slot] = False
                    done += 1
        return [results[i] for i in sorted(results)]
