"""GraphSession: batched BFS query serving over one prepared graph
(DESIGN §2.5).

A session owns ALL prepared state for one graph — ordering decision,
permutation + inverse, BVSS, the compiled single-source engine, and the
batched multi-source wave machinery — via the single static pipeline in
:func:`repro.core.policy.prepare`.

Concurrent single-source level queries are served in *waves*,
ServeEngine-style (``repro.serve.engine``): a fixed pool of ``max_batch``
source columns advances in lock-step levels through one batched BVSS
bit-SpMM pull per level; a column whose frontier empties is harvested and
its slot refilled from the request queue mid-flight, so queries that arrive
together share every adjacency read regardless of how their depths differ.
Singleton traffic falls back to the fused single-source engine (whole level
loop on device, no per-level host sync).

Id-space contract: callers speak ORIGINAL vertex ids everywhere — sources
in, level arrays / centrality scores out.  The internal reordering is
invisible (the regression the old example got wrong).

A session is MESH-NATIVE (DESIGN §2.4): pass ``mesh=...`` and the whole
stack — prepare, the fused single-source engine, the wave machinery —
runs row-sharded under ``shard_map``.  The serving loop and the caller-id
contract are identical in either mode; the only difference is the shape
of the wave state (a leading shard axis), which the engine's
``levels_of`` view hides from this layer.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Sequence

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.multi_source import closeness_centrality, make_ms_engine
from repro.core.policy import PreparedBFS, prepare
from repro.graphs import Graph


class GraphSession:
    """Prepared, query-serving state for one graph.

    Parameters mirror :func:`repro.core.policy.prepare`; ``max_batch`` is
    the wave slot-pool width (the S of the stacked bit-SpMM frontier);
    ``mesh`` row-shards the session over a device mesh.
    """

    def __init__(self, g: Graph, *, max_batch: int = 8, sigma: int = 8,
                 w: int = 512, seed: int = 0,
                 lazy_threshold: float | None = None, order: bool = True,
                 engine: str | None = None, use_kernel: bool = True,
                 max_steps: int | None = None, mesh: Mesh | None = None,
                 mesh_axis: str = "data"):
        t0 = time.time()
        self.prepared: PreparedBFS = prepare(
            g, sigma=sigma, w=w, seed=seed, lazy_threshold=lazy_threshold,
            order=order, engine=engine, use_kernels=use_kernel,
            mesh=mesh, mesh_axis=mesh_axis)
        if self.prepared.problem is not None:
            self._problem = self.prepared.problem
        else:
            # non-BVSS engine override: the wave pool still needs the
            # device BVSS; keep it session-local so PreparedBFS keeps its
            # "problem is None for non-BVSS engines" invariant
            from repro.core.bfs import BlestProblem
            self._problem = BlestProblem.build(self.prepared.bvss)
        self.max_batch = int(max_batch)
        self._ms = make_ms_engine(self._problem, self.max_batch,
                                  use_kernel=use_kernel)
        self.max_steps = max_steps
        self.preprocess_s = time.time() - t0

    # ------------------------------------------------------------------
    # prepared-state passthrough
    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.prepared.graph.n

    @property
    def perm(self) -> np.ndarray:
        return self.prepared.perm

    @property
    def inv(self) -> np.ndarray:
        return self.prepared.inv

    @property
    def bvss(self):
        return self.prepared.bvss

    @property
    def ordering(self) -> str:
        return self.prepared.ordering

    @property
    def engine_name(self) -> str:
        return self.prepared.engine_name

    @property
    def mesh(self) -> Mesh | None:
        return self.prepared.mesh

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    def levels(self, src: int) -> np.ndarray:
        """Single-source BFS levels in caller ids (fused device loop)."""
        return self.prepared.levels(int(src))

    def levels_batch(self, sources: Sequence[int]) -> list[np.ndarray]:
        """Serve concurrent level queries as batched multi-source waves.

        Returns one level array per query, aligned with ``sources``, in
        the caller's vertex ids.  More queries than ``max_batch`` are
        queued and refilled into freed slots mid-flight.
        """
        srcs = [int(s) for s in sources]
        if not srcs:
            return []
        if len(srcs) == 1:  # singleton traffic: no batching win available
            return [self.levels(srcs[0])]
        eng = self._ms
        perm = self.perm
        queue = deque(enumerate(srcs))
        owner: list[int | None] = [None] * self.max_batch
        results: dict[int, np.ndarray] = {}
        st = eng.idle()
        limit = self.max_steps if self.max_steps is not None else \
            (len(srcs) + self.max_batch) * (self.n + 1)
        steps = 0
        while queue or any(o is not None for o in owner):
            refilled = False
            for slot in range(self.max_batch):
                if owner[slot] is None and queue:
                    rid, src = queue.popleft()
                    st = eng.insert(st, jnp.int32(slot),
                                    jnp.int32(perm[src]))
                    owner[slot] = rid
                    refilled = True
            if refilled:
                st = eng.requeue(st)
            st, live_dev = eng.level_step(st)
            live = np.asarray(live_dev)
            for slot in range(self.max_batch):
                if owner[slot] is not None and not live[slot]:
                    # levels_of hides the shard layout (global (n,) column)
                    lv = np.asarray(eng.levels_of(st, slot))
                    results[owner[slot]] = lv[perm]
                    owner[slot] = None
            steps += 1
            if steps > limit:
                raise RuntimeError(
                    f"wave serving did not converge in {limit} level steps")
        return [results[i] for i in range(len(srcs))]

    # ------------------------------------------------------------------
    # centrality
    # ------------------------------------------------------------------
    def closeness(self, sources: Sequence[int]) -> np.ndarray:
        """Closeness centrality of the given sources (caller ids in, one
        score per source out).  Fixed cohort, so this skips the host-driven
        wave loop and runs the fused on-device multi-source engine
        (DESIGN §2.5); scores are invariant under the internal reordering."""
        srcs = [int(s) for s in sources]
        if not srcs:
            return np.zeros(0, dtype=np.float64)
        internal = self.perm[np.asarray(srcs)].astype(np.int32)
        return closeness_centrality(self.prepared.graph, internal,
                                    problem=self._problem)

    def centrality_sample(self, n_sources: int, seed: int = 0
                          ) -> tuple[np.ndarray, np.ndarray]:
        """Sample ``n_sources`` vertices (caller ids) and return
        ``(sources, closeness scores)`` aligned index-by-index."""
        rng = np.random.default_rng(seed)
        srcs = rng.integers(0, self.n, n_sources)
        return srcs, self.closeness(srcs)
