"""GraphSession: batched BFS query serving over one prepared graph
(DESIGN §2.5).

A session owns ALL prepared state for one graph — ordering decision,
permutation + inverse, BVSS, the compiled single-source engine, and the
batched multi-source wave machinery — via the single static pipeline in
:func:`repro.core.policy.prepare`.

Concurrent single-source level queries are served in *waves*,
ServeEngine-style (``repro.serve.engine``): a fixed pool of ``max_batch``
source columns advances in lock-step levels through one batched BVSS
bit-SpMM pull per level; a column whose frontier empties is harvested and
its slot refilled from the request queue mid-flight, so queries that arrive
together share every adjacency read regardless of how their depths differ.
Singleton traffic falls back to the fused single-source engine (whole level
loop on device, no per-level host sync).

Id-space contract: callers speak ORIGINAL vertex ids everywhere — sources
in, level arrays / centrality scores / component labels out.  The internal
reordering is invisible (the regression the old example got wrong).

Beyond level queries, a session serves the ANALYTICS query kinds
(DESIGN §2.6) multiplexed onto the same ``max_batch`` slot pool:
``components()`` (flood-fill re-seeding through the generic wave refill
hook), ``eccentricity(batch)`` / ``extremes()`` (iFUB sweeps through the
fused multi-source engine), ``betweenness(...)`` (Brandes forward σ
channel + reverse tile sweep) and ``closeness(...)`` (exact or sampled,
a reduction over wave level channels).  The classical undirected
analytics run on a lazily-built symmetrised twin of the prepared problem
(same internal id space, so the caller-id contract is unchanged).

A session is MESH-NATIVE (DESIGN §2.4): pass ``mesh=...`` and the whole
stack — prepare, the fused single-source engine, the wave machinery —
runs row-sharded under ``shard_map``.  The serving loop and the caller-id
contract are identical in either mode; the only difference is the shape
of the wave state (a leading shard axis), which the engine's
``levels_of`` view hides from this layer.  EVERY analytics verb rides
the sharded surface when the session has a mesh — betweenness included:
its weighted sweeps run under ``shard_map`` on the session's own
row-sharded problem (forward σ channel via the per-level float gather,
backward via psum-scattered column reductions), with no replicated twin
anywhere.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from typing import Sequence

import numpy as np
from jax.sharding import Mesh

from repro.analytics import (ExtremesReport, betweenness_centrality,
                             closeness_centrality, connected_components,
                             eccentricities, ifub_extremes, make_pagerank,
                             make_sssp, out_degrees, pagerank_scores,
                             sssp_distances)
from repro.core.bfs import BlestProblem
from repro.core.multi_source import drive_wave, make_ms_engine
from repro.core.policy import PreparedBFS, PrepareOptions, prepare
from repro.errors import ConfigError, check_source, check_sources
from repro.graphs import Graph
from repro.kernels.ref import normalize_labels
from repro.serve.faults import NO_FAULTS, FaultPlan


def _alias_warning(old: str, new: str) -> None:
    warnings.warn(
        f"GraphSession.{old}() is a deprecated alias; call "
        f"GraphSession.{new}() (same semantics, the 0.5 verb convention: "
        f"singular verbs take src, batched verbs take sources, sampling "
        f"verbs take k with keyword-only seed)",
        DeprecationWarning, stacklevel=3)


class GraphSession:
    """Prepared, query-serving state for one graph.

    Parameters mirror :func:`repro.core.policy.prepare`; ``max_batch`` is
    the wave slot-pool width (the S of the stacked bit-SpMM frontier);
    ``mesh`` row-shards the session over a device mesh; ``weights`` (one
    strictly-positive float per CSR edge of ``g``) arms the weighted
    verbs — an unweighted session lazily defaults them to unit weights,
    so ``sssp`` degrades to hop counts and ``pagerank`` to the classic
    unweighted iteration (DESIGN §2.9).
    """

    #: every query verb a session serves — the CI verbs lane iterates
    #: this tuple and fails if any verb lacks an oracle-parity check
    VERBS = ("levels", "components", "eccentricity", "betweenness",
             "closeness", "sssp", "pagerank")

    def __init__(self, g: Graph, *, max_batch: int = 8,
                 options: PrepareOptions | None = None, sigma: int = 8,
                 w: int = 512, seed: int = 0,
                 lazy_threshold: float | None = None, order: bool = True,
                 engine: str | None = None, use_kernel: bool = True,
                 direction: str = "auto", autotune: bool = False,
                 max_steps: int | None = None, mesh: Mesh | None = None,
                 mesh_axis: str = "data", weights=None,
                 fault_plan: FaultPlan | None = None):
        t0 = time.time()
        if options is None:
            options = PrepareOptions(
                sigma=sigma, w=w, seed=seed, lazy_threshold=lazy_threshold,
                order=order, engine=engine, use_kernels=use_kernel,
                direction=direction, autotune=autotune, mesh=mesh,
                mesh_axis=mesh_axis, weights=weights)
        elif (sigma, w, seed, lazy_threshold, order, engine, use_kernel,
              direction, autotune, mesh, mesh_axis, weights) != \
                (8, 512, 0, None, True, None, True, "auto", False, None,
                 "data", None):
            raise ConfigError(
                "GraphSession takes EITHER options=PrepareOptions(...) or "
                "the per-knob keywords, not both")
        # fault seams (DESIGN §2.7): a FaultPlan's wrappers are baked into
        # every engine this session builds — including the single-source
        # engine's push seam, so they must exist BEFORE prepare(); the
        # default plan injects nothing and adds nothing to the trace
        self.fault_plan = fault_plan if fault_plan is not None else NO_FAULTS
        self._seams = self.fault_plan.engine_overrides(
            use_kernel=options.use_kernels)
        if self._seams.get("push_impl") is not None:
            options = options.replace(push_impl=self._seams["push_impl"])
        self.options = options
        self.prepared: PreparedBFS = prepare(g, options=options)
        self.max_batch = int(max_batch)
        self._use_kernel = options.use_kernels
        self._direction = options.direction
        self._mesh_axis = options.mesh_axis
        self.max_steps = max_steps
        self._bind_prepared()
        self.preprocess_s = time.time() - t0

    def _bind_prepared(self) -> None:
        """(Re)build everything derived from ``self.prepared`` — called at
        construction and after every :meth:`update_edges` epoch swap."""
        if self.prepared.problem is not None:
            self._problem = self.prepared.problem
        else:
            # non-BVSS engine override: the wave pool still needs the
            # device BVSS; keep it session-local so PreparedBFS keeps its
            # "problem is None for non-BVSS engines" invariant
            self._problem = BlestProblem.build(self.prepared.bvss)
        self._ms = make_ms_engine(self._problem, self.max_batch,
                                  use_kernel=self._use_kernel,
                                  direction=self._direction, **self._seams)
        # analytics problems/engines, built on first use and cached so
        # repeat queries never recompile (DESIGN §2.6)
        self._analytics_cache: dict = {}

    def update_edges(self, inserts=(), deletes=(), *, insert_weights=None,
                     expected_epoch: int | None = None,
                     staleness_budget: int | None = None):
        """Apply a streaming edge-update batch (caller ids) and swap the
        session to the next epoch (DESIGN §2.10); returns the
        :class:`~repro.core.bvss_delta.UpdateReport`.

        The swap is atomic from the session's point of view: waves in
        flight keep the OLD prepared state (its device buffers are never
        mutated) and finish on the old epoch; queries issued after this
        returns see the new one.  Derived engines — the wave pool, cached
        analytics twins — rebuild lazily against the new epoch."""
        from repro.core.bvss_delta import apply_edge_updates
        after = apply_edge_updates(
            self.prepared, inserts, deletes, insert_weights=insert_weights,
            expected_epoch=expected_epoch,
            staleness_budget=staleness_budget)
        if after is self.prepared:      # effective no-op: same epoch
            return None
        self.prepared = after
        self._bind_prepared()
        return after.last_update

    # ------------------------------------------------------------------
    # prepared-state passthrough
    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.prepared.graph.n

    @property
    def perm(self) -> np.ndarray:
        return self.prepared.perm

    @property
    def inv(self) -> np.ndarray:
        return self.prepared.inv

    @property
    def bvss(self):
        return self.prepared.bvss

    @property
    def ordering(self) -> str:
        return self.prepared.ordering

    @property
    def engine_name(self) -> str:
        return self.prepared.engine_name

    @property
    def mesh(self) -> Mesh | None:
        return self.prepared.mesh

    @property
    def epoch(self) -> int:
        """Edge-update epoch of the prepared state (DESIGN §2.10)."""
        return self.prepared.epoch

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    def levels(self, src: int) -> np.ndarray:
        """Single-source BFS levels in caller ids (fused device loop)."""
        return self.prepared.levels(src)

    def levels_batch(self, sources: Sequence[int], *,
                     should_harvest=None, on_harvested=None
                     ) -> list[np.ndarray | None]:
        """Serve concurrent level queries as batched multi-source waves.

        Returns one level array per query, aligned with ``sources``, in
        the caller's vertex ids.  More queries than ``max_batch`` are
        queued and refilled into freed slots mid-flight.

        ``should_harvest(i)`` / ``on_harvested(i, partial_levels)`` are
        the per-request cancellation hooks (DESIGN §2.7), in REQUEST
        INDEX space (``i`` indexes ``sources``): after every lock-step
        level each still-running request is offered to ``should_harvest``;
        answering True cancels it mid-flight — ``on_harvested`` receives
        the partial caller-id levels (unreached vertices ``INF``), the
        returned list carries ``None`` at that index, and the freed slot
        is refilled from the queue.  Singleton traffic normally takes the
        fused single-source engine, which cannot be preempted, so a
        singleton WITH hooks rides the wave pool instead.
        """
        srcs = check_sources(sources, self.n)
        if not srcs:
            return []
        if len(srcs) == 1 and should_harvest is None:
            # singleton traffic: no batching win available
            return [self.levels(srcs[0])]
        perm = self.perm
        queue = deque(enumerate(srcs))
        owner: list[int | None] = [None] * self.max_batch
        results: dict[int, np.ndarray | None] = {}

        def next_source(slot: int) -> int | None:
            if not queue:
                return None
            rid, src = queue.popleft()
            owner[slot] = rid
            return int(perm[src])

        def on_converged(slot: int, lv: np.ndarray) -> None:
            results[owner[slot]] = lv[perm]
            owner[slot] = None

        _should = _harvested = None
        if should_harvest is not None:
            def _should(slot: int) -> bool:
                rid = owner[slot]
                return rid is not None and bool(should_harvest(rid))

            def _harvested(slot: int, lv: np.ndarray) -> None:
                rid = owner[slot]
                if on_harvested is not None:
                    on_harvested(rid, lv[perm])
                results[rid] = None
                owner[slot] = None

        limit = self.max_steps if self.max_steps is not None else \
            (len(srcs) + self.max_batch) * (self.n + 1)
        drive_wave(self._ms, next_source, on_converged, max_steps=limit,
                   should_harvest=_should, on_harvested=_harvested)
        return [results[i] for i in range(len(srcs))]

    # ------------------------------------------------------------------
    # centrality
    # ------------------------------------------------------------------
    def closeness_batch(self, sources: Sequence[int] | None = None, *,
                        wf_improved: bool = False) -> np.ndarray:
        """Closeness centrality (caller ids throughout): one score per
        given source, or — with ``sources=None`` — the EXACT variant, one
        score per vertex in caller-id order.  Fixed cohorts, so this
        skips the host-driven wave loop and runs the cached fused
        multi-source engine (DESIGN §2.5/§2.6); scores are invariant
        under the internal reordering and the mesh sharding."""
        if sources is None:
            internal = self.perm.astype(np.int64)   # caller v -> perm[v]
        else:
            srcs = check_sources(sources, self.n)
            if not srcs:
                return np.zeros(0, dtype=np.float64)
            internal = self.perm[np.asarray(srcs)].astype(np.int64)
        width = min(self.max_batch, len(internal))
        return closeness_centrality(None, internal, batch=width,
                                    wf_improved=wf_improved,
                                    levels_fn=self._dir_wave(width))

    def closeness(self, sources: Sequence[int] | None = None, *,
                  wf_improved: bool = False) -> np.ndarray:
        """Deprecated alias of :meth:`closeness_batch`."""
        _alias_warning("closeness", "closeness_batch")
        return self.closeness_batch(sources, wf_improved=wf_improved)

    def closeness_sample(self, k: int, *, seed: int = 0
                         ) -> tuple[np.ndarray, np.ndarray]:
        """Sample ``k`` vertices (caller ids) and return
        ``(sources, closeness scores)`` aligned index-by-index."""
        rng = np.random.default_rng(seed)
        srcs = rng.integers(0, self.n, int(k))
        return srcs, self.closeness_batch(srcs)

    def centrality_sample(self, n_sources: int, seed: int = 0
                          ) -> tuple[np.ndarray, np.ndarray]:
        """Deprecated alias of :meth:`closeness_sample`."""
        _alias_warning("centrality_sample", "closeness_sample")
        return self.closeness_sample(n_sources, seed=seed)

    # ------------------------------------------------------------------
    # analytics query kinds (DESIGN §2.6)
    # ------------------------------------------------------------------
    def _sym_problem(self) -> BlestProblem:
        """The symmetrised twin of the prepared problem (same internal id
        space — symmetrisation commutes with the reordering), backing the
        classical undirected analytics; sharded when the session is."""
        if "sym_problem" not in self._analytics_cache:
            from repro.core.policy import build_problem
            gs = self.prepared.graph.symmetrized
            self._analytics_cache["sym_problem"] = build_problem(
                gs, sigma=self.prepared.bvss.sigma, mesh=self.mesh,
                mesh_axis=self._mesh_axis)
        return self._analytics_cache["sym_problem"]

    def _sym_ms(self):
        """Wave slot pool over the symmetrised problem (flood-fill)."""
        if "sym_ms" not in self._analytics_cache:
            self._analytics_cache["sym_ms"] = make_ms_engine(
                self._sym_problem(), self.max_batch,
                use_kernel=self._use_kernel, **self._seams)
        return self._analytics_cache["sym_ms"]

    def _sym_sss(self):
        """Fused single-source engine on the symmetrised problem (the
        flood-fill's phase-0 giant-component pass)."""
        if "sym_sss" not in self._analytics_cache:
            from repro.core.bfs import make_blest_bfs
            self._analytics_cache["sym_sss"] = make_blest_bfs(
                self._sym_problem(), lazy=False,
                use_kernels=self._use_kernel)
        return self._analytics_cache["sym_sss"]

    def _sym_wave(self, width: int):
        """Cached fixed-cohort multi-source fn on the symmetrised problem
        (eccentricity batches; one compile per distinct width)."""
        key = ("sym_wave", width)
        if key not in self._analytics_cache:
            from repro.core.multi_source import make_multi_source_bfs
            self._analytics_cache[key] = make_multi_source_bfs(
                None, width, problem=self._sym_problem(),
                use_kernel=self._use_kernel)
        return self._analytics_cache[key]

    def _dir_wave(self, width: int):
        """Cached fixed-cohort multi-source fn on the session's own
        (directed, possibly sharded) problem — closeness cohorts; one
        compile per distinct width."""
        key = ("dir_wave", width)
        if key not in self._analytics_cache:
            from repro.core.multi_source import make_multi_source_bfs
            self._analytics_cache[key] = make_multi_source_bfs(
                None, width, problem=self._problem,
                use_kernel=self._use_kernel)
        return self._analytics_cache[key]

    def _bc_fn(self, width: int):
        """Cached Brandes forward+backward fn on the session's own
        problem — mesh-native when the session is sharded (one compile
        per width; zero replicated weighted sweeps, DESIGN §2.6)."""
        key = ("bc_fn", width)
        if key not in self._analytics_cache:
            from repro.analytics import make_betweenness
            self._analytics_cache[key] = make_betweenness(
                self._problem, width, use_kernel=self._use_kernel,
                spmm_w_impl=self._seams.get("spmm_w_impl"))
        return self._analytics_cache[key]

    def components(self) -> np.ndarray:
        """Connected-component labels, one per vertex in caller ids,
        normalised to 0..k-1 in order of each component's smallest caller
        vertex.  Phase 0 floods one component through the fused
        single-source engine; the wave slot pool then flood-fills the
        rest, converged slots re-seeded from still-untouched vertices —
        the serving refill loop aimed at the graph itself."""
        labels = connected_components(engine=self._sym_ms(),
                                      first_flood=self._sym_sss())
        return normalize_labels(labels[self.perm])

    def eccentricity_batch(self, sources: Sequence[int]) -> np.ndarray:
        """Eccentricity of each queried vertex (caller ids in, one value
        per source out), batched through the fused multi-source engine on
        the symmetrised problem."""
        srcs = np.asarray(check_sources(sources, self.n), dtype=np.int64)
        if len(srcs) == 0:
            return np.zeros(0, dtype=np.int64)
        internal = self.perm[srcs]
        width = min(self.max_batch, len(srcs))
        return eccentricities(internal, problem=self._sym_problem(),
                              batch=width, use_kernel=self._use_kernel,
                              levels_fn=self._sym_wave(width))

    def eccentricity(self, sources: Sequence[int]) -> np.ndarray:
        """Deprecated alias of :meth:`eccentricity_batch`."""
        _alias_warning("eccentricity", "eccentricity_batch")
        return self.eccentricity_batch(sources)

    def extremes(self, *, max_evals: int | None = None) -> ExtremesReport:
        """iFUB diameter / radius bounds of the largest component
        (center/periphery reported in caller ids)."""
        labels = self.components()
        comp = int(np.bincount(labels).argmax())
        members = np.flatnonzero(labels == comp)
        deg = (self.prepared.graph.out_degree
               + self.prepared.graph.in_degree)[self.perm[members]]
        start = int(members[int(np.argmax(deg))])
        rep = ifub_extremes(problem=self._sym_problem(),
                            start=int(self.perm[start]),
                            batch=self.max_batch,
                            use_kernel=self._use_kernel,
                            max_evals=max_evals,
                            levels_fn=self._sym_wave(self.max_batch))
        inv = self.inv
        return ExtremesReport(
            diameter_lb=rep.diameter_lb, diameter_ub=rep.diameter_ub,
            radius_ub=rep.radius_ub, center=int(inv[rep.center]),
            periphery=int(inv[rep.periphery]),
            n_ecc_evals=rep.n_ecc_evals)

    def betweenness_batch(self, sources: Sequence[int]) -> np.ndarray:
        """Partial Brandes betweenness Σ_{s∈sources} δ_s(v) on the
        directed graph (unnormalised, endpoints excluded): one score per
        vertex, caller ids throughout.  Forward phase = the fused wave
        BFS with the σ path-count channel; backward = the reverse sweep
        over the recorded per-level tile queues.  Mesh-native on a
        sharded session: both phases run under shard_map on the
        session's own row-sharded problem (DESIGN §2.6)."""
        srcs = np.asarray(check_sources(sources, self.n), dtype=np.int64)
        if len(srcs) == 0:
            return np.zeros(self.n, dtype=np.float64)
        internal = self.perm[srcs].astype(np.int32)
        width = min(self.max_batch, len(srcs))
        bc = betweenness_centrality(None, internal,
                                    problem=self._problem,
                                    use_kernel=self._use_kernel,
                                    batch=width,
                                    bc_fn=self._bc_fn(width))
        return bc[self.perm]

    def betweenness(self, sources: Sequence[int]) -> np.ndarray:
        """Deprecated alias of :meth:`betweenness_batch`."""
        _alias_warning("betweenness", "betweenness_batch")
        return self.betweenness_batch(sources)

    def betweenness_sample(self, k: int, *, seed: int = 0
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Sample ``k`` distinct pivots (caller ids) and return
        ``(sources, partial betweenness per vertex)`` — the standard
        sampled-source Brandes estimator."""
        rng = np.random.default_rng(seed)
        k = min(int(k), self.n)
        srcs = rng.choice(self.n, size=k, replace=False)
        return srcs, self.betweenness_batch(srcs)

    # ------------------------------------------------------------------
    # weighted verbs (DESIGN §2.9)
    # ------------------------------------------------------------------
    def _weights_ord(self) -> np.ndarray:
        """Per-edge weights in the REORDERED graph's CSR edge order —
        the session's own if it was built with ``weights=...``, else the
        lazy unit-weight default."""
        if self.prepared.weights is not None:
            return self.prepared.weights
        if "unit_weights" not in self._analytics_cache:
            self._analytics_cache["unit_weights"] = np.ones(
                self.prepared.graph.m, dtype=np.float32)
        return self._analytics_cache["unit_weights"]

    def _wplane(self):
        """The device weight plane the weighted verbs pull against:
        ``prepare``'s committed plane on a weighted session, a lazily
        built (and cached) unit plane otherwise — the same deterministic
        slice layout either way, so it aligns with the session's problem
        bit-for-bit."""
        if self.prepared.wplane is not None:
            return self.prepared.wplane
        if self._problem.is_2d:
            from repro.errors import ConfigError
            raise ConfigError(
                "weighted verbs are not supported on a 2-D (row × column) "
                "mesh yet — the weighted verbs ship 1-D row-sharded "
                "(DESIGN §2.9); use a 1-D mesh or a single device")
        if "unit_wplane" not in self._analytics_cache:
            from repro.core.bvss import (build_sharded_bvss,
                                         build_sharded_weight_plane,
                                         build_weight_plane,
                                         weight_plane_to_device)
            g_ord = self.prepared.graph
            ones = self._weights_ord()
            sigma = self.prepared.bvss.sigma
            if self.mesh is not None:
                sb = build_sharded_bvss(
                    g_ord, self.mesh.shape[self._mesh_axis], sigma=sigma)
                plane = weight_plane_to_device(
                    build_sharded_weight_plane(g_ord, ones, sb),
                    self.mesh, self._mesh_axis)
            else:
                plane = weight_plane_to_device(
                    build_weight_plane(g_ord, ones, sigma=sigma))
            self._analytics_cache["unit_wplane"] = plane
        return self._analytics_cache["unit_wplane"]

    def _sssp_fn(self, width: int):
        """Cached delta-stepping engine of cohort width ``width`` on the
        session's own (possibly sharded) problem."""
        key = ("sssp_fn", width)
        if key not in self._analytics_cache:
            self._analytics_cache[key] = make_sssp(
                self._problem, self._wplane(), width,
                use_kernel=self._use_kernel)
        return self._analytics_cache[key]

    def sssp(self, src: int, *, delta: float | None = None) -> np.ndarray:
        """Single-source shortest-path distances from ``src`` (caller
        ids in and out): one float64 distance per vertex, ``+inf`` where
        unreachable.  Delta-stepping over the min-plus tile product
        against the session's weight plane (unit weights on an
        unweighted session, where this equals BFS hop counts).  ``delta``
        overrides the bucket width (performance only, never
        correctness)."""
        src = check_source(src, self.n)
        dist = sssp_distances(
            [int(self.perm[src])], problem=self._problem,
            wplane=self._wplane(), weights=self._weights_ord(),
            batch=1, delta=delta, sssp_fn=self._sssp_fn(1))
        return dist[0][self.perm]

    def sssp_batch(self, sources: Sequence[int], *,
                   delta: float | None = None) -> np.ndarray:
        """Distances from each source (rows, aligned with ``sources``)
        to every vertex (cols): (S, n) float64, caller ids throughout.
        Cohorts of ``max_batch`` stacked distance columns share one
        min-plus tile stream."""
        srcs = np.asarray(check_sources(sources, self.n), dtype=np.int64)
        if len(srcs) == 0:
            return np.zeros((0, self.n), dtype=np.float64)
        width = min(self.max_batch, len(srcs))
        dist = sssp_distances(
            self.perm[srcs], problem=self._problem, wplane=self._wplane(),
            weights=self._weights_ord(), batch=width, delta=delta,
            sssp_fn=self._sssp_fn(width))
        return dist[:, self.perm]

    def pagerank(self, *, damping: float = 0.85, tol: float = 1e-8,
                 max_iter: int = 200) -> np.ndarray:
        """PageRank scores, one per vertex in caller-id order (sums to
        1): damped power iteration with dangling-mass correction, fused
        on device over the float tile product (DESIGN §2.9).  STRUCTURAL
        PageRank — the classic definition over the adjacency, so session
        edge weights do not influence the scores (the float channel
        carries rank mass, not edge weights)."""
        key = ("pagerank_fn", float(damping), float(tol), int(max_iter))
        if key not in self._analytics_cache:
            self._analytics_cache[key] = make_pagerank(
                self._problem, out_degrees(self.prepared.graph),
                use_kernel=self._use_kernel, damping=damping, tol=tol,
                max_iter=max_iter)
        r = pagerank_scores(pagerank_fn=self._analytics_cache[key])
        return r[self.perm]
