from .csr import Graph, from_edges, src_of_edges, to_dense_bits
from . import generators

__all__ = ["Graph", "from_edges", "src_of_edges", "to_dense_bits", "generators"]
