"""Synthetic graph generators covering the paper's benchmark families.

The paper evaluates on (i) social-like / scale-free graphs (GAP-twitter,
GAP-kron, com-Friendster, web crawls) and (ii) non-social high-diameter
graphs (GAP-road, europe_osm, delaunay, rgg).  We provide generators for
both regimes plus degenerate shapes used by property tests.
"""
from __future__ import annotations

import numpy as np

from .csr import Graph, from_edges


def rmat(n_log2: int, avg_degree: int = 16, *, a=0.57, b=0.19, c=0.19,
         seed: int = 0) -> Graph:
    """R-MAT / Kronecker-style scale-free digraph (GAP-kron regime)."""
    n = 1 << n_log2
    m = n * avg_degree
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for level in range(n_log2):
        r = rng.random(m)
        # quadrant probabilities a, b, c, d
        src_bit = (r >= a + b).astype(np.int64)
        dst_bit = (((r >= a) & (r < a + b)) | (r >= a + b + c)).astype(np.int64)
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    return from_edges(n, src, dst)


def erdos_renyi(n: int, avg_degree: float, seed: int = 0) -> Graph:
    """Uniform random digraph (GAP-urand regime)."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    return from_edges(n, src, dst)


def grid2d(rows: int, cols: int, *, seed: int = 0, shuffle: bool = False) -> Graph:
    """4-neighbour grid digraph, both directions (road-network regime).

    With ``shuffle=True`` the natural (bandwidth-friendly) labelling is
    destroyed, which is the regime where RCM reordering pays off.
    """
    n = rows * cols
    idx = np.arange(n).reshape(rows, cols)
    src, dst = [], []
    right = (idx[:, :-1].ravel(), idx[:, 1:].ravel())
    down = (idx[:-1, :].ravel(), idx[1:, :].ravel())
    for s, d in (right, down):
        src.append(s); dst.append(d)
        src.append(d); dst.append(s)
    src = np.concatenate(src)
    dst = np.concatenate(dst)
    if shuffle:
        rng = np.random.default_rng(seed)
        perm = rng.permutation(n)
        src, dst = perm[src], perm[dst]
    return from_edges(n, src, dst)


def star(n: int, out_hub: bool = True) -> Graph:
    """Star graph: hub 0 connected to all others (vsp_msc-like regime)."""
    others = np.arange(1, n, dtype=np.int64)
    hub = np.zeros(n - 1, dtype=np.int64)
    if out_hub:
        src = np.concatenate([hub, others])
        dst = np.concatenate([others, hub])
    else:
        src, dst = others, hub
    return from_edges(n, src, dst)


def path(n: int, bidirectional: bool = True) -> Graph:
    src = np.arange(n - 1, dtype=np.int64)
    dst = src + 1
    if bidirectional:
        return from_edges(n, np.concatenate([src, dst]),
                          np.concatenate([dst, src]))
    return from_edges(n, src, dst)


def random_digraph(n: int, m: int, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    return from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m))


def clustered(n_clusters: int, cluster_size: int, p_in: float = 0.4,
              p_out: float = 0.005, seed: int = 0) -> Graph:
    """Planted-partition graph: strong communities (Jaccard-ordering regime)."""
    n = n_clusters * cluster_size
    rng = np.random.default_rng(seed)
    src, dst = [], []
    m_in = int(p_in * cluster_size * cluster_size)
    for c in range(n_clusters):
        base = c * cluster_size
        src.append(rng.integers(base, base + cluster_size, m_in))
        dst.append(rng.integers(base, base + cluster_size, m_in))
    m_out = int(p_out * n * 10)
    src.append(rng.integers(0, n, m_out))
    dst.append(rng.integers(0, n, m_out))
    g = from_edges(n, np.concatenate(src), np.concatenate(dst))
    # shuffle labels so orderings have work to do
    perm = rng.permutation(n)
    return g.permute_fast(perm)


def rgg2d(n: int, radius: float | None = None, seed: int = 0) -> Graph:
    """Random geometric graph on the unit square (rgg_24 regime)."""
    rng = np.random.default_rng(seed)
    if radius is None:
        radius = 1.8 / np.sqrt(n)
    pts = rng.random((n, 2))
    # grid binning for near-linear neighbour search
    cell = radius
    nbins = max(1, int(1.0 / cell))
    bx = np.minimum((pts[:, 0] / cell).astype(np.int64), nbins - 1)
    by = np.minimum((pts[:, 1] / cell).astype(np.int64), nbins - 1)
    bin_id = bx * nbins + by
    order = np.argsort(bin_id, kind="stable")
    src_l, dst_l = [], []
    sorted_bin = bin_id[order]
    starts = np.searchsorted(sorted_bin, np.arange(nbins * nbins))
    ends = np.searchsorted(sorted_bin, np.arange(nbins * nbins), side="right")
    for gx in range(nbins):
        for gy in range(nbins):
            b = gx * nbins + gy
            mine = order[starts[b]:ends[b]]
            if len(mine) == 0:
                continue
            cand = [mine]
            for dx in (-1, 0, 1):
                for dy in (-1, 0, 1):
                    if dx == 0 and dy == 0:
                        continue
                    nx, ny = gx + dx, gy + dy
                    if 0 <= nx < nbins and 0 <= ny < nbins:
                        nb = nx * nbins + ny
                        cand.append(order[starts[nb]:ends[nb]])
            cand = np.concatenate(cand)
            d2 = ((pts[mine, None, :] - pts[None, cand, :]) ** 2).sum(-1)
            ii, jj = np.nonzero(d2 <= radius * radius)
            s, d = mine[ii], cand[jj]
            keep = s != d
            src_l.append(s[keep]); dst_l.append(d[keep])
    if not src_l:
        return from_edges(n, np.array([], dtype=np.int64),
                          np.array([], dtype=np.int64))
    return from_edges(n, np.concatenate(src_l), np.concatenate(dst_l))
