"""Graph containers.

A ``Graph`` stores a simple directed graph in CSR (out-neighbour) form and
lazily materialises the in-neighbour (CSC / transposed CSR) view that the
pull-based BFS pipeline consumes.  All construction is host-side NumPy; the
device-facing structures (BVSS, bit-adjacency) are built from these arrays.

Construction VALIDATES the CSR invariants (shape, monotone ``indptr``,
in-range ``indices``, integer dtypes) and raises
:class:`repro.errors.GraphValidationError` with a descriptive message —
not a bare ``assert``, so a malformed graph is rejected even under
``python -O`` (DESIGN §2.7).
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

from repro.errors import GraphValidationError


@dataclasses.dataclass(frozen=True)
class Graph:
    """Simple directed graph, CSR over out-neighbours."""

    n: int
    indptr: np.ndarray   # (n+1,) int64
    indices: np.ndarray  # (m,)  int32, out-neighbour lists, sorted per row

    def __post_init__(self):
        if not isinstance(self.n, (int, np.integer)) or self.n < 0:
            raise GraphValidationError(
                f"vertex count n must be a non-negative integer, got "
                f"{self.n!r}")
        indptr = np.asarray(self.indptr)
        indices = np.asarray(self.indices)
        if not np.issubdtype(indptr.dtype, np.integer):
            raise GraphValidationError(
                f"indptr must be an integer array, got dtype {indptr.dtype}")
        if not np.issubdtype(indices.dtype, np.integer):
            raise GraphValidationError(
                f"indices must be an integer array, got dtype "
                f"{indices.dtype}")
        if indptr.shape != (self.n + 1,):
            raise GraphValidationError(
                f"indptr has shape {indptr.shape}, expected ({self.n + 1},) "
                f"for a graph with n={self.n} vertices")
        if indptr[0] != 0:
            raise GraphValidationError(
                f"indptr[0] must be 0, got {int(indptr[0])}")
        if indptr[-1] != len(indices):
            raise GraphValidationError(
                f"indptr[-1]={int(indptr[-1])} does not match "
                f"len(indices)={len(indices)}")
        if len(indptr) > 1 and (np.diff(indptr) < 0).any():
            bad = int(np.flatnonzero(np.diff(indptr) < 0)[0])
            raise GraphValidationError(
                f"indptr must be non-decreasing; decreases at row {bad} "
                f"({int(indptr[bad])} -> {int(indptr[bad + 1])})")
        if len(indices) and (int(indices.min()) < 0
                             or int(indices.max()) >= self.n):
            bad_vals = indices[(indices < 0) | (indices >= self.n)]
            raise GraphValidationError(
                f"indices contain out-of-range vertex ids "
                f"{bad_vals[:8].tolist()} (valid ids are 0..{self.n - 1})")

    @property
    def m(self) -> int:
        return int(len(self.indices))

    @cached_property
    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    @cached_property
    def in_degree(self) -> np.ndarray:
        return np.bincount(self.indices, minlength=self.n).astype(np.int64)

    def neighbours(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]

    # -- transposed (in-neighbour) view: row u of A^T = incoming edges of u --
    @cached_property
    def t_csr(self) -> tuple[np.ndarray, np.ndarray]:
        """CSR of the transposed graph: (indptr, indices)."""
        order = np.argsort(self.indices, kind="stable")
        t_indices = src_of_edges(self)[order].astype(np.int32)
        counts = np.bincount(self.indices, minlength=self.n)
        t_indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(counts, out=t_indptr[1:])
        return t_indptr, t_indices

    def transpose(self) -> "Graph":
        t_indptr, t_indices = self.t_csr
        return Graph(self.n, t_indptr, t_indices)

    def _check_perm(self, perm: np.ndarray) -> np.ndarray:
        """Validate that ``perm`` is a permutation of 0..n-1."""
        raw = np.asarray(perm)
        if raw.shape != (self.n,):
            raise GraphValidationError(
                f"perm has shape {raw.shape}, expected ({self.n},)")
        if raw.size and not np.issubdtype(raw.dtype, np.integer):
            raise GraphValidationError(
                f"perm must be an integer array, got dtype {raw.dtype}")
        perm = raw.astype(np.int64)
        if self.n:
            oob = ((perm < 0) | (perm >= self.n)).any()
            if oob or (np.bincount(perm if not oob else
                                   np.clip(perm, 0, self.n - 1),
                                   minlength=self.n) != 1).any():
                raise GraphValidationError(
                    "perm is not a permutation of 0..n-1 (duplicate, "
                    "negative or out-of-range entries)")
        return perm

    def permute(self, perm: np.ndarray) -> "Graph":
        """Relabel vertices: new id of old vertex v is ``perm[v]``."""
        perm = self._check_perm(perm)
        inv = np.empty_like(perm)
        inv[perm] = np.arange(self.n)
        # Row u of the new graph is row inv[u] of the old one, with relabelled
        # column ids.
        new_deg = self.out_degree[inv]
        new_indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(new_deg, out=new_indptr[1:])
        new_indices = np.empty(self.m, dtype=np.int32)
        for u in range(self.n):
            old = inv[u]
            s, e = self.indptr[old], self.indptr[old + 1]
            seg = perm[self.indices[s:e]]
            seg.sort()
            new_indices[new_indptr[u]:new_indptr[u + 1]] = seg
        return Graph(self.n, new_indptr, new_indices)

    def permute_fast(self, perm: np.ndarray) -> "Graph":
        """Vectorised relabel (equivalent to :meth:`permute`)."""
        perm = self._check_perm(perm)
        src = perm[src_of_edges(self)]
        dst = perm[self.indices.astype(np.int64)]
        return from_edges(self.n, src, dst, dedup=False)

    @cached_property
    def symmetrized(self) -> "Graph":
        src = src_of_edges(self)
        dst = self.indices.astype(np.int64)
        return from_edges(
            self.n, np.concatenate([src, dst]), np.concatenate([dst, src]),
            dedup=True)

    def bandwidth(self) -> int:
        """Max |u - v| over edges (matrix bandwidth of the adjacency)."""
        if self.m == 0:
            return 0
        src = src_of_edges(self)
        return int(np.abs(src - self.indices.astype(np.int64)).max())


def src_of_edges(g: Graph) -> np.ndarray:
    """(m,) array of edge sources aligned with ``g.indices``."""
    return np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))


def from_edges(n: int, src: np.ndarray, dst: np.ndarray, *,
               dedup: bool = True, drop_loops: bool = True) -> Graph:
    """Build a Graph from edge lists (vectorised)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if drop_loops:
        keep = src != dst
        src, dst = src[keep], dst[keep]
    key = src * n + dst
    if dedup:
        key = np.unique(key)
    else:
        key = np.sort(key)
    src = key // n
    dst = key % n
    counts = np.bincount(src, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return Graph(n, indptr, dst.astype(np.int32))


def to_dense_bits(g: Graph, sigma_pad: int = 32) -> np.ndarray:
    """Packed bit-adjacency of the *transposed* graph.

    Returns (n, ceil(n/32)) uint32 where bit v of row u is set iff edge
    v -> u exists (the pull view).  Only for small test graphs.
    """
    n_words = (g.n + 31) // 32
    out = np.zeros((g.n, n_words), dtype=np.uint32)
    t_indptr, t_indices = g.t_csr
    for u in range(g.n):
        cols = t_indices[t_indptr[u]:t_indptr[u + 1]].astype(np.int64)
        np.bitwise_or.at(out[u], cols // 32,
                         (np.uint32(1) << (cols % 32).astype(np.uint32)))
    return out
