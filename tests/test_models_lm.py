"""Transformer variants: loss/grad finiteness, decode==forward consistency,
rotating-window caches, streaming-CE equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LMConfig, MoEConfig
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
B, S, V = 2, 32, 128

VARIANTS = {
    "gqa_qknorm": LMConfig(name="t", n_layers=3, d_model=64, n_heads=4,
                           n_kv_heads=2, d_head=16, d_ff=128, vocab=V,
                           qk_norm=True),
    "swa": LMConfig(name="t", n_layers=3, d_model=64, n_heads=4,
                    n_kv_heads=2, d_head=16, d_ff=128, vocab=V, window=8,
                    local_global=(1, 0), tie_embeddings=False),
    "local_global": LMConfig(name="t", n_layers=4, d_model=64, n_heads=4,
                             n_kv_heads=1, d_head=16, d_ff=128, vocab=V,
                             window=8, local_global=(2, 1)),
    "moe": LMConfig(name="t", n_layers=3, d_model=64, n_heads=4,
                    n_kv_heads=4, d_head=16, d_ff=128, vocab=V,
                    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32,
                                  capacity_factor=16.0)),
    "mla_ds3_mtp": LMConfig(name="t", n_layers=4, d_model=64, n_heads=4,
                            n_kv_heads=4, d_head=32, d_ff=128, vocab=V,
                            attn="mla", q_lora_rank=48, kv_lora_rank=32,
                            qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                            moe=MoEConfig(n_experts=8, top_k=2, d_expert=32,
                                          n_shared=1, router="sigmoid_ds3",
                                          capacity_factor=16.0),
                            n_dense_layers=2, dense_d_ff=96, mtp=True),
}


@pytest.mark.parametrize("name", sorted(VARIANTS))
def test_loss_grads_finite(name):
    cfg = VARIANTS[name]
    params = T.init_lm(KEY, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V)
    loss, grads = jax.value_and_grad(
        lambda p: T.lm_loss(p, cfg, tokens, compute_dtype=jnp.float32,
                            remat=False))(params)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree_util.tree_leaves(grads))


@pytest.mark.parametrize("name", sorted(VARIANTS))
def test_axes_tree_matches_params(name):
    cfg = VARIANTS[name]
    params = T.init_lm(KEY, cfg)
    axes = T.lm_axes(cfg)
    pt = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, params))
    at = jax.tree_util.tree_structure(jax.tree_util.tree_map(
        lambda x: 0, axes, is_leaf=lambda t: isinstance(t, tuple)))
    assert pt == at
    # every leaf's logical tuple matches the param rank
    flat_p = jax.tree_util.tree_leaves(params)
    flat_a = jax.tree_util.tree_leaves(
        axes, is_leaf=lambda t: isinstance(t, tuple))
    for p, a in zip(flat_p, flat_a):
        assert len(a) == p.ndim or a == ()


@pytest.mark.parametrize("name", ["gqa_qknorm", "local_global", "moe",
                                  "mla_ds3_mtp"])
def test_decode_matches_forward(name):
    cfg = VARIANTS[name]
    params = T.init_lm(KEY, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, V)
    logits_full, _ = T.forward(params, cfg, tokens,
                               compute_dtype=jnp.float32, remat=False)
    lp, caches = T.prefill(params, cfg, tokens[:, :S // 2], max_len=S,
                           compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lp),
                               np.asarray(logits_full[:, S // 2 - 1]),
                               rtol=3e-4, atol=3e-4)
    for pos in range(S // 2, S // 2 + 4):
        ld, caches = T.decode_step(params, cfg, caches,
                                   tokens[:, pos:pos + 1], jnp.int32(pos),
                                   compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(ld),
                                   np.asarray(logits_full[:, pos]),
                                   rtol=5e-4, atol=5e-4)


def test_window_rotation_long_decode():
    cfg = VARIANTS["swa"]
    params = T.init_lm(KEY, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, V)
    logits_full, _ = T.forward(params, cfg, tokens,
                               compute_dtype=jnp.float32, remat=False)
    _, caches = T.prefill(params, cfg, tokens[:, :4], max_len=S,
                          compute_dtype=jnp.float32)
    for pos in range(4, 28):  # decode well past the window wraparound
        ld, caches = T.decode_step(params, cfg, caches,
                                   tokens[:, pos:pos + 1], jnp.int32(pos),
                                   compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(ld),
                                   np.asarray(logits_full[:, pos]),
                                   rtol=5e-4, atol=5e-4)


def test_chunked_ce_equals_direct():
    cfg = VARIANTS["gqa_qknorm"]
    params = T.init_lm(KEY, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, 48), 0, V)
    logits, h = T.forward(params, cfg, tokens, compute_dtype=jnp.float32,
                          remat=False)
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    direct = -jnp.take_along_axis(
        lp, tokens[:, 1:][..., None].astype(jnp.int32), axis=-1).mean()
    head = params["embed"].T.astype(jnp.float32)
    chunked = T._chunked_nll(h[:, :-1].astype(jnp.float32), head,
                             tokens[:, 1:], chunk=16)
    np.testing.assert_allclose(float(chunked), float(direct), rtol=1e-5)


def test_per_slot_positions_decode():
    """Continuous batching: different positions per slot must equal
    per-slot independent decodes."""
    cfg = VARIANTS["gqa_qknorm"]
    params = T.init_lm(KEY, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, V)
    # fill slot 0 with 8 tokens, slot 1 with 5 tokens
    lens = [8, 5]
    logits_ind = []
    caches_ind = []
    for b in range(2):
        lg, c = T.prefill(params, cfg, tokens[b:b + 1, :lens[b]], max_len=16,
                          compute_dtype=jnp.float32)
        logits_ind.append(lg)
        caches_ind.append(c)
    # merge into one batch cache
    merged = []
    for lc0, lc1 in zip(*caches_ind):
        merged.append({k: jnp.concatenate([lc0[k], lc1[k]], axis=0)
                       for k in lc0})
    pos = jnp.asarray(lens, jnp.int32)
    tok = jnp.asarray([[int(tokens[0, lens[0]])], [int(tokens[1, lens[1]])]],
                      dtype=jnp.int32)
    lg_b, _ = T.decode_step(params, cfg, merged, tok, pos,
                            compute_dtype=jnp.float32)
    for b in range(2):
        lg_s, _ = T.decode_step(params, cfg, caches_ind[b], tok[b:b + 1],
                                jnp.int32(lens[b]),
                                compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg_b[b]), np.asarray(lg_s[0]),
                                   rtol=3e-4, atol=3e-4)
