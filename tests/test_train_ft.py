"""Training substrate + fault tolerance: checkpoint/restart bit-exactness,
resharding, straggler mitigation, gradient compression convergence."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import TokenPipeline, TokenPipelineConfig
from repro.ft import (FailureInjector, PrefetchQueue, RestartManager,
                      SimulatedFailure, elastic_remesh_plan,
                      latest_checkpoint, restore_checkpoint, save_checkpoint)
from repro.models import LMConfig
from repro.models import transformer as T
from repro.train import TrainConfig, train
from repro.train import compression, optim

CFG = LMConfig(name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
               d_head=16, d_ff=64, vocab=64)


def setup_lm():
    params = T.init_lm(jax.random.PRNGKey(0), CFG)
    pipe = TokenPipeline(TokenPipelineConfig(vocab=64, seq_len=32,
                                             global_batch=8))
    loss_fn = lambda p, b: T.lm_loss(p, CFG, jnp.asarray(b),
                                     compute_dtype=jnp.float32, remat=False)
    return params, loss_fn, pipe.batch


def test_restart_bit_exact():
    params, loss_fn, batch_fn = setup_lm()
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        t1 = TrainConfig(steps=16, log_every=4, ckpt_every=4, ckpt_dir=d1,
                         peak_lr=1e-2, warmup=2)
        r1 = train(loss_fn, params, batch_fn, t1, log_fn=lambda s: None)
        t2 = TrainConfig(steps=16, log_every=4, ckpt_every=4, ckpt_dir=d2,
                         peak_lr=1e-2, warmup=2)
        inj = FailureInjector(fail_at_steps=(10,))
        mgr = RestartManager(max_restarts=2)
        r2 = mgr.run(lambda resume: train(loss_fn, params, batch_fn, t2,
                                          injector=inj,
                                          log_fn=lambda s: None))
        assert mgr.stats.restarts == 1
        for a, b in zip(jax.tree_util.tree_leaves(r1.final_state.params),
                        jax.tree_util.tree_leaves(r2.final_state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert r1.losses[-1][1] < r1.losses[0][1]


def test_restart_gives_up_after_max():
    mgr = RestartManager(max_restarts=1)

    def always_fail(resume):
        raise SimulatedFailure("boom")

    with pytest.raises(SimulatedFailure):
        mgr.run(always_fail)
    assert mgr.stats.restarts == 2  # initial + one retry counted as failures


def test_checkpoint_roundtrip_and_retention():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.zeros((4,), jnp.int32), jnp.ones((), jnp.bfloat16)]}
    with tempfile.TemporaryDirectory() as d:
        for step in (1, 2, 3):
            save_checkpoint(d, step, tree, extra={"note": "x"})
        from repro.ft.checkpoint import list_checkpoints, retain
        retain(d, keep=2)
        cks = list_checkpoints(d)
        assert [s for s, _ in cks] == [2, 3]
        restored, manifest = restore_checkpoint(cks[-1][1], tree)
        assert manifest["step"] == 3
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_restore_missing_leaf_raises():
    tree = {"a": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 1, tree)
        bigger = {"a": jnp.zeros((2,)), "c": jnp.zeros((3,))}
        with pytest.raises(ValueError, match="missing"):
            restore_checkpoint(path, bigger)


def test_straggler_backup_batch():
    def slow_gen():
        yield np.zeros(3)
        time.sleep(60)       # simulated stuck data worker
        yield np.ones(3)

    q = PrefetchQueue(slow_gen(), timeout_s=0.3,
                      backup_fn=lambda step: np.full(3, step, np.float64))
    a = q.get(0)
    b = q.get(1)           # producer is stuck -> backup batch
    np.testing.assert_array_equal(a, np.zeros(3))
    np.testing.assert_array_equal(b, np.full(3, 1.0))
    assert q.stats.timeouts == 1


def test_elastic_remesh_plan():
    plan = elastic_remesh_plan(512, 256, model_parallel=16)
    assert plan["old_dp"] == 32 and plan["new_dp"] == 16
    with pytest.raises(ValueError):
        elastic_remesh_plan(512, 100, model_parallel=16)


@pytest.mark.parametrize("opt", ["adamw", "sgd", "adafactor"])
def test_optimizers_reduce_loss(opt):
    params, loss_fn, batch_fn = setup_lm()
    tcfg = TrainConfig(steps=12, optimizer=opt, peak_lr=5e-3, warmup=2,
                       log_every=3)
    r = train(loss_fn, params, batch_fn, tcfg, log_fn=lambda s: None)
    assert r.losses[-1][1] < r.losses[0][1] + 0.05


def test_accumulation_matches_big_batch():
    params, loss_fn, _ = setup_lm()
    pipe = TokenPipeline(TokenPipelineConfig(vocab=64, seq_len=16,
                                             global_batch=8))
    batch = jnp.asarray(pipe.batch(0))
    from repro.train.loop import init_train_state, make_train_step
    t_one = TrainConfig(steps=4, peak_lr=1e-3, warmup=1)
    t_acc = TrainConfig(steps=4, peak_lr=1e-3, warmup=1, accum_steps=4)
    s0 = init_train_state(params, t_one)
    s1 = init_train_state(params, t_acc)
    f0 = make_train_step(loss_fn, t_one, donate=False)
    f1 = make_train_step(loss_fn, t_acc, donate=False)
    s0, m0 = f0(s0, batch)
    s1, m1 = f1(s1, batch)
    np.testing.assert_allclose(float(m0["loss"]), float(m1["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s0.params),
                    jax.tree_util.tree_leaves(s1.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-5)


def test_quantize_error_feedback_identity():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    res = jnp.zeros((64,))
    q, scale, new_res = compression.quantize(g, res)
    deq = compression.dequantize(q, scale)
    # residual + dequantised = original (error feedback is exact)
    np.testing.assert_allclose(np.asarray(deq + new_res), np.asarray(g),
                               rtol=1e-5, atol=1e-6)
    assert np.abs(np.asarray(deq - g)).max() <= float(scale) * 0.5 + 1e-6


def test_compressed_training_converges():
    """int8+EF gradients still train a toy regression to low loss."""
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(8,)).astype(np.float32)
    X = rng.normal(size=(256, 8)).astype(np.float32)
    y = X @ w_true

    params = {"w": jnp.zeros((8,))}
    res = compression.init_residuals(params)
    lr = 0.1

    def loss(p):
        return jnp.mean((X @ p["w"] - y) ** 2)

    for _ in range(150):
        g = jax.grad(loss)(params)
        codes, scales, res = compression.compress_tree(g, res)
        g_hat = compression.decompress_tree(codes, scales)
        params = jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params,
                                        g_hat)
    assert float(loss(params)) < 1e-3
    np.testing.assert_allclose(np.asarray(params["w"]), w_true, atol=0.02)
