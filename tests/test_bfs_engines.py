"""Every BFS engine must reproduce the host oracle exactly (paper Alg. 2/3
correctness), including on hypothesis-generated graphs."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ENGINES, build_bvss, make_engine, reference_bfs
from repro.graphs import from_edges, generators as gen
from repro.kernels import pull_vss_kernel

FAMILIES = {
    "rmat": gen.rmat(8, 8, seed=1),
    "grid": gen.grid2d(17, 19),
    "star": gen.star(97),
    "er": gen.erdos_renyi(300, 3.0, seed=2),
    "path": gen.path(64),
    "disconnected": from_edges(50, np.array([1, 2, 10]),
                               np.array([2, 3, 11])),
}


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("gname", sorted(FAMILIES))
def test_engine_matches_oracle(engine, gname):
    g = FAMILIES[gname]
    if engine == "dense_pull" and g.n > 1024:
        pytest.skip("dense bitmap only for small n")
    fn = make_engine(g, engine)
    for src in (0, g.n // 2, g.n - 1):
        ref = reference_bfs(g, src)
        lv = np.asarray(fn(src))
        np.testing.assert_array_equal(lv, ref)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 150), m=st.integers(0, 600),
       seed=st.integers(0, 10_000), engine=st.sampled_from(
           ["blest", "blest_lazy", "brs"]))
def test_blest_engines_random_graphs(n, m, seed, engine):
    rng = np.random.default_rng(seed)
    g = from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m))
    fn = make_engine(g, engine)
    src = int(rng.integers(0, n))
    np.testing.assert_array_equal(np.asarray(fn(src)),
                                  reference_bfs(g, src))


@pytest.mark.parametrize("sigma", [4, 8, 16])
def test_blest_sigma_sweep(sigma):
    g = gen.rmat(7, 8, seed=5)
    fn = make_engine(g, "blest", sigma=sigma)
    np.testing.assert_array_equal(np.asarray(fn(3)), reference_bfs(g, 3))


def test_blest_with_pallas_pull_kernel():
    g = gen.rmat(7, 8, seed=6)
    b = build_bvss(g)
    fn = make_engine(g, "blest", bvss=b,
                     pull_impl=lambda m, f, s: pull_vss_kernel(m, f, s))
    np.testing.assert_array_equal(np.asarray(fn(1)), reference_bfs(g, 1))


def test_ordered_graph_same_levels():
    """Reordering must not change BFS distances (paper §3.2 sanity)."""
    from repro.core.ordering import auto_order
    g = gen.clustered(10, 32, seed=7)
    perm, _ = auto_order(g, w=128)
    gp = g.permute_fast(perm)
    fn = make_engine(gp, "blest_lazy")
    src = 5
    ref = reference_bfs(g, src)
    lv = np.asarray(fn(int(perm[src])))
    np.testing.assert_array_equal(lv[perm], ref)


def test_multi_source_matches_singles():
    from repro.core.multi_source import make_multi_source_bfs
    g = gen.rmat(7, 6, seed=9)
    srcs = np.array([0, 3, 17, 42], dtype=np.int32)
    f = make_multi_source_bfs(g, len(srcs))
    lv = np.asarray(f(srcs))
    for i, s in enumerate(srcs):
        np.testing.assert_array_equal(lv[:, i], reference_bfs(g, int(s)))


def test_closeness_centrality_nonnegative():
    from repro.analytics.closeness import closeness_centrality
    g = gen.rmat(7, 8, seed=10)
    cc = closeness_centrality(g, np.arange(6, dtype=np.int32))
    assert (cc >= 0).all() and np.isfinite(cc).all()
