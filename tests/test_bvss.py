"""BVSS construction invariants (unit + hypothesis property tests)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import build_bvss
from repro.graphs import Graph, from_edges, generators as gen


def edge_set_transposed(g: Graph) -> set:
    tp, ti = g.t_csr
    out = set()
    for u in range(g.n):
        for v in ti[tp[u]:tp[u + 1]]:
            out.add((int(v), int(u)))
    return out


def check_invariants(g: Graph, sigma: int = 8):
    b = build_bvss(g, sigma=sigma)
    # 1. exact edge reconstruction (every edge in exactly one slice bit)
    s, d = b.reconstruct_edges()
    assert len(s) == g.m
    assert set(zip(s.tolist(), d.tolist())) == edge_set_transposed(g)
    # 2. structural bounds
    assert b.num_vss >= -(-b.num_slices // b.tau)
    assert (np.diff(b.real_ptrs) >= 0).all()
    assert int(b.real_ptrs[-1]) == b.num_vss
    # 3. virtualToReal consistent with realPtrs
    v2r = b.virtual_to_real
    for s_id in range(b.n_sets):
        lo, hi = b.real_ptrs[s_id], b.real_ptrs[s_id + 1]
        assert (v2r[lo:hi] == s_id).all()
    # 4. only the last VSS of a set may be padded
    if b.num_vss == 0:
        assert b.num_slices == 0 and g.m == 0
        return b
    spw = b.slices_per_word
    shifts = (np.arange(spw, dtype=np.uint32) * sigma)[None, :, None]
    sub = ((b.masks[:, None, :] >> shifts)
           & np.uint32((1 << sigma) - 1)) != 0
    live_per_vss = sub.reshape(b.num_vss, -1).sum(axis=1)
    for s_id in range(b.n_sets):
        lo, hi = b.real_ptrs[s_id], b.real_ptrs[s_id + 1]
        if hi - lo > 1:
            assert (live_per_vss[lo:hi - 1] == b.tau).all()
    # 5. dummy rows only where mask is empty
    assert ((b.row_ids == g.n) == ~sub).all()
    # 6. compression ratio = m / (slices * sigma)
    assert b.compression_ratio() == pytest.approx(
        g.m / max(b.num_slices * sigma, 1))
    return b


@pytest.mark.parametrize("sigma", [4, 8, 16, 32])
def test_invariants_families(sigma):
    for g in (gen.rmat(7, 6, seed=1), gen.grid2d(11, 13), gen.star(67),
              gen.path(40), gen.erdos_renyi(200, 2.5, seed=3)):
        check_invariants(g, sigma=sigma)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 120), m=st.integers(0, 500),
       seed=st.integers(0, 10_000))
def test_invariants_random(n, m, seed):
    rng = np.random.default_rng(seed)
    g = from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m))
    check_invariants(g)


def test_update_divergence_orders_matter():
    g = gen.grid2d(30, 30, shuffle=True, seed=0)
    from repro.core.ordering import rcm
    u0 = build_bvss(g).update_divergence()
    u1 = build_bvss(g.permute_fast(rcm(g))).update_divergence()
    assert u1 < u0 / 2  # paper Table 1b: RCM slashes divergence


def test_memory_breakdown_counts_all_arrays():
    g = gen.rmat(8, 8, seed=2)
    b = build_bvss(g)
    mem = b.memory_bytes()
    assert mem["total"] == mem["bvss"] + mem["dynamic"] + mem["level"]
    assert mem["bvss"] >= b.masks.nbytes + b.row_ids.nbytes
