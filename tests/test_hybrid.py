"""Direction-optimizing push/pull hybrid (DESIGN §2.8): push-kernel
parity, oracle parity of levels AND parents in all three direction modes
across the single-source / lazy / multi-source engines, sharded parity on
{1, 2, 8} devices, and the autotuner's memoisation contract."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import reference_bfs
from repro.core.autotune import TileConfig, clear_cache, stats, tune
from repro.core.bfs import (DEFAULT_PUSH_CAP, BlestProblem, _round_width,
                            make_engine, queue_widths, selected_width)
from repro.core.bvss import build_bvss
from repro.core.multi_source import make_multi_source_bfs
from repro.core.policy import parents_from_levels, prepare
from repro.errors import ConfigError
from repro.graphs import generators as gen
from repro.kernels import push_vss_kernel
from repro.kernels.ref import bvss_push_ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RNG = np.random.default_rng(0)
INF = np.int32(np.iinfo(np.int32).max)
DIRECTIONS = ("pull", "push", "auto")

FAMILIES = {
    "rmat": gen.rmat(8, 8, seed=1),
    "star": gen.star(97),
    "path": gen.path(64),
    "grid": gen.grid2d(17, 19),
}
#: planted-partition graph whose frontier trace makes auto mode take BOTH
#: branches (probed host-side in test_auto_mode_genuinely_flips)
FLIP_GRAPH = gen.clustered(40, 60, p_in=0.4, seed=1)


def run_py(code: str, n_devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def check_parents(g, levels: np.ndarray, src: int) -> None:
    """A valid BFS tree: the source and unreached vertices are rootless,
    every other reached vertex has an in-neighbour one level shallower."""
    parents = parents_from_levels(g, levels)
    assert parents[src] == -1
    reached = np.flatnonzero((levels != INF) & (np.arange(g.n) != src))
    assert (parents[reached] >= 0).all()
    assert (levels[parents[reached]] == levels[reached] - 1).all()
    assert (parents[levels == INF] == -1).all()


# ---------------------------------------------------------------------------
# push kernel vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sigma", [4, 8, 16, 32])
@pytest.mark.parametrize("B", [1, 5, 127, 128, 129, 513])
def test_push_kernel_sweep(sigma, B):
    masks = RNG.integers(0, 2 ** 32, (B, 32), dtype=np.uint64
                         ).astype(np.uint32)
    bits = RNG.integers(0, sigma, (B,)).astype(np.int32)
    got = np.asarray(push_vss_kernel(masks, bits, sigma))
    want = np.asarray(bvss_push_ref(masks, bits, sigma))
    np.testing.assert_array_equal(got, want)


def test_push_is_pull_with_one_hot_frontier():
    """The defining identity: push(masks, b) == pull(masks, 1 << b)."""
    from repro.kernels import pull_vss_kernel
    masks = RNG.integers(0, 2 ** 32, (200, 32), dtype=np.uint64
                         ).astype(np.uint32)
    bits = RNG.integers(0, 8, (200,)).astype(np.int32)
    onehot = (np.uint32(1) << bits.astype(np.uint32))
    np.testing.assert_array_equal(
        np.asarray(push_vss_kernel(masks, bits, 8)) > 0,
        np.asarray(pull_vss_kernel(masks, onehot, 8)) > 0)


# ---------------------------------------------------------------------------
# oracle parity: every engine x every direction, levels AND parents
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("direction", DIRECTIONS)
@pytest.mark.parametrize("engine", ["blest", "blest_lazy"])
@pytest.mark.parametrize("gname", sorted(FAMILIES))
def test_hybrid_engine_matches_oracle(engine, direction, gname):
    g = FAMILIES[gname]
    fn = make_engine(g, engine, direction=direction, use_kernels=False)
    for src in (0, g.n // 2, g.n - 1):
        ref = reference_bfs(g, src)
        lv = np.asarray(fn(src))
        np.testing.assert_array_equal(lv, ref)
        check_parents(g, lv, src)


@pytest.mark.parametrize("direction", DIRECTIONS)
def test_hybrid_engine_matches_oracle_kernels(direction):
    """One kernel-backed (interpret-mode Pallas) pass per direction."""
    g = FAMILIES["rmat"]
    fn = make_engine(g, "blest", direction=direction, use_kernels=True)
    for src in (0, g.n - 1):
        np.testing.assert_array_equal(np.asarray(fn(src)),
                                      reference_bfs(g, src))


@pytest.mark.parametrize("direction", DIRECTIONS)
@pytest.mark.parametrize("gname", ["rmat", "star", "path"])
def test_hybrid_multi_source_matches_oracle(direction, gname):
    g = FAMILIES[gname]
    srcs = np.array([0, g.n // 3, g.n // 2, g.n - 1], dtype=np.int32)
    fn = make_multi_source_bfs(g, len(srcs), use_kernel=False,
                               direction=direction)
    lv = np.asarray(fn(srcs))
    for j, s in enumerate(srcs):
        np.testing.assert_array_equal(lv[: g.n, j],
                                      reference_bfs(g, int(s)),
                                      err_msg=f"source {s}")


def test_auto_mode_genuinely_flips():
    """On FLIP_GRAPH the auto heuristic must take BOTH branches: replay
    the on-device predicate host-side from the oracle levels and assert a
    mixed trace, then check auto parity on exactly that graph — so the
    parity run exercises push levels AND pull levels, not one of them."""
    g = FLIP_GRAPH
    b = build_bvss(g)
    p = BlestProblem.build(b)
    widths = queue_widths(p.num_vss, 2)
    pqcap = _round_width(DEFAULT_PUSH_CAP)
    push_cost = pqcap * p.max_vss_per_set
    assert push_cost < widths[-1], "static bail: graph cannot flip"
    vstart = np.asarray(p.dev.vss_of_vertex_start)
    vend = np.asarray(p.dev.vss_of_vertex_end)
    lv = reference_bfs(g, 0)
    n_push = n_pull = 0
    for L in range(int(lv[lv != INF].max())):
        fverts = np.flatnonzero(lv == L)
        rep = np.minimum(np.unique(fverts // b.sigma) * b.sigma, g.n - 1)
        count = int((vend[rep] - vstart[rep]).sum())
        use_push = (len(fverts) <= DEFAULT_PUSH_CAP
                    and push_cost < int(selected_width(widths, count))
                    and len(fverts) * 4.0 <= int(np.sum(lv > L)))
        n_push += use_push
        n_pull += not use_push
    assert n_push > 0 and n_pull > 0, (n_push, n_pull)
    fn = make_engine(g, "blest", problem=p, direction="auto",
                     use_kernels=False)
    got = np.asarray(fn(0))
    np.testing.assert_array_equal(got, lv)
    check_parents(g, got, 0)


def test_bad_direction_is_config_error():
    g = FAMILIES["path"]
    with pytest.raises(ConfigError):
        make_engine(g, "blest", direction="sideways")
    with pytest.raises(ConfigError):
        make_multi_source_bfs(g, 2, direction="sideways")


def test_track_sigma_rejects_forced_push():
    """The Brandes σ channel has no push twin: forcing push under
    track_sigma must be a typed ConfigError, never silent pull."""
    from repro.core.multi_source import make_ms_engine
    p = BlestProblem.build(build_bvss(FAMILIES["rmat"]))
    with pytest.raises(ConfigError):
        make_ms_engine(p, 2, use_kernel=False, track_sigma=True,
                       direction="push")


def test_bad_buckets_is_config_error():
    with pytest.raises(ConfigError):
        queue_widths(512, 0)


def test_queue_widths_ladder_shape():
    """Graduated ladder: ascending, deduplicated, full width last,
    PULL_TILE floor respected."""
    for num_vss, buckets in [(512, 2), (2048, 3), (2048, 4), (100, 4),
                             (60000, 4), (1, 1)]:
        ws = queue_widths(num_vss, buckets)
        assert ws == sorted(set(ws))
        assert ws[-1] == _round_width(num_vss)
        assert all(w >= 128 for w in ws)
        assert len(ws) <= buckets


# ---------------------------------------------------------------------------
# sharded parity: the same hybrid on {1, 2, 8} devices
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_devices", [1, 2, 8])
def test_sharded_hybrid_matches_oracle(n_devices):
    run_py(f"""
import numpy as np
from repro.graphs import generators as gen
from repro.core import reference_bfs
from repro.core.policy import prepare, parents_from_levels
from repro.distributed.bfs_dist import bfs_mesh
INF = np.int32(np.iinfo(np.int32).max)
mesh = bfs_mesh({n_devices})
for g in (gen.rmat(8, 8, seed=3), gen.clustered(40, 60, p_in=0.4, seed=1)):
    for direction in ("pull", "push", "auto"):
        pb = prepare(g, w=256, mesh=mesh, direction=direction,
                     use_kernels=False)
        for src in (0, g.n - 1):
            lv = pb.levels(src)
            assert (lv == reference_bfs(g, src)).all(), (direction, src)
            par = parents_from_levels(g, lv)
            reached = np.flatnonzero((lv != INF) & (np.arange(g.n) != src))
            assert (par[reached] >= 0).all(), (direction, src)
            assert (lv[par[reached]] == lv[reached] - 1).all(), \\
                (direction, src)
print("ok")
""", n_devices=max(n_devices, 1))


@pytest.mark.parametrize("n_devices", [2, 8])
def test_sharded_multi_source_hybrid_matches_oracle(n_devices):
    run_py(f"""
import numpy as np
from repro.graphs import generators as gen
from repro.core import reference_bfs
from repro.core.bvss import build_sharded_bvss
from repro.core.bfs import BlestProblem
from repro.core.multi_source import make_multi_source_bfs
from repro.distributed.bfs_dist import bfs_mesh
mesh = bfs_mesh({n_devices})
g = gen.rmat(8, 8, seed=3)
sb = build_sharded_bvss(g, {n_devices})
p = BlestProblem.build_sharded(sb, mesh, "data")
srcs = np.array([0, g.n // 3, g.n - 1], dtype=np.int32)
for direction in ("pull", "push", "auto"):
    fn = make_multi_source_bfs(g, len(srcs), problem=p, use_kernel=False,
                               direction=direction)
    lv = np.asarray(fn(srcs))
    for j, s in enumerate(srcs):
        assert (lv[: g.n, j] == reference_bfs(g, int(s))).all(), \\
            (direction, int(s))
print("ok")
""", n_devices=n_devices)


# ---------------------------------------------------------------------------
# autotuner: memoisation contract + escape hatch
# ---------------------------------------------------------------------------
@pytest.fixture
def fresh_tuner():
    clear_cache()
    before = dict(stats)
    yield before
    clear_cache()


def test_autotune_prepare_caches_winning_config(fresh_tuner):
    """Second prepare() of the same (backend, σ, size-class) performs
    ZERO additional tuning dispatches and re-serves the same knobs."""
    g1, g2 = gen.grid2d(32, 32), gen.grid2d(31, 33)
    pb1 = prepare(g1, engine="blest", use_kernels=False, autotune=True)
    assert isinstance(pb1.tile_config, TileConfig)
    assert pb1.tile_config.source == "tuned"
    runs_after_first = stats["tune_runs"]
    pb2 = prepare(g2, engine="blest", use_kernels=False, autotune=True)
    assert pb2.tile_config.source == "cached"
    assert stats["tune_runs"] == runs_after_first, "re-tuned a cached class"
    assert pb2.tile_config.pull_widths == pb1.tile_config.pull_widths
    assert pb2.tile_config.push_cap == pb1.tile_config.push_cap
    # the tuned engine still answers correctly
    for pb, g in ((pb1, g1), (pb2, g2)):
        np.testing.assert_array_equal(pb.levels(0), reference_bfs(g, 0))


def test_autotune_env_escape_hatch(fresh_tuner, monkeypatch):
    monkeypatch.setenv("BLEST_AUTOTUNE", "0")
    runs0 = stats["tune_runs"]
    p = BlestProblem.build(build_bvss(gen.grid2d(16, 16)))
    cfg = tune(p, use_kernels=False)
    assert cfg.source == "disabled"
    assert stats["tune_runs"] == runs0, "BLEST_AUTOTUNE=0 still measured"
    assert cfg.pull_widths == tuple(queue_widths(p.num_vss, 2))
    assert cfg.push_cap == DEFAULT_PUSH_CAP


def test_autotune_off_by_default():
    pb = prepare(gen.path(64), engine="blest", use_kernels=False)
    assert pb.tile_config is None


def test_autotune_rejects_bad_reps(fresh_tuner):
    p = BlestProblem.build(build_bvss(gen.path(64)))
    with pytest.raises(ConfigError):
        tune(p, use_kernels=False, reps=0)


def test_tile_config_engine_kwargs_roundtrip():
    cfg = TileConfig(pull_widths=(128, 512), push_cap=256, alpha=4.0,
                     source="tuned")
    kw = cfg.engine_kwargs()
    assert kw == {"widths": [128, 512], "push_cap": 256, "alpha": 4.0}
