"""Per-arch smoke tests (deliverable f): reduced config, one real train
run of a few steps on CPU — asserts finite, decreasing-ish loss and that
every family's full substrate path executes."""
import jax
import numpy as np
import pytest

from repro.configs.base import all_archs
from repro.launch.train import main as train_main

ARCH_IDS = [a.id for a in all_archs()]


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_train(arch_id):
    result = train_main(["--arch", arch_id, "--steps", "6", "--batch", "4",
                         "--seq-len", "32", "--lr", "1e-3"])
    losses = [l for _, l in result.losses]
    assert all(np.isfinite(l) for l in losses), losses
    params = result.final_state.params
    assert all(np.isfinite(np.asarray(p)).all()
               for p in jax.tree_util.tree_leaves(params))


def test_all_ten_archs_registered():
    assert len(ARCH_IDS) == 10
    expected = {"olmoe-1b-7b", "deepseek-v3-671b", "qwen3-0.6b", "gemma3-1b",
                "h2o-danube-1.8b", "dimenet", "gin-tu", "nequip", "egnn",
                "fm"}
    assert set(ARCH_IDS) == expected


def test_full_configs_match_assignment():
    from repro.configs.base import get_arch
    q = get_arch("qwen3-0.6b").model_cfg
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab) == (28, 1024, 16, 8, 3072, 151936)
    d = get_arch("deepseek-v3-671b").model_cfg
    assert (d.n_layers, d.d_model, d.n_heads, d.vocab) == (61, 7168, 128,
                                                           129280)
    assert d.moe.n_experts == 256 and d.moe.top_k == 8 and d.moe.n_shared == 1
    assert d.attn == "mla" and d.mtp
    o = get_arch("olmoe-1b-7b").model_cfg
    assert o.moe.n_experts == 64 and o.moe.top_k == 8 and o.d_model == 2048
    g = get_arch("gemma3-1b").model_cfg
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab) == (26, 1152, 4, 1, 6912, 262144)
    assert g.local_global == (5, 1)
    h = get_arch("h2o-danube-1.8b").model_cfg
    assert (h.n_layers, h.d_model, h.n_heads, h.n_kv_heads, h.d_ff,
            h.vocab) == (24, 2560, 32, 8, 6912, 32000)
    dm = get_arch("dimenet").model_cfg
    assert (dm.n_blocks, dm.d_hidden, dm.n_bilinear, dm.n_spherical,
            dm.n_radial) == (6, 128, 8, 7, 6)
    gi = get_arch("gin-tu").model_cfg
    assert (gi.n_layers, gi.d_hidden) == (5, 64) and gi.learn_eps
    nq = get_arch("nequip").model_cfg
    assert (nq.n_layers, nq.channels, nq.n_rbf, nq.cutoff) == (5, 32, 8, 5.0)
    eg = get_arch("egnn").model_cfg
    assert (eg.n_layers, eg.d_hidden) == (4, 64)
    f = get_arch("fm").model_cfg
    assert f.n_fields == 39 and f.embed_dim == 10
