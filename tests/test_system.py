"""End-to-end behaviour tests for the paper's system: the full BLEST
pipeline (classify -> order -> BVSS -> fused BFS -> verify) as a user
would run it."""
import numpy as np

from repro.core import build_bvss, make_engine, reference_bfs
from repro.core.ordering import auto_order, social_like_report
from repro.graphs import generators as gen
from repro.launch.bfs import ENGINE_VARIANTS, build_graph


def test_full_pipeline_social_graph():
    g = gen.rmat(9, 12, seed=7)
    assert social_like_report(g).is_social
    perm, kind = auto_order(g, w=256)
    assert kind == "jaccard_windows"
    g_ord = g.permute_fast(perm)
    b = build_bvss(g_ord)
    assert 0 < b.compression_ratio() <= 1
    fn = make_engine(g_ord, "blest_lazy", bvss=b)
    for src in (0, g.n // 2):
        lv = np.asarray(fn(int(perm[src])))
        np.testing.assert_array_equal(lv[perm], reference_bfs(g, src))


def test_full_pipeline_road_graph():
    g = build_graph("road", 9)
    perm, kind = auto_order(g, w=256)
    assert kind == "rcm"
    g_ord = g.permute_fast(perm)
    u_before = build_bvss(g).update_divergence()
    u_after = build_bvss(g_ord).update_divergence()
    assert u_after < u_before  # paper Table 1b property
    fn = make_engine(g_ord, "blest")
    lv = np.asarray(fn(int(perm[0])))
    np.testing.assert_array_equal(lv[perm], reference_bfs(g, 0))


def test_all_cli_engine_variants_verify():
    from repro.launch.bfs import main as bfs_main
    for engine in ("blest_full", "brs", "dirop"):
        bfs_main(["--graph", "clustered", "--scale", "9",
                  "--engine", engine, "--sources", "2"])


def test_graph_service_example():
    import importlib.util, os
    spec = importlib.util.spec_from_file_location(
        "bfs_service", os.path.join(os.path.dirname(__file__), "..",
                                    "examples", "bfs_service.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    g = gen.rmat(8, 8, seed=1)
    svc = mod.GraphService(g)
    lv = svc.levels(3)
    np.testing.assert_array_equal(lv, reference_bfs(g, 3))
