"""End-to-end behaviour tests for the paper's system: the full BLEST
pipeline (classify -> order -> BVSS -> fused BFS -> verify) as a user
would run it."""
import numpy as np

from repro.core import build_bvss, make_engine, reference_bfs
from repro.core.ordering import auto_order, social_like_report
from repro.graphs import generators as gen
from repro.launch.bfs import ENGINE_VARIANTS, build_graph


def test_full_pipeline_social_graph():
    g = gen.rmat(9, 12, seed=7)
    assert social_like_report(g).is_social
    perm, kind = auto_order(g, w=256)
    assert kind == "jaccard_windows"
    g_ord = g.permute_fast(perm)
    b = build_bvss(g_ord)
    assert 0 < b.compression_ratio() <= 1
    fn = make_engine(g_ord, "blest_lazy", bvss=b)
    for src in (0, g.n // 2):
        lv = np.asarray(fn(int(perm[src])))
        np.testing.assert_array_equal(lv[perm], reference_bfs(g, src))


def test_full_pipeline_road_graph():
    g = build_graph("road", 9)
    perm, kind = auto_order(g, w=256)
    assert kind == "rcm"
    g_ord = g.permute_fast(perm)
    u_before = build_bvss(g).update_divergence()
    u_after = build_bvss(g_ord).update_divergence()
    assert u_after < u_before  # paper Table 1b property
    fn = make_engine(g_ord, "blest")
    lv = np.asarray(fn(int(perm[0])))
    np.testing.assert_array_equal(lv[perm], reference_bfs(g, 0))


def test_all_cli_engine_variants_verify():
    from repro.launch.bfs import main as bfs_main
    for engine in ("blest_full", "brs", "dirop"):
        bfs_main(["--graph", "clustered", "--scale", "9",
                  "--engine", engine, "--sources", "2"])


def test_graph_service_example():
    """The end-user flow examples/bfs_service.py demonstrates, through
    the public façade only: manager session -> queued submits -> edge
    update -> post-update query."""
    import importlib.util, os
    spec = importlib.util.spec_from_file_location(
        "bfs_service", os.path.join(os.path.dirname(__file__), "..",
                                    "examples", "bfs_service.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)  # the example must at least import
    assert callable(mod.main)

    import repro
    g = gen.rmat(8, 8, seed=1)
    mgr = repro.GraphSessionManager()
    sess = mgr.open_session("svc", g, max_batch=4,
                            options=repro.PrepareOptions(w=256, seed=0))
    np.testing.assert_array_equal(sess.levels(3), reference_bfs(g, 3))

    queue = repro.RequestQueue(mgr)
    futs = [queue.submit("svc", s) for s in (0, 3, g.n // 2)]
    queue.drain()
    for s, f in zip((0, 3, g.n // 2), futs):
        np.testing.assert_array_equal(f.result(0), reference_bfs(g, s))

    # insert a guaranteed-missing edge and see it served immediately
    dst = next(d for d in range(g.n) if d != 3 and d not in g.neighbours(3))
    report = mgr.update_edges("svc", inserts=[(3, dst)])
    assert report is not None and report.epoch == 1
    assert sess.levels(3)[dst] == 1
