"""Distributed layer tests.  Multi-device cases run in subprocesses with
--xla_force_host_platform_device_count so the main pytest session keeps its
single-device jax instance (smoke tests must see 1 device)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, n_devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.mark.parametrize("n_devices", [1, 2, 8])
def test_sharded_prepare_matches_oracle(n_devices):
    """Mesh-native prepare (the ONE sharded entry point): levels must be
    bit-for-bit the host oracle's on every device count — same fused
    LevelPipeline, any mesh shape."""
    run_py(f"""
import numpy as np
from repro.graphs import generators as gen
from repro.core import reference_bfs
from repro.core.policy import prepare
from repro.distributed.bfs_dist import bfs_mesh
mesh = bfs_mesh({n_devices})
for g in (gen.rmat(8, 8, seed=3), gen.grid2d(20, 16)):
    pb = prepare(g, w=256, mesh=mesh)
    for src in (0, g.n // 3, g.n - 1):
        assert (pb.levels(src) == reference_bfs(g, src)).all(), src
print("ok")
""", n_devices=max(n_devices, 1))


def test_sharded_engine_variants_match_oracle():
    """Every BVSS engine (eager, lazy, brs) through the same sharded
    pipeline; the kernel/jnp switch must not change levels either."""
    run_py("""
import numpy as np
from repro.graphs import generators as gen
from repro.core import reference_bfs
from repro.core.policy import prepare
from repro.distributed.bfs_dist import bfs_mesh
mesh = bfs_mesh(4)
g = gen.rmat(8, 8, seed=5)
for eng in ("blest", "blest_lazy", "brs"):
    for use_kernels in (True, False):
        pb = prepare(g, w=256, mesh=mesh, engine=eng,
                     use_kernels=use_kernels)
        for src in (0, g.n - 1):
            assert (pb.levels(src) == reference_bfs(g, src)).all(), \\
                (eng, use_kernels, src)
print("ok")
""", n_devices=4)


def test_sharded_prepare_rejects_non_bvss_engines():
    run_py("""
from repro.graphs import generators as gen
from repro.core.policy import prepare
from repro.distributed.bfs_dist import bfs_mesh
try:
    prepare(gen.rmat(6, 4, seed=0), mesh=bfs_mesh(2), engine="csr_push")
except ValueError as e:
    assert "mesh-native" in str(e)
else:
    raise AssertionError("csr_push must be rejected under a mesh")
print("ok")
""", n_devices=2)


def test_sharded_graph_session_caller_id_contract():
    """The caller-id contract cases of tests/test_graph_session.py, over a
    2-device mesh: wave serving with mid-flight refills, duplicate
    queries, mixed depths, closeness — all in ORIGINAL vertex ids."""
    run_py("""
import numpy as np
from repro.graphs import from_edges, generators as gen
from repro.core import reference_bfs
from repro.serve import GraphSession
from repro.distributed.bfs_dist import bfs_mesh
mesh = bfs_mesh(2)
INF = np.int32(np.iinfo(np.int32).max)

# non-trivial ordering so any id-space slip shows up as a mismatch
g = gen.rmat(8, 8, seed=1)
sess = GraphSession(g, max_batch=3, w=256, mesh=mesh)
assert sess.ordering == "jaccard_windows"
assert (sess.perm != np.arange(g.n)).any()

# 7 queries through 3 slots: mid-flight refills, one duplicate query
rng = np.random.default_rng(0)
queries = [int(q) for q in rng.integers(0, g.n, 7)]
queries[3] = queries[0]
lvs = sess.levels_batch(queries)
assert len(lvs) == len(queries)
for q, lv in zip(queries, lvs):
    np.testing.assert_array_equal(lv, reference_bfs(g, q),
                                  err_msg=f"query {q}")

# shallow + deep queries on a path: slots must refill while deep
# columns are still running
g2 = from_edges(60, np.arange(59), np.arange(1, 60))
sess2 = GraphSession(g2, max_batch=2, order=False, mesh=mesh)
queries2 = [58, 0, 55, 2, 59]
for q, lv in zip(queries2, sess2.levels_batch(queries2)):
    np.testing.assert_array_equal(lv, reference_bfs(g2, q),
                                  err_msg=f"query {q}")

# closeness: caller-id sources, reordering + sharding invisible
srcs, cc = sess.centrality_sample(5, seed=2)
for s, c in zip(srcs, cc):
    lv = reference_bfs(g, int(s))
    finite = lv != INF
    dist_sum = float(lv[finite].sum())
    want = (int(finite.sum()) - 1) / dist_sum if dist_sum > 0 else 0.0
    assert abs(c - want) < 1e-12, (s, c, want)
print("ok")
""", n_devices=2)


@pytest.mark.parametrize("n_devices", [1, 2, 8])
def test_sharded_weighted_kernels_match_refs(n_devices):
    """The local-rows × global-columns weighted tile forms
    (``bvss_spmm_w_local`` / ``bvss_spmm_t_local``) under shard_map vs the
    ``kernels/ref.py`` oracles, per shard of a row-sharded BVSS whose last
    shard is RAGGED (zero-padded VSS rows and a partial row block).  The
    zeroed value column stands in for an empty-frontier level: both
    products must return exact zeros for it."""
    run_py(f"""
import numpy as np
import jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.graphs import generators as gen
from repro.core.bvss import ShardedBVSSDevice, build_sharded_bvss, shard_to_device
from repro.core.bfs import BlestProblem
from repro.distributed.bfs_dist import bfs_mesh, problem_specs
from repro.kernels import bvss_spmm_t_local, bvss_spmm_w_local
from repro.kernels.ref import bvss_spmm_t_ref, bvss_spmm_w_ref

D = {n_devices}
mesh = bfs_mesh(D)
g = gen.clustered(3, 23, seed=4)            # n = 69: ragged last shard
sb = build_sharded_bvss(g, D)
p = BlestProblem.build_sharded(sb, mesh)
assert D * sb.rows_per_shard >= g.n
S, sigma = 3, sb.sigma
B = p.num_vss + 1                           # include the dummy VSS
rng = np.random.default_rng(0)
n_pad = p.n_fwords * 32
xg = rng.random((n_pad, S)).astype(np.float32)
xg[:, 1] = 0.0                              # empty-frontier column
h = rng.random((D, sb.rows_per_shard + 1, S)).astype(np.float32)
h[:, -1, :] = 0.0                           # dummy row must stay zero
h[:, :, 1] = 0.0

def f(masks, row_ids, v2r, vstart, vend, xg, h):
    dev = ShardedBVSSDevice(masks[0], row_ids[0], v2r[0],
                            vstart[0], vend[0])
    ids = jnp.arange(B, dtype=jnp.int32)
    w = bvss_spmm_w_local(dev.masks[ids], dev.virtual_to_real[ids], xg,
                          sigma=sigma)
    t = bvss_spmm_t_local(dev.masks[ids], dev.row_ids[ids], h[0],
                          sigma=sigma)
    return w[None], t[None]

fn = shard_map(f, mesh=mesh, in_specs=problem_specs() + (P(), P('data')),
               out_specs=(P('data'), P('data')), check_rep=False)
w, t = fn(p.dev.masks, p.dev.row_ids, p.dev.virtual_to_real,
          p.dev.vss_of_vertex_start, p.dev.vss_of_vertex_end,
          jnp.asarray(xg), jnp.asarray(h))
w, t = np.asarray(w), np.asarray(t)

# per-shard oracle on the host, straight off the ShardedBVSS arrays
spw = 32 // sigma
for d in range(D):
    masks_d = np.concatenate([sb.masks[d], np.zeros((1, 32), np.uint32)])
    v2r_d = np.concatenate([sb.virtual_to_real[d], np.zeros(1, np.int32)])
    rid_d = np.concatenate(
        [sb.row_ids[d].reshape(-1, spw, 32),
         np.full((1, spw, 32), sb.rows_per_shard, np.int32)])
    cols = v2r_d[:, None] * sigma + np.arange(sigma)[None, :]
    want_w = np.asarray(bvss_spmm_w_ref(
        jnp.asarray(masks_d), jnp.asarray(xg[cols]), sigma))
    np.testing.assert_allclose(w[d], want_w, rtol=1e-6, err_msg=f"w d={{d}}")
    want_t = np.asarray(bvss_spmm_t_ref(
        jnp.asarray(masks_d), jnp.asarray(h[d][rid_d]), sigma))
    np.testing.assert_allclose(t[d], want_t, rtol=1e-6, err_msg=f"t d={{d}}")
    assert (w[d][..., 1] == 0).all() and (t[d][..., 1] == 0).all()
print("ok")
""", n_devices=max(n_devices, 1))


@pytest.mark.parametrize("n_devices", [1, 2, 8])
def test_sharded_betweenness_matches_single_and_oracle(n_devices):
    """Mesh-native Brandes across device counts: kernel AND ref-oracle
    tile paths, ragged last shard, isolated-source column (its frontier
    empties immediately while other columns keep running)."""
    run_py(f"""
import numpy as np
import jax.numpy as jnp
from repro.graphs import from_edges, generators as gen
from repro.core.bvss import build_bvss, build_sharded_bvss
from repro.core.bfs import BlestProblem
from repro.distributed.bfs_dist import bfs_mesh
from repro.analytics.betweenness import make_betweenness
from repro.kernels.ref import betweenness_ref

mesh = bfs_mesh({n_devices})
graphs = [gen.clustered(3, 23, seed=4),       # ragged n = 69
          from_edges(50, np.array([1, 2, 10]), np.array([2, 3, 11]))]
for g in graphs:
    p1 = BlestProblem.build(build_bvss(g))
    pD = BlestProblem.build_sharded(build_sharded_bvss(g, {n_devices}), mesh)
    # vertex 40 of the second graph is isolated: empty frontier at level 1
    srcs = np.array([1, min(40, g.n - 1), 2, g.n - 1], dtype=np.int32)
    ref = betweenness_ref(g, srcs)
    f1 = make_betweenness(p1, len(srcs))
    lv1, sg1, dl1 = [np.asarray(x) for x in f1(jnp.asarray(srcs))]
    for use_kernel in (True, False):
        fD = make_betweenness(pD, len(srcs), use_kernel=use_kernel)
        lvD, sgD, dlD = [np.asarray(x) for x in fD(jnp.asarray(srcs))]
        assert (lv1 == lvD).all(), use_kernel
        np.testing.assert_allclose(sgD, sg1, rtol=1e-6)
        scale = max(float(np.abs(dl1).max()), 1.0)
        assert float(np.abs(dlD - dl1).max()) / scale <= 1e-6, use_kernel
        bc = dlD.astype(np.float64).sum(axis=1)
        np.testing.assert_allclose(bc, ref, rtol=1e-4, atol=1e-4)
print("ok")
""", n_devices=max(n_devices, 1))


def test_gpipe_equals_sequential():
    run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import make_gpipe
mesh = jax.make_mesh((4, 2), ("pod", "data"))
def stage_fn(w, x):
    return jnp.tanh(x @ w)
rng = np.random.default_rng(0)
ws = jnp.asarray(rng.normal(size=(4, 16, 16)) * 0.3, jnp.float32)
x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
got = make_gpipe(mesh, stage_fn, n_micro=4, axis="pod")(ws, x)
want = x
for i in range(4):
    want = stage_fn(ws[i], want)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                           atol=1e-6)
print("ok")
""")


def test_ring_overlap_matmul_equivalence():
    run_py("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.collectives import make_overlap_matmul
mesh = jax.make_mesh((8,), ("model",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
w = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
om = make_overlap_matmul(mesh, "model")
np.testing.assert_allclose(np.asarray(om(x, w)), np.asarray(x @ w),
                           rtol=1e-4, atol=1e-5)
print("ok")
""")


def test_compressed_psum_close_to_exact():
    run_py("""
import functools, jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.train import compression
mesh = jax.make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
res = jnp.zeros((4, 64))
def f(g, r):
    mean, new_r = compression.compressed_psum({"g": g[0]}, {"g": r[0]},
                                              ("data",))
    return mean["g"][None], new_r["g"][None]
fn = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
               out_specs=(P("data"), P("data")), check_rep=False)
mean, new_res = jax.jit(fn)(g, res)
exact = np.asarray(g).mean(axis=0)
got = np.asarray(mean)[0]
scale = np.abs(np.asarray(g)).max() / 127.0
assert np.abs(got - exact).max() < 4 * scale, (got[:4], exact[:4])
# error feedback: residual equals what quantisation dropped
print("ok")
""")


def test_sharding_rule_engine_fallbacks():
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import spec_for_leaf

    mesh = jax.make_mesh((1,), ("model",))
    # divisibility fallback
    spec = spec_for_leaf(("embed", "heads", "head_dim"), (64, 3, 16),
                         {"heads": "model", "embed": None}, mesh)
    assert spec == P(None, "model" if 3 % 1 == 0 else None, None)
    # collision fallback: same mesh axis twice -> second replicated
    spec = spec_for_leaf(("experts", "embed", "ffn"), (4, 8, 16),
                         {"experts": "model", "ffn": "model"}, mesh)
    assert spec == P("model", None, None)


def test_dryrun_small_mesh_cells():
    """Compile a representative cell per family on a tiny multi-pod mesh
    (fast): proves the sharded lowering machinery end to end."""
    run_py("""
import jax
from repro.configs.base import get_arch
from repro.configs.families import build_cell
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
for arch_id, shape in [("fm", "train_batch"), ("gin-tu", "full_graph_sm"),
                       ("egnn", "molecule")]:
    cell = build_cell(get_arch(arch_id), shape, mesh)
    with mesh:
        compiled = cell.lower().compile()
    assert compiled.cost_analysis() is not None
    print(arch_id, shape, "compiled")
print("ok")
""", timeout=560)
