"""Shared test configuration.

Four test modules use ``hypothesis`` property tests.  The library is a
dev-only dependency (see ``requirements-dev.txt``); when it is absent we
install a minimal deterministic shim *before* collection so the suite
still runs: ``@given`` draws a fixed, seeded sample of examples instead
of hypothesis' adaptive search.  The shim covers exactly the API surface
the tests use (``given``, ``settings``, ``strategies.integers``,
``strategies.sampled_from``).
"""
from __future__ import annotations

import random
import sys
import types

_SHIM_SEED = 0xB1E57  # deterministic: same examples every run
_SHIM_MAX_EXAMPLES = 10  # cap so the fallback stays CI-fast


def _install_hypothesis_shim() -> None:
    try:
        import hypothesis  # noqa: F401
        return  # real library present — use it
    except ImportError:
        pass

    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    mod.__shim__ = True

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(elements) -> _Strategy:
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def given(**strategies):
        def deco(f):
            # NOTE: plain (*args, **kwargs) signature on purpose — pytest
            # must not mistake the drawn parameters for fixtures.
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_max_examples",
                                _SHIM_MAX_EXAMPLES), _SHIM_MAX_EXAMPLES)
                rng = random.Random(_SHIM_SEED)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    f(*args, **drawn, **kwargs)
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            wrapper.__module__ = f.__module__
            return wrapper
        return deco

    def settings(max_examples: int = _SHIM_MAX_EXAMPLES, deadline=None, **_):
        def deco(f):
            f._max_examples = max_examples
            return f
        return deco

    st.integers = integers
    st.sampled_from = sampled_from
    mod.strategies = st
    mod.given = given
    mod.settings = settings
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_shim()
