"""Shared test configuration.

Four test modules use ``hypothesis`` property tests.  The library is a
dev-only dependency (see ``requirements-dev.txt``); when it is absent we
install a minimal deterministic shim *before* collection so the suite
still runs: ``@given`` draws a fixed, seeded sample of examples instead
of hypothesis' adaptive search.  The shim covers exactly the API surface
the tests use (``given``, ``settings``, ``strategies.integers``,
``strategies.sampled_from``).

``require_devices`` guards the sharded-parity tests: they SKIP on a
plain single-device checkout (the simulated-device flag binds at backend
init, so an in-process pytest run cannot grow devices), but FAIL —
loudly, not silently skip — when ``BLEST_REQUIRE_MULTIDEVICE`` is set,
which the CI multidevice job does.  That turns "the parity suite ran
with 0 skips" into an enforced property instead of a hope: if the
XLA_FLAGS plumbing ever breaks, CI goes red instead of green-but-empty.
"""
from __future__ import annotations

import os
import random
import sys
import types

import pytest


def require_devices(n: int = 2) -> None:
    """Call at the top of a multi-device test body: skip locally when the
    process has fewer than ``n`` devices, FAIL under
    ``BLEST_REQUIRE_MULTIDEVICE=1`` (the CI multidevice job)."""
    import jax
    have = len(jax.devices())
    if have >= n:
        return
    msg = (f"needs >= {n} devices, have {have} (run under XLA_FLAGS="
           f"--xla_force_host_platform_device_count={n})")
    if os.environ.get("BLEST_REQUIRE_MULTIDEVICE"):
        pytest.fail(
            "BLEST_REQUIRE_MULTIDEVICE is set but the device-count "
            "prerequisite is unmet — the multidevice CI job must run the "
            "sharded-parity suite, never skip it: " + msg)
    pytest.skip(msg)

_SHIM_SEED = 0xB1E57  # deterministic: same examples every run
_SHIM_MAX_EXAMPLES = 10  # cap so the fallback stays CI-fast


def _install_hypothesis_shim() -> None:
    try:
        import hypothesis  # noqa: F401
        return  # real library present — use it
    except ImportError:
        pass

    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    mod.__shim__ = True

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(elements) -> _Strategy:
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def given(**strategies):
        def deco(f):
            # NOTE: plain (*args, **kwargs) signature on purpose — pytest
            # must not mistake the drawn parameters for fixtures.
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_max_examples",
                                _SHIM_MAX_EXAMPLES), _SHIM_MAX_EXAMPLES)
                rng = random.Random(_SHIM_SEED)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    f(*args, **drawn, **kwargs)
            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            wrapper.__module__ = f.__module__
            return wrapper
        return deco

    def settings(max_examples: int = _SHIM_MAX_EXAMPLES, deadline=None, **_):
        def deco(f):
            f._max_examples = max_examples
            return f
        return deco

    st.integers = integers
    st.sampled_from = sampled_from
    mod.strategies = st
    mod.given = given
    mod.settings = settings
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


_install_hypothesis_shim()
