"""Weighted workload verbs (PR 9, DESIGN §2.9): the min-plus tile
product, the edge-weight plane, delta-stepping SSSP and PageRank — all
against independent SciPy/NetworkX oracles, single-device and sharded —
plus the typed weight-validation ingress (satellite: negative/zero/NaN
weights must surface as GraphValidationError, never as a wrong answer).

SSSP tests use dyadic-rational weights (k/32): float32 path sums are
then EXACT, so the wave distances must match the float64 Dijkstra
oracle bit-for-bit, not approximately.
"""
import numpy as np
import pytest

from conftest import require_devices
from repro.core.policy import prepare
from repro.errors import ConfigError, GraphValidationError, check_weights
from repro.graphs import from_edges
from repro.graphs import generators as gen
from repro.kernels.ref import pagerank_ref, sssp_ref
from repro.serve import GraphSession


def dyadic(rng, m):
    return (rng.integers(1, 128, m) / 32.0).astype(np.float32)


def assert_dist_equal(dist, ref):
    np.testing.assert_array_equal(np.isinf(dist), np.isinf(ref))
    np.testing.assert_allclose(np.where(np.isinf(dist), 0.0, dist),
                               np.where(np.isinf(ref), 0.0, ref),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# min-plus tile kernel vs reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sigma", [8, 4])
def test_minplus_kernel_matches_ref(sigma):
    from repro.kernels import bvss_spmm_minplus
    from repro.kernels.ref import bvss_spmm_minplus_ref
    rng = np.random.default_rng(0)
    B, S = 6, 5
    spw = 32 // sigma
    masks = rng.integers(0, 2**32, (B, 32), dtype=np.uint64) \
               .astype(np.uint32)
    wv = rng.uniform(0.5, 4.0, (B, spw, 32, sigma)).astype(np.float32)
    xv = rng.uniform(0.0, 9.0, (B, sigma, S)).astype(np.float32)
    xv[rng.random((B, sigma, S)) < 0.3] = np.inf   # inactive columns
    got = np.asarray(bvss_spmm_minplus(masks, wv, xv, sigma=sigma))
    want = np.asarray(bvss_spmm_minplus_ref(masks, wv, xv, sigma=sigma))
    np.testing.assert_array_equal(got, want)
    assert not np.isnan(got).any()


def test_minplus_all_inf_columns_yield_inf():
    from repro.kernels import bvss_spmm_minplus
    rng = np.random.default_rng(1)
    masks = rng.integers(0, 2**32, (3, 32), dtype=np.uint64) \
               .astype(np.uint32)
    wv = rng.uniform(0.5, 4.0, (3, 4, 32, 8)).astype(np.float32)
    xv = np.full((3, 8, 2), np.inf, dtype=np.float32)
    out = np.asarray(bvss_spmm_minplus(masks, wv, xv, sigma=8))
    assert np.isinf(out).all() and not np.isnan(out).any()


# ---------------------------------------------------------------------------
# weight-validation ingress (typed errors, satellite)
# ---------------------------------------------------------------------------
def _bad_weight_cases(m):
    w = np.ones(m, dtype=np.float32)
    wrong_shape = np.ones(m + 1, dtype=np.float32)
    nan = w.copy(); nan[m // 2] = np.nan
    neg = w.copy(); neg[0] = -1.0
    zero = w.copy(); zero[-1] = 0.0
    inf = w.copy(); inf[0] = np.inf
    return {"shape": wrong_shape, "nan": nan, "negative": neg,
            "zero": zero, "inf": inf}


@pytest.mark.parametrize("case", ["shape", "nan", "negative", "zero", "inf"])
def test_check_weights_rejects(case):
    g = gen.rmat(6, 4, seed=3)
    bad = _bad_weight_cases(g.m)[case]
    with pytest.raises(GraphValidationError):
        check_weights(bad, g.m)


@pytest.mark.parametrize("case", ["shape", "nan", "negative", "zero"])
def test_prepare_and_session_reject_bad_weights(case):
    """The ingress is at prepare()/GraphSession() — a bad weight vector
    must be a typed error BEFORE any device work."""
    g = gen.rmat(6, 4, seed=3)
    bad = _bad_weight_cases(g.m)[case]
    with pytest.raises(GraphValidationError):
        prepare(g, weights=bad)
    with pytest.raises(GraphValidationError):
        GraphSession(g, weights=bad)


def test_check_weights_accepts_and_casts():
    w64 = np.arange(1, 11, dtype=np.float64) / 4.0
    out = check_weights(w64, 10)
    assert out.dtype == np.float32
    np.testing.assert_allclose(out, w64)


# ---------------------------------------------------------------------------
# weight plane: prepare() threading
# ---------------------------------------------------------------------------
def test_prepare_threads_weights_through_ordering():
    """The ordered weight vector must track the permuted edges exactly:
    every (src, dst, w) triple of the original graph appears with the
    same weight in the ordered graph's CSR."""
    from repro.graphs import src_of_edges
    g = gen.rmat(7, 6, seed=4)
    rng = np.random.default_rng(5)
    w = dyadic(rng, g.m)
    pb = prepare(g, weights=w)
    assert pb.weights is not None and pb.wplane is not None
    go = pb.graph
    orig = {(int(pb.perm[s]), int(pb.perm[d])): float(wt)
            for s, d, wt in zip(src_of_edges(g), g.indices, w)}
    for s, d, wt in zip(src_of_edges(go), go.indices, pb.weights):
        assert orig[(int(s), int(d))] == float(wt)


def test_prepare_unweighted_has_no_plane():
    pb = prepare(gen.rmat(6, 4, seed=3))
    assert pb.weights is None and pb.wplane is None


# ---------------------------------------------------------------------------
# SSSP vs the SciPy Dijkstra oracle (single device)
# ---------------------------------------------------------------------------
def _sssp_case(g, srcs, seed=7, batch=None):
    from repro.analytics import sssp_distances
    rng = np.random.default_rng(seed)
    w = dyadic(rng, g.m)
    pb = prepare(g, weights=w)
    dist = sssp_distances(pb.perm[np.asarray(srcs)], problem=pb.problem,
                          wplane=pb.wplane, weights=pb.weights,
                          batch=batch)
    ref = sssp_ref(g, srcs, w)          # caller ids
    assert_dist_equal(dist[:, pb.perm], ref)


def test_sssp_directed_scale_free():
    g = gen.rmat(7, 8, seed=8)
    _sssp_case(g, [0, 3, g.n // 2, g.n - 1])


def test_sssp_high_diameter_grid():
    g = gen.grid2d(11, 11, shuffle=True, seed=9)
    _sssp_case(g, [0, 60])


def test_sssp_disconnected_unreachable_is_inf():
    src = np.array([0, 1, 2, 5, 6], dtype=np.int64)
    dst = np.array([1, 2, 0, 6, 5], dtype=np.int64)
    g = from_edges(48, src, dst)
    _sssp_case(g, [0, 5, 40])


def test_sssp_single_vertex():
    g = from_edges(1, np.zeros(0, np.int64), np.zeros(0, np.int64))
    _sssp_case(g, [0])


def test_sssp_batch_chunking_matches_oracle():
    """More sources than the cohort width: the host loop chunks through
    the same engine; padding columns never leak."""
    g = gen.rmat(6, 6, seed=10)
    _sssp_case(g, list(range(7)), batch=3)


def test_sssp_delta_choice_never_changes_answers():
    """Δ shapes performance only: wildly different bucket widths must
    produce identical distances (module contract)."""
    from repro.analytics import sssp_distances
    g = gen.grid2d(8, 8, shuffle=True, seed=11)
    rng = np.random.default_rng(12)
    w = dyadic(rng, g.m)
    pb = prepare(g, weights=w)
    outs = []
    for delta in (0.05, 1.0, 1e6):
        d = sssp_distances(pb.perm[[0, 17]], problem=pb.problem,
                           wplane=pb.wplane, weights=pb.weights,
                           delta=delta)
        outs.append(d)
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


# ---------------------------------------------------------------------------
# PageRank vs the NetworkX oracle (single device)
# ---------------------------------------------------------------------------
def _pagerank_case(g):
    from repro.analytics import pagerank_scores
    pb = prepare(g)
    r = pagerank_scores(pb.graph, problem=pb.problem, tol=1e-10,
                        max_iter=500)
    ref = pagerank_ref(pb.graph)
    rel = np.max(np.abs(r - ref) / np.maximum(np.abs(ref), 1e-30))
    assert rel <= 1e-6, rel
    assert abs(r.sum() - 1.0) < 1e-5


def test_pagerank_directed_scale_free():
    _pagerank_case(gen.rmat(7, 8, seed=13))


def test_pagerank_dangling_star():
    # out_hub=False: every spoke points at the hub, all spokes dangle
    _pagerank_case(gen.star(64, out_hub=False))


def test_pagerank_disconnected():
    src = np.array([0, 1, 2, 5, 6], dtype=np.int64)
    dst = np.array([1, 2, 0, 6, 5], dtype=np.int64)
    _pagerank_case(from_edges(48, src, dst))


def test_pagerank_single_vertex():
    g = from_edges(1, np.zeros(0, np.int64), np.zeros(0, np.int64))
    _pagerank_case(g)


# ---------------------------------------------------------------------------
# GraphSession verbs: caller-id contract + unit-weight default
# ---------------------------------------------------------------------------
def test_session_sssp_caller_ids():
    g = gen.rmat(7, 8, seed=14)
    rng = np.random.default_rng(15)
    w = dyadic(rng, g.m)
    sess = GraphSession(g, weights=w)
    ref = sssp_ref(g, [5], w)[0]
    assert_dist_equal(sess.sssp(5), ref)


def test_session_unweighted_sssp_equals_levels():
    """An unweighted session defaults the weighted verbs to unit
    weights: sssp is then exactly BFS hop counts."""
    g = gen.rmat(7, 8, seed=16)
    sess = GraphSession(g)
    d = sess.sssp(2)
    lv0 = sess.levels(2)
    lv = np.where(lv0 == np.iinfo(np.int32).max, np.inf,
                  lv0.astype(np.float64))     # INF sentinel -> +inf
    np.testing.assert_array_equal(d, lv)


def test_session_pagerank_caller_ids():
    g = gen.rmat(7, 8, seed=17)
    sess = GraphSession(g)
    pr = sess.pagerank(tol=1e-10, max_iter=500)
    ref = pagerank_ref(g)               # caller ids
    rel = np.max(np.abs(pr - ref) / np.maximum(np.abs(ref), 1e-30))
    assert rel <= 1e-6, rel


def test_session_source_validation():
    g = gen.rmat(6, 4, seed=18)
    sess = GraphSession(g)
    with pytest.raises(GraphValidationError):
        sess.sssp(g.n)
    with pytest.raises(GraphValidationError):
        sess.sssp_batch([0, -1])


# ---------------------------------------------------------------------------
# sharded parity (1-D mesh) + 2-D typed rejection
# ---------------------------------------------------------------------------
def test_sssp_sharded_matches_oracle():
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh
    g = gen.rmat(7, 8, seed=19)
    rng = np.random.default_rng(20)
    w = dyadic(rng, g.m)
    sess = GraphSession(g, weights=w, mesh=bfs_mesh(2))
    ref = sssp_ref(g, [0, 9], w)
    assert_dist_equal(sess.sssp_batch([0, 9]), ref)


def test_pagerank_sharded_matches_oracle():
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh
    g = gen.rmat(7, 8, seed=21)
    sess = GraphSession(g, mesh=bfs_mesh(2))
    pr = sess.pagerank(tol=1e-10, max_iter=500)
    ref = pagerank_ref(g)
    rel = np.max(np.abs(pr - ref) / np.maximum(np.abs(ref), 1e-30))
    assert rel <= 1e-6, rel


def test_sharded_session_rejects_bad_weights():
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh
    g = gen.rmat(6, 4, seed=22)
    for case, bad in _bad_weight_cases(g.m).items():
        with pytest.raises(GraphValidationError):
            GraphSession(g, weights=bad, mesh=bfs_mesh(2))


def test_2d_mesh_weighted_prepare_is_typed_config_error():
    require_devices(4)
    import jax
    from jax.sharding import Mesh
    g = gen.rmat(6, 4, seed=23)
    rng = np.random.default_rng(24)
    w = dyadic(rng, g.m)
    devs = np.array(jax.devices()[:4]).reshape(2, 2)
    mesh = Mesh(devs, ("rows", "cols"))
    with pytest.raises(ConfigError):
        prepare(g, weights=w, mesh=mesh, mesh_axis="rows")


def test_2d_mesh_verbs_are_typed_config_errors():
    require_devices(4)
    import jax
    from jax.sharding import Mesh
    g = gen.rmat(6, 4, seed=25)
    devs = np.array(jax.devices()[:4]).reshape(2, 2)
    sess = GraphSession(g, mesh=Mesh(devs, ("rows", "cols")),
                        mesh_axis="rows")
    with pytest.raises(ConfigError):
        sess.sssp(0)
    with pytest.raises(ConfigError):
        sess.pagerank()
