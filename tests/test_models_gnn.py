"""GNN zoo: message passing, equivariance properties, FM identities."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.spatial.transform as st_rot

from repro.models.dimenet import DimeNetConfig, apply_dimenet, init_dimenet
from repro.models.fm import (FMConfig, apply_fm, apply_fm_bags, fm_loss,
                             fm_retrieval_scores, init_fm)
from repro.models.gnn import (EGNNConfig, GNNConfig, apply_egnn, apply_gin,
                              init_egnn, init_gin)
from repro.models.nequip import (NequIPConfig, apply_nequip, gaunt_tensors,
                                 init_nequip, real_sph_harm)

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)
N, E = 20, 60


def rand_graph():
    senders = np.concatenate([RNG.integers(0, N, E),
                              np.full(4, N)]).astype(np.int32)
    receivers = np.concatenate([RNG.integers(0, N, E),
                                np.full(4, N)]).astype(np.int32)
    pos = np.zeros((N + 1, 3), np.float32)
    pos[:N] = RNG.normal(size=(N, 3))
    return senders, receivers, pos


def rotate(pos, seed=1):
    R = st_rot.Rotation.random(random_state=seed).as_matrix().astype(
        np.float32)
    t = np.array([1.0, -2.0, 0.5], np.float32)
    out = pos.copy()
    out[:N] = pos[:N] @ R.T + t
    return out, R, t


def test_gin_shapes_and_gradients():
    cfg = GNNConfig(name="g", n_layers=3, d_hidden=16, d_in=8, n_classes=4)
    p = init_gin(KEY, cfg)
    s, r, _ = rand_graph()
    feat = np.zeros((N + 1, 8), np.float32)
    feat[:N] = RNG.normal(size=(N, 8))
    out = apply_gin(p, cfg, jnp.asarray(feat), jnp.asarray(s), jnp.asarray(r))
    assert out.shape == (N + 1, 4)

    def loss(p_):
        lg = apply_gin(p_, cfg, jnp.asarray(feat), jnp.asarray(s),
                       jnp.asarray(r))
        return (lg ** 2).mean()

    g = jax.grad(loss)(p)
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree_util.tree_leaves(g))


def test_gin_remat_equivalent():
    cfg = GNNConfig(name="g", n_layers=3, d_hidden=16, d_in=8, n_classes=4)
    p = init_gin(KEY, cfg)
    s, r, _ = rand_graph()
    feat = np.zeros((N + 1, 8), np.float32)
    feat[:N] = RNG.normal(size=(N, 8))
    a = apply_gin(p, cfg, jnp.asarray(feat), jnp.asarray(s), jnp.asarray(r),
                  remat=False)
    b = apply_gin(p, cfg, jnp.asarray(feat), jnp.asarray(s), jnp.asarray(r),
                  remat=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_egnn_equivariance():
    cfg = EGNNConfig(name="e", n_layers=3, d_hidden=16, d_in=8)
    p = init_egnn(KEY, cfg)
    s, r, pos = rand_graph()
    feat = np.zeros((N + 1, 8), np.float32)
    feat[:N] = RNG.normal(size=(N, 8))
    gids = np.concatenate([np.zeros(N, np.int32), [1]]).astype(np.int32)
    e1, x1 = apply_egnn(p, cfg, jnp.asarray(feat), jnp.asarray(pos),
                        jnp.asarray(s), jnp.asarray(r), jnp.asarray(gids))
    pos2, R, t = rotate(pos)
    e2, x2 = apply_egnn(p, cfg, jnp.asarray(feat), jnp.asarray(pos2),
                        jnp.asarray(s), jnp.asarray(r), jnp.asarray(gids))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-3,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(x2)[:N],
                               np.asarray(x1)[:N] @ R.T + t, rtol=1e-3,
                               atol=1e-3)


def test_nequip_rotation_invariance():
    cfg = NequIPConfig(name="n", n_layers=2, channels=8, n_species=4)
    species = np.concatenate([RNG.integers(0, 4, N), [0]]).astype(np.int32)
    p = init_nequip(KEY, cfg)
    s, r, pos = rand_graph()
    e1 = apply_nequip(p, cfg, jnp.asarray(species), jnp.asarray(pos),
                      jnp.asarray(s), jnp.asarray(r))
    pos2, _, _ = rotate(pos)
    e2 = apply_nequip(p, cfg, jnp.asarray(species), jnp.asarray(pos2),
                      jnp.asarray(s), jnp.asarray(r))
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-3, atol=1e-5)


def test_gaunt_tensors_selection_rules():
    gt = gaunt_tensors()
    for (l1, l2, l3) in gt:
        assert (l1 + l2 + l3) % 2 == 0
        assert abs(l1 - l2) <= l3 <= l1 + l2
    # (0,0,0) must integrate to Y00 normalisation
    np.testing.assert_allclose(gt[(0, 0, 0)][0, 0, 0], 0.28209479,
                               rtol=1e-5)


def test_sph_harm_orthonormal():
    """Quadrature check: ∫ Y_a Y_b = δ_ab over our real SH basis."""
    nt, nphi = 64, 128
    t, wt = np.polynomial.legendre.leggauss(nt)
    phi = (np.arange(nphi) + 0.5) * (2 * np.pi / nphi)
    ct = t[:, None] * np.ones(nphi)[None, :]
    stq = np.sqrt(1 - ct ** 2)
    xyz = np.stack([stq * np.cos(phi), stq * np.sin(phi), ct], axis=-1)
    Y = real_sph_harm(jnp.asarray(xyz))
    Yall = np.concatenate([np.asarray(y) for y in Y], axis=-1)  # (nt,np,9)
    w = wt[:, None] * (2 * np.pi / nphi)
    gram = np.einsum("tp,tpa,tpb->ab", w, Yall, Yall)
    np.testing.assert_allclose(gram, np.eye(9), atol=1e-6)


def test_dimenet_rotation_invariance_and_grads():
    cfg = DimeNetConfig(name="d", n_blocks=2, d_hidden=16, n_species=4)
    species = np.concatenate([RNG.integers(0, 4, N), [0]]).astype(np.int32)
    p = init_dimenet(KEY, cfg)
    s, r, pos = rand_graph()
    E2 = len(s)
    trips = [(e1, e2) for e2 in range(E2) for e1 in range(E2)
             if s[e2] < N and r[e1] == s[e2] and s[e1] != r[e2]
             and s[e1] < N][:100]
    trips = np.array(trips or [(E2, E2)], np.int32)
    args = (jnp.asarray(species), jnp.asarray(pos), jnp.asarray(s),
            jnp.asarray(r), jnp.asarray(trips[:, 0]), jnp.asarray(trips[:, 1]))
    e1 = apply_dimenet(p, cfg, *args)
    pos2, _, _ = rotate(pos)
    e2 = apply_dimenet(p, cfg, jnp.asarray(species), jnp.asarray(pos2),
                       *args[2:])
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-3, atol=1e-5)
    g = jax.grad(lambda pp: apply_dimenet(pp, cfg, *args))(p)
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree_util.tree_leaves(g))


def test_fm_identities():
    cfg = FMConfig(name="f", n_fields=10, embed_dim=6, rows_per_field=50)
    p = init_fm(KEY, cfg)
    ids = jnp.asarray(RNG.integers(0, 50, (16, 10)).astype(np.int32))
    labels = jnp.asarray(RNG.integers(0, 2, 16).astype(np.float32))
    loss = fm_loss(p, cfg, ids, labels)
    assert np.isfinite(float(loss))
    # sum-square trick == brute-force pairwise
    v = np.asarray(p["v"])
    rows = np.asarray(ids) + np.arange(10)[None, :] * 50
    brute = np.zeros(16)
    for b in range(16):
        vecs = v[rows[b]]
        for i in range(10):
            for j in range(i + 1, 10):
                brute[b] += vecs[i] @ vecs[j]
    fast = np.asarray(apply_fm(p, cfg, ids)) - float(p["b"]) \
        - np.asarray(p["w"])[rows].sum(1)
    np.testing.assert_allclose(fast, brute, rtol=1e-4, atol=1e-5)
    # bags == single-hot
    flat = rows.astype(np.int32).reshape(-1)
    bag_ids = np.arange(160, dtype=np.int32)
    lb = apply_fm_bags(p, cfg, jnp.asarray(flat), jnp.asarray(bag_ids), 160)
    la = apply_fm(p, cfg, ids) - p["b"]
    np.testing.assert_allclose(np.asarray(lb), np.asarray(la), rtol=2e-5,
                               atol=2e-5)
    sc = fm_retrieval_scores(p, cfg, ids[0, :4],
                             jnp.asarray(RNG.integers(0, 50, (500, 5))
                                         .astype(np.int32)))
    assert sc.shape == (500,) and np.isfinite(np.asarray(sc)).all()
