"""Serving engine + data pipeline tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import NeighborSampler, TokenPipeline, TokenPipelineConfig
from repro.data.graphs import full_graph_batch, molecule_batch, recsys_batch
from repro.graphs import generators as gen
from repro.models import LMConfig
from repro.models import transformer as T
from repro.serve import Request, ServeEngine


def test_token_pipeline_deterministic_and_sharded():
    cfg = TokenPipelineConfig(vocab=128, seq_len=16, global_batch=8)
    p0 = TokenPipeline(cfg, shard=0, num_shards=2)
    p1 = TokenPipeline(cfg, shard=1, num_shards=2)
    a, b = p0.batch(3), p0.batch(3)
    np.testing.assert_array_equal(a, b)            # deterministic
    assert not np.array_equal(p0.batch(3), p1.batch(3))  # shards differ
    assert not np.array_equal(p0.batch(3), p0.batch(4))  # steps differ
    assert a.shape == (4, 16) and a.min() >= 0 and a.max() < 128


def test_neighbor_sampler_is_bfs_frontier():
    g = gen.rmat(8, 8, seed=1)
    s = NeighborSampler(g, fanouts=(5, 3), seed=0)
    batch = s.sample(np.array([0, 1, 2]), max_nodes=128, max_edges=256)
    # every edge endpoint is a valid local id; seeds are first
    live = batch.senders < 128
    assert (batch.receivers[live] < 128).all()
    assert batch.seed_mask[:3].all()
    # edges really exist in the graph (in-neighbour direction)
    tp, ti = g.t_csr
    for s_l, d_l in zip(batch.senders[live][:50], batch.receivers[live][:50]):
        gs, gd = batch.node_ids[s_l], batch.node_ids[d_l]
        assert gs in ti[tp[gd]:tp[gd + 1]]
    # fanout bound: each node pulls at most fanout in-neighbours per hop
    from collections import Counter
    c = Counter(batch.receivers[live].tolist())
    assert max(c.values()) <= 5 + 3  # seed hop + next hop can share a node


def test_molecule_batch_triplets_consistent():
    mb = molecule_batch(4, 10, 24, seed=0)
    E = len(mb.senders)
    live_t = (mb.t_kj < E) & (mb.t_ji < E)
    # triplet edges share the middle vertex j: receiver(kj) == sender(ji)
    assert (mb.senders[mb.t_ji[live_t]] == mb.receivers[mb.t_kj[live_t]]).all()


def test_recsys_batch_learnable():
    ids, labels = recsys_batch(512, 8, 100, seed=0)
    assert ids.shape == (512, 8) and ids.max() < 100
    assert 0.15 < labels.mean() < 0.85   # non-degenerate labels


def test_serve_engine_matches_standalone_decode():
    cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                   d_head=16, d_ff=64, vocab=64, window=16,
                   local_global=(1, 1))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, max_batch=3, max_len=64, prompt_len=8)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, 64, rng.integers(3, 9)),
                    max_new_tokens=6) for _ in range(5)]
    outs = eng.run(reqs)
    assert len(outs) == 5
    prompt = np.asarray(reqs[0].prompt, np.int32)
    pad = 8 - len(prompt)
    padded = (np.concatenate([np.full(pad, prompt[0], np.int32), prompt])
              if pad > 0 else prompt[-8:])
    logits, caches = T.prefill(params, cfg, jnp.asarray(padded[None, :]),
                               max_len=64, compute_dtype=jnp.float32)
    toks = [int(jnp.argmax(logits[0]))]
    pos = 8
    for _ in range(5):
        lg, caches = T.decode_step(
            params, cfg, caches,
            jnp.asarray([[toks[-1]]], dtype=jnp.int32), jnp.int32(pos),
            compute_dtype=jnp.float32)
        toks.append(int(jnp.argmax(lg[0])))
        pos += 1
    assert outs[0].tokens[-6:] == toks


def test_full_graph_batch_shapes():
    g = gen.rmat(7, 6, seed=2)
    fb = full_graph_batch(g, d_feat=16, n_classes=4, seed=0)
    assert fb.node_feat.shape == (g.n + 1, 16)
    assert (fb.node_feat[-1] == 0).all()       # dummy row zero
    assert fb.senders.max() < g.n


def test_serve_engine_empty_prompt():
    """Regression: a zero-length prompt must prefill as BOS/0 padding
    instead of crashing on ``prompt[0]``."""
    cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=1,
                   d_head=16, d_ff=64, vocab=64, window=16,
                   local_global=(1, 1))
    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, max_batch=2, max_len=32, prompt_len=8)
    reqs = [Request(prompt=np.array([], dtype=np.int32), max_new_tokens=4),
            Request(prompt=np.array([3, 5], dtype=np.int32),
                    max_new_tokens=4)]
    outs = eng.run(reqs)
    assert len(outs) == 2
    assert len(outs[0].tokens) == 4          # empty prompt: only generated
    assert all(0 <= t < 64 for t in outs[0].tokens)
