"""The CI perf-regression gate (benchmarks/perf_gate.py): floors trip on
regression, pass at par, and the checked-in floors file is well-formed."""
import json
import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.perf_gate import DEFAULT_FLOORS, check, resolve  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def artifact(wave=2.0, comp=1.2):
    return {
        "fused": {"summary": {"geomean_speedup_blest": 1.5}},
        "service": {"summary": {"geomean_wave_speedup": wave}},
        "analytics": {"summary": {"geomean_components_speedup": comp}},
    }


def test_resolve_dotted_paths():
    a = artifact()
    assert resolve(a, "service.summary.geomean_wave_speedup") == 2.0
    assert resolve(a, "service.summary.nope") is None
    assert resolve(a, "nope.summary") is None


def test_gate_passes_at_or_above_floor():
    floors = {"service.summary.geomean_wave_speedup": 2.0,
              "analytics.summary.geomean_components_speedup": 1.0}
    _, violations = check(artifact(), floors)
    assert violations == []


def test_gate_fails_below_floor_and_on_missing_metric():
    floors = {"service.summary.geomean_wave_speedup": 2.5,
              "dist.summary.geomean_wave_speedup": 1.0}
    _, violations = check(artifact(), floors)
    assert len(violations) == 2
    assert any("MISSING" in v for v in violations)


def test_gate_fails_when_floors_artificially_raised():
    """The acceptance demonstration: raising the floors must trip the gate
    on an artifact that passes the real ones."""
    floors = {"service.summary.geomean_wave_speedup": 1.5}
    _, ok = check(artifact(), floors)
    assert ok == []
    _, raised = check(artifact(), {k: v * 100 for k, v in floors.items()})
    assert raised != []


def test_checked_in_floors_are_wellformed():
    with open(DEFAULT_FLOORS) as f:
        spec = json.load(f)
    assert 0 < spec["max_regression"] < 1
    assert spec["floors"], "floors file must gate at least one metric"
    for dotted, floor in spec["floors"].items():
        suite = dotted.split(".")[0]
        assert suite in ("fused", "service", "dist", "analytics",
                         "hybrid", "scale_sweep", "queue"), dotted
        # gated metrics live under a suite summary, or (PR 8) the
        # trace-time comm-volume block of the dist2d partition bench
        assert ".summary." in dotted or ".comm." in dotted, dotted
        assert floor > 0, dotted


@pytest.mark.parametrize("mode", ["pass", "fail", "empty"])
def test_gate_only_prefix_filters_floors(tmp_path, mode):
    """--only gates just the matching floors (the compiled-smoke job's
    hybrid-only artifact); an empty selection is an error, never a
    vacuous pass."""
    art = tmp_path / "bench.json"
    art.write_text(json.dumps(
        {"hybrid": {"summary": {"geomean_hybrid_vs_pull":
                                1.3 if mode != "fail" else 0.5}}}))
    floors = {"max_regression": 0.25,
              "floors": {"hybrid.summary.geomean_hybrid_vs_pull": 1.15,
                         # would be MISSING from the partial artifact —
                         # --only must exclude it for the gate to pass
                         "service.summary.geomean_wave_speedup": 2.0}}
    fl = tmp_path / "floors.json"
    fl.write_text(json.dumps(floors))
    prefix = "nonsense." if mode == "empty" else "hybrid."
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.perf_gate", str(art),
         "--floors", str(fl), "--only", prefix],
        cwd=REPO, capture_output=True, text=True)
    expected = 0 if mode == "pass" else 1
    assert res.returncode == expected, res.stdout + res.stderr
    if mode == "empty":
        assert "refusing to vacuously pass" in res.stdout


@pytest.mark.parametrize("mode", ["covered", "uncovered"])
def test_gate_require_covered_suites(tmp_path, mode):
    """--require-covered (the weekly full-depth run): every top-level
    suite the artifact carries must have at least one floor under it, so
    a newly added bench suite cannot silently escape the gate."""
    art_dict = {"hybrid": {"summary": {"geomean_hybrid_vs_pull": 1.3}}}
    if mode == "uncovered":
        art_dict["brand_new_suite"] = {"summary": {"metric": 1.0}}
    art = tmp_path / "bench.json"
    art.write_text(json.dumps(art_dict))
    floors = {"max_regression": 0.25,
              "floors": {"hybrid.summary.geomean_hybrid_vs_pull": 1.15}}
    fl = tmp_path / "floors.json"
    fl.write_text(json.dumps(floors))
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.perf_gate", str(art),
         "--floors", str(fl), "--require-covered"],
        cwd=REPO, capture_output=True, text=True)
    if mode == "covered":
        assert res.returncode == 0, res.stdout + res.stderr
        assert "covered by floors" in res.stdout
    else:
        assert res.returncode == 1, res.stdout + res.stderr
        assert "brand_new_suite" in res.stdout


def test_checked_in_floors_cover_every_run_py_suite(tmp_path):
    """The suites ``benchmarks.run --json`` emits must each carry at
    least one checked-in floor — the contract --require-covered enforces
    against the weekly artifact, checked here statically so a PR adding
    a suite without a floor fails tier-1, not next Monday."""
    with open(DEFAULT_FLOORS) as f:
        spec = json.load(f)
    # the top-level suite keys run.py assembles into the artifact
    run_py = open(os.path.join(REPO, "benchmarks", "run.py")).read()
    for suite in ("fused", "service", "dist", "analytics", "hybrid",
                  "scale_sweep", "queue"):
        assert f'"{suite}"' in run_py, f"run.py no longer emits {suite}?"
        assert any(path.startswith(suite + ".")
                   for path in spec["floors"]), \
            f"no checked-in floor covers the {suite!r} suite"


@pytest.mark.parametrize("mode", ["pass", "fail", "prove"])
def test_gate_cli_exit_codes(tmp_path, mode):
    art = tmp_path / "bench.json"
    art.write_text(json.dumps(artifact()))
    floors = {"max_regression": 0.25,
              "floors": {"service.summary.geomean_wave_speedup":
                         2.0 if mode != "fail" else 99.0}}
    fl = tmp_path / "floors.json"
    fl.write_text(json.dumps(floors))
    cmd = [sys.executable, "-m", "benchmarks.perf_gate", str(art),
           "--floors", str(fl)]
    if mode == "prove":
        cmd.append("--prove-gate")
    res = subprocess.run(cmd, cwd=REPO, capture_output=True, text=True)
    expected = 1 if mode == "fail" else 0
    assert res.returncode == expected, res.stdout + res.stderr
