"""Incremental BVSS maintenance (core/bvss_delta.py, DESIGN §2.10).

The contract under test: ``apply_edge_updates`` produces a PreparedBFS
whose BVSS is BIT-IDENTICAL to a fresh build of the mutated graph under
the same ordering (masks, row_ids, occupancy), whose weight plane matches
the merged weights, whose epoch advances by exactly one — and whose OLD
epoch's arrays are untouched, so in-flight waves finish on consistent
state.  Fallbacks: the staleness ledger forces a full re-``prepare`` past
the budget; ``expected_epoch`` turns concurrent updates into a typed
``StaleEpochError`` instead of a lost update.
"""
import dataclasses

import numpy as np
import pytest

from repro import (GraphValidationError, PrepareOptions, StaleEpochError,
                   apply_edge_updates, from_edges, prepare)
from repro.core import build_bvss, reference_bfs
from repro.core.bvss_delta import STALENESS_FRACTION
from repro.errors import ConfigError
from repro.graphs import generators as gen, src_of_edges
from tests.conftest import require_devices

INF = np.iinfo(np.int32).max


@pytest.fixture(scope="module")
def graph():
    return gen.rmat(7, 8, seed=21)


def _prep(g, **opts):
    return prepare(g, options=PrepareOptions(w=512, seed=0, **opts))


def _caller_graph(prep):
    """The caller-id view of the prepared (ordered) graph."""
    src_c = prep.inv[src_of_edges(prep.graph)]
    dst_c = prep.inv[prep.graph.indices]
    return from_edges(prep.graph.n, src_c, dst_c, dedup=True,
                      drop_loops=False)


def _missing_edge(prep):
    """Some (a, b) caller-id pair that is NOT an edge of prep.graph."""
    have = set(zip(prep.inv[src_of_edges(prep.graph)].tolist(),
                   prep.inv[prep.graph.indices].tolist()))
    n = prep.graph.n
    return next((a, b) for a in range(n) for b in range(n)
                if a != b and (a, b) not in have)


def _assert_fresh_build_parity(prep):
    """prep's BVSS must equal a fresh build of prep.graph bit for bit."""
    b2 = build_bvss(prep.graph, sigma=prep.bvss.sigma)
    np.testing.assert_array_equal(prep.bvss.masks, b2.masks)
    np.testing.assert_array_equal(prep.bvss.row_ids, b2.row_ids)
    np.testing.assert_array_equal(prep.bvss.real_ptrs, b2.real_ptrs)
    np.testing.assert_array_equal(prep.bvss.virtual_to_real,
                                  b2.virtual_to_real)
    assert prep.bvss.num_slices == b2.num_slices
    assert prep.bvss.m == b2.m


# ---------------------------------------------------------------------------
# bit-identity on randomized insert/delete sequences
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_randomized_sequences_bit_identical(graph, seed):
    prep = _prep(graph)
    rng = np.random.default_rng(seed)
    for round_i in range(4):
        ins = sorted({(int(a), int(b))
                      for a, b in rng.integers(0, graph.n, (5, 2))
                      if a != b})
        src_c = prep.inv[src_of_edges(prep.graph)]
        dst_c = prep.inv[prep.graph.indices]
        pick = rng.choice(len(src_c), size=3, replace=False)
        dels = sorted({(int(src_c[p]), int(dst_c[p])) for p in pick}
                      - set(ins))
        prep = apply_edge_updates(prep, inserts=ins, deletes=dels)
        assert prep.epoch == round_i + 1
        assert prep.last_update.path in ("patched", "rebuilt",
                                         "reprepared")
        _assert_fresh_build_parity(prep)
        g_now = _caller_graph(prep)
        for s in (0, graph.n // 2):
            np.testing.assert_array_equal(prep.levels(s),
                                          reference_bfs(g_now, s))


def test_insert_makes_vertex_reachable(graph):
    prep = _prep(graph)
    lv0 = prep.levels(0)
    far = int(np.argmax(lv0 == INF))
    assert lv0[far] == INF
    prep2 = apply_edge_updates(prep, inserts=[(0, far)])
    assert prep2.levels(0)[far] == 1


def test_delete_disconnects(graph):
    prep = _prep(graph)
    # pick a real edge and delete it; the edge count drops by one
    a = int(src_of_edges(prep.graph)[0])
    b = int(prep.graph.indices[0])
    edge = (int(prep.inv[a]), int(prep.inv[b]))
    prep2 = apply_edge_updates(prep, deletes=[edge])
    assert prep2.graph.m == prep.graph.m - 1
    _assert_fresh_build_parity(prep2)


# ---------------------------------------------------------------------------
# epoch versioning
# ---------------------------------------------------------------------------
def test_epoch_advances_and_old_arrays_untouched(graph):
    """Functional updates: epoch N's device masks are NOT mutated by the
    epoch N+1 patch — an in-flight wave holding the old problem keeps a
    consistent structure."""
    prep = _prep(graph)
    assert prep.epoch == 0
    old_masks = None
    if prep.problem is not None:
        old_masks = np.asarray(prep.problem.dev.masks).copy()
    old_host_masks = prep.bvss.masks.copy()
    prep2 = apply_edge_updates(prep, inserts=[_missing_edge(prep)])
    assert prep2.epoch == 1 and prep.epoch == 0
    np.testing.assert_array_equal(prep.bvss.masks, old_host_masks)
    if old_masks is not None:
        np.testing.assert_array_equal(np.asarray(prep.problem.dev.masks),
                                      old_masks)
    # the old prepared still answers on the OLD graph
    np.testing.assert_array_equal(prep.levels(0),
                                  reference_bfs(_caller_graph(prep), 0))


def test_expected_epoch_cas(graph):
    prep = _prep(graph)
    new = _missing_edge(prep)
    prep2 = apply_edge_updates(prep, inserts=[new], expected_epoch=0)
    assert prep2.epoch == 1
    with pytest.raises(StaleEpochError) as ei:
        apply_edge_updates(prep, inserts=[_missing_edge(prep)],
                           expected_epoch=1)
    assert ei.value.expected == 1 and ei.value.actual == 0


def test_noop_update_returns_same_object(graph):
    prep = _prep(graph)
    # inserting an existing edge of an unweighted prepared is a no-op
    a = int(prep.inv[src_of_edges(prep.graph)[0]])
    b = int(prep.inv[prep.graph.indices[0]])
    assert apply_edge_updates(prep, inserts=[(a, b)]) is prep
    assert apply_edge_updates(prep) is prep


# ---------------------------------------------------------------------------
# staleness ledger -> full re-prepare
# ---------------------------------------------------------------------------
def test_staleness_budget_forces_reprepare(graph):
    prep = _prep(graph)
    prep2 = apply_edge_updates(prep, inserts=[_missing_edge(prep)],
                               staleness_budget=0)
    assert prep2.last_update.path == "reprepared"
    assert prep2.stale_edges == 0
    assert "staleness" in prep2.last_update.reason
    _assert_fresh_build_parity(prep2)
    np.testing.assert_array_equal(
        prep2.levels(0), reference_bfs(_caller_graph(prep2), 0))


def test_stale_ledger_accumulates_until_budget(graph):
    prep = _prep(graph)
    budget = max(1, int(STALENESS_FRACTION * graph.m))
    rng = np.random.default_rng(3)
    while prep.last_update is None or \
            prep.last_update.path != "reprepared":
        ins = sorted({(int(a), int(b))
                      for a, b in rng.integers(0, graph.n, (8, 2))
                      if a != b})
        prep = apply_edge_updates(prep, inserts=ins)
        assert prep.epoch <= 4 * budget, "re-prepare never triggered"
    assert prep.stale_edges == 0           # ledger reset by the re-prepare


# ---------------------------------------------------------------------------
# weighted plane
# ---------------------------------------------------------------------------
def test_weighted_insert_and_reweight(graph):
    rng = np.random.default_rng(4)
    w = (rng.integers(1, 128, graph.m) / 32.0).astype(np.float32)
    prep = _prep(graph, weights=w)
    assert prep.weights is not None

    lv0 = prep.levels(0)
    far = int(np.argmax(lv0 == INF))
    prep2 = apply_edge_updates(prep, inserts=[(0, far)],
                               insert_weights=[2.5])
    # the merged weight vector holds the new edge's weight at its slot
    a_ord, b_ord = int(prep2.perm[0]), int(prep2.perm[far])
    keys = (src_of_edges(prep2.graph).astype(np.int64) * prep2.graph.n
            + prep2.graph.indices)
    slot = int(np.searchsorted(keys, a_ord * prep2.graph.n + b_ord))
    assert prep2.weights[slot] == np.float32(2.5)

    # re-inserting an existing edge with a new weight is a reweight
    e0 = (int(prep2.inv[src_of_edges(prep2.graph)[0]]),
          int(prep2.inv[prep2.graph.indices[0]]))
    prep3 = apply_edge_updates(prep2, inserts=[e0], insert_weights=[9.0])
    assert prep3.last_update.n_reweighted == 1
    keys3 = (src_of_edges(prep3.graph).astype(np.int64) * prep3.graph.n
             + prep3.graph.indices)
    slot3 = int(np.searchsorted(
        keys3, int(prep3.perm[e0[0]]) * prep3.graph.n
        + int(prep3.perm[e0[1]])))
    assert prep3.weights[slot3] == np.float32(9.0)


def test_weight_validation(graph):
    prep = _prep(graph)              # unweighted
    with pytest.raises(ConfigError):
        apply_edge_updates(prep, inserts=[(0, 1)], insert_weights=[1.0])
    rng = np.random.default_rng(5)
    w = (rng.integers(1, 8, graph.m) / 4.0).astype(np.float32)
    wp = _prep(graph, weights=w)
    with pytest.raises(GraphValidationError):
        apply_edge_updates(wp, inserts=[_missing_edge(wp)])  # no weight


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
def test_delete_missing_edge_rejected(graph):
    prep = _prep(graph)
    have = set(zip(prep.inv[src_of_edges(prep.graph)].tolist(),
                   prep.inv[prep.graph.indices].tolist()))
    missing = next((a, b) for a in range(graph.n) for b in range(graph.n)
                   if a != b and (a, b) not in have)
    with pytest.raises(GraphValidationError, match="not in the graph"):
        apply_edge_updates(prep, deletes=[missing])


def test_duplicate_and_conflicting_batches_rejected(graph):
    prep = _prep(graph)
    with pytest.raises(GraphValidationError):
        apply_edge_updates(prep, inserts=[(0, 1), (0, 1)])
    a = int(prep.inv[src_of_edges(prep.graph)[0]])
    b = int(prep.inv[prep.graph.indices[0]])
    with pytest.raises(GraphValidationError):
        apply_edge_updates(prep, inserts=[(a, b)], deletes=[(a, b)])


def test_out_of_range_edges_rejected(graph):
    prep = _prep(graph)
    with pytest.raises(GraphValidationError):
        apply_edge_updates(prep, inserts=[(0, graph.n)])
    with pytest.raises(GraphValidationError):
        apply_edge_updates(prep, inserts=[(-1, 0)])


def test_update_report_schema(graph):
    prep = _prep(graph)
    prep2 = apply_edge_updates(prep, inserts=[_missing_edge(prep)])
    rep = prep2.last_update
    for f in ("path", "epoch", "n_inserted", "n_deleted", "n_reweighted",
              "sets_touched", "vss_rows_rewritten", "stale_edges",
              "reason"):
        assert hasattr(rep, f), f
    assert rep.n_inserted == 1 and rep.n_deleted == 0
    assert rep.epoch == prep2.epoch == 1
    with pytest.raises(dataclasses.FrozenInstanceError):
        rep.path = "other"


# ---------------------------------------------------------------------------
# sharded (1-D mesh) parity
# ---------------------------------------------------------------------------
def test_sharded_update_matches_single_device(graph):
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh
    mesh = bfs_mesh(2)
    prep = _prep(graph, mesh=mesh)
    rng = np.random.default_rng(6)
    for round_i in range(3):
        ins = sorted({(int(a), int(b))
                      for a, b in rng.integers(0, graph.n, (4, 2))
                      if a != b})
        prep = apply_edge_updates(prep, inserts=ins)
        _assert_fresh_build_parity(prep)
        g_now = _caller_graph(prep)
        for s in (0, graph.n // 3):
            np.testing.assert_array_equal(prep.levels(s),
                                          reference_bfs(g_now, s))
