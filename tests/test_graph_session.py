"""GraphSession wave serving: oracle agreement through the slot pool with
mid-flight refills, the singleton fallback, and the caller-id contract
(levels AND centrality — the regression the old example had)."""
import numpy as np
import pytest

import repro.serve.graph_session as gs_mod
from repro.core import reference_bfs
from repro.graphs import from_edges, generators as gen
from repro.serve import GraphSession

INF = np.int32(np.iinfo(np.int32).max)


@pytest.fixture(scope="module")
def social_session():
    """An rmat session whose ordering is non-trivial (perm != identity),
    so any id-space slip shows up as a mismatch."""
    g = gen.rmat(8, 8, seed=1)
    sess = GraphSession(g, max_batch=3, w=256)
    assert sess.ordering == "jaccard_windows"
    assert (sess.perm != np.arange(g.n)).any()
    return g, sess


def test_single_query_caller_ids(social_session):
    g, sess = social_session
    for src in (0, g.n // 2, g.n - 1):
        np.testing.assert_array_equal(sess.levels(src),
                                      reference_bfs(g, src))


def test_wave_batch_more_queries_than_slots(social_session):
    """7 queries through 3 slots: finished columns must be refilled from
    the queue mid-flight, and every answer must be in caller ids."""
    g, sess = social_session
    rng = np.random.default_rng(0)
    queries = [int(q) for q in rng.integers(0, g.n, 7)]
    queries[3] = queries[0]                      # duplicate query
    lvs = sess.levels_batch(queries)
    assert len(lvs) == len(queries)
    for q, lv in zip(queries, lvs):
        np.testing.assert_array_equal(lv, reference_bfs(g, q),
                                      err_msg=f"query {q}")


def test_wave_columns_converge_at_different_levels():
    """Mix near-converging and deep queries (path graph): a slot freed by a
    shallow query must be refilled while deep columns are still running."""
    g = from_edges(60, np.arange(59), np.arange(1, 60))  # directed path
    sess = GraphSession(g, max_batch=2, order=False)
    queries = [58, 0, 55, 2, 59]                 # depths 1, 59, 4, 57, 0
    lvs = sess.levels_batch(queries)
    for q, lv in zip(queries, lvs):
        np.testing.assert_array_equal(lv, reference_bfs(g, q),
                                      err_msg=f"query {q}")


def test_singleton_falls_back_to_single_source_engine(social_session,
                                                      monkeypatch):
    import dataclasses

    g, sess = social_session
    calls = {"wave": 0}
    real = sess._ms.level_step

    def spy(st):
        calls["wave"] += 1
        return real(st)

    monkeypatch.setattr(sess, "_ms",
                        dataclasses.replace(sess._ms, level_step=spy))
    [lv] = sess.levels_batch([5])
    np.testing.assert_array_equal(lv, reference_bfs(g, 5))
    assert calls["wave"] == 0, "singleton query must not run the wave pool"


def test_empty_batch(social_session):
    _, sess = social_session
    assert sess.levels_batch([]) == []


def test_centrality_sample_caller_id_regression(social_session):
    """Regression for the old example bug: closeness scores must correspond
    to the returned caller-id sources, computed as if on the ORIGINAL
    graph (reordering must be invisible)."""
    g, sess = social_session
    srcs, cc = sess.centrality_sample(6, seed=2)
    assert srcs.shape == cc.shape == (6,)
    for s, c in zip(srcs, cc):
        lv = reference_bfs(g, int(s))
        finite = lv != INF
        dist_sum = float(lv[finite].sum())
        want = (int(finite.sum()) - 1) / dist_sum if dist_sum > 0 else 0.0
        assert c == pytest.approx(want, abs=1e-12), (s, c, want)


def test_wave_non_convergence_guard():
    g = gen.rmat(6, 4, seed=0)
    sess = GraphSession(g, max_batch=2, order=False, max_steps=0)
    with pytest.raises(RuntimeError, match="did not converge"):
        sess.levels_batch([0, 1])


def test_session_collapses_prepare_duplication():
    """The session must reuse core.policy.prepare's state, not rebuild it:
    one BVSS, one problem, shared with the prepared engine."""
    g = gen.grid2d(9, 9)
    sess = GraphSession(g, max_batch=2)
    assert sess._ms.problem is sess.prepared.problem
    assert sess._problem is sess.prepared.problem
    assert sess.bvss is sess.prepared.bvss
    # inverse permutation is a real inverse
    np.testing.assert_array_equal(sess.perm[sess.inv], np.arange(g.n))
    # monkeypatch-free sanity that module exposes what the docs promise
    assert hasattr(gs_mod, "GraphSession")
