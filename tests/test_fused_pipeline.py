"""Fused level-step pipeline: Pallas routing, bucketing, and edge cases.

The ``blest``/``blest_lazy`` default path must (a) reproduce the host
oracle exactly, (b) actually route through the Pallas kernels
(``bvss_pull`` + ``finalize_pack_sweep``), and (c) agree with the
pure-jnp fallback and with a single-bucket (no ``lax.cond``) build.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core.bfs as bfs_mod
from repro.core import make_engine, reference_bfs
from repro.graphs import from_edges, generators as gen

EDGE_CASES = {
    # directed: a one-way path — BFS from the tail reaches nothing
    "directed_path": from_edges(40, np.arange(39), np.arange(1, 40)),
    "disconnected": from_edges(50, np.array([1, 2, 10]),
                               np.array([2, 3, 11])),
    "single_vertex": from_edges(1, np.array([], dtype=np.int64),
                                np.array([], dtype=np.int64)),
    "two_isolated": from_edges(2, np.array([], dtype=np.int64),
                               np.array([], dtype=np.int64)),
    "high_diameter": gen.grid2d(23, 29),
}


@pytest.mark.parametrize("engine", ["blest", "blest_lazy"])
@pytest.mark.parametrize("gname", sorted(EDGE_CASES))
def test_fused_engine_edge_cases(engine, gname):
    g = EDGE_CASES[gname]
    fn = make_engine(g, engine)
    for src in {0, g.n // 2, g.n - 1}:
        np.testing.assert_array_equal(np.asarray(fn(src)),
                                      reference_bfs(g, src))


@pytest.mark.parametrize("engine", ["blest", "blest_lazy"])
def test_default_path_calls_pallas_kernels(engine, monkeypatch):
    """The default device path must route through the Pallas pull AND the
    fused finalise/pack kernel (not the jnp fallbacks)."""
    calls = {"pull": 0, "finalize": 0}
    real_pull = bfs_mod.pull_vss_kernel
    real_fin = bfs_mod.finalize_pack_sweep

    def spy_pull(*a, **k):
        calls["pull"] += 1
        return real_pull(*a, **k)

    def spy_fin(*a, **k):
        calls["finalize"] += 1
        return real_fin(*a, **k)

    monkeypatch.setattr(bfs_mod, "pull_vss_kernel", spy_pull)
    monkeypatch.setattr(bfs_mod, "finalize_pack_sweep", spy_fin)
    g = gen.rmat(7, 8, seed=3)
    fn = make_engine(g, engine)
    np.testing.assert_array_equal(np.asarray(fn(1)), reference_bfs(g, 1))
    assert calls["pull"] > 0, "Pallas bvss_pull not on the default path"
    assert calls["finalize"] > 0, \
        "Pallas finalize_pack_sweep not on the default path"


@pytest.mark.parametrize("engine", ["blest", "blest_lazy"])
def test_kernel_and_jnp_paths_agree(engine):
    g = gen.rmat(8, 6, seed=4)
    f_kernel = make_engine(g, engine, use_kernels=True)
    f_jnp = make_engine(g, engine, use_kernels=False)
    for src in (0, 7, g.n - 1):
        np.testing.assert_array_equal(np.asarray(f_kernel(src)),
                                      np.asarray(f_jnp(src)))
        np.testing.assert_array_equal(np.asarray(f_jnp(src)),
                                      reference_bfs(g, src))


@pytest.mark.parametrize("engine", ["blest", "blest_lazy"])
def test_bucketed_pull_matches_single_bucket(engine):
    """The 2-bucket cond-selected queue width must be invisible in the
    output; a high-diameter grid exercises the small bucket, an rmat the
    full one."""
    for g in (gen.grid2d(19, 23), gen.rmat(8, 8, seed=5)):
        f2 = make_engine(g, engine, buckets=2)
        f1 = make_engine(g, engine, buckets=1)
        for src in (0, g.n - 1):
            ref = reference_bfs(g, src)
            np.testing.assert_array_equal(np.asarray(f2(src)), ref)
            np.testing.assert_array_equal(np.asarray(f1(src)), ref)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 120), m=st.integers(0, 500),
       seed=st.integers(0, 10_000),
       engine=st.sampled_from(["blest", "blest_lazy"]))
def test_fused_pallas_path_random_graphs(n, m, seed, engine):
    """Hypothesis parity of the fused Pallas (interpret) path vs oracle on
    directed random multigraph edge lists."""
    rng = np.random.default_rng(seed)
    g = from_edges(n, rng.integers(0, n, m), rng.integers(0, n, m))
    fn = make_engine(g, engine)
    src = int(rng.integers(0, n))
    np.testing.assert_array_equal(np.asarray(fn(src)),
                                  reference_bfs(g, src))
