"""Ingress validation (DESIGN §2.7): the malformed-graph matrix, source
bounds on every serve verb, and degenerate graphs end-to-end through
GraphSession — single-device AND mesh-sharded.

Every check here must hold under ``python -O`` too (the CI chaos job runs
an ``-O`` smoke lane), which is why the library raises
``GraphValidationError`` instead of asserting."""
import numpy as np
import pytest

from conftest import require_devices
from repro.core import reference_bfs
from repro.core.bvss import build_bvss
from repro.core.policy import prepare
from repro.errors import (BlestError, GraphValidationError, check_source,
                          check_sources)
from repro.graphs import Graph, from_edges, generators as gen
from repro.serve import GraphSession

INF = np.int32(np.iinfo(np.int32).max)


# ---------------------------------------------------------------------------
# malformed-graph matrix
# ---------------------------------------------------------------------------
GOOD_INDPTR = np.array([0, 2, 3, 3, 4], dtype=np.int64)
GOOD_INDICES = np.array([1, 2, 3, 0], dtype=np.int32)

BAD_GRAPHS = {
    "negative-n": (-1, GOOD_INDPTR, GOOD_INDICES),
    "float-n": (4.0, GOOD_INDPTR, GOOD_INDICES),
    "float-indptr": (4, GOOD_INDPTR.astype(np.float64), GOOD_INDICES),
    "float-indices": (4, GOOD_INDPTR, GOOD_INDICES.astype(np.float32)),
    "short-indptr": (4, GOOD_INDPTR[:-1], GOOD_INDICES),
    "long-indptr": (4, np.append(GOOD_INDPTR, 4), GOOD_INDICES),
    "nonzero-start": (4, GOOD_INDPTR + 1, GOOD_INDICES),
    "tail-mismatch": (4, np.array([0, 2, 3, 3, 9]), GOOD_INDICES),
    "non-monotone": (4, np.array([0, 3, 2, 3, 4]), GOOD_INDICES),
    "oob-index": (4, GOOD_INDPTR,
                  np.array([1, 2, 7, 0], dtype=np.int32)),
    "negative-index": (4, GOOD_INDPTR,
                       np.array([1, -1, 3, 0], dtype=np.int32)),
}


@pytest.mark.parametrize("case", sorted(BAD_GRAPHS))
def test_malformed_graph_rejected(case):
    n, indptr, indices = BAD_GRAPHS[case]
    with pytest.raises(GraphValidationError):
        Graph(n, indptr, indices)


def test_good_graph_accepted():
    g = Graph(4, GOOD_INDPTR, GOOD_INDICES)
    assert g.m == 4
    np.testing.assert_array_equal(reference_bfs(g, 0),
                                  [0, 1, 1, 2])


def test_error_messages_name_the_defect():
    with pytest.raises(GraphValidationError, match="non-decreasing"):
        Graph(4, np.array([0, 3, 2, 3, 4]), GOOD_INDICES)
    with pytest.raises(GraphValidationError, match="out-of-range"):
        Graph(4, GOOD_INDPTR, np.array([1, 2, 7, 0], dtype=np.int32))
    with pytest.raises(GraphValidationError, match="indptr\\[0\\]"):
        Graph(4, GOOD_INDPTR + 1, GOOD_INDICES)


@pytest.mark.parametrize("perm", [
    np.array([0, 1, 2]),                       # wrong length
    np.array([0.0, 1.0, 2.0, 3.0]),            # float dtype
    np.array([0, 1, 1, 3]),                    # duplicate
    np.array([0, 1, 2, 4]),                    # out of range
    np.array([0, 1, 2, -1]),                   # negative
])
def test_bad_permutations_rejected(perm):
    g = Graph(4, GOOD_INDPTR, GOOD_INDICES)
    with pytest.raises(GraphValidationError):
        g.permute(perm)
    with pytest.raises(GraphValidationError):
        g.permute_fast(perm)


def test_bad_sigma_rejected():
    g = Graph(4, GOOD_INDPTR, GOOD_INDICES)
    for sigma in (0, 3, 33, -8):
        with pytest.raises(GraphValidationError):
            build_bvss(g, sigma=sigma)


# ---------------------------------------------------------------------------
# source-id bounds on the serve path (the perm[-1] silent-wrap regression)
# ---------------------------------------------------------------------------
def test_check_source_contract():
    assert check_source(3, 10) == 3
    assert check_source(np.int64(0), 10) == 0
    for bad in (-1, 10, 3.5, True, "3", None):
        with pytest.raises(GraphValidationError):
            check_source(bad, 10)
    with pytest.raises(GraphValidationError):
        check_sources([[0, 1]], 10)            # not 1-D
    with pytest.raises(GraphValidationError):
        check_sources(np.array([0.5, 1.0]), 10)
    assert check_sources(np.array([2, 0]), 3) == [2, 0]


@pytest.fixture(scope="module")
def small_session():
    g = gen.rmat(6, 6, seed=3)
    return g, GraphSession(g, max_batch=3)


def test_prepared_levels_rejects_bad_sources(small_session):
    g, sess = small_session
    # the regression: perm[-1] used to silently serve the LAST vertex
    with pytest.raises(GraphValidationError):
        sess.prepared.levels(-1)
    with pytest.raises(GraphValidationError):
        sess.prepared.levels(g.n)


@pytest.mark.parametrize("bad", [-1, 10_000, 2.5, True])
def test_session_verbs_reject_bad_sources(small_session, bad):
    _, sess = small_session
    with pytest.raises(GraphValidationError):
        sess.levels(bad)
    with pytest.raises(GraphValidationError):
        sess.levels_batch([0, bad])
    with pytest.raises(GraphValidationError):
        sess.closeness([bad])
    with pytest.raises(GraphValidationError):
        sess.eccentricity([0, bad])
    with pytest.raises(GraphValidationError):
        sess.betweenness([bad, 1])


def test_prepared_without_engine_raises_typed_error(small_session):
    import dataclasses
    _, sess = small_session
    hollow = dataclasses.replace(sess.prepared, _fn=None)
    with pytest.raises(BlestError):
        hollow.levels(0)


def test_csr_mode_rejected():
    from repro.core.bfs import make_csr_bfs
    g = Graph(4, GOOD_INDPTR, GOOD_INDICES)
    with pytest.raises(GraphValidationError):
        make_csr_bfs(g, "sideways")


# ---------------------------------------------------------------------------
# degenerate graphs end-to-end through GraphSession
# ---------------------------------------------------------------------------
def _empty_graph(n: int) -> Graph:
    return Graph(n, np.zeros(n + 1, dtype=np.int64),
                 np.zeros(0, dtype=np.int32))


DEGENERATE = {
    "single-vertex": (_empty_graph(1), [0]),
    "zero-edge": (_empty_graph(40), [0, 17, 39]),
    "all-isolated-but-one-edge": (
        from_edges(40, np.array([0]), np.array([1])), [0, 1, 25]),
    "source-in-empty-component": (
        from_edges(50, np.arange(10), np.arange(1, 11)), [45, 0, 49]),
}


@pytest.mark.parametrize("case", sorted(DEGENERATE))
def test_degenerate_graphs_single_device(case):
    g, sources = DEGENERATE[case]
    sess = GraphSession(g, max_batch=2, order=False)
    for s in sources:
        np.testing.assert_array_equal(sess.levels(s), reference_bfs(g, s),
                                      err_msg=f"{case}: levels({s})")
    lvs = sess.levels_batch(sources)
    for s, lv in zip(sources, lvs):
        np.testing.assert_array_equal(lv, reference_bfs(g, s),
                                      err_msg=f"{case}: batch {s}")


@pytest.mark.parametrize("case", sorted(DEGENERATE))
def test_degenerate_graphs_mesh(case):
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh
    g, sources = DEGENERATE[case]
    sess = GraphSession(g, max_batch=2, order=False, mesh=bfs_mesh(2))
    lvs = sess.levels_batch(sources)
    for s, lv in zip(sources, lvs):
        np.testing.assert_array_equal(lv, reference_bfs(g, s),
                                      err_msg=f"{case}: mesh batch {s}")


def test_degenerate_prepare_round_trip():
    """The full static pipeline (ordering included) must survive the
    degenerate shapes, not just the order=False session path."""
    for case, (g, sources) in DEGENERATE.items():
        prep = prepare(g)
        for s in sources:
            np.testing.assert_array_equal(
                prep.levels(s), reference_bfs(g, s),
                err_msg=f"{case}: prepared levels({s})")
