"""Analytics suite: wave-engine clients vs independent oracles
(DESIGN §2.6) — weighted tiles, σ channel, components / eccentricity /
betweenness / closeness, edge cases, caller-id contract, sharded
parity (skip locally, FAIL when CI sets BLEST_REQUIRE_MULTIDEVICE)."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_devices
from repro.analytics import (betweenness_centrality, closeness_centrality,
                             connected_components, eccentricities,
                             ifub_extremes)
from repro.core import INF, reference_bfs
from repro.core.bfs import BlestProblem
from repro.core.bvss import build_bvss
from repro.core.level_pipeline import LevelPipeline, run_levels
from repro.core.multi_source import drive_wave, make_ms_engine
from repro.graphs import from_edges, generators as gen
from repro.kernels import bvss_spmm_t, bvss_spmm_w
from repro.kernels.ref import (betweenness_ref, bvss_spmm_t_ref,
                               bvss_spmm_w_ref, closeness_ref,
                               connected_components_ref, eccentricity_ref,
                               normalize_labels)
from repro.serve import GraphSession


def small_suite():
    return {
        "rmat": gen.rmat(6, 8, seed=1),
        "grid": gen.grid2d(8, 8, shuffle=True, seed=3),
        "star": gen.star(48),
        "clustered": gen.clustered(3, 16, seed=4),
        # many components + isolated vertices
        "disc": from_edges(40, np.array([0, 1, 2, 10, 11, 20, 21]),
                           np.array([1, 2, 0, 11, 12, 21, 22])),
    }


def empty_graph(n):
    z = np.array([], dtype=np.int64)
    return from_edges(n, z, z)


# ---------------------------------------------------------------------------
# weighted BVSS tile products
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sigma", [4, 8])
def test_weighted_tiles_match_refs(sigma):
    rng = np.random.default_rng(0)
    B, S = 9, 5
    spw = 32 // sigma
    masks = jnp.asarray(rng.integers(0, 2**32, (B, 32), dtype=np.uint32))
    xv = jnp.asarray(rng.random((B, sigma, S), dtype=np.float32))
    hv = jnp.asarray(rng.random((B, spw, 32, S), dtype=np.float32))
    np.testing.assert_allclose(
        np.asarray(bvss_spmm_w(masks, xv, sigma=sigma)),
        np.asarray(bvss_spmm_w_ref(masks, xv, sigma)), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(bvss_spmm_t(masks, hv, sigma=sigma)),
        np.asarray(bvss_spmm_t_ref(masks, hv, sigma)), rtol=1e-6)


# ---------------------------------------------------------------------------
# σ path-count channel (Brandes forward)
# ---------------------------------------------------------------------------
def _numpy_sigma(g, s):
    dist = np.full(g.n, -1, np.int64)
    sig = np.zeros(g.n)
    dist[s] = 0
    sig[s] = 1
    order = [int(s)]
    head = 0
    while head < len(order):
        v = order[head]
        head += 1
        for w in g.indices[g.indptr[v]:g.indptr[v + 1]]:
            w = int(w)
            if dist[w] < 0:
                dist[w] = dist[v] + 1
                order.append(w)
            if dist[w] == dist[v] + 1:
                sig[w] += sig[v]
    return dist, sig


@pytest.mark.parametrize("use_kernel", [True, False])
def test_sigma_channel_matches_per_source_counts(use_kernel):
    g = gen.rmat(6, 8, seed=2)
    problem = BlestProblem.build(build_bvss(g))
    srcs = np.array([3, 17, 42, 61], dtype=np.int32)
    eng = make_ms_engine(problem, len(srcs), use_kernel=use_kernel,
                         track_sigma=True)
    pipe = LevelPipeline(step=lambda s, lvl: eng.step(s),
                         finalize=lambda s, lvl: eng.finalize(s),
                         active=lambda s: s.cont)
    st, _ = run_levels(pipe, eng.init(jnp.asarray(srcs)),
                       max_levels=g.n + 1)
    levels = np.asarray(st.levels[:g.n])
    paths = np.asarray(st.paths)
    for c, s in enumerate(srcs):
        dist, sig = _numpy_sigma(g, s)
        assert (levels[:, c] == np.where(dist >= 0, dist, INF)).all()
        reached = dist >= 0
        np.testing.assert_allclose(paths[reached, c], sig[reached],
                                   rtol=1e-5)


def test_sigma_channel_survives_slot_refill():
    g = gen.rmat(6, 8, seed=3)
    problem = BlestProblem.build(build_bvss(g))
    eng = make_ms_engine(problem, 2, track_sigma=True)
    st = eng.init(jnp.asarray(np.array([5, 9], dtype=np.int32)))
    # run to convergence, then refill slot 0 and re-run
    for _ in range(g.n):
        st, live = eng.level_step(st)
        if not np.asarray(live).any():
            break
    st = eng.insert_batch(st, jnp.asarray(np.array([23, 0], np.int32)),
                          jnp.asarray(np.array([True, False])))
    for _ in range(g.n):
        st, live = eng.level_step(st)
        if not np.asarray(live).any():
            break
    dist, sig = _numpy_sigma(g, 23)
    reached = dist >= 0
    np.testing.assert_allclose(np.asarray(st.paths)[reached, 0],
                               sig[reached], rtol=1e-5)


# ---------------------------------------------------------------------------
# connected components
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(small_suite()))
def test_components_match_scipy(name):
    g = small_suite()[name]
    labels = connected_components(g, max_batch=4)
    assert (labels == connected_components_ref(g)).all()


def test_components_edge_cases():
    # single-vertex graph
    assert (connected_components(empty_graph(1)) == [0]).all()
    # all-isolated vertices: n singleton components
    labels = connected_components(empty_graph(17), max_batch=4)
    assert (labels == np.arange(17)).all()
    # empty graph
    assert len(connected_components(empty_graph(0))) == 0


def test_components_label_normalisation():
    g = small_suite()["disc"]
    labels = connected_components(g, max_batch=4)
    # normalised: first occurrence of each label is in increasing order
    firsts = [int(np.flatnonzero(labels == c)[0])
              for c in range(labels.max() + 1)]
    assert firsts == sorted(firsts)
    assert (labels == normalize_labels(labels)).all()


# ---------------------------------------------------------------------------
# eccentricity / iFUB
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["rmat", "grid", "star"])
def test_eccentricities_match_scipy(name):
    g = small_suite()[name].symmetrized
    srcs = np.random.default_rng(1).integers(0, g.n, 6)
    ecc = eccentricities(srcs, g=g, batch=4)
    assert (ecc == eccentricity_ref(g, srcs)).all()


@pytest.mark.parametrize("name", ["rmat", "grid", "star", "clustered"])
def test_ifub_certifies_exact_diameter(name):
    g = small_suite()[name]
    gs = g.symmetrized
    rep = ifub_extremes(g, batch=4)
    assert rep.exact
    ecc_all = eccentricity_ref(gs, np.arange(g.n))
    # ifub starts from a max-degree vertex: its component's diameter
    start = int(np.argmax(gs.out_degree + gs.in_degree))
    comp = connected_components_ref(g)
    members = comp == comp[start]
    assert rep.diameter == ecc_all[members].max()
    assert rep.radius_ub >= ecc_all[members].min()
    assert rep.n_ecc_evals <= g.n + 2


def test_ifub_certification_is_sound_from_unlucky_start():
    """Regression: the certification threshold at the top of fringe i is
    lb > 2*i (fringe i is not yet evaluated there) — the old 2*(i-1)
    check certified diameter 3 as exact on this graph (true diameter 4,
    e.g. d(2, 6)) when started from vertex 5."""
    e = [(1, 0), (2, 0), (3, 1), (4, 3), (5, 4), (6, 3), (7, 4), (4, 0)]
    src = np.array([a for a, b in e] + [b for a, b in e])
    dst = np.array([b for a, b in e] + [a for a, b in e])
    g = from_edges(8, src, dst)
    true_d = int(eccentricity_ref(g, np.arange(8)).max())
    assert true_d == 4
    for start in range(8):
        rep = ifub_extremes(g, start=start, batch=4)
        assert rep.diameter_lb <= true_d <= rep.diameter_ub, (start, rep)
        if rep.exact:
            assert rep.diameter == true_d, (start, rep)


def test_ifub_budget_returns_bounds():
    g = small_suite()["grid"]
    rep = ifub_extremes(g, batch=4, max_evals=4)
    assert rep.diameter_lb <= rep.diameter_ub
    ecc_all = eccentricity_ref(g.symmetrized, np.arange(g.n))
    assert rep.diameter_lb <= ecc_all.max() <= rep.diameter_ub


# ---------------------------------------------------------------------------
# betweenness
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(small_suite()))
def test_betweenness_matches_brandes_oracle(name):
    g = small_suite()[name]
    srcs = np.random.default_rng(2).integers(0, g.n, 5)
    bc = betweenness_centrality(g, srcs, batch=4)
    ref = betweenness_ref(g, srcs)
    np.testing.assert_allclose(bc, ref, rtol=1e-4, atol=1e-4)


def test_betweenness_ref_matches_networkx():
    nx = pytest.importorskip("networkx")
    g = gen.rmat(6, 6, seed=5)
    G = nx.DiGraph()
    G.add_nodes_from(range(g.n))
    for u in range(g.n):
        for v in g.indices[g.indptr[u]:g.indptr[u + 1]]:
            G.add_edge(u, int(v))
    ref = betweenness_ref(g, np.arange(g.n))
    nx_bc = np.array([b for _, b in sorted(
        nx.betweenness_centrality(G, normalized=False).items())])
    np.testing.assert_allclose(ref, nx_bc, rtol=1e-9, atol=1e-9)


def test_betweenness_edge_cases():
    # single vertex / no edges: all zeros
    assert (betweenness_centrality(empty_graph(1), [0]) == 0).all()
    bc = betweenness_centrality(empty_graph(9), [0, 4, 8], batch=2)
    assert (bc == 0).all()
    # empty source set
    g = small_suite()["rmat"]
    assert (betweenness_centrality(g, []) == 0).all()
    # duplicated sources count once each (two copies = 2x one copy)
    one = betweenness_centrality(g, [7], batch=2)
    two = betweenness_centrality(g, [7, 7], batch=2)
    np.testing.assert_allclose(two, 2 * one, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# closeness
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(small_suite()))
def test_closeness_matches_scipy_oracle(name):
    g = small_suite()[name]
    srcs = np.random.default_rng(6).integers(0, g.n, 5)
    cc = closeness_centrality(g, srcs, batch=4)
    np.testing.assert_allclose(cc, closeness_ref(g, srcs), rtol=1e-12)


@pytest.mark.parametrize("wf", [False, True])
def test_exact_closeness_matches_networkx(wf):
    """The acceptance oracle: exact closeness on the directed graph must
    equal NetworkX's (which measures INWARD distance — hence
    ``G.reverse()`` to compare with our outward wave columns)."""
    nx = pytest.importorskip("networkx")
    g = gen.rmat(6, 6, seed=5)
    cc = closeness_centrality(g, None, batch=4, wf_improved=wf)
    G = nx.DiGraph()
    G.add_nodes_from(range(g.n))
    for u in range(g.n):
        for v in g.indices[g.indptr[u]:g.indptr[u + 1]]:
            G.add_edge(u, int(v))
    nx_cc = np.array([c for _, c in sorted(
        nx.closeness_centrality(G.reverse(), wf_improved=wf).items())])
    np.testing.assert_allclose(cc, nx_cc, rtol=1e-9, atol=1e-12)


def test_closeness_edge_cases():
    # isolated vertices score 0; empty source set returns empty
    assert (closeness_centrality(empty_graph(9), [0, 4, 8], batch=2)
            == 0).all()
    assert len(closeness_centrality(small_suite()["rmat"], [])) == 0
    # single-vertex graph, exact mode
    assert (closeness_centrality(empty_graph(1)) == [0.0]).all()
    # duplicated sources give identical scores
    g = small_suite()["grid"]
    cc = closeness_centrality(g, [7, 7, 3], batch=2)
    assert cc[0] == cc[1]


def test_session_closeness_caller_ids():
    g = gen.rmat(6, 8, seed=1)     # ordering ON: internal ids != caller ids
    sess = GraphSession(g, max_batch=4)
    srcs = np.random.default_rng(7).integers(0, g.n, 5)
    np.testing.assert_allclose(sess.closeness(srcs),
                               closeness_ref(g, srcs), rtol=1e-12)
    # exact mode: one score per vertex, caller-id order, + WF scaling
    np.testing.assert_allclose(sess.closeness(), closeness_ref(g),
                               rtol=1e-12)
    np.testing.assert_allclose(sess.closeness(wf_improved=True),
                               closeness_ref(g, wf_improved=True),
                               rtol=1e-12)


# ---------------------------------------------------------------------------
# GraphSession query kinds: caller-id contract
# ---------------------------------------------------------------------------
def test_session_analytics_caller_ids():
    g = gen.rmat(6, 8, seed=1)     # ordering ON: internal ids != caller ids
    sess = GraphSession(g, max_batch=4)
    assert (sess.components() == connected_components_ref(g)).all()
    srcs = np.random.default_rng(3).integers(0, g.n, 5)
    assert (sess.eccentricity(srcs)
            == eccentricity_ref(g.symmetrized, srcs)).all()
    np.testing.assert_allclose(sess.betweenness(srcs),
                               betweenness_ref(g, srcs),
                               rtol=1e-4, atol=1e-4)
    rep = sess.extremes()
    assert rep.exact
    comp = connected_components_ref(g)
    big = np.bincount(comp).argmax()
    ecc_all = eccentricity_ref(g.symmetrized, np.arange(g.n))
    assert rep.diameter == ecc_all[comp == big].max()
    # center/periphery are caller ids inside the largest component
    assert comp[rep.center] == big and comp[rep.periphery] == big


def test_session_betweenness_sample_aligned():
    g = small_suite()["clustered"]
    sess = GraphSession(g, max_batch=4)
    srcs, bc = sess.betweenness_sample(4, seed=11)
    assert len(set(srcs.tolist())) == 4
    np.testing.assert_allclose(bc, betweenness_ref(g, srcs),
                               rtol=1e-4, atol=1e-4)


def test_drive_wave_generic_hook_serves_levels():
    g = small_suite()["rmat"]
    problem = BlestProblem.build(build_bvss(g))
    eng = make_ms_engine(problem, 3)
    pending = [1, 5, 9, 33, 50]
    owner, results = {}, {}

    def next_source(slot):
        if not pending:
            return None
        s = pending.pop()
        owner[slot] = s
        return s

    def on_converged(slot, lv):
        results[owner[slot]] = lv

    drive_wave(eng, next_source, on_converged, max_steps=10 * g.n)
    assert len(results) == 5
    for s, lv in results.items():
        assert (lv == reference_bfs(g, s)).all()


# ---------------------------------------------------------------------------
# sharded parity — runs whenever the process has >= 2 devices (the CI
# multidevice job, where BLEST_REQUIRE_MULTIDEVICE turns a would-be skip
# into a FAILURE, so the suite provably executes with 0 skips)
# ---------------------------------------------------------------------------
def test_sharded_components_parity():
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh
    g = gen.rmat(6, 8, seed=1)
    sess1 = GraphSession(g, max_batch=4)
    sessD = GraphSession(g, max_batch=4, mesh=bfs_mesh(2))
    labels1, labelsD = sess1.components(), sessD.components()
    assert (labels1 == labelsD).all()
    assert (labelsD == connected_components_ref(g)).all()


def test_sharded_betweenness_parity():
    """Mesh-native Brandes (the acceptance criterion): a sharded session's
    betweenness must match the single-device result to <= 1e-6 REL error
    with ZERO replicated weighted sweeps — the forward σ wave and the
    backward tile sweep both run under shard_map on the session's own
    row-sharded problem, and no single-device twin is ever built."""
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh
    g = gen.rmat(6, 8, seed=1)
    sess1 = GraphSession(g, max_batch=4)
    sessD = GraphSession(g, max_batch=4, mesh=bfs_mesh(2))
    srcs = np.random.default_rng(4).integers(0, g.n, 4)
    bc1, bcD = sess1.betweenness(srcs), sessD.betweenness(srcs)
    scale = max(float(np.abs(bc1).max()), 1.0)
    assert float(np.abs(bcD - bc1).max()) / scale <= 1e-6
    np.testing.assert_allclose(bcD, betweenness_ref(g, srcs),
                               rtol=1e-4, atol=1e-4)
    # zero replication: the sharded session never builds a replicated
    # single-device σ problem — every cached analytics problem carries
    # the mesh, and the cached Brandes fn was built on the sharded one
    assert "bc_problem" not in sessD._analytics_cache
    for key, val in sessD._analytics_cache.items():
        if isinstance(val, BlestProblem):
            assert val.mesh is not None, key


def test_sharded_eccentricity_parity():
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh
    g = gen.grid2d(8, 8, shuffle=True, seed=3)
    sessD = GraphSession(g, max_batch=4, mesh=bfs_mesh(2))
    srcs = np.random.default_rng(5).integers(0, g.n, 5)
    assert (sessD.eccentricity(srcs)
            == eccentricity_ref(g.symmetrized, srcs)).all()


def test_sharded_sigma_channel_refill_parity():
    """The generic sharded float channel on the HOST-DRIVEN wave surface:
    a 2-device track_sigma engine must survive a mid-flight insert_batch
    refill with exact per-source σ counts (read back through the
    engine's ``paths_of`` shard-layout-hiding view)."""
    require_devices(2)
    from repro.core.bvss import build_sharded_bvss
    from repro.distributed.bfs_dist import bfs_mesh
    g = gen.rmat(6, 8, seed=3)
    mesh = bfs_mesh(2)
    pD = BlestProblem.build_sharded(build_sharded_bvss(g, 2), mesh)
    eng = make_ms_engine(pD, 2, track_sigma=True)
    st = eng.init(jnp.asarray(np.array([5, 9], np.int32)))
    for _ in range(g.n):
        st, live = eng.level_step(st)
        if not np.asarray(live).any():
            break
    st = eng.insert_batch(st, jnp.asarray(np.array([23, 0], np.int32)),
                          jnp.asarray(np.array([True, False])))
    for _ in range(g.n):
        st, live = eng.level_step(st)
        if not np.asarray(live).any():
            break
    for slot, src in ((0, 23), (1, 9)):
        dist, sig = _numpy_sigma(g, src)
        reached = dist >= 0
        np.testing.assert_allclose(
            np.asarray(eng.paths_of(st, slot))[reached], sig[reached],
            rtol=1e-5, err_msg=f"slot {slot} source {src}")


def test_sharded_closeness_parity():
    """The fifth verb rides the same sharded surface: sampled AND exact
    closeness on a 2-device session must match the single-device scores
    and the SciPy oracle exactly (levels are integers; the reduction is
    deterministic)."""
    require_devices(2)
    from repro.distributed.bfs_dist import bfs_mesh
    g = gen.clustered(3, 16, seed=4)   # several components + ragged n
    sess1 = GraphSession(g, max_batch=4)
    sessD = GraphSession(g, max_batch=4, mesh=bfs_mesh(2))
    srcs = np.random.default_rng(8).integers(0, g.n, 5)
    np.testing.assert_allclose(sessD.closeness(srcs), sess1.closeness(srcs),
                               rtol=1e-12)
    np.testing.assert_allclose(sessD.closeness(srcs), closeness_ref(g, srcs),
                               rtol=1e-12)
    np.testing.assert_allclose(sessD.closeness(), closeness_ref(g),
                               rtol=1e-12)
